"""Quickstart: train a small LM on the unified compute unit, then sample.

    PYTHONPATH=src python examples/quickstart.py

Uses the reduced qwen2-0.5b family config on CPU; the identical code path
(train step, sharding rules, checkpointing) runs the full config on the
256/512-chip meshes — see src/repro/launch/dryrun.py.
"""
import jax
import jax.numpy as jnp

from repro.configs import all_configs, reduced
from repro.data.pipeline import synthetic_batch
from repro.launch.serve import generate
from repro.launch.steps import make_train_step
from repro.models import transformer as T
from repro.optim import AdamW, adamw_init, cosine_warmup


def main():
    cfg = reduced(all_configs()["qwen2-0.5b"])
    print(f"arch: {cfg.name} ({cfg.n_layers}L d={cfg.d_model} vocab={cfg.vocab})")

    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt = AdamW(lr=cosine_warmup(2e-3, 10, 120))
    opt_state = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, opt=opt), donate_argnums=(0, 1))

    losses = []
    for step in range(120):
        batch = {"tokens": synthetic_batch(0, step, 8, 128, cfg.vocab)}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % 20 == 0 or step == 119:
            print(f"step {step:4d}  loss {losses[-1]:.4f}  "
                  f"lr {float(metrics['lr']):.2e}")

    assert losses[-1] < losses[0], "loss should decrease"
    print(f"\nloss {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")

    prompts = synthetic_batch(1, 0, 2, 16, cfg.vocab)
    out = generate(cfg, params, prompts, gen=12)
    print("sampled continuations:")
    for row in out:
        print("  ", row.tolist())


if __name__ == "__main__":
    main()
