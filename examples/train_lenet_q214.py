"""Paper-faithful end-to-end example: LeNet on the unified compute unit with
Qm.n quantization-aware training, deployed on the grid-resident QTensor path.

This is the paper's deployment story in miniature:
  1. train float (conv + FC all routed through the Template compute unit)
  2. fine-tune with fake-quant (straight-through estimator) on the chosen
     grid — Q2.14 trains activations into [-2, 2); ``--fmt q17`` instead
     clamps into [-1, 1) so the network is int8-ready on the Q1.7 rung
  3. deploy: calibrate the activation grid from one batch, quantize the
     weights **once** into QTensors, and run inference entirely in int16
     fixed point — the whole network performs exactly one quantize (the
     input) and one dequantize (the classifier read-out), the stay-on-grid
     dataflow an FPGA build of the paper's template executes (DESIGN.md §8).
  4. precision DSE: measure per-layer drift against the fake-quant
     reference and drop every layer that tolerates it to the int8 rung
     (Q2.14 -> Q2.6, Q1.7 stays 8-bit), halving activation bytes
     (DESIGN.md §11).

    PYTHONPATH=src python examples/train_lenet_q214.py [--fmt q17]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core.quantization import Q1_7, Q2_14
from repro.core.template import default_template
from repro.data.pipeline import synthetic_images
from repro.models.cnn import (
    LENET,
    calibrate_cnn_policy,
    calibrate_cnn_precision,
    cnn_forward,
    init_cnn,
    quantize_cnn_params,
)
from repro.optim import AdamW, adamw_init, adamw_update


def accuracy(tpl, params, step0, n=4, quantized=False, fmt=Q2_14):
    hits = tot = 0
    for s in range(n):
        img, lab = synthetic_images(99, step0 + s, 32, LENET.input_hw,
                                    LENET.input_ch, LENET.n_classes)
        logits = cnn_forward(tpl, LENET, params, img, quantized=quantized,
                             fmt=fmt)
        hits += int((jnp.argmax(logits, -1) == lab).sum())
        tot += lab.shape[0]
    return hits / tot


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fmt", choices=["q214", "q17"], default="q214",
                    help="fake-quant grid for the QAT fine-tune: q214 trains "
                         "activations into [-2,2), q17 into [-1,1)")
    args = ap.parse_args(argv)
    fq = Q1_7 if args.fmt == "q17" else Q2_14

    tpl = default_template("xla")
    params = init_cnn(jax.random.PRNGKey(0), LENET, scale=0.4)
    opt = AdamW(lr=3e-3, weight_decay=0.0)
    opt_state = adamw_init(params)

    def loss_fn(p, img, lab, quantized):
        logits = cnn_forward(tpl, LENET, p, img, quantized=quantized, fmt=fq)
        onehot = jax.nn.one_hot(lab, LENET.n_classes)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        return -(onehot * logp).sum(-1).mean()

    from functools import partial

    @partial(jax.jit, static_argnums=(4,))
    def train_step(p, o, img, lab, quantized):
        l, g = jax.value_and_grad(loss_fn)(p, img, lab, quantized)
        p, o, _ = adamw_update(opt, g, o, p)
        return p, o, l

    print("phase 1: float training")
    for step in range(60):
        img, lab = synthetic_images(0, step, 32, 32, 1, 10)
        params, opt_state, l = train_step(params, opt_state, img, lab, False)
        if step % 20 == 0:
            print(f"  step {step:3d} loss {float(l):.4f}")

    print(f"phase 2: {fq.name} quantization-aware fine-tune (STE)")
    for step in range(60, 90):
        img, lab = synthetic_images(0, step, 32, 32, 1, 10)
        params, opt_state, l = train_step(params, opt_state, img, lab, True)
    print(f"  final QAT loss {float(l):.4f}")

    acc_f = accuracy(tpl, params, 1000, quantized=False)
    acc_q = accuracy(tpl, params, 1000, quantized=True, fmt=fq)
    print(f"\naccuracy float={acc_f:.2%}  fake-quant {fq.name}={acc_q:.2%}")

    # deployment numerics: calibrate once, quantize weights once, then run
    # the whole network grid-resident in int16 (QTensor path, DESIGN.md §8)
    tpl_q16 = default_template("q16")
    cal_img, _ = synthetic_images(7, 0, 16, 32, 1, 10)
    policy = calibrate_cnn_policy(tpl_q16, LENET, params, cal_img)
    qparams = quantize_cnn_params(tpl_q16, LENET, params, policy)
    print(f"\ndeploy: activations on {policy.fmt.name} (max-abs calibrated), "
          f"weights per-tensor Qm.n, quantized once")

    eng = tpl_q16.engine
    q0, d0 = eng.counters["quantize_calls"], eng.counters["dequantize_calls"]
    img, lab = synthetic_images(99, 2000, 16, 32, 1, 10)
    lf = cnn_forward(tpl, LENET, params, img, quantized=True, fmt=fq)
    lq = cnn_forward(tpl_q16, LENET, qparams, img, policy=policy)
    agree = float((jnp.argmax(lf, -1) == jnp.argmax(lq, -1)).mean())
    print(f"grid-resident q16 vs float-backend argmax agreement: {agree:.2%} "
          f"(max |logit diff| {float(jnp.abs(lf - lq).max()):.4f})")
    print(f"float islands crossed per forward: "
          f"{eng.counters['quantize_calls'] - q0} quantize / "
          f"{eng.counters['dequantize_calls'] - d0} dequantize "
          f"(input + classifier read-out only)")

    # quantize-once: a second inference call reuses the cached qparams —
    # the engine's qparam cache reports a hit, not a rebuild
    b0 = eng.counters["qparam_builds"]
    qparams2 = quantize_cnn_params(tpl_q16, LENET, params, policy)
    assert qparams2 is qparams and eng.counters["qparam_builds"] == b0
    print(f"qparam cache: {eng.counters['qparam_builds']} build(s), "
          f"{eng.counters['qparam_cache_hits']} hit(s) — weights quantized once")

    # precision DSE: the QAT clamp is part of the trained model, so the
    # fake-quant forward is the accuracy reference (DESIGN.md §11) — an
    # unclamped float reference would penalize the grid for saturating
    # activations the training loop deliberately clamped.
    ref = jnp.argmax(lf, -1)
    mixed = calibrate_cnn_precision(tpl_q16, LENET, params, img,
                                    budget=0.99, policy=policy, ref=ref)
    plan = dict(mixed.layer_fmts)
    int8 = sorted(n for n, f in plan.items() if f.total_bits == 8)
    print(f"\nprecision DSE (budget 0.99): base {mixed.fmt.name}, "
          f"{len(int8)}/{len(plan)} layers on the int8 rung -> "
          f"{ {n: f.name for n, f in sorted(plan.items())} }")
    if int8:
        lm = cnn_forward(tpl_q16, LENET,
                         quantize_cnn_params(tpl_q16, LENET, params, mixed),
                         img, policy=mixed)
        am = float((jnp.argmax(lf, -1) == jnp.argmax(lm, -1)).mean())
        print(f"mixed int8/int16 argmax agreement vs fake-quant ref: {am:.2%}")


if __name__ == "__main__":
    main()
