"""Paper-faithful end-to-end example: LeNet on the unified compute unit with
Q2.14 quantization-aware training, evaluated with the fixed-point GEMM path.

This is the paper's deployment story in miniature:
  1. train float (conv + FC all routed through the Template compute unit)
  2. fine-tune with fake-quant Q2.14 (straight-through estimator)
  3. deploy: inference through the int16 Q2.14 kernel path ("q16" backend),
     the numerics an FPGA build of the paper's template executes.

    PYTHONPATH=src python examples/train_lenet_q214.py
"""
import jax
import jax.numpy as jnp

from repro.core.template import default_template
from repro.data.pipeline import synthetic_images
from repro.models.cnn import LENET, cnn_forward, init_cnn
from repro.optim import AdamW, adamw_init, adamw_update


def accuracy(tpl, params, step0, n=4, quantized=False):
    hits = tot = 0
    for s in range(n):
        img, lab = synthetic_images(99, step0 + s, 32, LENET.input_hw,
                                    LENET.input_ch, LENET.n_classes)
        logits = cnn_forward(tpl, LENET, params, img, quantized=quantized)
        hits += int((jnp.argmax(logits, -1) == lab).sum())
        tot += lab.shape[0]
    return hits / tot


def main():
    tpl = default_template("xla")
    params = init_cnn(jax.random.PRNGKey(0), LENET, scale=0.4)
    opt = AdamW(lr=3e-3, weight_decay=0.0)
    opt_state = adamw_init(params)

    def loss_fn(p, img, lab, quantized):
        logits = cnn_forward(tpl, LENET, p, img, quantized=quantized)
        onehot = jax.nn.one_hot(lab, LENET.n_classes)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        return -(onehot * logp).sum(-1).mean()

    from functools import partial

    @partial(jax.jit, static_argnums=(4,))
    def train_step(p, o, img, lab, quantized):
        l, g = jax.value_and_grad(loss_fn)(p, img, lab, quantized)
        p, o, _ = adamw_update(opt, g, o, p)
        return p, o, l

    print("phase 1: float training")
    for step in range(60):
        img, lab = synthetic_images(0, step, 32, 32, 1, 10)
        params, opt_state, l = train_step(params, opt_state, img, lab, False)
        if step % 20 == 0:
            print(f"  step {step:3d} loss {float(l):.4f}")

    print("phase 2: Q2.14 quantization-aware fine-tune (STE)")
    for step in range(60, 90):
        img, lab = synthetic_images(0, step, 32, 32, 1, 10)
        params, opt_state, l = train_step(params, opt_state, img, lab, True)
    print(f"  final QAT loss {float(l):.4f}")

    acc_f = accuracy(tpl, params, 1000, quantized=False)
    acc_q = accuracy(tpl, params, 1000, quantized=True)
    print(f"\naccuracy float={acc_f:.2%}  fake-quant Q2.14={acc_q:.2%}")

    # deployment numerics: the int16 fixed-point kernel path end to end
    tpl_q16 = default_template("q16")
    img, lab = synthetic_images(99, 2000, 16, 32, 1, 10)
    lf = cnn_forward(tpl, LENET, params, img, quantized=True)
    lq = cnn_forward(tpl_q16, LENET, params, img, quantized=True)
    agree = float((jnp.argmax(lf, -1) == jnp.argmax(lq, -1)).mean())
    print(f"q16-kernel vs float-backend argmax agreement: {agree:.2%} "
          f"(max |logit diff| {float(jnp.abs(lf - lq).max()):.4f})")


if __name__ == "__main__":
    main()
