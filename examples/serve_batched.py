"""Batched serving across architectures: prefill a prompt batch, decode with
ring-buffer KV caches / recurrent states, compare decode parity vs the
teacher-forced forward.

    PYTHONPATH=src python examples/serve_batched.py [arch ...]

Runs reduced configs on CPU; the full-size serving graphs are the
prefill_32k / decode_32k / long_500k dry-run cells.
"""
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import all_configs, reduced
from repro.core.template import default_template
from repro.data.pipeline import synthetic_batch
from repro.launch.serve import generate
from repro.models import transformer as T

DEFAULT = ["qwen2-0.5b", "mamba2-1.3b", "recurrentgemma-9b", "whisper-medium"]


def run(name: str):
    cfg = reduced(all_configs()[name])
    tpl = default_template()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    b, s, gen = 4, 24, 12
    prompts = synthetic_batch(0, 0, b, s, cfg.vocab)
    ctx = None
    if cfg.family == "encdec":
        ctx = jax.random.normal(jax.random.PRNGKey(1), (b, cfg.n_frames, cfg.d_model)) * 0.1
    elif cfg.family == "vlm":
        ctx = jax.random.normal(jax.random.PRNGKey(1), (b, cfg.n_image_tokens, cfg.d_model)) * 0.1

    # correctness: greedy decode continuation == greedy argmax of forward
    logits_full, _ = T.forward(tpl, cfg, params, prompts, ctx=ctx)
    lg_pre, cache = T.prefill(tpl, cfg, params, prompts[:, :-1], ctx=ctx,
                              cache_len=s + gen)
    lg_dec, _ = T.decode_step(tpl, cfg, params, prompts[:, -1:], s - 1, cache)
    err = float(np.abs(np.asarray(lg_dec) - np.asarray(logits_full[:, -1])).max())

    t0 = time.time()
    out = generate(cfg, params, prompts, ctx, gen=gen)
    dt = time.time() - t0
    print(f"{name:24s} batch={b} prompt={s} +{gen} tok  "
          f"{b * gen / dt:6.1f} tok/s  decode-parity err {err:.1e}")
    return out


def main():
    archs = sys.argv[1:] or DEFAULT
    print(f"{'arch':24s} throughput (CPU, reduced configs)")
    for name in archs:
        run(name)


if __name__ == "__main__":
    main()
