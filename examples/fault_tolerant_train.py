"""Fault-tolerance drill: train with injected failures, atomic checkpoints,
auto-resume, and straggler detection — the runtime features a 1000-node
deployment leans on, exercised end to end on CPU.

    PYTHONPATH=src python examples/fault_tolerant_train.py
"""
import tempfile

from repro.launch.train import main as train_main
from repro.runtime import HeartbeatMonitor, detect_stragglers
from repro.runtime.failover import plan_elastic_remesh


def main():
    with tempfile.TemporaryDirectory() as ckpt:
        print("== crash-loop training: failures injected at steps 8 and 17 ==")
        stats, history = train_main([
            "--arch", "internlm2-1.8b", "--steps", "24", "--batch", "4",
            "--seq", "64", "--ckpt-every", "6", "--ckpt-dir", ckpt,
            "--fail-at", "8", "--fail-at", "17", "--log-every", "6",
        ])
        print(f"survived {stats['failures']} failures, "
              f"restarted from checkpoints at {stats['restarts']}")
        assert history[-1] < history[0]

    print("\n== heartbeat / straggler policy ==")
    mon = HeartbeatMonitor([f"host{i}" for i in range(8)], timeout_steps=3)
    for step in range(6):
        for i in range(8):
            if i == 5 and step >= 3:
                continue  # host5 dies at step 3
            t = 1.0 if i != 2 else (1.0 if step < 2 else 3.5)  # host2 slows
            mon.report(f"host{i}", step, t)
    print("dead hosts:", mon.dead_hosts(current_step=5))
    print("stragglers:", mon.stragglers(factor=2.0, patience=3))

    print("\n== elastic re-mesh decision after losing 8 hosts ==")
    plan = plan_elastic_remesh({"pod": 2, "data": 16, "model": 16},
                               lost_hosts=8, hosts_per_replica=4)
    print(f"mesh {plan.old_shape} -> {plan.new_shape}: {plan.note}")
    print("(checkpoint restore re-shards state onto the shrunken mesh — "
          "see tests/test_checkpoint_failover.py)")


if __name__ == "__main__":
    main()
