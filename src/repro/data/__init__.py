from .pipeline import DataPipeline, make_pipeline, synthetic_batch

__all__ = ["DataPipeline", "make_pipeline", "synthetic_batch"]
