"""Deterministic synthetic data pipeline, sharded onto the mesh.

Production data loaders are I/O systems; what the framework needs from this
substrate is (a) *determinism under restart* — batch(step) must be a pure
function of the step index so checkpoint-resume replays identical data with
no loader state to snapshot, (b) *device placement* — batches land already
sharded over the mesh's batch axes, and (c) a learnable signal so examples
show loss going down.

Tokens follow a stationary order-k Markov chain derived from a hash mix of
(seed, step, position) — cheap, reproducible, and compressible (so
cross-entropy decreases measurably within a few hundred steps).  Images are
class-conditional Gaussian blobs for the CNN examples.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.sharding import ShardingRules, named_sharding

__all__ = ["DataPipeline", "make_pipeline", "synthetic_batch", "synthetic_images"]


def _batch_key(seed: int, step: int) -> jax.Array:
    return jax.random.fold_in(jax.random.PRNGKey(seed), step)


def synthetic_batch(seed: int, step: int, batch: int, seq: int, vocab: int) -> jax.Array:
    """(batch, seq) int32 tokens; a deterministic pure function of (seed, step).

    Order-1 Markov structure: token_{t+1} = (a * token_t + noise) % vocab with
    per-sequence offsets — enough mutual information for a 100M model to show
    a clearly decreasing loss curve.
    """
    key = _batch_key(seed, step)
    k1, k2, k3 = jax.random.split(key, 3)
    first = jax.random.randint(k1, (batch, 1), 0, vocab)
    noise = jax.random.randint(k2, (batch, seq - 1), 0, max(2, vocab // 64))
    mult = 31

    def body(tok, n):
        nxt = (tok * mult + n + 7) % vocab
        return nxt, nxt

    _, rest = jax.lax.scan(body, first[:, 0], noise.T)
    return jnp.concatenate([first, rest.T], axis=1).astype(jnp.int32)


def synthetic_images(seed: int, step: int, batch: int, hw: int, ch: int,
                     n_classes: int):
    """Class-conditional blobs: (images (B,H,W,C) in [-1,1], labels (B,))."""
    key = _batch_key(seed, step)
    k1, k2, k3 = jax.random.split(key, 3)
    labels = jax.random.randint(k1, (batch,), 0, n_classes)
    yy, xx = jnp.mgrid[0:hw, 0:hw].astype(jnp.float32) / hw
    cy = (labels % 4).astype(jnp.float32) / 4.0 + 0.125
    cx = ((labels // 4) % 4).astype(jnp.float32) / 4.0 + 0.125
    d2 = (yy[None] - cy[:, None, None]) ** 2 + (xx[None] - cx[:, None, None]) ** 2
    blob = jnp.exp(-d2 * (8.0 + (labels % 3))[:, None, None].astype(jnp.float32))
    noise = 0.1 * jax.random.normal(k2, (batch, hw, hw, ch))
    img = blob[..., None] * jnp.ones((ch,)) + noise
    return (img * 2.0 - 1.0).astype(jnp.float32), labels.astype(jnp.int32)


@dataclasses.dataclass
class DataPipeline:
    """Sharded token pipeline for one (arch, shape) workload."""

    seed: int
    global_batch: int
    seq_len: int
    vocab: int
    ctx_len: int = 0  # encdec/vlm context stub length (0 = none)
    d_model: int = 0
    mesh: Optional[object] = None
    rules: Optional[ShardingRules] = None

    def batch(self, step: int) -> dict:
        out = {
            "tokens": synthetic_batch(
                self.seed, step, self.global_batch, self.seq_len, self.vocab
            )
        }
        if self.ctx_len:
            key = _batch_key(self.seed ^ 0x5EED, step)
            out["ctx"] = (
                jax.random.normal(key, (self.global_batch, self.ctx_len, self.d_model))
                * 0.1
            ).astype(jnp.float32)
        if self.mesh is not None and self.rules is not None:
            tok_sh = named_sharding(
                self.mesh, self.rules, ("batch", None),
                dim_sizes=out["tokens"].shape,
            )
            out["tokens"] = jax.device_put(out["tokens"], tok_sh)
            if "ctx" in out:
                ctx_sh = named_sharding(
                    self.mesh, self.rules, ("batch", "ctx", None),
                    dim_sizes=out["ctx"].shape,
                )
                out["ctx"] = jax.device_put(out["ctx"], ctx_sh)
        return out


def make_pipeline(cfg, shape, *, seed: int = 0, mesh=None, rules=None,
                  global_batch: Optional[int] = None,
                  seq_len: Optional[int] = None) -> DataPipeline:
    ctx_len = 0
    if cfg.family == "encdec":
        ctx_len = cfg.n_frames
    elif cfg.family == "vlm":
        ctx_len = cfg.n_image_tokens
    return DataPipeline(
        seed=seed,
        global_batch=global_batch or shape.global_batch,
        seq_len=seq_len or shape.seq_len,
        vocab=cfg.vocab,
        ctx_len=ctx_len,
        d_model=cfg.d_model,
        mesh=mesh,
        rules=rules,
    )
