from .failover import (
    FailureInjector,
    HeartbeatMonitor,
    SimulatedFailure,
    detect_stragglers,
    run_with_restarts,
)

__all__ = [
    "FailureInjector",
    "HeartbeatMonitor",
    "SimulatedFailure",
    "detect_stragglers",
    "run_with_restarts",
]
