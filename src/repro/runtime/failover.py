"""Fault-tolerance runtime: heartbeats, straggler policy, restart loop.

At 1000+ node scale the MTBF of the *job* is hours even when per-node MTBF is
months; the runtime therefore treats failure as the steady state:

* :class:`HeartbeatMonitor` — per-host step-time reports; hosts silent for
  ``timeout_steps`` are declared dead.  On a real deployment heartbeats ride
  the coordination service (GCS / etcd); here they are process-local state
  with the identical decision logic, unit-tested by simulation.
* :func:`detect_stragglers` — median-based outlier policy (a host is a
  straggler when its step time exceeds ``factor`` x the fleet median for
  ``patience`` consecutive steps).  The mitigation at mesh level is elastic:
  drop the replica's hosts and re-mesh (checkpoint restore handles the
  re-shard — see checkpoint/manager.py).
* :func:`run_with_restarts` — the crash-loop driver: run the step function,
  on failure restore the latest checkpoint and continue, up to
  ``max_failures``.  Training state is (params, opt, step) + a pure-function
  data pipeline, so resume is exact.
* :class:`FailureInjector` — deterministic fault injection for tests and
  chaos drills (fail at given steps, or with given probability).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

__all__ = [
    "SimulatedFailure",
    "FailureInjector",
    "FaultPlan",
    "HeartbeatMonitor",
    "detect_stragglers",
    "run_with_restarts",
    "ElasticPlan",
    "plan_elastic_remesh",
]


class SimulatedFailure(RuntimeError):
    """A injected/hardware failure surfaced to the restart loop."""


@dataclasses.dataclass
class FailureInjector:
    """Raise SimulatedFailure at chosen steps (deterministic chaos)."""

    fail_at_steps: Sequence[int] = ()
    fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic, replayable fault schedule keyed to *virtual ticks*.

    The serving analogue of :class:`FailureInjector`: instead of raising at
    training steps, it tells the :class:`~repro.launch.router.ReplicaRouter`
    what goes wrong at which tick of its event loop, so the same trace +
    the same FaultPlan replays to the same token stream every run
    (DESIGN.md §9).  Three fault species:

    * ``kills``: ``(tick, replica)`` — replica dies at the *start* of the
      tick (its last completed step was ``tick - 1``); the router drives
      checkpoint-restore + requeue of its in-flight sessions.
    * ``reject_windows``: ``(replica, first_tick, last_tick)`` — admission
      to the replica is refused for ticks in the inclusive window (brown-out
      / drain semantics); pending requests route elsewhere or wait.
    * ``delayed_saves``: ``(replica, due_tick, delay_ticks)`` — the
      replica's periodic plan-store write due at ``due_tick`` lands
      ``delay_ticks`` late (slow-disk fault); the flock'd merge must still
      converge to a complete store.
    """

    kills: tuple = ()
    reject_windows: tuple = ()
    delayed_saves: tuple = ()

    def kills_at(self, tick: int) -> List[int]:
        """Replica ids scheduled to die at the start of ``tick``."""
        return [r for (t, r) in self.kills if t == tick]

    def rejects_admission(self, replica: int, tick: int) -> bool:
        return any(
            r == replica and lo <= tick <= hi
            for (r, lo, hi) in self.reject_windows
        )

    def save_delay(self, replica: int, due_tick: int) -> int:
        for (r, t, d) in self.delayed_saves:
            if r == replica and t == due_tick:
                return int(d)
        return 0


class HeartbeatMonitor:
    """Track last-seen step + step times per host; flag dead/slow hosts."""

    def __init__(self, hosts: Sequence[str], timeout_steps: int = 3):
        self.hosts = list(hosts)
        self.timeout_steps = timeout_steps
        self.last_step: Dict[str, int] = {h: -1 for h in self.hosts}
        self.step_times: Dict[str, List[float]] = {h: [] for h in self.hosts}

    def report(self, host: str, step: int, step_time_s: float):
        self.last_step[host] = step
        self.step_times[host].append(step_time_s)

    def dead_hosts(self, current_step: int) -> List[str]:
        return [
            h
            for h in self.hosts
            if current_step - self.last_step[h] > self.timeout_steps
        ]

    def stragglers(self, factor: float = 2.0, patience: int = 3) -> List[str]:
        return detect_stragglers(self.step_times, factor=factor, patience=patience)


def detect_stragglers(
    step_times: Dict[str, List[float]], factor: float = 2.0, patience: int = 3
) -> List[str]:
    """Hosts whose last ``patience`` steps all exceed factor x fleet median."""
    recent = {h: t[-patience:] for h, t in step_times.items() if len(t) >= patience}
    if not recent:
        return []
    all_last = sorted(t[-1] for t in recent.values())
    median = all_last[len(all_last) // 2]
    if median <= 0:
        return []
    return [
        h for h, t in recent.items() if all(x > factor * median for x in t)
    ]


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """Decision record for shrinking the mesh after host loss."""

    old_shape: tuple
    new_shape: tuple
    dropped_axis: str
    note: str


def plan_elastic_remesh(mesh_shape: dict, lost_hosts: int, hosts_per_replica: int) -> Optional[ElasticPlan]:
    """Shrink the data axis by whole replicas to exclude lost hosts.

    Model-parallel groups are indivisible (they hold a param shard each), so
    elasticity always drops along the (pod, data) axes.  Returns None when the
    loss fits inside spare capacity (0 replicas to drop).
    """
    replicas_lost = -(-lost_hosts // hosts_per_replica)
    if replicas_lost <= 0:
        return None
    data = mesh_shape.get("data", 1)
    new_data = data - replicas_lost
    if new_data < 1:
        raise SimulatedFailure("not enough healthy replicas to continue")
    old = tuple(mesh_shape.values())
    new_shape = dict(mesh_shape, data=new_data)
    return ElasticPlan(
        old_shape=old,
        new_shape=tuple(new_shape.values()),
        dropped_axis="data",
        note=f"dropped {replicas_lost} data replicas after losing {lost_hosts} hosts",
    )


def run_with_restarts(
    *,
    num_steps: int,
    step_fn: Callable[[int], dict],
    save_fn: Callable[[int], None],
    restore_fn: Callable[[], int],
    checkpoint_every: int = 10,
    max_failures: int = 3,
    on_failure: Optional[Callable[[int, BaseException], None]] = None,
) -> dict:
    """Crash-loop training driver.

    ``step_fn(step)`` runs one step (may raise SimulatedFailure);
    ``save_fn(step)`` checkpoints; ``restore_fn()`` -> resume step (state is
    restored by the caller's closure).  Returns run statistics.
    """
    failures = 0
    restarts: List[int] = []
    step = restore_fn()
    while step < num_steps:
        try:
            step_fn(step)
            step += 1
            if step % checkpoint_every == 0 or step == num_steps:
                save_fn(step)
        except SimulatedFailure as e:
            failures += 1
            if on_failure is not None:
                on_failure(step, e)
            if failures > max_failures:
                raise
            step = restore_fn()
            restarts.append(step)
    return {"steps": step, "failures": failures, "restarts": restarts}
