"""Post-optimization HLO analyzer with while-loop trip-count attribution.

``compiled.cost_analysis()`` counts a while-loop *body once*, regardless of
trip count — under scan-over-layers that undercounts FLOPs, bytes and
collectives by ~n_layers.  This module re-derives the three roofline inputs
by walking the call graph of ``compiled.as_text()``:

  * **flops** — 2 x result_elems x contracted_elems for every ``dot``
    (+ convolutions), multiplied by the product of enclosing
    ``known_trip_count``s.  Elementwise flops are ignored (<1% for LM
    workloads; documented).
  * **bytes** — per materializing op: operand bytes + result bytes (fusion
    internals excluded — they live in registers/VMEM; dynamic-update-slice
    counted as 2x update size since XLA performs it in place).  This is an
    HBM-traffic estimate in the same spirit as cost_analysis' "bytes
    accessed", with loop attribution.
  * **wire_bytes** — per-device interconnect traffic per collective with ring
    factors (g = group size, S = result bytes):
        all-reduce 2S(g-1)/g | all-gather S(g-1)/g | reduce-scatter S(g-1)
        all-to-all S(g-1)/g  | collective-permute S

Also records the top-k largest GEMMs and per-collective byte totals — the
"profile" used by the §Perf hillclimb loop.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["HloStats", "analyze_hlo", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s4": 1, "u4": 1,  # round up
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CALLED_RE = re.compile(r"(?:calls|to_apply|condition|body)=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_NO_BYTES = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while", "call",
    "conditional", "custom-call", "rng-bit-generator",
}


def _parse_shapes(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for t, dims in _SHAPE_RE.findall(type_str):
        if t in DTYPE_BYTES:
            shape = tuple(int(d) for d in dims.split(",")) if dims else ()
            out.append((t, shape))
    return out


def _nbytes(shapes) -> int:
    total = 0
    for t, dims in shapes:
        n = DTYPE_BYTES[t]
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class _Op:
    name: str
    kind: str
    result_shapes: list
    rhs: str  # full text after '='

    @property
    def result_bytes(self) -> int:
        return _nbytes(self.result_shapes)


@dataclasses.dataclass
class _Computation:
    name: str
    params: Dict[str, list]  # param name -> shapes
    ops: List[_Op]


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    bytes: float = 0.0
    wire_bytes: float = 0.0
    dtype_corrected_bytes: float = 0.0  # bytes saved by the shadow-bf16 pass
    coll_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_static_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    bytes_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    top_dots: List[dict] = dataclasses.field(default_factory=list)
    top_colls: List[dict] = dataclasses.field(default_factory=list)

    def finalize(self, top: int = 12) -> "HloStats":
        self.top_dots = sorted(self.top_dots, key=lambda d: -d["flops"])[:top]
        self.top_colls = sorted(self.top_colls, key=lambda d: -d["wire_bytes"])[:top]
        return self


def _split_computations(hlo: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HEAD_RE.match(line)
            if m and line.endswith("{"):
                params = {}
                for part in _split_top_level(m.group(2)):
                    if ":" in part:
                        pname, ptype = part.split(":", 1)
                        params[pname.strip().lstrip("%")] = _parse_shapes(ptype)
                cur = _Computation(m.group(1), params, [])
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # result type = rhs text before the instruction token
        instr_m = re.search(r"([a-z][\w\-]*)\(", rhs)
        kind = instr_m.group(1) if instr_m else "unknown"
        head = rhs[: instr_m.start()] if instr_m else rhs
        cur.ops.append(_Op(name, kind, _parse_shapes(head), rhs))
    return comps


def _split_top_level(s: str) -> List[str]:
    parts, depth, start = [], 0, 0
    for i, ch in enumerate(s):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == "," and depth == 0:
            parts.append(s[start:i])
            start = i + 1
    if s[start:].strip():
        parts.append(s[start:])
    return parts


def _operand_names(rhs: str) -> List[str]:
    lp = rhs.index("(")
    depth = 0
    for i in range(lp, len(rhs)):
        if rhs[i] == "(":
            depth += 1
        elif rhs[i] == ")":
            depth -= 1
            if depth == 0:
                inner = rhs[lp + 1 : i]
                return [
                    m.group(1)
                    for part in _split_top_level(inner)
                    for m in [_OPERAND_RE.search(part)]
                    if m
                ]
    return []


def _group_size(rhs: str, total_devices: int) -> int:
    m = _GROUPS_IOTA_RE.search(rhs)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(rhs)
    if m:
        return max(len([x for x in m.group(1).split(",") if x.strip()]), 1)
    return total_devices


def _dot_flops(op: _Op, symtab: Dict[str, list]) -> float:
    result_elems = 1
    for _, dims in op.result_shapes:
        for d in dims:
            result_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rhs)
    contracted = 1
    if m:
        lhs_name = _operand_names(op.rhs)
        lhs_shapes = symtab.get(lhs_name[0]) if lhs_name else None
        if lhs_shapes:
            dims = lhs_shapes[0][1]
            for idx in m.group(1).split(","):
                if idx != "" and int(idx) < len(dims):
                    contracted *= dims[int(idx)]
    return 2.0 * result_elems * contracted


def _conv_flops(op: _Op, symtab: Dict[str, list]) -> float:
    # flops ~= 2 * result_elems * (kernel_elems / out_features)
    result_elems = 1
    for _, dims in op.result_shapes:
        for d in dims:
            result_elems *= d
    names = _operand_names(op.rhs)
    if len(names) < 2 or names[1] not in symtab:
        return 0.0
    kdims = symtab[names[1]][0][1]
    kernel_elems = 1
    for d in kdims:
        kernel_elems *= d
    m = re.search(r"dim_labels=[^,]*_[^-,]*o", op.rhs)
    # fall back: assume last kernel dim is output features
    out_feat = kdims[-1] if kdims else 1
    return 2.0 * result_elems * (kernel_elems / max(out_feat, 1))


# ---------------------------------------------------------------------------
# shadow-bf16 pass: undo XLA:CPU FloatNormalization for the TPU roofline
# ---------------------------------------------------------------------------
#
# XLA:CPU has no native bf16 compute, so FloatNormalization legalizes every
# requested-bf16 op into convert(bf16->f32) -> f32 op -> convert(f32->bf16).
# On the TPU target those ops run at bf16 (the MXU accumulates f32
# *internally*), so counting their HLO bytes at 4 B/elem double-counts HBM
# and wire traffic.  The pass marks f32 values as "shadow bf16" when every
# transitive consumer path ends in a downcast-to-bf16 while passing only
# through dtype-preserving ops — intentional f32 math (softmax scores, norm
# statistics, the f32 optimizer state) keeps full width because its
# consumers are real f32 computations, not downcasts.

_PASSTHROUGH = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "copy", "transpose", "reshape", "add", "dot",
    "all-reduce-start", "all-reduce-done", "all-gather-start",
    "all-gather-done", "bitcast", "slice", "dynamic-slice", "concatenate",
    "get-tuple-element",  # variadic collectives unpack through GTEs
}


def _f32_result(op: _Op) -> bool:
    # single f32 result, or a variadic (tuple) op whose elements are all f32
    return bool(op.result_shapes) and all(
        t == "f32" for t, _ in op.result_shapes
    )


def _conv_kinds(op: _Op, comps) -> str:
    """'up' (bf16->f32), 'down' (f32->bf16) or '' for non-convert ops.

    Detects both raw converts and convert-only kLoop fusions (XLA wraps
    normalization converts into wrapped_convert fusions)."""
    kind = op.kind
    if kind == "fusion":
        m = re.search(r"calls=%?([\w.\-]+)", op.rhs)
        inner = comps.get(m.group(1)) if m else None
        if inner is None:
            return ""
        body = [o for o in inner.ops if o.kind != "parameter"]
        if len(body) != 1 or body[0].kind != "convert":
            return ""
        kind = "convert"
    if kind != "convert" or len(op.result_shapes) != 1:
        return ""
    res_t = op.result_shapes[0][0]
    if res_t == "f32":
        return "up"
    if res_t == "bf16":
        return "down"
    return ""


def _shadow_bf16(comp: _Computation, comps) -> set:
    """Names of f32 values in ``comp`` that would be bf16 on TPU."""
    uses: Dict[str, list] = {}
    convk = {op.name: _conv_kinds(op, comps) for op in comp.ops}
    for op in comp.ops:
        for n in (_operand_names(op.rhs) if "(" in op.rhs else []):
            uses.setdefault(n, []).append(op)
    shadow: set = set()
    # iterate to fixpoint (consumer chains are short; 2 rounds suffice)
    for _ in range(4):
        changed = False
        for op in reversed(comp.ops):
            if op.name in shadow or not _f32_result(op):
                continue
            if op.kind not in _PASSTHROUGH and convk.get(op.name) != "up":
                continue
            consumers = uses.get(op.name, [])
            if not consumers:
                continue
            ok = all(
                convk.get(c.name) == "down" or c.name in shadow
                for c in consumers
            )
            if ok:
                shadow.add(op.name)
                changed = True
        if not changed:
            break
    return shadow


def analyze_hlo(hlo: str, total_devices: int = 1, top: int = 12,
                tpu_dtype_correction: bool = True) -> HloStats:
    comps = _split_computations(hlo)
    entry_name = None
    for raw in hlo.splitlines():
        if raw.startswith("ENTRY"):
            m = _COMP_HEAD_RE.match(raw)
            if m:
                entry_name = m.group(1)
            break
    if entry_name is None or entry_name not in comps:
        # fall back: the last computation is usually the entry
        entry_name = list(comps)[-1]

    stats = HloStats()
    visiting: set = set()

    def walk(comp_name: str, mult: float, count_bytes: bool):
        if comp_name not in comps or comp_name in visiting:
            return
        visiting.add(comp_name)
        comp = comps[comp_name]
        symtab: Dict[str, list] = dict(comp.params)
        for op in comp.ops:
            symtab[op.name] = op.result_shapes
        shadow = _shadow_bf16(comp, comps) if tpu_dtype_correction else set()
        convk = (
            {op.name: _conv_kinds(op, comps) for op in comp.ops}
            if tpu_dtype_correction else {}
        )

        def val_bytes(name: str) -> float:
            """Bytes of a value at its TPU wire width."""
            b = float(_nbytes(symtab.get(name, [])))
            if name in shadow or convk.get(name) == "up":
                b *= 0.5  # f32 here, bf16 on the TPU target
            return b

        for op in comp.ops:
            kind = op.kind
            if kind == "while":
                t = _TRIP_RE.search(op.rhs)
                trip = float(t.group(1)) if t else 1.0
                called = dict(
                    (m.group(0).split("=")[0], m.group(1))
                    for m in _CALLED_RE.finditer(op.rhs)
                )
                body = re.search(r"body=%?([\w.\-]+)", op.rhs)
                cond = re.search(r"condition=%?([\w.\-]+)", op.rhs)
                if body:
                    walk(body.group(1), mult * trip, count_bytes)
                if cond:
                    walk(cond.group(1), mult * trip, False)
                continue
            if kind in ("call", "conditional"):
                for m in _CALLED_RE.finditer(op.rhs):
                    walk(m.group(1), mult, count_bytes)
                continue
            if kind == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", op.rhs)
                if m:
                    walk(m.group(1), mult, False)  # flops inside, bytes at op level
            if kind == "dot":
                f = _dot_flops(op, symtab) * mult
                stats.flops += f
                meta = re.search(r'op_name="([^"]*)"', op.rhs)
                stats.top_dots.append({
                    "flops": f,
                    "result": op.rhs.split(" dot(")[0].strip(),
                    "op_name": meta.group(1) if meta else "",
                    "mult": mult,
                })
            elif kind == "convolution":
                stats.flops += _conv_flops(op, symtab) * mult
            else:
                base = kind.replace("-start", "")
                if base in _COLLECTIVES:
                    size = op.result_bytes
                    if op.name in shadow:
                        size *= 0.5  # wire at bf16 on TPU
                    # all-gather/all-reduce done-ops repeat the shape; the
                    # -done op has no operands list worth counting
                    if kind.endswith("-done"):
                        continue
                    g = _group_size(op.rhs, total_devices)
                    if base == "all-reduce":
                        wire = 2.0 * size * (g - 1) / g
                    elif base == "all-gather":
                        wire = size * (g - 1) / g
                    elif base == "reduce-scatter":
                        wire = float(size) * (g - 1)
                    elif base == "all-to-all":
                        wire = size * (g - 1) / g
                    else:
                        wire = float(size)
                    stats.wire_bytes += wire * mult
                    stats.coll_counts[base] = stats.coll_counts.get(base, 0) + int(mult)
                    stats.coll_static_counts[base] = (
                        stats.coll_static_counts.get(base, 0) + 1
                    )
                    stats.coll_bytes[base] = (
                        stats.coll_bytes.get(base, 0.0) + wire * mult
                    )
                    meta = re.search(r'op_name="([^"]*)"', op.rhs)
                    stats.top_colls.append({
                        "wire_bytes": wire * mult,
                        "op": base,
                        "result": op.rhs.split(f" {kind}(")[0].strip(),
                        "group": g,
                        "op_name": meta.group(1) if meta else "",
                        "mult": mult,
                    })
            if count_bytes and kind not in _NO_BYTES:
                if convk.get(op.name):
                    # normalization converts are fused into their neighbors
                    # on TPU: no HBM round trip
                    stats.dtype_corrected_bytes += (
                        op.result_bytes + sum(
                            _nbytes(symtab.get(n, []))
                            for n in _operand_names(op.rhs))
                    ) * mult
                    continue
                full = 0.0
                if kind == "dynamic-update-slice":
                    # in-place: touches update bytes twice (read + write)
                    names = _operand_names(op.rhs)
                    upd = _nbytes(symtab.get(names[1], [])) if len(names) > 1 else 0
                    b = 2.0 * (val_bytes(names[1]) if len(names) > 1 else 0) * mult
                    full = 2.0 * upd * mult
                elif kind in ("dynamic-slice", "slice", "gather"):
                    # reads only the sliced/gathered region, not the operand
                    b = 2.0 * op.result_bytes * mult
                    if op.name in shadow:
                        b *= 0.5
                    full = 2.0 * op.result_bytes * mult
                else:
                    res = float(op.result_bytes)
                    if op.name in shadow:
                        res *= 0.5
                    operand_bytes = sum(
                        val_bytes(n) for n in _operand_names(op.rhs)
                    )
                    full_operands = sum(
                        _nbytes(symtab.get(n, [])) for n in _operand_names(op.rhs)
                    )
                    b = (res + operand_bytes) * mult
                    full = (op.result_bytes + full_operands) * mult
                stats.bytes += b
                stats.dtype_corrected_bytes += max(full - b, 0.0)
                stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0.0) + b
        visiting.discard(comp_name)

    walk(entry_name, 1.0, True)
    return stats.finalize(top)
