"""Loop-tiling transformation (paper §III.B) — tile legality and footprints.

Two planes:

* **FPGA plane** (paper-faithful): conv tiles (𝒯, ℭ, μ, τ) and FC tiles
  (λ, Ω) determine BRAM buffer footprints and the per-invocation fixed
  computation of the μ×τ compute unit.  Used by ``fpga_model`` and ``dse``.

* **TPU plane** (hardware adaptation): Pallas BlockSpec tiles (bm, bn, bk)
  determine the VMEM working set and MXU alignment.  Used by the Pallas
  kernels and the TPU-side DSE.

Both are *the same transformation* — convert variable layer loops into fixed
blocks sized to on-chip memory — instantiated for two memory hierarchies.
"""
from __future__ import annotations

import dataclasses
import math

__all__ = [
    "ConvTiling",
    "FCTiling",
    "MatmulBlock",
    "TPU_V5E",
    "TpuSpec",
    "ceil_div",
]


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# FPGA plane
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ConvTiling:
    """Conv loop-tiling factors (paper notation: 𝒯, ℭ, μ, τ)."""

    t_r: int  # output-row tile 𝒯
    t_c: int  # output-col tile ℭ
    mu: int  # input-channel tile μ  (compute-unit input width)
    tau: int  # output-channel tile τ (compute-unit output width)

    def eff_spatial(self, r: int, c: int) -> tuple[int, int]:
        """HLS templates bound the tile loop by min(tile, layer dim)."""
        return min(self.t_r, r), min(self.t_c, c)

    def num_invocations(self, r: int, c: int, p: int, q: int) -> int:
        """Tile invocations to cover an output of r x c x q from p channels."""
        tr, tc = self.eff_spatial(r, c)
        return (
            ceil_div(r, tr)
            * ceil_div(c, tc)
            * ceil_div(p, self.mu)
            * ceil_div(q, self.tau)
        )

    def compute_cycles_per_invocation(self, k: int, r: int = None, c: int = None) -> int:
        """Fig. 4 dataflow: one μ×τ MAC wave per (spatial, tap) position.

        II=1 pipeline over 𝒯'·ℭ'·K² positions (effective tile).
        """
        tr, tc = self.eff_spatial(r or self.t_r, c or self.t_c)
        return tr * tc * k * k

    def input_tile_elems(self, k: int, stride: int = 1) -> int:
        h = stride * self.t_r + k - stride
        w = stride * self.t_c + k - stride
        return h * w * self.mu

    def weight_tile_elems(self, k: int) -> int:
        return self.mu * self.tau * k * k

    def output_tile_elems(self) -> int:
        return self.t_r * self.t_c * self.tau


@dataclasses.dataclass(frozen=True)
class FCTiling:
    """FC loop-tiling factors (paper notation: λ, Ω) over the same μ×τ unit.

    λ/Ω are the BRAM-resident vector tiles; the compute unit consumes them in
    (μ, τ) sub-blocks (paper Fig. 5).
    """

    lam: int  # input-neuron tile λ
    omega: int  # output-neuron tile Ω
    mu: int
    tau: int

    def num_invocations(self, p: int, q: int) -> int:
        return ceil_div(p, self.lam) * ceil_div(q, self.omega)

    def compute_cycles_per_invocation(self) -> int:
        # (λ/μ)·(Ω/τ) sub-blocks, each one MAC wave per μ-element column.
        return ceil_div(self.lam, self.mu) * ceil_div(self.omega, self.tau)

    def input_tile_elems(self) -> int:
        return self.lam

    def weight_tile_elems(self) -> int:
        return self.lam * self.omega

    def output_tile_elems(self) -> int:
        return self.omega


# ---------------------------------------------------------------------------
# TPU plane
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TpuSpec:
    """Per-chip TPU hardware description used by tiling/DSE/roofline."""

    name: str = "tpu_v5e"
    peak_bf16_flops: float = 197e12  # FLOP/s
    hbm_bw: float = 819e9  # bytes/s
    ici_bw: float = 50e9  # bytes/s per link
    vmem_bytes: int = 64 * 1024 * 1024  # usable VMEM budget we tile against
    mxu_dim: int = 128  # systolic array edge
    lane: int = 128  # last-dim register lane count
    sublane: int = 8  # second-minor dim granularity (f32)


TPU_V5E = TpuSpec()


@dataclasses.dataclass(frozen=True)
class MatmulBlock:
    """Pallas BlockSpec tile for the unified matmul compute unit.

    This is the TPU analogue of the paper's (μ, τ) compute-unit config:
    ``bm`` plays μ's role (inputs consumed per wave), ``bn`` plays τ's
    (outputs produced per wave), ``bk`` is the reduction tile streamed from
    HBM (the paper streams K² taps).
    """

    bm: int = 512
    bn: int = 512
    bk: int = 512

    def vmem_bytes(self, in_dtype_bytes: int = 2, acc_bytes: int = 4) -> int:
        # x-tile + w-tile (double-buffered by the Pallas pipeline: x2) +
        # f32 accumulator + output tile.
        x = self.bm * self.bk * in_dtype_bytes * 2
        w = self.bk * self.bn * in_dtype_bytes * 2
        acc = self.bm * self.bn * acc_bytes
        out = self.bm * self.bn * in_dtype_bytes * 2
        return x + w + acc + out

    def aligned(self, spec: TpuSpec = TPU_V5E) -> bool:
        return (
            self.bm % spec.sublane == 0
            and self.bn % spec.lane == 0
            and self.bk % spec.lane == 0
        )

    def mxu_efficiency(self, spec: TpuSpec = TPU_V5E) -> float:
        """Fraction of MXU issue slots doing useful work for this tile."""

        def frac(dim: int) -> float:
            return dim / (ceil_div(dim, spec.mxu_dim) * spec.mxu_dim)

        return frac(self.bm) * frac(self.bn) * frac(self.bk)

    def arithmetic_intensity(self, in_dtype_bytes: int = 2) -> float:
        """FLOPs per HBM byte for one grid step (higher = more compute bound)."""
        flops = 2 * self.bm * self.bn * self.bk
        bytes_moved = (self.bm * self.bk + self.bk * self.bn) * in_dtype_bytes
        return flops / bytes_moved

    def legal(self, m: int, n: int, k: int, spec: TpuSpec = TPU_V5E) -> bool:
        return (
            self.aligned(spec)
            and self.vmem_bytes() <= spec.vmem_bytes
            and self.bm <= max(m, spec.sublane)
            and self.bn <= max(n, spec.lane)
            and self.bk <= max(k, spec.lane)
        )


def clamp_block(m: int, n: int, k: int, block: MatmulBlock, spec: TpuSpec = TPU_V5E) -> MatmulBlock:
    """Shrink a block to fit a (possibly small) problem, keeping alignment."""

    def shrink(dim: int, b: int, gran: int) -> int:
        b = min(b, max(gran, math.ceil(dim / gran) * gran))
        return max(gran, b - b % gran)

    return MatmulBlock(
        bm=shrink(m, block.bm, spec.sublane),
        bn=shrink(n, block.bn, spec.lane),
        bk=shrink(k, block.bk, spec.lane),
    )
