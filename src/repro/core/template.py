"""The paper's primary contribution: a single templated compute unit that
every GEMM-bearing layer routes through.

The paper computes "convolutional and FC layers operations in vector
multiplication on a single on-chip compute unit" (§I contributions).  Here
:class:`Template` is that compute unit for TPU: conv, FC, attention
projections, MLP, MoE expert FFNs and vocab projections all call
:meth:`Template.matmul`, which dispatches to one of three backends:

  * ``"xla"``    — `jnp.dot`; the lowering used inside pjit/shard_map programs
                   (the multi-pod dry-run plane).  XLA's own MXU tiling is the
                   production path on real TPUs for the distributed graph.
  * ``"pallas"`` — the hand-tiled Pallas kernels (`kernels/matmul_fp.py`,
                   `kernels/conv2d.py`) with BlockSpec tiles chosen by the
                   DSE (`core/dse.py`); the TPU-target artifact, validated
                   interpret=True on CPU.
  * ``"q16"``    — the paper's 16-bit Q2.14 fixed-point numerics
                   (`kernels/matmul_q16.py`), for paper-faithful inference.

``Template`` is the stable API; the actual plan-then-execute machinery —
memoized DSE block selection, direct-conv vs im2col routing, fused epilogues
— lives in :class:`repro.core.engine.Engine` (DESIGN.md).  The template also
carries the quantization format and the tile configuration, mirroring the
paper's "pre-trained weights + target hardware specification -> optimized
template" flow.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Literal, Optional

import jax
import jax.numpy as jnp

from .quantization import QFormat, Q2_14
from .tiling import MatmulBlock, TPU_V5E, TpuSpec

__all__ = ["Template", "TemplateConfig", "default_template"]

Backend = Literal["xla", "pallas", "q16"]


@dataclasses.dataclass(frozen=True)
class TemplateConfig:
    """Hardware-specification half of the template (paper abstract:
    'takes pre-trained weights ... and target hardware specification')."""

    backend: Backend = "xla"
    block: Optional[MatmulBlock] = None  # None => DSE picks per-shape (plan-cached)
    qformat: QFormat = Q2_14
    hw: TpuSpec = TPU_V5E
    interpret: bool = True  # CPU container: Pallas kernels run interpreted
    #: GEMM output dtype; None = match the input dtype.  The TPU MXU
    #: accumulates bf16 products in f32 internally either way — requesting a
    #: bf16 *result* halves dot-output HBM traffic and lets the FSDP
    #: all-gathers / TP all-reduces ride the wire at 2 bytes instead of 4
    #: (§Perf iteration 1).  Set jnp.float32 to force f32 results.
    accum_dtype: Optional[jnp.dtype] = None


@dataclasses.dataclass(frozen=True)
class Template:
    config: TemplateConfig = TemplateConfig()

    # -- the execution-plan engine -------------------------------------------

    @functools.cached_property
    def engine(self):
        """The execution engine for this config (shares the global plan cache)."""
        from .engine import Engine

        return Engine(self.config)

    def block_for(self, m: int, n: int, k: int) -> MatmulBlock:
        return self.engine.block_for(m, n, k)

    # -- fixed-point residency (QTensor boundary ops, DESIGN.md §8) ----------

    def quant(self, x, fmt: Optional[QFormat] = None):
        """Float -> QTensor on the activation grid (counted island exit)."""
        return self.engine.quant(x, fmt)

    def dequant(self, q, fmt: Optional[QFormat] = None, dtype=jnp.float32):
        """QTensor / raw int16 -> float (counted island entry)."""
        return self.engine.dequant(q, fmt, dtype)

    # -- the unified compute unit ---------------------------------------------

    def matmul(self, x: jax.Array, w: jax.Array, **kw) -> jax.Array:
        """``x @ w`` where x: (..., k), w: (k, n).

        Leading dims of ``x`` are flattened into the GEMM M dimension — this
        is exactly the paper's unification: conv patches, tokens, and FC
        neurons are all just rows of one matrix multiply.  Keyword args
        (``bias``/``relu``/``qout``/``plan``) are fused-epilogue and plan
        controls forwarded to the engine.
        """
        return self.engine.matmul(x, w, **kw)

    def linear(
        self, x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None, **kw
    ) -> jax.Array:
        return self.engine.linear(x, w, b, **kw)

    def conv2d(
        self,
        x: jax.Array,
        w: jax.Array,
        stride: int = 1,
        padding: str | int = 0,
        **kw,
    ) -> jax.Array:
        """NHWC conv on the unified compute unit (paper Fig. 4).

        x: (N, H, W, Cin), w: (K, K, Cin, Cout) -> (N, Ho, Wo, Cout).
        The engine routes to the direct Pallas conv kernel or the im2col
        GEMM per its plan (DESIGN.md §2).
        """
        return self.engine.conv2d(x, w, stride=stride, padding=padding, **kw)


def default_template(backend: Backend = "xla", **kw) -> Template:
    return Template(TemplateConfig(backend=backend, **kw))
