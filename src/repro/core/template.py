"""The paper's primary contribution: a single templated compute unit that
every GEMM-bearing layer routes through.

The paper computes "convolutional and FC layers operations in vector
multiplication on a single on-chip compute unit" (§I contributions).  Here
:class:`Template` is that compute unit for TPU: conv (via im2col), FC,
attention projections, MLP, MoE expert FFNs and vocab projections all call
:meth:`Template.matmul`, which dispatches to one of three backends:

  * ``"xla"``    — `jnp.dot`; the lowering used inside pjit/shard_map programs
                   (the multi-pod dry-run plane).  XLA's own MXU tiling is the
                   production path on real TPUs for the distributed graph.
  * ``"pallas"`` — the hand-tiled Pallas kernel (`kernels/matmul_fp.py`) with
                   BlockSpec tiles chosen by the DSE (`core/dse.py`); the
                   TPU-target artifact, validated interpret=True on CPU.
  * ``"q16"``    — the paper's 16-bit Q2.14 fixed-point numerics
                   (`kernels/matmul_q16.py`), for paper-faithful inference.

The template also carries the quantization format and the tile configuration,
mirroring the paper's "pre-trained weights + target hardware specification
-> optimized template" flow.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional

import jax
import jax.numpy as jnp

from .quantization import QFormat, Q2_14, dequantize, quantize
from .tiling import MatmulBlock, TPU_V5E, TpuSpec, clamp_block

__all__ = ["Template", "TemplateConfig", "default_template"]

Backend = Literal["xla", "pallas", "q16"]


@dataclasses.dataclass(frozen=True)
class TemplateConfig:
    """Hardware-specification half of the template (paper abstract:
    'takes pre-trained weights ... and target hardware specification')."""

    backend: Backend = "xla"
    block: Optional[MatmulBlock] = None  # None => DSE picks per-shape
    qformat: QFormat = Q2_14
    hw: TpuSpec = TPU_V5E
    interpret: bool = True  # CPU container: Pallas kernels run interpreted
    #: GEMM output dtype; None = match the input dtype.  The TPU MXU
    #: accumulates bf16 products in f32 internally either way — requesting a
    #: bf16 *result* halves dot-output HBM traffic and lets the FSDP
    #: all-gathers / TP all-reduces ride the wire at 2 bytes instead of 4
    #: (§Perf iteration 1).  Set jnp.float32 to force f32 results.
    accum_dtype: Optional[jnp.dtype] = None


@dataclasses.dataclass(frozen=True)
class Template:
    config: TemplateConfig = TemplateConfig()

    # -- tile selection ------------------------------------------------------

    def block_for(self, m: int, n: int, k: int) -> MatmulBlock:
        if self.config.block is not None:
            return clamp_block(m, n, k, self.config.block, self.config.hw)
        from .dse import default_block_for

        return default_block_for(m, n, k, self.config.hw)

    # -- the unified compute unit ---------------------------------------------

    def matmul(self, x: jax.Array, w: jax.Array) -> jax.Array:
        """``x @ w`` where x: (..., k), w: (k, n).

        Leading dims of ``x`` are flattened into the GEMM M dimension — this
        is exactly the paper's unification: conv patches, tokens, and FC
        neurons are all just rows of one matrix multiply.
        """
        if x.ndim == 1:
            return self.matmul(x[None, :], w)[0]
        lead = x.shape[:-1]
        k = x.shape[-1]
        n = w.shape[-1]
        x2 = x.reshape(-1, k)
        backend = self.config.backend
        if backend == "xla":
            pet = self.config.accum_dtype or x.dtype
            out = jnp.dot(x2, w.astype(x.dtype), preferred_element_type=pet)
            out = out.astype(x.dtype)
        elif backend == "pallas":
            from repro.kernels import ops as kops

            out = kops.matmul_fp(
                x2,
                w,
                block=self.block_for(x2.shape[0], n, k),
                interpret=self.config.interpret,
            )
        elif backend == "q16":
            from repro.kernels import ops as kops

            fmt = self.config.qformat
            qout = kops.matmul_q16(
                quantize(x2, fmt),
                quantize(w, fmt),
                fmt=fmt,
                block=self.block_for(x2.shape[0], n, k),
                interpret=self.config.interpret,
            )
            out = dequantize(qout, fmt, dtype=x.dtype)
        else:  # pragma: no cover - config validation
            raise ValueError(f"unknown backend {backend!r}")
        return out.reshape(*lead, n)

    def linear(self, x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None) -> jax.Array:
        y = self.matmul(x, w)
        if b is not None:
            y = y + b.astype(y.dtype)
        return y

    # -- conv as matmul (paper's conv/FC unification) -------------------------

    def conv2d(
        self,
        x: jax.Array,
        w: jax.Array,
        stride: int = 1,
        padding: str | int = 0,
    ) -> jax.Array:
        """NHWC conv via im2col + the unified matmul (paper Fig. 4).

        x: (N, H, W, Cin), w: (K, K, Cin, Cout) -> (N, Ho, Wo, Cout).
        """
        n, h, wdt, cin = x.shape
        kh, kw, _, cout = w.shape
        pad = padding if isinstance(padding, int) else {"SAME": kh // 2, "VALID": 0}[padding]
        if pad:
            x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
            h, wdt = h + 2 * pad, wdt + 2 * pad
        ho = (h - kh) // stride + 1
        wo = (wdt - kw) // stride + 1
        # im2col: gather K x K patches -> rows of the GEMM
        patches = jax.lax.conv_general_dilated_patches(
            x.transpose(0, 3, 1, 2),  # NCHW for patch extraction
            filter_shape=(kh, kw),
            window_strides=(stride, stride),
            padding="VALID",
        )  # (N, Cin*K*K, Ho, Wo), features ordered (cin, kh, kw)
        cols = patches.transpose(0, 2, 3, 1).reshape(n * ho * wo, cin * kh * kw)
        wmat = w.transpose(2, 0, 1, 3).reshape(cin * kh * kw, cout)
        out = self.matmul(cols, wmat)
        return out.reshape(n, ho, wo, cout)


def default_template(backend: Backend = "xla", **kw) -> Template:
    return Template(TemplateConfig(backend=backend, **kw))
