"""TPU roofline analysis from compiled HLO (no hardware required).

Three terms per (architecture x shape x mesh), in seconds:

    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bw_per_chip
    collective = wire_bytes_per_device / ICI_link_bw

``compiled.cost_analysis()`` supplies per-device FLOPs and bytes.  Collective
bytes are NOT in cost_analysis: we parse the post-optimization HLO
(``compiled.as_text()``) and model per-device wire traffic per op with ring
algorithm factors (g = replica group size, S = result bytes):

    all-reduce          2 * S * (g-1)/g
    all-gather          S * (g-1)/g
    reduce-scatter      S * (g-1)        (operand = g * result)
    all-to-all          S * (g-1)/g
    collective-permute  S

This is the whole-program generalization of the paper's per-tile ping-pong
bound: latency >= max(compute, transfer) — here transfer splits into HBM and
interconnect terms.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

from .tiling import TPU_V5E, TpuSpec

__all__ = [
    "CollectiveStats",
    "RooflineReport",
    "parse_collective_bytes",
    "roofline_from_compiled",
    "model_flops",
]

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "c64": 8, "c128": 16,
}

# matches every result shape in a (possibly tuple-typed) HLO instruction
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# `replica_groups=[4,2]<=...` (iota) or `replica_groups={{0,1},{2,3}}`
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _shape_bytes(type_str: str, dims_str: str) -> int:
    if type_str not in _DTYPE_BYTES:
        return 0
    elems = 1
    if dims_str:
        for d in dims_str.split(","):
            elems *= int(d)
    return elems * _DTYPE_BYTES[type_str]


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return total_devices


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0  # per-device bytes on the interconnect (ring model)
    operand_bytes: float = 0.0  # naive sum of result sizes (for reference)
    counts: dict = dataclasses.field(default_factory=dict)
    by_op_bytes: dict = dataclasses.field(default_factory=dict)

    def add(self, op: str, wire: float, operand: float) -> None:
        self.wire_bytes += wire
        self.operand_bytes += operand
        self.counts[op] = self.counts.get(op, 0) + 1
        self.by_op_bytes[op] = self.by_op_bytes.get(op, 0.0) + wire


def parse_collective_bytes(hlo_text: str, total_devices: int = 1) -> CollectiveStats:
    """Sum per-device wire bytes over every collective in optimized HLO."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        lhs, _, rhs = stripped.partition("=")
        rhs = rhs.strip()
        op = None
        for cand in _COLLECTIVE_OPS:
            # match `bf16[..] all-gather(`, incl. async `all-gather-start(`
            if f" {cand}(" in f" {rhs}" or f"{cand}-start(" in rhs:
                op = cand
                break
        if op is None:
            continue
        # result shapes: everything before the opening paren of the op call
        head = rhs.split(op)[0]
        shapes = _SHAPE_RE.findall(head)
        size = sum(_shape_bytes(t, d) for t, d in shapes)
        if size == 0:
            continue
        g = max(2, _group_size(stripped, total_devices))
        if op == "all-reduce":
            wire = 2.0 * size * (g - 1) / g
        elif op == "all-gather":
            wire = size * (g - 1) / g
        elif op == "reduce-scatter":
            wire = float(size) * (g - 1)
        elif op == "all-to-all":
            wire = size * (g - 1) / g
        else:  # collective-permute
            wire = float(size)
        stats.add(op, wire, float(size))
    return stats


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # raw measurements (per device)
    hlo_flops: float
    hlo_bytes: float
    wire_bytes: float
    collective_counts: dict
    collective_by_op: dict
    # derived terms, seconds
    compute_s: float
    memory_s: float
    collective_s: float
    # usefulness
    model_flops_total: float
    useful_ratio: float  # MODEL_FLOPS / (HLO_FLOPs * chips)
    # memory fit
    per_device_mem_bytes: Optional[float] = None

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the step spent in the best-case (compute) bound.

        1.0 means perfectly compute-bound at peak; lower means memory or
        collectives dominate or compute is wasted vs model FLOPs.
        """
        if self.bound_s <= 0:
            return 0.0
        return (self.compute_s / self.bound_s) * self.useful_ratio

    def row(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "hlo_flops_per_dev": self.hlo_flops,
            "hlo_bytes_per_dev": self.hlo_bytes,
            "wire_bytes_per_dev": self.wire_bytes,
            "per_device_mem_bytes": self.per_device_mem_bytes,
            "collective_counts": self.collective_counts,
            "collective_by_op": self.collective_by_op,
        }


def model_flops(n_params_active: float, tokens: float, training: bool) -> float:
    """MODEL_FLOPS = 6·N·D for training, 2·N·D for inference forward."""
    return (6.0 if training else 2.0) * n_params_active * tokens


def roofline_from_compiled(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    cost_analysis: dict,
    hlo_text: str,
    n_params_active: float,
    tokens: float,
    training: bool,
    spec: TpuSpec = TPU_V5E,
    per_device_mem_bytes: Optional[float] = None,
) -> RooflineReport:
    flops = float(cost_analysis.get("flops", 0.0))
    byts = float(cost_analysis.get("bytes accessed", 0.0))
    colls = parse_collective_bytes(hlo_text, total_devices=chips)
    mflops = model_flops(n_params_active, tokens, training)
    total_hlo_flops = flops * chips
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        hlo_flops=flops,
        hlo_bytes=byts,
        wire_bytes=colls.wire_bytes,
        collective_counts=colls.counts,
        collective_by_op={k: round(v) for k, v in colls.by_op_bytes.items()},
        compute_s=flops / spec.peak_bf16_flops,
        memory_s=byts / spec.hbm_bw,
        collective_s=colls.wire_bytes / spec.ici_bw,
        model_flops_total=mflops,
        useful_ratio=(mflops / total_hlo_flops) if total_hlo_flops else 0.0,
        per_device_mem_bytes=per_device_mem_bytes,
    )
