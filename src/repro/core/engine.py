"""The execution-plan engine: plan-then-execute for the unified compute unit.

The paper chooses the compute-unit configuration *once* per network from the
hardware specification, then runs every conv/FC layer through the resulting
template.  This module is that split for the TPU plane:

* :class:`PlanCache` — memoized DSE block selection.  ``default_block_for``
  is an exhaustive grid search over (bm, bn, bk); the cache guarantees it
  runs **once per GEMM shape per hardware spec**, with hit/miss counters so
  tests (and ops dashboards) can assert no re-search happens on the hot path.
  Caches are process-global per :class:`~repro.core.tiling.TpuSpec`, so every
  Template/Engine instance targeting the same hardware shares one plan.

* :class:`ConvPlan` / :class:`GemmPlan` — per-layer execution plans: which
  kernel route a conv takes (direct Pallas conv vs im2col GEMM), the
  output-channel tile τ and spatial row tile of the direct route, and the
  pre-resolved Pallas block for GEMM routes.

* :class:`Engine` — executes plans.  It owns backend dispatch (xla / pallas
  float / q16 fixed point), the conv routing decision (DESIGN.md §2), and
  epilogue fusion (bias + ReLU + optional output quantization pushed into
  the kernels' write-back, DESIGN.md §3).

:class:`~repro.core.template.Template` delegates its ``matmul`` / ``linear``
/ ``conv2d`` API here; networks (``models/cnn.py``) compile a
``NetworkPlan`` once and reuse it every step.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from . import dse
from .quantization import QFormat, dequantize, fake_quant_fmt, quantize
from .tiling import MatmulBlock, TPU_V5E, TpuSpec, clamp_block

__all__ = [
    "PlanCache",
    "ConvPlan",
    "GemmPlan",
    "Engine",
    "plan_cache_for",
    "register_plan_store",
    "reset_plan_caches",
]


# ---------------------------------------------------------------------------
# plan cache (memoized DSE)
# ---------------------------------------------------------------------------


class PlanCache:
    """Memoized DSE selection: GEMM blocks and direct-conv tile configs.

    GEMM blocks are keyed by (m, n, k, hardware spec); direct-conv
    (τ, tile_rows) choices by the layer geometry + spec.  ``misses`` counts
    actual grid searches performed (either kind); ``hits`` counts lookups
    served from the cache.  A repeated shape must cost exactly one search
    for the lifetime of the cache.
    """

    def __init__(self) -> None:
        self._blocks: dict = {}
        self._conv_tiles: dict = {}
        self.hits = 0
        self.misses = 0

    def block_for(self, m: int, n: int, k: int, spec: TpuSpec = TPU_V5E) -> MatmulBlock:
        key = (m, n, k, spec)
        blk = self._blocks.get(key)
        if blk is None:
            self.misses += 1
            blk = dse.default_block_for(m, n, k, spec)
            self._blocks[key] = blk
        else:
            self.hits += 1
        return blk

    def conv_tile_for(
        self,
        hp: int, wp: int, cin: int, kh: int, kw: int, ho: int, wo: int,
        cout: int, stride: int, in_bytes: int, spec: TpuSpec = TPU_V5E,
    ):
        """Memoized :func:`dse.default_conv_tile_for` (None = no fit cached)."""
        key = (hp, wp, cin, kh, kw, ho, wo, cout, stride, in_bytes, spec)
        if key in self._conv_tiles:
            self.hits += 1
            return self._conv_tiles[key]
        self.misses += 1
        choice = dse.default_conv_tile_for(
            hp, wp, cin, kh, kw, ho, wo, cout, stride, spec, in_bytes
        )
        self._conv_tiles[key] = choice
        return choice

    def __len__(self) -> int:
        return len(self._blocks) + len(self._conv_tiles)

    def clear(self) -> None:
        self._blocks.clear()
        self._conv_tiles.clear()
        self.hits = 0
        self.misses = 0


_PLAN_CACHES: dict = {}
#: Higher-level plan memos (e.g. models/cnn.py's NetworkPlan table) register
#: themselves here so reset_plan_caches() empties them too.
_EXTRA_PLAN_STORES: list = []


def plan_cache_for(spec: TpuSpec = TPU_V5E) -> PlanCache:
    """The process-global plan cache for a hardware spec."""
    cache = _PLAN_CACHES.get(spec)
    if cache is None:
        cache = _PLAN_CACHES[spec] = PlanCache()
    return cache


def register_plan_store(store: dict) -> None:
    """Register a derived plan memo to be emptied by :func:`reset_plan_caches`."""
    _EXTRA_PLAN_STORES.append(store)


def reset_plan_caches() -> None:
    """Drop all cached plans (tests / reconfiguration).

    Caches are cleared in place — live Engines keep their (now empty)
    PlanCache object, so their stats stay consistent with the global one.
    """
    for cache in _PLAN_CACHES.values():
        cache.clear()
    for store in _EXTRA_PLAN_STORES:
        store.clear()


# ---------------------------------------------------------------------------
# per-layer plans
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GemmPlan:
    """Pre-resolved plan for one GEMM shape."""

    m: int
    n: int
    k: int
    block: Optional[MatmulBlock]  # None for the xla backend


@dataclasses.dataclass(frozen=True)
class ConvPlan:
    """Pre-resolved plan for one conv layer.

    route: "direct" (Pallas direct conv), "im2col" (GEMM fallback), or "xla".
    tau: output-channel tile of the direct kernel (0 on GEMM routes).
    block: Pallas block for the im2col GEMM (None otherwise).
    gemm: the layer's equivalent (m, n, k) GEMM shape.
    vmem_bytes: modeled VMEM working set of the chosen route's grid step.
    tile_rows: direct-route output rows per grid step (0 = whole image).
    spatial_tiles: ceil(Ho / tile_rows) — grid steps along the row axis.
    """

    route: str
    stride: int
    pad: int
    tau: int
    block: Optional[MatmulBlock]
    gemm: tuple
    vmem_bytes: int
    tile_rows: int = 0
    spatial_tiles: int = 1


#: VMEM working-set model of one direct-conv grid step — lives with the rest
#: of the DSE scoring in core/dse.py; re-exported here because the engine is
#: its primary consumer (DESIGN.md §2).
_direct_conv_vmem = dse.direct_conv_vmem


def _resolve_pad(padding, kh: int) -> int:
    if isinstance(padding, int):
        return padding
    return {"SAME": kh // 2, "VALID": 0}[padding]


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


class Engine:
    """Executes GEMM/conv plans for one template configuration.

    Stateless w.r.t. numerics; holds the (shared) plan cache and per-engine
    routing counters (``counters["conv_direct"]`` etc.) used by routing
    assertions in tests.
    """

    def __init__(self, config=None, plan_cache: Optional[PlanCache] = None) -> None:
        if config is None:
            from .template import TemplateConfig

            config = TemplateConfig()
        self.config = config
        # explicit `is not None`: an empty PlanCache is falsy (__len__ == 0)
        # but still the caller's requested isolated cache
        self.plan_cache = plan_cache if plan_cache is not None else plan_cache_for(config.hw)
        self.counters: collections.Counter = collections.Counter()

    # -- planning ------------------------------------------------------------

    def block_for(self, m: int, n: int, k: int) -> MatmulBlock:
        """The Pallas block for a GEMM shape: config override or cached DSE."""
        if self.config.block is not None:
            return clamp_block(m, n, k, self.config.block, self.config.hw)
        return self.plan_cache.block_for(m, n, k, self.config.hw)

    def plan_gemm(self, m: int, n: int, k: int) -> GemmPlan:
        block = None if self.config.backend == "xla" else self.block_for(m, n, k)
        return GemmPlan(m=m, n=n, k=k, block=block)

    def plan_conv(
        self, x_shape, w_shape, *, stride: int = 1, padding=0, route: Optional[str] = None
    ) -> ConvPlan:
        """Pick the kernel route for one conv layer (DESIGN.md §2).

        Direct route: the DSE (``dse.explore_conv_spatial``, memoized in the
        plan cache) picks the (τ, tile_rows) compute-unit config — whole-slab
        when the padded image fits the VMEM budget, an output-row spatial
        tiling with two-block halo reads when it doesn't.  Only when *no*
        (τ, tile_rows) fits does the layer fall back to the im2col GEMM with
        a plan-cached DSE block.  ``route`` forces a route (tests /
        benchmarks).
        """
        n, h, wd, cin = x_shape
        kh, kw, _, cout = w_shape
        pad = _resolve_pad(padding, kh)
        hp, wp = h + 2 * pad, wd + 2 * pad
        ho = (hp - kh) // stride + 1
        wo = (wp - kw) // stride + 1
        gemm = (n * ho * wo, cout, cin * kh * kw)
        backend = self.config.backend
        if backend == "xla" or route == "xla":
            return ConvPlan("xla", stride, pad, 0, None, gemm, 0)
        if route != "im2col":
            in_bytes = 2 if backend == "q16" else 4
            choice = self.plan_cache.conv_tile_for(
                hp, wp, cin, kh, kw, ho, wo, cout, stride, in_bytes, self.config.hw
            )
            if choice is not None:
                tile_rows = 0 if choice.tile_rows >= ho else choice.tile_rows
                return ConvPlan(
                    "direct", stride, pad, choice.tau, None, gemm,
                    choice.vmem_bytes, tile_rows, choice.spatial_tiles,
                )
            if route == "direct":
                raise ValueError(
                    f"direct conv route forced but no (tau, tile_rows) config "
                    f"for image slab {x_shape} fits VMEM "
                    f"({self.config.hw.vmem_bytes} bytes)"
                )
        block = self.block_for(*gemm)
        return ConvPlan("im2col", stride, pad, 0, block, gemm, block.vmem_bytes())

    # -- execution: GEMM -----------------------------------------------------

    def _xla_epilogue(self, out, bias, relu, qout, dtype):
        out = out.astype(dtype)
        if bias is not None:
            out = out + bias.astype(dtype)
        if relu:
            out = jax.nn.relu(out)
        if qout is not None:
            out = fake_quant_fmt(out, qout)  # STE: keeps the train path differentiable
        return out

    def matmul(
        self,
        x: jax.Array,
        w: jax.Array,
        *,
        bias: Optional[jax.Array] = None,
        relu: bool = False,
        qout: Optional[QFormat] = None,
        plan: Optional[GemmPlan] = None,
    ) -> jax.Array:
        """``x @ w`` with fused epilogue; leading dims of x flatten into M.

        On the q16 backend the output is inherently snapped to the backend's
        ``config.qformat`` grid by the kernel's saturating write-back, so
        ``qout`` is implied by the backend and ignored there (same rule as
        :meth:`conv2d`).
        """
        if x.ndim == 1:
            return self.matmul(x[None, :], w, bias=bias, relu=relu, qout=qout, plan=plan)[0]
        lead = x.shape[:-1]
        k = x.shape[-1]
        n = w.shape[-1]
        x2 = x.reshape(-1, k)
        m = x2.shape[0]
        backend = self.config.backend
        if backend == "xla":
            pet = self.config.accum_dtype or x.dtype
            out = jnp.dot(x2, w.astype(x.dtype), preferred_element_type=pet)
            out = self._xla_epilogue(out, bias, relu, qout, x.dtype)
        elif backend == "pallas":
            from repro.kernels import ops as kops

            self.counters["gemm_pallas"] += 1
            block = plan.block if plan is not None and plan.block is not None else self.block_for(m, n, k)
            out = kops.matmul_fp(
                x2, w, bias=bias, relu=relu, qout=qout, block=block,
                interpret=self.config.interpret,
            )
        elif backend == "q16":
            from repro.kernels import ops as kops

            self.counters["gemm_q16"] += 1
            fmt = self.config.qformat
            block = plan.block if plan is not None and plan.block is not None else self.block_for(m, n, k)
            qres = kops.matmul_q16(
                quantize(x2, fmt),
                quantize(w, fmt),
                bias=None if bias is None else quantize(bias, fmt),
                relu=relu,
                fmt=fmt,
                block=block,
                interpret=self.config.interpret,
            )
            out = dequantize(qres, fmt, dtype=x.dtype)
        else:  # pragma: no cover - config validation
            raise ValueError(f"unknown backend {backend!r}")
        return out.reshape(*lead, n)

    def linear(
        self,
        x: jax.Array,
        w: jax.Array,
        b: Optional[jax.Array] = None,
        *,
        relu: bool = False,
        qout: Optional[QFormat] = None,
        plan: Optional[GemmPlan] = None,
    ) -> jax.Array:
        return self.matmul(x, w, bias=b, relu=relu, qout=qout, plan=plan)

    # -- execution: conv -----------------------------------------------------

    def conv2d(
        self,
        x: jax.Array,
        w: jax.Array,
        *,
        stride: int = 1,
        padding=0,
        bias: Optional[jax.Array] = None,
        relu: bool = False,
        qout: Optional[QFormat] = None,
        plan: Optional[ConvPlan] = None,
    ) -> jax.Array:
        """NHWC conv through the planned kernel route, epilogue fused.

        x: (N, H, W, Cin), w: (K, K, Cin, Cout) -> (N, Ho, Wo, Cout).
        On the q16 backend the output is inherently Q-gridded, so ``qout``
        is implied by the backend's qformat.
        """
        from repro.kernels import ops as kops

        kh, kw = w.shape[0], w.shape[1]
        if plan is None:
            plan = self.plan_conv(x.shape, w.shape, stride=stride, padding=padding)
        # The plan is the single source of geometry: stride *and* pad both
        # come from it, so a mismatched plan cannot half-apply.
        stride, pad = plan.stride, plan.pad
        backend = self.config.backend
        if plan.route == "xla":
            self.counters["conv_xla"] += 1
            xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0))) if pad else x
            cols, ho, wo = kops.im2col(xp, kh, kw, stride)
            pet = self.config.accum_dtype or x.dtype
            out = jnp.dot(cols, kops.conv_gemm_weights(w).astype(x.dtype),
                          preferred_element_type=pet)
            out = self._xla_epilogue(out, bias, relu, qout, x.dtype)
            return out.reshape(x.shape[0], ho, wo, -1)
        self.counters["conv_direct" if plan.route == "direct" else "conv_im2col"] += 1
        if backend == "pallas":
            return kops.conv2d(
                x, w, bias=bias, stride=stride, padding=pad, tau=plan.tau,
                relu=relu, qout=qout, route=plan.route, block=plan.block,
                tile_rows=plan.tile_rows, interpret=self.config.interpret,
            )
        assert backend == "q16", backend
        fmt = self.config.qformat
        qres = kops.conv2d_q16(
            quantize(x, fmt),
            quantize(w, fmt),
            bias=None if bias is None else quantize(bias, fmt),
            stride=stride,
            padding=pad,
            tau=plan.tau,
            relu=relu,
            fmt=fmt,
            route=plan.route,
            block=plan.block,
            tile_rows=plan.tile_rows,
            interpret=self.config.interpret,
        )
        return dequantize(qres, fmt, dtype=x.dtype)
