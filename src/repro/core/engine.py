"""The execution-plan engine: plan-then-execute for the unified compute unit.

The paper chooses the compute-unit configuration *once* per network from the
hardware specification, then runs every conv/FC layer through the resulting
template.  This module is that split for the TPU plane:

* :class:`PlanRegistry` — the durable DSE artifact (DESIGN.md §6).
  ``default_block_for`` is an exhaustive grid search over (bm, bn, bk); the
  registry guarantees it runs **once per GEMM shape per hardware spec**, with
  hit/miss counters so tests (and ops dashboards) can assert no re-search
  happens on the hot path.  Beyond the in-process memo the registry
  *persists*: ``save``/``load`` round-trip GEMM blocks and direct-conv
  (τ, tile_rows, tile_cols, halo_mode) choices — including cached no-fit
  sentinels — as versioned
  JSON keyed by (shape..., :class:`~repro.core.tiling.TpuSpec`), and
  ``measure_and_pin`` overwrites the analytic choice with a measured-time
  winner (per-entry ``source`` provenance: ``analytic`` vs ``measured``).
  Registries are process-global per spec (:func:`plan_cache_for`);
  :func:`save_plan_store`/:func:`load_plan_store` serialize them all to the
  ``REPRO_PLAN_STORE`` path so serving restarts and CI benchmark runs
  warm-start with zero grid searches.

* :class:`ConvPlan` / :class:`GemmPlan` — per-layer execution plans: which
  kernel route a conv takes (direct Pallas conv vs im2col GEMM), the
  output-channel tile τ and spatial row tile of the direct route, and the
  pre-resolved Pallas block for GEMM routes.  Planning is sharding-aware:
  ``Engine.plan_gemm``/``plan_conv`` accept an optional mesh + PartitionSpec
  and plan the *local per-shard* shapes (M over data axes, N over model).

* :class:`Engine` — executes plans.  It owns backend dispatch (xla / pallas
  float / q16 fixed point), the conv routing decision (DESIGN.md §2), and
  epilogue fusion (bias + ReLU + optional output quantization pushed into
  the kernels' write-back, DESIGN.md §3).

:class:`~repro.core.template.Template` delegates its ``matmul`` / ``linear``
/ ``conv2d`` API here; networks (``models/cnn.py``) compile a
``NetworkPlan`` once and reuse it every step.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import glob
import json
import os
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from . import dse
from .quantization import (
    NumericsPolicy,
    QFormat,
    QTensor,
    dequantize,
    fake_quant_fmt,
    quantize,
    quantize_qtensor,
)
from .tiling import MatmulBlock, TPU_V5E, TpuSpec, clamp_block

__all__ = [
    "PLAN_STORE_ENV",
    "PLAN_STORE_FORMAT",
    "PLAN_STORE_VERSION",
    "PLAN_STORE_COMPAT_VERSIONS",
    "PlanCache",
    "PlanRegistry",
    "PlanStoreError",
    "ConvPlan",
    "GemmPlan",
    "PrecisionChoice",
    "Engine",
    "batch_rungs",
    "bucket_for",
    "default_plan_store_path",
    "validate_policy",
    "load_plan_store",
    "plan_cache_for",
    "plan_store_stats",
    "register_plan_store",
    "reset_plan_caches",
    "save_plan_store",
    "warm_start_plan_store",
]


def bucket_for(length: int, ladder: Sequence[int]) -> Optional[int]:
    """The bucket-ladder rule: the smallest ladder entry >= length.

    The serve scheduler pads every prefill up to a rung of a small ladder so
    the engine sees a handful of fixed GEMM shapes — each planned once,
    registry hits forever after — instead of one shape per prompt length.
    Returns None when the length exceeds every rung (the request cannot be
    admitted at this ladder).
    """
    if length < 0:
        raise ValueError(f"negative length {length}")
    best = None
    for rung in ladder:
        if rung >= length and (best is None or rung < best):
            best = rung
    return best


def batch_rungs(slots: int) -> tuple:
    """Batch-size ladder for coalesced (B, L) prefill launches.

    Powers of two up to ``slots`` plus ``slots`` itself: a tick's pending
    prefills for one bucket rung are padded up to the smallest batch rung
    >= their count, so the engine sees |batch_rungs| x |ladder| prefill GEMM
    shapes total — each planned and traced once at warmup — instead of a
    fresh shape per admission-count.
    """
    if slots < 1:
        raise ValueError(f"slots must be >= 1, got {slots}")
    rungs = set()
    b = 1
    while b < slots:
        rungs.add(b)
        b *= 2
    rungs.add(slots)
    return tuple(sorted(rungs))


# ---------------------------------------------------------------------------
# plan registry (memoized DSE, persistent + measured-time overwrite)
# ---------------------------------------------------------------------------

PLAN_STORE_FORMAT = "repro-plan-store"
#: v2 (PR 8) added the ConvTileChoice column-tiling fields (tile_cols,
#: col_tiles, halo_mode).  v3 (PR 10) added the per-layer precision section
#: (the drift-aware int8/int16 grid assignments, DESIGN.md §11).  Older
#: stores still load leniently: v2 keeps its gemm *and* conv entries (their
#: schemas are unchanged) and simply has no precision pins; v1 keeps gemm
#: only — its pre-column-tiling conv entries are dropped so those layers
#: re-plan against the three-regime DSE instead of raising PlanStoreError.
PLAN_STORE_VERSION = 3
PLAN_STORE_COMPAT_VERSIONS = (1, 2)
#: Env var naming the default persisted plan-store path.  When set, the
#: launch drivers (serve/train) and the benchmark harness warm-start from it
#: and write newly planned shapes back on exit.
PLAN_STORE_ENV = "REPRO_PLAN_STORE"


class PlanStoreError(ValueError):
    """A plan store file is unreadable, corrupted, or version-mismatched."""


@dataclasses.dataclass(frozen=True)
class PrecisionChoice:
    """One pinned per-layer activation grid (the precision DSE's output).

    ``fmt`` is the layer's *input* activation format (int8 rung or the
    network's base int16 grid); ``drift`` records the measured solo-flip
    argmax agreement that justified the choice (None for analytic pins).
    """

    fmt: QFormat
    drift: Optional[float] = None


def _spec_to_doc(spec: TpuSpec) -> dict:
    return dataclasses.asdict(spec)


def _spec_from_doc(doc: dict) -> TpuSpec:
    try:
        return TpuSpec(**doc)
    except TypeError as err:
        raise PlanStoreError(f"unrecognized TpuSpec fields in plan store: {err}") from err


class PlanRegistry:
    """Memoized DSE selection: GEMM blocks and direct-conv tile configs.

    GEMM blocks are keyed by (m, n, k, hardware spec); direct-conv
    (τ, tile_rows, tile_cols, halo_mode) choices by the layer geometry +
    spec.  ``misses`` counts
    actual grid searches performed (either kind); ``hits`` counts lookups
    served from the registry.  A repeated shape must cost exactly one search
    for the lifetime of the registry — or *zero* when the entry was
    pre-loaded from a persisted store (:meth:`load`) or pinned by the
    measured-time autotuner (:meth:`measure_and_pin`).  Every entry carries
    ``source`` provenance: ``"analytic"`` (grid-search score) or
    ``"measured"`` (timed kernel launches).
    """

    def __init__(self) -> None:
        self._blocks: dict = {}
        self._conv_tiles: dict = {}
        self._precision: dict = {}
        self._block_src: dict = {}
        self._conv_src: dict = {}
        self._prec_src: dict = {}
        self.hits = 0
        self.misses = 0

    # -- lookups (memoized searches) ----------------------------------------

    def block_for(self, m: int, n: int, k: int, spec: TpuSpec = TPU_V5E) -> MatmulBlock:
        key = (m, n, k, spec)
        blk = self._blocks.get(key)
        if blk is None:
            self.misses += 1
            blk = dse.default_block_for(m, n, k, spec)
            self._blocks[key] = blk
            self._block_src[key] = "analytic"
        else:
            self.hits += 1
        return blk

    def conv_tile_for(
        self,
        hp: int, wp: int, cin: int, kh: int, kw: int, ho: int, wo: int,
        cout: int, stride: int, in_bytes: int, spec: TpuSpec = TPU_V5E,
    ):
        """Memoized :func:`dse.default_conv_tile_for` (None = no fit cached)."""
        key = (hp, wp, cin, kh, kw, ho, wo, cout, stride, in_bytes, spec)
        if key in self._conv_tiles:
            self.hits += 1
            return self._conv_tiles[key]
        self.misses += 1
        choice = dse.default_conv_tile_for(
            hp, wp, cin, kh, kw, ho, wo, cout, stride, spec, in_bytes
        )
        self._conv_tiles[key] = choice
        self._conv_src[key] = "analytic"
        return choice

    # -- per-layer precision pins (the drift-aware DSE, DESIGN.md §11) -------

    def precision_for(
        self, net: str, layer: str, spec: TpuSpec = TPU_V5E
    ) -> Optional[PrecisionChoice]:
        """The pinned activation grid for one named layer, or None.

        A found pin counts as a hit; a miss is *not* ticked here — the
        precision search is a whole-network drift sweep, so the single miss
        is charged by :meth:`pin_precision` when the sweep actually ran
        (``searched=True``).  A warm restart therefore replays every layer
        as hits with zero misses (``REPRO_PLAN_ASSERT_WARM``).
        """
        ent = self._precision.get((net, layer, spec))
        if ent is not None:
            self.hits += 1
        return ent

    def pin_precision(
        self,
        net: str,
        layer: str,
        fmt: QFormat,
        *,
        drift: Optional[float] = None,
        spec: TpuSpec = TPU_V5E,
        source: str = "measured",
        searched: bool = True,
    ) -> PrecisionChoice:
        """Record one layer's chosen grid (``source: measured`` provenance —
        the choice came from a real drift sweep, not an analytic model)."""
        if searched:
            self.misses += 1
        choice = PrecisionChoice(fmt=fmt, drift=drift)
        key = (net, layer, spec)
        self._precision[key] = choice
        self._prec_src[key] = source
        return choice

    def precision_plan(self, net: str, spec: TpuSpec = TPU_V5E) -> dict:
        """All pinned (layer -> QFormat) choices for one network (no
        counter ticks — this is an inspection/report helper)."""
        return {
            key[1]: ent.fmt
            for key, ent in self._precision.items()
            if key[0] == net and key[2] == spec
        }

    # -- measured-time autotune ---------------------------------------------

    def measure_and_pin(
        self,
        m: int,
        n: int,
        k: int,
        spec: TpuSpec = TPU_V5E,
        *,
        candidates: Optional[Sequence[MatmulBlock]] = None,
        top_k: int = 3,
        reps: int = 2,
        interpret: bool = True,
        dtype=jnp.float32,
    ) -> MatmulBlock:
        """Time the top-K analytic candidates with real kernel launches and
        overwrite the registry entry with the fastest (``source: measured``).

        On this CPU container ``interpret=True`` times the Pallas interpreter
        rather than the MXU — the *mechanism* (measure, pick, pin, persist)
        is what ships; on real hardware the same call times compiled kernels.
        """
        from repro.kernels import ops as kops

        if candidates is None:
            ranked = dse.explore_tpu_block(m, n, k, spec, top=top_k)
            candidates = [blk for blk, _ in ranked]
        if not candidates:
            candidates = [clamp_block(m, n, k, MatmulBlock(128, 128, 128), spec)]
        key0 = jax.random.PRNGKey(0)
        x = jax.random.normal(key0, (m, k), dtype) * 0.3
        w = jax.random.normal(jax.random.fold_in(key0, 1), (k, n), dtype) * 0.3
        best, best_t = None, float("inf")
        for blk in candidates:
            run = lambda: jax.block_until_ready(
                kops.matmul_fp(x, w, block=blk, interpret=interpret)
            )
            run()  # compile / first-touch outside the timed region
            t0 = time.perf_counter()
            for _ in range(reps):
                run()
            t = (time.perf_counter() - t0) / reps
            if t < best_t:
                best, best_t = blk, t
        key = (m, n, k, spec)
        self._blocks[key] = best
        self._block_src[key] = "measured"
        return best

    # -- provenance / stats --------------------------------------------------

    def source_for(self, m: int, n: int, k: int, spec: TpuSpec = TPU_V5E) -> Optional[str]:
        return self._block_src.get((m, n, k, spec))

    def stats(self) -> dict:
        """Separate GEMM-block and conv-tile counts (+ counters, provenance)."""
        measured = sum(1 for s in self._block_src.values() if s == "measured")
        measured += sum(1 for s in self._conv_src.values() if s == "measured")
        measured += sum(1 for s in self._prec_src.values() if s == "measured")
        return {
            "gemm_blocks": len(self._blocks),
            "conv_tiles": len(self._conv_tiles),
            "precision": len(self._precision),
            "hits": self.hits,
            "misses": self.misses,
            "measured": measured,
        }

    @contextlib.contextmanager
    def scope(self, into: Optional[dict] = None):
        """Count hits/misses attributable to one region (per-bucket stats).

        Yields a dict that, on exit, holds the hit/miss *delta* incurred
        inside the with-block; when ``into`` is given the delta is also
        accumulated there (``into["hits"] += ...``).  The scheduler wraps
        each bucket's prefill trace and the decode trace in a scope so its
        stats line can attribute plan work to individual ladder rungs.
        """
        delta = {"hits": 0, "misses": 0}
        h0, m0 = self.hits, self.misses
        try:
            yield delta
        finally:
            delta["hits"] = self.hits - h0
            delta["misses"] = self.misses - m0
            if into is not None:
                into["hits"] = into.get("hits", 0) + delta["hits"]
                into["misses"] = into.get("misses", 0) + delta["misses"]

    def __len__(self) -> int:
        return len(self._blocks) + len(self._conv_tiles) + len(self._precision)

    def clear(self) -> None:
        self._blocks.clear()
        self._conv_tiles.clear()
        self._precision.clear()
        self._block_src.clear()
        self._conv_src.clear()
        self._prec_src.clear()
        self.hits = 0
        self.misses = 0

    # -- serialization (DESIGN.md §6 schema) ---------------------------------

    def to_doc(self) -> dict:
        """The registry as a versioned, JSON-serializable document."""
        specs: list = []
        spec_ix: dict = {}

        def six(spec: TpuSpec) -> int:
            if spec not in spec_ix:
                spec_ix[spec] = len(specs)
                specs.append(_spec_to_doc(spec))
            return spec_ix[spec]

        def order(key):  # deterministic artifact: sort by spec then shape
            return (repr(key[-1]), key[:-1])

        gemm = [
            {
                "spec": six(key[3]),
                "key": list(key[:3]),
                "block": [blk.bm, blk.bn, blk.bk],
                "source": self._block_src.get(key, "analytic"),
            }
            for key, blk in sorted(self._blocks.items(), key=lambda kv: order(kv[0]))
        ]
        conv = [
            {
                "spec": six(key[-1]),
                "key": list(key[:-1]),
                "choice": None if choice is None else dse.conv_choice_to_doc(choice),
                "source": self._conv_src.get(key, "analytic"),
            }
            for key, choice in sorted(self._conv_tiles.items(), key=lambda kv: order(kv[0]))
        ]
        precision = [
            {
                "spec": six(key[-1]),
                "key": list(key[:-1]),  # [net, layer]
                "fmt": [ent.fmt.int_bits, ent.fmt.frac_bits, ent.fmt.total_bits],
                "drift": ent.drift,
                "source": self._prec_src.get(key, "measured"),
            }
            for key, ent in sorted(self._precision.items(), key=lambda kv: order(kv[0]))
        ]
        return {
            "format": PLAN_STORE_FORMAT,
            "version": PLAN_STORE_VERSION,
            "specs": specs,
            "gemm": gemm,
            "conv": conv,
            "precision": precision,
        }

    def merge_doc(self, doc: dict) -> int:
        """Merge a :meth:`to_doc` document into this registry.

        Loaded entries overwrite existing ones and count as neither hits nor
        misses (a later lookup of a loaded entry is a hit).  Returns the
        number of entries merged; raises :class:`PlanStoreError` on any
        format/structure mismatch or an *unknown* version.  A known older
        version (``PLAN_STORE_COMPAT_VERSIONS``) loads leniently: gemm
        entries merge from every compat version (their schema is unchanged),
        conv entries merge from v2+ (v1's pre-column-tiling docs are dropped
        so those layers re-plan under the current DSE), and precision pins
        merge from v3+ (older stores simply have none, so those networks
        re-run the drift sweep) — a warm fleet store survives the upgrade
        instead of crashing the loader.
        """
        blocks: dict = {}
        block_src: dict = {}
        conv_tiles: dict = {}
        conv_src: dict = {}
        precision: dict = {}
        prec_src: dict = {}
        try:
            if doc.get("format") != PLAN_STORE_FORMAT:
                raise PlanStoreError(
                    f"not a plan store (format={doc.get('format')!r}, "
                    f"want {PLAN_STORE_FORMAT!r})"
                )
            version = doc.get("version")
            if version != PLAN_STORE_VERSION and version not in PLAN_STORE_COMPAT_VERSIONS:
                raise PlanStoreError(
                    f"plan store version {version!r} does not match "
                    f"this build's version {PLAN_STORE_VERSION}"
                )
            legacy_conv = version < 2  # pre-column-tiling conv docs
            specs = [_spec_from_doc(d) for d in doc["specs"]]

            def spec_at(ix) -> TpuSpec:
                if not isinstance(ix, int) or not 0 <= ix < len(specs):
                    raise PlanStoreError(f"bad spec index {ix!r}")
                return specs[ix]

            for e in doc["gemm"]:
                if len(e["key"]) != 3 or len(e["block"]) != 3:
                    raise PlanStoreError(
                        f"bad gemm entry: key={e['key']!r} block={e['block']!r}"
                    )
                m, nn, k = (int(v) for v in e["key"])
                key = (m, nn, k, spec_at(e["spec"]))
                blocks[key] = MatmulBlock(*(int(v) for v in e["block"]))
                block_src[key] = str(e.get("source", "analytic"))
            for e in doc["conv"]:
                if legacy_conv:
                    # pre-column-tiling choice docs lack (tile_cols,
                    # halo_mode); dropping them re-plans those layers
                    continue
                key = tuple(int(v) for v in e["key"]) + (spec_at(e["spec"]),)
                if len(key) != 11:
                    raise PlanStoreError(f"bad conv key of length {len(key)}")
                choice = e["choice"]
                conv_tiles[key] = (
                    None if choice is None else dse.conv_choice_from_doc(choice)
                )
                conv_src[key] = str(e.get("source", "analytic"))
            for e in doc.get("precision", ()) if version >= 3 else ():
                if len(e["key"]) != 2 or len(e["fmt"]) != 3:
                    raise PlanStoreError(
                        f"bad precision entry: key={e['key']!r} fmt={e['fmt']!r}"
                    )
                net, layer = (str(v) for v in e["key"])
                key = (net, layer, spec_at(e["spec"]))
                ib, fb, tb = (int(v) for v in e["fmt"])
                drift = e.get("drift")
                precision[key] = PrecisionChoice(
                    fmt=QFormat(ib, fb, tb),
                    drift=None if drift is None else float(drift),
                )
                prec_src[key] = str(e.get("source", "measured"))
        except PlanStoreError:
            raise
        except (KeyError, IndexError, TypeError, ValueError) as err:
            raise PlanStoreError(f"corrupted plan store: {err!r}") from err
        # commit only after the whole document validated — a rejected store
        # must never leave a half-merged registry behind
        self._merge_entries(self._blocks, self._block_src, blocks, block_src)
        self._merge_entries(self._conv_tiles, self._conv_src, conv_tiles, conv_src)
        self._merge_entries(self._precision, self._prec_src, precision, prec_src)
        return len(blocks) + len(conv_tiles) + len(precision)

    @staticmethod
    def _merge_entries(dst_vals: dict, dst_src: dict, vals: dict, srcs: dict) -> None:
        """Merge entry maps; an existing *measured* pin outranks an incoming
        analytic choice (measured-time autotune results are expensive and
        must never be silently downgraded by a concurrent analytic writer)."""
        for key, val in vals.items():
            src = srcs.get(key, "analytic")
            if dst_src.get(key) == "measured" and src != "measured":
                continue
            dst_vals[key] = val
            dst_src[key] = src

    def merge_from(self, other: "PlanRegistry", spec: Optional[TpuSpec] = None) -> None:
        """Copy ``other``'s entries into this registry (incoming wins on
        conflict, except that measured pins outrank analytic choices);
        ``spec`` restricts the copy to entries keyed by one hardware spec.
        Counters are untouched — merges are not lookups."""
        blocks = {
            k: v for k, v in other._blocks.items() if spec is None or k[3] == spec
        }
        tiles = {
            k: v for k, v in other._conv_tiles.items() if spec is None or k[-1] == spec
        }
        prec = {
            k: v for k, v in other._precision.items() if spec is None or k[-1] == spec
        }
        self._merge_entries(self._blocks, self._block_src, blocks, other._block_src)
        self._merge_entries(self._conv_tiles, self._conv_src, tiles, other._conv_src)
        self._merge_entries(self._precision, self._prec_src, prec, other._prec_src)

    def specs(self) -> set:
        """The distinct hardware specs this registry holds entries for."""
        return (
            {key[3] for key in self._blocks}
            | {key[-1] for key in self._conv_tiles}
            | {key[-1] for key in self._precision}
        )

    def gemm_shapes(self, spec: TpuSpec = TPU_V5E) -> list:
        """The distinct (m, n, k) GEMM keys planned for ``spec``, sorted.

        Lets a mesh-mode scheduler warmup re-plan every GEMM it just traced
        at its *local per-shard* shape (``Engine.plan_gemm(mesh=...)``)
        without re-deriving the model's layer dimensions."""
        return sorted(key[:3] for key in self._blocks if key[3] == spec)

    def save(self, path: str) -> str:
        """Write the registry as versioned JSON (stage-then-commit atomic).

        The staged temp file is fsync'd before the ``os.replace`` commit so
        a crash after the rename cannot leave the store pointing at
        unflushed data; a crash *before* it leaves the previous store
        untouched (plus a stale ``{path}.tmp.{pid}`` — garbage-collected by
        the next :func:`save_plan_store` under the merge lock)."""
        doc = self.to_doc()
        tmp = f"{path}.tmp.{os.getpid()}"
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        try:  # make the rename itself durable
            dfd = os.open(parent, os.O_RDONLY)
            try:
                os.fsync(dfd)
            finally:
                os.close(dfd)
        except OSError:
            pass
        return path

    def load(self, path: str) -> int:
        """Merge a persisted store into this registry; returns entries loaded."""
        try:
            with open(path) as f:
                doc = json.load(f)
        except OSError as err:
            raise PlanStoreError(f"cannot read plan store {path!r}: {err}") from err
        except json.JSONDecodeError as err:
            raise PlanStoreError(f"corrupted plan store {path!r}: {err}") from err
        if not isinstance(doc, dict):
            raise PlanStoreError(f"corrupted plan store {path!r}: not a JSON object")
        return self.merge_doc(doc)


#: Back-compat alias — PR 1/2 code and tests constructed PlanCache directly.
PlanCache = PlanRegistry


_PLAN_CACHES: dict = {}
#: Higher-level plan memos (e.g. models/cnn.py's NetworkPlan table) register
#: themselves here so reset_plan_caches() empties them too.
_EXTRA_PLAN_STORES: list = []


def plan_cache_for(spec: TpuSpec = TPU_V5E) -> PlanRegistry:
    """The process-global plan registry for a hardware spec."""
    cache = _PLAN_CACHES.get(spec)
    if cache is None:
        cache = _PLAN_CACHES[spec] = PlanRegistry()
    return cache


def register_plan_store(store: dict) -> None:
    """Register a derived plan memo to be emptied by :func:`reset_plan_caches`.

    Registrations are deduplicated by identity: a module re-registering its
    (module-level) memo — e.g. via importlib.reload — must not grow the list.
    """
    if any(s is store for s in _EXTRA_PLAN_STORES):
        return
    _EXTRA_PLAN_STORES.append(store)


def reset_plan_caches() -> None:
    """Drop all cached plans (tests / reconfiguration).

    Caches are cleared in place — live Engines keep their (now empty)
    PlanRegistry object, so their stats stay consistent with the global one.
    """
    for cache in _PLAN_CACHES.values():
        cache.clear()
    for store in _EXTRA_PLAN_STORES:
        store.clear()


# ---------------------------------------------------------------------------
# persisted plan store (all per-spec registries <-> one JSON file)
# ---------------------------------------------------------------------------


def default_plan_store_path() -> Optional[str]:
    """The ``REPRO_PLAN_STORE`` path, or None when unset/empty."""
    return os.environ.get(PLAN_STORE_ENV) or None


@contextlib.contextmanager
def _store_write_lock(path: str):
    """Serialize the read-merge-write save cycle across processes sharing one
    store (serve + train, parallel CI shards) via an advisory flock on a
    sidecar file.  Best-effort: on platforms without fcntl the save falls
    back to the unserialized (atomic-replace) write."""
    try:
        import fcntl
    except ImportError:  # pragma: no cover - non-POSIX platforms
        yield
        return
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(f"{path}.lock", "w") as lockf:
        fcntl.flock(lockf, fcntl.LOCK_EX)
        try:
            yield
        finally:
            fcntl.flock(lockf, fcntl.LOCK_UN)


def save_plan_store(path: Optional[str] = None) -> str:
    """Serialize every process-global registry into one versioned JSON file.

    Entries already on disk are merged in first (this process's plans win on
    conflict), so concurrent writers sharing one store — e.g. serve + train,
    or two CI shards — append to rather than overwrite each other's work.
    An unusable on-disk store is simply replaced.
    """
    path = path or default_plan_store_path()
    if path is None:
        raise ValueError(
            f"no plan-store path given and {PLAN_STORE_ENV} is unset"
        )
    with _store_write_lock(path):
        merged = PlanRegistry()
        if os.path.exists(path):
            try:
                merged.load(path)
            except PlanStoreError:
                pass
        for reg in _PLAN_CACHES.values():
            merged.merge_from(reg)
        out = merged.save(path)
        # gc temp litter from writers that died inside the stage->commit
        # window; safe under the merge lock (every store writer stages its
        # temp file while holding it, so any `{path}.tmp.*` sibling we can
        # see here is an orphan)
        for stale in glob.glob(f"{path}.tmp.*"):
            try:
                os.unlink(stale)
            except OSError:
                pass
        return out


def load_plan_store(path: Optional[str] = None, *, missing_ok: bool = False) -> int:
    """Load a persisted store and distribute entries to the per-spec global
    registries.  Returns the number of entries loaded (0 when ``missing_ok``
    and the file does not exist)."""
    path = path or default_plan_store_path()
    if path is None:
        raise ValueError(
            f"no plan-store path given and {PLAN_STORE_ENV} is unset"
        )
    if missing_ok and not os.path.exists(path):
        return 0
    stage = PlanRegistry()
    n = stage.load(path)
    for spec in stage.specs():
        plan_cache_for(spec).merge_from(stage, spec)
    return n


def warm_start_plan_store(path: Optional[str] = None) -> tuple[Optional[str], int]:
    """Warm start from ``path`` (default: ``REPRO_PLAN_STORE``) if it exists.

    The one warm-start entry point the launch drivers and the benchmark
    harness share.  Returns (path, entries_loaded); (None, 0) when neither a
    path nor the env var names a store.  A corrupted or version-mismatched
    store is *not* fatal here — a warm-start cache must never be a startup
    single point of failure, so the error is reported and the process cold
    starts (strict loading stays available via :func:`load_plan_store`; the
    CI warm gate still fails because zero entries load).
    """
    path = path or default_plan_store_path()
    if path is None:
        return None, 0
    try:
        return path, load_plan_store(path, missing_ok=True)
    except PlanStoreError as err:
        import warnings

        warnings.warn(f"ignoring unusable plan store {path!r}: {err}")
        return path, 0


def plan_store_stats() -> dict:
    """Aggregate :meth:`PlanRegistry.stats` across all per-spec registries."""
    total = {
        "gemm_blocks": 0, "conv_tiles": 0, "precision": 0,
        "hits": 0, "misses": 0, "measured": 0,
    }
    for reg in _PLAN_CACHES.values():
        for k, v in reg.stats().items():
            total[k] += v
    return total


# ---------------------------------------------------------------------------
# per-layer plans
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GemmPlan:
    """Pre-resolved plan for one GEMM shape.

    (m, n, k) is the shape the kernel *executes* — under a mesh that is the
    local per-shard shape, and ``logical`` records the global shape it was
    derived from (empty when planned unsharded or the mesh splits nothing).
    """

    m: int
    n: int
    k: int
    block: Optional[MatmulBlock]  # None for the xla backend
    logical: tuple = ()


@dataclasses.dataclass(frozen=True)
class ConvPlan:
    """Pre-resolved plan for one conv layer.

    route: "direct" (Pallas direct conv), "im2col" (GEMM fallback), or "xla".
    tau: output-channel tile of the direct kernel (0 on GEMM routes).
    block: Pallas block for the im2col GEMM (None otherwise).
    gemm: the layer's equivalent (m, n, k) GEMM shape.
    vmem_bytes: modeled VMEM working set of the chosen route's grid step.
    tile_rows: direct-route output rows per grid step (0 = whole image).
    spatial_tiles: ceil(Ho / tile_rows) — grid steps along the row axis.
    tile_cols: direct-route output columns per grid step (0 = full width;
        only the DMA-halo regime tiles this axis).
    col_tiles: ceil(Wo / tile_cols) — grid steps along the column axis.
    halo_mode: tiled-input regime — "none" (untiled), "two_block" (blocked
        successor reads), or "dma" (exact-window async copies); see
        kernels/conv2d.py and DESIGN.md §2.
    halo: cross-chip spatial-sharding seam (a ``SpatialHalo``, DESIGN.md
        §10) — when set, the layer executes per H slab in the slab-major
        (S, N, lx, W, C) layout via :meth:`Engine._conv2d_spatial`; ``pad``
        is then 0 (the halo exchange's zero fill *is* the H padding, and
        the executor pre-pads W by ``halo.pad``).
    """

    route: str
    stride: int
    pad: int
    tau: int
    block: Optional[MatmulBlock]
    gemm: tuple
    vmem_bytes: int
    tile_rows: int = 0
    spatial_tiles: int = 1
    tile_cols: int = 0
    col_tiles: int = 1
    halo_mode: str = "none"
    halo: Optional[object] = None  # SpatialHalo (kept untyped: lazy import)


#: VMEM working-set model of one direct-conv grid step — lives with the rest
#: of the DSE scoring in core/dse.py; re-exported here because the engine is
#: its primary consumer (DESIGN.md §2).
_direct_conv_vmem = dse.direct_conv_vmem


def _resolve_pad(padding, kh: int) -> int:
    if isinstance(padding, int):
        return padding
    return {"SAME": kh // 2, "VALID": 0}[padding]


def validate_policy(config, policy: Optional[NumericsPolicy]) -> NumericsPolicy:
    """Check a numerics policy against a template config (DESIGN.md §8).

    A quantized policy only makes sense on the q16 backend (the float
    backends would silently run the QTensor raws as numbers); rejecting the
    combo here gives serve/scheduler callers one clear error instead of
    garbage logits.  Returns the resolved policy (float when ``None``).
    """
    policy = policy or NumericsPolicy("float")
    if policy.quantized and config.backend != "q16":
        raise ValueError(
            f"NumericsPolicy({policy.name!r}) requires the 'q16' backend, but "
            f"the template is configured with backend={config.backend!r}"
        )
    return policy


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


class Engine:
    """Executes GEMM/conv plans for one template configuration.

    Stateless w.r.t. numerics; holds the (shared) plan cache and per-engine
    routing counters (``counters["conv_direct"]`` etc.) used by routing
    assertions in tests.
    """

    def __init__(self, config=None, plan_cache: Optional[PlanCache] = None) -> None:
        if config is None:
            from .template import TemplateConfig

            config = TemplateConfig()
        self.config = config
        # explicit `is not None`: an empty PlanCache is falsy (__len__ == 0)
        # but still the caller's requested isolated cache
        self.plan_cache = plan_cache if plan_cache is not None else plan_cache_for(config.hw)
        self.counters: collections.Counter = collections.Counter()
        # quantized-param cache: (id(params), policy) -> (params, qparams).
        # The strong ref to the source tree both prevents id-reuse aliasing
        # and documents the contract: weights are quantized exactly once per
        # (param tree, policy) per engine (DESIGN.md §8).
        self._qparam_cache: dict = {}
        self._calibrating = False
        self._act_maxabs = 0.0

    # -- planning ------------------------------------------------------------

    def block_for(self, m: int, n: int, k: int) -> MatmulBlock:
        """The Pallas block for a GEMM shape: config override or cached DSE."""
        if self.config.block is not None:
            return clamp_block(m, n, k, self.config.block, self.config.hw)
        return self.plan_cache.block_for(m, n, k, self.config.hw)

    @staticmethod
    def _active_mesh():
        from repro.parallel.sharding import active_mesh

        return active_mesh()

    def _adhoc_block(self, m: int, n: int, k: int) -> MatmulBlock:
        """Block for a *plan-less* GEMM dispatch: localize (m, n, k) under an
        active :func:`use_mesh` context first (ISSUE 9) — an ad-hoc call
        inside a mesh otherwise plans at the global shape, which
        ``plan_gemm(mesh=...)`` never executes, so a store warmed through
        the planner reports spurious misses for the very same layer."""
        mesh = self._active_mesh()
        if mesh is not None:
            from repro.parallel.sharding import local_gemm_shape

            m, n, k = local_gemm_shape(m, n, k, mesh=mesh)
        return self.block_for(m, n, k)

    def measure_and_pin(self, m: int, n: int, k: int, **kw) -> MatmulBlock:
        """Measured-time autotune for this engine's hardware spec — times the
        top-K analytic candidates and pins the winner in the registry."""
        kw.setdefault("interpret", self.config.interpret)
        return self.plan_cache.measure_and_pin(m, n, k, self.config.hw, **kw)

    def plan_gemm(
        self, m: int, n: int, k: int, *, mesh=None, partition=None
    ) -> GemmPlan:
        """Plan one GEMM; with ``mesh`` (+ optional PartitionSpec over
        (M, N[, K])) the *local per-shard* shape is planned instead of the
        logical one — a (16,16) mesh and a single chip produce different,
        each-correct, plans from the same registry (DESIGN.md §6)."""
        logical = ()
        if mesh is not None:
            from repro.parallel.sharding import local_gemm_shape

            lm, ln, lk = local_gemm_shape(m, n, k, mesh=mesh, partition=partition)
            if (lm, ln, lk) != (m, n, k):
                logical = (m, n, k)
            m, n, k = lm, ln, lk
        block = None if self.config.backend == "xla" else self.block_for(m, n, k)
        return GemmPlan(m=m, n=n, k=k, block=block, logical=logical)

    def plan_gemm_ladder(
        self, ladder: Sequence[int], n: int, k: int, *, batches: Sequence[int] = (1,),
        mesh=None, partition=None
    ) -> dict:
        """Plan one GEMM per (batch rung x bucket-ladder rung) product
        (M = batch * rung, fixed N/K).

        This is the scheduler's warmup primitive: planning every rung up
        front guarantees each bucket's shape is in the PlanRegistry before
        traffic arrives, so a mixed trace replayed against the warm registry
        (or a persisted store) reports ``misses == 0``.  ``batches`` extends
        the ladder to coalesced (B, L) prefill launches, whose GEMMs flatten
        the leading dims into M = B * L (:func:`batch_rungs`); the default
        (1,) is the plain per-rung ladder.
        """
        ms = sorted({int(b) * int(m) for b in batches for m in ladder})
        return {
            m: self.plan_gemm(m, n, k, mesh=mesh, partition=partition)
            for m in ms
        }

    def plan_conv(
        self, x_shape, w_shape, *, stride: int = 1, padding=0,
        route: Optional[str] = None, mesh=None, partition=None, spatial=None,
    ) -> ConvPlan:
        """Pick the kernel route for one conv layer (DESIGN.md §2).

        Direct route: the DSE (``dse.explore_conv_spatial``, memoized in the
        plan cache) picks the (τ, tile_rows, tile_cols, halo_mode)
        compute-unit config — whole-slab when the padded image fits the VMEM
        budget, otherwise a (𝒯, ℭ) spatial tiling whose halo regime the
        HBM-traffic score chooses (the manual-DMA regime wins over two-block
        whenever legal — strictly less re-streaming and residency).  Only
        when *no* config fits does the layer fall back to the im2col GEMM
        with a plan-cached DSE block.  ``route`` forces a route (tests /
        benchmarks).  With ``mesh`` the *local* shard of the layer is planned:
        batch over the partition's M axes, output channels over its N axes.

        ``spatial`` (a shard count, mesh axis name, or pre-chained
        :class:`SpatialHalo`) plans the cross-chip H-slab partition instead
        (DESIGN.md §10): the per-shard kernel runs at the halo-augmented
        ``win``-row window with padding folded into the exchange's zero fill,
        and the returned plan carries the seam in ``plan.halo`` — batch and
        Cout then stay shard-local, so ``partition`` does not apply.
        """
        if spatial is not None:
            from repro.parallel.sharding import (SpatialHalo,
                                                 plan_spatial_halo,
                                                 spatial_shards)

            n, h, wd, cin = x_shape
            kh = w_shape[0]
            pad = _resolve_pad(padding, kh)
            hs = spatial if isinstance(spatial, SpatialHalo) else plan_spatial_halo(
                h, kh, stride, pad, *spatial_shards(spatial, mesh)
            )
            inner = self.plan_conv(
                (n, hs.win, wd + 2 * pad, cin), w_shape,
                stride=stride, padding=0, route=route,
            )
            return dataclasses.replace(inner, halo=hs)
        if mesh is not None:
            from repro.parallel.sharding import local_conv_shapes

            x_shape, w_shape = local_conv_shapes(
                x_shape, w_shape, mesh=mesh, partition=partition
            )
        n, h, wd, cin = x_shape
        kh, kw, _, cout = w_shape
        pad = _resolve_pad(padding, kh)
        hp, wp = h + 2 * pad, wd + 2 * pad
        ho = (hp - kh) // stride + 1
        wo = (wp - kw) // stride + 1
        gemm = (n * ho * wo, cout, cin * kh * kw)
        backend = self.config.backend
        if backend == "xla" or route == "xla":
            return ConvPlan("xla", stride, pad, 0, None, gemm, 0)
        if route != "im2col":
            in_bytes = (self.config.qformat.total_bits // 8) if backend == "q16" else 4
            choice = self.plan_cache.conv_tile_for(
                hp, wp, cin, kh, kw, ho, wo, cout, stride, in_bytes, self.config.hw
            )
            if choice is not None:
                tile_rows = 0 if choice.tile_rows >= ho else choice.tile_rows
                tile_cols = 0 if (choice.tile_cols or wo) >= wo else choice.tile_cols
                halo_mode = choice.halo_mode or (
                    "two_block" if tile_rows else "none"
                )
                return ConvPlan(
                    "direct", stride, pad, choice.tau, None, gemm,
                    choice.vmem_bytes, tile_rows, choice.spatial_tiles,
                    tile_cols, choice.col_tiles, halo_mode,
                )
            if route == "direct":
                raise ValueError(
                    f"direct conv route forced but no (tau, tile_rows) config "
                    f"for image slab {x_shape} fits VMEM "
                    f"({self.config.hw.vmem_bytes} bytes)"
                )
        block = self.block_for(*gemm)
        return ConvPlan("im2col", stride, pad, 0, block, gemm, block.vmem_bytes())

    # -- fixed-point residency (the QTensor plane, DESIGN.md §8) -------------

    def quant(self, x, fmt: Optional[QFormat] = None) -> QTensor:
        """Float -> QTensor on the activation grid — a counted island *exit*.

        ``quantize_calls`` is the residency enforcement counter: between two
        consecutive grid-resident ops it must not tick, so a test tracing one
        q16 decode step can assert the count equals exactly the number of
        designated float islands (DESIGN.md §8).
        """
        if isinstance(x, QTensor):
            return x
        fmt = fmt or self.config.qformat
        self.counters["quantize_calls"] += 1
        if self._calibrating:
            # debug.callback so recording survives scan/jit tracing: the
            # concrete per-site max reaches the host at execution time
            jax.debug.callback(self._record_act_maxabs, jnp.max(jnp.abs(x)))
        return QTensor(quantize(x, fmt), fmt)

    def _record_act_maxabs(self, v) -> None:
        self._act_maxabs = max(self._act_maxabs, float(v))

    def calibrate_activation_format(self, run, *, total_bits: int = 16) -> QFormat:
        """The activation half of the max-abs calibration pass (DESIGN.md §8).

        Runs ``run()`` (an *eager* forward over a calibration batch) with
        every :meth:`quant` site recording the magnitude of the float value
        it is about to snap, then picks the smallest Qm.n whose range covers
        the observed maximum.  Per-tensor weight formats come from
        :meth:`quantize_weight`; activations share this one grid so every
        island exit lands on a single, kernel-static format.
        """
        from .quantization import calibrate_format

        self._act_maxabs = 0.0
        self._calibrating = True
        try:
            jax.block_until_ready(run())
            # block_until_ready waits on device buffers only; the host-side
            # recording callbacks need the effects barrier on async backends
            jax.effects_barrier()
        finally:
            self._calibrating = False
        return calibrate_format(
            jnp.float32(self._act_maxabs), total_bits=total_bits
        )

    def dequant(self, q, fmt: Optional[QFormat] = None, dtype=jnp.float32) -> jax.Array:
        """QTensor (or raw int16 + fmt) -> float — a counted island *entry*."""
        self.counters["dequantize_calls"] += 1
        if isinstance(q, QTensor):
            return dequantize(q.raw, q.fmt, dtype)
        return dequantize(q, fmt or self.config.qformat, dtype)

    def quantize_weight(
        self,
        w: jax.Array,
        policy: NumericsPolicy,
        fmt: Optional[QFormat] = None,
        contraction_axes: Optional[tuple] = None,
        fused_bias: bool = False,
        act_fmt: Optional[QFormat] = None,
        total_bits: Optional[int] = None,
    ) -> QTensor:
        """Quantize one persistent weight (calibrated per-tensor by default;
        ``fmt`` pins a format — e.g. biases stay on the activation grid so
        the accumulator alignment shift can never go negative).

        ``contraction_axes`` (the axes a GEMM/conv reduces over — (-2,) for
        dense (…, k, n) weights, the kh/kw/cin axes for conv) enables the
        *accumulator-headroom rule*: the int32 accumulator wraps (TPU-native;
        the FPGA DSP48 cascade is 48-bit, DESIGN.md §2), and the exact
        adversarial bound on one output is ``max|x_raw| · L1`` with L1 the
        largest per-output column sum of |w_raw|.  The calibrated fraction is
        capped so even ``max|x_raw| · L1`` cannot reach 2^31 — the finest
        weight grid that can never overflow, regardless of activation
        content; with ``fused_bias`` one extra headroom bit covers the
        in-kernel shifted bias add.  ``act_fmt`` names the activation grid
        feeding this layer (default ``policy.fmt``): an int8 input has
        ``max|x_raw| ≤ 2^7``, which widens the budget by 8 bits vs int16.
        ``total_bits`` pins the weight's *storage* rung (default: match the
        activation's — the int8 weight grid of the precision ladder).
        Counted separately from ``quantize_calls``: weight quantization
        happens once at preparation, never inside a step.
        """
        import math

        self.counters["weights_quantized"] += 1
        if fmt is not None:
            return quantize_qtensor(w, fmt)
        if not policy.per_tensor_weights:
            return quantize_qtensor(w, policy.fmt)
        act_fmt = act_fmt or policy.fmt
        total_bits = total_bits or act_fmt.total_bits
        max_frac = None
        if contraction_axes:
            l1 = float(jnp.max(jnp.sum(jnp.abs(w.astype(jnp.float32)),
                                       axis=contraction_axes)))
            if l1 > 0:
                # 2^(act_bits-1) * (L1 * 2^frac) < 2^31
                #   =>  frac <= 32 - act_bits - log2(L1)
                # (16/15 for int16 activations, 24/23 for int8), minus one
                # bit of margin when a bias add joins the epilogue
                budget = float(31 - (act_fmt.total_bits - 1) - (1 if fused_bias else 0))
                max_frac = math.floor(budget - math.log2(l1) - 1e-9)
        from .quantization import calibrate_format

        wfmt = calibrate_format(w, max_frac=max_frac, total_bits=total_bits)
        return QTensor(quantize(w, wfmt), wfmt)

    def qparams_for(self, params, policy: NumericsPolicy, build):
        """Quantize-once parameter cache, keyed by param-tree identity.

        ``build()`` constructs the quantized tree on the first call for a
        given (params, policy); later calls — a second `generate()`, every
        scheduler restart sharing the tree — return the cached tree without
        touching the weights (``qparam_cache_hits`` vs ``qparam_builds``).
        The cache holds a strong reference to the source tree, so an id()
        recycled by the allocator can never alias a different tree.
        """
        validate_policy(self.config, policy)
        key = (id(params), policy)
        ent = self._qparam_cache.get(key)
        if ent is not None and ent[0] is params:
            self.counters["qparam_cache_hits"] += 1
            return ent[1]
        self.counters["qparam_builds"] += 1
        qp = build()
        self._qparam_cache[key] = (params, qp)
        return qp

    def drop_qparams(self, params, policy: NumericsPolicy) -> bool:
        """Release one cached quantized tree (e.g. a calibration probe's —
        it was built under the provisional base policy and would otherwise
        pin a full int16 weight copy for the process lifetime)."""
        return self._qparam_cache.pop((id(params), policy), None) is not None

    def _quant_operand(self, v) -> QTensor:
        """QTensor passthrough; float operands are quantized inline (counted).

        Persistent weights should arrive pre-quantized via a qparam tree —
        the inline path exists so ad-hoc callers still compute correctly,
        at the cost of a visible ``quantize_calls`` tick per call.
        """
        if isinstance(v, QTensor):
            return v
        return self.quant(v)

    def _qbias_operand(self, bias, acc_frac: int):
        """Shared bias prep for the grid-resident GEMM/conv: quantize if
        needed and compute the accumulator alignment shift.  Returns
        (raw_or_None, bias_shift_or_None)."""
        if bias is None:
            return None, None
        bias = self._quant_operand(bias)
        bias_shift = acc_frac - bias.fmt.frac_bits
        if bias_shift < 0:
            raise ValueError(
                f"bias format {bias.fmt.name} is finer than the "
                f"2^-{acc_frac} accumulator grid"
            )
        return bias.raw, bias_shift

    def _qmatmul(
        self,
        x,
        w,
        *,
        bias=None,
        relu: bool = False,
        out_fmt: Optional[QFormat] = None,
        wide: bool = False,
        plan: Optional[GemmPlan] = None,
    ):
        """Grid-resident GEMM: QTensor in -> QTensor out, zero float hops.

        The requantize epilogue is fused into the kernel write-back (shift =
        fa + fb - fo); ``wide=True`` reads the int32 accumulator out instead
        and descales exactly — the final-logits island, counted as one
        dequantize.
        """
        from repro.kernels import ops as kops

        x = self._quant_operand(x)
        w = self._quant_operand(w)
        # stay on the *input's* activation grid by default: consecutive
        # grid-resident ops then agree on the format without the caller
        # re-stating the policy at every call site
        out_fmt = out_fmt or x.fmt
        lead = x.shape[:-1]
        k = x.shape[-1]
        n = w.shape[-1]
        x2 = x.reshape(-1, k)
        m = x2.shape[0]
        acc_frac = x.fmt.frac_bits + w.fmt.frac_bits
        b_raw, bias_shift = self._qbias_operand(bias, acc_frac)
        self.counters["gemm_q16"] += 1
        block = (
            plan.block
            if plan is not None and plan.block is not None
            else self._adhoc_block(m, n, k)
        )
        out = kops.matmul_q16(
            x2.raw, w.raw, bias=b_raw, relu=relu, fmt=out_fmt,
            shift=acc_frac - out_fmt.frac_bits, bias_shift=bias_shift,
            wide=wide, block=block, interpret=self.config.interpret,
        )
        if wide:
            self.counters["dequantize_calls"] += 1
            return (out.astype(jnp.float32) * 2.0 ** -acc_frac).reshape(*lead, n)
        return QTensor(out.reshape(*lead, n), out_fmt)

    def _qconv2d(
        self,
        x,
        w,
        *,
        stride: int = 1,
        padding=0,
        bias=None,
        relu: bool = False,
        out_fmt: Optional[QFormat] = None,
        plan: Optional[ConvPlan] = None,
    ) -> QTensor:
        """Grid-resident conv (direct or im2col route per the plan)."""
        from repro.kernels import ops as kops

        x = self._quant_operand(x)
        w = self._quant_operand(w)
        out_fmt = out_fmt or x.fmt  # same grid-following rule as _qmatmul
        if plan is not None and plan.halo is not None:
            return self._conv2d_spatial(
                x, w, bias=bias, relu=relu, qout=out_fmt, plan=plan
            )
        if plan is None:
            # ad-hoc dispatch inside use_mesh plans the *local* shard shape,
            # matching plan_conv(mesh=...) warmups (ISSUE 9)
            plan = self.plan_conv(
                x.shape, w.shape, stride=stride, padding=padding,
                mesh=self._active_mesh(),
            )
        if plan.route == "xla":
            raise ValueError("grid-resident conv has no xla route (q16 only)")
        stride, pad = plan.stride, plan.pad
        acc_frac = x.fmt.frac_bits + w.fmt.frac_bits
        b_raw, bias_shift = self._qbias_operand(bias, acc_frac)
        self.counters["conv_direct" if plan.route == "direct" else "conv_im2col"] += 1
        out = kops.conv2d_q16(
            x.raw, w.raw, bias=b_raw, stride=stride, padding=pad, tau=plan.tau,
            relu=relu, fmt=out_fmt, shift=acc_frac - out_fmt.frac_bits,
            bias_shift=bias_shift, route=plan.route, block=plan.block,
            tile_rows=plan.tile_rows, tile_cols=plan.tile_cols,
            halo_mode=plan.halo_mode, interpret=self.config.interpret,
        )
        return QTensor(out, out_fmt)

    # -- execution: GEMM -----------------------------------------------------

    def _xla_epilogue(self, out, bias, relu, qout, dtype):
        out = out.astype(dtype)
        if bias is not None:
            out = out + bias.astype(dtype)
        if relu:
            out = jax.nn.relu(out)
        if qout is not None:
            out = fake_quant_fmt(out, qout)  # STE: keeps the train path differentiable
        return out

    def matmul(
        self,
        x: jax.Array,
        w: jax.Array,
        *,
        bias: Optional[jax.Array] = None,
        relu: bool = False,
        qout: Optional[QFormat] = None,
        wide: bool = False,
        plan: Optional[GemmPlan] = None,
    ) -> jax.Array:
        """``x @ w`` with fused epilogue; leading dims of x flatten into M.

        On the q16 backend the output is inherently snapped to the backend's
        ``config.qformat`` grid by the kernel's saturating write-back, so
        ``qout`` is implied by the backend and ignored there (same rule as
        :meth:`conv2d`).

        QTensor operands take the *grid-resident* path (DESIGN.md §8): the
        GEMM consumes int16 raws, fuses the requantize epilogue in-kernel,
        and returns a QTensor — no float round-trip.  ``qout`` then names the
        output grid (default: the backend qformat) and ``wide=True`` returns
        exactly-descaled float logits from the int32 accumulator instead.
        """
        if isinstance(x, QTensor) or isinstance(w, QTensor):
            return self._qmatmul(
                x, w, bias=bias, relu=relu, out_fmt=qout, wide=wide, plan=plan
            )
        if x.ndim == 1:
            return self.matmul(x[None, :], w, bias=bias, relu=relu, qout=qout, plan=plan)[0]
        lead = x.shape[:-1]
        k = x.shape[-1]
        n = w.shape[-1]
        x2 = x.reshape(-1, k)
        m = x2.shape[0]
        backend = self.config.backend
        if backend == "xla":
            pet = self.config.accum_dtype or x.dtype
            out = jnp.dot(x2, w.astype(x.dtype), preferred_element_type=pet)
            out = self._xla_epilogue(out, bias, relu, qout, x.dtype)
        elif backend == "pallas":
            from repro.kernels import ops as kops

            self.counters["gemm_pallas"] += 1
            block = plan.block if plan is not None and plan.block is not None else self._adhoc_block(m, n, k)
            out = kops.matmul_fp(
                x2, w, bias=bias, relu=relu, qout=qout, block=block,
                interpret=self.config.interpret,
            )
        elif backend == "q16":
            from repro.kernels import ops as kops

            # legacy per-op fixed point: float operands are quantized and the
            # result dequantized *every call* — the counters make this float
            # round-trip visible so residency tests catch accidental use
            # (the stay-on-grid path is the QTensor dispatch above).
            self.counters["gemm_q16"] += 1
            self.counters["quantize_calls"] += 2 if bias is None else 3
            self.counters["dequantize_calls"] += 1
            fmt = self.config.qformat
            block = plan.block if plan is not None and plan.block is not None else self._adhoc_block(m, n, k)
            qres = kops.matmul_q16(
                quantize(x2, fmt),
                quantize(w, fmt),
                bias=None if bias is None else quantize(bias, fmt),
                relu=relu,
                fmt=fmt,
                block=block,
                interpret=self.config.interpret,
            )
            out = dequantize(qres, fmt, dtype=x.dtype)
        else:  # pragma: no cover - config validation
            raise ValueError(f"unknown backend {backend!r}")
        return out.reshape(*lead, n)

    def linear(
        self,
        x: jax.Array,
        w: jax.Array,
        b: Optional[jax.Array] = None,
        *,
        relu: bool = False,
        qout: Optional[QFormat] = None,
        wide: bool = False,
        plan: Optional[GemmPlan] = None,
    ) -> jax.Array:
        return self.matmul(x, w, bias=b, relu=relu, qout=qout, wide=wide, plan=plan)

    # -- execution: conv -----------------------------------------------------

    def conv2d(
        self,
        x: jax.Array,
        w: jax.Array,
        *,
        stride: int = 1,
        padding=0,
        bias: Optional[jax.Array] = None,
        relu: bool = False,
        qout: Optional[QFormat] = None,
        plan: Optional[ConvPlan] = None,
    ) -> jax.Array:
        """NHWC conv through the planned kernel route, epilogue fused.

        x: (N, H, W, Cin), w: (K, K, Cin, Cout) -> (N, Ho, Wo, Cout).
        On the q16 backend the output is inherently Q-gridded, so ``qout``
        is implied by the backend's qformat.  QTensor operands take the
        grid-resident path and return a QTensor (DESIGN.md §8).
        """
        from repro.kernels import ops as kops

        if plan is not None and plan.halo is not None and not isinstance(x, QTensor):
            return self._conv2d_spatial(
                x, w, bias=bias, relu=relu, qout=qout, plan=plan
            )
        if isinstance(x, QTensor) or isinstance(w, QTensor):
            return self._qconv2d(
                x, w, stride=stride, padding=padding, bias=bias, relu=relu,
                out_fmt=qout, plan=plan,
            )
        kh, kw = w.shape[0], w.shape[1]
        if plan is None:
            # ad-hoc dispatch inside use_mesh plans the *local* shard shape,
            # matching plan_conv(mesh=...) warmups (ISSUE 9)
            plan = self.plan_conv(
                x.shape, w.shape, stride=stride, padding=padding,
                mesh=self._active_mesh(),
            )
        # The plan is the single source of geometry: stride *and* pad both
        # come from it, so a mismatched plan cannot half-apply.
        stride, pad = plan.stride, plan.pad
        backend = self.config.backend
        if plan.route == "xla":
            self.counters["conv_xla"] += 1
            xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0))) if pad else x
            cols, ho, wo = kops.im2col(xp, kh, kw, stride)
            pet = self.config.accum_dtype or x.dtype
            out = jnp.dot(cols, kops.conv_gemm_weights(w).astype(x.dtype),
                          preferred_element_type=pet)
            out = self._xla_epilogue(out, bias, relu, qout, x.dtype)
            return out.reshape(x.shape[0], ho, wo, -1)
        self.counters["conv_direct" if plan.route == "direct" else "conv_im2col"] += 1
        if backend == "pallas":
            return kops.conv2d(
                x, w, bias=bias, stride=stride, padding=pad, tau=plan.tau,
                relu=relu, qout=qout, route=plan.route, block=plan.block,
                tile_rows=plan.tile_rows, tile_cols=plan.tile_cols,
                halo_mode=plan.halo_mode, interpret=self.config.interpret,
            )
        assert backend == "q16", backend
        # legacy per-op fixed point (see matmul): quantize/dequantize every
        # call, counted so the float round-trip is visible.
        self.counters["quantize_calls"] += 2 if bias is None else 3
        self.counters["dequantize_calls"] += 1
        fmt = self.config.qformat
        qres = kops.conv2d_q16(
            quantize(x, fmt),
            quantize(w, fmt),
            bias=None if bias is None else quantize(bias, fmt),
            stride=stride,
            padding=pad,
            tau=plan.tau,
            relu=relu,
            fmt=fmt,
            route=plan.route,
            block=plan.block,
            tile_rows=plan.tile_rows,
            tile_cols=plan.tile_cols,
            halo_mode=plan.halo_mode,
            interpret=self.config.interpret,
        )
        return dequantize(qres, fmt, dtype=x.dtype)

    def _conv2d_spatial(self, x, w, *, bias, relu, qout, plan: ConvPlan):
        """One spatially-sharded conv seam (DESIGN.md §10).

        ``x`` is slab-major (S, N, lx, W, C) — float array or QTensor —
        with the slab dim (optionally) sharded over ``plan.halo.axis``.
        Exchange the halo rows with the neighbor shards, pre-pad W by the
        conv's ``pad`` (H zeros already came from the exchange's edge
        fill), fold slabs into the batch dim for the planned per-shard
        kernel, then restore the slab layout — masking the ragged tail
        shard's invalid rows back to zero so the *next* seam's halo reads
        stay exact.  Contraction dims never cross a shard boundary, so the
        result is bit-identical to the unsharded kernel per output row.
        """
        from repro.parallel import sharding as sh

        hs = plan.halo
        inner = dataclasses.replace(plan, halo=None)
        self.counters["conv_spatial"] += 1
        quant = isinstance(x, QTensor)
        v = x.raw if quant else x
        v = sh.constrain_slabs(v, hs.axis)
        ext = sh.halo_exchange(v, hs)  # (S, N, win, W, C)
        if hs.pad:
            ext = jnp.pad(
                ext, ((0, 0), (0, 0), (0, 0), (hs.pad, hs.pad), (0, 0))
            )
        s, n = ext.shape[0], ext.shape[1]
        flat = ext.reshape(s * n, *ext.shape[2:])
        out = self.conv2d(
            QTensor(flat, x.fmt) if quant else flat, w,
            bias=bias, relu=relu, qout=qout, plan=inner,
        )
        qres = isinstance(out, QTensor)
        ov = out.raw if qres else out
        ov = ov.reshape(s, n, *ov.shape[1:])
        ov = sh.constrain_slabs(sh.mask_slab_rows(ov, hs), hs.axis)
        return QTensor(ov, out.fmt) if qres else ov
