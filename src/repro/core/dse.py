"""Design-space exploration (paper §III.E "Scalability and Efficiency").

The paper uses trial-based exploration: sample template parameters, simulate,
keep configurations that meet resources/latency.  We make the same search
analytic and exhaustive over a quantized grid:

* :func:`explore_board` — FPGA plane: enumerate (μ, τ, 𝒯, ℭ, λ, Ω) within a
  board's DSP/BRAM/LUT/FF envelope and rank by modeled GOP/s on a target
  network.  Reproduces the paper's per-board compute-unit choices and the
  "τ ≈ 2μ" finding.

* :func:`explore_tpu_block` — TPU plane: enumerate Pallas (bm, bn, bk) blocks
  within the VMEM budget and rank by a roofline score (MXU occupancy ×
  min(1, intensity/ridge)).  This picks the compute-unit configuration the
  Pallas kernels use.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Sequence

from .fpga_model import Board, LayerSpec, TemplateInstance, evaluate_network
from .tiling import ConvTiling, FCTiling, MatmulBlock, TPU_V5E, TpuSpec

__all__ = [
    "DseResult",
    "explore_board",
    "explore_tpu_block",
    "default_block_for",
]


@dataclasses.dataclass
class DseResult:
    instance: TemplateInstance
    gops: float
    latency_ms: float

    @property
    def mu(self) -> int:
        return self.instance.conv.mu

    @property
    def tau(self) -> int:
        return self.instance.conv.tau


def explore_board(
    board: Board,
    layers: Sequence[LayerSpec],
    name: str = "net",
    mu_range: Sequence[int] = (4, 8, 12, 16, 20, 24, 28, 32),
    tau_range: Sequence[int] = (8, 12, 16, 20, 24, 30, 36, 44, 55, 64),
    spatial_tiles: Sequence[int] = (13, 14, 26, 27, 28),
    fc_tiles: Sequence[tuple[int, int]] = ((1024, 64), (2048, 128), (4096, 256)),
    top: int = 10,
) -> list[DseResult]:
    """Exhaustive grid search over the template parameter space for a board."""
    results: list[DseResult] = []
    for mu, tau in itertools.product(mu_range, tau_range):
        if mu * tau > board.dsp:
            continue
        for t_spatial in spatial_tiles:
            conv = ConvTiling(t_r=t_spatial, t_c=t_spatial, mu=mu, tau=tau)
            for lam, omega in fc_tiles:
                fc = FCTiling(lam=lam, omega=omega, mu=mu, tau=tau)
                inst = TemplateInstance(board=board, conv=conv, fc=fc)
                if not inst.fits():
                    continue
                rep = evaluate_network(name, layers, inst)
                results.append(
                    DseResult(instance=inst, gops=rep.gops, latency_ms=rep.latency_ms)
                )
    results.sort(key=lambda r: -r.gops)
    return results[:top]


# ---------------------------------------------------------------------------
# TPU plane
# ---------------------------------------------------------------------------


def _block_score(
    block: MatmulBlock, m: int, n: int, k: int, spec: TpuSpec, dtype_bytes: int = 2
) -> float:
    """Roofline score for one grid step of the tiled matmul.

    peak-normalized throughput = MXU efficiency x min(1, AI / ridge) x
    quantization-waste factor from ceil-division of the problem dims
    (the TPU analogue of the paper's ceil(p/μ)·ceil(q/τ) waste).
    """
    ridge = spec.peak_bf16_flops / spec.hbm_bw  # FLOP/byte to be compute bound
    ai = block.arithmetic_intensity(dtype_bytes)
    waste = (
        (m / (max(1, -(-m // block.bm)) * block.bm))
        * (n / (max(1, -(-n // block.bn)) * block.bn))
        * (k / (max(1, -(-k // block.bk)) * block.bk))
    )
    return block.mxu_efficiency(spec) * min(1.0, ai / ridge) * waste


def explore_tpu_block(
    m: int,
    n: int,
    k: int,
    spec: TpuSpec = TPU_V5E,
    dtype_bytes: int = 2,
    bm_range: Sequence[int] = (128, 256, 512, 1024),
    bn_range: Sequence[int] = (128, 256, 512, 1024, 2048),
    bk_range: Sequence[int] = (128, 256, 512, 1024, 2048),
    top: int = 5,
) -> list[tuple[MatmulBlock, float]]:
    """Enumerate legal Pallas blocks for an (m, n, k) GEMM; rank by score."""
    out: list[tuple[MatmulBlock, float]] = []
    for bm, bn, bk in itertools.product(bm_range, bn_range, bk_range):
        block = MatmulBlock(bm=bm, bn=bn, bk=bk)
        if not block.legal(m, n, k, spec):
            continue
        out.append((block, _block_score(block, m, n, k, spec, dtype_bytes)))
    out.sort(key=lambda t: -t[1])
    return out[:top]


def default_block_for(m: int, n: int, k: int, spec: TpuSpec = TPU_V5E) -> MatmulBlock:
    """Best-scoring legal block, with a safe fallback for tiny problems."""
    ranked = explore_tpu_block(m, n, k, spec)
    if ranked:
        return ranked[0][0]
    from .tiling import clamp_block

    return clamp_block(m, n, k, MatmulBlock(128, 128, 128), spec)
