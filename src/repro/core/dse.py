"""Design-space exploration (paper §III.E "Scalability and Efficiency").

The paper uses trial-based exploration: sample template parameters, simulate,
keep configurations that meet resources/latency.  We make the same search
analytic and exhaustive over a quantized grid:

* :func:`explore_board` — FPGA plane: enumerate (μ, τ, 𝒯, ℭ, λ, Ω) within a
  board's DSP/BRAM/LUT/FF envelope and rank by modeled GOP/s on a target
  network.  Reproduces the paper's per-board compute-unit choices and the
  "τ ≈ 2μ" finding.

* :func:`explore_tpu_block` — TPU plane: enumerate Pallas (bm, bn, bk) blocks
  within the VMEM budget and rank by a roofline score (MXU occupancy ×
  min(1, intensity/ridge)).  This picks the compute-unit configuration the
  Pallas kernels use.

* :func:`explore_conv_spatial` — TPU plane, direct conv: enumerate the
  direct-conv kernel's (τ, tile_rows) grid — output-channel tile × spatial
  output-row tile (the paper's 𝒯 tile) — inside the VMEM working-set model
  (:func:`direct_conv_vmem`) and rank by a compute-unit utilization score.
  This is what lets oversized layers (ZynqNet-style large early-layer
  feature maps) stay on the direct route instead of spilling to im2col.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Optional, Sequence

from .fpga_model import Board, LayerSpec, TemplateInstance, evaluate_network
from .tiling import ConvTiling, FCTiling, MatmulBlock, TPU_V5E, TpuSpec, ceil_div

__all__ = [
    "DseResult",
    "ConvTileChoice",
    "conv_choice_from_doc",
    "conv_choice_to_doc",
    "explore_board",
    "explore_tpu_block",
    "explore_conv_spatial",
    "default_block_for",
    "default_conv_tile_for",
    "direct_conv_vmem",
]


@dataclasses.dataclass
class DseResult:
    instance: TemplateInstance
    gops: float
    latency_ms: float

    @property
    def mu(self) -> int:
        return self.instance.conv.mu

    @property
    def tau(self) -> int:
        return self.instance.conv.tau


def explore_board(
    board: Board,
    layers: Sequence[LayerSpec],
    name: str = "net",
    mu_range: Sequence[int] = (4, 8, 12, 16, 20, 24, 28, 32),
    tau_range: Sequence[int] = (8, 12, 16, 20, 24, 30, 36, 44, 55, 64),
    spatial_tiles: Sequence[int] = (13, 14, 26, 27, 28),
    fc_tiles: Sequence[tuple[int, int]] = ((1024, 64), (2048, 128), (4096, 256)),
    top: int = 10,
) -> list[DseResult]:
    """Exhaustive grid search over the template parameter space for a board."""
    results: list[DseResult] = []
    for mu, tau in itertools.product(mu_range, tau_range):
        if mu * tau > board.dsp:
            continue
        for t_spatial in spatial_tiles:
            conv = ConvTiling(t_r=t_spatial, t_c=t_spatial, mu=mu, tau=tau)
            for lam, omega in fc_tiles:
                fc = FCTiling(lam=lam, omega=omega, mu=mu, tau=tau)
                inst = TemplateInstance(board=board, conv=conv, fc=fc)
                if not inst.fits():
                    continue
                rep = evaluate_network(name, layers, inst)
                results.append(
                    DseResult(instance=inst, gops=rep.gops, latency_ms=rep.latency_ms)
                )
    results.sort(key=lambda r: -r.gops)
    return results[:top]


# ---------------------------------------------------------------------------
# TPU plane
# ---------------------------------------------------------------------------


def _block_score(
    block: MatmulBlock, m: int, n: int, k: int, spec: TpuSpec, dtype_bytes: int = 2
) -> float:
    """Roofline score for one grid step of the tiled matmul.

    peak-normalized throughput = MXU efficiency x min(1, AI / ridge) x
    quantization-waste factor from ceil-division of the problem dims
    (the TPU analogue of the paper's ceil(p/μ)·ceil(q/τ) waste).
    """
    ridge = spec.peak_bf16_flops / spec.hbm_bw  # FLOP/byte to be compute bound
    ai = block.arithmetic_intensity(dtype_bytes)
    waste = (
        (m / (max(1, -(-m // block.bm)) * block.bm))
        * (n / (max(1, -(-n // block.bn)) * block.bn))
        * (k / (max(1, -(-k // block.bk)) * block.bk))
    )
    return block.mxu_efficiency(spec) * min(1.0, ai / ridge) * waste


def explore_tpu_block(
    m: int,
    n: int,
    k: int,
    spec: TpuSpec = TPU_V5E,
    dtype_bytes: int = 2,
    bm_range: Sequence[int] = (128, 256, 512, 1024),
    bn_range: Sequence[int] = (128, 256, 512, 1024, 2048),
    bk_range: Sequence[int] = (128, 256, 512, 1024, 2048),
    top: int = 5,
) -> list[tuple[MatmulBlock, float]]:
    """Enumerate legal Pallas blocks for an (m, n, k) GEMM; rank by score."""
    out: list[tuple[MatmulBlock, float]] = []
    for bm, bn, bk in itertools.product(bm_range, bn_range, bk_range):
        block = MatmulBlock(bm=bm, bn=bn, bk=bk)
        if not block.legal(m, n, k, spec):
            continue
        out.append((block, _block_score(block, m, n, k, spec, dtype_bytes)))
    out.sort(key=lambda t: -t[1])
    return out[:top]


def default_block_for(m: int, n: int, k: int, spec: TpuSpec = TPU_V5E) -> MatmulBlock:
    """Best-scoring legal block, with a safe fallback for tiny problems."""
    ranked = explore_tpu_block(m, n, k, spec)
    if ranked:
        return ranked[0][0]
    from .tiling import clamp_block

    return clamp_block(m, n, k, MatmulBlock(128, 128, 128), spec)


# ---------------------------------------------------------------------------
# TPU plane: direct-conv spatial tiling (the paper's 𝒯 tile on the row axis)
# ---------------------------------------------------------------------------


def direct_conv_vmem(
    hp: int, wp: int, cin: int, kh: int, kw: int, ho: int, wo: int, tau: int,
    in_bytes: int, acc_bytes: int = 4, *, stride: int = 1, tile_rows: int = 0,
) -> int:
    """VMEM working set of one direct-conv grid step (double-buffered I/O).

    Untiled (``tile_rows`` 0 or ≥ Ho): the whole padded image slab is
    resident.  Spatially tiled: each step holds *two* adjacent
    ``stride·tile_rows``-row input blocks — the tile plus its successor,
    which supplies the ``kh - stride`` halo rows (``kernels/conv2d.py``) —
    plus the same-sized concatenated copy the kernel materializes to stitch
    them, and the accumulator/output shrink from Ho to tile_rows output
    rows.
    """
    th = tile_rows if 0 < tile_rows < ho else ho
    if th < ho:
        rows = 2 * stride * th
        # two double-buffered input blocks + the in-kernel concat buffer
        x = rows * wp * cin * in_bytes * 3
    else:
        x = hp * wp * cin * in_bytes * 2
    w = kh * kw * cin * tau * in_bytes * 2
    acc = th * wo * tau * acc_bytes
    out = th * wo * tau * in_bytes * 2
    return x + w + acc + out


@dataclasses.dataclass(frozen=True)
class ConvTileChoice:
    """One legal direct-conv compute-unit configuration (τ, spatial tile)."""

    tau: int
    tile_rows: int  # output rows per grid step (== ho when untiled)
    spatial_tiles: int  # ceil(ho / tile_rows)
    vmem_bytes: int
    score: float


def conv_choice_to_doc(choice: ConvTileChoice) -> dict:
    """JSON-serializable form of a ConvTileChoice (plan-store schema)."""
    return dataclasses.asdict(choice)


def conv_choice_from_doc(doc: dict) -> ConvTileChoice:
    """Inverse of :func:`conv_choice_to_doc`; bit-identical round-trip."""
    return ConvTileChoice(
        tau=int(doc["tau"]),
        tile_rows=int(doc["tile_rows"]),
        spatial_tiles=int(doc["spatial_tiles"]),
        vmem_bytes=int(doc["vmem_bytes"]),
        score=float(doc["score"]),
    )


def _conv_tile_score(
    tau: int, th: int, hp: int, wp: int, cin: int, kh: int, kw: int,
    ho: int, wo: int, cout: int, stride: int, spec: TpuSpec,
) -> float:
    """Compute-unit utilization of one (τ, tile_rows) configuration.

    Traffic-based: ideal HBM bytes (image + weights + output each touched
    once) over the bytes the grid actually moves — the TPU analogue of the
    paper's ceil(p/μ)·ceil(q/τ) invocation-waste terms:

    * the image is re-streamed once per τ-way (ceil(cout/τ) output-channel
      tiles), and the two-block halo scheme holds ~2× the tile's rows,
    * the τ-wide weight slab is re-fetched once per spatial tile,
    * padded output rows (tiles·th ≥ ho) and padded channels (coutp ≥ cout)
      are wasted write-back traffic,

    times the MXU row occupancy of the per-step (th·wo, cin) GEMM.  Untiled
    pays no halo or weight refetch, so it wins whenever it fits; among tiled
    configs the score trades τ-width (image refetch) against tile height
    (weight refetch).
    """
    coutp = ceil_div(cout, tau) * tau
    ways = coutp // tau
    tiles = ceil_div(ho, th)
    if th >= ho:
        x_traffic = ways * hp * wp * cin
    else:
        x_traffic = ways * tiles * 2 * stride * th * wp * cin
    w_traffic = tiles * kh * kw * cin * coutp
    out_traffic = tiles * th * wo * coutp
    ideal = hp * wp * cin + kh * kw * cin * cout + ho * wo * cout
    rows = th * wo
    m_eff = rows / (ceil_div(rows, spec.mxu_dim) * spec.mxu_dim)
    return ideal / (x_traffic + w_traffic + out_traffic) * m_eff


def explore_conv_spatial(
    hp: int,
    wp: int,
    cin: int,
    kh: int,
    kw: int,
    ho: int,
    wo: int,
    cout: int,
    stride: int,
    spec: TpuSpec = TPU_V5E,
    in_bytes: int = 4,
    top: int = 5,
) -> list[ConvTileChoice]:
    """Enumerate legal (τ, tile_rows) direct-conv configs; rank by score.

    τ ladder: min(lane, cout) halved down to 8 (same ladder the engine used
    pre-tiling).  tile_rows ladder: Ho halved down to the smallest tile whose
    input block still covers the tap window (stride·tile_rows ≥ kh, the
    two-block halo legality bound).
    """
    tau0 = min(spec.lane, cout)
    taus = []
    t = tau0
    while True:
        taus.append(t)
        if t <= 8:
            break
        t //= 2
    th_min = max(1, ceil_div(kh, stride))
    ths = []
    t = ho
    while t > th_min:
        ths.append(t)
        t = ceil_div(t, 2)
    ths.append(max(th_min, min(t, ho)))
    out: list[ConvTileChoice] = []
    for tau, th in itertools.product(taus, dict.fromkeys(ths)):
        if th < ho and stride * th < kh:
            continue  # halo block cannot cover the tap window
        vmem = direct_conv_vmem(
            hp, wp, cin, kh, kw, ho, wo, tau, in_bytes, stride=stride, tile_rows=th
        )
        if vmem > spec.vmem_bytes:
            continue
        score = _conv_tile_score(
            tau, th, hp, wp, cin, kh, kw, ho, wo, cout, stride, spec
        )
        out.append(
            ConvTileChoice(
                tau=tau,
                tile_rows=th,
                spatial_tiles=ceil_div(ho, th),
                vmem_bytes=vmem,
                score=score,
            )
        )
    out.sort(key=lambda c: (-c.score, -c.tau, -c.tile_rows))
    return out[:top]


def default_conv_tile_for(
    hp: int,
    wp: int,
    cin: int,
    kh: int,
    kw: int,
    ho: int,
    wo: int,
    cout: int,
    stride: int,
    spec: TpuSpec = TPU_V5E,
    in_bytes: int = 4,
) -> Optional[ConvTileChoice]:
    """Best-scoring legal direct-conv config, or None (→ im2col fallback)."""
    ranked = explore_conv_spatial(
        hp, wp, cin, kh, kw, ho, wo, cout, stride, spec, in_bytes
    )
    return ranked[0] if ranked else None
