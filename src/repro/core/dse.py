"""Design-space exploration (paper §III.E "Scalability and Efficiency").

The paper uses trial-based exploration: sample template parameters, simulate,
keep configurations that meet resources/latency.  We make the same search
analytic and exhaustive over a quantized grid:

* :func:`explore_board` — FPGA plane: enumerate (μ, τ, 𝒯, ℭ, λ, Ω) within a
  board's DSP/BRAM/LUT/FF envelope and rank by modeled GOP/s on a target
  network.  Reproduces the paper's per-board compute-unit choices and the
  "τ ≈ 2μ" finding.

* :func:`explore_tpu_block` — TPU plane: enumerate Pallas (bm, bn, bk) blocks
  within the VMEM budget and rank by a roofline score (MXU occupancy ×
  min(1, intensity/ridge)).  This picks the compute-unit configuration the
  Pallas kernels use.

* :func:`explore_conv_spatial` — TPU plane, direct conv: enumerate the
  direct-conv kernel's (τ, tile_rows, tile_cols, halo_mode) grid —
  output-channel tile × the paper's 𝒯/ℭ spatial tiles × input-halo regime
  (untiled / two-block / manual-DMA) — inside the VMEM working-set model
  (:func:`direct_conv_vmem`) and rank by the HBM-traffic score
  (:func:`direct_conv_hbm_traffic`).  This is what lets oversized layers
  (ZynqNet-style large early-layer feature maps) stay on the direct route
  instead of spilling to im2col.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Optional, Sequence

from .fpga_model import Board, LayerSpec, TemplateInstance, evaluate_network
from .tiling import ConvTiling, FCTiling, MatmulBlock, TPU_V5E, TpuSpec, ceil_div

__all__ = [
    "DseResult",
    "ConvTileChoice",
    "choose_precision",
    "conv_choice_from_doc",
    "conv_choice_to_doc",
    "explore_board",
    "explore_tpu_block",
    "explore_conv_spatial",
    "default_block_for",
    "default_conv_tile_for",
    "direct_conv_vmem",
    "direct_conv_hbm_traffic",
    "direct_conv_ideal_traffic",
    "direct_conv_input_traffic",
]


@dataclasses.dataclass
class DseResult:
    instance: TemplateInstance
    gops: float
    latency_ms: float

    @property
    def mu(self) -> int:
        return self.instance.conv.mu

    @property
    def tau(self) -> int:
        return self.instance.conv.tau


def explore_board(
    board: Board,
    layers: Sequence[LayerSpec],
    name: str = "net",
    mu_range: Sequence[int] = (4, 8, 12, 16, 20, 24, 28, 32),
    tau_range: Sequence[int] = (8, 12, 16, 20, 24, 30, 36, 44, 55, 64),
    spatial_tiles: Sequence[int] = (13, 14, 26, 27, 28),
    fc_tiles: Sequence[tuple[int, int]] = ((1024, 64), (2048, 128), (4096, 256)),
    top: int = 10,
) -> list[DseResult]:
    """Exhaustive grid search over the template parameter space for a board."""
    results: list[DseResult] = []
    for mu, tau in itertools.product(mu_range, tau_range):
        if mu * tau > board.dsp:
            continue
        for t_spatial in spatial_tiles:
            conv = ConvTiling(t_r=t_spatial, t_c=t_spatial, mu=mu, tau=tau)
            for lam, omega in fc_tiles:
                fc = FCTiling(lam=lam, omega=omega, mu=mu, tau=tau)
                inst = TemplateInstance(board=board, conv=conv, fc=fc)
                if not inst.fits():
                    continue
                rep = evaluate_network(name, layers, inst)
                results.append(
                    DseResult(instance=inst, gops=rep.gops, latency_ms=rep.latency_ms)
                )
    results.sort(key=lambda r: -r.gops)
    return results[:top]


# ---------------------------------------------------------------------------
# TPU plane
# ---------------------------------------------------------------------------


def _block_score(
    block: MatmulBlock, m: int, n: int, k: int, spec: TpuSpec, dtype_bytes: int = 2
) -> float:
    """Roofline score for one grid step of the tiled matmul.

    peak-normalized throughput = MXU efficiency x min(1, AI / ridge) x
    quantization-waste factor from ceil-division of the problem dims
    (the TPU analogue of the paper's ceil(p/μ)·ceil(q/τ) waste).
    """
    ridge = spec.peak_bf16_flops / spec.hbm_bw  # FLOP/byte to be compute bound
    ai = block.arithmetic_intensity(dtype_bytes)
    waste = (
        (m / (max(1, -(-m // block.bm)) * block.bm))
        * (n / (max(1, -(-n // block.bn)) * block.bn))
        * (k / (max(1, -(-k // block.bk)) * block.bk))
    )
    return block.mxu_efficiency(spec) * min(1.0, ai / ridge) * waste


def explore_tpu_block(
    m: int,
    n: int,
    k: int,
    spec: TpuSpec = TPU_V5E,
    dtype_bytes: int = 2,
    bm_range: Sequence[int] = (128, 256, 512, 1024),
    bn_range: Sequence[int] = (128, 256, 512, 1024, 2048),
    bk_range: Sequence[int] = (128, 256, 512, 1024, 2048),
    top: int = 5,
) -> list[tuple[MatmulBlock, float]]:
    """Enumerate legal Pallas blocks for an (m, n, k) GEMM; rank by score."""
    out: list[tuple[MatmulBlock, float]] = []
    for bm, bn, bk in itertools.product(bm_range, bn_range, bk_range):
        block = MatmulBlock(bm=bm, bn=bn, bk=bk)
        if not block.legal(m, n, k, spec):
            continue
        out.append((block, _block_score(block, m, n, k, spec, dtype_bytes)))
    out.sort(key=lambda t: -t[1])
    return out[:top]


def default_block_for(m: int, n: int, k: int, spec: TpuSpec = TPU_V5E) -> MatmulBlock:
    """Best-scoring legal block, with a safe fallback for tiny problems."""
    ranked = explore_tpu_block(m, n, k, spec)
    if ranked:
        return ranked[0][0]
    from .tiling import clamp_block

    return clamp_block(m, n, k, MatmulBlock(128, 128, 128), spec)


# ---------------------------------------------------------------------------
# TPU plane: direct-conv spatial tiling (the paper's 𝒯/ℭ tiles)
# ---------------------------------------------------------------------------


def _eff_tiles(ho: int, wo: int, tile_rows: int, tile_cols: int):
    """Normalize a (tile_rows, tile_cols) request to effective tile dims."""
    th = tile_rows if 0 < tile_rows < ho else ho
    tw = tile_cols if 0 < tile_cols < wo else wo
    return th, tw


def _infer_halo_mode(ho: int, wo: int, th: int, tw: int, halo_mode) -> str:
    """Default regime for legacy callers that don't pass ``halo_mode``:
    column tiling forces DMA; row-only tiling keeps the PR 2 two-block
    scheme; no tiling is the untiled whole-slab regime."""
    if halo_mode is not None:
        return halo_mode
    if tw < wo:
        return "dma"
    return "two_block" if th < ho else "none"


def direct_conv_vmem(
    hp: int, wp: int, cin: int, kh: int, kw: int, ho: int, wo: int, tau: int,
    in_bytes: int, acc_bytes: int = 4, *, stride: int = 1, tile_rows: int = 0,
    tile_cols: int = 0, halo_mode: Optional[str] = None,
) -> int:
    """VMEM working set of one direct-conv grid step (double-buffered I/O).

    Three regimes (``halo_mode``, inferred from the tile dims when omitted):

    * ``"none"`` — untiled: the whole padded image slab is resident
      (double-buffered).
    * ``"two_block"`` — row-tiled with blocked successor reads: each step
      holds *two* adjacent ``stride·tile_rows``-row full-width input blocks
      (the tile plus the successor supplying the ``kh − stride`` halo rows)
      plus the same-sized concatenated copy the kernel materializes to
      stitch them — a ~6× tile-rows residency.
    * ``"dma"`` — (𝒯, ℭ)-tiled with manual async copies: exactly the
      ``stride·tile_rows + kh − stride`` × ``stride·tile_cols + kw −
      stride`` input window a tile reads, double-buffered (×2) for the
      prefetch pipeline — roughly half the two-block residency at equal
      tile_rows, and the only regime that tiles the width.

    The accumulator/output shrink to tile_rows × tile_cols output pixels.
    """
    th, tw = _eff_tiles(ho, wo, tile_rows, tile_cols)
    mode = _infer_halo_mode(ho, wo, th, tw, halo_mode)
    if mode == "none":
        x = hp * wp * cin * in_bytes * 2
    elif mode == "two_block":
        if tw < wo:
            raise ValueError("two_block halo cannot tile columns (use 'dma')")
        rows = 2 * stride * th
        # two double-buffered input blocks + the in-kernel concat buffer
        x = rows * wp * cin * in_bytes * 3
    elif mode == "dma":
        rows_in = min(hp, stride * th + kh - stride)
        cols_in = min(wp, stride * tw + kw - stride)
        x = 2 * rows_in * cols_in * cin * in_bytes  # double-buffered window
    else:
        raise ValueError(f"unknown halo_mode {mode!r}")
    w = kh * kw * cin * tau * in_bytes * 2
    acc = th * tw * tau * acc_bytes
    out = th * tw * tau * in_bytes * 2
    return x + w + acc + out


def direct_conv_hbm_traffic(
    hp: int, wp: int, cin: int, kh: int, kw: int, ho: int, wo: int, cout: int,
    stride: int, tau: int, in_bytes: int, *, tile_rows: int = 0,
    tile_cols: int = 0, halo_mode: Optional[str] = None,
) -> int:
    """Modeled HBM bytes one forward pass of the layer actually moves.

    The cost model behind the conv DSE score (and the bench table's
    HBM-traffic column):

    * the image streams once per τ-way (ceil(cout/τ) output-channel tiles);
      the two-block regime additionally re-streams every full-width block
      ~2× (each block is also its predecessor's halo), while the DMA regime
      fetches each tile's exact window once — only the ``kh/kw − stride``
      overlap between neighbouring windows is paid twice,
    * the τ-wide weight slab is re-fetched once per spatial tile,
    * padded output tiles (tiles·th ≥ ho etc.) and padded channels
      (coutp ≥ cout) are wasted write-back traffic.
    """
    th, tw = _eff_tiles(ho, wo, tile_rows, tile_cols)
    mode = _infer_halo_mode(ho, wo, th, tw, halo_mode)
    coutp = ceil_div(cout, tau) * tau
    ways = coutp // tau
    tiles_r = ceil_div(ho, th)
    tiles_c = ceil_div(wo, tw)
    tiles = tiles_r * tiles_c
    if mode == "none":
        x_traffic = ways * hp * wp * cin
    elif mode == "two_block":
        x_traffic = ways * tiles_r * 2 * stride * th * wp * cin
    elif mode == "dma":
        rows_in = min(hp, stride * th + kh - stride)
        cols_in = min(wp, stride * tw + kw - stride)
        x_traffic = ways * tiles * rows_in * cols_in * cin
    else:
        raise ValueError(f"unknown halo_mode {mode!r}")
    w_traffic = tiles * kh * kw * cin * coutp
    out_traffic = tiles * th * tw * coutp
    return (x_traffic + w_traffic + out_traffic) * in_bytes


def direct_conv_input_traffic(
    hp: int, wp: int, cin: int, kh: int, kw: int, ho: int, wo: int, cout: int,
    stride: int, tau: int, in_bytes: int, *, tile_rows: int = 0,
    tile_cols: int = 0, halo_mode: Optional[str] = None,
) -> int:
    """The input-stream component of :func:`direct_conv_hbm_traffic` alone.

    This is the term the halo regime actually changes (weights and output
    write-back move identically under either scheme at equal tile dims), so
    it is what the bench table's ≤ 0.6× DMA-vs-two-block gate compares.
    """
    full = direct_conv_hbm_traffic(
        hp, wp, cin, kh, kw, ho, wo, cout, stride, tau, in_bytes,
        tile_rows=tile_rows, tile_cols=tile_cols, halo_mode=halo_mode,
    )
    th, tw = _eff_tiles(ho, wo, tile_rows, tile_cols)
    coutp = ceil_div(cout, tau) * tau
    tiles = ceil_div(ho, th) * ceil_div(wo, tw)
    w_out = tiles * (kh * kw * cin * coutp + th * tw * coutp) * in_bytes
    return full - w_out


def direct_conv_ideal_traffic(
    hp: int, wp: int, cin: int, kh: int, kw: int, ho: int, wo: int, cout: int,
    in_bytes: int,
) -> int:
    """Lower-bound HBM bytes: image + weights + output each touched once."""
    return (hp * wp * cin + kh * kw * cin * cout + ho * wo * cout) * in_bytes


@dataclasses.dataclass(frozen=True)
class ConvTileChoice:
    """One legal direct-conv compute-unit configuration (τ, 𝒯, ℭ, regime).

    ``tile_rows``/``tile_cols`` are output rows/columns per grid step (== the
    full extent when untiled on that axis); ``halo_mode`` names the input
    regime ("none" | "two_block" | "dma", see :func:`direct_conv_vmem`).
    The defaults on the PR 8 fields keep hand-built pre-column-tiling
    choices constructible (row-tiled two-block or untiled semantics).
    """

    tau: int
    tile_rows: int  # output rows per grid step (== ho when untiled)
    spatial_tiles: int  # ceil(ho / tile_rows)
    vmem_bytes: int
    score: float
    tile_cols: int = 0  # output cols per grid step (0/== wo: untiled axis)
    col_tiles: int = 1  # ceil(wo / tile_cols)
    halo_mode: str = ""  # "" on legacy choices: infer from the tile dims


def conv_choice_to_doc(choice: ConvTileChoice) -> dict:
    """JSON-serializable form of a ConvTileChoice (plan-store schema)."""
    return dataclasses.asdict(choice)


def conv_choice_from_doc(doc: dict) -> ConvTileChoice:
    """Inverse of :func:`conv_choice_to_doc`; bit-identical round-trip."""
    return ConvTileChoice(
        tau=int(doc["tau"]),
        tile_rows=int(doc["tile_rows"]),
        spatial_tiles=int(doc["spatial_tiles"]),
        vmem_bytes=int(doc["vmem_bytes"]),
        score=float(doc["score"]),
        tile_cols=int(doc.get("tile_cols", 0)),
        col_tiles=int(doc.get("col_tiles", 1)),
        halo_mode=str(doc.get("halo_mode", "")),
    )


def _conv_tile_score(
    tau: int, th: int, tw: int, halo_mode: str, hp: int, wp: int, cin: int,
    kh: int, kw: int, ho: int, wo: int, cout: int, stride: int, spec: TpuSpec,
    in_bytes: int,
) -> float:
    """Compute-unit utilization of one (τ, 𝒯, ℭ, regime) configuration.

    Traffic-based: ideal HBM bytes over the bytes the grid actually moves
    (:func:`direct_conv_hbm_traffic`) — the TPU analogue of the paper's
    ceil(p/μ)·ceil(q/τ) invocation-waste terms — times the MXU row occupancy
    of the per-step (th·tw, cin) GEMM.  Untiled pays no halo or weight
    refetch, so it wins whenever it fits; among tiled configs DMA beats
    two-block at equal tile dims (strictly less input re-streaming), and
    squarer (𝒯, ℭ) windows beat full-width strips of the same area because
    the two-sided halo overlap shrinks with the perimeter-to-area ratio.
    """
    traffic = direct_conv_hbm_traffic(
        hp, wp, cin, kh, kw, ho, wo, cout, stride, tau, in_bytes,
        tile_rows=th, tile_cols=tw, halo_mode=halo_mode,
    )
    ideal = direct_conv_ideal_traffic(hp, wp, cin, kh, kw, ho, wo, cout, in_bytes)
    rows = th * min(tw, wo)
    m_eff = rows / (ceil_div(rows, spec.mxu_dim) * spec.mxu_dim)
    return ideal / traffic * m_eff


def _tile_ladder(extent: int, lo: int) -> list[int]:
    """Candidate tile sizes for one spatial axis, largest first.

    The halving ladder (extent, ⌈extent/2⌉, …, lo) gives geometric coverage;
    every divisor of the extent in [lo, extent] is added so exact tilings —
    no ragged final tile, no padded write-back waste — are always
    enumerable (e.g. Ho=27 offers 9 and 3, not just 27→14→7→4).
    """
    lo = max(1, min(lo, extent))
    vals = {d for d in range(lo, extent + 1) if extent % d == 0}
    t = extent
    while t > lo:
        vals.add(t)
        t = ceil_div(t, 2)
    vals.add(lo)
    return sorted(vals, reverse=True)


def explore_conv_spatial(
    hp: int,
    wp: int,
    cin: int,
    kh: int,
    kw: int,
    ho: int,
    wo: int,
    cout: int,
    stride: int,
    spec: TpuSpec = TPU_V5E,
    in_bytes: int = 4,
    top: int = 5,
) -> list[ConvTileChoice]:
    """Enumerate legal (τ, tile_rows, tile_cols, halo_mode) configs; rank by
    the HBM-traffic score.

    τ ladder: min(lane, cout) halved down to 8 (same ladder the engine used
    pre-tiling).  Tile ladders (:func:`_tile_ladder`): halving steps plus
    every exact divisor of the extent.  Three regimes are enumerated:
    untiled whole-slab, row-tiled two-block (legality: stride·tile_rows ≥
    kh so the successor block covers the tap window), and (𝒯, ℭ)-tiled
    manual-DMA — which has no legality bound (the window always covers the
    taps) and is the only regime that tiles the width, so extreme-width
    layers stay direct instead of falling back to im2col.
    """
    tau0 = min(spec.lane, cout)
    taus = []
    t = tau0
    while True:
        taus.append(t)
        if t <= 8:
            break
        t //= 2
    th_two_min = max(1, ceil_div(kh, stride))
    configs: list[tuple[int, int, str]] = [(ho, wo, "none")]
    for th in _tile_ladder(ho, th_two_min):
        if th < ho and stride * th >= kh:
            configs.append((th, wo, "two_block"))
    for th in _tile_ladder(ho, 1):
        for tw in _tile_ladder(wo, 1):
            if th >= ho and tw >= wo:
                continue  # the untiled regime already covers the whole slab
            configs.append((th, tw, "dma"))
    out: list[ConvTileChoice] = []
    for tau, (th, tw, mode) in itertools.product(taus, configs):
        vmem = direct_conv_vmem(
            hp, wp, cin, kh, kw, ho, wo, tau, in_bytes, stride=stride,
            tile_rows=th, tile_cols=tw, halo_mode=mode,
        )
        if vmem > spec.vmem_bytes:
            continue
        score = _conv_tile_score(
            tau, th, tw, mode, hp, wp, cin, kh, kw, ho, wo, cout, stride,
            spec, in_bytes,
        )
        out.append(
            ConvTileChoice(
                tau=tau,
                tile_rows=th,
                spatial_tiles=ceil_div(ho, th),
                vmem_bytes=vmem,
                score=score,
                tile_cols=tw,
                col_tiles=ceil_div(wo, tw),
                halo_mode=mode,
            )
        )
    # deterministic rank: score, then wider τ, then taller/wider tiles, then
    # regime name — ties between symmetric (𝒯, ℭ) transposes resolve to the
    # taller tile
    out.sort(
        key=lambda c: (-c.score, -c.tau, -c.tile_rows, -c.tile_cols, c.halo_mode)
    )
    return out[:top]


def default_conv_tile_for(
    hp: int,
    wp: int,
    cin: int,
    kh: int,
    kw: int,
    ho: int,
    wo: int,
    cout: int,
    stride: int,
    spec: TpuSpec = TPU_V5E,
    in_bytes: int = 4,
) -> Optional[ConvTileChoice]:
    """Best-scoring legal direct-conv config, or None (→ im2col fallback)."""
    ranked = explore_conv_spatial(
        hp, wp, cin, kh, kw, ho, wo, cout, stride, spec, in_bytes
    )
    return ranked[0] if ranked else None


# ---------------------------------------------------------------------------
# per-layer precision assignment (drift-aware DSE, DESIGN.md §11)
# ---------------------------------------------------------------------------


def choose_precision(
    drift: dict,
    budget: float,
    base_fmt,
    low_fmt,
) -> dict:
    """Assign each layer the cheapest activation grid meeting ``budget``.

    ``drift`` maps layer name -> measured *solo-flip* argmax agreement (the
    network's end-to-end agreement vs the all-``base_fmt`` reference when
    only that layer drops to ``low_fmt``; from the extended drift sweep in
    ``benchmarks/precision_drift.py``).  A layer gets ``low_fmt`` (int8 —
    half the activation/KV bytes) iff its solo-flip agreement is >= the
    network accuracy budget; everything else keeps ``base_fmt``.  Pure and
    deterministic: the engine pins the result in the PlanRegistry with
    ``source: measured`` provenance and the per-layer drift attached, so a
    warm restart replays the exact assignment with zero sweeps.
    """
    if not 0.0 <= budget <= 1.0:
        raise ValueError(f"precision budget must be in [0, 1], got {budget}")
    return {
        layer: low_fmt if agreement >= budget else base_fmt
        for layer, agreement in drift.items()
    }
