"""Analytic FPGA resource/latency model — the paper-faithful evaluation plane.

The paper's results (Tables 1 & 2) are throughput (GOP/s) and resource
utilization for the template instantiated on three ZYNQ boards.  Without the
physical boards we reproduce the *methodology*: a cycle-level analytic model
of the tiled, ping-pong-buffered schedule plus a resource model for the
compute unit and its buffers, driven by the same (μ, τ, 𝒯, ℭ, λ, Ω) template
parameters.  ``benchmarks/table1.py`` and ``benchmarks/table2.py`` evaluate
this model for the paper's compute-unit configurations and compare against
the paper's reported numbers.

Model assumptions (documented, calibrated to the paper where stated):
  * one DSP slice per 16-bit MAC  => DSP = μ·τ
  * BRAM18 = 1024 x 18 bit; 16-bit data => 1024 entries per BRAM18
  * buffers ping-pong (x2) and are partitioned for parallel access:
    input by μ, weight by τ (paper §III.C), output by τ
  * two 128-bit M-AXI ports (16 B/cycle each): one shared by IFM/OFM,
    one dedicated to weights (paper §III.C)
  * per-tile latency = max(compute cycles, transfer cycles)  (ping-pong,
    paper §III.C "simultaneous data transfer")
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from .tiling import ConvTiling, FCTiling, ceil_div

__all__ = [
    "Board",
    "ULTRA96",
    "ZCU104",
    "ZCU102",
    "BOARDS",
    "LayerSpec",
    "conv_layer",
    "fc_layer",
    "TemplateInstance",
    "LayerReport",
    "NetworkReport",
    "evaluate_network",
]

BYTES_PER_ELEM = 2  # 16-bit fixed point (Q2.14)
AXI_BYTES_PER_CYCLE = 16  # 128-bit M-AXI burst
AXI_EFFICIENCY = 0.75  # achieved burst efficiency (arbitration + realign)
PIPELINE_FILL = 64  # systolic fill + FSM handshake cycles per invocation
MAX_K = 5  # largest kernel the synthesized buffers support directly;
# K > MAX_K (AlexNet conv1) or p < mu layers use input-feature unrolling
# ("im2col mode"): the K*K taps are folded into the input-channel dimension,
# which is the paper's own conv->vector unification applied one level deeper.


@dataclasses.dataclass(frozen=True)
class Board:
    """ZYNQ SoC-FPGA resource envelope (PL side)."""

    name: str
    dsp: int
    bram18: int
    lut: int
    ff: int
    freq_mhz: float  # achieved template frequency from the paper

    @property
    def freq_hz(self) -> float:
        return self.freq_mhz * 1e6


# Resource counts from the Zynq UltraScale+ datasheets (ZU3EG / ZU7EV / ZU9EG);
# frequencies are the paper's achieved values (Table 1).
ULTRA96 = Board("Ultra96", dsp=360, bram18=432, lut=70560, ff=141120, freq_mhz=169.0)
ZCU104 = Board("ZCU104", dsp=1728, bram18=624, lut=230400, ff=460800, freq_mhz=198.0)
ZCU102 = Board("ZCU102", dsp=2520, bram18=1824, lut=274080, ff=548160, freq_mhz=167.0)
BOARDS = {b.name: b for b in (ULTRA96, ZCU104, ZCU102)}


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One GEMM-bearing layer, as the template sees it (paper eq. 1/3)."""

    name: str
    kind: str  # "conv" | "fc"
    r: int = 1  # output rows R
    c: int = 1  # output cols C
    p: int = 1  # input channels / neurons
    q: int = 1  # output channels / neurons
    k: int = 1  # kernel size K
    stride: int = 1

    @property
    def macs(self) -> int:
        if self.kind == "conv":
            return self.r * self.c * self.p * self.q * self.k * self.k
        return self.p * self.q

    @property
    def ops(self) -> int:
        """Paper eq. (2)/(4): 2·MACs."""
        return 2 * self.macs


def conv_layer(name, r, c, p, q, k, stride=1) -> LayerSpec:
    return LayerSpec(name, "conv", r=r, c=c, p=p, q=q, k=k, stride=stride)


def fc_layer(name, p, q) -> LayerSpec:
    return LayerSpec(name, "fc", p=p, q=q)


@dataclasses.dataclass(frozen=True)
class TemplateInstance:
    """A fully-instantiated template: compute unit + tile factors."""

    board: Board
    conv: ConvTiling
    fc: FCTiling

    # -- resource model ----------------------------------------------------

    @property
    def dsp(self) -> int:
        return self.conv.mu * self.conv.tau

    def _brams_for(self, elems_per_bank: int, banks: int) -> int:
        depth = 1024  # 18-bit wide BRAM18, 16-bit data
        return banks * ceil_div(max(elems_per_bank, 1), depth) * 2  # x2 ping-pong

    @property
    def bram18(self) -> int:
        cv, fc = self.conv, self.fc
        k = MAX_K  # buffers synthesized for the largest directly-supported K
        total = 0
        # conv input buffer: partitioned by μ
        total += self._brams_for(cv.input_tile_elems(k) // cv.mu, cv.mu)
        # conv weight buffer: partitioned by τ (paper §III.C)
        total += self._brams_for(cv.weight_tile_elems(k) // cv.tau, cv.tau)
        # conv output buffer: partitioned by τ
        total += self._brams_for(cv.output_tile_elems() // cv.tau, cv.tau)
        # dedicated FC buffers (paper: "dedicated buffers for both types")
        total += self._brams_for(fc.input_tile_elems() // cv.mu, cv.mu)
        total += self._brams_for(fc.weight_tile_elems() // cv.tau, cv.tau)
        total += self._brams_for(fc.output_tile_elems() // cv.tau, cv.tau)
        return total

    @property
    def lut(self) -> int:
        # control FSM + AXI + per-MAC glue; linear fit vs Table 1.
        return int(9000 + 11.5 * self.dsp)

    @property
    def ff(self) -> int:
        return int(12000 + 40 * self.dsp)

    def fits(self) -> bool:
        b = self.board
        return (
            self.dsp <= b.dsp
            and self.bram18 <= b.bram18
            and self.lut <= b.lut
            and self.ff <= b.ff
        )

    # -- latency model (ping-pong: max(compute, transfer) per tile) --------

    def layer_cycles(self, layer: LayerSpec, batch: int = 1) -> tuple[int, int, int]:
        """Returns (total_cycles, compute_cycles, transfer_cycles) for ``batch``
        images through one layer.

        Ping-pong model (paper §III.C): per-invocation latency =
        max(compute, transfer) + pipeline fill.  Output partial sums
        accumulate in BRAM across input-channel tiles, so OFM traffic is
        charged once per full p-accumulation, not per μ-tile.  Weights stay
        resident across the batch (the batch loop is innermost of the weight
        loop), so weight traffic amortizes by 1/batch per image.
        """
        bw = AXI_BYTES_PER_CYCLE * AXI_EFFICIENCY
        if layer.kind == "conv":
            t = self.conv
            p, q, k = layer.p, layer.q, layer.k
            raw_k, raw_p = k, p
            if k > MAX_K or p < t.mu:
                # input-feature unrolling: fold the K*K taps into channels.
                # The raw input tile is read once and windowed on-chip, so
                # IFM traffic is charged from the *raw* tile, not the
                # im2col-expanded patches.
                p, k = p * k * k, 1
            inv = t.num_invocations(layer.r, layer.c, p, q)
            comp = t.compute_cycles_per_invocation(k, layer.r, layer.c)
            p_tiles = ceil_div(p, t.mu)
            tr, tc = t.eff_spatial(layer.r, layer.c)
            raw_cin = min(raw_p, t.mu) if raw_k == k else raw_p
            in_elems = (layer.stride * tr + raw_k - layer.stride) * (
                layer.stride * tc + raw_k - layer.stride
            ) * raw_cin
            in_bytes = in_elems * BYTES_PER_ELEM / p_tiles
            w_bytes = t.mu * t.tau * k * k * BYTES_PER_ELEM / batch
            out_bytes = tr * tc * t.tau * BYTES_PER_ELEM / p_tiles
        else:
            t = self.fc
            inv = t.num_invocations(layer.p, layer.q)
            comp = t.compute_cycles_per_invocation() * batch
            p_tiles = ceil_div(layer.p, t.lam)
            in_bytes = t.input_tile_elems() * BYTES_PER_ELEM * batch
            w_bytes = t.weight_tile_elems() * BYTES_PER_ELEM
            out_bytes = t.output_tile_elems() * BYTES_PER_ELEM * batch / p_tiles
        # port 0: IFM read + OFM write; port 1: weights (paper §III.C)
        xfer = max(
            ceil_div(int(in_bytes + out_bytes), int(bw)),
            ceil_div(int(w_bytes), int(bw)),
        )
        per_tile = max(comp, xfer) + PIPELINE_FILL
        scale = batch if layer.kind == "conv" else 1
        return scale * inv * per_tile, scale * inv * comp, scale * inv * xfer

    def network_latency_s(self, layers: Sequence[LayerSpec], batch: int = 1) -> float:
        cycles = sum(self.layer_cycles(l, batch)[0] for l in layers)
        return cycles / self.board.freq_hz

    @property
    def peak_gops(self) -> float:
        return 2 * self.dsp * self.board.freq_hz / 1e9


@dataclasses.dataclass
class LayerReport:
    layer: LayerSpec
    cycles: int
    compute_cycles: int
    transfer_cycles: int
    latency_ms: float
    gops: float
    bound: str


@dataclasses.dataclass
class NetworkReport:
    name: str
    instance: TemplateInstance
    layers: list[LayerReport]
    total_ops: int
    conv_ops: int
    latency_ms: float
    conv_latency_ms: float
    gops: float
    conv_gops: float

    def summary(self) -> str:
        t = self.instance
        return (
            f"{self.name} on {t.board.name} (CU {t.conv.mu}x{t.conv.tau} @ "
            f"{t.board.freq_mhz:.0f} MHz): {self.gops:.1f} GOP/s all-layers, "
            f"{self.conv_gops:.1f} GOP/s conv-only, latency {self.latency_ms:.3f} ms, "
            f"DSP {t.dsp}/{t.board.dsp}, BRAM {t.bram18}/{t.board.bram18}"
        )


def evaluate_network(
    name: str,
    layers: Sequence[LayerSpec],
    instance: TemplateInstance,
    batch: int = 1,
) -> NetworkReport:
    reports = []
    freq = instance.board.freq_hz
    for layer in layers:
        cyc, comp, xfer = instance.layer_cycles(layer, batch)
        lat = cyc / freq
        reports.append(
            LayerReport(
                layer=layer,
                cycles=cyc,
                compute_cycles=comp,
                transfer_cycles=xfer,
                latency_ms=lat * 1e3,
                gops=batch * layer.ops / lat / 1e9,
                bound="compute" if comp >= xfer else "memory",
            )
        )
    total_ops = sum(l.layer.ops for l in reports) * batch
    conv = [l for l in reports if l.layer.kind == "conv"]
    conv_ops = sum(l.layer.ops for l in conv) * batch
    lat_s = sum(l.cycles for l in reports) / freq
    conv_lat_s = sum(l.cycles for l in conv) / freq if conv else 0.0
    return NetworkReport(
        name=name,
        instance=instance,
        layers=reports,
        total_ops=total_ops,
        conv_ops=conv_ops,
        latency_ms=lat_s * 1e3,
        conv_latency_ms=conv_lat_s * 1e3,
        gops=total_ops / lat_s / 1e9,
        conv_gops=(conv_ops / conv_lat_s / 1e9) if conv else 0.0,
    )


# ---------------------------------------------------------------------------
# Reference network layer tables (paper §III.A case studies)
# ---------------------------------------------------------------------------


def alexnet_layers() -> list[LayerSpec]:
    """AlexNet (single-tower, as deployed from the PyTorch model zoo)."""
    return [
        conv_layer("conv1", 55, 55, 3, 64, 11, stride=4),
        conv_layer("conv2", 27, 27, 64, 192, 5),
        conv_layer("conv3", 13, 13, 192, 384, 3),
        conv_layer("conv4", 13, 13, 384, 256, 3),
        conv_layer("conv5", 13, 13, 256, 256, 3),
        fc_layer("fc6", 9216, 4096),
        fc_layer("fc7", 4096, 4096),
        fc_layer("fc8", 4096, 1000),
    ]


def vgg16_layers() -> list[LayerSpec]:
    cfg = [
        (224, 3, 64), (224, 64, 64),
        (112, 64, 128), (112, 128, 128),
        (56, 128, 256), (56, 256, 256), (56, 256, 256),
        (28, 256, 512), (28, 512, 512), (28, 512, 512),
        (14, 512, 512), (14, 512, 512), (14, 512, 512),
    ]
    layers = [
        conv_layer(f"conv{i+1}", r, r, p, q, 3) for i, (r, p, q) in enumerate(cfg)
    ]
    layers += [
        fc_layer("fc14", 25088, 4096),
        fc_layer("fc15", 4096, 4096),
        fc_layer("fc16", 4096, 1000),
    ]
    return layers


def lenet_layers() -> list[LayerSpec]:
    return [
        conv_layer("conv1", 28, 28, 1, 6, 5),
        conv_layer("conv2", 10, 10, 6, 16, 5),
        fc_layer("fc3", 400, 120),
        fc_layer("fc4", 120, 84),
        fc_layer("fc5", 84, 10),
    ]


NETWORKS = {
    "alexnet": alexnet_layers,
    "vgg16": vgg16_layers,
    "lenet": lenet_layers,
}
