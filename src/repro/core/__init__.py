"""Core of the reproduction: the paper's template-based accelerator design.

- template.py      the unified compute unit (conv/FC/attention/MoE -> one GEMM op)
- engine.py        execution-plan engine (plan cache, kernel routing, epilogues)
- tiling.py        loop-tiling transformation (FPGA tiles + TPU BlockSpec tiles)
- dse.py           design-space exploration over template parameters
- fpga_model.py    analytic board model reproducing the paper's evaluation
- quantization.py  16-bit fixed-point Q2.14 numerics
- roofline.py      compiled-HLO roofline analysis for the TPU adaptation
"""
from .quantization import Q2_14, QFormat, dequantize, fake_quant_fmt, qmatmul_real, qmatmul_ref, quantize
from .template import Template, TemplateConfig, default_template
from .engine import (
    ConvPlan,
    Engine,
    GemmPlan,
    PlanCache,
    PlanRegistry,
    PlanStoreError,
    load_plan_store,
    plan_cache_for,
    plan_store_stats,
    reset_plan_caches,
    save_plan_store,
    warm_start_plan_store,
)
from .tiling import ConvTiling, FCTiling, MatmulBlock, TPU_V5E, TpuSpec
from .roofline import RooflineReport, parse_collective_bytes, roofline_from_compiled

__all__ = [
    "ConvPlan",
    "Engine",
    "GemmPlan",
    "PlanCache",
    "PlanRegistry",
    "PlanStoreError",
    "load_plan_store",
    "plan_cache_for",
    "plan_store_stats",
    "reset_plan_caches",
    "save_plan_store",
    "warm_start_plan_store",
    "Q2_14",
    "QFormat",
    "quantize",
    "dequantize",
    "fake_quant_fmt",
    "qmatmul_ref",
    "qmatmul_real",
    "Template",
    "TemplateConfig",
    "default_template",
    "ConvTiling",
    "FCTiling",
    "MatmulBlock",
    "TpuSpec",
    "TPU_V5E",
    "RooflineReport",
    "parse_collective_bytes",
    "roofline_from_compiled",
]
