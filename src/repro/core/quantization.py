"""Fixed-point Q-format quantization (paper §III.E: 16-bit, Q2.14).

The paper quantizes weights and activations to 16-bit fixed point with 2
integer bits and 14 fractional bits ("2.14 format"), i.e. values in
[-2, 2 - 2^-14] with resolution 2^-14.  This module provides:

  * :class:`QFormat` — a general Qm.n fixed-point format descriptor.
  * ``quantize`` / ``dequantize`` — float <-> int16 conversion with
    round-to-nearest and saturation.
  * ``fake_quant`` — straight-through-estimator quantization for training-time
    simulation of the deployed numerics.
  * ``qmatmul_ref`` — the *semantic definition* of the fixed-point matmul the
    Pallas kernel implements: int16 x int16 products accumulated in int32
    (TPU-native accumulator; the FPGA DSP48 cascade uses 48 bits — see
    DESIGN.md §2 for the documented difference), followed by a saturating
    right-shift write-back to Q2.14.
  * :class:`QTensor` — a pytree of int16 raw values + their :class:`QFormat`,
    the unit of *fixed-point residency*: grid-resident engine ops consume and
    produce QTensors so activations stay on the Q grid between consecutive
    layers instead of round-tripping through float per op (DESIGN.md §8).
  * :class:`NumericsPolicy` — names the numerics a whole forward pass runs
    under ("float" | "q16") plus the activation grid format.
  * ``calibrate_format`` — per-tensor max-abs Qm.n selection (the "small
    calibration pass"): the smallest integer-bit budget whose range covers
    the observed magnitude gets the most fractional resolution.

All functions are jit-safe and differentiable where meaningful.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "QFormat",
    "Q2_14",
    "Q1_7",
    "Q2_6",
    "QTensor",
    "NumericsPolicy",
    "FLOAT_POLICY",
    "Q16_POLICY",
    "calibrate_format",
    "int8_rung",
    "quantize",
    "quantize_qtensor",
    "dequantize",
    "fake_quant",
    "qmatmul_ref",
    "qtensor_matmul_ref",
    "requantize_i32",
    "requantize_i32_to_i16",
    "shift_saturate_i32",
]


@dataclasses.dataclass(frozen=True)
class QFormat:
    """Signed fixed point, paper convention: ``int_bits`` *includes* the sign.

    Q2.14 = 2 integer bits (one of which is the sign) + 14 fractional bits
    = 16 bits total, representable range [-2, 2 - 2^-14] ("two bits integer
    and fourteen bits fractional", paper §II/§III.E).  ``total_bits`` names
    the storage width of the precision ladder rung this format lives on —
    int16 (the paper's grid) or int8 (Q1.7 / Q2.6, DESIGN.md §11) — and
    int_bits + frac_bits must fit it.  Sub-width formats (e.g. Q2.6 in an
    int16 rung) are legal: the raw range just doesn't fill the container.
    """

    int_bits: int
    frac_bits: int
    total_bits: int = 16

    def __post_init__(self):
        if self.total_bits not in (8, 16):
            raise ValueError(
                f"unsupported storage width {self.total_bits} (want 8 or 16)"
            )
        if self.int_bits + self.frac_bits > self.total_bits:
            raise ValueError(
                f"Qm.n with m+n > {self.total_bits} does not fit "
                f"int{self.total_bits} storage"
            )
        if self.int_bits < 1:
            raise ValueError("need at least the sign bit")

    @property
    def storage_dtype(self):
        """The integer dtype raw values of this format are stored as."""
        return jnp.int8 if self.total_bits == 8 else jnp.int16

    @property
    def scale(self) -> float:
        """Multiplier from real value to raw integer."""
        return float(1 << self.frac_bits)

    @property
    def max_val(self) -> float:
        """Largest representable real value."""
        return 2.0 ** (self.int_bits - 1) - 2.0 ** (-self.frac_bits)

    @property
    def min_val(self) -> float:
        return -(2.0 ** (self.int_bits - 1))

    @property
    def raw_max(self) -> int:
        return (1 << (self.int_bits - 1 + self.frac_bits)) - 1

    @property
    def raw_min(self) -> int:
        return -(1 << (self.int_bits - 1 + self.frac_bits))

    @property
    def resolution(self) -> float:
        return 2.0 ** (-self.frac_bits)

    @property
    def name(self) -> str:
        return f"Q{self.int_bits}.{self.frac_bits}"


#: The paper's format: 2 integer bits, 14 fractional bits.
Q2_14 = QFormat(int_bits=2, frac_bits=14)
#: int8 rungs of the precision ladder (DESIGN.md §11): Q1.7 covers [-1, 1)
#: at 2^-7 resolution (QAT-clamped activations), Q2.6 covers the paper's
#: [-2, 2) range at 2^-6.
Q1_7 = QFormat(int_bits=1, frac_bits=7, total_bits=8)
Q2_6 = QFormat(int_bits=2, frac_bits=6, total_bits=8)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    """Raw fixed-point values (int16 or int8 per ``fmt.storage_dtype``) + the
    :class:`QFormat` they live on.

    A *pytree*: the raw array is the traced child, the format is static aux
    data — so QTensors flow through ``jax.jit`` / ``lax.scan`` unchanged and
    a stacked parameter leaf keeps one format for every scanned slice.
    Grid-resident engine ops (``Engine.matmul``/``conv2d`` with QTensor
    operands) consume and produce QTensors without touching float; crossing
    back to float is an explicit, counted ``Engine.dequant``.
    """

    raw: jax.Array
    fmt: QFormat = Q2_14

    def tree_flatten(self):
        return (self.raw,), self.fmt

    @classmethod
    def tree_unflatten(cls, fmt, children):
        return cls(children[0], fmt)

    @property
    def shape(self):
        return self.raw.shape

    @property
    def ndim(self) -> int:
        return self.raw.ndim

    @property
    def dtype(self):
        return self.raw.dtype

    def reshape(self, *shape) -> "QTensor":
        return QTensor(self.raw.reshape(*shape), self.fmt)

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        return dequantize(self.raw, self.fmt, dtype)


@dataclasses.dataclass(frozen=True)
class NumericsPolicy:
    """The numerics one forward pass runs under (DESIGN.md §8, §11).

    ``name``: "float" (every op in the input dtype), "q16" (activations
    resident on the ``fmt`` grid between compute-unit ops; float only at the
    designated islands — softmax, norms, RoPE, non-ReLU activations — and the
    final logits read-out), "q8" (same residency on an int8 grid), or
    "mixed" (per-layer grids named by ``layer_fmts``, chosen by the
    drift-aware precision DSE).  ``per_tensor_weights`` selects max-abs
    calibrated Qm.n per weight tensor instead of forcing every weight onto
    ``fmt``.  ``layer_fmts`` is a sorted tuple of (layer_name, QFormat)
    pairs — layers not named fall back to ``fmt`` — kept as a tuple so the
    policy stays frozen + hashable: compiled-step memos and qparam caches
    key on it.
    """

    name: str = "float"  # "float" | "q16" | "q8" | "mixed"
    fmt: QFormat = Q2_14
    per_tensor_weights: bool = True
    layer_fmts: tuple = ()  # ((layer_name, QFormat), ...)

    def __post_init__(self):
        if self.name not in ("float", "q16", "q8", "mixed"):
            raise ValueError(f"unknown numerics policy {self.name!r}")

    @property
    def quantized(self) -> bool:
        return self.name != "float"

    def fmt_for(self, layer: str) -> QFormat:
        """The activation grid of one named layer (``fmt`` if unnamed)."""
        for name, fmt in self.layer_fmts:
            if name == layer:
                return fmt
        return self.fmt


FLOAT_POLICY = NumericsPolicy("float")
Q16_POLICY = NumericsPolicy("q16")


def calibrate_format(x, *, total_bits: int = 16,
                     max_frac: int | None = None) -> QFormat:
    """Max-abs per-tensor Qm.n selection (host-side, once per tensor).

    Picks the smallest integer-bit count whose representable range covers
    ``max|x|`` — every remaining bit goes to fractional resolution,
    optionally capped at ``max_frac`` (accumulator-headroom rule, see
    ``Engine.quantize_weight``).  Runs a host sync (``float(...)``), so call
    it from parameter-preparation code, never inside a jitted step.
    """
    maxabs = float(jnp.max(jnp.abs(jnp.asarray(x, jnp.float32)))) if jnp.size(x) else 0.0
    for int_bits in range(1, total_bits + 1):
        frac = total_bits - int_bits
        if max_frac is not None:
            frac = max(0, min(frac, max_frac))
        fmt = QFormat(int_bits, frac, total_bits)
        if maxabs <= fmt.max_val:
            return fmt
    return QFormat(total_bits, 0, total_bits)  # saturating fallback


def int8_rung(fmt: QFormat) -> QFormat | None:
    """The int8 rung covering the same real range as an int16 grid.

    Q2.14 -> Q2.6, Q1.15 -> Q1.7 (the precision ladder, DESIGN.md §11): keep
    the integer bits (range), drop fractional resolution to fit 8-bit
    storage.  None when the range itself needs more than 7 + sign bits —
    such a layer has no int8 rung and must stay int16.
    """
    if fmt.int_bits >= 8:
        return None
    return QFormat(fmt.int_bits, 8 - fmt.int_bits, 8)


def quantize_qtensor(x: jax.Array, fmt: QFormat | None = None) -> QTensor:
    """Quantize to a :class:`QTensor`; ``fmt=None`` calibrates per-tensor."""
    fmt = fmt or calibrate_format(x)
    return QTensor(quantize(x, fmt), fmt)


def quantize(x: jax.Array, fmt: QFormat = Q2_14) -> jax.Array:
    """Real -> raw fixed point (``fmt.storage_dtype``), round-to-nearest-even,
    saturating."""
    raw = jnp.round(x.astype(jnp.float32) * fmt.scale)
    raw = jnp.clip(raw, fmt.raw_min, fmt.raw_max)
    return raw.astype(fmt.storage_dtype)


def dequantize(q: jax.Array, fmt: QFormat = Q2_14, dtype=jnp.float32) -> jax.Array:
    """Raw fixed point -> real."""
    return (q.astype(jnp.float32) * (1.0 / fmt.scale)).astype(dtype)


@jax.custom_vjp
def fake_quant(x: jax.Array, scale: float, lo: float, hi: float) -> jax.Array:
    q = jnp.clip(jnp.round(x * scale) / scale, lo, hi)
    return q.astype(x.dtype)


def _fq_fwd(x, scale, lo, hi):
    return fake_quant(x, scale, lo, hi), (x, lo, hi)


def _fq_bwd(res, g):
    # Straight-through estimator, gated outside the representable range.
    x, lo, hi = res
    mask = ((x >= lo) & (x <= hi)).astype(g.dtype)
    return (g * mask, None, None, None)


fake_quant.defvjp(_fq_fwd, _fq_bwd)


def fake_quant_fmt(x: jax.Array, fmt: QFormat = Q2_14) -> jax.Array:
    """STE fake-quantization to ``fmt`` (for quantization-aware training)."""
    return fake_quant(x, fmt.scale, fmt.min_val, fmt.max_val)


def shift_saturate_i32(acc: jax.Array, shift: int, raw_min: int, raw_max: int,
                       out_dtype=jnp.int16) -> jax.Array:
    """The one write-back ladder: round-half-up arithmetic shift (exact
    up-scale for ``shift <= 0``) + saturation into a raw integer range,
    stored as ``out_dtype`` (int16 or int8 per the output grid's rung).

    Pure jnp on int32 values with static ``shift``, so the Pallas q16/q8
    kernels call this exact function inside their epilogues — the
    bit-identical contract between :func:`requantize_i32` and the kernels is
    structural, not copy-pasted.
    """
    if shift > 0:
        shifted = (acc + jnp.int32(1 << (shift - 1))) >> shift
    elif shift == 0:
        shifted = acc
    else:
        shifted = acc << (-shift)
    return jnp.clip(shifted, raw_min, raw_max).astype(out_dtype)


def requantize_i32(acc: jax.Array, shift: int, fmt: QFormat = Q2_14) -> jax.Array:
    """Saturating write-back of an int32 accumulator to Qm.n raw storage.

    ``shift`` is the scale gap between the accumulator and the output grid:
    for an x(Qa.fa) x w(Qb.fb) product written back to Qm.n it is
    ``fa + fb - n``.  Positive shifts round-to-nearest before the arithmetic
    right shift; ``shift <= 0`` up-scales (exact).  Saturates into the raw
    range of ``fmt`` (int16 or int8) — this models the FPGA accumulator
    write-back stage, and the Pallas kernels' fused epilogue runs the same
    :func:`shift_saturate_i32`.  The mixed-boundary epilogue is this exact
    ladder with an int8-rung output format: an int8 layer feeds an int16
    layer (or vice versa) with zero float round-trips (DESIGN.md §11).
    """
    return shift_saturate_i32(acc, shift, fmt.raw_min, fmt.raw_max,
                              fmt.storage_dtype)


def requantize_i32_to_i16(acc: jax.Array, fmt: QFormat = Q2_14) -> jax.Array:
    """Same-format write-back: the accumulator holds values at scale
    2^(2*frac_bits) (product of two Qm.n numbers), so the shift is one
    frac_bits.  Kept as the single-format entry point the q16 kernels and
    ``qmatmul_ref`` share."""
    return requantize_i32(acc, fmt.frac_bits, fmt)


@partial(jax.jit, static_argnames=("fmt",))
def qmatmul_ref(xq: jax.Array, wq: jax.Array, fmt: QFormat = Q2_14) -> jax.Array:
    """Semantic oracle for the fixed-point matmul kernel.

    xq: (m, k) int16 raw, wq: (k, n) int16 raw  ->  (m, n) int16 raw.
    int32 accumulation (wraparound, TPU-native), saturating Q write-back.
    """
    acc = jnp.dot(
        xq.astype(jnp.int32), wq.astype(jnp.int32), preferred_element_type=jnp.int32
    )
    return requantize_i32_to_i16(acc, fmt)


def qtensor_matmul_ref(
    x: QTensor, w: QTensor, out_fmt: QFormat = Q2_14,
    bias: QTensor | None = None, relu: bool = False,
) -> QTensor:
    """Mixed-format oracle for the grid-resident GEMM (DESIGN.md §8).

    x: (m, k) Qa.fa, w: (k, n) Qb.fb -> (m, n) on ``out_fmt``; the int32
    accumulator sits at scale 2^(fa+fb), bias raw (Qc.fc) is aligned onto
    the accumulator by ``fa + fb - fc`` before the epilogue.  This is what
    ``matmul_q16_pallas`` computes when given explicit shifts.
    """
    acc = jnp.dot(
        x.raw.astype(jnp.int32), w.raw.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    if bias is not None:
        bshift = x.fmt.frac_bits + w.fmt.frac_bits - bias.fmt.frac_bits
        if bshift < 0:
            raise ValueError(
                f"bias format {bias.fmt.name} finer than the accumulator grid"
            )
        acc = acc + (bias.raw.astype(jnp.int32) << bshift)
    if relu:
        acc = jnp.maximum(acc, 0)
    shift = x.fmt.frac_bits + w.fmt.frac_bits - out_fmt.frac_bits
    return QTensor(requantize_i32(acc, shift, out_fmt), out_fmt)


def qmatmul_real(x: jax.Array, w: jax.Array, fmt: QFormat = Q2_14) -> jax.Array:
    """Quantize real inputs, run the fixed-point matmul, dequantize.

    This is the end-to-end numerics an FPGA deployment of the paper sees for
    one GEMM: float reference -> Q2.14 -> dot -> Q2.14 -> float.
    """
    return dequantize(qmatmul_ref(quantize(x, fmt), quantize(w, fmt), fmt), fmt)
