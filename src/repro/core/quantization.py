"""Fixed-point Q-format quantization (paper §III.E: 16-bit, Q2.14).

The paper quantizes weights and activations to 16-bit fixed point with 2
integer bits and 14 fractional bits ("2.14 format"), i.e. values in
[-2, 2 - 2^-14] with resolution 2^-14.  This module provides:

  * :class:`QFormat` — a general Qm.n fixed-point format descriptor.
  * ``quantize`` / ``dequantize`` — float <-> int16 conversion with
    round-to-nearest and saturation.
  * ``fake_quant`` — straight-through-estimator quantization for training-time
    simulation of the deployed numerics.
  * ``qmatmul_ref`` — the *semantic definition* of the fixed-point matmul the
    Pallas kernel implements: int16 x int16 products accumulated in int32
    (TPU-native accumulator; the FPGA DSP48 cascade uses 48 bits — see
    DESIGN.md §2 for the documented difference), followed by a saturating
    right-shift write-back to Q2.14.

All functions are jit-safe and differentiable where meaningful.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "QFormat",
    "Q2_14",
    "quantize",
    "dequantize",
    "fake_quant",
    "qmatmul_ref",
    "requantize_i32_to_i16",
]


@dataclasses.dataclass(frozen=True)
class QFormat:
    """Signed fixed point, paper convention: ``int_bits`` *includes* the sign.

    Q2.14 = 2 integer bits (one of which is the sign) + 14 fractional bits
    = 16 bits total, representable range [-2, 2 - 2^-14] ("two bits integer
    and fourteen bits fractional", paper §II/§III.E).  Storage is int16, so
    int_bits + frac_bits must be <= 16.
    """

    int_bits: int
    frac_bits: int

    def __post_init__(self):
        if self.int_bits + self.frac_bits > 16:
            raise ValueError("Qm.n with m+n > 16 does not fit int16 storage")
        if self.int_bits < 1:
            raise ValueError("need at least the sign bit")

    @property
    def scale(self) -> float:
        """Multiplier from real value to raw integer."""
        return float(1 << self.frac_bits)

    @property
    def max_val(self) -> float:
        """Largest representable real value."""
        return 2.0 ** (self.int_bits - 1) - 2.0 ** (-self.frac_bits)

    @property
    def min_val(self) -> float:
        return -(2.0 ** (self.int_bits - 1))

    @property
    def raw_max(self) -> int:
        return (1 << (self.int_bits - 1 + self.frac_bits)) - 1

    @property
    def raw_min(self) -> int:
        return -(1 << (self.int_bits - 1 + self.frac_bits))

    @property
    def resolution(self) -> float:
        return 2.0 ** (-self.frac_bits)

    @property
    def name(self) -> str:
        return f"Q{self.int_bits}.{self.frac_bits}"


#: The paper's format: 2 integer bits, 14 fractional bits.
Q2_14 = QFormat(int_bits=2, frac_bits=14)


def quantize(x: jax.Array, fmt: QFormat = Q2_14) -> jax.Array:
    """Real -> int16 raw fixed point, round-to-nearest-even, saturating."""
    raw = jnp.round(x.astype(jnp.float32) * fmt.scale)
    raw = jnp.clip(raw, fmt.raw_min, fmt.raw_max)
    return raw.astype(jnp.int16)


def dequantize(q: jax.Array, fmt: QFormat = Q2_14, dtype=jnp.float32) -> jax.Array:
    """Raw fixed point -> real."""
    return (q.astype(jnp.float32) * (1.0 / fmt.scale)).astype(dtype)


@jax.custom_vjp
def fake_quant(x: jax.Array, scale: float, lo: float, hi: float) -> jax.Array:
    q = jnp.clip(jnp.round(x * scale) / scale, lo, hi)
    return q.astype(x.dtype)


def _fq_fwd(x, scale, lo, hi):
    return fake_quant(x, scale, lo, hi), (x, lo, hi)


def _fq_bwd(res, g):
    # Straight-through estimator, gated outside the representable range.
    x, lo, hi = res
    mask = ((x >= lo) & (x <= hi)).astype(g.dtype)
    return (g * mask, None, None, None)


fake_quant.defvjp(_fq_fwd, _fq_bwd)


def fake_quant_fmt(x: jax.Array, fmt: QFormat = Q2_14) -> jax.Array:
    """STE fake-quantization to ``fmt`` (for quantization-aware training)."""
    return fake_quant(x, fmt.scale, fmt.min_val, fmt.max_val)


def requantize_i32_to_i16(acc: jax.Array, fmt: QFormat = Q2_14) -> jax.Array:
    """Saturating write-back of an int32 accumulator to Qm.n int16.

    The accumulator holds values at scale 2^(2*frac_bits) (product of two
    Qm.n numbers); shift right by frac_bits with round-to-nearest, then
    saturate into the int16 raw range.  This models the FPGA accumulator
    write-back stage.
    """
    rounding = jnp.int32(1 << (fmt.frac_bits - 1))
    shifted = (acc + rounding) >> fmt.frac_bits
    return jnp.clip(shifted, fmt.raw_min, fmt.raw_max).astype(jnp.int16)


@partial(jax.jit, static_argnames=("fmt",))
def qmatmul_ref(xq: jax.Array, wq: jax.Array, fmt: QFormat = Q2_14) -> jax.Array:
    """Semantic oracle for the fixed-point matmul kernel.

    xq: (m, k) int16 raw, wq: (k, n) int16 raw  ->  (m, n) int16 raw.
    int32 accumulation (wraparound, TPU-native), saturating Q write-back.
    """
    acc = jnp.dot(
        xq.astype(jnp.int32), wq.astype(jnp.int32), preferred_element_type=jnp.int32
    )
    return requantize_i32_to_i16(acc, fmt)


def qmatmul_real(x: jax.Array, w: jax.Array, fmt: QFormat = Q2_14) -> jax.Array:
    """Quantize real inputs, run the fixed-point matmul, dequantize.

    This is the end-to-end numerics an FPGA deployment of the paper sees for
    one GEMM: float reference -> Q2.14 -> dot -> Q2.14 -> float.
    """
    return dequantize(qmatmul_ref(quantize(x, fmt), quantize(w, fmt), fmt), fmt)
