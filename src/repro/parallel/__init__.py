"""Distribution substrate: logical-axis sharding rules (DP/FSDP/TP/EP/SP)."""
from .sharding import (
    SERVE_RULES,
    TRAIN_RULES,
    ShardingRules,
    active_mesh,
    constrain,
    logical_to_spec,
    named_sharding,
    tree_shardings,
    use_mesh,
)

__all__ = [
    "SERVE_RULES",
    "TRAIN_RULES",
    "ShardingRules",
    "active_mesh",
    "constrain",
    "logical_to_spec",
    "named_sharding",
    "tree_shardings",
    "use_mesh",
]
