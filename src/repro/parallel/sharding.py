"""Logical-axis sharding: DP / FSDP / TP / EP / SP from one rule table.

Model code annotates tensors with *logical* axis names ("batch", "embed",
"heads", "mlp", "vocab", "experts", ...).  A :class:`ShardingRules` table maps
logical names to mesh axes; :func:`constrain` applies
``with_sharding_constraint`` only when a mesh context is active, so the same
model code runs unsharded on one CPU device and fully sharded on a 512-chip
multi-pod mesh.

Rules follow the MaxText convention; the defaults implement:
  * batch            -> ("pod", "data")   data parallel across pods + pod axis
  * embed/ffn params -> "model"           tensor parallel
  * fsdp dim         -> "data"            ZeRO-3 parameter sharding (training)
  * experts          -> "model"           expert parallel (MoE)
  * kv_heads         -> "model"           GSPMD pads when not divisible
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "ShardingRules",
    "TRAIN_RULES",
    "SERVE_RULES",
    "DECODE_RULES",
    "column_parallel_shardings",
    "use_mesh",
    "active_mesh",
    "axis_size",
    "local_dim",
    "local_gemm_shape",
    "local_conv_shapes",
    "logical_to_spec",
    "constrain",
    "named_sharding",
    "tree_shardings",
]

MeshAxes = Union[str, tuple, None]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Mapping from logical axis name -> mesh axis (or tuple, or None)."""

    rules: tuple = ()

    def get(self, name: Optional[str]) -> MeshAxes:
        if name is None:
            return None
        for k, v in self.rules:
            if k == name:
                return v
        return None

    def with_overrides(self, **overrides) -> "ShardingRules":
        kept = tuple((k, v) for k, v in self.rules if k not in overrides)
        return ShardingRules(rules=kept + tuple(overrides.items()))


def _mk(rules: dict) -> ShardingRules:
    return ShardingRules(rules=tuple(rules.items()))


#: Training: FSDP over "data" + TP over "model"; batch over every data-ish axis.
TRAIN_RULES = _mk(
    {
        "batch": ("pod", "data"),
        "seq": None,
        # sequence parallelism for the residual stream / remat stash: shards
        # per-layer saved activations 16x and keeps norm/add seq-local
        # (default ON for training since §Perf iteration 2)
        "seq_act": "model",
        "seq_kv": "model",  # decode KV-cache seq dim (flash-decoding style)
        "embed": "data",  # FSDP shard dim of 2D params
        "embed_tp": None,
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "qkv": "model",  # flattened heads*head_dim param dim
        "mlp": "model",
        "vocab": "model",
        "experts": "model",
        "expert_mlp": None,
        "expert_cap": None,  # capacity-dim EP variant (see moe.py / §Perf B)
        "ssm_inner": "model",  # mamba2 inner dim (heads*headdim + BC groups)
        "rec": "model",  # RG-LRU recurrent width
        "rec_in": None,  # gate matrix input dim (dense dr x dr)
        "conv_io": None,
        "state": None,
        "ctx": None,  # cross-attention context length (frames / image tokens)
        "act_heads": "model",
        "act_embed": None,
    }
)

#: Serving: params replicated over "data" (no FSDP), TP over "model";
#: batch over data axes.
SERVE_RULES = _mk(
    {
        "batch": ("pod", "data"),
        "seq": None,
        "seq_act": None,
        "seq_kv": "model",
        "embed": None,
        "embed_tp": None,
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "qkv": "model",
        "mlp": "model",
        "vocab": "model",
        "experts": "model",
        "expert_mlp": None,
        "expert_cap": None,
        "ssm_inner": "model",
        "rec": "model",
        "rec_in": None,
        "conv_io": None,
        "state": None,
        "ctx": None,
        "act_heads": "model",
        "act_embed": None,
    }
)


#: Bitwise-reproducible tensor-parallel decode (PR 7).  Serving replicas must
#: produce token streams byte-identical to a single-device run, so every
#: contraction (GEMM K) dimension stays shard-local: params are sharded
#: *column-parallel only* (their final/output dim over "model", see
#: :func:`column_parallel_shardings`) and activations are gathered back to
#: replicated at the existing ``constrain`` seams between GEMMs.  Each shard
#: then computes its output columns with the same left operand and the same
#: reduction order as the unsharded program — no psum reduction whose
#: float reassociation could flip low bits.  Batch (the per-slot KV cache
#: slot dim) still shards over the data-ish axes; vocab stays sharded until
#: the logits constraint gathers it for sampling.
DECODE_RULES = _mk(
    {
        "batch": ("pod", "data"),
        "seq": None,
        "seq_act": None,
        "seq_kv": None,
        "embed": None,
        "embed_tp": None,
        "heads": None,
        "kv_heads": None,
        "head_dim": None,
        "qkv": "model",
        "mlp": "model",
        "vocab": "model",
        "experts": None,
        "expert_mlp": None,
        "expert_cap": None,
        "ssm_inner": None,
        "rec": None,
        "rec_in": None,
        "conv_io": None,
        "state": None,
        "ctx": None,
        "act_heads": None,
        "act_embed": None,
    }
)


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Optional[ShardingRules] = None


_CTX = _Ctx()


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: ShardingRules):
    """Activate a mesh + rule table for ``constrain``/``named_sharding``."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        with mesh:
            yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def _axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _present_axes(mesh, axes: MeshAxes) -> MeshAxes:
    """Drop mesh axes the given mesh does not have (e.g. "pod" on the
    single-pod mesh), collapsing a surviving 1-tuple to its string.  The one
    implementation of the drop rule — shared by :func:`logical_to_spec` and
    the local-shape planners below."""
    if axes is None or mesh is None:
        return None
    present = set(mesh.axis_names)
    if isinstance(axes, str):
        return axes if axes in present else None
    kept = tuple(a for a in axes if a in present)
    if not kept:
        return None
    return kept[0] if len(kept) == 1 else kept


def axis_size(mesh, axes: MeshAxes) -> int:
    """Total shard count over ``axes``, ignoring axes the mesh lacks."""
    return _axis_size(mesh, _present_axes(mesh, axes))


def local_dim(dim: int, mesh, axes: MeshAxes) -> int:
    """Per-shard extent of ``dim`` sharded over ``axes`` (ceil-div: GSPMD
    pads the ragged tail shard).  Dims smaller than the shard count stay
    replicated — the same drop rule :func:`logical_to_spec` applies."""
    s = axis_size(mesh, axes)
    if s <= 1 or dim < s:
        return dim
    return -(-dim // s)


def _resolve_partition(mesh, partition):
    """The (M, N[, K]) partition to plan against: the caller's, or the
    mesh's canonical :func:`repro.launch.mesh.gemm_partition` default."""
    if partition is not None:
        return partition
    from repro.launch.mesh import gemm_partition

    return gemm_partition(mesh)


def local_gemm_shape(m: int, n: int, k: int, *, mesh, partition=None) -> tuple:
    """Per-shard (m, n, k) of a logical GEMM under a mesh partition.

    ``partition`` is a PartitionSpec over (M, N[, K]) — M typically over the
    data-ish axes, N over "model" (K only for reduce-scattered contractions).
    Defaults to :func:`repro.launch.mesh.gemm_partition` for the mesh.
    """
    partition = _resolve_partition(mesh, partition)
    axes = tuple(partition) + (None,) * (3 - len(tuple(partition)))
    return tuple(
        local_dim(d, mesh, a) for d, a in zip((m, n, k), axes[:3])
    )


def local_conv_shapes(x_shape, w_shape, *, mesh, partition=None):
    """Per-shard (NHWC x, KKIO w) of a conv layer under a mesh partition.

    The conv's GEMM M scales with batch and its N is Cout, so the same
    (M, N) partition applies: batch over the M axes, output channels over
    the N axes; spatial dims and Cin stay shard-local (the layer's input
    activations are gathered over channels between layers).
    """
    p = tuple(_resolve_partition(mesh, partition)) + (None, None)
    batch_axes, cout_axes = p[0], p[1]
    n, h, w, c = x_shape
    kh, kw, cin, cout = w_shape
    return (
        (local_dim(n, mesh, batch_axes), h, w, c),
        (kh, kw, cin, local_dim(cout, mesh, cout_axes)),
    )


def logical_to_spec(
    logical: Sequence[Optional[str]],
    *,
    mesh: Optional[Mesh] = None,
    rules: Optional[ShardingRules] = None,
    dim_sizes: Optional[Sequence[int]] = None,
    require_divisible: bool = False,
) -> P:
    """Translate logical axis names to a PartitionSpec.

    If ``dim_sizes`` is given, axes whose size is not divisible by the mesh
    axis size are only kept when GSPMD padding is acceptable (always true for
    jit inputs/constraints); we still drop the mapping when the dim is
    *smaller* than the mesh axis product (e.g. batch=1 over 16-way data).
    """
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules
    if rules is None:
        return P()
    out = []
    for i, name in enumerate(logical):
        axes = rules.get(name)
        if axes is not None and mesh is not None:
            axes = _present_axes(mesh, axes)
        if axes is not None and mesh is not None and dim_sizes is not None:
            if dim_sizes[i] < _axis_size(mesh, axes):
                axes = None
            elif require_divisible and dim_sizes[i] % _axis_size(mesh, axes):
                # jit in/out shardings must divide exactly (GSPMD pads only
                # inside the program, not at its boundary)
                axes = None
        out.append(axes)
    # a mesh axis may appear at most once: keep its first (leftmost) use.
    # (e.g. with sequence parallelism seq_act->model, a logits constraint
    # (batch, seq_act, vocab) would map "model" twice)
    seen: set = set()
    for i, axes in enumerate(out):
        if axes is None:
            continue
        tup = (axes,) if isinstance(axes, str) else tuple(axes)
        kept = tuple(a for a in tup if a not in seen)
        seen.update(kept)
        if not kept:
            out[i] = None
        elif len(kept) == 1:
            out[i] = kept[0]
        else:
            out[i] = kept
    # trailing Nones are implicit
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without a mesh."""
    if _CTX.mesh is None or _CTX.rules is None:
        return x
    spec = logical_to_spec(logical, dim_sizes=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_CTX.mesh, spec))


def named_sharding(
    mesh: Mesh,
    rules: ShardingRules,
    logical: Sequence[Optional[str]],
    dim_sizes: Optional[Sequence[int]] = None,
    require_divisible: bool = False,
) -> NamedSharding:
    return NamedSharding(
        mesh,
        logical_to_spec(
            logical, mesh=mesh, rules=rules, dim_sizes=dim_sizes,
            require_divisible=require_divisible,
        ),
    )


def _is_axes_leaf(x) -> bool:
    return x is None or (
        isinstance(x, tuple)
        and len(x) > 0
        and all(e is None or isinstance(e, str) for e in x)
    )


def tree_shardings(mesh: Mesh, rules: ShardingRules, shapes_tree, axes_tree):
    """Build a NamedSharding pytree from a ShapeDtypeStruct tree and a parallel
    tree of logical-axis tuples (None leaf => replicated).

    Mapped over ``axes_tree`` first so tuple leaves are not traversed as
    subtrees.
    """

    def one(axes_leaf, shape_leaf):
        if axes_leaf is None:
            return NamedSharding(mesh, P())
        return named_sharding(
            mesh, rules, axes_leaf, dim_sizes=shape_leaf.shape,
            require_divisible=True,
        )

    return jax.tree.map(one, axes_tree, shapes_tree, is_leaf=_is_axes_leaf)


def column_parallel_shardings(mesh: Mesh, rules: ShardingRules, params_tree,
                              axes_tree):
    """Param shardings that keep every GEMM contraction shard-local.

    Masks each logical-axes leaf down to its *final* (output/N) dimension
    before resolving against ``rules`` — e.g. wq ("embed", "qkv") becomes
    (None, "qkv") — so a parameter is only ever split along the columns it
    *produces*.  Combined with :data:`DECODE_RULES` (activations replicated
    at the constrain seams) this yields a tensor-parallel step whose every
    partial product is computed with the full K extent in the original
    reduction order: bitwise-equal to the single-device step, float and q16.

    ``params_tree`` may be the float param tree or the quantized exec tree
    (QTensor leaves expose ``.shape``); 1-D leaves (biases, norm scales)
    keep their single logical name and shard iff the rules map it.
    """

    def one(axes_leaf, param_leaf):
        if axes_leaf is None:
            return NamedSharding(mesh, P())
        masked = (None,) * (len(axes_leaf) - 1) + (axes_leaf[-1],)
        return named_sharding(
            mesh, rules, masked, dim_sizes=param_leaf.shape,
            require_divisible=True,
        )

    return jax.tree.map(one, axes_tree, params_tree, is_leaf=_is_axes_leaf)
