"""Logical-axis sharding: DP / FSDP / TP / EP / SP from one rule table.

Model code annotates tensors with *logical* axis names ("batch", "embed",
"heads", "mlp", "vocab", "experts", ...).  A :class:`ShardingRules` table maps
logical names to mesh axes; :func:`constrain` applies
``with_sharding_constraint`` only when a mesh context is active, so the same
model code runs unsharded on one CPU device and fully sharded on a 512-chip
multi-pod mesh.

Rules follow the MaxText convention; the defaults implement:
  * batch            -> ("pod", "data")   data parallel across pods + pod axis
  * embed/ffn params -> "model"           tensor parallel
  * fsdp dim         -> "data"            ZeRO-3 parameter sharding (training)
  * experts          -> "model"           expert parallel (MoE)
  * kv_heads         -> "model"           GSPMD pads when not divisible
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "ShardingRules",
    "TRAIN_RULES",
    "SERVE_RULES",
    "DECODE_RULES",
    "SpatialHalo",
    "column_parallel_shardings",
    "use_mesh",
    "active_mesh",
    "axis_size",
    "local_dim",
    "local_gemm_shape",
    "local_conv_shapes",
    "logical_to_spec",
    "constrain",
    "constrain_slabs",
    "named_sharding",
    "tree_shardings",
    "plan_spatial_halo",
    "spatial_shards",
    "halo_exchange",
    "spatial_halo_bytes",
    "spatial_gather_bytes",
]

MeshAxes = Union[str, tuple, None]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Mapping from logical axis name -> mesh axis (or tuple, or None)."""

    rules: tuple = ()

    def get(self, name: Optional[str]) -> MeshAxes:
        if name is None:
            return None
        for k, v in self.rules:
            if k == name:
                return v
        return None

    def with_overrides(self, **overrides) -> "ShardingRules":
        kept = tuple((k, v) for k, v in self.rules if k not in overrides)
        return ShardingRules(rules=kept + tuple(overrides.items()))


def _mk(rules: dict) -> ShardingRules:
    return ShardingRules(rules=tuple(rules.items()))


#: Training: FSDP over "data" + TP over "model"; batch over every data-ish axis.
TRAIN_RULES = _mk(
    {
        "batch": ("pod", "data"),
        "seq": None,
        # sequence parallelism for the residual stream / remat stash: shards
        # per-layer saved activations 16x and keeps norm/add seq-local
        # (default ON for training since §Perf iteration 2)
        "seq_act": "model",
        "seq_kv": "model",  # decode KV-cache seq dim (flash-decoding style)
        "embed": "data",  # FSDP shard dim of 2D params
        "embed_tp": None,
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "qkv": "model",  # flattened heads*head_dim param dim
        "mlp": "model",
        "vocab": "model",
        "experts": "model",
        "expert_mlp": None,
        "expert_cap": None,  # capacity-dim EP variant (see moe.py / §Perf B)
        "ssm_inner": "model",  # mamba2 inner dim (heads*headdim + BC groups)
        "rec": "model",  # RG-LRU recurrent width
        "rec_in": None,  # gate matrix input dim (dense dr x dr)
        "conv_io": None,
        "state": None,
        "ctx": None,  # cross-attention context length (frames / image tokens)
        "act_heads": "model",
        "act_embed": None,
    }
)

#: Serving: params replicated over "data" (no FSDP), TP over "model";
#: batch over data axes.
SERVE_RULES = _mk(
    {
        "batch": ("pod", "data"),
        "seq": None,
        "seq_act": None,
        "seq_kv": "model",
        "embed": None,
        "embed_tp": None,
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "qkv": "model",
        "mlp": "model",
        "vocab": "model",
        "experts": "model",
        "expert_mlp": None,
        "expert_cap": None,
        "ssm_inner": "model",
        "rec": "model",
        "rec_in": None,
        "conv_io": None,
        "state": None,
        "ctx": None,
        "act_heads": "model",
        "act_embed": None,
    }
)


#: Bitwise-reproducible tensor-parallel decode (PR 7).  Serving replicas must
#: produce token streams byte-identical to a single-device run, so every
#: contraction (GEMM K) dimension stays shard-local: params are sharded
#: *column-parallel only* (their final/output dim over "model", see
#: :func:`column_parallel_shardings`) and activations are gathered back to
#: replicated at the existing ``constrain`` seams between GEMMs.  Each shard
#: then computes its output columns with the same left operand and the same
#: reduction order as the unsharded program — no psum reduction whose
#: float reassociation could flip low bits.  Batch (the per-slot KV cache
#: slot dim) still shards over the data-ish axes; vocab stays sharded until
#: the logits constraint gathers it for sampling.
DECODE_RULES = _mk(
    {
        "batch": ("pod", "data"),
        "seq": None,
        "seq_act": None,
        "seq_kv": None,
        "embed": None,
        "embed_tp": None,
        "heads": None,
        "kv_heads": None,
        "head_dim": None,
        "qkv": "model",
        "mlp": "model",
        "vocab": "model",
        "experts": None,
        "expert_mlp": None,
        "expert_cap": None,
        "ssm_inner": None,
        "rec": None,
        "rec_in": None,
        "conv_io": None,
        "state": None,
        "ctx": None,
        "act_heads": None,
        "act_embed": None,
    }
)


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Optional[ShardingRules] = None


_CTX = _Ctx()


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: ShardingRules):
    """Activate a mesh + rule table for ``constrain``/``named_sharding``."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        with mesh:
            yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def _axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _present_axes(mesh, axes: MeshAxes) -> MeshAxes:
    """Drop mesh axes the given mesh does not have (e.g. "pod" on the
    single-pod mesh), collapsing a surviving 1-tuple to its string.  The one
    implementation of the drop rule — shared by :func:`logical_to_spec` and
    the local-shape planners below."""
    if axes is None or mesh is None:
        return None
    present = set(mesh.axis_names)
    if isinstance(axes, str):
        return axes if axes in present else None
    kept = tuple(a for a in axes if a in present)
    if not kept:
        return None
    return kept[0] if len(kept) == 1 else kept


def axis_size(mesh, axes: MeshAxes) -> int:
    """Total shard count over ``axes``, ignoring axes the mesh lacks."""
    return _axis_size(mesh, _present_axes(mesh, axes))


def local_dim(dim: int, mesh, axes: MeshAxes) -> int:
    """Per-shard extent of ``dim`` sharded over ``axes``.

    One drop rule, shared with :func:`logical_to_spec` (ISSUE 9): a dim
    that does not divide the shard count stays **replicated** (returns
    ``dim``), because the param/jit-boundary shardings built by
    :func:`tree_shardings`/:func:`column_parallel_shardings` drop exactly
    those mappings — a planner that ceil-divided here would plan a local
    Cout/batch shape that never executes.
    """
    s = axis_size(mesh, axes)
    if s <= 1 or dim < s or dim % s:
        return dim
    return dim // s


def _resolve_partition(mesh, partition):
    """The (M, N[, K]) partition to plan against: the caller's, or the
    mesh's canonical :func:`repro.launch.mesh.gemm_partition` default."""
    if partition is not None:
        return partition
    from repro.launch.mesh import gemm_partition

    return gemm_partition(mesh)


def local_gemm_shape(m: int, n: int, k: int, *, mesh, partition=None) -> tuple:
    """Per-shard (m, n, k) of a logical GEMM under a mesh partition.

    ``partition`` is a PartitionSpec over (M, N[, K]) — M typically over the
    data-ish axes, N over "model" (K only for reduce-scattered contractions).
    Defaults to :func:`repro.launch.mesh.gemm_partition` for the mesh.
    """
    partition = _resolve_partition(mesh, partition)
    axes = tuple(partition) + (None,) * (3 - len(tuple(partition)))
    return tuple(
        local_dim(d, mesh, a) for d, a in zip((m, n, k), axes[:3])
    )


def local_conv_shapes(x_shape, w_shape, *, mesh, partition=None,
                      spatial=None, stride: int = 1, padding: int = 0):
    """Per-shard (NHWC x, KKIO w) of a conv layer under a mesh partition.

    Default (batch/Cout) mode: the conv's GEMM M scales with batch and its
    N is Cout, so the same (M, N) partition applies: batch over the M axes,
    output channels over the N axes; spatial dims and Cin stay shard-local
    (the layer's input activations are gathered over channels between
    layers).

    Spatial mode (ISSUE 9): ``spatial`` — a shard count, a mesh axis name,
    or a pre-planned :class:`SpatialHalo` — partitions **H** instead: each
    shard owns an H slab of the feature map and the per-shard x shape is the
    *halo-augmented* local slab (the ``(lo−1)·stride + kh`` input-row window
    its output rows consume, width pre-padded), with batch and Cout staying
    shard-local — the data-ish mesh axes carry H, not batch.  ``stride`` /
    ``padding`` are required to size the halo window.
    """
    n, h, w, c = x_shape
    kh, kw, cin, cout = w_shape
    if spatial is not None:
        hs = spatial if isinstance(spatial, SpatialHalo) else plan_spatial_halo(
            h, kh, stride, padding, *spatial_shards(spatial, mesh)
        )
        return (n, hs.win, w + 2 * padding, c), w_shape
    p = tuple(_resolve_partition(mesh, partition)) + (None, None)
    batch_axes, cout_axes = p[0], p[1]
    return (
        (local_dim(n, mesh, batch_axes), h, w, c),
        (kh, kw, cin, local_dim(cout, mesh, cout_axes)),
    )


# ---------------------------------------------------------------------------
# cross-chip spatial (H) sharding with halo exchange (ISSUE 9, DESIGN.md §10)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SpatialHalo:
    """Plan for one spatially-sharded conv/pool layer seam.

    Each of ``shards`` shards owns a contiguous H slab of the activation in
    the *slab-major* layout ``(S, N, lx, W, C)``: buffer row ``r`` of slab
    ``s`` always holds global row ``s·lx + r`` (zero when that row is beyond
    the global extent — the invariant every spatial op re-establishes by
    masking its ragged tail shard).  Before the op, each shard receives
    ``up`` rows from the shard above and ``dn`` rows from the shard below —
    the only cross-shard movement of the layer, ``kh − stride`` rows at an
    aligned seam — and slices its ``win``-row input window at ``offsets[s]``
    inside the extended buffer.  Zero fill at the mesh edges doubles as the
    conv's spatial zero padding (``pad`` is re-applied to W explicitly).
    """

    shards: int  # S
    axis: Optional[str]  # mesh axis the slab dim shards over (None = local)
    h: int  # global input rows
    ho: int  # global output rows
    lx: int  # slab buffer rows of the incoming layout
    lo: int  # output rows each shard computes (= ceil(ho / S))
    win: int  # input rows of each shard's window: (lo − 1)·stride + kh
    up: int  # halo rows received from the shard above
    dn: int  # halo rows received from the shard below
    offsets: tuple  # per-shard window start inside the (up + lx + dn) buffer
    valid_out: tuple  # per-shard valid output rows (ragged tail < lo)
    pad: int  # the conv's spatial zero padding (W is pre-padded by this)

    @property
    def ragged(self) -> bool:
        return any(v != self.lo for v in self.valid_out)


def spatial_shards(spatial, mesh=None) -> tuple:
    """Resolve a ``spatial=`` option to ``(shards, axis_name_or_None)``.

    An int is a plain shard count (slab-major simulation on however many
    devices the arrays land on); a str names the mesh axis whose size is
    the shard count and over which the slab dim is sharded.
    """
    if isinstance(spatial, str):
        mesh = mesh if mesh is not None else _CTX.mesh
        if mesh is None or spatial not in mesh.axis_names:
            raise ValueError(
                f"spatial mesh axis {spatial!r} needs an active mesh that "
                f"has it (mesh={None if mesh is None else mesh.axis_names})"
            )
        return int(mesh.shape[spatial]), spatial
    s = int(spatial)
    if s < 1:
        raise ValueError(f"spatial shard count must be >= 1, got {s}")
    return s, None


def plan_spatial_halo(
    h: int, kh: int, stride: int, pad: int, shards: int,
    axis: Optional[str] = None, lx: Optional[int] = None,
) -> SpatialHalo:
    """Plan the halo exchange for one conv/pool seam (all static Python ints).

    ``h`` rows arrive laid out as ``shards`` slabs of ``lx`` buffer rows
    (default: ceil-div — the layout :func:`plan_spatial_halo` itself assigns
    to the *previous* layer's output, so chained calls pass ``lx=prev.lo``).
    Shard ``s`` computes output rows ``[s·lo, s·lo + lo)`` of the
    ``ho = (h + 2·pad − kh)//stride + 1`` global output rows, for which it
    needs input rows ``[s·lo·stride − pad, …)`` — ``up``/``dn`` are the
    worst-case per-seam row counts that window reaches into the neighbor
    slabs.  At an aligned seam (``lo·stride == lx``) that is exactly the
    paper's ``kh − stride`` halo rows.  Raises when a slab is too thin to
    serve its neighbor's halo from one hop away (shards > what H supports).
    """
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    if h < 1 or kh < 1 or stride < 1 or pad < 0:
        raise ValueError(f"bad conv geometry h={h} kh={kh} stride={stride} pad={pad}")
    ho = (h + 2 * pad - kh) // stride + 1
    if ho < 1:
        raise ValueError(f"conv produces no output rows (h={h}, kh={kh}, pad={pad})")
    lx = -(-h // shards) if lx is None else int(lx)
    if lx * shards < h:
        raise ValueError(f"slab layout lx={lx} x {shards} shards cannot hold h={h}")
    lo = -(-ho // shards)
    win = (lo - 1) * stride + kh
    up = dn = 0
    offsets, valid_out = [], []
    for s in range(shards):
        g = s * lo * stride - pad  # global row of this shard's window start
        up = max(up, s * lx - g)
        dn = max(dn, (g + win) - (s + 1) * lx)
        offsets.append(g - s * lx)  # relative to own slab start; += up below
        valid_out.append(max(0, min(lo, ho - s * lo)))
    up, dn = max(0, up), max(0, dn)
    if up > lx or dn > lx:
        raise ValueError(
            f"spatial halo needs {up}/{dn} rows from a {lx}-row neighbor "
            f"slab: h={h} is too thin for {shards} shards at kh={kh}, "
            f"stride={stride} (halo exchange is single-hop)"
        )
    return SpatialHalo(
        shards=shards, axis=axis, h=h, ho=ho, lx=lx, lo=lo, win=win,
        up=up, dn=dn, offsets=tuple(o + up for o in offsets),
        valid_out=tuple(valid_out), pad=pad,
    )


def halo_exchange(v: jax.Array, hs: SpatialHalo) -> jax.Array:
    """The neighbor collective + window select of one spatial layer seam.

    ``v``: slab-major raw array ``(S, N, lx, W, C)`` -> the per-shard input
    windows ``(S, N, win, W, C)``.  Only the ``up``/``dn`` halo *rows* move
    between shards — the slices along the (sharded) slab axis lower to a
    neighbor collective-permute under GSPMD, and the mesh-edge shards
    receive zeros, which doubles as the conv's H zero padding.
    """
    if v.ndim != 5 or v.shape[0] != hs.shards or v.shape[2] != hs.lx:
        raise ValueError(
            f"expected slab-major (S={hs.shards}, N, lx={hs.lx}, W, C), "
            f"got {v.shape}"
        )
    # Neighbor movement is jnp.roll on the slab axis — the one shift pattern
    # GSPMD reliably lowers to a collective-permute of just the rolled rows
    # (slice+concat *along the sharded axis* miscompiles under the CPU SPMD
    # partitioner) — with the wrapped-around mesh-edge slab masked to zero,
    # which doubles as the conv's H zero padding.  Everything else (the row
    # concat, the window select) happens on the unsharded row axis.
    sidx = jax.lax.broadcasted_iota(jnp.int32, (hs.shards, 1, 1, 1, 1), 0)
    parts = []
    if hs.up:
        above = jnp.roll(v, 1, axis=0)[:, :, hs.lx - hs.up:]
        parts.append(jnp.where(sidx > 0, above, jnp.zeros_like(above)))
    parts.append(v)
    if hs.dn:
        below = jnp.roll(v, -1, axis=0)[:, :, :hs.dn]
        parts.append(
            jnp.where(sidx < hs.shards - 1, below, jnp.zeros_like(below))
        )
    ext = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=2)
    if len(set(hs.offsets)) == 1:
        o = hs.offsets[0]
        return ext[:, :, o:o + hs.win]
    # misaligned seams (lo·stride != lx): per-shard window starts differ, so
    # gather each shard's rows in place — indices stay within the shard's
    # extended buffer, no extra communication
    rows = (
        jnp.asarray(hs.offsets, jnp.int32)[:, None]
        + jnp.arange(hs.win, dtype=jnp.int32)[None, :]
    )
    return jnp.take_along_axis(ext, rows[:, None, :, None, None], axis=2)


def mask_slab_rows(v: jax.Array, hs: SpatialHalo) -> jax.Array:
    """Zero the ragged tail shard's invalid output rows (the slab invariant:
    buffer rows beyond the global extent hold zeros, so the *next* seam's
    zero fill and halo reads stay exact)."""
    if not hs.ragged:
        return v
    rows = jax.lax.broadcasted_iota(jnp.int32, (hs.shards, 1, hs.lo, 1, 1), 2)
    ok = rows < jnp.asarray(hs.valid_out, jnp.int32).reshape(-1, 1, 1, 1, 1)
    return jnp.where(ok, v, jnp.zeros_like(v))


def constrain_slabs(v: jax.Array, axis: Optional[str]) -> jax.Array:
    """Keep a slab-major array's leading (slab) dim sharded over ``axis``.

    No-op without an active mesh, when ``axis`` is absent from it, or when
    the slab count does not divide the axis (the module's one drop rule).
    """
    mesh = _CTX.mesh
    if axis is None or mesh is None or axis not in mesh.axis_names:
        return v
    if v.shape[0] % mesh.shape[axis]:
        return v
    return jax.lax.with_sharding_constraint(
        v, NamedSharding(mesh, P(axis))
    )


def spatial_halo_bytes(hs: SpatialHalo, n: int, w: int, c: int,
                       itemsize: int) -> int:
    """Modeled bytes the halo exchange moves between shards for one seam:
    every interior seam carries ``up`` rows downward and ``dn`` rows upward,
    each a full-width (N, rows, W, C) strip."""
    return (hs.shards - 1) * (hs.up + hs.dn) * n * w * c * itemsize


def spatial_gather_bytes(h: int, n: int, w: int, c: int, shards: int,
                         itemsize: int) -> int:
    """Modeled bytes of the alternative the halo exchange replaces: a ring
    all-gather of the whole (N, H, W, C) activation onto every shard before
    each conv ((S−1)/S of the tensor received per shard, S shards)."""
    return (shards - 1) * n * h * w * c * itemsize


def logical_to_spec(
    logical: Sequence[Optional[str]],
    *,
    mesh: Optional[Mesh] = None,
    rules: Optional[ShardingRules] = None,
    dim_sizes: Optional[Sequence[int]] = None,
    require_divisible: bool = False,
) -> P:
    """Translate logical axis names to a PartitionSpec.

    If ``dim_sizes`` is given, a mapping whose dim is smaller than — or not
    divisible by — the mesh axis product is dropped (replicated).  This is
    the **one** drop rule of the module, shared with :func:`local_dim`
    (ISSUE 9): it used to apply only under ``require_divisible=True`` (the
    jit-boundary callers), which let `plan_conv(mesh=...)` ceil-div a ragged
    Cout that `column_parallel_shardings` would silently replicate — a
    planned local shape that never executed.  ``require_divisible`` is kept
    for API compatibility but divisibility is now always enforced.
    """
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules
    if rules is None:
        return P()
    out = []
    for i, name in enumerate(logical):
        axes = rules.get(name)
        if axes is not None and mesh is not None:
            axes = _present_axes(mesh, axes)
        if axes is not None and mesh is not None and dim_sizes is not None:
            s = _axis_size(mesh, axes)
            if dim_sizes[i] < s or dim_sizes[i] % s:
                axes = None
        out.append(axes)
    # a mesh axis may appear at most once: keep its first (leftmost) use.
    # (e.g. with sequence parallelism seq_act->model, a logits constraint
    # (batch, seq_act, vocab) would map "model" twice)
    seen: set = set()
    for i, axes in enumerate(out):
        if axes is None:
            continue
        tup = (axes,) if isinstance(axes, str) else tuple(axes)
        kept = tuple(a for a in tup if a not in seen)
        seen.update(kept)
        if not kept:
            out[i] = None
        elif len(kept) == 1:
            out[i] = kept[0]
        else:
            out[i] = kept
    # trailing Nones are implicit
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without a mesh."""
    if _CTX.mesh is None or _CTX.rules is None:
        return x
    spec = logical_to_spec(logical, dim_sizes=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_CTX.mesh, spec))


def named_sharding(
    mesh: Mesh,
    rules: ShardingRules,
    logical: Sequence[Optional[str]],
    dim_sizes: Optional[Sequence[int]] = None,
    require_divisible: bool = False,
) -> NamedSharding:
    return NamedSharding(
        mesh,
        logical_to_spec(
            logical, mesh=mesh, rules=rules, dim_sizes=dim_sizes,
            require_divisible=require_divisible,
        ),
    )


def _is_axes_leaf(x) -> bool:
    return x is None or (
        isinstance(x, tuple)
        and len(x) > 0
        and all(e is None or isinstance(e, str) for e in x)
    )


def tree_shardings(mesh: Mesh, rules: ShardingRules, shapes_tree, axes_tree):
    """Build a NamedSharding pytree from a ShapeDtypeStruct tree and a parallel
    tree of logical-axis tuples (None leaf => replicated).

    Mapped over ``axes_tree`` first so tuple leaves are not traversed as
    subtrees.
    """

    def one(axes_leaf, shape_leaf):
        if axes_leaf is None:
            return NamedSharding(mesh, P())
        return named_sharding(
            mesh, rules, axes_leaf, dim_sizes=shape_leaf.shape,
            require_divisible=True,
        )

    return jax.tree.map(one, axes_tree, shapes_tree, is_leaf=_is_axes_leaf)


def column_parallel_shardings(mesh: Mesh, rules: ShardingRules, params_tree,
                              axes_tree):
    """Param shardings that keep every GEMM contraction shard-local.

    Masks each logical-axes leaf down to its *final* (output/N) dimension
    before resolving against ``rules`` — e.g. wq ("embed", "qkv") becomes
    (None, "qkv") — so a parameter is only ever split along the columns it
    *produces*.  Combined with :data:`DECODE_RULES` (activations replicated
    at the constrain seams) this yields a tensor-parallel step whose every
    partial product is computed with the full K extent in the original
    reduction order: bitwise-equal to the single-device step, float and q16.

    ``params_tree`` may be the float param tree or the quantized exec tree
    (QTensor leaves expose ``.shape``); 1-D leaves (biases, norm scales)
    keep their single logical name and shard iff the rules map it.
    """

    def one(axes_leaf, param_leaf):
        if axes_leaf is None:
            return NamedSharding(mesh, P())
        masked = (None,) * (len(axes_leaf) - 1) + (axes_leaf[-1],)
        return named_sharding(
            mesh, rules, masked, dim_sizes=param_leaf.shape,
            require_divisible=True,
        )

    return jax.tree.map(one, axes_tree, params_tree, is_leaf=_is_axes_leaf)
