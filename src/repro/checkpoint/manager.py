"""Atomic checkpointing with elastic (cross-mesh) restore.

Fault-tolerance contract:

* **Atomicity** — a checkpoint is written to ``step_<n>.tmp-<pid>`` and
  renamed to ``step_<n>`` only after every array and the metadata manifest
  are fsync'd.  A crash mid-write leaves a ``.tmp`` dir that restore ignores
  and the next save garbage-collects; the previous complete checkpoint is
  never touched.
* **Elastic restore** — arrays are stored unsharded (np.save per leaf); on
  restore they are ``device_put`` against whatever shardings the *current*
  mesh prescribes, so a job can come back on a different topology (e.g.
  512 -> 256 chips after losing a pod) without conversion tooling.  On a real
  multi-host deployment each host would read its local shard slice; the
  single-process layout here keeps the same API.
* **Determinism** — the data pipeline is a pure function of the step, so
  (params, opt_state, step) is the complete resume state.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "manifest_extra", "CheckpointManager"]

_MANIFEST = "manifest.json"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


def save(directory: str, step: int, tree: Any, extra: Optional[dict] = None) -> str:
    """Atomically write ``tree`` as checkpoint ``step_<step>``."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=f"step_{step:08d}.tmp-", dir=directory)
    try:
        flat = _flatten(tree)
        names = {}
        for i, (key, leaf) in enumerate(sorted(flat.items())):
            fname = f"arr_{i:05d}.npy"
            arr = np.asarray(jax.device_get(leaf))
            with open(os.path.join(tmp, fname), "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())
            names[key] = {"file": fname, "dtype": str(arr.dtype), "shape": list(arr.shape)}
        manifest = {"step": step, "arrays": names, "extra": extra or {}}
        mpath = os.path.join(tmp, _MANIFEST)
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # gc any stale tmp dirs from crashed writers
    for d in os.listdir(directory):
        if ".tmp-" in d:
            shutil.rmtree(os.path.join(directory, d), ignore_errors=True)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and ".tmp" not in d:
            if os.path.exists(os.path.join(directory, d, _MANIFEST)):
                steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(directory: str, step: int, target_tree: Any,
            shardings: Optional[Any] = None) -> Any:
    """Load checkpoint ``step`` into the structure of ``target_tree``.

    ``shardings``: optional pytree of NamedSharding (same structure) — arrays
    are placed onto them (elastic re-shard).  Without it, arrays go to the
    default device.
    """
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    flat_target = _flatten(target_tree)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    loaded = {}
    for key, meta in manifest["arrays"].items():
        if key not in flat_target:
            raise KeyError(f"checkpoint key {key!r} missing from target tree")
        arr = np.load(os.path.join(path, meta["file"]))
        if list(arr.shape) != list(flat_target[key].shape):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != target {flat_target[key].shape}"
            )
        sh = flat_shard.get(key)
        loaded[key] = jax.device_put(arr, sh) if sh is not None else jax.device_put(arr)
    missing = set(flat_target) - set(loaded)
    if missing:
        raise KeyError(f"target keys missing from checkpoint: {sorted(missing)[:5]}")
    # rebuild the pytree in target order
    paths, tdef = jax.tree_util.tree_flatten_with_path(target_tree)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            for path, _ in paths]
    return tdef.unflatten([loaded[k] for k in keys])


def manifest_extra(directory: str, step: int) -> dict:
    """The ``extra`` metadata dict stored with checkpoint ``step``.

    ``save(..., extra=...)`` persists arbitrary JSON alongside the arrays
    (train loop hyperparams, and — since PR 7 — a serving replica's
    in-flight session snapshots) but :func:`restore` only rebuilds the
    array tree; this is the read path for the metadata half.
    """
    path = os.path.join(directory, f"step_{step:08d}", _MANIFEST)
    with open(path) as f:
        manifest = json.load(f)
    return manifest.get("extra") or {}


class CheckpointManager:
    """Keep-last-N rotation + auto-resume."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep

    def save(self, step: int, tree, extra: Optional[dict] = None) -> str:
        path = save(self.directory, step, tree, extra)
        self._gc()
        return path

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step_") and ".tmp" not in d
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True
            )

    def latest(self) -> Optional[int]:
        return latest_step(self.directory)

    def restore_latest(self, target_tree, shardings=None):
        step = self.latest()
        if step is None:
            return None, None
        return step, restore(self.directory, step, target_tree, shardings)

    def latest_extra(self):
        """(step, extra-dict) of the newest checkpoint, or (None, None)."""
        step = self.latest()
        if step is None:
            return None, None
        return step, manifest_extra(self.directory, step)
