from .adamw import AdamW, OptState, adamw_init, adamw_update
from .schedules import constant, cosine_warmup, linear_warmup
from .compress import compress_int8, decompress_int8, compressed_grad_reduce

__all__ = [
    "AdamW",
    "OptState",
    "adamw_init",
    "adamw_update",
    "constant",
    "cosine_warmup",
    "linear_warmup",
    "compress_int8",
    "decompress_int8",
    "compressed_grad_reduce",
]
