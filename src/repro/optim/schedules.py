"""Learning-rate schedules (pure functions of the int32 step)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["constant", "linear_warmup", "cosine_warmup"]


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_warmup(lr: float, warmup: int):
    def fn(step):
        s = step.astype(jnp.float32)
        return jnp.asarray(lr, jnp.float32) * jnp.minimum(1.0, s / max(warmup, 1))

    return fn


def cosine_warmup(lr: float, warmup: int, total: int, final_frac: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, s / max(warmup, 1))
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.asarray(lr, jnp.float32) * warm * cos

    return fn
