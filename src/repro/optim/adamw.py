"""AdamW with fully-sharded optimizer states and global-norm clipping.

State entries (m, v) mirror the parameter pytree, so they inherit the exact
parameter shardings (FSDP: optimizer states shard with their params — the
ZeRO invariant).  The update is pure and jit-safe; the learning-rate schedule
is evaluated from the carried step count.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamW", "OptState", "adamw_init", "adamw_update", "global_norm"]


class OptState(NamedTuple):
    step: jax.Array  # () int32
    m: dict
    v: dict


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0

    def init(self, params) -> OptState:
        return adamw_init(params)

    def update(self, grads, state: OptState, params):
        return adamw_update(self, grads, state, params)


def adamw_init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves)
    )


def adamw_update(opt: AdamW, grads, state: OptState, params):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    if opt.clip_norm is not None:
        scale = jnp.minimum(1.0, opt.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    lr = opt.lr(step) if callable(opt.lr) else jnp.asarray(opt.lr, jnp.float32)
    b1, b2 = opt.b1, opt.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + opt.eps)
        if opt.weight_decay and p.ndim >= 2:  # decay matrices only
            delta = delta + opt.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, OptState(step=step, m=new_m, v=new_v), {"grad_norm": gnorm, "lr": lr}
