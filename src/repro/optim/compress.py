"""int8 gradient compression with error feedback — a distributed-optimization
feature for the data-parallel gradient reduction.

On a 512-chip multi-pod mesh the DP gradient all-reduce moves 2 bytes/param
(bf16) per step per chip-pair; compressing the wire format to int8 halves the
collective term (4x vs f32).  Error feedback (Seide et al., 1-bit SGD; Karimireddy
et al. 2019) accumulates the quantization residual locally and re-injects it
next step, which provably preserves SGD convergence for contractive
compressors.

Two integration points:

* :func:`compressed_grad_reduce` — a ``shard_map``-level psum that quantizes
  per-tensor to int8 before the wire and dequantizes after.  Used by the
  training driver when ``--compress-grads`` is set; the dry-run plane keeps
  GSPMD's own bf16 all-reduce (documented in EXPERIMENTS.md §Perf).
* :func:`apply_error_feedback` — pure-pytree EF state update usable with any
  compressor.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = [
    "compress_int8",
    "decompress_int8",
    "apply_error_feedback",
    "compressed_grad_reduce",
    "compressed_psum",
]


def compress_int8(g: jax.Array):
    """Per-tensor symmetric int8 quantization.  Returns (q, scale)."""
    absmax = jnp.max(jnp.abs(g.astype(jnp.float32)))
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def apply_error_feedback(grads, ef_state, compress_fn, decompress_fn):
    """g' = C(g + e);  e' = (g + e) - g'.  Returns (compressed_grads, new_ef)."""

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        packed = compress_fn(corrected)
        restored = decompress_fn(packed)
        return restored.astype(g.dtype), corrected - restored

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), tdef.unflatten([o[1] for o in out])


def init_error_feedback(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(g: jax.Array, axis_name: str) -> jax.Array:
    """psum whose wire format is int8 + one f32 scale per tensor.

    Inside shard_map: quantize locally, all-reduce the int8 payload as int32
    partial sums (the hardware reduction dtype), all-reduce the scales, and
    dequantize with the max scale.  Wire bytes ≈ 1/4 of an f32 psum.
    """
    q, scale = compress_int8(g)
    scale_max = jax.lax.pmax(scale, axis_name)
    # requantize against the shared scale so the integer sum is coherent
    q = jnp.clip(
        jnp.round(g.astype(jnp.float32) / scale_max), -127, 127
    ).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return (total.astype(jnp.float32) * scale_max).astype(g.dtype)


def compressed_grad_reduce(grads, mesh, axis: str = "data",
                           ef_state: Optional[dict] = None):
    """All-reduce a *per-replica* gradient pytree over ``axis`` in int8.

    grads must be replica-local (e.g. computed under shard_map without psum).
    Returns (reduced_grads, new_ef_state).  With ef_state, error feedback is
    applied before the wire quantization.
    """
    if ef_state is not None:
        def comp(x):
            return compress_int8(x)

        def decomp(p):
            return decompress_int8(*p)

        grads, ef_state = apply_error_feedback(grads, ef_state, comp, decomp)

    from jax.experimental.shard_map import shard_map

    n = mesh.shape[axis]

    def reduce_fn(g):
        return jax.tree.map(lambda x: compressed_psum(x, axis) / n, g)

    spec = jax.tree.map(lambda _: P(), grads)
    fn = shard_map(
        reduce_fn, mesh=mesh, in_specs=(spec,), out_specs=spec, check_rep=False
    )
    return fn(grads), ef_state
