"""The paper's fixed-point compute unit as a Pallas kernel (int16 and int8).

int16/int8 x int16/int8 products accumulated in int32 (TPU-native
accumulator width; the FPGA DSP48 cascade is 48-bit — difference documented
in DESIGN.md §2), then a saturating round-shift write-back onto the output
format's storage rung (Q2.14 int16, Q1.7/Q2.6 int8, ...), exactly matching
``repro.core.quantization.qmatmul_ref`` / ``qtensor_matmul_ref``.  Mixed
operand widths are legal — both sides widen to int32 before the MXU dot —
and an int8-rung ``fmt`` with an int16-grid accumulator shift *is* the
mixed-boundary epilogue (DESIGN.md §11): the layer writes its successor's
grid directly, no float hop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quantization import QFormat, Q2_14, shift_saturate_i32
from repro.core.tiling import MatmulBlock

__all__ = ["matmul_q16_pallas"]


def _qmm_kernel(*refs, shift, bias_shift, raw_min, raw_max, relu, wide,
                out_dtype):
    # refs: (x, w[, bias], out, acc) — bias operand only present when fused.
    if len(refs) == 5:
        x_ref, w_ref, b_ref, o_ref, acc_ref = refs
    else:
        x_ref, w_ref, o_ref, acc_ref = refs
        b_ref = None

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.int32),
        w_ref[...].astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _write_back():
        # bias raw (Qc.fc) aligns onto the accumulator scale 2^(fa+fb) by
        # bias_shift = fa+fb-fc, so the shifted add is bit-identical to
        # adding raw bias post-shift (fused epilogue, DESIGN.md §3/§8).
        acc = acc_ref[...]
        if b_ref is not None:
            acc = acc + (b_ref[...].astype(jnp.int32) << bias_shift)
        if relu:
            acc = jnp.maximum(acc, 0)
        if wide:
            # accumulator read-out (final logits boundary): no requantize —
            # the caller descales by 2^-(fa+fb) exactly, so the head never
            # saturates on logits outside the int16 grid's range.
            o_ref[...] = acc
            return
        o_ref[...] = shift_saturate_i32(acc, shift, raw_min, raw_max,
                                        out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("fmt", "block", "relu", "shift", "bias_shift", "wide", "interpret"),
)
def matmul_q16_pallas(
    xq: jax.Array,
    wq: jax.Array,
    bias: jax.Array | None = None,
    *,
    fmt: QFormat = Q2_14,
    block: MatmulBlock = MatmulBlock(256, 256, 256),
    relu: bool = False,
    shift: int | None = None,
    bias_shift: int | None = None,
    wide: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """xq: (m, k) raw @ wq: (k, n) raw -> (m, n) raw on ``fmt``'s rung.

    Operands are int16 or int8 raws (mixed widths are fine — both widen to
    int32 before the dot) and the output is stored as ``fmt.storage_dtype``.
    ``bias``: (n,) int16/int8 raw, fused into the write-back; ``relu``:
    fused on the int32 accumulator before the saturating shift.  ``shift`` /
    ``bias_shift`` override the write-back scale gaps for mixed-format
    operands (default: same-format semantics, one ``fmt.frac_bits`` each);
    ``wide=True`` returns the raw int32 accumulator (no requantize) for the
    final-layer read-out.
    """
    assert xq.dtype in (jnp.int8, jnp.int16) and wq.dtype in (jnp.int8, jnp.int16)
    m, k = xq.shape
    k2, n = wq.shape
    assert k == k2

    bm, bn, bk = block.bm, block.bn, block.bk
    mp, np_, kp = -(-m // bm) * bm, -(-n // bn) * bn, -(-k // bk) * bk
    if (mp, kp) != (m, k):
        xq = jnp.pad(xq, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        wq = jnp.pad(wq, ((0, kp - k), (0, np_ - n)))
    operands = [xq, wq]
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    if bias is not None:
        operands.append(jnp.pad(bias.astype(jnp.int16), (0, np_ - n)).reshape(1, np_))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))

    kernel = functools.partial(
        _qmm_kernel,
        shift=fmt.frac_bits if shift is None else shift,
        bias_shift=fmt.frac_bits if bias_shift is None else bias_shift,
        raw_min=fmt.raw_min,
        raw_max=fmt.raw_max,
        relu=relu,
        wide=wide,
        out_dtype=fmt.storage_dtype,
    )
    out = pl.pallas_call(
        kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct(
            (mp, np_), jnp.int32 if wide else fmt.storage_dtype
        ),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(*operands)
    return out[:m, :n]
