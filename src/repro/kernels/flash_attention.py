"""Streaming-softmax (flash) attention Pallas kernel — the prefill hot spot.

The attention score/value GEMMs are the dominant non-projection compute at
prefill_32k; this kernel keeps the running-max/denominator online-softmax
state and the output accumulator in VMEM while streaming KV blocks from HBM
(the same ping-pong structure as the matmul unit, applied to attention).

Layout: q/k/v are (BH, S, D) with batch*heads folded into the grid's first
(parallel) axis; GQA is handled in ops.py by folding the q-head group into
the query rows, so KV is never materialized per-q-head.

Grid: (BH, Sq/bq, Sk/bk), kv axis innermost/sequential.  Causal masking
compares global row/col indices; fully-masked kv blocks are skipped via
pl.when (no MXU work, no softmax update).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

_NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, bq, bk, scale, causal, q_offset):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal block skip: the first kv row of this block vs the last q row.
    q_last = q_offset + (qi + 1) * bq - 1
    k_first = ki * bk
    live = (not causal) or (k_first <= q_last)

    @pl.when(live)
    def _update():
        q = q_ref[0]  # (bq, d)
        k = k_ref[0]  # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, bk)
        if causal:
            rows = q_offset + qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(rows >= cols, s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p.astype(v_ref.dtype), v_ref[0], preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _write_back():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "bq", "bk", "interpret", "q_offset")
)
def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    bq: int = 256,
    bk: int = 256,
    q_offset: int = 0,
    interpret: bool = False,
) -> jax.Array:
    """q: (BH, Sq, D), k/v: (BH, Sk, D) -> (BH, Sq, D).

    ``q_offset`` is the global position of q row 0 (for decode-with-cache the
    query sits at the end of the key sequence).
    """
    bh, sq, d = q.shape
    _, sk, _ = k.shape
    bq = min(bq, sq)
    bk = min(bk, sk)
    sqp, skp = -(-sq // bq) * bq, -(-sk // bk) * bk
    if sqp != sq:
        q = jnp.pad(q, ((0, 0), (0, sqp - sq), (0, 0)))
    if skp != sk:
        # padded kv columns are masked off via the causal/row-col comparison
        # only when causal; for non-causal we mask via a length guard below.
        k = jnp.pad(k, ((0, 0), (0, skp - sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, skp - sk), (0, 0)))
        if not causal:
            raise ValueError("non-causal flash kernel requires sk % bk == 0")

    scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(
        _fa_kernel, bq=bq, bk=bk, scale=scale, causal=causal, q_offset=q_offset
    )
    out = pl.pallas_call(
        kernel,
        grid=(bh, sqp // bq, skp // bk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sqp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq, :]
