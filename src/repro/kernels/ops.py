"""jit'd public wrappers around the Pallas kernels, with shape handling,
GQA folding, epilogue fusion, and explicit kernel routes.

These are the entry points the rest of the framework uses; ``ref.py`` holds
the oracles each one is tested against.  Route *selection* (direct conv vs
im2col GEMM, plan-cached DSE blocks) is the execution-plan engine's job
(``core/engine.py``, DESIGN.md §2); these wrappers execute whichever route
they are told.

This module also owns the single im2col implementation in the codebase
(:func:`im2col`) — the GEMM-lowering shared by the im2col conv route on
every backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantization import QFormat, Q2_14
from repro.core.tiling import MatmulBlock, clamp_block

from . import ref
from .conv2d import conv2d_pallas, conv2d_q16_pallas
from .flash_attention import flash_attention_pallas
from .matmul_fp import matmul_fp_pallas
from .matmul_q16 import matmul_q16_pallas

__all__ = [
    "im2col",
    "conv_gemm_weights",
    "matmul_fp",
    "matmul_q16",
    "conv2d",
    "conv2d_q16",
    "flash_attention",
]


# ---------------------------------------------------------------------------
# im2col lowering (the one implementation; paper Fig. 4's conv-as-GEMM)
# ---------------------------------------------------------------------------


def im2col(x: jax.Array, kh: int, kw: int, stride: int = 1):
    """Already-padded NHWC image -> GEMM rows.

    x: (N, H, W, Cin) -> cols (N*Ho*Wo, Cin*Kh*Kw) with features ordered
    (cin, kh, kw) to match :func:`conv_gemm_weights`.  Integer inputs are
    gathered in f32 (exact for int16 magnitudes < 2^24) and cast back, since
    the patch-extraction primitive is float-only.

    Returns (cols, ho, wo).
    """
    n, h, wd, cin = x.shape
    ho = (h - kh) // stride + 1
    wo = (wd - kw) // stride + 1
    cast = None
    xg = x
    if jnp.issubdtype(x.dtype, jnp.integer):
        cast = x.dtype
        xg = x.astype(jnp.float32)
    patches = jax.lax.conv_general_dilated_patches(
        xg.transpose(0, 3, 1, 2),  # NCHW for patch extraction
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding="VALID",
    )  # (N, Cin*Kh*Kw, Ho, Wo), features ordered (cin, kh, kw)
    cols = patches.transpose(0, 2, 3, 1).reshape(n * ho * wo, cin * kh * kw)
    if cast is not None:
        cols = cols.astype(cast)
    return cols, ho, wo


def conv_gemm_weights(w: jax.Array) -> jax.Array:
    """(K, K, Cin, Cout) conv weights -> (Cin*Kh*Kw, Cout) GEMM operand."""
    kh, kw, cin, cout = w.shape
    return w.transpose(2, 0, 1, 3).reshape(cin * kh * kw, cout)


# ---------------------------------------------------------------------------
# GEMM wrappers
# ---------------------------------------------------------------------------


def matmul_fp(
    x: jax.Array,
    w: jax.Array,
    *,
    bias: jax.Array | None = None,
    relu: bool = False,
    qout: QFormat | None = None,
    block: MatmulBlock | None = None,
    interpret: bool = False,
) -> jax.Array:
    m, k = x.shape
    n = w.shape[1]
    block = clamp_block(m, n, k, block or MatmulBlock(256, 256, 256))
    return matmul_fp_pallas(
        x, w, bias, block=block, relu=relu, qout=qout, interpret=interpret
    )


def matmul_q16(
    xq: jax.Array,
    wq: jax.Array,
    *,
    bias: jax.Array | None = None,
    relu: bool = False,
    fmt: QFormat = Q2_14,
    shift: int | None = None,
    bias_shift: int | None = None,
    wide: bool = False,
    block: MatmulBlock | None = None,
    interpret: bool = False,
) -> jax.Array:
    m, k = xq.shape
    n = wq.shape[1]
    block = clamp_block(m, n, k, block or MatmulBlock(256, 256, 256))
    return matmul_q16_pallas(
        xq, wq, bias, fmt=fmt, block=block, relu=relu, shift=shift,
        bias_shift=bias_shift, wide=wide, interpret=interpret
    )


# ---------------------------------------------------------------------------
# conv wrappers (route chosen by the caller / engine)
# ---------------------------------------------------------------------------


def conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    bias: jax.Array | None = None,
    stride: int = 1,
    padding: int = 0,
    tau: int = 128,
    relu: bool = False,
    qout: QFormat | None = None,
    route: str = "direct",
    block: MatmulBlock | None = None,
    tile_rows: int = 0,
    tile_cols: int = 0,
    halo_mode: str = "two_block",
    interpret: bool = False,
) -> jax.Array:
    """NHWC conv on the unified compute unit, float path.

    route == "direct": the direct Pallas conv kernel — taps unrolled over the
    MXU, strided taps read strided slices of the resident image slab, and
    ``tile_rows`` / ``tile_cols`` > 0 tile the output (𝒯, ℭ) with
    halo-aware input fetches (``halo_mode``: blocked two-block reads or
    exact-window manual DMA) so oversized images stay on this route.
    route == "im2col": im2col + the Pallas matmul kernel — same unified-GEMM
    semantics; used when no direct (τ, tile_rows, tile_cols) config fits
    the VMEM budget (DESIGN.md §2).  Epilogue (bias/ReLU/quant) is fused on
    both routes.
    """
    if padding:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    if route == "direct":
        return conv2d_pallas(
            x, w, bias, stride=stride, tau=tau, relu=relu, qout=qout,
            tile_rows=tile_rows, tile_cols=tile_cols, halo_mode=halo_mode,
            interpret=interpret,
        )
    assert route == "im2col", route
    n = x.shape[0]
    kh, kw, _, cout = w.shape
    cols, ho, wo = im2col(x, kh, kw, stride)
    out = matmul_fp(
        cols, conv_gemm_weights(w), bias=bias, relu=relu, qout=qout,
        block=block, interpret=interpret,
    )
    return out.reshape(n, ho, wo, cout)


def conv2d_q16(
    xq: jax.Array,
    wq: jax.Array,
    *,
    bias: jax.Array | None = None,
    stride: int = 1,
    padding: int = 0,
    tau: int = 128,
    relu: bool = False,
    fmt: QFormat = Q2_14,
    shift: int | None = None,
    bias_shift: int | None = None,
    route: str = "direct",
    block: MatmulBlock | None = None,
    tile_rows: int = 0,
    tile_cols: int = 0,
    halo_mode: str = "two_block",
    interpret: bool = False,
) -> jax.Array:
    """NHWC conv, fixed-point path.  All tensors int16 raw Qm.n; ``shift`` /
    ``bias_shift`` carry mixed-format write-back gaps (see matmul_q16)."""
    if padding:
        xq = jnp.pad(xq, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    if route == "direct":
        return conv2d_q16_pallas(
            xq, wq, bias, stride=stride, tau=tau, relu=relu, fmt=fmt,
            shift=shift, bias_shift=bias_shift, tile_rows=tile_rows,
            tile_cols=tile_cols, halo_mode=halo_mode, interpret=interpret,
        )
    assert route == "im2col", route
    n = xq.shape[0]
    kh, kw, _, cout = wq.shape
    cols, ho, wo = im2col(xq, kh, kw, stride)
    out = matmul_q16(
        cols, conv_gemm_weights(wq), bias=bias, relu=relu, fmt=fmt,
        shift=shift, bias_shift=bias_shift, block=block, interpret=interpret,
    )
    return out.reshape(n, ho, wo, cout)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset: int = 0,
    bq: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """GQA-aware attention.  q: (B, Hq, Sq, D), k/v: (B, Hkv, Sk, D).

    The q-head group is folded into the query *rows* (not by repeating KV),
    so each kv head streams its KV exactly once: q is reshaped to
    (B*Hkv, G*Sq, D) with causal masking applied per original row index.
    For G > 1 with causal masks this needs per-row offsets, so we instead
    fold the group into the batch-head axis of q against *shared* kv blocks.
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0
    g = hq // hkv
    # (B*Hkv*G, Sq, D) queries against (B*Hkv, Sk, D) kv, broadcast over G.
    qf = q.reshape(b, hkv, g, sq, d).reshape(b * hkv * g, sq, d)
    kf = jnp.broadcast_to(k[:, :, None], (b, hkv, g, sk, d)).reshape(b * hkv * g, sk, d)
    vf = jnp.broadcast_to(v[:, :, None], (b, hkv, g, sk, d)).reshape(b * hkv * g, sk, d)
    out = flash_attention_pallas(
        qf, kf, vf, causal=causal, q_offset=q_offset, bq=bq, bk=bk, interpret=interpret
    )
    return out.reshape(b, hq, sq, d)
