"""jit'd public wrappers around the Pallas kernels, with shape handling,
GQA folding, and documented fallbacks.

These are the entry points the rest of the framework uses; ``ref.py`` holds
the oracles each one is tested against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantization import QFormat, Q2_14
from repro.core.tiling import MatmulBlock, clamp_block

from . import ref
from .conv2d import conv2d_pallas
from .flash_attention import flash_attention_pallas
from .matmul_fp import matmul_fp_pallas
from .matmul_q16 import matmul_q16_pallas

__all__ = ["matmul_fp", "matmul_q16", "conv2d", "flash_attention"]


def matmul_fp(
    x: jax.Array,
    w: jax.Array,
    *,
    block: MatmulBlock | None = None,
    interpret: bool = False,
) -> jax.Array:
    m, k = x.shape
    n = w.shape[1]
    block = clamp_block(m, n, k, block or MatmulBlock(256, 256, 256))
    return matmul_fp_pallas(x, w, block=block, interpret=interpret)


def matmul_q16(
    xq: jax.Array,
    wq: jax.Array,
    *,
    fmt: QFormat = Q2_14,
    block: MatmulBlock | None = None,
    interpret: bool = False,
) -> jax.Array:
    m, k = xq.shape
    n = wq.shape[1]
    block = clamp_block(m, n, k, block or MatmulBlock(256, 256, 256))
    return matmul_q16_pallas(xq, wq, fmt=fmt, block=block, interpret=interpret)


def conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    padding: int = 0,
    tau: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """NHWC conv on the unified compute unit.

    stride == 1: the direct Pallas conv kernel (taps unrolled over the MXU).
    stride > 1: im2col + the Pallas matmul kernel — same unified-GEMM
    semantics; strided taps are not block-aligned for the direct kernel
    (DESIGN.md §2).
    """
    if padding:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    if stride == 1:
        return conv2d_pallas(x, w, tau=tau, interpret=interpret)
    n, h, wd, cin = x.shape
    kh, kw, _, cout = w.shape
    ho = (h - kh) // stride + 1
    wo = (wd - kw) // stride + 1
    patches = jax.lax.conv_general_dilated_patches(
        x.transpose(0, 3, 1, 2),
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding="VALID",
    )  # (N, Cin*K*K, Ho, Wo)
    cols = patches.transpose(0, 2, 3, 1).reshape(n * ho * wo, cin * kh * kw)
    wmat = w.transpose(2, 0, 1, 3).reshape(cin * kh * kw, cout)
    out = matmul_fp(cols, wmat, interpret=interpret)
    return out.reshape(n, ho, wo, cout)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset: int = 0,
    bq: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """GQA-aware attention.  q: (B, Hq, Sq, D), k/v: (B, Hkv, Sk, D).

    The q-head group is folded into the query *rows* (not by repeating KV),
    so each kv head streams its KV exactly once: q is reshaped to
    (B*Hkv, G*Sq, D) with causal masking applied per original row index.
    For G > 1 with causal masks this needs per-row offsets, so we instead
    fold the group into the batch-head axis of q against *shared* kv blocks.
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0
    g = hq // hkv
    # (B*Hkv*G, Sq, D) queries against (B*Hkv, Sk, D) kv, broadcast over G.
    qf = q.reshape(b, hkv, g, sq, d).reshape(b * hkv * g, sq, d)
    kf = jnp.broadcast_to(k[:, :, None], (b, hkv, g, sk, d)).reshape(b * hkv * g, sk, d)
    vf = jnp.broadcast_to(v[:, :, None], (b, hkv, g, sk, d)).reshape(b * hkv * g, sk, d)
    out = flash_attention_pallas(
        qf, kf, vf, causal=causal, q_offset=q_offset, bq=bq, bk=bk, interpret=interpret
    )
    return out.reshape(b, hq, sq, d)
