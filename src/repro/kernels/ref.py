"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantization import QFormat, Q2_14, qmatmul_ref as _qmatmul_core

__all__ = ["matmul_ref", "matmul_q16_ref", "conv2d_ref", "attention_ref"]


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """f32-accumulated matmul, output in x.dtype."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def matmul_q16_ref(xq: jax.Array, wq: jax.Array, fmt: QFormat = Q2_14) -> jax.Array:
    """int16 raw x int16 raw -> int16 raw (int32 accumulate, saturating shift)."""
    return _qmatmul_core(xq, wq, fmt)


def conv2d_ref(x: jax.Array, w: jax.Array, stride: int = 1, padding: int = 0) -> jax.Array:
    """NHWC conv oracle via lax.conv_general_dilated.

    x: (N,H,W,Cin), w: (K,K,Cin,Cout).
    """
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ).astype(x.dtype)


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    q_offset: int = 0,
) -> jax.Array:
    """Dense softmax attention oracle.  q: (BH, Sq, D), k/v: (BH, Sk, D)."""
    sq, sk = q.shape[1], k.shape[1]
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        rows = q_offset + jnp.arange(sq)[:, None]
        cols = jnp.arange(sk)[None, :]
        s = jnp.where(rows >= cols, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
