"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantization import (
    QFormat,
    Q2_14,
    qmatmul_ref as _qmatmul_core,
    requantize_i32_to_i16,
)

__all__ = [
    "matmul_ref",
    "matmul_fused_ref",
    "matmul_q16_ref",
    "matmul_q16_fused_ref",
    "conv2d_ref",
    "conv2d_fused_ref",
    "conv2d_q16_ref",
    "attention_ref",
]


def _fake_quant(x: jax.Array, fmt: QFormat) -> jax.Array:
    return jnp.clip(jnp.round(x * fmt.scale) / fmt.scale, fmt.min_val, fmt.max_val)


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """f32-accumulated matmul, output in x.dtype."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def matmul_fused_ref(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array | None = None,
    *,
    relu: bool = False,
    qout: QFormat | None = None,
) -> jax.Array:
    """Oracle for the float GEMM with fused epilogue (bias -> ReLU -> quant)."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)
    if relu:
        y = jnp.maximum(y, 0.0)
    if qout is not None:
        y = _fake_quant(y, qout)
    return y.astype(x.dtype)


def matmul_q16_ref(xq: jax.Array, wq: jax.Array, fmt: QFormat = Q2_14) -> jax.Array:
    """int16 raw x int16 raw -> int16 raw (int32 accumulate, saturating shift)."""
    return _qmatmul_core(xq, wq, fmt)


def matmul_q16_fused_ref(
    xq: jax.Array,
    wq: jax.Array,
    bq: jax.Array | None = None,
    *,
    fmt: QFormat = Q2_14,
    relu: bool = False,
) -> jax.Array:
    """Fixed-point GEMM oracle with fused epilogue on the int32 accumulator."""
    acc = jnp.dot(
        xq.astype(jnp.int32), wq.astype(jnp.int32), preferred_element_type=jnp.int32
    )
    if bq is not None:
        acc = acc + (bq.astype(jnp.int32) << fmt.frac_bits)
    if relu:
        acc = jnp.maximum(acc, 0)
    return requantize_i32_to_i16(acc, fmt)


def conv2d_ref(x: jax.Array, w: jax.Array, stride: int = 1, padding: int = 0) -> jax.Array:
    """NHWC conv oracle via lax.conv_general_dilated.

    x: (N,H,W,Cin), w: (K,K,Cin,Cout).
    """
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ).astype(x.dtype)


def conv2d_fused_ref(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array | None = None,
    *,
    stride: int = 1,
    padding: int = 0,
    relu: bool = False,
    qout: QFormat | None = None,
) -> jax.Array:
    """Conv oracle with fused epilogue (bias -> ReLU -> fake-quant)."""
    y = conv2d_ref(x, w, stride=stride, padding=padding).astype(jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)
    if relu:
        y = jnp.maximum(y, 0.0)
    if qout is not None:
        y = _fake_quant(y, qout)
    return y.astype(x.dtype)


def conv2d_q16_ref(
    xq: jax.Array,
    wq: jax.Array,
    bq: jax.Array | None = None,
    *,
    fmt: QFormat = Q2_14,
    stride: int = 1,
    padding: int = 0,
    relu: bool = False,
) -> jax.Array:
    """Fixed-point conv oracle: exact int32 tap-loop accumulation.

    xq: (N,H,W,Cin) int16 raw, wq: (K,K,Cin,Cout) int16 raw -> int16 raw.
    """
    if padding:
        xq = jnp.pad(xq, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    n, h, wd, cin = xq.shape
    kh, kw, _, cout = wq.shape
    ho = (h - kh) // stride + 1
    wo = (wd - kw) // stride + 1
    acc = jnp.zeros((n, ho, wo, cout), jnp.int32)
    for i in range(kh):
        for j in range(kw):
            patch = xq[
                :,
                i : i + stride * (ho - 1) + 1 : stride,
                j : j + stride * (wo - 1) + 1 : stride,
                :,
            ].astype(jnp.int32)
            acc = acc + jnp.einsum(
                "nhwc,cd->nhwd", patch, wq[i, j].astype(jnp.int32)
            )
    if bq is not None:
        acc = acc + (bq.astype(jnp.int32) << fmt.frac_bits)
    if relu:
        acc = jnp.maximum(acc, 0)
    return requantize_i32_to_i16(acc, fmt)


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    q_offset: int = 0,
) -> jax.Array:
    """Dense softmax attention oracle.  q: (BH, Sq, D), k/v: (BH, Sk, D)."""
    sq, sk = q.shape[1], k.shape[1]
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        rows = q_offset + jnp.arange(sq)[:, None]
        cols = jnp.arange(sk)[None, :]
        s = jnp.where(rows >= cols, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
