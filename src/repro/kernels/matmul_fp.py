"""The unified compute unit as a Pallas TPU kernel (float path).

This is the TPU realization of the paper's μ×τ dot-product array: a tiled
matmul where the BlockSpec tile (bm, bn, bk) plays the role of the paper's
loop-tiling factors and Pallas's revolving VMEM windows provide the
ping-pong double buffering (HBM->VMEM copies for grid step i+1 overlap the
MXU work of step i).

Grid layout: (m/bm, n/bn, k/bk) with the reduction axis innermost and marked
"arbitrary" (sequential) so the f32 VMEM scratch accumulator carries across
k-steps; m/n axes are "parallel".

The epilogue (bias add, ReLU, optional output fake-quantization to a Q
format) is fused into the final-k write-back so activations never round-trip
through HBM between the GEMM and the nonlinearity (DESIGN.md §3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quantization import QFormat
from repro.core.tiling import MatmulBlock

__all__ = ["matmul_fp_pallas"]


def _mm_kernel(*refs, relu, qout):
    # refs: (x, w[, bias], out, acc) — the bias operand only exists when the
    # caller fused one, so bias-free GEMMs pay nothing for the epilogue.
    if len(refs) == 5:
        x_ref, w_ref, b_ref, o_ref, acc_ref = refs
    else:
        x_ref, w_ref, o_ref, acc_ref = refs
        b_ref = None

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _write_back():
        acc = acc_ref[...]
        if b_ref is not None:
            acc = acc + b_ref[...].astype(jnp.float32)  # (1, bn) broadcast
        if relu:
            acc = jnp.maximum(acc, 0.0)
        if qout is not None:
            acc = jnp.clip(
                jnp.round(acc * qout.scale) / qout.scale, qout.min_val, qout.max_val
            )
        o_ref[...] = acc.astype(o_ref.dtype)


def _compiler_params():
    # grid axes: (m parallel, n parallel, k sequential/arbitrary)
    params_cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams", None
    )
    if params_cls is None:  # pragma: no cover - very old jax
        return None
    return params_cls(dimension_semantics=("parallel", "parallel", "arbitrary"))


@functools.partial(
    jax.jit, static_argnames=("block", "relu", "qout", "interpret", "out_dtype")
)
def matmul_fp_pallas(
    x: jax.Array,
    w: jax.Array,
    bias: jax.Array | None = None,
    *,
    block: MatmulBlock = MatmulBlock(256, 256, 256),
    relu: bool = False,
    qout: QFormat | None = None,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """x: (m, k) @ w: (k, n) -> (m, n). Pads to block multiples internally.

    ``bias``: (n,) fused into the last-k write-back; ``relu``/``qout``: fused
    nonlinearity and (fake-)quantization, applied after bias.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    out_dtype = out_dtype or x.dtype

    bm, bn, bk = block.bm, block.bn, block.bk
    mp, np_, kp = -(-m // bm) * bm, -(-n // bn) * bn, -(-k // bk) * bk
    if (mp, kp) != (m, k):
        x = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        w = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    operands = [x, w]
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    if bias is not None:
        operands.append(jnp.pad(bias.astype(jnp.float32), (0, np_ - n)).reshape(1, np_))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))

    grid = (mp // bm, np_ // bn, kp // bk)
    kwargs = {}
    cp = _compiler_params()
    if cp is not None and not interpret:
        kwargs["compiler_params"] = cp
    kernel = functools.partial(_mm_kernel, relu=relu, qout=qout)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(*operands)
    return out[:m, :n]
