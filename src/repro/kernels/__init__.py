"""Pallas TPU kernels for the compute hot spots the paper optimizes.

- matmul_fp.py        the unified mu x tau compute unit, float path
- matmul_q16.py       the paper's Q2.14 fixed-point path
- conv2d.py           conv-as-GEMM on the same unit (paper Fig. 4)
- flash_attention.py  streaming-softmax attention (prefill hot spot)
- ops.py              public jit'd wrappers (GQA folding, fallbacks)
- ref.py              pure-jnp oracles

Kernels target TPU (pallas_call + BlockSpec, MXU-aligned tiles) and are
validated with interpret=True on CPU.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
