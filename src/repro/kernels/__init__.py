"""Pallas TPU kernels for the compute hot spots the paper optimizes.

- matmul_fp.py        the unified mu x tau compute unit, float path
- matmul_q16.py       the paper's Q2.14 fixed-point path
- conv2d.py           direct conv (float + q16) on the same unit (paper Fig. 4)
- flash_attention.py  streaming-softmax attention (prefill hot spot)
- ops.py              public jit'd wrappers (im2col, GQA folding, routes)
- ref.py              pure-jnp oracles

All kernels fuse the layer epilogue (bias / ReLU / output quantization) into
the accumulator write-back; route selection between the direct conv kernel
and the im2col GEMM is the execution-plan engine's job (core/engine.py,
DESIGN.md).

Kernels target TPU (pallas_call + BlockSpec, MXU-aligned tiles) and are
validated with interpret=True on CPU.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
