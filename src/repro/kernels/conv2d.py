"""Direct convolution on the unified compute unit, as a Pallas kernel.

The paper's key move is computing conv as vector multiplication on the same
μ×τ unit used for FC layers (Fig. 4): for each spatial position and each of
the K² taps, a μ-wide input-channel vector is dotted with a μ×τ weight slab.

TPU adaptation: instead of one (spatial, tap) position per cycle, each grid
step keeps an (H, W, Cin) image slab in VMEM and runs K² *matmuls* of shape
(Ho·Wo, Cin) x (Cin, τ) — the tap loop is unrolled (K is static) and each tap
is an MXU-shaped GEMM, which is how the μ×τ wave generalizes to a 128×128
systolic array.  Accumulation lives in a f32 VMEM scratch across taps.

Grid: (N, Cout/τ).  Stride-1 only — strided taps need non-block-aligned
windows; strided convs (AlexNet conv1) take the im2col + matmul_fp path in
``ops.conv2d`` (documented fallback, same unified-GEMM semantics).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["conv2d_pallas"]


def _conv_kernel(x_ref, w_ref, o_ref, acc_ref, *, kh, kw, ho, wo):
    # x_ref: (1, H, W, Cin) one padded image; w_ref: (kh*kw*Cin, tau)
    # o_ref: (1, ho, wo, tau); acc_ref: (ho*wo, tau) f32
    acc_ref[...] = jnp.zeros_like(acc_ref)
    cin = x_ref.shape[3]
    for i in range(kh):
        for j in range(kw):
            patch = x_ref[0, i : i + ho, j : j + wo, :]  # (ho, wo, cin)
            lhs = patch.reshape(ho * wo, cin)
            rhs = w_ref[(i * kw + j) * cin : (i * kw + j + 1) * cin, :]
            acc_ref[...] += jnp.dot(lhs, rhs, preferred_element_type=jnp.float32)
    o_ref[...] = acc_ref[...].reshape(1, ho, wo, -1).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tau", "interpret"))
def conv2d_pallas(
    x: jax.Array,
    w: jax.Array,
    *,
    tau: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """NHWC stride-1 VALID conv.  x: (N,H,W,Cin), w: (K,K,Cin,Cout)."""
    n, h, wdt, cin = x.shape
    kh, kw, cin2, cout = w.shape
    assert cin == cin2
    ho, wo = h - kh + 1, wdt - kw + 1
    tau = min(tau, cout)
    coutp = -(-cout // tau) * tau
    if coutp != cout:
        w = jnp.pad(w, ((0, 0), (0, 0), (0, 0), (0, coutp - cout)))
    # (kh*kw*cin, cout) with rows ordered (tap-major, cin-minor) to match the
    # kernel's per-tap row slices.
    wmat = w.reshape(kh * kw * cin, coutp)

    kernel = functools.partial(_conv_kernel, kh=kh, kw=kw, ho=ho, wo=wo)
    out = pl.pallas_call(
        kernel,
        grid=(n, coutp // tau),
        in_specs=[
            pl.BlockSpec((1, h, wdt, cin), lambda b, t: (b, 0, 0, 0)),
            pl.BlockSpec((kh * kw * cin, tau), lambda b, t: (0, t)),
        ],
        out_specs=pl.BlockSpec((1, ho, wo, tau), lambda b, t: (b, 0, 0, t)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, coutp), x.dtype),
        scratch_shapes=[pltpu.VMEM((ho * wo, tau), jnp.float32)],
        interpret=interpret,
    )(x, wmat)
    return out[..., :cout]
