"""Direct convolution on the unified compute unit, as Pallas kernels.

The paper's key move is computing conv as vector multiplication on the same
μ×τ unit used for FC layers (Fig. 4): for each spatial position and each of
the K² taps, a μ-wide input-channel vector is dotted with a μ×τ weight slab.

TPU adaptation: instead of one (spatial, tap) position per cycle, each grid
step keeps an (H, W, Cin) image slab in VMEM and runs K² *matmuls* of shape
(Ho·Wo, Cin) x (Cin, τ) — the tap loop is unrolled (K is static) and each tap
is an MXU-shaped GEMM, which is how the μ×τ wave generalizes to a 128×128
systolic array.  Accumulation lives in a f32/i32 VMEM scratch across taps.

Strided convs (AlexNet conv1) are handled *directly*: each tap reads a
strided slice of the resident image slab (per-tap strided slicing), so the
same kernel covers stride ∈ {1, 2, 4, ...} without falling back to im2col.
The im2col + matmul fallback remains only for layers whose image slab does
not fit the VMEM budget — the routing decision lives in ``core/engine.py``
(DESIGN.md §2).

Both kernels fuse the layer epilogue (bias add, ReLU, and — float path —
output quantization) into the accumulator write-back, so activations never
round-trip through HBM between the GEMM and the nonlinearity (DESIGN.md §3).

Grid: (N, Cout/τ).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quantization import QFormat, Q2_14

__all__ = ["conv2d_pallas", "conv2d_q16_pallas"]


def _tap_patch(img, i, j, ho, wo, stride):
    """(H, W, Cin) slab -> (Ho*Wo, Cin) GEMM rows for tap (i, j).

    Per-tap strided slicing: output position (r, c) reads input pixel
    (i + stride*r, j + stride*c), so tap (i, j)'s rows are a strided window
    of the resident slab.
    """
    patch = img[
        i : i + stride * (ho - 1) + 1 : stride,
        j : j + stride * (wo - 1) + 1 : stride,
        :,
    ]
    return patch.reshape(ho * wo, img.shape[-1])


def _conv_kernel(*refs, kh, kw, ho, wo, stride, relu, qout):
    # refs: x (1, H, W, Cin) one padded image; w (kh*kw*Cin, tau); optional
    # bias (1, tau) — only present when fused; out (1, ho, wo, tau);
    # acc scratch (ho*wo, tau) f32.
    if len(refs) == 5:
        x_ref, w_ref, b_ref, o_ref, acc_ref = refs
    else:
        x_ref, w_ref, o_ref, acc_ref = refs
        b_ref = None
    acc_ref[...] = jnp.zeros_like(acc_ref)
    cin = x_ref.shape[3]
    img = x_ref[0]
    for i in range(kh):
        for j in range(kw):
            lhs = _tap_patch(img, i, j, ho, wo, stride)
            rhs = w_ref[(i * kw + j) * cin : (i * kw + j + 1) * cin, :]
            acc_ref[...] += jnp.dot(lhs, rhs, preferred_element_type=jnp.float32)
    # fused epilogue on the f32 accumulator (DESIGN.md §3)
    acc = acc_ref[...]
    if b_ref is not None:
        acc = acc + b_ref[...].astype(jnp.float32)
    if relu:
        acc = jnp.maximum(acc, 0.0)
    if qout is not None:
        acc = jnp.clip(jnp.round(acc * qout.scale) / qout.scale, qout.min_val, qout.max_val)
    o_ref[...] = acc.reshape(1, ho, wo, -1).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("stride", "tau", "relu", "qout", "interpret")
)
def conv2d_pallas(
    x: jax.Array,
    w: jax.Array,
    bias: jax.Array | None = None,
    *,
    stride: int = 1,
    tau: int = 128,
    relu: bool = False,
    qout: QFormat | None = None,
    interpret: bool = False,
) -> jax.Array:
    """NHWC VALID conv, any stride.  x: (N,H,W,Cin), w: (K,K,Cin,Cout).

    ``bias``: (Cout,) fused into the write-back; ``relu``/``qout``: fused
    nonlinearity and (fake-)quantization to a Q format, applied after bias.
    """
    n, h, wdt, cin = x.shape
    kh, kw, cin2, cout = w.shape
    assert cin == cin2
    ho = (h - kh) // stride + 1
    wo = (wdt - kw) // stride + 1
    tau = min(tau, cout)
    coutp = -(-cout // tau) * tau
    if coutp != cout:
        w = jnp.pad(w, ((0, 0), (0, 0), (0, 0), (0, coutp - cout)))
    # (kh*kw*cin, cout) with rows ordered (tap-major, cin-minor) to match the
    # kernel's per-tap row slices.
    wmat = w.reshape(kh * kw * cin, coutp)
    operands = [x, wmat]
    in_specs = [
        pl.BlockSpec((1, h, wdt, cin), lambda b, t: (b, 0, 0, 0)),
        pl.BlockSpec((kh * kw * cin, tau), lambda b, t: (0, t)),
    ]
    if bias is not None:
        operands.append(
            jnp.pad(bias.astype(jnp.float32), (0, coutp - cout)).reshape(1, coutp)
        )
        in_specs.append(pl.BlockSpec((1, tau), lambda b, t: (0, t)))

    kernel = functools.partial(
        _conv_kernel, kh=kh, kw=kw, ho=ho, wo=wo, stride=stride, relu=relu, qout=qout
    )
    out = pl.pallas_call(
        kernel,
        grid=(n, coutp // tau),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, ho, wo, tau), lambda b, t: (b, 0, 0, t)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, coutp), x.dtype),
        scratch_shapes=[pltpu.VMEM((ho * wo, tau), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return out[..., :cout]


def _conv_q16_kernel(*refs, kh, kw, ho, wo, stride, relu, frac_bits, raw_min, raw_max):
    # Same dataflow as _conv_kernel, fixed point: int16 taps accumulated in
    # int32 (DESIGN.md §2), saturating round-shift write-back to Qm.n.
    if len(refs) == 5:
        x_ref, w_ref, b_ref, o_ref, acc_ref = refs
    else:
        x_ref, w_ref, o_ref, acc_ref = refs
        b_ref = None
    acc_ref[...] = jnp.zeros_like(acc_ref)
    cin = x_ref.shape[3]
    img = x_ref[0]
    for i in range(kh):
        for j in range(kw):
            lhs = _tap_patch(img, i, j, ho, wo, stride).astype(jnp.int32)
            rhs = w_ref[(i * kw + j) * cin : (i * kw + j + 1) * cin, :].astype(jnp.int32)
            acc_ref[...] += jnp.dot(lhs, rhs, preferred_element_type=jnp.int32)
    acc = acc_ref[...]
    if b_ref is not None:
        # bias is Qm.n raw at scale 2^n; the accumulator sits at 2^(2n), so
        # the shifted add is bit-identical to adding raw bias post-shift.
        acc = acc + (b_ref[...].astype(jnp.int32) << frac_bits)
    if relu:
        acc = jnp.maximum(acc, 0)
    rounding = jnp.int32(1 << (frac_bits - 1))
    shifted = (acc + rounding) >> frac_bits
    out = jnp.clip(shifted, raw_min, raw_max).astype(jnp.int16)
    o_ref[...] = out.reshape(1, ho, wo, -1)


@functools.partial(
    jax.jit, static_argnames=("stride", "tau", "relu", "fmt", "interpret")
)
def conv2d_q16_pallas(
    xq: jax.Array,
    wq: jax.Array,
    bias: jax.Array | None = None,
    *,
    stride: int = 1,
    tau: int = 128,
    relu: bool = False,
    fmt: QFormat = Q2_14,
    interpret: bool = False,
) -> jax.Array:
    """Fixed-point NHWC VALID conv, any stride.  All tensors int16 raw Qm.n."""
    assert xq.dtype == jnp.int16 and wq.dtype == jnp.int16
    n, h, wdt, cin = xq.shape
    kh, kw, cin2, cout = wq.shape
    assert cin == cin2
    ho = (h - kh) // stride + 1
    wo = (wdt - kw) // stride + 1
    tau = min(tau, cout)
    coutp = -(-cout // tau) * tau
    if coutp != cout:
        wq = jnp.pad(wq, ((0, 0), (0, 0), (0, 0), (0, coutp - cout)))
    wmat = wq.reshape(kh * kw * cin, coutp)
    operands = [xq, wmat]
    in_specs = [
        pl.BlockSpec((1, h, wdt, cin), lambda b, t: (b, 0, 0, 0)),
        pl.BlockSpec((kh * kw * cin, tau), lambda b, t: (0, t)),
    ]
    if bias is not None:
        operands.append(
            jnp.pad(bias.astype(jnp.int16), (0, coutp - cout)).reshape(1, coutp)
        )
        in_specs.append(pl.BlockSpec((1, tau), lambda b, t: (0, t)))

    kernel = functools.partial(
        _conv_q16_kernel,
        kh=kh,
        kw=kw,
        ho=ho,
        wo=wo,
        stride=stride,
        relu=relu,
        frac_bits=fmt.frac_bits,
        raw_min=fmt.raw_min,
        raw_max=fmt.raw_max,
    )
    out = pl.pallas_call(
        kernel,
        grid=(n, coutp // tau),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, ho, wo, tau), lambda b, t: (b, 0, 0, t)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, coutp), jnp.int16),
        scratch_shapes=[pltpu.VMEM((ho * wo, tau), jnp.int32)],
        interpret=interpret,
    )(*operands)
    return out[..., :cout]
