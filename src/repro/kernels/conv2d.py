"""Direct convolution on the unified compute unit, as Pallas kernels.

The paper's key move is computing conv as vector multiplication on the same
μ×τ unit used for FC layers (Fig. 4): for each spatial position and each of
the K² taps, a μ-wide input-channel vector is dotted with a μ×τ weight slab.

TPU adaptation: instead of one (spatial, tap) position per cycle, each grid
step keeps an image slab in VMEM and runs K² *matmuls* of shape
(rows·Wo, Cin) x (Cin, τ) — the tap loop is unrolled (K is static) and each
tap is an MXU-shaped GEMM, which is how the μ×τ wave generalizes to a 128×128
systolic array.  Accumulation lives in a f32/i32 VMEM scratch across taps.

Strided convs (AlexNet conv1) are handled *directly*: each tap reads a
strided slice of the resident image slab (per-tap strided slicing), so the
same kernel covers stride ∈ {1, 2, 4, ...} without falling back to im2col.

Spatial tiling (the paper's 𝒯/ℭ loop tiles, §III.B): when the whole image
slab exceeds the VMEM budget, ``tile_rows`` adds an output-row tile axis to
the grid.  Each grid step computes ``tile_rows`` output rows from a
``stride·tile_rows``-row input block plus its *successor* block — the second
block supplies the ``kh - stride`` halo rows a tap window reads past the
tile boundary, while both operands stay ordinary blocked BlockSpecs (no
unaligned slicing).  Legality: ``stride·tile_rows ≥ kh`` so one successor
block always covers the halo.  The im2col + matmul fallback remains only for
layers where no (τ, tile_rows) fits the VMEM budget — the routing decision
lives in ``core/engine.py`` (DESIGN.md §2).

Both kernels fuse the layer epilogue (bias add, ReLU, and — float path —
output quantization) into the accumulator write-back, so activations never
round-trip through HBM between the GEMM and the nonlinearity (DESIGN.md §3).

Grid: (N, ceil(Ho/tile_rows), Cout/τ); the middle axis is 1 when untiled.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quantization import QFormat, Q2_14, shift_saturate_i32

__all__ = ["conv2d_pallas", "conv2d_q16_pallas"]


def _tap_patch(img, i, j, rows, wo, stride):
    """Image slab -> (rows*Wo, Cin) GEMM rows for tap (i, j).

    Per-tap strided slicing: output position (r, c) reads input pixel
    (i + stride*r, j + stride*c), so tap (i, j)'s rows are a strided window
    of the resident slab.
    """
    patch = img[
        i : i + stride * (rows - 1) + 1 : stride,
        j : j + stride * (wo - 1) + 1 : stride,
        :,
    ]
    return patch.reshape(rows * wo, img.shape[-1])


def _split_refs(refs, halo, fused_bias):
    """refs -> (x1, x2 | None, w, bias | None, out, acc)."""
    refs = list(refs)
    x1 = refs.pop(0)
    x2 = refs.pop(0) if halo else None
    w = refs.pop(0)
    b = refs.pop(0) if fused_bias else None
    o, acc = refs
    return x1, x2, w, b, o, acc


def _conv_kernel(*refs, kh, kw, th, wo, stride, relu, qout, halo, fused_bias):
    # refs: x1 (1, rows, Wp, Cin) image block; x2 same-shape successor block
    # (halo rows; only when spatially tiled); w (kh*kw*Cin, tau); optional
    # bias (1, tau); out (1, th, wo, tau); acc scratch (th*wo, tau) f32.
    x1_ref, x2_ref, w_ref, b_ref, o_ref, acc_ref = _split_refs(refs, halo, fused_bias)
    acc_ref[...] = jnp.zeros_like(acc_ref)
    cin = x1_ref.shape[3]
    img = x1_ref[0]
    if halo:
        # the tap window of the last output row in this tile reads up to
        # stride*(th-1) + kh - 1 < 2*stride*th rows (stride*th >= kh), so
        # the pair of adjacent row blocks always covers it.
        img = jnp.concatenate([img, x2_ref[0]], axis=0)
    for i in range(kh):
        for j in range(kw):
            lhs = _tap_patch(img, i, j, th, wo, stride)
            rhs = w_ref[(i * kw + j) * cin : (i * kw + j + 1) * cin, :]
            acc_ref[...] += jnp.dot(lhs, rhs, preferred_element_type=jnp.float32)
    # fused epilogue on the f32 accumulator (DESIGN.md §3)
    acc = acc_ref[...]
    if b_ref is not None:
        acc = acc + b_ref[...].astype(jnp.float32)
    if relu:
        acc = jnp.maximum(acc, 0.0)
    if qout is not None:
        acc = jnp.clip(jnp.round(acc * qout.scale) / qout.scale, qout.min_val, qout.max_val)
    o_ref[...] = acc.reshape(1, th, wo, -1).astype(o_ref.dtype)


def _conv_grid(x, kh, stride, ho, tile_rows):
    """Shared grid/BlockSpec geometry for both conv kernels.

    Returns (x, x_specs, grid_tiles, th, halo): ``x`` zero-row-padded so the
    successor halo block of the last tile is always in range, ``th`` output
    rows per grid step.
    """
    n, h, wdt, cin = x.shape
    th = tile_rows if 0 < tile_rows < ho else ho
    tiles = -(-ho // th)
    halo = tiles > 1
    if not halo:
        x_specs = [pl.BlockSpec((1, h, wdt, cin), lambda b, r, t: (b, 0, 0, 0))]
        return x, x_specs, 1, th, False
    row_in = stride * th  # input rows consumed per output-row tile
    if row_in < kh:
        raise ValueError(
            f"tile_rows={th} too small: stride*tile_rows ({row_in}) must cover "
            f"the {kh}-row tap window for the two-block halo scheme"
        )
    # tile r reads blocks r and r+1; the last tile (and its ragged output
    # rows) must see zeros past the real image
    need = (tiles + 1) * row_in
    if need > h:
        x = jnp.pad(x, ((0, 0), (0, need - h), (0, 0), (0, 0)))
    x_specs = [
        pl.BlockSpec((1, row_in, wdt, cin), lambda b, r, t: (b, r, 0, 0)),
        pl.BlockSpec((1, row_in, wdt, cin), lambda b, r, t: (b, r + 1, 0, 0)),
    ]
    return x, x_specs, tiles, th, True


@functools.partial(
    jax.jit,
    static_argnames=("stride", "tau", "relu", "qout", "tile_rows", "interpret"),
)
def conv2d_pallas(
    x: jax.Array,
    w: jax.Array,
    bias: jax.Array | None = None,
    *,
    stride: int = 1,
    tau: int = 128,
    relu: bool = False,
    qout: QFormat | None = None,
    tile_rows: int = 0,
    interpret: bool = False,
) -> jax.Array:
    """NHWC VALID conv, any stride.  x: (N,H,W,Cin), w: (K,K,Cin,Cout).

    ``bias``: (Cout,) fused into the write-back; ``relu``/``qout``: fused
    nonlinearity and (fake-)quantization to a Q format, applied after bias.
    ``tile_rows``: output rows per grid step (0 = whole image untiled); the
    engine picks it so the working set fits VMEM (DESIGN.md §2).
    """
    n, h, wdt, cin = x.shape
    kh, kw, cin2, cout = w.shape
    assert cin == cin2
    ho = (h - kh) // stride + 1
    wo = (wdt - kw) // stride + 1
    tau = min(tau, cout)
    coutp = -(-cout // tau) * tau
    if coutp != cout:
        w = jnp.pad(w, ((0, 0), (0, 0), (0, 0), (0, coutp - cout)))
    # (kh*kw*cin, cout) with rows ordered (tap-major, cin-minor) to match the
    # kernel's per-tap row slices.
    wmat = w.reshape(kh * kw * cin, coutp)
    x, x_specs, tiles, th, halo = _conv_grid(x, kh, stride, ho, tile_rows)
    operands = [x] * (2 if halo else 1) + [wmat]
    in_specs = x_specs + [pl.BlockSpec((kh * kw * cin, tau), lambda b, r, t: (0, t))]
    if bias is not None:
        operands.append(
            jnp.pad(bias.astype(jnp.float32), (0, coutp - cout)).reshape(1, coutp)
        )
        in_specs.append(pl.BlockSpec((1, tau), lambda b, r, t: (0, t)))

    kernel = functools.partial(
        _conv_kernel, kh=kh, kw=kw, th=th, wo=wo, stride=stride, relu=relu,
        qout=qout, halo=halo, fused_bias=bias is not None,
    )
    out = pl.pallas_call(
        kernel,
        grid=(n, tiles, coutp // tau),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, th, wo, tau), lambda b, r, t: (b, r, 0, t)),
        out_shape=jax.ShapeDtypeStruct((n, tiles * th, wo, coutp), x.dtype),
        scratch_shapes=[pltpu.VMEM((th * wo, tau), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return out[:, :ho, :, :cout]


def _conv_q16_kernel(
    *refs, kh, kw, th, wo, stride, relu, shift, bias_shift, raw_min, raw_max,
    halo, fused_bias
):
    # Same dataflow as _conv_kernel, fixed point: int16 taps accumulated in
    # int32 (DESIGN.md §2), saturating round-shift write-back to the output
    # Q format.  ``shift`` = fa+fb-fo for x(Qa.fa) x w(Qb.fb) -> Qm.fo;
    # ``bias_shift`` aligns the raw bias onto the 2^(fa+fb) accumulator.
    x1_ref, x2_ref, w_ref, b_ref, o_ref, acc_ref = _split_refs(refs, halo, fused_bias)
    acc_ref[...] = jnp.zeros_like(acc_ref)
    cin = x1_ref.shape[3]
    img = x1_ref[0]
    if halo:
        img = jnp.concatenate([img, x2_ref[0]], axis=0)
    for i in range(kh):
        for j in range(kw):
            lhs = _tap_patch(img, i, j, th, wo, stride).astype(jnp.int32)
            rhs = w_ref[(i * kw + j) * cin : (i * kw + j + 1) * cin, :].astype(jnp.int32)
            acc_ref[...] += jnp.dot(lhs, rhs, preferred_element_type=jnp.int32)
    acc = acc_ref[...]
    if b_ref is not None:
        acc = acc + (b_ref[...].astype(jnp.int32) << bias_shift)
    if relu:
        acc = jnp.maximum(acc, 0)
    out = shift_saturate_i32(acc, shift, raw_min, raw_max)
    o_ref[...] = out.reshape(1, th, wo, -1)


@functools.partial(
    jax.jit,
    static_argnames=(
        "stride", "tau", "relu", "fmt", "shift", "bias_shift", "tile_rows",
        "interpret",
    ),
)
def conv2d_q16_pallas(
    xq: jax.Array,
    wq: jax.Array,
    bias: jax.Array | None = None,
    *,
    stride: int = 1,
    tau: int = 128,
    relu: bool = False,
    fmt: QFormat = Q2_14,
    shift: int | None = None,
    bias_shift: int | None = None,
    tile_rows: int = 0,
    interpret: bool = False,
) -> jax.Array:
    """Fixed-point NHWC VALID conv, any stride.  All tensors int16 raw Qm.n.

    ``tile_rows`` spatially tiles the output rows exactly as in
    :func:`conv2d_pallas`; zero-padded halo rows contribute zero products, so
    tiled and untiled accumulations are bit-identical.  ``shift`` /
    ``bias_shift`` override the write-back scale gaps for mixed-format
    operands (default: same-format Qm.n semantics).
    """
    assert xq.dtype == jnp.int16 and wq.dtype == jnp.int16
    n, h, wdt, cin = xq.shape
    kh, kw, cin2, cout = wq.shape
    assert cin == cin2
    ho = (h - kh) // stride + 1
    wo = (wdt - kw) // stride + 1
    tau = min(tau, cout)
    coutp = -(-cout // tau) * tau
    if coutp != cout:
        wq = jnp.pad(wq, ((0, 0), (0, 0), (0, 0), (0, coutp - cout)))
    wmat = wq.reshape(kh * kw * cin, coutp)
    xq, x_specs, tiles, th, halo = _conv_grid(xq, kh, stride, ho, tile_rows)
    operands = [xq] * (2 if halo else 1) + [wmat]
    in_specs = x_specs + [pl.BlockSpec((kh * kw * cin, tau), lambda b, r, t: (0, t))]
    if bias is not None:
        operands.append(
            jnp.pad(bias.astype(jnp.int16), (0, coutp - cout)).reshape(1, coutp)
        )
        in_specs.append(pl.BlockSpec((1, tau), lambda b, r, t: (0, t)))

    kernel = functools.partial(
        _conv_q16_kernel,
        kh=kh,
        kw=kw,
        th=th,
        wo=wo,
        stride=stride,
        relu=relu,
        shift=fmt.frac_bits if shift is None else shift,
        bias_shift=fmt.frac_bits if bias_shift is None else bias_shift,
        raw_min=fmt.raw_min,
        raw_max=fmt.raw_max,
        halo=halo,
        fused_bias=bias is not None,
    )
    out = pl.pallas_call(
        kernel,
        grid=(n, tiles, coutp // tau),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, th, wo, tau), lambda b, r, t: (b, r, 0, t)),
        out_shape=jax.ShapeDtypeStruct((n, tiles * th, wo, coutp), jnp.int16),
        scratch_shapes=[pltpu.VMEM((th * wo, tau), jnp.int32)],
        interpret=interpret,
    )(*operands)
    return out[:, :ho, :, :cout]
