"""Direct convolution on the unified compute unit, as Pallas kernels.

The paper's key move is computing conv as vector multiplication on the same
μ×τ unit used for FC layers (Fig. 4): for each spatial position and each of
the K² taps, a μ-wide input-channel vector is dotted with a μ×τ weight slab.

TPU adaptation: instead of one (spatial, tap) position per cycle, each grid
step keeps an image slab in VMEM and runs K² *matmuls* of shape
(rows·Wo, Cin) x (Cin, τ) — the tap loop is unrolled (K is static) and each
tap is an MXU-shaped GEMM, which is how the μ×τ wave generalizes to a 128×128
systolic array.  Accumulation lives in a f32/i32 VMEM scratch across taps.

Strided convs (AlexNet conv1) are handled *directly*: each tap reads a
strided slice of the resident image slab (per-tap strided slicing), so the
same kernel covers stride ∈ {1, 2, 4, ...} without falling back to im2col.

Spatial tiling (the paper's 𝒯/ℭ loop tiles, §III.B): when the whole image
slab exceeds the VMEM budget, ``tile_rows`` adds an output-row tile axis to
the grid, in one of two halo regimes (DESIGN.md §2):

* ``halo_mode="two_block"`` (PR 2, row tiling only): each grid step reads
  the tile's ``stride·tile_rows``-row input block plus its *successor*
  block as ordinary blocked BlockSpecs and concatenates them in-kernel —
  the second block supplies the ``kh - stride`` halo rows a tap window
  reads past the tile boundary.  Legality: ``stride·tile_rows ≥ kh`` so one
  successor block always covers the halo.  Residency tax: ~2× the tile's
  input rows live in VMEM, and every input block streams from HBM twice
  (once as a tile, once as its predecessor's halo).

* ``halo_mode="dma"``: the input stays an unblocked HBM/ANY operand and the
  kernel issues an explicit async copy of *exactly* the window a tile
  reads — ``stride·tile_rows + kh − stride`` input rows (and, when
  ``tile_cols`` also tiles the width, ``stride·tile_cols + kw − stride``
  columns) — into a double-buffered VMEM scratch; the next tile's window
  prefetches while the current one computes.  No successor block, no
  concat copy, no ``stride·tile_rows ≥ kh`` legality bound, and each input
  byte streams from HBM once per τ-way plus the (kh−stride)-row overlap.
  ``tile_cols`` adds the paper's ℭ column-tile axis so extreme-width
  layers tile as (𝒯, ℭ) blocks instead of spilling to im2col.

The im2col + matmul fallback remains only for layers where no
(τ, tile_rows, tile_cols) fits the VMEM budget — the routing decision lives
in ``core/engine.py`` (DESIGN.md §2).

Both kernels fuse the layer epilogue (bias add, ReLU, and — float path —
output quantization) into the accumulator write-back, so activations never
round-trip through HBM between the GEMM and the nonlinearity (DESIGN.md §3).

Grid: (N, ceil(Ho/tile_rows), Cout/τ) for the blocked regimes, with a
ceil(Wo/tile_cols) axis inserted before the τ axis in the DMA regime; tile
axes are 1 when untiled.  τ is the fastest axis so a DMA'd input window is
fetched once and reused by every output-channel way.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quantization import QFormat, Q2_14, shift_saturate_i32

__all__ = ["conv2d_pallas", "conv2d_q16_pallas"]


def _tap_patch(img, i, j, rows, wo, stride):
    """Image slab -> (rows*Wo, Cin) GEMM rows for tap (i, j).

    Per-tap strided slicing: output position (r, c) reads input pixel
    (i + stride*r, j + stride*c), so tap (i, j)'s rows are a strided window
    of the resident slab.
    """
    patch = img[
        i : i + stride * (rows - 1) + 1 : stride,
        j : j + stride * (wo - 1) + 1 : stride,
        :,
    ]
    return patch.reshape(rows * wo, img.shape[-1])


def _split_refs(refs, halo, fused_bias):
    """refs -> (x1, x2 | None, w, bias | None, out, acc)."""
    refs = list(refs)
    x1 = refs.pop(0)
    x2 = refs.pop(0) if halo else None
    w = refs.pop(0)
    b = refs.pop(0) if fused_bias else None
    o, acc = refs
    return x1, x2, w, b, o, acc


def _float_epilogue(acc, b_ref, *, relu, qout):
    """Fused bias/ReLU/fake-quant on the f32 accumulator (DESIGN.md §3)."""
    if b_ref is not None:
        acc = acc + b_ref[...].astype(jnp.float32)
    if relu:
        acc = jnp.maximum(acc, 0.0)
    if qout is not None:
        acc = jnp.clip(jnp.round(acc * qout.scale) / qout.scale, qout.min_val, qout.max_val)
    return acc


def _q16_epilogue(acc, b_ref, *, relu, shift, bias_shift, raw_min, raw_max,
                  out_dtype=jnp.int16):
    """Fused bias/ReLU/saturating-requantize on the i32 accumulator."""
    if b_ref is not None:
        acc = acc + (b_ref[...].astype(jnp.int32) << bias_shift)
    if relu:
        acc = jnp.maximum(acc, 0)
    return shift_saturate_i32(acc, shift, raw_min, raw_max, out_dtype)


def _conv_kernel(*refs, kh, kw, th, wo, stride, relu, qout, halo, fused_bias):
    # refs: x1 (1, rows, Wp, Cin) image block; x2 same-shape successor block
    # (halo rows; only when spatially tiled); w (kh*kw*Cin, tau); optional
    # bias (1, tau); out (1, th, wo, tau); acc scratch (th*wo, tau) f32.
    x1_ref, x2_ref, w_ref, b_ref, o_ref, acc_ref = _split_refs(refs, halo, fused_bias)
    acc_ref[...] = jnp.zeros_like(acc_ref)
    cin = x1_ref.shape[3]
    img = x1_ref[0]
    if halo:
        # the tap window of the last output row in this tile reads up to
        # stride*(th-1) + kh - 1 < 2*stride*th rows (stride*th >= kh), so
        # the pair of adjacent row blocks always covers it.
        img = jnp.concatenate([img, x2_ref[0]], axis=0)
    for i in range(kh):
        for j in range(kw):
            lhs = _tap_patch(img, i, j, th, wo, stride)
            rhs = w_ref[(i * kw + j) * cin : (i * kw + j + 1) * cin, :]
            acc_ref[...] += jnp.dot(lhs, rhs, preferred_element_type=jnp.float32)
    acc = _float_epilogue(acc_ref[...], b_ref, relu=relu, qout=qout)
    o_ref[...] = acc.reshape(1, th, wo, -1).astype(o_ref.dtype)


# ---------------------------------------------------------------------------
# manual-DMA halo regime (double-buffered (𝒯, ℭ) windows)
# ---------------------------------------------------------------------------


def _conv_dma_kernel(*refs, kh, kw, th, tw, stride, fixed_point, epilogue,
                     fused_bias):
    """(𝒯, ℭ)-tiled direct conv with a manual-DMA input halo.

    The input operand lives in HBM (``memory_space=ANY``); each (r, c) tile
    copies exactly its ``stride·th + kh − stride`` × ``stride·tw + kw −
    stride`` input window into one slot of a double-buffered VMEM scratch.
    The copy for tile k+1 is started on tile k's last τ-way, so the fetch
    overlaps the K² tap GEMMs of the current tile (the classic
    prefetch/compute pipeline); the τ axis is innermost, so each window is
    DMA'd once and reused by every output-channel way.
    """
    refs = list(refs)
    x_hbm = refs.pop(0)  # (N, Hp', Wp', Cin), unblocked, HBM-resident
    w_ref = refs.pop(0)
    b_ref = refs.pop(0) if fused_bias else None
    o_ref, xs_ref, sem, acc_ref = refs
    b = pl.program_id(0)
    r = pl.program_id(1)
    c = pl.program_id(2)
    t = pl.program_id(3)
    tiles_c = pl.num_programs(2)
    ways = pl.num_programs(3)
    tile = r * tiles_c + c
    total = pl.num_programs(1) * tiles_c
    rows_in, cols_in, cin = xs_ref.shape[1], xs_ref.shape[2], xs_ref.shape[3]

    def fetch(tile_ix, slot):
        rr = tile_ix // tiles_c
        cc = tile_ix % tiles_c
        return pltpu.make_async_copy(
            x_hbm.at[
                b,
                pl.ds(rr * stride * th, rows_in),
                pl.ds(cc * stride * tw, cols_in),
                :,
            ],
            xs_ref.at[slot],
            sem.at[slot],
        )

    # warm-up: the first tile of each image has no predecessor to prefetch it
    @pl.when((tile == 0) & (t == 0))
    def _():
        fetch(tile, tile % 2).start()

    # wait for this tile's window, once per tile (way 0)
    @pl.when(t == 0)
    def _():
        fetch(tile, tile % 2).wait()

    # prefetch the next tile's window into the other slot while computing
    @pl.when((t == ways - 1) & (tile + 1 < total))
    def _():
        fetch(tile + 1, (tile + 1) % 2).start()

    acc_ref[...] = jnp.zeros_like(acc_ref)
    img = xs_ref[tile % 2]
    for i in range(kh):
        for j in range(kw):
            lhs = _tap_patch(img, i, j, th, tw, stride)
            rhs = w_ref[(i * kw + j) * cin : (i * kw + j + 1) * cin, :]
            if fixed_point:
                acc_ref[...] += jnp.dot(
                    lhs.astype(jnp.int32), rhs.astype(jnp.int32),
                    preferred_element_type=jnp.int32,
                )
            else:
                acc_ref[...] += jnp.dot(lhs, rhs, preferred_element_type=jnp.float32)
    out = epilogue(acc_ref[...], b_ref)
    o_ref[...] = out.reshape(1, th, tw, -1).astype(o_ref.dtype)


def _conv_dma_call(
    x, wmat, bias_row, *, kh, kw, stride, ho, wo, cout, tau, coutp,
    tile_rows, tile_cols, fixed_point, epilogue, out_dtype, acc_dtype,
    interpret,
):
    """Shared pallas_call plumbing for the DMA-halo regime (float + q16).

    Pads x so every tile's DMA window is in-bounds (zero rows/cols past the
    image contribute zero products, so ragged edges stay exact), pads the
    output grid to whole tiles, and slices both back to (Ho, Wo, Cout).
    """
    n, h, wdt, cin = x.shape
    th = tile_rows if 0 < tile_rows < ho else ho
    tw = tile_cols if 0 < tile_cols < wo else wo
    tiles_r = -(-ho // th)
    tiles_c = -(-wo // tw)
    rows_in = stride * th + kh - stride
    cols_in = stride * tw + kw - stride
    need_h = stride * th * (tiles_r - 1) + rows_in
    need_w = stride * tw * (tiles_c - 1) + cols_in
    if need_h > h or need_w > wdt:
        x = jnp.pad(
            x, ((0, 0), (0, max(0, need_h - h)), (0, max(0, need_w - wdt)), (0, 0))
        )
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
        pl.BlockSpec((kh * kw * cin, tau), lambda b, r, c, t: (0, t)),
    ]
    operands = [x, wmat]
    if bias_row is not None:
        operands.append(bias_row)
        in_specs.append(pl.BlockSpec((1, tau), lambda b, r, c, t: (0, t)))
    kernel = functools.partial(
        _conv_dma_kernel, kh=kh, kw=kw, th=th, tw=tw, stride=stride,
        fixed_point=fixed_point, epilogue=epilogue,
        fused_bias=bias_row is not None,
    )
    out = pl.pallas_call(
        kernel,
        grid=(n, tiles_r, tiles_c, coutp // tau),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, th, tw, tau), lambda b, r, c, t: (b, r, c, t)),
        out_shape=jax.ShapeDtypeStruct(
            (n, tiles_r * th, tiles_c * tw, coutp), out_dtype
        ),
        scratch_shapes=[
            pltpu.VMEM((2, rows_in, cols_in, cin), x.dtype),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.VMEM((th * tw, tau), acc_dtype),
        ],
        interpret=interpret,
    )(*operands)
    return out[:, :ho, :wo, :cout]


def _halo_mode_for(tile_rows, tile_cols, ho, wo, halo_mode):
    """Validate/normalize the halo regime for a (tile_rows, tile_cols) pair."""
    row_tiled = 0 < tile_rows < ho
    col_tiled = 0 < tile_cols < wo
    if not (row_tiled or col_tiled):
        return "untiled"
    if col_tiled and halo_mode != "dma":
        raise ValueError(
            f"tile_cols={tile_cols} requires halo_mode='dma' (the two-block "
            f"BlockSpec scheme only tiles output rows), got {halo_mode!r}"
        )
    if halo_mode == "dma":
        return "dma"
    if halo_mode in ("two_block", "none"):
        # "none" is the untiled plans' sentinel; a tiled call with it keeps
        # the legacy two-block behaviour for back-compat
        return "two_block"
    raise ValueError(f"unknown halo_mode {halo_mode!r}")


def _conv_grid(x, kh, stride, ho, tile_rows):
    """Shared grid/BlockSpec geometry for both conv kernels.

    Returns (x, x_specs, grid_tiles, th, halo): ``x`` zero-row-padded so the
    successor halo block of the last tile is always in range, ``th`` output
    rows per grid step.
    """
    n, h, wdt, cin = x.shape
    th = tile_rows if 0 < tile_rows < ho else ho
    tiles = -(-ho // th)
    halo = tiles > 1
    if not halo:
        x_specs = [pl.BlockSpec((1, h, wdt, cin), lambda b, r, t: (b, 0, 0, 0))]
        return x, x_specs, 1, th, False
    row_in = stride * th  # input rows consumed per output-row tile
    if row_in < kh:
        raise ValueError(
            f"tile_rows={th} too small: stride*tile_rows ({row_in}) must cover "
            f"the {kh}-row tap window for the two-block halo scheme"
        )
    # tile r reads blocks r and r+1; the last tile (and its ragged output
    # rows) must see zeros past the real image
    need = (tiles + 1) * row_in
    if need > h:
        x = jnp.pad(x, ((0, 0), (0, need - h), (0, 0), (0, 0)))
    x_specs = [
        pl.BlockSpec((1, row_in, wdt, cin), lambda b, r, t: (b, r, 0, 0)),
        pl.BlockSpec((1, row_in, wdt, cin), lambda b, r, t: (b, r + 1, 0, 0)),
    ]
    return x, x_specs, tiles, th, True


@functools.partial(
    jax.jit,
    static_argnames=(
        "stride", "tau", "relu", "qout", "tile_rows", "tile_cols", "halo_mode",
        "interpret",
    ),
)
def conv2d_pallas(
    x: jax.Array,
    w: jax.Array,
    bias: jax.Array | None = None,
    *,
    stride: int = 1,
    tau: int = 128,
    relu: bool = False,
    qout: QFormat | None = None,
    tile_rows: int = 0,
    tile_cols: int = 0,
    halo_mode: str = "two_block",
    interpret: bool = False,
) -> jax.Array:
    """NHWC VALID conv, any stride.  x: (N,H,W,Cin), w: (K,K,Cin,Cout).

    ``bias``: (Cout,) fused into the write-back; ``relu``/``qout``: fused
    nonlinearity and (fake-)quantization to a Q format, applied after bias.
    ``tile_rows`` / ``tile_cols``: output rows/columns per grid step (0 =
    untiled on that axis); ``halo_mode`` picks the tiled input regime —
    "two_block" (blocked successor reads, rows only) or "dma" (exact-window
    async copies, required for column tiling).  The engine picks all three
    so the working set fits VMEM (DESIGN.md §2).
    """
    n, h, wdt, cin = x.shape
    kh, kw, cin2, cout = w.shape
    assert cin == cin2
    ho = (h - kh) // stride + 1
    wo = (wdt - kw) // stride + 1
    tau = min(tau, cout)
    coutp = -(-cout // tau) * tau
    if coutp != cout:
        w = jnp.pad(w, ((0, 0), (0, 0), (0, 0), (0, coutp - cout)))
    # (kh*kw*cin, cout) with rows ordered (tap-major, cin-minor) to match the
    # kernel's per-tap row slices.
    wmat = w.reshape(kh * kw * cin, coutp)
    if _halo_mode_for(tile_rows, tile_cols, ho, wo, halo_mode) == "dma":
        bias_row = None
        if bias is not None:
            bias_row = jnp.pad(
                bias.astype(jnp.float32), (0, coutp - cout)
            ).reshape(1, coutp)
        return _conv_dma_call(
            x, wmat, bias_row, kh=kh, kw=kw, stride=stride, ho=ho, wo=wo,
            cout=cout, tau=tau, coutp=coutp, tile_rows=tile_rows,
            tile_cols=tile_cols, fixed_point=False,
            epilogue=functools.partial(_float_epilogue, relu=relu, qout=qout),
            out_dtype=x.dtype, acc_dtype=jnp.float32, interpret=interpret,
        )
    x, x_specs, tiles, th, halo = _conv_grid(x, kh, stride, ho, tile_rows)
    operands = [x] * (2 if halo else 1) + [wmat]
    in_specs = x_specs + [pl.BlockSpec((kh * kw * cin, tau), lambda b, r, t: (0, t))]
    if bias is not None:
        operands.append(
            jnp.pad(bias.astype(jnp.float32), (0, coutp - cout)).reshape(1, coutp)
        )
        in_specs.append(pl.BlockSpec((1, tau), lambda b, r, t: (0, t)))

    kernel = functools.partial(
        _conv_kernel, kh=kh, kw=kw, th=th, wo=wo, stride=stride, relu=relu,
        qout=qout, halo=halo, fused_bias=bias is not None,
    )
    out = pl.pallas_call(
        kernel,
        grid=(n, tiles, coutp // tau),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, th, wo, tau), lambda b, r, t: (b, r, 0, t)),
        out_shape=jax.ShapeDtypeStruct((n, tiles * th, wo, coutp), x.dtype),
        scratch_shapes=[pltpu.VMEM((th * wo, tau), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return out[:, :ho, :, :cout]


def _conv_q16_kernel(
    *refs, kh, kw, th, wo, stride, relu, shift, bias_shift, raw_min, raw_max,
    out_dtype, halo, fused_bias
):
    # Same dataflow as _conv_kernel, fixed point: int16/int8 taps accumulated
    # in int32 (DESIGN.md §2), saturating round-shift write-back to the output
    # Q format's storage rung.  ``shift`` = fa+fb-fo for x(Qa.fa) x w(Qb.fb)
    # -> Qm.fo; ``bias_shift`` aligns the raw bias onto the 2^(fa+fb)
    # accumulator.
    x1_ref, x2_ref, w_ref, b_ref, o_ref, acc_ref = _split_refs(refs, halo, fused_bias)
    acc_ref[...] = jnp.zeros_like(acc_ref)
    cin = x1_ref.shape[3]
    img = x1_ref[0]
    if halo:
        img = jnp.concatenate([img, x2_ref[0]], axis=0)
    for i in range(kh):
        for j in range(kw):
            lhs = _tap_patch(img, i, j, th, wo, stride).astype(jnp.int32)
            rhs = w_ref[(i * kw + j) * cin : (i * kw + j + 1) * cin, :].astype(jnp.int32)
            acc_ref[...] += jnp.dot(lhs, rhs, preferred_element_type=jnp.int32)
    out = _q16_epilogue(
        acc_ref[...], b_ref, relu=relu, shift=shift, bias_shift=bias_shift,
        raw_min=raw_min, raw_max=raw_max, out_dtype=out_dtype,
    )
    o_ref[...] = out.reshape(1, th, wo, -1)


@functools.partial(
    jax.jit,
    static_argnames=(
        "stride", "tau", "relu", "fmt", "shift", "bias_shift", "tile_rows",
        "tile_cols", "halo_mode", "interpret",
    ),
)
def conv2d_q16_pallas(
    xq: jax.Array,
    wq: jax.Array,
    bias: jax.Array | None = None,
    *,
    stride: int = 1,
    tau: int = 128,
    relu: bool = False,
    fmt: QFormat = Q2_14,
    shift: int | None = None,
    bias_shift: int | None = None,
    tile_rows: int = 0,
    tile_cols: int = 0,
    halo_mode: str = "two_block",
    interpret: bool = False,
) -> jax.Array:
    """Fixed-point NHWC VALID conv, any stride.  int16/int8 raw Qm.n tensors.

    ``tile_rows`` / ``tile_cols`` / ``halo_mode`` tile the output exactly as
    in :func:`conv2d_pallas`; zero-padded halo rows/columns contribute zero
    products and integer accumulation is order-exact, so every tiling (and
    both halo regimes) is bit-identical to the untiled kernel.  Mixed operand
    widths are legal (both sides widen to int32 before the tap GEMMs) and the
    output is stored on ``fmt.storage_dtype``; ``shift`` / ``bias_shift``
    override the write-back scale gaps for mixed-format operands (default:
    same-format Qm.n semantics) — an int8-rung ``fmt`` with an int16-grid
    ``shift`` is the mixed-boundary epilogue of DESIGN.md §11.
    """
    assert xq.dtype in (jnp.int8, jnp.int16) and wq.dtype in (jnp.int8, jnp.int16)
    n, h, wdt, cin = xq.shape
    kh, kw, cin2, cout = wq.shape
    assert cin == cin2
    ho = (h - kh) // stride + 1
    wo = (wdt - kw) // stride + 1
    tau = min(tau, cout)
    coutp = -(-cout // tau) * tau
    if coutp != cout:
        wq = jnp.pad(wq, ((0, 0), (0, 0), (0, 0), (0, coutp - cout)))
    wmat = wq.reshape(kh * kw * cin, coutp)
    if _halo_mode_for(tile_rows, tile_cols, ho, wo, halo_mode) == "dma":
        bias_row = None
        if bias is not None:
            bias_row = jnp.pad(
                bias.astype(jnp.int16), (0, coutp - cout)
            ).reshape(1, coutp)
        epilogue = functools.partial(
            _q16_epilogue, relu=relu,
            shift=fmt.frac_bits if shift is None else shift,
            bias_shift=fmt.frac_bits if bias_shift is None else bias_shift,
            raw_min=fmt.raw_min, raw_max=fmt.raw_max,
            out_dtype=fmt.storage_dtype,
        )
        return _conv_dma_call(
            xq, wmat, bias_row, kh=kh, kw=kw, stride=stride, ho=ho, wo=wo,
            cout=cout, tau=tau, coutp=coutp, tile_rows=tile_rows,
            tile_cols=tile_cols, fixed_point=True, epilogue=epilogue,
            out_dtype=fmt.storage_dtype, acc_dtype=jnp.int32,
            interpret=interpret,
        )
    xq, x_specs, tiles, th, halo = _conv_grid(xq, kh, stride, ho, tile_rows)
    operands = [xq] * (2 if halo else 1) + [wmat]
    in_specs = x_specs + [pl.BlockSpec((kh * kw * cin, tau), lambda b, r, t: (0, t))]
    if bias is not None:
        operands.append(
            jnp.pad(bias.astype(jnp.int16), (0, coutp - cout)).reshape(1, coutp)
        )
        in_specs.append(pl.BlockSpec((1, tau), lambda b, r, t: (0, t)))

    kernel = functools.partial(
        _conv_q16_kernel,
        kh=kh,
        kw=kw,
        th=th,
        wo=wo,
        stride=stride,
        relu=relu,
        shift=fmt.frac_bits if shift is None else shift,
        bias_shift=fmt.frac_bits if bias_shift is None else bias_shift,
        raw_min=fmt.raw_min,
        raw_max=fmt.raw_max,
        out_dtype=fmt.storage_dtype,
        halo=halo,
        fused_bias=bias is not None,
    )
    out = pl.pallas_call(
        kernel,
        grid=(n, tiles, coutp // tau),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, th, wo, tau), lambda b, r, t: (b, r, 0, t)),
        out_shape=jax.ShapeDtypeStruct(
            (n, tiles * th, wo, coutp), fmt.storage_dtype
        ),
        scratch_shapes=[pltpu.VMEM((th * wo, tau), jnp.int32)],
        interpret=interpret,
    )(*operands)
    return out[:, :ho, :, :cout]
