"""mamba2-1.3b — attention-free SSD (state-space duality).
[arXiv:2405.21060]"""
from .base import ArchConfig, register


@register
def mamba2_1_3b() -> ArchConfig:
    return ArchConfig(
        name="mamba2-1.3b",
        family="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=0,             # attention-free
        n_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab=50280,
        train_accum=2,
        ssm_state=128,
        ssm_expand=2,
        ssm_headdim=64,
        ssm_ngroups=1,
        ssm_conv=4,
        ssm_chunk=256,
        tie_embeddings=True,
        use_rope=False,
        notes="SSD chunked scan; O(1) decode state => long_500k runs",
    )
