"""Architecture registry: importing this package registers every config.

10 assigned architectures (``--arch <id>``) + the paper's own 3 CNNs.
"""
from .base import (
    ArchConfig,
    SHAPES,
    ShapeSpec,
    all_configs,
    get_config,
    reduced,
    register,
    shape_applicable,
)

# importing registers each @register'd config
from . import (  # noqa: F401
    qwen2_5_32b,
    internlm2_1_8b,
    mistral_nemo_12b,
    qwen2_0_5b,
    whisper_medium,
    granite_moe_3b,
    phi3_5_moe,
    recurrentgemma_9b,
    mamba2_1_3b,
    llama3_2_vision_90b,
)

__all__ = [
    "ArchConfig",
    "SHAPES",
    "ShapeSpec",
    "all_configs",
    "get_config",
    "reduced",
    "register",
    "shape_applicable",
]
