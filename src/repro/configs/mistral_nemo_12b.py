"""mistral-nemo-12b — dense GQA, 128k context.
[hf:mistralai/Mistral-Nemo-Base-2407; hf]"""
from .base import ArchConfig, register


@register
def mistral_nemo_12b() -> ArchConfig:
    return ArchConfig(
        name="mistral-nemo-12b",
        family="dense",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        train_accum=2,
        vocab=131072,
        rope_theta=1e6,
        notes="GQA kv=8; attention dim 4096 != d_model; full attention",
    )
