"""llama-3.2-vision-90b — VLM text backbone with gated cross-attention image
layers every 5th layer; vision frontend is a STUB per the assignment
(input_specs supplies 1600 precomputed patch embeddings).
[hf:meta-llama/Llama-3.2-90B-Vision]"""
from .base import ArchConfig, register


@register
def llama3_2_vision_90b() -> ArchConfig:
    return ArchConfig(
        name="llama-3.2-vision-90b",
        family="vlm",
        n_layers=100,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab=128256,
        rope_theta=5e5,
        train_accum=4,  # microbatch 64: 2 seqs/chip on the 512-chip mesh (1/chip degenerates GSPMD reshape merges)
        serve_rule_overrides=(("embed", "data"),),  # 180 GB of weights cannot replicate over data
        cross_attn_period=5,
        n_image_tokens=1600,
        notes="100L = 80 self + 20 gated cross-attn; full attention",
    )
