"""recurrentgemma-9b — hybrid RG-LRU + local attention, pattern 2:1
(two recurrent blocks then one 2048-window attention block).
[arXiv:2402.19427]"""
from .base import ArchConfig, register


@register
def recurrentgemma_9b() -> ArchConfig:
    return ArchConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,          # MQA on the attention blocks
        head_dim=256,
        d_ff=12288,
        vocab=256000,
        train_accum=4,
        pattern=("rec", "rec", "attn"),
        window=2048,
        act="swiglu",
        tie_embeddings=True,
        notes="sub-quadratic (RG-LRU + windowed attn) => long_500k runs",
    )
