"""Architecture configuration schema + registry.

Every assigned architecture is a frozen :class:`ArchConfig`; ``reduced()``
derives the small smoke-test variant of the same family.  Input shapes for
the dry-run matrix live in :data:`SHAPES`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "register", "get_config", "all_configs", "reduced"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    # pad q-heads to this count for TP alignment (0 = no padding).  48/16=3
    # heads per shard compiles head-local attention; 40/16=2.5 forces GSPMD
    # to replicate the whole attention region (see EXPERIMENTS.md §Perf).
    n_heads_padded: int = 0

    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "swiglu"  # swiglu | gelu
    rope_theta: float = 1e4
    use_rope: bool = True
    abs_pos: bool = False  # add sinusoidal absolute positions at the embedding

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_group: int = 512  # token-group size for capacity dispatch (see moe.py)

    # hybrid recurrent width (0 => d_model)
    d_rec: int = 0

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # hybrid (RecurrentGemma): repeating block pattern + local-attn window
    pattern: tuple = ()  # e.g. ("rec", "rec", "attn"); empty = uniform
    window: int = 0  # sliding-window size for "attn" pattern layers

    # encoder-decoder (whisper): n_layers = decoder layers
    n_encoder_layers: int = 0
    n_frames: int = 1500  # precomputed frame embeddings (stub frontend)

    # VLM (llama-3.2-vision): every Nth layer is a gated cross-attn layer
    cross_attn_period: int = 0
    n_image_tokens: int = 1600  # precomputed patch embeddings (stub frontend)

    dtype: str = "bfloat16"
    remat: bool = True
    # gradient-accumulation microbatches for train_4k (activation-memory knob)
    train_accum: int = 1
    # remat policy: "" = save nothing (recompute all); "attn_out" = save the
    # attention sublayer outputs so backward skips the chunked-attention
    # recompute (§Perf iteration 7) at +1 saved (B,S,d) tensor per layer
    remat_policy: str = ""
    notes: str = ""
    # per-arch sharding-rule overrides ((logical_axis, mesh_axes), ...)
    rule_overrides: tuple = ()
    # extra overrides applied only to serving (prefill/decode) cells, e.g.
    # ZeRO-style weight sharding for models whose replicated-over-data
    # params exceed HBM (("embed", "data"),)
    serve_rule_overrides: tuple = ()

    # ---- derived ----
    @property
    def eff_heads(self) -> int:
        return self.n_heads_padded or self.n_heads

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_headdim else 0

    @property
    def attends_full(self) -> bool:
        """True when sequence mixing is quadratic full attention everywhere."""
        if self.family == "ssm":
            return False
        if self.family == "hybrid" and self.window:
            return False
        return True

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        qkv = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim
        attn = qkv + self.n_heads * self.head_dim * d
        if self.act == "swiglu":
            mlp = 3 * d * ff
        else:
            mlp = 2 * d * ff
        if self.family == "moe":
            mlp = self.n_experts * 3 * d * ff + d * self.n_experts  # + router
        per_layer = attn + mlp
        total = self.n_layers * per_layer
        if self.family == "ssm":
            di, ds, g, nh = self.d_inner, self.ssm_state, self.ssm_ngroups, self.ssm_nheads
            in_proj = d * (2 * di + 2 * g * ds + nh)
            out_proj = di * d
            total = self.n_layers * (in_proj + out_proj + self.ssm_conv * (di + 2 * g * ds))
        if self.family == "hybrid" and self.pattern:
            # rec layers replace attn with linear-recurrent block of ~3*d*d
            n_rec = sum(1 for i in range(self.n_layers) if self.pattern[i % len(self.pattern)] == "rec")
            n_att = self.n_layers - n_rec
            rec = 3 * d * d
            total = n_att * (attn + mlp) + n_rec * (rec + mlp)
        if self.family == "encdec":
            enc = self.n_encoder_layers * (attn + mlp)
            dec = self.n_layers * (2 * attn + mlp)  # self + cross
            total = enc + dec
        if self.family == "vlm" and self.cross_attn_period:
            n_cross = self.n_layers // self.cross_attn_period
            total = (self.n_layers - n_cross) * (attn + mlp) + n_cross * (attn + mlp + attn)
        embed = v * d * (1 if self.tie_embeddings else 2)
        return total + embed

    def n_params_active(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.family != "moe" or not self.n_experts:
            return self.n_params()
        d, ff = self.d_model, self.d_ff
        qkv = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim
        attn = qkv + self.n_heads * self.head_dim * d
        mlp_active = self.top_k * 3 * d * ff + d * self.n_experts
        embed = self.vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * (attn + mlp_active) + embed


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        if self.kind == "decode":
            return self.global_batch  # one new token per sequence per step
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


_REGISTRY: dict[str, Callable[[], ArchConfig]] = {}


def register(fn: Callable[[], ArchConfig]) -> Callable[[], ArchConfig]:
    cfg = fn()
    _REGISTRY[cfg.name] = fn
    return fn


def get_config(name: str) -> ArchConfig:
    # import the config modules lazily so the registry is populated
    from repro import configs as _c  # noqa: F401

    return _REGISTRY[name]()


def all_configs() -> dict[str, ArchConfig]:
    from repro import configs as _c  # noqa: F401

    return {k: v() for k, v in _REGISTRY.items()}


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether a dry-run cell runs (DESIGN.md §5 skip rules)."""
    if shape.name == "long_500k" and cfg.attends_full:
        return False, "full quadratic attention: 512k decode skipped per spec"
    return True, ""


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Small same-family variant for CPU smoke tests."""
    period = len(cfg.pattern) if cfg.pattern else 1
    n_layers = max(2, period) if cfg.family != "vlm" else max(2, cfg.cross_attn_period)
    if cfg.family == "vlm":
        n_layers = cfg.cross_attn_period  # one group: (period-1) self + 1 cross
    kv = min(cfg.n_kv_heads, 2)
    heads = max(4, 2 * kv)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        n_heads_padded=0,  # TP-alignment padding is a full-config concern
        train_accum=1,
        d_model=64,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=96 if cfg.family != "moe" else 32,
        vocab=128,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_headdim=16 if cfg.ssm_state else cfg.ssm_headdim,
        n_encoder_layers=2 if cfg.n_encoder_layers else 0,
        n_frames=8 if cfg.n_encoder_layers else cfg.n_frames,
        window=16 if cfg.window else 0,
        n_image_tokens=8 if cfg.family == "vlm" else cfg.n_image_tokens,
        dtype="float32",
        remat=False,
    )
