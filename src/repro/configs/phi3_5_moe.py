"""phi3.5-moe-42b-a6.6b — MoE 16 experts top-2.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from .base import ArchConfig, register


@register
def phi3_5_moe() -> ArchConfig:
    return ArchConfig(
        name="phi3.5-moe-42b-a6.6b",
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=6400,
        vocab=32064,
        train_accum=2,
        serve_rule_overrides=(("embed", "data"),),
        n_experts=16,
        top_k=2,
        norm="layernorm",
        notes="16e top-2; 16 experts divide the 16-way model axis exactly",
    )
