"""qwen2.5-32b — dense GQA, QKV bias.  [hf:Qwen/Qwen2.5-32B; hf]"""
from .base import ArchConfig, register


@register
def qwen2_5_32b() -> ArchConfig:
    return ArchConfig(
        name="qwen2.5-32b",
        family="dense",
        n_layers=64,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=27648,
        vocab=152064,
        qkv_bias=True,
        n_heads_padded=48,   # 40 heads -> 3/shard on 16-way TP (§Perf)
        train_accum=2,
        remat_policy="attn_out",  # skip attention recompute in bwd (§Perf iter 7)
        serve_rule_overrides=(("embed", "data"),),
        rope_theta=1e6,
        notes="GQA kv=8; QKV bias; full attention (long_500k skipped)",
    )
