"""qwen2-0.5b — dense GQA, QKV bias, tied embeddings.  [arXiv:2407.10671; hf]"""
from .base import ArchConfig, register


@register
def qwen2_0_5b() -> ArchConfig:
    return ArchConfig(
        name="qwen2-0.5b",
        family="dense",
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        head_dim=64,
        d_ff=4864,
        vocab=151936,
        qkv_bias=True,
        n_heads_padded=16,   # 14 heads -> 1/shard on 16-way TP (§Perf)
        tie_embeddings=True,
        rope_theta=1e6,
        notes="GQA kv=2; tied embeddings; full attention",
    )
