"""granite-moe-3b-a800m — MoE 40 experts top-8, per-expert d_ff=512.
[hf:ibm-granite/granite-3.0-3b-a800m-base; hf]"""
from .base import ArchConfig, register


@register
def granite_moe_3b() -> ArchConfig:
    return ArchConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        n_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,
        head_dim=64,
        d_ff=512,              # per-expert FFN width
        vocab=49155,
        n_heads_padded=32,   # 24 heads -> 2/shard (§Perf)
        train_accum=4,
        n_experts=40,
        top_k=8,
        tie_embeddings=True,
        notes="40e top-8; 40 does not divide 16-way model, so EP shards the "
              "capacity dim instead (a batch dim of every expert GEMM: all "
              "expert compute is reduction-free; see §Perf cell B)",
        rule_overrides=(("experts", None), ("expert_cap", "model")),
        # serving: shard the (model-replicated under capacity-EP) expert
        # weights over the per-expert FFN dim + ZeRO the rest
        serve_rule_overrides=(("expert_mlp", "model"), ("expert_cap", None),
                              ("embed", "data")),
    )
