"""whisper-medium — encoder-decoder audio backbone; conv/mel frontend is a
STUB per the assignment (input_specs supplies precomputed frame embeddings,
1500 frames = 30 s window after the 2x conv stride).  [arXiv:2212.04356]"""
from .base import ArchConfig, register


@register
def whisper_medium() -> ArchConfig:
    return ArchConfig(
        name="whisper-medium",
        family="encdec",
        n_layers=24,           # decoder layers
        n_encoder_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,         # MHA
        head_dim=64,
        d_ff=4096,
        vocab=51865,
        norm="layernorm",
        act="gelu",
        use_rope=False,
        abs_pos=True,
        n_frames=1500,
        train_accum=2,
        notes="enc-dec; sinusoidal positions; cross-attn every decoder layer",
    )
