"""Batched serving driver: prefill a batch of prompts, then decode tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --prompts 4 \
        --prompt-len 32 --gen 16

Reduced configs run end-to-end on CPU; full configs are exercised by the
dry-run (prefill_32k / decode_32k / long_500k cells compile the exact same
step functions under the production mesh).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.core.engine import PLAN_STORE_ENV, save_plan_store, warm_start_plan_store
from repro.core.template import default_template
from repro.data.pipeline import synthetic_batch
from repro.models import transformer as T


def generate(cfg, params, tokens, ctx=None, *, gen: int = 16, cache_len=None,
             greedy=True, tpl=None):
    """Prefill + autoregressive decode.  tokens: (B, S) prompts."""
    tpl = tpl or default_template()
    b, s = tokens.shape
    cache_len = cache_len or (s + gen)

    prefill = jax.jit(lambda p, tk, cx: T.prefill(tpl, cfg, p, tk, ctx=cx,
                                                  cache_len=cache_len))
    decode = jax.jit(lambda p, tok, t, c: T.decode_step(tpl, cfg, p, tok, t, c))

    logits, cache = prefill(params, tokens, ctx)
    out = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out.append(tok)
    for i in range(gen - 1):
        logits, cache = decode(params, tok, jnp.int32(s + i), cache)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--backend", default="xla", choices=["xla", "pallas", "q16"])
    ap.add_argument("--prompts", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--plan-store", default=None,
                    help=f"persisted plan-store path (default: ${PLAN_STORE_ENV})")
    args = ap.parse_args(argv)

    # Warm-start the plan registry from the persisted store (if any): a
    # restart with a populated store performs zero DSE grid searches.
    store_path, n = warm_start_plan_store(args.plan_store)
    if n:
        print(f"[serve] plan store: warm-started {n} entries from {store_path}")

    cfg = reduced(get_config(args.arch))
    params = T.init_params(jax.random.PRNGKey(args.seed), cfg)
    tokens = synthetic_batch(args.seed, 0, args.prompts, args.prompt_len, cfg.vocab)
    ctx = None
    if cfg.family == "encdec":
        ctx = jax.random.normal(
            jax.random.PRNGKey(1), (args.prompts, cfg.n_frames, cfg.d_model)
        ) * 0.1
    elif cfg.family == "vlm":
        ctx = jax.random.normal(
            jax.random.PRNGKey(1), (args.prompts, cfg.n_image_tokens, cfg.d_model)
        ) * 0.1

    # One template (and thus one execution engine + shared plan cache) for the
    # whole serve session: prefill and every decode step reuse the same plan,
    # so DSE block selection runs at most once per distinct GEMM shape.
    tpl = default_template(args.backend)
    t0 = time.time()
    gen = generate(cfg, params, tokens, ctx, gen=args.gen, tpl=tpl)
    dt = time.time() - t0
    pc = tpl.engine.plan_cache
    st = pc.stats()
    print(f"[serve] arch={cfg.name} backend={args.backend} batch={args.prompts} "
          f"prompt={args.prompt_len} generated={gen.shape[1]} tokens "
          f"in {dt:.2f}s ({args.prompts * args.gen / dt:.1f} tok/s)")
    print(f"[serve] plan registry: {st['gemm_blocks']} GEMM blocks + "
          f"{st['conv_tiles']} conv tiles planned "
          f"({st['measured']} measured), {st['misses']} DSE searches, "
          f"{st['hits']} cache hits")
    if store_path:
        save_plan_store(store_path)
        print(f"[serve] plan store: saved to {store_path}")
    print("[serve] sample generations:")
    for row in gen[: min(2, args.prompts)]:
        print("   ", row.tolist())
    return gen


if __name__ == "__main__":
    main()
