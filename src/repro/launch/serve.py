"""Batched serving driver: prefill a batch of prompts, then decode tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --prompts 4 \
        --prompt-len 32 --gen 16

Reduced configs run end-to-end on CPU; full configs are exercised by the
dry-run (prefill_32k / decode_32k / long_500k cells compile the exact same
step functions under the production mesh).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core.engine import PLAN_STORE_ENV, save_plan_store, warm_start_plan_store
from repro.core.template import default_template
from repro.data.pipeline import synthetic_batch
from repro.launch.scheduler import (
    Request,
    SamplingParams,
    SchedulerConfig,
    ServeScheduler,
    SystemClock,
    compiled_steps,
    replay_trace,
    sampler_fn,
)
from repro.launch.router import ReplicaRouter
from repro.models import transformer as T


def shards_mesh(shards: int):
    """An ("data", "model") mesh with a ``shards``-way model axis over the
    visible devices (1 = no mesh, single-device decode)."""
    if shards <= 1:
        return None
    n = jax.device_count()
    if n % shards:
        raise SystemExit(
            f"--shards {shards} does not divide the {n} visible devices")
    return jax.make_mesh((n // shards, shards), ("data", "model"))


def run_router(cfg, params, tpl, *, replicas: int, mesh=None,
               requests: int, prompt_len: int, gen: int, seed: int,
               policy=None, sampling=None) -> ReplicaRouter:
    """Serve the synthetic request set across N scheduler replicas behind
    the front-tier :class:`ReplicaRouter` (DESIGN.md §9).  Each replica runs
    the same tensor-parallel mesh (or none); tokens drain into the router's
    exactly-once ledger."""
    ladder = tuple(sorted({max(4, prompt_len // 2), prompt_len, 2 * prompt_len}))

    def make_sched(rid, clock):
        return ServeScheduler(
            cfg, params, tpl=tpl, clock=clock, policy=policy,
            sampling=sampling, mesh=mesh,
            sched=SchedulerConfig(ladder=ladder, slots=4,
                                  max_new_limit=max(gen, 1),
                                  max_queue=max(256, requests)),
        )

    router = ReplicaRouter(make_sched, replicas, clock=SystemClock(),
                           tick_dt=0.0)
    rng = np.random.default_rng(seed)
    trace = []
    for _ in range(requests):
        length = int(rng.integers(max(2, prompt_len // 2), 2 * prompt_len + 1))
        prompt = synthetic_batch(seed, len(trace), 1, length, cfg.vocab)
        trace.append(Request(prompt=tuple(int(t) for t in np.asarray(prompt)[0]),
                             max_new=gen))
    router.run(trace)
    return router


def generate(cfg, params, tokens, ctx=None, *, gen: int = 16, cache_len=None,
             greedy=True, tpl=None, policy=None, sampling=None):
    """Prefill + autoregressive decode.  tokens: (B, S) prompts.

    The jitted prefill/decode closures are hoisted into the
    `scheduler.compiled_steps` memo (keyed by template, config, cache_len,
    numerics policy): repeated calls — and the continuous-batching
    scheduler, which shares the memo — reuse one triple of compiled
    callables instead of retracing per call.

    ``policy``: a quantized :class:`NumericsPolicy` runs the whole decode
    loop grid-resident (weights quantized once via the engine's qparam
    cache, int16 KV cache, float only at the designated islands).

    ``sampling``: a :class:`SamplingParams` with temperature > 0 draws each
    token from a per-row RNG lane (lane = batch row, position = the drawn
    token's absolute position); None / temperature <= 0 is exact greedy.
    """
    tpl = tpl or default_template()
    if policy is not None and policy.quantized:
        params = T.quantize_params(tpl, cfg, params, policy)
    b, s = tokens.shape
    cache_len = cache_len or (s + gen)
    fns = compiled_steps(tpl, cfg, cache_len, policy)
    prefill, decode = fns.prefill, fns.decode
    sampled = sampling is not None and not sampling.greedy
    smp = sampler_fn(sampling.temperature, sampling.top_k) if sampled else None
    lanes = jnp.arange(b, dtype=jnp.int32)

    def pick(logits, position):
        if not sampled:
            return jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        toks = smp(logits, jnp.uint32(sampling.seed), lanes,
                   jnp.full((b,), position, jnp.int32))
        return toks[:, None].astype(jnp.int32)

    logits, cache = prefill(params, tokens, ctx, jnp.int32(s - 1))
    out = []
    tok = pick(logits, s)
    out.append(tok)
    for i in range(gen - 1):
        logits, cache = decode(params, tok, jnp.int32(s + i), cache)
        tok = pick(logits, s + i + 1)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def run_scheduler(cfg, params, tpl, *, requests: int, prompt_len: int,
                  gen: int, seed: int, clock=None, policy=None,
                  sampling=None, prefill_chunk: int = 0,
                  mesh=None) -> ServeScheduler:
    """Serve a mixed-length synthetic request set through the
    continuous-batching scheduler (the production path of DESIGN.md §7).

    ``policy`` threads the numerics policy into the scheduler's compiled
    steps — `--backend q16 --scheduler` serves a fully fixed-point decode
    loop instead of silently ignoring the backend.  ``sampling`` selects
    greedy vs per-slot-lane sampled decode; ``prefill_chunk`` > 0 streams
    long prompts in chunks interleaved with decode."""
    ladder = tuple(sorted({max(4, prompt_len // 2), prompt_len, 2 * prompt_len}))
    sched = ServeScheduler(
        cfg, params, tpl=tpl, clock=clock or SystemClock(), policy=policy,
        sampling=sampling, mesh=mesh,
        # this path serves exactly `requests` requests, all arriving at t=0 —
        # the queue must hold the whole burst, rejection is not policy here
        sched=SchedulerConfig(ladder=ladder, slots=4, max_new_limit=max(gen, 1),
                              max_queue=max(256, requests),
                              prefill_chunk=prefill_chunk),
    )
    sched.warmup()
    rng = np.random.default_rng(seed)
    trace = []
    for _ in range(requests):
        length = int(rng.integers(max(2, prompt_len // 2), 2 * prompt_len + 1))
        prompt = synthetic_batch(seed, len(trace), 1, length, cfg.vocab)
        trace.append(Request(prompt=tuple(int(t) for t in np.asarray(prompt)[0]),
                             max_new=gen))
    replay_trace(sched, trace, tick=0.0)
    return sched


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--backend", default="xla",
                    choices=["xla", "pallas", "q16", "q8"])
    ap.add_argument("--precision-budget", type=float, default=0.99,
                    help="with --backend q8: minimum per-layer solo-flip "
                         "argmax agreement for the precision DSE to drop a "
                         "layer group to the int8 rung (DESIGN.md §11)")
    ap.add_argument("--prompts", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0,
                    help="seeds the synthetic prompts AND the sampled-decode "
                         "RNG lanes (reproducible per seed)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampled decode temperature; 0 = exact greedy "
                         "argmax (the byte-parity default)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="restrict sampled decode to the k highest logits "
                         "(0 = full softmax)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="with --scheduler: stream prompts longer than this "
                         "into their slot in fixed-width chunks interleaved "
                         "with decode (0 = whole-bucket prefill)")
    ap.add_argument("--scheduler", action="store_true",
                    help="serve through the continuous-batching scheduler "
                         "(mixed-length requests, bucketed prefill, coalesced "
                         "decode; DESIGN.md §7)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="with --scheduler: route requests across N "
                         "data-parallel scheduler replicas behind the "
                         "front-tier ReplicaRouter (DESIGN.md §9)")
    ap.add_argument("--shards", type=int, default=1,
                    help="with --scheduler: run each replica's decode step "
                         "tensor-parallel over an N-way model axis "
                         "(bitwise-equal to single-device; DESIGN.md §9)")
    ap.add_argument("--plan-store", default=None,
                    help=f"persisted plan-store path (default: ${PLAN_STORE_ENV})")
    args = ap.parse_args(argv)

    # Warm-start the plan registry from the persisted store (if any): a
    # restart with a populated store performs zero DSE grid searches.
    store_path, n = warm_start_plan_store(args.plan_store)
    if n:
        print(f"[serve] plan store: warm-started {n} entries from {store_path}")

    cfg = reduced(get_config(args.arch))
    params = T.init_params(jax.random.PRNGKey(args.seed), cfg)

    # One template (and thus one execution engine + shared plan cache) for the
    # whole serve session: prefill and every decode step reuse the same plan,
    # so DSE block selection runs at most once per distinct GEMM shape.
    # --backend q8 is the mixed-precision tier of the same q16 template: the
    # kernels are dtype-polymorphic, so the template backend stays "q16" and
    # the precision DSE decides per layer group which grid it runs on.
    backend = "q16" if args.backend == "q8" else args.backend
    tpl = default_template(backend)
    # --backend q16 serves grid-resident fixed point (DESIGN.md §8): weights
    # quantized once, int16 KV cache, activation grid picked by a small
    # max-abs calibration pass over one synthetic batch.
    policy = None
    if backend == "q16":
        cal = synthetic_batch(args.seed + 1, 7, 2, max(args.prompt_len, 8),
                              cfg.vocab)
        try:
            policy = T.calibrate_policy(tpl, cfg, params, cal)
        except ValueError as err:
            if args.scheduler:  # the batched path must not silently degrade
                raise SystemExit(f"--backend {args.backend} --scheduler: "
                                 f"{err}") from err
            print(f"[serve] WARNING: {err}; falling back to per-op q16 "
                  f"(float round-trips between layers)")
        else:
            if args.backend == "q8":
                # the drift-aware precision DSE (DESIGN.md §11): measure each
                # group's solo-flip argmax drift, drop groups meeting the
                # budget to the int8 rung, pin every choice in the registry
                # (warm restarts replay the pins with zero searches)
                policy = T.calibrate_precision(
                    tpl, cfg, params, cal, budget=args.precision_budget,
                    policy=policy)
                n8 = sum(1 for _, f in policy.layer_fmts if f.total_bits == 8)
                print(f"[serve] numerics: mixed int8/int16 grid-resident, "
                      f"base {policy.fmt.name}, {n8}/"
                      f"{len(policy.layer_fmts)} groups on the int8 rung "
                      f"(budget {args.precision_budget})")
            else:
                print(f"[serve] numerics: q16 grid-resident, activations "
                      f"{policy.fmt.name} (calibrated), weights per-tensor")
    sampling = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                              seed=args.seed)
    if not sampling.greedy:
        print(f"[serve] sampling: temperature={sampling.temperature} "
              f"top_k={sampling.top_k} seed={sampling.seed} "
              f"(per-lane RNG, reproducible per seed)")
    t0 = time.time()
    if args.scheduler and args.replicas > 1:
        try:
            router = run_router(cfg, params, tpl, replicas=args.replicas,
                                mesh=shards_mesh(args.shards),
                                requests=args.prompts,
                                prompt_len=args.prompt_len, gen=args.gen,
                                seed=args.seed, policy=policy,
                                sampling=sampling)
        except ValueError as err:
            raise SystemExit(f"--replicas: {err}") from err
        dt = time.time() - t0
        ledger = router.ledger.as_dict()
        n_tok = sum(len(s) for s in ledger.values())
        print(f"[serve] arch={cfg.name} backend={args.backend} "
              f"router replicas={args.replicas} shards={args.shards} "
              f"requests={args.prompts} generated={n_tok} tokens "
              f"in {dt:.2f}s ({n_tok / dt:.1f} tok/s)")
        print(f"[serve] {router.stats_line()}")
        gen = [ledger[r] for r in sorted(ledger)]
    elif args.scheduler:
        try:
            sched = run_scheduler(cfg, params, tpl, requests=args.prompts,
                                  prompt_len=args.prompt_len, gen=args.gen,
                                  seed=args.seed, policy=policy,
                                  sampling=sampling,
                                  prefill_chunk=args.prefill_chunk,
                                  mesh=shards_mesh(args.shards))
        except ValueError as err:  # admission policy lives in ServeScheduler
            raise SystemExit(f"--scheduler: {err}") from err
        dt = time.time() - t0
        n_tok = sched.counters["tokens"]
        print(f"[serve] arch={cfg.name} backend={args.backend} "
              f"scheduler requests={args.prompts} generated={n_tok} tokens "
              f"in {dt:.2f}s ({n_tok / dt:.1f} tok/s)")
        print(f"[serve] {sched.stats_line()}")
        gen = [sched.results[r].generated for r in sorted(sched.results)]
    else:
        tokens = synthetic_batch(args.seed, 0, args.prompts, args.prompt_len,
                                 cfg.vocab)
        ctx = None
        if cfg.family == "encdec":
            ctx = jax.random.normal(
                jax.random.PRNGKey(1), (args.prompts, cfg.n_frames, cfg.d_model)
            ) * 0.1
        elif cfg.family == "vlm":
            ctx = jax.random.normal(
                jax.random.PRNGKey(1), (args.prompts, cfg.n_image_tokens, cfg.d_model)
            ) * 0.1
        gen = generate(cfg, params, tokens, ctx, gen=args.gen, tpl=tpl,
                       policy=policy, sampling=sampling)
        dt = time.time() - t0
        print(f"[serve] arch={cfg.name} backend={args.backend} batch={args.prompts} "
              f"prompt={args.prompt_len} generated={gen.shape[1]} tokens "
              f"in {dt:.2f}s ({args.prompts * args.gen / dt:.1f} tok/s)")
    st = tpl.engine.plan_cache.stats()
    print(f"[serve] plan registry: {st['gemm_blocks']} GEMM blocks + "
          f"{st['conv_tiles']} conv tiles planned "
          f"({st['measured']} measured), {st['misses']} DSE searches, "
          f"{st['hits']} cache hits")
    if store_path:
        save_plan_store(store_path)
        print(f"[serve] plan store: saved to {store_path}")
    print("[serve] sample generations:")
    for row in gen[: min(2, len(gen))]:
        print("   ", list(np.asarray(row).tolist()))
    return gen


if __name__ == "__main__":
    main()
