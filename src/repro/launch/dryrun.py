import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# The two lines above MUST run before any jax import (jax locks the device
# count at first init).  Everything below is ordinary code.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell and record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k --mesh multi

For each cell this builds the real step function (train_step for train
shapes; prefill/decode serve steps otherwise), the NamedSharding trees from
the logical-axis rules, lowers with ShapeDtypeStruct stand-ins (no
allocation), compiles under the production mesh, and writes a JSON record
(FLOPs, bytes, per-collective wire bytes, per-device memory) consumed by
benchmarks/roofline_report.py.

A compile failure here (sharding mismatch, OOM at compile, unsupported
collective) is a bug in the framework — the run exits nonzero.
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import SHAPES, all_configs, get_config, shape_applicable
from repro.core.hlo_analysis import analyze_hlo
from repro.core.tiling import TPU_V5E
from repro.launch.mesh import make_production_mesh, mesh_chips, mesh_name
from repro.launch.steps import step_and_specs
from repro.parallel.sharding import SERVE_RULES, TRAIN_RULES, use_mesh

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")


def rules_for(kind: str, cfg=None, overrides: dict | None = None):
    rules = TRAIN_RULES if kind == "train" else SERVE_RULES
    if cfg is not None and cfg.rule_overrides:
        rules = rules.with_overrides(**dict(cfg.rule_overrides))
    if cfg is not None and kind != "train" and cfg.serve_rule_overrides:
        rules = rules.with_overrides(**dict(cfg.serve_rule_overrides))
    if overrides:
        rules = rules.with_overrides(**overrides)
    return rules


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             accum: int = 1, rule_overrides: dict | None = None,
             tag: str = "", pad_heads: int = 0,
             remat_policy: str | None = None) -> dict:
    cfg = get_config(arch)
    import dataclasses as _dc
    if pad_heads:
        cfg = _dc.replace(cfg, n_heads_padded=pad_heads)
    if remat_policy is not None:
        cfg = _dc.replace(cfg, remat_policy=remat_policy)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(shape.kind, cfg, rule_overrides)
    if accum == 0:
        accum = cfg.train_accum if shape.kind == "train" else 1
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name(mesh),
        "chips": mesh_chips(mesh),
        "kind": shape.kind,
        "accum": accum,
        "tag": tag,
    }
    t0 = time.time()
    with use_mesh(mesh, rules):
        cell = step_and_specs(cfg, shape, mesh, rules, accum=accum)
        jitted = jax.jit(
            cell.step_fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate_argnums,
        )
        lowered = jitted.lower(*cell.args)
        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()

    record["lower_s"] = round(t_lower - t0, 2)
    record["compile_s"] = round(t_compile - t_lower, 2)
    # ---- memory (proves it fits) ----
    memd = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            memd[k] = int(v)
    args_b = memd.get("argument_size_in_bytes", 0)
    alias_b = memd.get("alias_size_in_bytes", 0)
    out_b = memd.get("output_size_in_bytes", 0)
    tmp_b = memd.get("temp_size_in_bytes", 0)
    memd["per_device_total_bytes"] = args_b + tmp_b + max(out_b - alias_b, 0)
    record["memory"] = memd

    # ---- cost (FLOPs / bytes for the roofline) ----
    cost = dict(cost or {})
    record["cost"] = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "transcendentals": float(cost.get("transcendentals", 0.0)),
    }

    # ---- trip-count-aware HLO analysis (flops/bytes/collectives) ----
    # XLA's cost_analysis counts while bodies once; analyze_hlo multiplies by
    # known_trip_count so scanned layers are attributed correctly.
    st = analyze_hlo(hlo, total_devices=record["chips"])
    record["hlo"] = {
        "flops": st.flops,
        "bytes": st.bytes,
        "wire_bytes": st.wire_bytes,
        "coll_counts": st.coll_counts,
        "coll_static_counts": st.coll_static_counts,
        "coll_bytes": {k: round(v) for k, v in st.coll_bytes.items()},
        "top_dots": st.top_dots,
        "top_colls": st.top_colls,
    }
    record["hlo_lines"] = hlo.count("\n")

    # ---- roofline terms ----
    spec = TPU_V5E
    flops = st.flops
    byts = st.bytes
    record["roofline"] = {
        "compute_s": flops / spec.peak_bf16_flops,
        "memory_s": byts / spec.hbm_bw,
        "collective_s": st.wire_bytes / spec.ici_bw,
    }
    terms = record["roofline"]
    record["roofline"]["dominant"] = max(terms, key=lambda k: terms[k])
    n_active = cfg.n_params_active()
    tokens = shape.tokens
    mf = (6.0 if shape.kind == "train" else 2.0) * n_active * tokens
    total_flops = flops * record["chips"]
    record["model_flops"] = mf
    record["useful_ratio"] = mf / total_flops if total_flops else 0.0
    bound = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    record["roofline_fraction"] = (
        (terms["compute_s"] / bound) * record["useful_ratio"] if bound else 0.0
    )

    os.makedirs(out_dir, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    fname = f"{arch.replace('/', '_')}_{shape_name}_{record['mesh']}{suffix}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(record, f, indent=1)
    return record


def iter_cells(archs, shapes):
    for arch in archs:
        cfg = get_config(arch)
        for shape_name in shapes:
            yield arch, shape_name, shape_applicable(cfg, SHAPES[shape_name])


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="architecture id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--out", default=os.environ.get("DRYRUN_OUT", "experiments/dryrun"))
    ap.add_argument("--accum", type=int, default=0,
                    help="gradient accumulation (0 = per-arch default)")
    ap.add_argument("--pad-heads", type=int, default=0,
                    help="pad q-heads to this count for TP alignment")
    ap.add_argument("--remat-policy", default=None,
                    help="override cfg.remat_policy (e.g. attn_out)")
    ap.add_argument("--tag", default="", help="suffix for experiment variants")
    ap.add_argument("--override", action="append", default=[],
                    help="sharding rule override logical=axis (axis may be "
                         "'none' or comma-joined mesh axes)")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else sorted(all_configs())
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    overrides = {}
    for ov in args.override:
        k, _, v = ov.partition("=")
        if v.lower() in ("none", ""):
            overrides[k] = None
        elif "," in v:
            overrides[k] = tuple(v.split(","))
        else:
            overrides[k] = v

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                mesh_label = "2x16x16" if multi else "16x16"
                head = f"[{arch} x {shape_name} x {mesh_label}]"
                try:
                    rec = run_cell(arch, shape_name, multi, args.out,
                                   accum=args.accum,
                                   rule_overrides=overrides or None,
                                   tag=args.tag, pad_heads=args.pad_heads,
                                   remat_policy=args.remat_policy)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    failures.append((arch, shape_name, mesh_label, repr(e)))
                    print(f"{head} FAILED: {e}", flush=True)
                    continue
                if "skipped" in rec:
                    print(f"{head} SKIP: {rec['skipped']}", flush=True)
                    continue
                r = rec["roofline"]
                print(
                    f"{head} ok kind={rec['kind']} "
                    f"compile={rec['compile_s']}s "
                    f"mem/dev={rec['memory'].get('per_device_total_bytes', 0)/2**30:.2f}GiB "
                    f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
                    f"collective={r['collective_s']:.3e}s dominant={r['dominant']} "
                    f"useful={rec['useful_ratio']:.2f} "
                    f"roofline_frac={rec['roofline_fraction']:.3f}",
                    flush=True,
                )
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", *f)
        sys.exit(1)
    print("\nall requested dry-run cells compiled OK")


if __name__ == "__main__":
    main()
