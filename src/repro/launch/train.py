"""End-to-end fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --steps 50 \
        --reduced --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On this CPU container the driver runs *reduced* configs on the host device;
on a real cluster the same code runs the full config under
``make_production_mesh()`` (pass ``--mesh single|multi``).  Features:

  * deterministic restart-safe data pipeline (pure function of step)
  * atomic checkpoints + auto-resume (elastic across mesh changes)
  * crash-loop restarts with injected failures (``--fail-at``)
  * optional int8 gradient compression with error feedback (``--compress``)
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import SHAPES, get_config, reduced
from repro.core.engine import (
    PLAN_STORE_ENV,
    plan_store_stats,
    save_plan_store,
    warm_start_plan_store,
)
from repro.data import make_pipeline
from repro.launch.steps import (
    default_optimizer,
    make_train_step,
    state_shardings,
)
from repro.models import transformer as T
from repro.optim import adamw_init
from repro.parallel.sharding import TRAIN_RULES, use_mesh
from repro.runtime import FailureInjector, run_with_restarts


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--mesh", choices=["none", "single", "multi"], default="none")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--fail-at", type=int, action="append", default=[],
                    help="inject a failure at this step (repeatable)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--plan-store", default=None,
                    help=f"persisted plan-store path (default: ${PLAN_STORE_ENV})")
    args = ap.parse_args(argv)

    store_path, n = warm_start_plan_store(args.plan_store)
    if n:
        print(f"[train] plan store: warm-started {n} entries from {store_path}")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if cfg.rule_overrides:
        rules = TRAIN_RULES.with_overrides(**dict(cfg.rule_overrides))
    else:
        rules = TRAIN_RULES

    mesh = None
    if args.mesh != "none":
        from repro.launch.mesh import make_production_mesh

        mesh = make_production_mesh(multi_pod=args.mesh == "multi")

    from repro.optim import AdamW, cosine_warmup

    opt = AdamW(lr=cosine_warmup(args.lr, max(args.steps // 10, 1), args.steps))
    train_step = make_train_step(cfg, opt=opt, accum=args.accum)
    pipe = make_pipeline(
        cfg, SHAPES["train_4k"], seed=args.seed,
        mesh=mesh, rules=rules if mesh else None,
        global_batch=args.batch, seq_len=args.seq,
    )

    def build_state():
        params = T.init_params(jax.random.PRNGKey(args.seed), cfg)
        return params, adamw_init(params)

    ckpt = CheckpointManager(args.ckpt_dir, keep=3)
    injector = FailureInjector(fail_at_steps=args.fail_at)

    state = {}

    def restore_fn() -> int:
        params, opt_state = build_state()
        step = ckpt.latest()
        if step is None:
            state["params"], state["opt"] = params, opt_state
            return 0
        shardings = None
        if mesh is not None:
            p_sh, o_sh = state_shardings(cfg, mesh, rules)
            shardings = {"params": p_sh, "opt": o_sh}
        tree = {"params": params, "opt": opt_state}
        from repro.checkpoint import restore

        loaded = restore(args.ckpt_dir, step, tree, shardings)
        state["params"], state["opt"] = loaded["params"], loaded["opt"]
        print(f"[train] resumed from checkpoint step {step}")
        return step

    jit_step = jax.jit(train_step, donate_argnums=(0, 1))
    history = []

    def step_fn(step: int):
        injector.check(step)
        batch = pipe.batch(step)
        t0 = time.time()
        state["params"], state["opt"], metrics = jit_step(
            state["params"], state["opt"], batch
        )
        loss = float(metrics["loss"])
        history.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(
                f"[train] step {step:4d} loss {loss:8.4f} "
                f"gnorm {float(metrics['grad_norm']):8.3f} "
                f"lr {float(metrics['lr']):.2e} "
                f"({time.time() - t0:.2f}s)",
                flush=True,
            )

    def save_fn(step: int):
        ckpt.save(step, {"params": state["params"], "opt": state["opt"]},
                  extra={"arch": cfg.name})

    ctx = use_mesh(mesh, rules) if mesh is not None else _null_ctx()
    with ctx:
        stats = run_with_restarts(
            num_steps=args.steps,
            step_fn=step_fn,
            save_fn=save_fn,
            restore_fn=restore_fn,
            checkpoint_every=args.ckpt_every,
            max_failures=max(len(args.fail_at), 1),
        )
    first, last = history[0], sum(history[-5:]) / max(len(history[-5:]), 1)
    print(
        f"[train] done: {stats['steps']} steps, {stats['failures']} failures, "
        f"restarts at {stats['restarts']}, loss {first:.4f} -> {last:.4f}"
    )
    pst = plan_store_stats()
    print(f"[train] plan registry: {pst['gemm_blocks']} GEMM blocks + "
          f"{pst['conv_tiles']} conv tiles, {pst['misses']} DSE searches")
    if store_path:
        save_plan_store(store_path)
        print(f"[train] plan store: saved to {store_path}")
    return stats, history


class _null_ctx:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
