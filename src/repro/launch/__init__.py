"""Launchers: production mesh, multi-pod dry-run, training + serving drivers,
and the continuous-batching serve scheduler (DESIGN.md §7)."""
