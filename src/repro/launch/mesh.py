"""Production meshes.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* the first
jax device query, and smoke tests must keep seeing one device.

Topology (TPU v5e):
  * single pod: (16, 16)  axes ("data", "model")          = 256 chips
  * multi-pod:  (2, 16, 16) axes ("pod", "data", "model") = 512 chips

"model" maps to the intra-pod ICI dimension with the densest wiring (TP and
EP collectives are latency-bound); "data"/"pod" carry the FSDP/DP collectives
(bandwidth-bound all-gather / reduce-scatter, DCN-tolerant across pods).
"""
from __future__ import annotations

import jax

__all__ = [
    "make_production_mesh",
    "make_test_mesh",
    "mesh_name",
    "mesh_chips",
    "gemm_partition",
]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(*, multi_pod: bool = False):
    """Shrunken topology for CI-scale dry-run tests (8 host devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_name(mesh) -> str:
    return "x".join(str(mesh.shape[a]) for a in mesh.axis_names)


def mesh_chips(mesh) -> int:
    n = 1
    for a in mesh.axis_names:
        n *= mesh.shape[a]
    return n


def gemm_partition(mesh):
    """The canonical GEMM sharding on this mesh: M over the data-ish axes
    ("pod", "data"), N over "model", K unsharded.

    This is the default partition ``Engine.plan_gemm``/``plan_conv`` use to
    derive local per-shard shapes when given a mesh without an explicit
    PartitionSpec (DESIGN.md §6).
    """
    from jax.sharding import PartitionSpec as P

    data = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    model = "model" if "model" in mesh.axis_names else None
    if len(data) == 1:
        data = data[0]
    return P(data or None, model)
