"""Continuous-batching serve scheduler over the execution-plan engine.

The paper's template sustains throughput only while the single on-chip
compute unit is fed uniformly-shaped work; the serving analogue is a
scheduler that quantizes *traffic* into the handful of GEMM shapes the
PlanRegistry already holds plans for (ROADMAP "Serving batch scheduler";
DESIGN.md §7):

* **Bucket ladder** — every prefill is right-padded up to the smallest
  ladder rung >= its prompt length (`core/engine.py:bucket_for`).  Under
  causal attention the padding cannot influence logits at real positions, so
  a bucket costs only wasted FLOPs, never accuracy; each rung is one fixed
  prefill shape, planned once (warmup) and a registry hit forever after.
* **Slot-indexed continuous batching** — decode requests from different
  sessions are coalesced into ONE batched decode step against a slot-indexed
  KV cache (`models/transformer.py:init_cache(per_slot=True)`): every batch
  row is an independent session at its own position t[b].  Slots are
  allocated on admission (`insert_cache_slot`), freed on EOS/length
  completion, and reused by later requests — the decode GEMM shape is the
  constant (slots, ...) regardless of traffic mix.
* **Injectable clock + event loop** — the scheduler never reads wall time
  directly; it takes a :class:`SystemClock` in production
  (``serve.py --scheduler``) and a :class:`VirtualClock` in tests, so the
  identical `submit`/`step`/`drain` code path is driven deterministically by
  scripted arrival traces with no sleeps (`tests/test_scheduler.py`).

Also here: :func:`compiled_steps`, the per-(template, config, cache_len)
memo of jitted prefill/decode closures.  `serve.generate` used to rebuild
its `jax.jit` wrappers on every call — every call retraced; the memo is
shared by the scheduler and `generate`, with `TRACE_COUNTS` exposing actual
trace counts for regression tests.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import bucket_for, register_plan_store, validate_policy
from repro.core.quantization import NumericsPolicy
from repro.core.template import Template, default_template
from repro.models import transformer as T

__all__ = [
    "Request",
    "SchedulerConfig",
    "ServeScheduler",
    "SystemClock",
    "VirtualClock",
    "TRACE_COUNTS",
    "compiled_steps",
    "replay_trace",
    "synthetic_trace",
]


# ---------------------------------------------------------------------------
# injectable clocks
# ---------------------------------------------------------------------------


class VirtualClock:
    """Deterministic simulation clock: time moves only when told to."""

    def __init__(self, start: float = 0.0) -> None:
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def sleep(self, dt: float) -> None:
        self._t += max(0.0, float(dt))


class SystemClock:
    """Production clock (monotonic)."""

    def now(self) -> float:
        return time.monotonic()

    def sleep(self, dt: float) -> None:
        if dt > 0:
            time.sleep(dt)


# ---------------------------------------------------------------------------
# compiled step functions (hoisted jit closures, trace-counted)
# ---------------------------------------------------------------------------

#: (kind, cfg.name, cache_len) -> number of times the closure body actually
#: ran under jax tracing.  A repeated `generate()`/scheduler call with
#: unchanged shapes must not grow these counts.
TRACE_COUNTS: collections.Counter = collections.Counter()

_STEP_FNS: dict = {}
#: LRU bound: generate()'s default cache_len is s+gen, so prompt-length
#: diversity would otherwise pin one executable pair per distinct length
#: forever in a long-lived process.
_STEP_FNS_MAX = 64
# cleared together with the plan caches so reset_plan_caches() drops the
# compiled closures too (they capture Templates whose plans just vanished)
register_plan_store(_STEP_FNS)
register_plan_store(TRACE_COUNTS)


def compiled_steps(tpl: Template, cfg, cache_len: int,
                   policy: Optional[NumericsPolicy] = None):
    """The memoized (prefill_fn, decode_fn) pair for one serving setup.

    prefill_fn(params, tokens, ctx, last_pos) -> (logits (B,V), cache)
    decode_fn(params, token, t, cache)        -> (logits (B,V), cache')

    Keyed by (template, config, cache_len, numerics policy): repeated
    `generate()` calls and every scheduler step reuse one pair of jitted
    callables, so jax's own compilation cache applies — distinct *shapes*
    still trace once each (that is the bucket ladder's job to bound), but a
    repeated shape never retraces.  A quantized policy closure expects the
    matching :func:`repro.models.transformer.quantize_params` tree as
    ``params``.  The closure bodies bump :data:`TRACE_COUNTS` — they only
    run while jax is tracing.
    """
    policy = validate_policy(tpl.config, policy)
    key = (tpl, cfg, int(cache_len), policy)
    fns = _STEP_FNS.pop(key, None)
    if fns is None:
        def _prefill(params, tokens, ctx, last_pos):
            TRACE_COUNTS["prefill", cfg.name, int(cache_len)] += 1
            return T.prefill(tpl, cfg, params, tokens, ctx=ctx,
                             cache_len=cache_len, last_pos=last_pos,
                             policy=policy)

        def _decode(params, token, t, cache):
            TRACE_COUNTS["decode", cfg.name, int(cache_len)] += 1
            return T.decode_step(tpl, cfg, params, token, t, cache,
                                 policy=policy)

        # the input cache dies the moment a decode step returns — donate it
        # so XLA aliases the (slots, Hkv, C, D) ring buffers in place instead
        # of copying the whole KV cache per generated token
        fns = (jax.jit(_prefill), jax.jit(_decode, donate_argnums=(3,)))
        while len(_STEP_FNS) >= _STEP_FNS_MAX:
            _STEP_FNS.pop(next(iter(_STEP_FNS)))
    _STEP_FNS[key] = fns  # (re-)insert at the LRU tail
    return fns


# ---------------------------------------------------------------------------
# requests
# ---------------------------------------------------------------------------

_RID = itertools.count()


@dataclasses.dataclass
class Request:
    """One generation request moving through queued -> active -> completed."""

    prompt: tuple  # prompt token ids
    max_new: int
    eos_id: Optional[int] = None
    arrival: float = 0.0
    rid: int = dataclasses.field(default_factory=lambda: next(_RID))

    # runtime state (owned by the scheduler)
    state: str = "new"  # new | queued | active | completed | rejected
    bucket: int = 0
    slot: Optional[int] = None
    generated: list = dataclasses.field(default_factory=list)
    t_next: int = 0
    submitted_at: float = 0.0
    admitted_at: float = 0.0
    completed_at: float = 0.0
    preemptions: int = 0
    slot_history: list = dataclasses.field(default_factory=list)
    finish_reason: str = ""

    @property
    def seq_len(self) -> int:
        """Tokens a (re-)prefill must process: prompt + already generated."""
        return len(self.prompt) + len(self.generated)

    @property
    def remaining(self) -> int:
        return self.max_new - len(self.generated)


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Admission/batching policy (the ladder is the shape contract)."""

    ladder: tuple = (16, 32, 64)
    slots: int = 4
    max_new_limit: int = 32
    #: ring-cache length; 0 derives max(ladder) + max_new_limit (no wrap)
    cache_len: int = 0
    max_queue: int = 256
    #: preempt the most recently admitted active request once the queue head
    #: has waited this long with no free slot (None = never preempt)
    preempt_after: Optional[float] = None

    def resolved_cache_len(self) -> int:
        return self.cache_len or (max(self.ladder) + self.max_new_limit)


# ---------------------------------------------------------------------------
# the scheduler
# ---------------------------------------------------------------------------


class ServeScheduler:
    """Continuous-batching scheduler: FIFO queue, bucketed prefill, one
    coalesced decode step per tick over a slot-indexed KV cache.

    Padding a prompt is only sound for attention mixers (pad keys are masked
    out; recurrent/SSM states would absorb the pad tokens), so admission is
    restricted to families whose every layer mixes by attention.
    """

    def __init__(self, cfg, params, *, sched: Optional[SchedulerConfig] = None,
                 tpl: Optional[Template] = None, clock=None,
                 policy: Optional[NumericsPolicy] = None) -> None:
        pattern = T.plan_pattern(cfg)
        # "local" with a real window is also unsound: its ring cache is only
        # window-sized, so a bucket-padded prefill longer than the window
        # evicts *real* keys in favor of pad keys that trimming then voids.
        bad = [
            p.mixer for p in pattern
            if not (p.mixer == "attn" or (p.mixer == "local" and not cfg.window))
        ]
        if bad or any(p.cross for p in pattern) or cfg.family in ("encdec", "vlm"):
            raise ValueError(
                f"scheduler requires full-attention mixers without context "
                f"inputs; {cfg.name} ({cfg.family}) has {bad or 'cross-attention'}"
            )
        self.cfg = cfg
        self.params = params
        self.tpl = tpl or default_template()
        self.sched = sched or SchedulerConfig()
        self.clock = clock or SystemClock()
        # backend/policy combos are rejected up front with a clear error
        # (q16 policy on a float backend, quantized non-dense families, ...)
        # instead of silently serving the wrong numerics
        self.policy = validate_policy(self.tpl.config, policy)
        self.exec_params = (
            T.quantize_params(self.tpl, cfg, params, self.policy)
            if self.policy.quantized else params
        )
        self.cache_dtype = jnp.int16 if self.policy.quantized else None
        self.cache_len = self.sched.resolved_cache_len()
        if max(self.sched.ladder) > self.cache_len:
            raise ValueError("cache_len smaller than the largest bucket")
        self.engine = self.tpl.engine
        self.registry = self.engine.plan_cache
        self._prefill, self._decode = compiled_steps(self.tpl, cfg,
                                                     self.cache_len, self.policy)

        # compiled slot insertion (one trace per slot index — cache shapes
        # are bucket-independent); the old batched cache is dead afterwards
        # and aliases the output 1:1, so donate it (the batch-1 prefill row
        # cannot alias — its shapes differ from every output)
        def _ins(cache, row_cache, valid_len, slot):
            return T.insert_cache_slot(cache, slot, row_cache, valid_len=valid_len)

        self._insert = jax.jit(_ins, static_argnums=(3,), donate_argnums=(0,))

        self.queue: collections.deque = collections.deque()
        self.active: dict = {}  # slot -> Request
        self._free: list = sorted(range(self.sched.slots))
        self.cache = None  # batched slot-indexed cache, built on first admit
        self.counters: collections.Counter = collections.Counter()
        self.bucket_stats: dict = {
            int(b): {"admitted": 0, "prefills": 0, "occupancy": 0,
                     "hits": 0, "misses": 0}
            for b in sorted(self.sched.ladder)
        }
        self.history: list = []
        self.results: dict = {}  # rid -> Request (completed)

    # -- warmup --------------------------------------------------------------

    def warmup(self) -> dict:
        """Trace every bucket's prefill and the coalesced decode step once.

        All plan work (DSE lookups happen at trace time) lands here, scoped
        per bucket — after warmup a mixed trace replays with ``misses == 0``
        against the warm registry.  Returns the per-bucket hit/miss deltas.
        """
        for b in sorted(self.sched.ladder):
            toks = jnp.zeros((1, b), jnp.int32)
            with self.registry.scope(into=self.bucket_stats[b]):
                jax.block_until_ready(
                    self._prefill(self.exec_params, toks, None, jnp.int32(b - 1))[0]
                )
        cache = T.init_cache(self.cfg, self.sched.slots, self.cache_len,
                             dtype=self.cache_dtype, per_slot=True)
        tok = jnp.zeros((self.sched.slots, 1), jnp.int32)
        tvec = jnp.zeros((self.sched.slots,), jnp.int32)
        with self.registry.scope() as decode_delta:
            jax.block_until_ready(
                self._decode(self.exec_params, tok, tvec, cache)[0]
            )
        self.counters["warmup_decode_misses"] += decode_delta["misses"]
        return {b: dict(s) for b, s in self.bucket_stats.items()}

    # -- admission control ---------------------------------------------------

    def submit(self, req: Request) -> bool:
        """Queue a request; False (state=rejected) when admission control
        refuses it: unknown-bucket length, over-limit generation budget, a
        sequence that would wrap the ring cache, or a full queue."""
        self.counters["submitted"] += 1
        bucket = bucket_for(req.seq_len, self.sched.ladder)
        fits = (
            bucket is not None
            and 0 < req.max_new <= self.sched.max_new_limit
            and req.seq_len + req.max_new <= self.cache_len
        )
        if not fits or len(self.queue) >= self.sched.max_queue:
            req.state = "rejected"
            self.counters["rejected"] += 1
            return False
        req.bucket = bucket
        req.state = "queued"
        req.submitted_at = self.clock.now()
        self.queue.append(req)
        return True

    # -- internals -----------------------------------------------------------

    def _complete(self, req: Request, reason: str) -> None:
        req.state = "completed"
        req.finish_reason = reason
        req.completed_at = self.clock.now()
        if req.slot is not None:
            self.active.pop(req.slot, None)
            self._free.append(req.slot)
            self._free.sort()
            req.slot = None
        self.counters["completed"] += 1
        self.results[req.rid] = req

    def _admit(self, req: Request) -> None:
        slot = self._free.pop(0)
        req.slot = slot
        req.slot_history.append(slot)
        req.state = "active"
        req.admitted_at = self.clock.now()
        self.counters["admitted"] += 1

        s_total = req.seq_len
        bucket = bucket_for(s_total, self.sched.ladder)
        req.bucket = bucket
        bstats = self.bucket_stats[bucket]
        bstats["admitted"] += 1
        bstats["prefills"] += 1
        self.counters["prefills"] += 1

        tokens = np.zeros((1, bucket), np.int32)  # right-pad up to the rung
        tokens[0, :s_total] = np.asarray(
            list(req.prompt) + list(req.generated), np.int32
        )
        with self.registry.scope(into=bstats):
            logits, row_cache = self._prefill(
                self.exec_params, jnp.asarray(tokens), None, jnp.int32(s_total - 1)
            )
        tok = int(jnp.argmax(logits[0]))
        req.generated.append(tok)
        self.counters["tokens"] += 1
        if req.eos_id is not None and tok == req.eos_id:
            self._complete(req, "eos")
            return
        if req.remaining <= 0:
            self._complete(req, "length")
            return
        if self.cache is None:
            self.cache = T.init_cache(self.cfg, self.sched.slots, self.cache_len,
                                      dtype=self.cache_dtype, per_slot=True)
        self.cache = self._insert(self.cache, row_cache, jnp.int32(s_total), slot)
        req.t_next = s_total
        self.active[slot] = req

    def _preempt_if_starving(self, now: float) -> Optional[Request]:
        pa = self.sched.preempt_after
        if pa is None or not self.queue or self._free or not self.active:
            return None
        head = self.queue[0]
        if now - head.submitted_at < pa:
            return None
        # victim: most recently admitted active request that can re-bucket
        for slot in sorted(self.active,
                           key=lambda s: (self.active[s].admitted_at, s),
                           reverse=True):
            req = self.active[slot]
            nb = bucket_for(req.seq_len, self.sched.ladder)
            if nb is not None and req.seq_len + req.remaining <= self.cache_len:
                self.active.pop(slot)
                self._free.append(slot)
                self._free.sort()
                req.slot = None
                req.state = "queued"
                req.preemptions += 1
                req.submitted_at = now  # waits its turn afresh
                self.counters["preempted"] += 1
                return req
        return None

    # -- the event loop body -------------------------------------------------

    def step(self) -> bool:
        """One scheduler tick: (maybe) preempt, admit FIFO, one coalesced
        decode step over all active slots.  Returns whether any work ran."""
        now = self.clock.now()
        event = {"now": now, "admitted": [], "completed": [], "preempted": [],
                 "decoded": 0}

        victim = self._preempt_if_starving(now)

        while self._free and self.queue:
            req = self.queue.popleft()
            self._admit(req)
            event["admitted"].append(req.rid)
            if req.state == "completed":
                event["completed"].append((req.rid, req.finish_reason))
        if victim is not None:
            self.queue.appendleft(victim)
            event["preempted"].append(victim.rid)

        if self.active:
            slots = self.sched.slots
            tok = np.zeros((slots, 1), np.int32)
            tvec = np.zeros((slots,), np.int32)
            for slot, req in self.active.items():
                tok[slot, 0] = req.generated[-1]
                tvec[slot] = req.t_next
            logits, self.cache = self._decode(
                self.exec_params, jnp.asarray(tok), jnp.asarray(tvec), self.cache
            )
            next_tok = np.asarray(jnp.argmax(logits, axis=-1))
            self.counters["decode_steps"] += 1
            self.counters["slot_steps"] += len(self.active)
            event["decoded"] = len(self.active)
            for slot in sorted(self.active):
                req = self.active[slot]
                self.bucket_stats[req.bucket]["occupancy"] += 1
                t = int(next_tok[slot])
                req.generated.append(t)
                req.t_next += 1
                self.counters["tokens"] += 1
            for slot in sorted(self.active):
                req = self.active[slot]
                if req.eos_id is not None and req.generated[-1] == req.eos_id:
                    self._complete(req, "eos")
                    event["completed"].append((req.rid, "eos"))
                elif req.remaining <= 0:
                    self._complete(req, "length")
                    event["completed"].append((req.rid, "length"))

        worked = bool(event["admitted"] or event["decoded"] or event["preempted"])
        if worked:
            self.history.append(event)
        return worked

    def drain(self, *, tick: float = 0.0, max_steps: int = 100_000) -> None:
        """Run the event loop until queue and slots are empty."""
        for _ in range(max_steps):
            if not (self.queue or self.active):
                return
            self.step()
            self.clock.sleep(tick)
        raise RuntimeError(f"scheduler did not drain in {max_steps} steps")

    # -- reporting -----------------------------------------------------------

    def stats(self) -> dict:
        c = self.counters
        reg = self.registry.stats()
        return {
            "counters": dict(c),
            "mean_occupancy": round(c["slot_steps"] / max(c["decode_steps"], 1), 3),
            "buckets": {b: dict(s) for b, s in self.bucket_stats.items()},
            "registry": reg,
        }

    def stats_line(self) -> str:
        c = self.counters
        occ = c["slot_steps"] / max(c["decode_steps"], 1)
        per_bucket = " ".join(
            f"{b}:{s['admitted']}a/{s['occupancy']}o/{s['misses']}m"
            for b, s in sorted(self.bucket_stats.items())
        )
        return (
            f"scheduler: submitted={c['submitted']} admitted={c['admitted']} "
            f"completed={c['completed']} rejected={c['rejected']} "
            f"preempted={c['preempted']} prefills={c['prefills']} "
            f"decode_steps={c['decode_steps']} tokens={c['tokens']} "
            f"mean_occupancy={occ:.2f} | buckets[adm/occ/miss] {per_bucket}"
        )


# ---------------------------------------------------------------------------
# trace replay (the simulation harness — same loop production uses)
# ---------------------------------------------------------------------------


def replay_trace(sched: ServeScheduler, requests: Sequence[Request], *,
                 tick: float = 1.0, max_steps: int = 100_000) -> dict:
    """Drive the scheduler from a scripted arrival trace.

    ``arrival`` times are offsets from the start of the replay (the injected
    clock's reading at entry — a SystemClock reports absolute monotonic
    time, a VirtualClock usually 0): submissions become due as the clock
    passes start + arrival; when the scheduler is idle the clock jumps
    (virtual) or the process sleeps (production clock) to the next arrival.
    One `step()` per ``tick`` of clock time.  Returns `sched.stats()` once
    everything drains.
    """
    pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
    pending = collections.deque(pending)
    t0 = sched.clock.now()
    for _ in range(max_steps):
        elapsed = sched.clock.now() - t0
        while pending and pending[0].arrival <= elapsed:
            sched.submit(pending.popleft())
        if not (sched.queue or sched.active):
            if not pending:
                return sched.stats()
            sched.clock.sleep(pending[0].arrival - elapsed)
            continue
        sched.step()
        sched.clock.sleep(tick)
    raise RuntimeError(f"trace did not drain in {max_steps} steps")


def synthetic_trace(n: int, *, seed: int = 0, vocab: int = 128,
                    ladder: Sequence[int] = (16, 32, 64), max_new: int = 8,
                    arrival_every: float = 0.0, eos_id: Optional[int] = None) -> list:
    """A deterministic mixed prompt-length trace (for benchmarks / soak).

    Lengths sweep the full ladder (from just-above the previous rung to the
    rung itself) so every bucket sees traffic; ``arrival_every > 0`` spaces
    arrivals out (uniform trace), 0 makes the trace bursty (all at t=0).
    """
    rng = np.random.default_rng(seed)
    lo = [1] + [int(b) + 1 for b in sorted(ladder)[:-1]]
    hi = sorted(int(b) for b in ladder)
    reqs = []
    for i in range(n):
        j = int(rng.integers(0, len(hi)))
        length = int(rng.integers(lo[j], hi[j] + 1))
        prompt = tuple(int(x) for x in rng.integers(0, vocab, size=length))
        reqs.append(Request(
            prompt=prompt,
            max_new=int(rng.integers(1, max_new + 1)),
            eos_id=eos_id,
            arrival=i * arrival_every,
        ))
    return reqs
