"""Continuous-batching serve scheduler over the execution-plan engine.

The paper's template sustains throughput only while the single on-chip
compute unit is fed uniformly-shaped work; the serving analogue is a
scheduler that quantizes *traffic* into the handful of GEMM shapes the
PlanRegistry already holds plans for (ROADMAP "Serving batch scheduler";
DESIGN.md §7):

* **Bucket ladder** — every prefill is right-padded up to the smallest
  ladder rung >= its prompt length (`core/engine.py:bucket_for`).  Under
  causal attention the padding cannot influence logits at real positions, so
  a bucket costs only wasted FLOPs, never accuracy; each rung is one fixed
  prefill shape, planned once (warmup) and a registry hit forever after.
* **Coalesced (B, L) bucket prefill** — a tick's pending prefills for one
  rung are stacked into ONE batched launch (per-row `last_pos` vectors,
  batch padded up to a power-of-two batch rung, `engine.batch_rungs`), then
  scattered row-by-row into the slot-indexed KV cache
  (`transformer.insert_cache_rows`).  Prefill launches per tick are bounded
  by the number of *occupied rungs*, never the number of admissions.
* **Chunked prefill / decode interleaving** — with ``prefill_chunk > 0``,
  prompts longer than one chunk stream into their slot chunk by chunk
  (`transformer.prefill_chunk_step`, one fixed (slots, chunk) launch per
  tick) interleaved with the batched decode step, so one long prompt no
  longer stalls time-to-first-token for every resident session.
* **Slot-indexed continuous batching** — decode requests from different
  sessions are coalesced into ONE batched decode step against a slot-indexed
  KV cache (`models/transformer.py:init_cache(per_slot=True)`): every batch
  row is an independent session at its own position t[b] (t[b] < 0 gates a
  lane off entirely).  Slots are allocated on admission, freed on EOS/length
  completion, and reused by later requests — the decode GEMM shape is the
  constant (slots, ...) regardless of traffic mix.
* **Sampled decode lanes** — greedy argmax by default; a
  :class:`SamplingParams` with temperature > 0 draws each token from a
  per-slot RNG lane, `fold_in(fold_in(PRNGKey(seed), slot), position)`, so a
  request's stream depends only on (seed, slot, position) — byte-reproducible
  per seed under the VirtualClock regardless of batch composition.
* **Injectable clock + event loop** — the scheduler never reads wall time
  directly; it takes a :class:`SystemClock` in production
  (``serve.py --scheduler``) and a :class:`VirtualClock` in tests, so the
  identical `submit`/`step`/`drain` code path is driven deterministically by
  scripted arrival traces with no sleeps (`tests/test_scheduler.py`).

Also here: :func:`compiled_steps`, the per-(template, config, cache_len)
memo of jitted prefill/decode/chunk closures.  `serve.generate` used to
rebuild its `jax.jit` wrappers on every call — every call retraced; the memo
is shared by the scheduler and `generate`, with `TRACE_COUNTS` exposing
actual trace counts for regression tests.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import time
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import (
    batch_rungs,
    bucket_for,
    register_plan_store,
    validate_policy,
)
from repro.core.quantization import NumericsPolicy
from repro.core.template import Template, default_template
from repro.models import transformer as T
from repro.parallel.sharding import (
    DECODE_RULES,
    axis_size,
    column_parallel_shardings,
    local_gemm_shape,
    tree_shardings,
    use_mesh,
)

__all__ = [
    "Request",
    "SamplingParams",
    "SchedulerConfig",
    "ServeScheduler",
    "StepFns",
    "SystemClock",
    "VirtualClock",
    "TRACE_COUNTS",
    "compiled_steps",
    "replay_trace",
    "request_from_snapshot",
    "sampler_fn",
    "session_snapshot",
    "synthetic_trace",
]


# ---------------------------------------------------------------------------
# injectable clocks
# ---------------------------------------------------------------------------


class VirtualClock:
    """Deterministic simulation clock: time moves only when told to."""

    def __init__(self, start: float = 0.0) -> None:
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def sleep(self, dt: float) -> None:
        self._t += max(0.0, float(dt))


class SystemClock:
    """Production clock (monotonic)."""

    def now(self) -> float:
        return time.monotonic()

    def sleep(self, dt: float) -> None:
        if dt > 0:
            time.sleep(dt)


# ---------------------------------------------------------------------------
# compiled step functions (hoisted jit closures, trace-counted)
# ---------------------------------------------------------------------------

#: (kind, cfg.name, cache_len) -> number of times the closure body actually
#: ran under jax tracing.  A repeated `generate()`/scheduler call with
#: unchanged shapes must not grow these counts.
TRACE_COUNTS: collections.Counter = collections.Counter()

_STEP_FNS: dict = {}
#: LRU bound: generate()'s default cache_len is s+gen, so prompt-length
#: diversity would otherwise pin one executable triple per distinct length
#: forever in a long-lived process.
_STEP_FNS_MAX = 64
_SAMPLE_FNS: dict = {}
# cleared together with the plan caches so reset_plan_caches() drops the
# compiled closures too (they capture Templates whose plans just vanished)
register_plan_store(_STEP_FNS)
register_plan_store(_SAMPLE_FNS)
register_plan_store(TRACE_COUNTS)


class StepFns(NamedTuple):
    """The jitted serving closures of one (template, config, cache_len,
    policy) setup.  Indexable like the old (prefill, decode) pair."""

    prefill: object  # (params, tokens (B,L), ctx, last_pos) -> (logits, cache)
    decode: object   # (params, token (B,1), t, cache) -> (logits, cache')
    chunk: object    # (params, tokens (B,S), t, n_valid, cache) -> (logits, cache')


def compiled_steps(tpl: Template, cfg, cache_len: int,
                   policy: Optional[NumericsPolicy] = None, *,
                   mesh=None, rules=None) -> StepFns:
    """The memoized :class:`StepFns` triple for one serving setup.

    prefill(params, tokens, ctx, last_pos)   -> (logits (B,V), cache)
    decode(params, token, t, cache)          -> (logits (B,V), cache')
    chunk(params, tokens, t, n_valid, cache) -> (logits (B,V), cache')

    Keyed by (template, config, cache_len, numerics policy): repeated
    `generate()` calls and every scheduler step reuse one triple of jitted
    callables, so jax's own compilation cache applies — distinct *shapes*
    still trace once each (that is the bucket ladder's job to bound), but a
    repeated shape never retraces.  A quantized policy closure expects the
    matching :func:`repro.models.transformer.quantize_params` tree as
    ``params``.  The closure bodies bump :data:`TRACE_COUNTS` — they only
    run while jax is tracing.

    With ``mesh`` the returned callables enter ``use_mesh(mesh, rules)``
    (default :data:`~repro.parallel.sharding.DECODE_RULES`) around every
    call, so the model's ``constrain`` seams resolve against the mesh at
    trace time — mesh and no-mesh setups get *separate* memo entries and
    never contaminate each other's traced constraints.
    """
    policy = validate_policy(tpl.config, policy)
    if mesh is not None and rules is None:
        rules = DECODE_RULES
    key = (tpl, cfg, int(cache_len), policy, mesh, rules)
    fns = _STEP_FNS.pop(key, None)
    if fns is None:
        def _prefill(params, tokens, ctx, last_pos):
            TRACE_COUNTS["prefill", cfg.name, int(cache_len)] += 1
            return T.prefill(tpl, cfg, params, tokens, ctx=ctx,
                             cache_len=cache_len, last_pos=last_pos,
                             policy=policy)

        def _decode(params, token, t, cache):
            TRACE_COUNTS["decode", cfg.name, int(cache_len)] += 1
            return T.decode_step(tpl, cfg, params, token, t, cache,
                                 policy=policy)

        def _chunk(params, tokens, t, n_valid, cache):
            TRACE_COUNTS["chunk", cfg.name, int(cache_len)] += 1
            return T.prefill_chunk_step(tpl, cfg, params, tokens, t, n_valid,
                                        cache, policy=policy)

        # the input cache dies the moment a decode/chunk step returns —
        # donate it so XLA aliases the (slots, Hkv, C, D) ring buffers in
        # place instead of copying the whole KV cache per generated token
        fns = StepFns(
            jax.jit(_prefill),
            jax.jit(_decode, donate_argnums=(3,)),
            jax.jit(_chunk, donate_argnums=(4,)),
        )
        if mesh is not None:
            def _meshed(fn):
                def call(*args):
                    with use_mesh(mesh, rules):
                        return fn(*args)
                return call

            fns = StepFns(*(_meshed(f) for f in fns))
        while len(_STEP_FNS) >= _STEP_FNS_MAX:
            _STEP_FNS.pop(next(iter(_STEP_FNS)))
    _STEP_FNS[key] = fns  # (re-)insert at the LRU tail
    return fns


# ---------------------------------------------------------------------------
# sampling (per-slot RNG lanes)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Decode-time sampling policy.  temperature <= 0 is exact greedy argmax
    (the byte-parity mode); temperature > 0 samples from the softmax, with
    ``top_k > 0`` restricting to the k highest logits first.  ``seed`` roots
    every RNG lane: token draws are keyed (seed, lane, position) only."""

    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


def sampler_fn(temperature: float, top_k: int):
    """The memoized jitted sampler for one (temperature, top_k) setting.

    sample(logits (B,V), seed, lanes (B,), positions (B,)) -> tokens (B,)

    Row b draws from `fold_in(fold_in(PRNGKey(seed), lanes[b]),
    positions[b])` — an independent counter-mode stream per (lane, position),
    so a draw never depends on which other rows share the batch.  The
    scheduler uses lane = slot id; `generate` uses lane = batch row.
    """
    if temperature <= 0.0:
        raise ValueError("greedy sampling is argmax, not a sampler_fn")
    key = (float(temperature), int(top_k))
    fn = _SAMPLE_FNS.get(key)
    if fn is None:
        def _sample(logits, seed, lanes, positions):
            TRACE_COUNTS["sample", f"T{temperature}/k{top_k}",
                         int(logits.shape[0])] += 1
            scaled = logits.astype(jnp.float32) / jnp.float32(temperature)
            if 0 < top_k < logits.shape[-1]:
                kth = jax.lax.top_k(scaled, top_k)[0][..., -1:]
                scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
            base = jax.random.PRNGKey(seed)

            def draw(row, lane, pos):
                k = jax.random.fold_in(jax.random.fold_in(base, lane), pos)
                return jax.random.categorical(k, row)

            return jax.vmap(draw)(scaled, lanes, positions)

        fn = jax.jit(_sample)
        _SAMPLE_FNS[key] = fn
    return fn


# ---------------------------------------------------------------------------
# requests
# ---------------------------------------------------------------------------

_RID = itertools.count()


@dataclasses.dataclass
class Request:
    """One generation request moving through queued -> active -> completed."""

    prompt: tuple  # prompt token ids
    max_new: int
    eos_id: Optional[int] = None
    arrival: float = 0.0
    rid: int = dataclasses.field(default_factory=lambda: next(_RID))

    # runtime state (owned by the scheduler)
    state: str = "new"  # new | queued | active | completed | rejected
    bucket: int = 0
    slot: Optional[int] = None
    generated: list = dataclasses.field(default_factory=list)
    t_next: int = 0
    prefilled: int = 0  # prompt positions already written to the cache
    prefill_target: int = 0  # positions a (re-)prefill must cover
    submitted_at: float = 0.0
    admitted_at: float = 0.0
    first_token_at: float = -1.0
    completed_at: float = 0.0
    preemptions: int = 0
    slot_history: list = dataclasses.field(default_factory=list)
    finish_reason: str = ""

    @property
    def seq_len(self) -> int:
        """Tokens a (re-)prefill must process: prompt + already generated."""
        return len(self.prompt) + len(self.generated)

    @property
    def remaining(self) -> int:
        return self.max_new - len(self.generated)


def session_snapshot(req: Request) -> dict:
    """The JSON-serializable resume state of one in-flight request.

    Carries exactly what a fresh scheduler needs to continue the session
    with byte-identical output under greedy decode: the prompt, the tokens
    generated so far (the re-prefill covers prompt + generated, then decode
    continues at the next position), the total budget, and identity/arrival
    metadata.  Scheduler-owned runtime state (slot, bucket, prefill
    progress) is deliberately dropped — the restoring scheduler re-derives
    it on admission.
    """
    return {
        "rid": req.rid,
        "prompt": list(req.prompt),
        "generated": list(req.generated),
        "max_new": req.max_new,
        "eos_id": req.eos_id,
        "arrival": req.arrival,
        "preemptions": req.preemptions,
    }


def request_from_snapshot(doc: dict) -> Request:
    """Rebuild a resumable :class:`Request` from :func:`session_snapshot`.

    The original ``rid`` is preserved (the request is the *same* logical
    session, so ledgers and results keyed by rid line up across the
    restore); state resets to "new" for a fresh ``submit``.
    """
    req = Request(
        prompt=tuple(doc["prompt"]),
        max_new=int(doc["max_new"]),
        eos_id=doc["eos_id"],
        arrival=float(doc.get("arrival", 0.0)),
        rid=int(doc["rid"]),
    )
    req.generated = [int(t) for t in doc.get("generated", ())]
    req.preemptions = int(doc.get("preemptions", 0))
    return req


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Admission/batching policy (the ladder is the shape contract)."""

    ladder: tuple = (16, 32, 64)
    slots: int = 4
    max_new_limit: int = 32
    #: ring-cache length; 0 derives max(ladder) + max_new_limit (no wrap)
    cache_len: int = 0
    max_queue: int = 256
    #: preempt the most recently admitted active request once the queue head
    #: has waited this long with no free slot (None = never preempt)
    preempt_after: Optional[float] = None
    #: > 0 streams prompts longer than this into their slot in fixed-width
    #: chunks (one (slots, prefill_chunk) launch per tick, interleaved with
    #: decode) instead of one whole-bucket prefill; 0 disables chunking
    prefill_chunk: int = 0
    #: "batched" coalesces a rung's pending prefills into one (B, L) launch;
    #: "sequential" is the one-(1, L)-launch-per-admission baseline
    prefill_mode: str = "batched"

    def resolved_cache_len(self) -> int:
        return self.cache_len or (max(self.ladder) + self.max_new_limit)


# ---------------------------------------------------------------------------
# the scheduler
# ---------------------------------------------------------------------------


class ServeScheduler:
    """Continuous-batching scheduler: FIFO queue, one coalesced (B, L)
    prefill launch per bucket rung per tick, chunked long-prompt streaming,
    one coalesced decode step per tick over a slot-indexed KV cache.

    Padding a prompt is only sound for attention mixers (pad keys are masked
    out; recurrent/SSM states would absorb the pad tokens), so admission is
    restricted to families whose every layer mixes by attention.
    """

    def __init__(self, cfg, params, *, sched: Optional[SchedulerConfig] = None,
                 tpl: Optional[Template] = None, clock=None,
                 policy: Optional[NumericsPolicy] = None,
                 sampling: Optional[SamplingParams] = None,
                 mesh=None, rules=None) -> None:
        pattern = T.plan_pattern(cfg)
        # "local" with a real window is also unsound: its ring cache is only
        # window-sized, so a bucket-padded prefill longer than the window
        # evicts *real* keys in favor of pad keys that trimming then voids.
        bad = [
            p.mixer for p in pattern
            if not (p.mixer == "attn" or (p.mixer == "local" and not cfg.window))
        ]
        if bad or any(p.cross for p in pattern) or cfg.family in ("encdec", "vlm"):
            raise ValueError(
                f"scheduler requires full-attention mixers without context "
                f"inputs; {cfg.name} ({cfg.family}) has {bad or 'cross-attention'}"
            )
        self.cfg = cfg
        self.params = params
        self.tpl = tpl or default_template()
        self.sched = sched or SchedulerConfig()
        self.clock = clock or SystemClock()
        self.sampling = sampling or SamplingParams()
        # backend/policy combos are rejected up front with a clear error
        # (q16 policy on a float backend, quantized non-dense families, ...)
        # instead of silently serving the wrong numerics
        self.policy = validate_policy(self.tpl.config, policy)
        self.exec_params = (
            T.quantize_params(self.tpl, cfg, params, self.policy)
            if self.policy.quantized else params
        )
        # quantized policies resolve the KV dtype per scan group inside
        # init_cache (int8 where the precision DSE dropped the group's grid
        # to the 8-bit rung, int16 elsewhere); float serving keeps cfg.dtype
        self.cache_dtype = None
        self.cache_len = self.sched.resolved_cache_len()
        if max(self.sched.ladder) > self.cache_len:
            raise ValueError("cache_len smaller than the largest bucket")
        if self.sched.prefill_mode not in ("batched", "sequential"):
            raise ValueError(f"unknown prefill_mode {self.sched.prefill_mode!r}")
        if self.sched.prefill_chunk < 0 or self.sched.prefill_chunk > self.cache_len:
            raise ValueError(
                f"prefill_chunk {self.sched.prefill_chunk} must be in "
                f"[0, cache_len={self.cache_len}]")
        # -- tensor-parallel decode (PR 7) ---------------------------------
        # Bitwise-reproducible sharding: params column-parallel only (every
        # GEMM keeps its full K extent per shard), activations gathered at
        # the model's constrain seams (DECODE_RULES), the per-slot KV cache
        # sharded over slots on the data-ish axes.  A replica's token stream
        # is byte-identical whether it runs on one device or the mesh.
        self.mesh = mesh
        self.rules = (rules or DECODE_RULES) if mesh is not None else None
        if mesh is not None:
            data_shards = axis_size(mesh, self.rules.get("batch"))
            if data_shards > 1 and self.sched.slots % data_shards:
                raise ValueError(
                    f"slots={self.sched.slots} must divide over the "
                    f"{data_shards}-way data axes to shard the per-slot KV "
                    f"cache")
            axes = T.param_axes(cfg)
            if (isinstance(self.exec_params, dict) and isinstance(axes, dict)
                    and "lm_head" in self.exec_params and "lm_head" not in axes):
                # quantize_params materializes an int16 head for tied
                # embeddings; give it the untied head's logical axes
                axes = dict(axes, lm_head={"w": ("embed", "vocab")})
            self.exec_params = jax.device_put(
                self.exec_params,
                column_parallel_shardings(mesh, self.rules, self.exec_params,
                                          axes),
            )
        self.engine = self.tpl.engine
        self.registry = self.engine.plan_cache
        fns = compiled_steps(self.tpl, cfg, self.cache_len, self.policy,
                             mesh=self.mesh, rules=self.rules)
        self._prefill, self._decode, self._chunk = fns
        self._sampler = (
            None if self.sampling.greedy
            else sampler_fn(self.sampling.temperature, self.sampling.top_k)
        )
        #: batch sizes a coalesced prefill launch is padded up to — the
        #: (|batch_rungs| x |ladder|) product is the whole prefill shape set
        self._batch_rungs = (
            (1,) if self.sched.prefill_mode == "sequential"
            else batch_rungs(self.sched.slots)
        )

        # compiled cache maintenance (no GEMMs — memory ops, not launches);
        # the old batched cache is dead afterwards and aliases the output
        # 1:1, so donate it
        def _ins(cache, rows_cache, src_rows, sel, valid_lens):
            return T.insert_cache_rows(cache, rows_cache, src_rows=src_rows,
                                       sel=sel, valid_lens=valid_lens)

        def _clr(cache, sel):
            return T.clear_cache_rows(cache, sel)

        self._insert_rows = jax.jit(_ins, donate_argnums=(0,))
        self._clear_rows = jax.jit(_clr, donate_argnums=(0,))

        self.queue: collections.deque = collections.deque()
        self.active: dict = {}  # slot -> Request
        self._free: list = sorted(range(self.sched.slots))
        self.cache = None  # batched slot-indexed cache, built on first admit
        self.counters: collections.Counter = collections.Counter()
        self.bucket_stats: dict = {
            int(b): {"admitted": 0, "prefills": 0, "launches": 0,
                     "occupancy": 0, "hits": 0, "misses": 0}
            for b in sorted(self.sched.ladder)
        }
        self.history: list = []
        self.results: dict = {}  # rid -> Request (completed)

    def _make_cache(self):
        """A fresh slot-indexed KV cache, sharded over slots under a mesh."""
        cache = T.init_cache(self.cfg, self.sched.slots, self.cache_len,
                             dtype=self.cache_dtype, per_slot=True,
                             policy=self.policy if self.policy.quantized
                             else None)
        if self.mesh is not None:
            shapes = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), cache)
            cache = jax.device_put(
                cache,
                tree_shardings(self.mesh, self.rules, shapes,
                               T.cache_axes(self.cfg, shapes)),
            )
        return cache

    # -- warmup --------------------------------------------------------------

    def warmup(self) -> dict:
        """Trace every (batch rung x bucket) prefill, the chunk step, and the
        coalesced decode step once.

        All plan work (DSE lookups happen at trace time) lands here, scoped
        per bucket — after warmup a mixed trace replays with ``misses == 0``
        against the warm registry: a coalesced (B, L) launch flattens its
        leading dims into GEMM M = B*L, so every batch-rung product must be
        planned up front, not just the per-rung shapes.  Returns the
        per-bucket hit/miss deltas.
        """
        for b in sorted(self.sched.ladder):
            for nb in self._batch_rungs:
                toks = jnp.zeros((nb, b), jnp.int32)
                last = jnp.full((nb,), b - 1, jnp.int32)
                with self.registry.scope(into=self.bucket_stats[b]):
                    jax.block_until_ready(
                        self._prefill(self.exec_params, toks, None, last)[0]
                    )
        cache = self._make_cache()
        if self.sched.prefill_chunk:
            ck = self.sched.prefill_chunk
            tok = jnp.zeros((self.sched.slots, ck), jnp.int32)
            t0 = jnp.full((self.sched.slots,), -1, jnp.int32)
            nv = jnp.zeros((self.sched.slots,), jnp.int32)
            with self.registry.scope() as chunk_delta:
                _, cache = self._chunk(self.exec_params, tok, t0, nv, cache)
                jax.block_until_ready(cache)
            self.counters["warmup_chunk_misses"] += chunk_delta["misses"]
        tok = jnp.zeros((self.sched.slots, 1), jnp.int32)
        tvec = jnp.zeros((self.sched.slots,), jnp.int32)
        with self.registry.scope() as decode_delta:
            jax.block_until_ready(
                self._decode(self.exec_params, tok, tvec, cache)[0]
            )
        self.counters["warmup_decode_misses"] += decode_delta["misses"]
        if self.mesh is not None:
            # per-shard plans: re-plan every GEMM shape the traces above
            # touched at its local (per-shard) extent, so mesh execution hits
            # the registry for both the logical and the shard-local lookups
            # and a warm-started replica replays with misses == 0.  A warm
            # registry (restored from a store a previous mesh run wrote)
            # already holds the local entries — skip shapes that are the
            # local image of another registered shape, else each warmup
            # would localize the locals again (quarter-shapes, and so on).
            shapes = self.registry.gemm_shapes(self.engine.config.hw)
            loc = {
                s: local_gemm_shape(*s, mesh=self.mesh) for s in shapes
            }
            local_images = {img for s, img in loc.items() if img != s}
            with self.registry.scope() as shard_delta:
                for s in shapes:
                    if s not in local_images:
                        self.engine.plan_gemm(*s, mesh=self.mesh)
            self.counters["warmup_shard_misses"] += shard_delta["misses"]
        return {b: dict(s) for b, s in self.bucket_stats.items()}

    # -- admission control ---------------------------------------------------

    def submit(self, req: Request) -> bool:
        """Queue a request; False (state=rejected) when admission control
        refuses it: unknown-bucket length, over-limit generation budget, a
        sequence that would wrap the ring cache, or a full queue.

        A *resumed* session (non-empty ``generated``, restored from a dead
        replica's checkpoint) is budgeted by ``remaining``, not ``max_new``
        — its already-generated tokens count toward ``seq_len``, so using
        ``max_new`` would double-count them.  For a fresh request the two
        are identical.
        """
        self.counters["submitted"] += 1
        bucket = bucket_for(req.seq_len, self.sched.ladder)
        fits = (
            bucket is not None
            and 0 < req.remaining
            and req.max_new <= self.sched.max_new_limit
            and req.seq_len + req.remaining <= self.cache_len
        )
        if not fits or len(self.queue) >= self.sched.max_queue:
            req.state = "rejected"
            self.counters["rejected"] += 1
            return False
        if req.generated:
            self.counters["resumed_sessions"] += 1
        req.bucket = bucket
        req.state = "queued"
        req.submitted_at = self.clock.now()
        self.queue.append(req)
        return True

    # -- internals -----------------------------------------------------------

    def _complete(self, req: Request, reason: str) -> None:
        req.state = "completed"
        req.finish_reason = reason
        req.completed_at = self.clock.now()
        if req.slot is not None:
            self.active.pop(req.slot, None)
            self._free.append(req.slot)
            self._free.sort()
            req.slot = None
        self.counters["completed"] += 1
        self.results[req.rid] = req

    def _preempt_if_starving(self, now: float) -> Optional[Request]:
        pa = self.sched.preempt_after
        if pa is None or not self.queue or self._free or not self.active:
            return None
        head = self.queue[0]
        if now - head.submitted_at < pa:
            return None
        # victim: most recently admitted active request that can re-bucket
        for slot in sorted(self.active,
                           key=lambda s: (self.active[s].admitted_at, s),
                           reverse=True):
            req = self.active[slot]
            nb = bucket_for(req.seq_len, self.sched.ladder)
            if nb is not None and req.seq_len + req.remaining <= self.cache_len:
                self.active.pop(slot)
                self._free.append(slot)
                self._free.sort()
                req.slot = None
                req.state = "queued"
                req.preemptions += 1
                req.prefilled = 0
                req.prefill_target = 0
                req.submitted_at = now  # waits its turn afresh
                self.counters["preempted"] += 1
                return req
        return None

    def _pick_tokens(self, logits, lanes, positions) -> np.ndarray:
        """Next token per row of a (B, V) logits batch: exact argmax when
        greedy, else one draw per RNG lane (lane = slot id, position = the
        absolute position the drawn token will occupy)."""
        if self.sampling.greedy:
            return np.asarray(jnp.argmax(logits, axis=-1))
        return np.asarray(self._sampler(
            logits, jnp.uint32(self.sampling.seed),
            jnp.asarray(lanes, jnp.int32), jnp.asarray(positions, jnp.int32),
        ))

    def _emit_first(self, req: Request, tok: int, event: dict) -> None:
        """Record a request's first generated token (prefill completion)."""
        req.generated.append(int(tok))
        req.first_token_at = self.clock.now()
        self.counters["tokens"] += 1
        if req.eos_id is not None and int(tok) == req.eos_id:
            self._complete(req, "eos")
            event["completed"].append((req.rid, "eos"))
        elif req.remaining <= 0:
            self._complete(req, "length")
            event["completed"].append((req.rid, "length"))
        else:
            req.t_next = req.prefill_target

    def _launch_prefill(self, bucket: int, group: list, event: dict) -> None:
        """ONE coalesced (B, bucket) prefill launch for a rung's admissions:
        batch padded up to the smallest batch rung >= |group| (pad rows are
        zero prompts whose outputs are discarded), logits read at each row's
        real last token, surviving rows scattered into their cache slots."""
        bstats = self.bucket_stats[bucket]
        nreal = len(group)
        npad = next(nb for nb in self._batch_rungs if nb >= nreal)
        tokens = np.zeros((npad, bucket), np.int32)  # right-pad up to the rung
        last = np.zeros((npad,), np.int32)
        for i, r in enumerate(group):
            seq = list(r.prompt) + list(r.generated)
            tokens[i, : len(seq)] = seq
            last[i] = len(seq) - 1
        with self.registry.scope(into=bstats):
            logits, rows_cache = self._prefill(
                self.exec_params, jnp.asarray(tokens), None, jnp.asarray(last)
            )
        bstats["admitted"] += nreal
        bstats["prefills"] += nreal
        bstats["launches"] += 1
        self.counters["prefills"] += nreal
        self.counters["prefill_launches"] += 1
        self.counters["prefill_rows"] += nreal
        event["prefill_launches"] += 1
        event["prefill_rows"] += nreal
        event["launches"] += 1

        lanes = np.zeros((npad,), np.int32)
        posv = np.zeros((npad,), np.int32)
        for i, r in enumerate(group):
            lanes[i] = r.slot
            posv[i] = r.prefill_target
        toks = self._pick_tokens(logits, lanes, posv)
        sel = np.zeros((self.sched.slots,), bool)
        src = np.zeros((self.sched.slots,), np.int32)
        vlen = np.ones((self.sched.slots,), np.int32)
        for i, r in enumerate(group):
            r.prefilled = r.prefill_target
            self._emit_first(r, int(toks[i]), event)
            if r.state == "active":  # not instantly eos/length-completed
                sel[r.slot] = True
                src[r.slot] = i
                vlen[r.slot] = r.prefill_target
        if sel.any():
            self.cache = self._insert_rows(
                self.cache, rows_cache, jnp.asarray(src), jnp.asarray(sel),
                jnp.asarray(vlen),
            )

    # -- the event loop body -------------------------------------------------

    def step(self):
        """One scheduler tick: (maybe) preempt, admit FIFO, one coalesced
        prefill launch per occupied bucket rung, one chunk launch for
        mid-prefill slots, one coalesced decode step over decoding slots.
        Returns the tick's event dict when any work ran, else False.  The
        event's ``launches`` counts compute launches only (prefill + chunk +
        decode; cache scatter/clear are memory ops) — the unit of the
        virtual-time cost model in :func:`replay_trace`."""
        now = self.clock.now()
        event = {"now": now, "admitted": [], "completed": [], "preempted": [],
                 "decoded": 0, "prefill_launches": 0, "prefill_rows": 0,
                 "chunk_rows": 0, "launches": 0}

        victim = self._preempt_if_starving(now)

        admitted = []
        while self._free and self.queue:
            req = self.queue.popleft()
            slot = self._free.pop(0)
            req.slot = slot
            req.slot_history.append(slot)
            req.state = "active"
            req.admitted_at = now
            req.bucket = bucket_for(req.seq_len, self.sched.ladder)
            req.prefill_target = req.seq_len
            req.prefilled = 0
            self.active[slot] = req
            self.counters["admitted"] += 1
            admitted.append(req)
            event["admitted"].append(req.rid)
        if victim is not None:
            self.queue.appendleft(victim)
            event["preempted"].append(victim.rid)

        if admitted and self.cache is None:
            self.cache = self._make_cache()

        ck = self.sched.prefill_chunk
        whole = [r for r in admitted if not ck or r.prefill_target <= ck]
        chunked = [r for r in admitted if ck and r.prefill_target > ck]

        # ONE coalesced launch per rung with pending whole-prompt prefills
        # (sequential mode degrades to one launch per admission — the PR 4
        # baseline, kept for A/B soak comparisons)
        by_bucket: dict = {}
        for r in whole:
            by_bucket.setdefault(r.bucket, []).append(r)
        for bucket in sorted(by_bucket):
            grp = by_bucket[bucket]
            if self.sched.prefill_mode == "sequential":
                for r in grp:
                    self._launch_prefill(bucket, [r], event)
            else:
                self._launch_prefill(bucket, grp, event)

        # chunk-admitted slots inherit stale ring entries from their previous
        # occupant — invalidate before the first chunk lands
        if chunked:
            sel = np.zeros((self.sched.slots,), bool)
            for r in chunked:
                sel[r.slot] = True
            self.cache = self._clear_rows(self.cache, jnp.asarray(sel))

        # ONE fixed-shape chunk launch streams every mid-prefill slot forward
        pending = [r for r in self.active.values()
                   if r.prefilled < r.prefill_target]
        if pending:
            slots = self.sched.slots
            tok = np.zeros((slots, ck), np.int32)
            t0 = np.full((slots,), -1, np.int32)
            nv = np.zeros((slots,), np.int32)
            for r in pending:
                seq = list(r.prompt) + list(r.generated)
                n = min(ck, r.prefill_target - r.prefilled)
                tok[r.slot, :n] = seq[r.prefilled: r.prefilled + n]
                t0[r.slot] = r.prefilled
                nv[r.slot] = n
            logits, self.cache = self._chunk(
                self.exec_params, jnp.asarray(tok), jnp.asarray(t0),
                jnp.asarray(nv), self.cache,
            )
            self.counters["chunk_steps"] += 1
            event["chunk_rows"] = len(pending)
            event["launches"] += 1
            finishers = []
            for r in pending:
                r.prefilled += int(nv[r.slot])
                if r.prefilled >= r.prefill_target:
                    finishers.append(r)
            if finishers:
                lanes = np.arange(slots, dtype=np.int32)
                posv = np.zeros((slots,), np.int32)
                for r in finishers:
                    posv[r.slot] = r.prefill_target
                toks = self._pick_tokens(logits, lanes, posv)
                for r in finishers:
                    self._emit_first(r, int(toks[r.slot]), event)

        # ONE coalesced decode step over every decoding slot; mid-chunk and
        # free lanes are gated off with t = -1 (their cache rows must not
        # move — the write mask keeps them byte-identical)
        decoding = {s: r for s, r in self.active.items()
                    if r.prefilled >= r.prefill_target}
        if decoding:
            slots = self.sched.slots
            tok = np.zeros((slots, 1), np.int32)
            tvec = np.full((slots,), -1, np.int32)
            for slot, req in decoding.items():
                tok[slot, 0] = req.generated[-1]
                tvec[slot] = req.t_next
            logits, self.cache = self._decode(
                self.exec_params, jnp.asarray(tok), jnp.asarray(tvec), self.cache
            )
            lanes = np.arange(slots, dtype=np.int32)
            posv = np.maximum(tvec + 1, 0)
            next_tok = self._pick_tokens(logits, lanes, posv)
            self.counters["decode_steps"] += 1
            self.counters["slot_steps"] += len(decoding)
            event["decoded"] = len(decoding)
            event["launches"] += 1
            for slot in sorted(decoding):
                req = decoding[slot]
                self.bucket_stats[req.bucket]["occupancy"] += 1
                t = int(next_tok[slot])
                req.generated.append(t)
                req.t_next += 1
                self.counters["tokens"] += 1
            for slot in sorted(decoding):
                req = decoding[slot]
                if req.eos_id is not None and req.generated[-1] == req.eos_id:
                    self._complete(req, "eos")
                    event["completed"].append((req.rid, "eos"))
                elif req.remaining <= 0:
                    self._complete(req, "length")
                    event["completed"].append((req.rid, "length"))

        worked = bool(event["admitted"] or event["decoded"]
                      or event["preempted"] or event["launches"])
        if not worked:
            return False
        self.history.append(event)
        return event

    def export_sessions(self) -> list:
        """JSON-serializable snapshots of every in-flight session.

        Active sessions first (in admission order — the FIFO order a
        restoring router must resubmit them in), then the queued backlog in
        queue order.  Together with the generated-so-far token lists this is
        everything a failover needs to resume the replica's work exactly
        (:mod:`repro.launch.router`); the checkpoint manager persists it as
        the manifest's ``extra``.
        """
        order = sorted(self.active, key=lambda s: (self.active[s].admitted_at, s))
        reqs = [self.active[s] for s in order] + list(self.queue)
        return [session_snapshot(r) for r in reqs]

    def drain(self, *, tick: float = 0.0, max_steps: int = 100_000) -> None:
        """Run the event loop until queue and slots are empty."""
        for _ in range(max_steps):
            if not (self.queue or self.active):
                return
            self.step()
            self.clock.sleep(tick)
        raise RuntimeError(f"scheduler did not drain in {max_steps} steps")

    # -- reporting -----------------------------------------------------------

    def _ttft(self) -> dict:
        """Time-to-first-token percentiles over completed requests."""
        waits = sorted(
            r.first_token_at - r.submitted_at
            for r in self.results.values() if r.first_token_at >= 0
        )
        out = {"n": len(waits)}
        if waits:
            arr = np.asarray(waits)
            out["p50"] = float(np.percentile(arr, 50))
            out["p99"] = float(np.percentile(arr, 99))
            out["mean"] = float(arr.mean())
        return out

    def stats(self) -> dict:
        c = self.counters
        reg = self.registry.stats()
        return {
            "counters": dict(c),
            "mean_occupancy": round(c["slot_steps"] / max(c["decode_steps"], 1), 3),
            "prefill_coalescing": round(
                c["prefill_rows"] / max(c["prefill_launches"], 1), 3),
            "ttft": self._ttft(),
            "buckets": {b: dict(s) for b, s in self.bucket_stats.items()},
            "registry": reg,
        }

    def stats_line(self) -> str:
        c = self.counters
        occ = c["slot_steps"] / max(c["decode_steps"], 1)
        coal = c["prefill_rows"] / max(c["prefill_launches"], 1)
        ttft = self._ttft()
        per_bucket = " ".join(
            f"{b}:{s['admitted']}a/{s['occupancy']}o/{s['misses']}m"
            for b, s in sorted(self.bucket_stats.items())
        )
        return (
            f"scheduler: submitted={c['submitted']} admitted={c['admitted']} "
            f"completed={c['completed']} rejected={c['rejected']} "
            f"preempted={c['preempted']} prefills={c['prefills']} "
            f"prefill_launches={c['prefill_launches']} coalescing={coal:.2f} "
            f"chunk_steps={c['chunk_steps']} "
            f"decode_steps={c['decode_steps']} tokens={c['tokens']} "
            f"mean_occupancy={occ:.2f} "
            f"ttft_p50={ttft.get('p50', 0.0):.3f} "
            f"ttft_p99={ttft.get('p99', 0.0):.3f} | "
            f"buckets[adm/occ/miss] {per_bucket}"
        )


# ---------------------------------------------------------------------------
# trace replay (the simulation harness — same loop production uses)
# ---------------------------------------------------------------------------


def replay_trace(sched: ServeScheduler, requests: Sequence[Request], *,
                 tick: float = 1.0, max_steps: int = 100_000,
                 launch_cost: float = 0.0) -> dict:
    """Drive the scheduler from a scripted arrival trace.

    ``arrival`` times are offsets from the start of the replay (the injected
    clock's reading at entry — a SystemClock reports absolute monotonic
    time, a VirtualClock usually 0): submissions become due as the clock
    passes start + arrival; when the scheduler is idle the clock jumps
    (virtual) or the process sleeps (production clock) to the next arrival.
    One `step()` per ``tick`` of clock time; ``launch_cost > 0`` additionally
    charges that much clock per compute launch the step issued (prefill,
    chunk, decode — the event's ``launches``), so batching fewer launches
    per tick measurably improves virtual-time TTFT/throughput, deterministic
    and machine-independent.  Returns `sched.stats()` once everything
    drains.
    """
    pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
    pending = collections.deque(pending)
    t0 = sched.clock.now()
    for _ in range(max_steps):
        elapsed = sched.clock.now() - t0
        while pending and pending[0].arrival <= elapsed:
            sched.submit(pending.popleft())
        if not (sched.queue or sched.active):
            if not pending:
                return sched.stats()
            sched.clock.sleep(pending[0].arrival - elapsed)
            continue
        ev = sched.step()
        n_launch = ev["launches"] if isinstance(ev, dict) else 0
        sched.clock.sleep(tick + launch_cost * n_launch)
    raise RuntimeError(f"trace did not drain in {max_steps} steps")


def synthetic_trace(n: int, *, seed: int = 0, vocab: int = 128,
                    ladder: Sequence[int] = (16, 32, 64), max_new: int = 8,
                    arrival_every: float = 0.0, eos_id: Optional[int] = None) -> list:
    """A deterministic mixed prompt-length trace (for benchmarks / soak).

    Lengths sweep the full ladder (from just-above the previous rung to the
    rung itself) so every bucket sees traffic; ``arrival_every > 0`` spaces
    arrivals out (uniform trace), 0 makes the trace bursty (all at t=0).
    """
    rng = np.random.default_rng(seed)
    lo = [1] + [int(b) + 1 for b in sorted(ladder)[:-1]]
    hi = sorted(int(b) for b in ladder)
    reqs = []
    for i in range(n):
        j = int(rng.integers(0, len(hi)))
        length = int(rng.integers(lo[j], hi[j] + 1))
        prompt = tuple(int(x) for x in rng.integers(0, vocab, size=length))
        reqs.append(Request(
            prompt=prompt,
            max_new=int(rng.integers(1, max_new + 1)),
            eos_id=eos_id,
            arrival=i * arrival_every,
        ))
    return reqs
