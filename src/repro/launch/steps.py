"""Step functions + abstract input/state specs — shared by the dry-run,
the training driver, and the serving driver.

Everything here is mesh-agnostic: callers pick a mesh + rule table and get
back (step_fn, abstract inputs, NamedSharding trees) ready for
``jax.jit(step_fn, in_shardings=..., out_shardings=..., donate_argnums=...)
.lower(...).compile()``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.core.template import Template, default_template
from repro.models import transformer as T
from repro.optim import AdamW, OptState, adamw_init, adamw_update, cosine_warmup
from repro.parallel.sharding import (
    ShardingRules,
    tree_shardings,
    use_mesh,
)

__all__ = [
    "make_train_step",
    "make_prefill_step",
    "make_decode_step",
    "input_specs",
    "abstract_params",
    "abstract_opt_state",
    "abstract_cache",
    "state_shardings",
    "batch_shardings",
    "cache_shardings",
    "cell_gemm_plans",
    "step_and_specs",
]


# ---------------------------------------------------------------------------
# abstract shapes (ShapeDtypeStruct stand-ins — no allocation)
# ---------------------------------------------------------------------------


def _ctx_spec(cfg: ArchConfig, batch: int):
    if cfg.family == "encdec":
        return jax.ShapeDtypeStruct((batch, cfg.n_frames, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        return jax.ShapeDtypeStruct((batch, cfg.n_image_tokens, cfg.d_model), jnp.float32)
    return None


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train/prefill: {tokens [, labels] [, ctx]};  decode: {token, t}.
    """
    b = shape.global_batch
    if shape.kind == "decode":
        return {
            "token": jax.ShapeDtypeStruct((b, 1), jnp.int32),
            "t": jax.ShapeDtypeStruct((), jnp.int32),
        }
    specs = {"tokens": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32)}
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32)
    ctx = _ctx_spec(cfg, b)
    if ctx is not None:
        specs["ctx"] = ctx
    return specs


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg))


def abstract_opt_state(cfg: ArchConfig):
    return jax.eval_shape(lambda: adamw_init(abstract_params(cfg)))


def abstract_cache(cfg: ArchConfig, batch: int, cache_len: int):
    return jax.eval_shape(lambda: T.init_cache(cfg, batch, cache_len))


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------


def state_shardings(cfg: ArchConfig, mesh, rules: ShardingRules):
    """(param_shardings, opt_shardings) NamedSharding trees."""
    p_shapes = abstract_params(cfg)
    p_axes = T.param_axes(cfg)
    p_sh = tree_shardings(mesh, rules, p_shapes, p_axes)
    o_shapes = abstract_opt_state(cfg)
    o_axes = OptState(step=None, m=p_axes, v=p_axes)
    o_sh = tree_shardings(mesh, rules, o_shapes, o_axes)
    return p_sh, o_sh


def batch_shardings(cfg: ArchConfig, shape: ShapeSpec, mesh, rules: ShardingRules):
    specs = input_specs(cfg, shape)
    axes = {}
    for k, v in specs.items():
        if k in ("tokens", "labels", "token"):
            axes[k] = ("batch", None)
        elif k == "ctx":
            axes[k] = ("batch", "ctx", None)
        else:  # scalar t
            axes[k] = None
    return tree_shardings(mesh, rules, specs, axes)


def cache_shardings(cfg: ArchConfig, cache_shapes, mesh, rules: ShardingRules):
    axes = T.cache_axes(cfg, cache_shapes)
    return tree_shardings(mesh, rules, cache_shapes, axes)


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------


def default_optimizer(total_steps: int = 10000) -> AdamW:
    return AdamW(lr=cosine_warmup(3e-4, min(2000, total_steps // 10 + 1), total_steps))


def make_train_step(cfg: ArchConfig, tpl: Optional[Template] = None,
                    opt: Optional[AdamW] = None, accum: int = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``accum`` > 1 splits the global batch into microbatches under lax.scan
    and accumulates grads in f32 (activation-memory knob for the big cells).
    """
    tpl = tpl or default_template()
    opt = opt or default_optimizer()

    def loss(params, batch):
        return T.loss_fn(tpl, cfg, params, batch)

    def train_step(params, opt_state, batch):
        if accum == 1:
            (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params, batch)
        else:
            def split(x):
                return x.reshape(accum, x.shape[0] // accum, *x.shape[1:])

            micro = jax.tree.map(split, batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def mb(carry, mbatch):
                gsum, lsum, auxsum = carry
                (l, m), g = jax.value_and_grad(loss, has_aux=True)(params, mbatch)
                gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l, auxsum + m["aux"]), None

            (gsum, lsum, auxsum), _ = jax.lax.scan(
                mb, (g0, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), micro
            )
            grads = jax.tree.map(lambda gg: (gg / accum), gsum)
            l = lsum / accum
            metrics = {"ce": l, "aux": auxsum / accum}
        new_params, new_opt, om = adamw_update(opt, grads, opt_state, params)
        return new_params, new_opt, {**metrics, **om, "loss": l}

    return train_step


def make_prefill_step(cfg: ArchConfig, tpl: Optional[Template] = None,
                      cache_len: Optional[int] = None):
    """(params, batch) -> (last-pos logits, filled decode cache)."""
    tpl = tpl or default_template()

    def prefill_step(params, batch):
        return T.prefill(
            tpl, cfg, params, batch["tokens"], ctx=batch.get("ctx"),
            cache_len=cache_len,
        )

    return prefill_step


def make_decode_step(cfg: ArchConfig, tpl: Optional[Template] = None):
    """(params, cache, batch{token, t}) -> (logits, new cache)."""
    tpl = tpl or default_template()

    def decode_step(params, cache, batch):
        return T.decode_step(tpl, cfg, params, batch["token"], batch["t"], cache)

    return decode_step


# ---------------------------------------------------------------------------
# sharding-aware GEMM planning for a cell
# ---------------------------------------------------------------------------


def cell_gemm_plans(cfg: ArchConfig, shape: ShapeSpec, mesh,
                    rules: ShardingRules, tpl: Optional[Template] = None) -> dict:
    """Plan the cell's dominant GEMMs at their *local* per-shard shapes.

    Threads the mesh + the cell's logical-axis rule table into
    ``Engine.plan_gemm``: M is the token dim sharded by the "batch" rule, N
    by each projection's own logical axis ("qkv"/"mlp"/"vocab"), and the MLP
    down-projection contracts over the model-sharded ff dim.  On a Pallas/q16
    template this warms the plan registry with exactly the shapes each shard
    executes; on the xla backend it still records the local geometry (blocks
    are XLA's own there).
    """
    from jax.sharding import PartitionSpec as P

    tpl = tpl or default_template()
    eng = tpl.engine
    m = shape.tokens
    d = cfg.d_model
    batch_axes = rules.get("batch")

    def plan(n, k, n_axis=None, k_axis=None):
        part = P(batch_axes, rules.get(n_axis) if n_axis else None,
                 rules.get(k_axis) if k_axis else None)
        return eng.plan_gemm(m, n, k, mesh=mesh, partition=part)

    return {
        "qkv": plan((cfg.eff_heads + 2 * cfg.n_kv_heads) * cfg.head_dim, d,
                    n_axis="qkv"),
        "attn_out": plan(d, cfg.eff_heads * cfg.head_dim, k_axis="qkv"),
        "mlp_up": plan(cfg.d_ff, d, n_axis="mlp"),
        "mlp_down": plan(d, cfg.d_ff, k_axis="mlp"),
        "lm_head": plan(cfg.vocab, d, n_axis="vocab"),
    }


# ---------------------------------------------------------------------------
# one-call assembly for a dry-run cell
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CellSpec:
    """Everything needed to lower one (arch x shape x mesh) cell."""

    step_fn: object
    args: tuple  # abstract args, in order
    in_shardings: tuple
    out_shardings: object
    donate_argnums: tuple
    kind: str
    #: local per-shard GemmPlans of the cell's dominant projections
    #: (qkv / attn_out / mlp_up / mlp_down / lm_head), from cell_gemm_plans
    gemm_plans: dict = dataclasses.field(default_factory=dict)


def step_and_specs(cfg: ArchConfig, shape: ShapeSpec, mesh,
                   rules: ShardingRules, accum: int = 1,
                   tpl: Optional[Template] = None) -> CellSpec:
    """Build the jit-ready (fn, abstract args, shardings) for one cell.

    ``tpl`` is forwarded to both the step functions and the cell's GEMM
    planning — pass a Pallas/q16 template to warm the plan registry with the
    cell's local per-shard shapes (the default xla template records the
    local geometry but leaves block selection to XLA).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    repl = NamedSharding(mesh, P())
    specs = input_specs(cfg, shape)
    b_sh = batch_shardings(cfg, shape, mesh, rules)
    p_shapes = abstract_params(cfg)
    p_sh, o_sh = state_shardings(cfg, mesh, rules)
    plans = cell_gemm_plans(cfg, shape, mesh, rules, tpl)

    if shape.kind == "train":
        fn = make_train_step(cfg, tpl=tpl, accum=accum)
        o_shapes = abstract_opt_state(cfg)
        metrics_sh = None  # replicated outputs
        return CellSpec(
            step_fn=fn,
            args=(p_shapes, o_shapes, specs),
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, jax.tree.map(lambda _: repl, {
                "ce": 0, "aux": 0, "grad_norm": 0, "lr": 0, "loss": 0})),
            donate_argnums=(0, 1),
            kind="train",
            gemm_plans=plans,
        )
    if shape.kind == "prefill":
        fn = make_prefill_step(cfg, tpl=tpl, cache_len=shape.seq_len)
        c_shapes = abstract_cache(cfg, shape.global_batch, shape.seq_len)
        c_sh = cache_shardings(cfg, c_shapes, mesh, rules)
        logits_sh = None
        return CellSpec(
            step_fn=fn,
            args=(p_shapes, specs),
            in_shardings=(p_sh, b_sh),
            out_shardings=(None, c_sh),
            donate_argnums=(),
            kind="prefill",
            gemm_plans=plans,
        )
    # decode
    fn = make_decode_step(cfg, tpl=tpl)
    c_shapes = abstract_cache(cfg, shape.global_batch, shape.seq_len)
    c_sh = cache_shardings(cfg, c_shapes, mesh, rules)
    return CellSpec(
        step_fn=fn,
        args=(p_shapes, c_shapes, specs),
        in_shardings=(p_sh, c_sh, b_sh),
        out_shardings=(None, c_sh),
        donate_argnums=(1,),
        kind="decode",
        gemm_plans=plans,
    )
