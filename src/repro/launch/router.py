"""Front-tier request router over N data-parallel ServeScheduler replicas.

The scale-out layer of the serving stack (DESIGN.md §9): the paper's
template scales by re-instantiating one compute unit across device sizes;
the serving analogue replicates one :class:`~repro.launch.scheduler.
ServeScheduler` (each replica optionally running its decode step
tensor-parallel over a mesh) behind a single admission queue.  Three design
rules keep the composition as deterministic as its parts:

* **One clock, integer ticks.**  The router drives every replica from the
  same injectable clock, one ``step()`` per router tick.  Faults are
  injected through a :class:`~repro.runtime.failover.FaultPlan` keyed to
  those ticks, so a (trace, fault plan) pair replays to the same token
  stream every run.
* **Exactly-once tokens via the ledger.**  Every generated token is drained
  into a :class:`TokenLedger` (rid -> append-only stream) each tick.  After
  a kill, the dead replica's in-flight sessions are rebuilt from its last
  checkpoint (``checkpoint/manager.py`` ``extra`` carries
  ``export_sessions()`` snapshots) — or from the router's own admission
  record when the session was admitted after the last checkpoint — and
  resubmitted in their original FIFO order.  Greedy decode is a pure
  function of (params, prompt, generated-so-far), so a resumed session
  regenerates byte-identical tokens; positions the ledger already holds are
  verified equal and suppressed as duplicates.  Net effect: zero lost and
  zero duplicated tokens, proven by byte-comparing the final ledger against
  an unkilled single-replica run.
* **Loud unrecoverability.**  A resumed session a replica refuses (e.g. its
  re-prefill no longer fits the bucket ladder — give the top rung
  ``max prompt + max_new`` headroom) raises instead of silently losing
  tokens.

Replica death is modeled, not real, in-process: the replica's scheduler
object is dropped (its KV cache, slots, and queue go with it), a fresh
incarnation warm-starts after ``restart_delay`` ticks, and the cross-process
variant — real killed worker processes sharing one flock'd plan store — is
exercised by ``benchmarks/router_soak.py``.
"""
from __future__ import annotations

import collections
import dataclasses
import os
from typing import Callable, Optional, Sequence

import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.engine import save_plan_store
from repro.launch.scheduler import (
    Request,
    VirtualClock,
    request_from_snapshot,
    session_snapshot,
)
from repro.runtime.failover import FaultPlan

__all__ = ["Assignment", "ReplicaRouter", "TokenLedger"]


class TokenLedger:
    """Append-only per-session token streams with duplicate suppression.

    ``record(rid, pos, tok)`` appends when ``pos`` is the next position of
    the stream; re-emissions of an already-recorded position must match
    byte-for-byte (they are a resumed replica regenerating its greedy
    prefix) and are counted, not stored.  A *mismatched* re-emission or a
    gap means the exactly-once protocol broke — both raise immediately
    rather than corrupting the stream.
    """

    def __init__(self) -> None:
        self._streams: dict = {}
        self.duplicates_suppressed = 0

    def record(self, rid: int, pos: int, tok: int) -> bool:
        stream = self._streams.setdefault(rid, [])
        if pos < len(stream):
            if stream[pos] != tok:
                raise RuntimeError(
                    f"ledger divergence: session {rid} position {pos} "
                    f"re-emitted as {tok}, previously {stream[pos]}")
            self.duplicates_suppressed += 1
            return False
        if pos > len(stream):
            raise RuntimeError(
                f"ledger gap: session {rid} emitted position {pos} but "
                f"stream holds {len(stream)} tokens")
        stream.append(int(tok))
        return True

    def tokens(self, rid: int) -> list:
        return list(self._streams.get(rid, ()))

    def as_dict(self) -> dict:
        return {rid: list(s) for rid, s in self._streams.items()}


@dataclasses.dataclass
class Assignment:
    """One (session -> replica incarnation) placement interval.  ``seq`` is
    a router-global routing sequence number: placements are totally ordered
    by it, which is what the requeue-FIFO-preservation asserts compare."""

    replica: int
    incarnation: int
    start_tick: int
    seq: int = 0
    end_tick: Optional[int] = None
    end_reason: str = ""  # "completed" | "killed"


@dataclasses.dataclass
class _Replica:
    rid: int
    sched: object = None
    incarnation: int = 0
    alive: bool = False
    restart_at: Optional[int] = 0  # tick to (re)start at; None while running
    assigned: dict = dataclasses.field(default_factory=dict)  # rid -> Request
    seen: dict = dataclasses.field(default_factory=dict)  # rid -> harvested
    graveyard: list = dataclasses.field(default_factory=list)


class ReplicaRouter:
    """Admit requests across N scheduler replicas; survive replica death.

    ``make_scheduler(replica_id, clock)`` builds one replica's
    :class:`ServeScheduler` (the factory decides model, policy, mesh).  All
    replicas share the router's clock; ``checkpoint_dir`` enables per-replica
    session checkpoints every ``checkpoint_every`` ticks (written *after*
    the tick's step + token harvest, so a checkpoint never leads the
    ledger); ``store_path``/``store_save_every`` periodically merge each
    replica's plans into the shared flock'd plan store, honoring
    ``FaultPlan.delayed_saves``.
    """

    def __init__(self, make_scheduler: Callable, n_replicas: int, *,
                 clock=None, fault_plan: Optional[FaultPlan] = None,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 1, restart_delay: int = 1,
                 store_path: Optional[str] = None, store_save_every: int = 0,
                 warmup: bool = True, tick_dt: float = 1.0) -> None:
        if n_replicas < 1:
            raise ValueError("need at least one replica")
        self.make_scheduler = make_scheduler
        self.clock = clock or VirtualClock()
        self.fault_plan = fault_plan or FaultPlan()
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = max(1, int(checkpoint_every))
        self.restart_delay = max(1, int(restart_delay))
        self.store_path = store_path
        self.store_save_every = int(store_save_every)
        self.warmup = warmup
        self.tick_dt = float(tick_dt)

        self.replicas = [_Replica(rid=i) for i in range(n_replicas)]
        self.pending: collections.deque = collections.deque()
        self.ledger = TokenLedger()
        self.accepted: dict = {}  # rid -> admission-time snapshot (fresh)
        self.assignments: dict = {}  # rid -> [Assignment, ...]
        self.completed: set = set()
        self.rejected: set = set()
        self.counters: collections.Counter = collections.Counter()
        self.store_save_log: list = []
        self._pending_saves: list = []  # (actual_tick, replica, due_tick)
        self._mgrs: dict = {}
        self.tick_index = 0
        for rep in self.replicas:
            self._start(rep, 0)

    # -- replica lifecycle ---------------------------------------------------

    def _ckpt_mgr(self, rep: _Replica) -> Optional[CheckpointManager]:
        if self.checkpoint_dir is None:
            return None
        mgr = self._mgrs.get(rep.rid)
        if mgr is None:
            mgr = CheckpointManager(
                os.path.join(self.checkpoint_dir, f"replica_{rep.rid}"))
            self._mgrs[rep.rid] = mgr
        return mgr

    def _start(self, rep: _Replica, tick: int) -> None:
        rep.sched = self.make_scheduler(rep.rid, self.clock)
        if self.warmup:
            rep.sched.warmup()
        rep.alive = True
        rep.restart_at = None
        rep.seen = {}
        self.counters["replica_starts"] += 1
        if tick > 0:
            self.counters["restarted"] += 1

    def _kill(self, rep: _Replica, tick: int) -> None:
        """Replica death: recover its in-flight sessions, schedule restart.

        Recovery source of truth, per session and in the replica's original
        assignment (FIFO) order: the last checkpoint's snapshot when present
        (``restored_*`` counters), else the router's admission record (the
        session was admitted after the last checkpoint — requeued fresh).
        Recovered sessions go to the *front* of the router queue so their
        original FIFO standing is preserved relative to not-yet-routed work.
        """
        self.counters["killed"] += 1
        rep.graveyard.append((rep.incarnation, rep.sched))
        snaps: dict = {}
        mgr = self._ckpt_mgr(rep)
        if mgr is not None:
            _, extra = mgr.latest_extra()
            if extra:
                snaps = {int(s["rid"]): s for s in extra.get("sessions", ())}
        recovered = []
        for rid, req in rep.assigned.items():
            if rid in self.completed:
                continue
            recs = self.assignments.get(rid)
            if recs:
                recs[-1].end_reason = "killed"
                recs[-1].end_tick = tick
            if rid in snaps:
                nreq = request_from_snapshot(snaps[rid])
                self.counters["restored_sessions"] += 1
                self.counters["restored_tokens"] += len(nreq.generated)
            else:
                nreq = request_from_snapshot(self.accepted[rid])
                self.counters["requeued_fresh"] += 1
            recovered.append(nreq)
        self.counters["requeued_sessions"] += len(recovered)
        for nreq in reversed(recovered):
            self.pending.appendleft(nreq)
        rep.assigned = {}
        rep.sched = None
        rep.alive = False
        rep.incarnation += 1
        rep.restart_at = tick + self.restart_delay

    # -- admission -----------------------------------------------------------

    def submit(self, req: Request) -> None:
        """Enqueue at the front tier; replica placement happens at tick."""
        self.counters["submitted"] += 1
        self.pending.append(req)

    def _route(self, tick: int) -> None:
        """Place queued requests FIFO onto the least-loaded live replica
        (ties to the lowest replica id — deterministic), skipping replicas
        in a FaultPlan admission-reject window or with a full queue."""
        while self.pending:
            candidates = [
                rep for rep in self.replicas
                if rep.alive
                and not self.fault_plan.rejects_admission(rep.rid, tick)
                and len(rep.sched.queue) < rep.sched.sched.max_queue
            ]
            if not candidates:
                self.counters["route_stalls"] += 1
                return
            rep = min(candidates,
                      key=lambda r: (len(r.sched.queue) + len(r.sched.active),
                                     r.rid))
            req = self.pending.popleft()
            if not rep.sched.submit(req):
                if req.generated or req.rid in self.accepted:
                    raise RuntimeError(
                        f"unrecoverable: replica {rep.rid} rejected resumed "
                        f"session {req.rid} (seq_len={req.seq_len}, "
                        f"remaining={req.remaining}) — the ladder needs "
                        f"max prompt + max_new headroom in its top rung")
                self.rejected.add(req.rid)
                self.counters["rejected"] += 1
                continue
            if req.rid not in self.accepted:
                self.accepted[req.rid] = session_snapshot(req)
            rep.assigned[req.rid] = req
            rep.seen[req.rid] = len(req.generated)
            self.counters["assignments"] += 1
            self.assignments.setdefault(req.rid, []).append(
                Assignment(rep.rid, rep.incarnation, tick,
                           seq=self.counters["assignments"]))

    # -- the event loop body -------------------------------------------------

    def _harvest(self, rep: _Replica, tick: int) -> None:
        finished = []
        for rid, req in rep.assigned.items():
            cur = rep.seen.get(rid, 0)
            for pos in range(cur, len(req.generated)):
                if self.ledger.record(rid, pos, req.generated[pos]):
                    self.counters["ledger_tokens"] += 1
            rep.seen[rid] = len(req.generated)
            if req.state == "completed":
                self.completed.add(rid)
                recs = self.assignments.get(rid)
                if recs:
                    recs[-1].end_reason = "completed"
                    recs[-1].end_tick = tick
                finished.append(rid)
        for rid in finished:
            rep.assigned.pop(rid)
            rep.seen.pop(rid, None)

    def _store_saves(self, tick: int) -> None:
        if self.store_path and self.store_save_every > 0 and tick > 0:
            if tick % self.store_save_every == 0:
                for rep in self.replicas:
                    if rep.alive:
                        delay = self.fault_plan.save_delay(rep.rid, tick)
                        self._pending_saves.append((tick + delay, rep.rid, tick))
        due_now = [s for s in self._pending_saves if s[0] <= tick]
        self._pending_saves = [s for s in self._pending_saves if s[0] > tick]
        for actual, rid, due in due_now:
            save_plan_store(self.store_path)
            self.counters["store_saves"] += 1
            self.store_save_log.append(
                {"replica": rid, "due": due, "actual": tick})

    def tick(self) -> dict:
        """One router tick: fire kills, restart, route, step every live
        replica, harvest tokens, checkpoint, flush store saves."""
        tick = self.tick_index
        event = {"tick": tick, "killed": [], "restarted": [], "stepped": 0}
        for rid in self.fault_plan.kills_at(tick):
            rep = self.replicas[rid]
            if rep.alive:
                self._kill(rep, tick)
                event["killed"].append(rid)
        for rep in self.replicas:
            if not rep.alive and rep.restart_at is not None \
                    and rep.restart_at <= tick:
                self._start(rep, tick)
                event["restarted"].append(rep.rid)
        self._route(tick)
        for rep in self.replicas:
            if rep.alive and (rep.sched.queue or rep.sched.active):
                rep.sched.step()
                event["stepped"] += 1
        for rep in self.replicas:
            if rep.alive:
                self._harvest(rep, tick)
        if self.checkpoint_dir is not None and \
                tick % self.checkpoint_every == 0:
            for rep in self.replicas:
                if rep.alive:
                    self._ckpt_mgr(rep).save(
                        tick, {"tick": np.asarray(tick, np.int64)},
                        extra={"tick": tick,
                               "sessions": rep.sched.export_sessions()})
                    self.counters["checkpoints"] += 1
        self._store_saves(tick)
        self.clock.sleep(self.tick_dt)
        self.tick_index += 1
        return event

    def _drained(self, arrivals) -> bool:
        if arrivals or self.pending:
            return False
        for rep in self.replicas:
            if not rep.alive:
                if rep.restart_at is not None:
                    return False  # restart still owes us a live replica
            elif rep.sched.queue or rep.sched.active:
                return False
        return True

    def run(self, requests: Sequence[Request], *,
            max_ticks: int = 100_000) -> dict:
        """Drive a scripted arrival trace to completion; returns stats()."""
        arrivals = collections.deque(
            sorted(requests, key=lambda r: (r.arrival, r.rid)))
        t0 = self.clock.now()
        for _ in range(max_ticks):
            elapsed = self.clock.now() - t0
            while arrivals and arrivals[0].arrival <= elapsed:
                self.submit(arrivals.popleft())
            if self._drained(arrivals):
                return self.stats()
            self.tick()
        raise RuntimeError(f"router did not drain in {max_ticks} ticks")

    # -- exactly-once verification (the harness asserts) ---------------------

    def verify_against(self, reference: dict) -> None:
        """Byte-compare the ledger to a reference {rid: tokens} run.

        Zero lost tokens (every reference stream present and complete) and
        zero duplicated tokens (no extra sessions or over-long streams; any
        re-emission already had to match byte-for-byte to be suppressed).
        """
        led = self.ledger.as_dict()
        missing = set(reference) - set(led)
        extra = set(led) - set(reference)
        if missing or extra:
            raise AssertionError(
                f"ledger session mismatch: missing={sorted(missing)} "
                f"extra={sorted(extra)}")
        for rid, want in reference.items():
            if led[rid] != list(want):
                raise AssertionError(
                    f"session {rid} stream diverged: {led[rid]} != {list(want)}")

    def assert_exactly_once(self) -> None:
        """Every completed session was served exactly once per incarnation:
        all non-final placements ended by a kill, the final one completed."""
        for rid in self.completed:
            recs = self.assignments[rid]
            for rec in recs[:-1]:
                if rec.end_reason != "killed":
                    raise AssertionError(
                        f"session {rid} left replica {rec.replica} with "
                        f"reason {rec.end_reason!r} but was re-placed")
            if recs[-1].end_reason != "completed":
                raise AssertionError(
                    f"session {rid} final placement ended "
                    f"{recs[-1].end_reason!r}, not completed")

    # -- reporting -----------------------------------------------------------

    def stats(self) -> dict:
        per = {}
        for rep in self.replicas:
            if rep.sched is not None:
                s = rep.sched.stats()
                per[rep.rid] = {
                    "incarnation": rep.incarnation,
                    "alive": rep.alive,
                    "mean_occupancy": s["mean_occupancy"],
                    "ttft": s["ttft"],
                    "counters": s["counters"],
                }
        return {
            "ticks": self.tick_index,
            "counters": dict(self.counters),
            "completed": len(self.completed),
            "rejected": len(self.rejected),
            "duplicates_suppressed": self.ledger.duplicates_suppressed,
            "ledger_sessions": len(self.ledger.as_dict()),
            "replicas": per,
        }

    def stats_line(self) -> str:
        """One-line per-replica occupancy/TTFT rollup + failover counters."""
        c = self.counters
        per = []
        for rep in self.replicas:
            if rep.sched is None:
                per.append(f"r{rep.rid}[dead]")
                continue
            s = rep.sched.stats()
            ttft = s["ttft"].get("p50", 0.0)
            per.append(
                f"r{rep.rid}[inc={rep.incarnation} "
                f"occ={s['mean_occupancy']:.2f} ttft_p50={ttft:.2f} "
                f"done={s['counters'].get('completed', 0)}]")
        return (
            f"router: replicas={len(self.replicas)} ticks={self.tick_index} "
            f"submitted={c['submitted']} completed={len(self.completed)} "
            f"rejected={len(self.rejected)} killed={c['killed']} "
            f"restarted={c['restarted']} requeued={c['requeued_sessions']} "
            f"restored={c['restored_sessions']} "
            f"restored_tokens={c['restored_tokens']} "
            f"dup_suppressed={self.ledger.duplicates_suppressed} "
            f"store_saves={c['store_saves']} | " + " ".join(per)
        )
