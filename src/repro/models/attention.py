"""Attention: GQA/MHA, causal/sliding-window/cross, KV-cache prefill+decode.

Projections route through the Template compute unit; the attention math
itself runs on the XLA plane (GSPMD shards it) with two strategies:

* dense  — full (B,H,S,T) scores; used when S*T is small.
* chunked — memory-efficient online-softmax over (q-chunk, k-chunk) pairs
  under two nested ``lax.scan``s (the XLA-plane analogue of the Pallas flash
  kernel; the kernel itself is the TPU-target artifact in kernels/).
  Baseline computes all chunk pairs with masking; the causal-waste is
  attacked in the §Perf hillclimb.

Cache layout per layer: {"k","v": (B, Hkv, C, D), "pos": (C,) int32} — a ring
buffer (slot = pos % C) so sliding-window layers carry only window-sized
caches (the long_500k cell for hybrid archs).

Slot-indexed (continuous-batching) variant: with ``per_slot=True`` the pos
vector is per-batch-row — (B, C) — and ``decode_attention`` accepts a
*vector* position t: (B,), so every batch row can sit at a different decode
position.  This is the cache layout the serve scheduler
(`launch/scheduler.py`) coalesces independent sessions into.  The per-slot
path additionally generalizes to a *block* of S tokens per row (chunked
prefill: positions t[b]..t[b]+S-1) with per-row write gating — t[b] < 0
marks an inactive lane whose cache row must not change, and ``n_valid``
bounds how many of the S tokens are real (ragged final chunks) — so one
fixed-shape launch serves any mix of chunking / decoding / idle slots.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantization import NumericsPolicy, QTensor
from repro.core.template import Template
from repro.parallel.sharding import constrain

from .layers import apply_rope, init_dense, dense

__all__ = [
    "init_attention",
    "attention_axes",
    "attention",
    "attention_islands",
    "decode_attention",
    "init_layer_cache",
    "CHUNKED_THRESHOLD",
]

_NEG = -1e30
#: use the chunked path when key length reaches this (4096: even train_4k
#: must not materialize (B,H,S,S) scores — 15 GiB/device at B_local=16)
CHUNKED_THRESHOLD = 4096
_BQ, _BK = 1024, 1024


def init_attention(key, cfg, *, d_model=None, n_heads=None, n_kv=None,
                   head_dim=None, bias=None, dtype=jnp.float32):
    d = d_model or cfg.d_model
    h = n_heads or cfg.eff_heads
    kv = n_kv or cfg.n_kv_heads
    hd = head_dim or cfg.head_dim
    bias = cfg.qkv_bias if bias is None else bias
    ks = jax.random.split(key, 4)
    return {
        "wq": init_dense(ks[0], d, h * hd, bias=bias, dtype=dtype),
        "wk": init_dense(ks[1], d, kv * hd, bias=bias, dtype=dtype),
        "wv": init_dense(ks[2], d, kv * hd, bias=bias, dtype=dtype),
        "wo": init_dense(ks[3], h * hd, d, dtype=dtype, scale=(h * hd) ** -0.5),
    }


def attention_axes(cfg, bias=None) -> dict:
    bias = cfg.qkv_bias if bias is None else bias
    ax = {
        "wq": {"w": ("embed", "qkv")},
        "wk": {"w": ("embed", "qkv")},
        "wv": {"w": ("embed", "qkv")},
        "wo": {"w": ("qkv", "embed")},
    }
    if bias:
        for k in ("wq", "wk", "wv"):
            ax[k]["b"] = ("qkv",)
    return ax


def init_layer_cache(batch: int, n_kv: int, cache_len: int, head_dim: int, dtype,
                     per_slot: bool = False) -> dict:
    """Zero k/v ring cache.  ``per_slot`` gives each batch row its own pos
    vector — (B, C) instead of the shared (C,) — so rows can decode at
    independent positions (continuous batching)."""
    pos_shape = (batch, cache_len) if per_slot else (cache_len,)
    return {
        "k": jnp.zeros((batch, n_kv, cache_len, head_dim), dtype),
        "v": jnp.zeros((batch, n_kv, cache_len, head_dim), dtype),
        "pos": jnp.full(pos_shape, -1, jnp.int32),
    }


def _split_heads(x: jax.Array, n: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n, -1)


# ---------------------------------------------------------------------------
# score/value math
# ---------------------------------------------------------------------------


def _sdpa_dense(q, k, v, mask) -> jax.Array:
    """q: (B,S,H,D); k/v: (B,T,Hkv,D); mask: (B,1,S,T) or None -> (B,S,H,D)."""
    b, s, h, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, s, hkv, g, d)
    scores = jnp.einsum("bshgd,bthd->bhgst", qg.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores / (d ** 0.5)
    if mask is not None:
        scores = jnp.where(mask[:, :, None], scores, _NEG)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", p, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)


def _sdpa_folded(qc, kc, vc, *, t: int, bq: int, bk: int, scale: float):
    """Causal attention over the lower triangle only, statically.

    Fold q-chunks (r, n-1-r): row r processes q-chunk r for k-chunks 0..r and
    q-chunk n-1-r for k-chunks 0..n-1-r — (n+1) single-tile steps per folded
    row, n/2 rows => n(n+1)/2 tiles instead of the n^2 masked rectangle.
    This is the flash-attention causal schedule expressed in XLA (§Perf C).

    qc/kc/vc: (n, B, bq|bk, H, D) with n even.  Returns (n, B, bq, H, D).
    """
    n, b, _, h, d = qc.shape
    half = n // 2
    qa = qc[:half]  # row r -> q-chunk r
    qb = qc[::-1][:half]  # row r -> q-chunk n-1-r

    def row_body(_, xs):
        r, qA, qB = xs  # (B,bq,H,D) each

        @jax.checkpoint
        def k_body(state, j):
            mA, lA, aA, mB, lB, aB = state
            is_a = j <= r
            kidx = jnp.where(is_a, j, j - (r + 1))
            kblk = jnp.take(kc, kidx, axis=0)  # (B,bk,H,D)
            vblk = jnp.take(vc, kidx, axis=0)
            qblk = jnp.where(is_a, qA, qB)
            row_chunk = jnp.where(is_a, r, n - 1 - r)
            rows = row_chunk * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = kidx * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            srt = jnp.einsum(
                "bqhd,bkhd->bhqk", qblk.astype(jnp.float32), kblk.astype(jnp.float32)
            ) * scale
            valid = (rows >= cols) & (cols < t)
            srt = jnp.where(valid[None, None], srt, _NEG)
            m_prev = jnp.where(is_a, mA, mB)
            l_prev = jnp.where(is_a, lA, lB)
            a_prev = jnp.where(is_a, aA, aB)
            m_new = jnp.maximum(m_prev, srt.max(-1))
            p = jnp.exp(srt - m_new[..., None])
            alpha = jnp.exp(m_prev - m_new)
            l_new = l_prev * alpha + p.sum(-1)
            a_new = a_prev * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vblk.astype(jnp.float32)
            )
            mA2 = jnp.where(is_a, m_new, mA)
            lA2 = jnp.where(is_a, l_new, lA)
            aA2 = jnp.where(is_a, a_new, aA)
            mB2 = jnp.where(is_a, mB, m_new)
            lB2 = jnp.where(is_a, lB, l_new)
            aB2 = jnp.where(is_a, aB, a_new)
            return (mA2, lA2, aA2, mB2, lB2, aB2), None

        z3 = jnp.full((b, h, bq), _NEG, jnp.float32)
        z0 = jnp.zeros((b, h, bq), jnp.float32)
        a0 = jnp.zeros((b, h, bq, d), jnp.float32)
        (mA, lA, aA, mB, lB, aB), _ = jax.lax.scan(
            k_body, (z3, z0, a0, z3, z0, a0), jnp.arange(n + 1)
        )
        outA = aA / jnp.maximum(lA[..., None], 1e-30)
        outB = aB / jnp.maximum(lB[..., None], 1e-30)
        return None, (jnp.moveaxis(outA, 2, 1), jnp.moveaxis(outB, 2, 1))

    _, (outsA, outsB) = jax.lax.scan(
        jax.checkpoint(row_body), None, (jnp.arange(half), qa, qb)
    )
    # rows 0..half-1 from A; rows n-1..half (reversed) from B
    return jnp.concatenate([outsA, outsB[::-1]], axis=0)


def _sdpa_chunked(q, k, v, *, causal: bool, window: int, q_offset: int,
                  bq: int = _BQ, bk: int = _BK) -> jax.Array:
    """Online-softmax attention over chunk pairs; memory O(bq*bk) per head.

    q: (B,S,H,D); k/v: (B,T,Hkv,D).  Rows are global positions q_offset+i;
    cols are 0..T-1.  Pure-causal self-attention takes the folded triangular
    schedule (~2x fewer chunk GEMMs); other cases the masked rectangle.
    """
    b, s, h, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    if g > 1:
        # GQA: replicate KV to flat heads so the head dim (40, 64, ...) is
        # shardable over 16-way TP.  The (hkv, g) factored layout replicates
        # attention over every chip (both 8 and 5 < 16); flat heads shard.
        # The extra KV reads are O(S*Hkv*D*g) — noise next to the p-matrix.
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    q = constrain(q, "batch", None, "act_heads", None)
    k = constrain(k, "batch", None, "act_heads", None)
    v = constrain(v, "batch", None, "act_heads", None)
    bq = min(bq, s)
    bk = min(bk, t)
    sp, tp = -(-s // bq) * bq, -(-t // bk) * bk
    qp = jnp.pad(q, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, tp - t), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, tp - t), (0, 0), (0, 0)))
    nq, nk = sp // bq, tp // bk
    scale = 1.0 / (d ** 0.5)

    use_folded = (
        causal and not window and q_offset == 0 and s == t
        and bq == bk and nq == nk and nq >= 2 and nq % 2 == 0
    )
    qc = jnp.moveaxis(qp.reshape(b, nq, bq, h, d), 1, 0)  # (nq,B,bq,H,D)
    kc = jnp.moveaxis(kp.reshape(b, nk, bk, h, d), 1, 0)  # (nk,B,bk,H,D)
    vc = jnp.moveaxis(vp.reshape(b, nk, bk, h, d), 1, 0)

    if use_folded:
        outs = _sdpa_folded(qc, kc, vc, t=t, bq=bq, bk=bk, scale=scale)
        out = jnp.moveaxis(outs, 0, 1).reshape(b, sp, h, d)[:, :s]
        return out.astype(q.dtype)

    def q_body(_, qi_and_q):
        qi, qblk = qi_and_q  # qblk: (B,bq,H,D)
        rows = q_offset + qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)

        @jax.checkpoint
        def k_body(state, ki_and_kv):
            m, l, acc = state
            ki, kblk, vblk = ki_and_kv
            cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            srt = jnp.einsum(
                "bqhd,bkhd->bhqk", qblk.astype(jnp.float32), kblk.astype(jnp.float32)
            ) * scale  # (B,H,bq,bk)
            valid = cols < t
            if causal:
                valid &= rows >= cols
                if window:
                    valid &= (rows - cols) < window
            srt = jnp.where(valid[None, None], srt, _NEG)
            m_new = jnp.maximum(m, srt.max(-1))
            p = jnp.exp(srt - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vblk.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, bq), _NEG, jnp.float32)
        l0 = jnp.zeros((b, h, bq), jnp.float32)
        a0 = jnp.zeros((b, h, bq, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            k_body, (m0, l0, a0), (jnp.arange(nk), kc, vc)
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)  # (B,H,bq,D)
        return None, jnp.moveaxis(out, 2, 1)  # (B,bq,H,D)

    # checkpoint both scan bodies: backward recomputes scores per chunk pair
    # (flash-attention backward) instead of storing (bq, bk) probabilities
    # for every pair — O(S*D) residuals instead of O(S^2).
    _, outs = jax.lax.scan(jax.checkpoint(q_body), None, (jnp.arange(nq), qc))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sp, h, d)[:, :s]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# full-sequence attention (train / prefill)
# ---------------------------------------------------------------------------


def attention(
    tpl: Template,
    p,
    x: jax.Array,
    *,
    cfg,
    positions: jax.Array,
    causal: bool = True,
    window: int = 0,
    kv_source: Optional[jax.Array] = None,
    n_heads: Optional[int] = None,
    n_kv: Optional[int] = None,
    head_dim: Optional[int] = None,
    use_rope: Optional[bool] = None,
    cache_len: int = 0,
    policy: Optional[NumericsPolicy] = None,
):
    """Full-sequence attention.  x: (B, S, d).

    - self-attention: kv_source is None
    - cross-attention: kv_source = encoder states / image embeds
    - ``cache_len > 0`` (prefill): additionally returns the filled ring-buffer
      cache {"k","v","pos"} for decode continuation.
    Returns (out, cache_or_None).

    Under a quantized ``policy`` (QTensor weights, DESIGN.md §8) the four
    projections run grid-resident off one quantized input; q/k/v cross to
    float only for the designated RoPE/softmax island, the returned cache
    holds int16 raws (v straight off the GEMM grid, k requantized after
    RoPE), and the wo output dequantizes once into the residual stream.
    """
    h = n_heads or cfg.eff_heads
    kvh = n_kv or cfg.n_kv_heads
    hd = head_dim or cfg.head_dim
    rope = cfg.use_rope if use_rope is None else use_rope
    q16 = (
        policy is not None and policy.quantized
        and isinstance(p["wq"]["w"], QTensor)
    )
    eng = tpl.engine

    if q16:
        xin = eng.quant(x, policy.fmt)
        src_in = xin if kv_source is None else eng.quant(kv_source, policy.fmt)
        q = _split_heads(eng.dequant(dense(tpl, p["wq"], xin)), h)
        kq = dense(tpl, p["wk"], src_in)  # QTensor, stays on the grid
        vq = dense(tpl, p["wv"], src_in)
        k = _split_heads(eng.dequant(kq), kvh)
        v = _split_heads(eng.dequant(vq), kvh)
    else:
        q = _split_heads(dense(tpl, p["wq"], x), h)
        src = x if kv_source is None else kv_source
        k = _split_heads(dense(tpl, p["wk"], src), kvh)
        v = _split_heads(dense(tpl, p["wv"], src), kvh)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
    q = constrain(q, "batch", None, "act_heads", None)
    if rope and kv_source is None:
        k = apply_rope(k, positions, cfg.rope_theta)
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)

    sq, st = q.shape[1], k.shape[1]
    is_causal = causal and kv_source is None
    if st >= CHUNKED_THRESHOLD:
        out = _sdpa_chunked(q, k, v, causal=is_causal, window=window, q_offset=0)
    else:
        if is_causal:
            rows = jnp.arange(sq)[:, None]
            cols = jnp.arange(st)[None, :]
            m = rows >= cols
            if window:
                m &= (rows - cols) < window
            mask = jnp.broadcast_to(m[None, None], (x.shape[0], 1, sq, st))
        else:
            mask = None
        out = _sdpa_dense(q, k, v, mask)

    out = constrain(out, "batch", None, "act_heads", None)
    out = out.reshape(x.shape[0], x.shape[1], h * hd)
    if q16:
        out = eng.dequant(dense(tpl, p["wo"], eng.quant(out, policy.fmt)))
    else:
        out = dense(tpl, p["wo"], out)

    cache = None
    if cache_len:
        # self-attention caches query positions; cross-attention caches the
        # (static) context positions 0..T-1
        fill_pos = positions if kv_source is None else jnp.arange(st)
        if q16:
            # int16-resident cache: v comes straight off the GEMM grid (it
            # was never roped); k re-enters the grid after the RoPE island
            k_c = (
                eng.quant(k, policy.fmt).raw
                if rope and kv_source is None
                else kq.reshape(*k.shape).raw
            )
            v_c = vq.reshape(*v.shape).raw
        else:
            k_c, v_c = k, v
        cache = _fill_cache(k_c, v_c, fill_pos, cache_len if kv_source is None else st)
    return out, cache


def _fill_cache(k: jax.Array, v: jax.Array, positions: jax.Array, cache_len: int) -> dict:
    """Pack rotated k/v (B,S,Hkv,D) into a ring cache of ``cache_len`` slots.

    Ring invariant: slot = pos % cache_len.  Keeps the *last* cache_len
    positions; assumes positions are contiguous 0..S-1 (prefill).
    """
    b, s, hkv, d = k.shape
    kt = k.transpose(0, 2, 1, 3)  # (B,Hkv,S,D)
    vt = v.transpose(0, 2, 1, 3)
    pos = jnp.broadcast_to(
        positions if positions.ndim == 1 else positions[0], (s,)
    ).astype(jnp.int32)
    if s < cache_len:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, cache_len - s), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, cache_len - s), (0, 0)))
        pos = jnp.pad(pos, (0, cache_len - s), constant_values=-1)
        return {"k": kt, "v": vt, "pos": pos}
    # keep last cache_len entries, rolled so slot = pos % cache_len
    kt = kt[:, :, s - cache_len :]
    vt = vt[:, :, s - cache_len :]
    pos = pos[s - cache_len :]
    shift = (s % cache_len + cache_len) % cache_len
    kt = jnp.roll(kt, shift, axis=2)
    vt = jnp.roll(vt, shift, axis=2)
    pos = jnp.roll(pos, shift)
    return {"k": kt, "v": vt, "pos": pos}


def attention_islands(cfg, *, mode: str, cached: bool = False) -> dict:
    """Designated float islands of one quantized attention sublayer, as
    (quantize, dequantize) call counts — the law the residency test asserts
    (DESIGN.md §8).

    decode: quantize {x, attn-out, +k after RoPE}; dequantize {q, +k for
    RoPE, cache k, cache v, wo-out}.  prefill/forward: quantize {x,
    attn-out, +k for the cache when RoPE rotated it}; dequantize {q, k, v,
    wo-out}.  v never costs an island: it is written to (and read from) the
    int16 cache straight off the GEMM grid.
    """
    rope = cfg.use_rope
    if mode == "decode":
        return {"quantize": 2 + int(rope), "dequantize": 5 if rope else 4}
    return {"quantize": 2 + int(rope and cached), "dequantize": 4}


# ---------------------------------------------------------------------------
# decode (one token, ring cache)
# ---------------------------------------------------------------------------


def decode_attention(
    tpl: Template,
    p,
    x: jax.Array,
    cache: dict,
    *,
    cfg,
    t: jax.Array,
    window: int = 0,
    cross: bool = False,
    n_heads: Optional[int] = None,
    n_kv: Optional[int] = None,
    head_dim: Optional[int] = None,
    use_rope: Optional[bool] = None,
    policy: Optional[NumericsPolicy] = None,
    n_valid: Optional[jax.Array] = None,
):
    """One decode step.  x: (B, 1, d); t: scalar int32 position, or — with a
    slot-indexed cache (pos: (B, C)) — a per-row position vector t: (B,).

    Self-attention (cross=False) appends the new kv at slot t % C and masks
    by stored positions; cross-attention reads a static cache (no update).
    Returns (out, new_cache).

    The slot-indexed path also accepts a *block* x: (B, S, d) — row b covers
    positions t[b]..t[b]+S-1 (chunked prefill).  Per-row gating: t[b] < 0
    marks an inactive lane (cache row untouched, output garbage), and
    ``n_valid``: (B,) limits writes to the first n_valid[b] of the S tokens
    (ragged final chunk; None means all S are real).  Writes are
    gather-select-scatter so gated-off lanes keep their bytes exactly.

    Under a quantized ``policy`` the projections are grid-resident and the
    ring cache holds int16 raws: the new v row is written straight off the
    GEMM grid (zero float hops), k re-enters the grid after the RoPE island,
    and the cached keys/values dequantize once into the softmax island.
    """
    h = n_heads or cfg.eff_heads
    kvh = n_kv or cfg.n_kv_heads
    hd = head_dim or cfg.head_dim
    rope = (cfg.use_rope if use_rope is None else use_rope) and not cross
    q16 = (
        policy is not None and policy.quantized
        and isinstance(p["wq"]["w"], QTensor)
    )
    eng = tpl.engine

    b, s = x.shape[0], x.shape[1]
    per_slot = (not cross) and cache["pos"].ndim == 2
    tpos = jnp.asarray(t, jnp.int32)
    if per_slot:
        tpos = jnp.broadcast_to(tpos.reshape(-1), (b,))  # scalar t -> every row
        q_positions = tpos[:, None] + jnp.arange(s)[None, :]  # (B, S)
    else:
        tpos = tpos.reshape(())
        q_positions = tpos[None]  # (1,)
    xin = eng.quant(x, policy.fmt) if q16 else x
    q = _split_heads(eng.dequant(dense(tpl, p["wq"], xin)) if q16
                     else dense(tpl, p["wq"], xin), h)
    if rope:
        q = apply_rope(q, q_positions, cfg.rope_theta)

    mask = None
    if cross:
        k, v = cache["k"], cache["v"]  # (B,Hkv,T,D) static
        valid = cache["pos"] >= 0
        new_cache = cache
    else:
        c = cache["k"].shape[2]
        kq = dense(tpl, p["wk"], xin)
        vq = dense(tpl, p["wv"], xin)
        if q16:
            # v never leaves the grid; k crosses only for the RoPE island
            v_new = vq.reshape(b, s, kvh, hd).raw
            if rope:
                k_new = apply_rope(
                    _split_heads(eng.dequant(kq), kvh), q_positions, cfg.rope_theta
                )
                k_new = eng.quant(k_new, policy.fmt).raw
            else:
                k_new = kq.reshape(b, s, kvh, hd).raw
        else:
            k_new = _split_heads(kq, kvh)
            v_new = _split_heads(vq, kvh)
            if rope:
                k_new = apply_rope(k_new, q_positions, cfg.rope_theta)
        if per_slot:
            # each row writes its own ring slots (qpos % C); gating must not
            # disturb other lanes' bytes, so read-modify-write: gather the
            # incumbent entries, select per write mask, scatter back.  Slot
            # indices within a row are distinct (S <= C), so the scatter has
            # no duplicate targets.
            nv = (
                jnp.full((b,), s, jnp.int32)
                if n_valid is None
                else jnp.broadcast_to(jnp.asarray(n_valid, jnp.int32).reshape(-1), (b,))
            )
            write = (tpos >= 0)[:, None] & (jnp.arange(s)[None, :] < nv[:, None])
            slots = (q_positions % c).astype(jnp.int32)  # (B, S), non-negative
            rows = jnp.arange(b)[:, None]
            old_k = cache["k"][rows, :, slots]  # (B,S,Hkv,D)
            old_v = cache["v"][rows, :, slots]
            old_pos = cache["pos"][rows, slots]  # (B,S)
            wm = write[:, :, None, None]
            k = cache["k"].at[rows, :, slots].set(
                jnp.where(wm, k_new.astype(cache["k"].dtype), old_k)
            )
            v = cache["v"].at[rows, :, slots].set(
                jnp.where(wm, v_new.astype(cache["v"].dtype), old_v)
            )
            pos = cache["pos"].at[rows, slots].set(
                jnp.where(write, q_positions, old_pos)
            )
            # causal block mask against the whole ring: (B, S, C)
            valid = (pos[:, None, :] >= 0) & (pos[:, None, :] <= q_positions[:, :, None])
            if window:
                valid &= pos[:, None, :] > q_positions[:, :, None] - window
            mask = valid[:, None]  # (B, 1, S, C)
        else:
            slot = (tpos % c).astype(jnp.int32)
            k = jax.lax.dynamic_update_slice(
                cache["k"], k_new.transpose(0, 2, 1, 3).astype(cache["k"].dtype),
                (0, 0, slot, 0),
            )
            v = jax.lax.dynamic_update_slice(
                cache["v"], v_new.transpose(0, 2, 1, 3).astype(cache["v"].dtype),
                (0, 0, slot, 0),
            )
            pos = jax.lax.dynamic_update_slice(cache["pos"], tpos[None], (slot,))
            valid = (pos >= 0) & (pos <= tpos)
            if window:
                valid &= pos > tpos - window
        new_cache = {"k": k, "v": v, "pos": pos}

    if q16:
        # the int16 ring cache crosses into the softmax island here — the
        # only read of (B, Hkv, C, D) per step moves 2-byte, not 4-byte, rows
        k = eng.dequant(k, policy.fmt)
        v = eng.dequant(v, policy.fmt)
    if mask is None:
        if valid.ndim == 1:
            valid = valid[None]
        mask = jnp.broadcast_to(valid[:, None, None, :], (b, 1, s, k.shape[2]))
    out = _sdpa_dense(q, k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3), mask)
    out = out.reshape(b, s, h * hd)
    if q16:
        out = eng.dequant(dense(tpl, p["wo"], eng.quant(out, policy.fmt)))
    else:
        out = dense(tpl, p["wo"], out)
    return out, new_cache
