"""The paper's own case-study networks (AlexNet / VGG16 / LeNet) built on the
unified compute unit.

Per the paper's HW/SW partitioning: conv + FC layers run on the "PL plane"
(the Template compute unit — direct Pallas conv / im2col GEMM / Q2.14 fixed
point), while pooling, flatten and softmax are "PS plane" XLA ops.  Bias and
ReLU are fused into the compute unit's write-back (DESIGN.md §3).
``quantized=True`` inference reproduces the deployed numerics: weights and
activations fake- or fully-quantized to Q2.14 around every GEMM.

Following the paper's plan-then-execute flow, :func:`plan_cnn` compiles the
whole network's kernel routes and Pallas blocks **once** per (template
config, spec, input shape) and every ``cnn_forward`` step reuses that plan —
no per-call DSE, no per-call routing.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.engine import ConvPlan, GemmPlan, register_plan_store, validate_policy
from repro.core.quantization import (
    NumericsPolicy,
    Q2_14,
    QFormat,
    QTensor,
    fake_quant_fmt,
)
from repro.core.template import Template

__all__ = [
    "CNNSpec",
    "ALEXNET",
    "VGG16",
    "LENET",
    "CNN_ZOO",
    "NetworkPlan",
    "init_cnn",
    "plan_cnn",
    "cnn_layer_names",
    "quantize_cnn_params",
    "calibrate_cnn_policy",
    "calibrate_cnn_precision",
    "cnn_forward",
]


@dataclasses.dataclass(frozen=True)
class CNNSpec:
    name: str
    input_hw: int
    input_ch: int
    n_classes: int
    # conv stages: (out_ch, k, stride, pad, pool) — pool is maxpool window (0 = none)
    convs: tuple
    # fc widths (excluding the final classifier)
    fcs: tuple


ALEXNET = CNNSpec(
    "alexnet", 224, 3, 1000,
    convs=(
        (64, 11, 4, 2, 3),
        (192, 5, 1, 2, 3),
        (384, 3, 1, 1, 0),
        (256, 3, 1, 1, 0),
        (256, 3, 1, 1, 3),
    ),
    fcs=(4096, 4096),
)

VGG16 = CNNSpec(
    "vgg16", 224, 3, 1000,
    convs=(
        (64, 3, 1, 1, 0), (64, 3, 1, 1, 2),
        (128, 3, 1, 1, 0), (128, 3, 1, 1, 2),
        (256, 3, 1, 1, 0), (256, 3, 1, 1, 0), (256, 3, 1, 1, 2),
        (512, 3, 1, 1, 0), (512, 3, 1, 1, 0), (512, 3, 1, 1, 2),
        (512, 3, 1, 1, 0), (512, 3, 1, 1, 0), (512, 3, 1, 1, 2),
    ),
    fcs=(4096, 4096),
)

LENET = CNNSpec(
    "lenet", 32, 1, 10,
    convs=((6, 5, 1, 0, 2), (16, 5, 1, 0, 2)),
    fcs=(120, 84),
)

CNN_ZOO = {c.name: c for c in (ALEXNET, VGG16, LENET)}


def _maxpool(x, w: int):
    """NHWC max pool, window w, stride w (PS-plane op).

    QTensor inputs pool on the integer raws directly (int16 or int8 per the
    grid's rung): dequantization is monotone, so max-of-raw == raw-of-max
    and the activation never leaves the fixed-point grid for pooling
    (DESIGN.md §8).
    """
    if isinstance(x, QTensor):
        init = jnp.array(jnp.iinfo(x.raw.dtype).min, x.raw.dtype)
        return QTensor(
            jax.lax.reduce_window(
                x.raw, init, jax.lax.max, (1, w, w, 1), (1, w, w, 1), "VALID"
            ),
            x.fmt,
        )
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, w, w, 1), (1, w, w, 1), "VALID"
    )


# -- spatial (H-slab) sharding helpers (DESIGN.md §10) -----------------------


def _on_raw(x, f):
    """Apply ``f`` to a float array or to a QTensor's int16 raws (layout ops
    are grid-transparent)."""
    return QTensor(f(x.raw), x.fmt) if isinstance(x, QTensor) else f(x)


def _to_slabs(x, shards: int):
    """NHWC -> slab-major (S, N, lx, W, C) with ``lx = ceil(H / S)`` and a
    zero tail — the layout every spatial op preserves (buffer row ``r`` of
    slab ``s`` holds global row ``s·lx + r``, zero beyond H)."""

    def f(v):
        n, h, w, c = v.shape
        lx = -(-h // shards)
        vp = jnp.pad(v, ((0, 0), (0, shards * lx - h), (0, 0), (0, 0)))
        return jnp.moveaxis(vp.reshape(n, shards, lx, w, c), 1, 0)

    return _on_raw(x, f)


def _gather_slabs(x, h: int):
    """Slab-major (S, N, l, W, C) -> NHWC (N, h, W, C): the conv→FC flatten
    seam.  Correct even for a ragged tail shard by the slab invariant — the
    buffer rows past the global extent are zeros and land past row ``h``."""

    def f(v):
        s, n, l = v.shape[0], v.shape[1], v.shape[2]
        return jnp.moveaxis(v, 0, 1).reshape(n, s * l, *v.shape[3:])[:, :h]

    return _on_raw(x, f)


def _maxpool_spatial(x, w: int, ph):
    """Spatially-sharded max pool: a pool is just a halo op with ``kh = w``,
    ``stride = w``, ``pad = 0`` — exchange the (up, dn) rows the seam needs,
    pool each shard's window, and re-zero the ragged tail rows so the next
    seam's halo reads stay exact."""
    from repro.parallel import sharding as sh

    def f(v):
        v = sh.constrain_slabs(v, ph.axis)
        ext = sh.halo_exchange(v, ph)  # (S, N, win, W, C)
        init = (
            jnp.array(jnp.iinfo(v.dtype).min, v.dtype)
            if jnp.issubdtype(v.dtype, jnp.integer)
            else jnp.array(-jnp.inf, v.dtype)
        )
        out = jax.lax.reduce_window(
            ext, init, jax.lax.max, (1, 1, w, w, 1), (1, 1, w, w, 1), "VALID"
        )
        return sh.constrain_slabs(sh.mask_slab_rows(out, ph), ph.axis)

    return _on_raw(x, f)


def init_cnn(key, spec: CNNSpec, dtype=jnp.float32, scale: float = 0.5):
    """He-style init, scaled into the Q2.14 representable range [-2, 2)."""
    params = {"convs": [], "fcs": []}
    ch = spec.input_ch
    hw = spec.input_hw
    keys = jax.random.split(key, len(spec.convs) + len(spec.fcs) + 1)
    ki = 0
    for (cout, k, stride, pad, pool) in spec.convs:
        fan_in = k * k * ch
        w = jax.random.normal(keys[ki], (k, k, ch, cout)) * (scale * fan_in ** -0.5)
        b = jnp.zeros((cout,))
        params["convs"].append({"w": w.astype(dtype), "b": b.astype(dtype)})
        ki += 1
        hw = (hw + 2 * pad - k) // stride + 1
        if pool:
            hw //= pool
        ch = cout
    feat = hw * hw * ch
    widths = (*spec.fcs, spec.n_classes)
    fan = feat
    for wd in widths:
        w = jax.random.normal(keys[ki], (fan, wd)) * (scale * fan ** -0.5)
        b = jnp.zeros((wd,))
        params["fcs"].append({"w": w.astype(dtype), "b": b.astype(dtype)})
        ki += 1
        fan = wd
    return params


@dataclasses.dataclass(frozen=True)
class NetworkPlan:
    """Compiled per-layer execution plan for one CNN (plan-then-execute)."""

    convs: tuple  # ConvPlan per conv stage
    fcs: tuple  # GemmPlan per FC layer
    # spatial (H-slab) sharding, DESIGN.md §10 — shards == 1 means unsharded
    spatial: int = 1  # H-slab shard count S
    spatial_axis: Optional[str] = None  # mesh axis the slab dim shards over
    pool_halos: tuple = ()  # per conv stage: SpatialHalo of its pool, or None
    feat_h: int = 0  # global H entering the conv→FC flatten gather

    def describe(self) -> list[str]:
        """One line per layer: route, τ, spatial tiles, modeled VMEM.

        The human-readable face of the plan — ``benchmarks/kernel_table.py``
        prints it so route/tile regressions show up in benchmark diffs
        between PRs.
        """
        lines = []
        for i, cp in enumerate(self.convs):
            if cp.spatial_tiles > 1 or cp.col_tiles > 1:
                # (𝒯, ℭ) tile grid, per-tile output dims, and halo regime,
                # e.g. "tiles=2x4(256rx128c,dma)" or "tiles=4x1(8r,two_block)"
                dims = f"{cp.tile_rows}r"
                if cp.col_tiles > 1:
                    dims += f"x{cp.tile_cols}c"
                tiling = (
                    f"tiles={cp.spatial_tiles}x{cp.col_tiles}"
                    f"({dims},{cp.halo_mode})"
                )
            else:
                tiling = "untiled"
            halo = ""
            if cp.halo is not None:
                halo = (
                    f" halo=S{cp.halo.shards}"
                    f"(up{cp.halo.up},dn{cp.halo.dn},win{cp.halo.win})"
                )
            lines.append(
                f"conv{i}: route={cp.route} tau={cp.tau} {tiling} "
                f"vmem={cp.vmem_bytes / 2**20:.1f}MiB gemm={cp.gemm}{halo}"
            )
        for i, gp in enumerate(self.fcs):
            blk = (gp.block.bm, gp.block.bn, gp.block.bk) if gp.block else None
            lines.append(f"fc{i}: m={gp.m} n={gp.n} k={gp.k} block={blk}")
        return lines


_NETWORK_PLANS: dict = {}
register_plan_store(_NETWORK_PLANS)


def plan_cnn(
    tpl: Template,
    spec: CNNSpec,
    input_shape: Sequence[int],
    *,
    force_route: Optional[str] = None,
    mesh=None,
    partition=None,
    spatial=None,
) -> NetworkPlan:
    """Compile the network's kernel routes and Pallas blocks once.

    Memoized per (template config, spec, input shape, mesh topology):
    repeated calls — and every training/serving step — reuse the same plan
    object, so the DSE grid search runs at most once per distinct GEMM shape
    in the network.  ``force_route`` overrides conv routing (e.g. "im2col"
    for A/B tests).  With ``mesh`` every layer is planned at its *local*
    per-shard shape (batch over the partition's M axes, output channels /
    FC widths over its N axes); the inter-layer geometry stays logical since
    activations are gathered between layers.

    ``spatial`` (a shard count or mesh axis name) plans the cross-chip
    H-slab partition instead (DESIGN.md §10): every conv and pool is planned
    at its halo-augmented local slab (the seams chain — each layer's slab
    layout is the previous layer's per-shard output rows), batch and Cout
    stay shard-local, and the FCs are planned at the logical shape (the
    flatten seam gathers the slabs, so ``mesh``/``partition`` do not apply
    to spatial plans).
    """
    spatial_n, spatial_ax = 1, None
    if spatial is not None:
        from repro.parallel.sharding import spatial_shards

        spatial_n, spatial_ax = spatial_shards(spatial, mesh)
    mesh_key = None
    if mesh is not None:
        mesh_key = (
            tuple((a, mesh.shape[a]) for a in mesh.axis_names),
            partition,
        )
    key = (
        tpl.config, spec, tuple(input_shape), force_route, mesh_key,
        (spatial_n, spatial_ax),
    )
    plan = _NETWORK_PLANS.get(key)
    if plan is not None:
        return plan
    eng = tpl.engine
    n, hh, ww, ch = input_shape
    if spatial_n > 1:
        from repro.parallel.sharding import plan_spatial_halo

        lx = -(-hh // spatial_n)  # the _to_slabs layout of the input
        convs, pool_halos = [], []
        for cout, k, stride, pad, pool in spec.convs:
            hs = plan_spatial_halo(
                hh, k, stride, pad, spatial_n, axis=spatial_ax, lx=lx
            )
            cp = eng.plan_conv(
                (n, hh, ww, ch), (k, k, ch, cout), stride=stride,
                padding=pad, route=force_route, spatial=hs,
            )
            convs.append(cp)
            lx = hs.lo
            hh = (hh + 2 * pad - k) // stride + 1
            ww = (ww + 2 * pad - k) // stride + 1
            if pool:
                ph = plan_spatial_halo(
                    hh, pool, pool, 0, spatial_n, axis=spatial_ax, lx=lx
                )
                pool_halos.append(ph)
                lx = ph.lo
                hh //= pool
                ww //= pool
            else:
                pool_halos.append(None)
            ch = cout
        fan = hh * ww * ch
        fcs = []
        for wd in (*spec.fcs, spec.n_classes):
            fcs.append(eng.plan_gemm(n, wd, fan))
            fan = wd
        plan = NetworkPlan(
            convs=tuple(convs), fcs=tuple(fcs), spatial=spatial_n,
            spatial_axis=spatial_ax, pool_halos=tuple(pool_halos), feat_h=hh,
        )
        _NETWORK_PLANS[key] = plan
        return plan
    convs = []
    for cout, k, stride, pad, pool in spec.convs:
        cp = eng.plan_conv(
            (n, hh, ww, ch), (k, k, ch, cout), stride=stride, padding=pad,
            route=force_route, mesh=mesh, partition=partition,
        )
        convs.append(cp)
        hh = (hh + 2 * cp.pad - k) // stride + 1
        ww = (ww + 2 * cp.pad - k) // stride + 1
        if pool:
            hh //= pool
            ww //= pool
        ch = cout
    fan = hh * ww * ch
    fcs = []
    for wd in (*spec.fcs, spec.n_classes):
        fcs.append(eng.plan_gemm(n, wd, fan, mesh=mesh, partition=partition))
        fan = wd
    plan = NetworkPlan(convs=tuple(convs), fcs=tuple(fcs))
    _NETWORK_PLANS[key] = plan
    return plan


def cnn_layer_names(spec: CNNSpec) -> tuple:
    """The per-layer precision-DSE names, forward order: conv0.. then fc0..
    (the final entry is the classifier).  A layer's name keys its *input*
    activation grid in ``NumericsPolicy.layer_fmts`` and the plan store."""
    return tuple(f"conv{i}" for i in range(len(spec.convs))) + tuple(
        f"fc{i}" for i in range(len(spec.fcs) + 1)
    )


def quantize_cnn_params(tpl: Template, spec: CNNSpec, params,
                        policy: NumericsPolicy):
    """Quantize-once CNN parameter preparation (DESIGN.md §8, §11).

    Conv and FC weights become per-tensor max-abs calibrated QTensors;
    biases pin to the layer's activation grid.  Under a mixed policy each
    layer calibrates against its *own* input grid (``policy.fmt_for``): an
    int8-assigned layer gets int8 weights and the 24/23-bit accumulator
    headroom budget instead of 16/15.  Memoized by parameter-tree identity
    (and policy — ``layer_fmts`` is part of the key) in the engine's qparam
    cache — repeated inference calls never touch the float weights again.
    """
    policy = validate_policy(tpl.config, policy)
    if not policy.quantized:
        return params
    eng = tpl.engine
    names = cnn_layer_names(spec)

    def build():
        def qdense(leaf, name):
            # conv (kh, kw, cin, cout) reduces over kh*kw*cin; fc (k, n)
            # over k — the accumulator headroom rule bounds both
            axes = tuple(range(leaf["w"].ndim - 1))
            fmt = policy.fmt_for(name)
            return {
                "w": eng.quantize_weight(leaf["w"], policy,
                                         contraction_axes=axes,
                                         fused_bias=True,
                                         act_fmt=fmt,
                                         total_bits=fmt.total_bits),
                "b": eng.quantize_weight(leaf["b"], policy, fmt=fmt),
            }

        nc = len(params["convs"])
        return {
            "convs": [qdense(p, names[i]) for i, p in enumerate(params["convs"])],
            "fcs": [qdense(p, names[nc + i]) for i, p in enumerate(params["fcs"])],
        }

    return eng.qparams_for(params, policy, build)


def calibrate_cnn_policy(tpl: Template, spec: CNNSpec, params, x,
                         base: Optional[NumericsPolicy] = None) -> NumericsPolicy:
    """Max-abs activation calibration for the CNN zoo: one eager forward over
    a calibration batch picks the activation grid (see
    ``transformer.calibrate_policy`` for the transformer twin).  A QAT
    network whose activations fit [-2, 2) keeps the paper's Q2.14."""
    import dataclasses

    base = base or NumericsPolicy("q16")
    probe_qp = quantize_cnn_params(tpl, spec, params, base)
    fmt = tpl.engine.calibrate_activation_format(
        lambda: cnn_forward(tpl, spec, probe_qp, x, policy=base)
    )
    policy = dataclasses.replace(base, fmt=fmt)
    if policy != base:
        tpl.engine.drop_qparams(params, base)  # release the probe tree
    return policy


def calibrate_cnn_precision(
    tpl: Template,
    spec: CNNSpec,
    params,
    x,
    *,
    budget: float = 0.99,
    policy: Optional[NumericsPolicy] = None,
    drift: Optional[dict] = None,
    ref=None,
) -> NumericsPolicy:
    """The drift-aware per-layer precision DSE for a CNN (DESIGN.md §11).

    Warm path: when the PlanRegistry holds a pinned precision choice for
    *every* layer of ``spec`` (loaded from the v3 plan store), the mixed
    policy is rebuilt from the pins — zero forwards, zero searches, each
    layer a registry hit (the ``REPRO_PLAN_ASSERT_WARM`` contract).

    Cold path: measure each layer's *solo-flip* drift — run the network
    with only that layer's activations dropped to the int8 rung of the
    calibrated grid and record the argmax agreement vs the float reference
    (``drift`` short-circuits the sweep with pre-measured rows, e.g. from
    ``benchmarks/precision_drift.py``'s JSON) — then assign int8 wherever
    the agreement meets ``budget`` (:func:`repro.core.dse.choose_precision`)
    and pin every choice with ``source: measured`` provenance.

    ``ref`` overrides the reference class predictions (an (N,) argmax
    array).  The default is the pure-float forward; a QAT-trained network
    should pass the argmax of its *fake-quant* float forward — the clamp
    is part of the trained model, so the unclamped float path is not the
    semantics deployment must agree with (see examples/train_lenet_q214).
    """
    import dataclasses

    from repro.core import dse
    from repro.core.quantization import int8_rung

    policy = policy or calibrate_cnn_policy(tpl, spec, params, x)
    eng = tpl.engine
    reg = eng.plan_cache
    hw = tpl.config.hw
    names = cnn_layer_names(spec)
    low = int8_rung(policy.fmt)
    if low is None:
        return policy  # the calibrated range has no int8 rung
    pins = {name: reg.precision_for(spec.name, name, hw) for name in names}
    if all(p is not None for p in pins.values()):
        fmts = tuple(sorted(((n, p.fmt) for n, p in pins.items()),
                            key=lambda kv: kv[0]))
        return dataclasses.replace(policy, name="mixed", layer_fmts=fmts)
    if ref is None:
        ref = jnp.argmax(cnn_forward(tpl, spec, params, x), axis=-1)

    def probe_agreement(fmts):
        probe = dataclasses.replace(policy, name="mixed", layer_fmts=fmts)
        qp = quantize_cnn_params(tpl, spec, params, probe)
        got = jnp.argmax(cnn_forward(tpl, spec, qp, x, policy=probe), axis=-1)
        eng.drop_qparams(params, probe)  # release the probe tree
        return float(jnp.mean(got == ref))

    if drift is None:
        drift = {name: probe_agreement(((name, low),)) for name in names}
    chosen = dse.choose_precision(drift, budget, policy.fmt, low)

    def full_plan():
        return tuple(sorted(((n, chosen.get(n, policy.fmt)) for n in names),
                            key=lambda kv: kv[0]))

    # solo-flip drifts compose: the joint plan can land below the *network*
    # budget even when every member met it alone.  Greedily revert the int8
    # layer with the lowest measured agreement until the composed network
    # meets the budget — the accuracy constraint is on the network, not the
    # per-layer probes.
    while probe_agreement(full_plan()) < budget:
        int8s = [n for n in names if chosen[n].total_bits == 8]
        if not int8s:
            break
        chosen[min(int8s, key=lambda n: (drift[n], n))] = policy.fmt
    for name in names:
        reg.pin_precision(
            spec.name, name, chosen.get(name, policy.fmt),
            drift=drift.get(name), spec=hw, source="measured",
        )
    fmts = tuple(sorted(
        ((n, chosen.get(n, policy.fmt)) for n in names), key=lambda kv: kv[0]
    ))
    return dataclasses.replace(policy, name="mixed", layer_fmts=fmts)


def cnn_forward(
    tpl: Template,
    spec: CNNSpec,
    params,
    x: jax.Array,
    *,
    quantized: bool = False,
    fmt: QFormat = Q2_14,
    plan: Optional[NetworkPlan] = None,
    policy: Optional[NumericsPolicy] = None,
) -> jax.Array:
    """x: (N, H, W, C) -> logits (N, n_classes).

    ``quantized``: Q2.14 both weights and activations around every GEMM
    (the deployed fixed-point numerics); the GEMM itself runs on whatever
    backend ``tpl`` selects (XLA / Pallas float / Pallas q16).  Bias + ReLU
    (and, when quantized, the post-activation Q2.14 snap) are fused into the
    compute unit's write-back.  ``plan`` defaults to the memoized
    :func:`plan_cnn` result for this (config, spec, input shape).

    ``policy``: a quantized :class:`NumericsPolicy` (with a
    :func:`quantize_cnn_params` tree) runs the *whole network* grid-resident:
    the input is quantized exactly once, every conv/FC (ReLU fused in-kernel)
    and every maxpool stays on the int16 grid, and the only dequantization is
    the exact int32 read-out of the final classifier — one quantize and one
    dequantize for the entire forward (DESIGN.md §8).
    """
    if policy is not None and policy.quantized and isinstance(
        params["convs"][0]["w"], QTensor
    ):
        eng = tpl.engine
        plan = plan or plan_cnn(tpl, spec, x.shape)
        halos = plan.pool_halos or (None,) * len(plan.convs)
        names = cnn_layer_names(spec)
        # each layer writes its *successor's* input grid in-kernel — the
        # mixed-boundary epilogue (DESIGN.md §11): an int8 layer feeds an
        # int16 layer (and vice versa) with zero float round-trips.  Pooling
        # is grid-transparent, so conv output and pooled map share the grid.
        h = eng.quant(x, policy.fmt_for(names[0]))
        if plan.spatial > 1:
            h = _to_slabs(h, plan.spatial)
        nc = len(plan.convs)
        for i, (p, (cout, k, stride, pad, pool), cp, ph) in enumerate(zip(
            params["convs"], spec.convs, plan.convs, halos
        )):
            h = tpl.conv2d(h, p["w"], stride=stride, padding=pad,
                           bias=p["b"], relu=True,
                           qout=policy.fmt_for(names[i + 1]), plan=cp)
            if pool:
                h = _maxpool_spatial(h, pool, ph) if ph is not None else _maxpool(h, pool)
        if plan.spatial > 1:
            h = _gather_slabs(h, plan.feat_h)
        h = h.reshape(h.shape[0], -1)
        last = len(params["fcs"]) - 1
        for i, (p, gp) in enumerate(zip(params["fcs"], plan.fcs)):
            if i < last:
                h = tpl.linear(h, p["w"], p["b"], relu=True,
                               qout=policy.fmt_for(names[nc + i + 1]), plan=gp)
            else:
                # final classifier: exact accumulator read-out (the single
                # counted dequantize of the whole network)
                h = tpl.linear(h, p["w"], p["b"], wide=True, plan=gp)
        return h
    plan = plan or plan_cnn(tpl, spec, x.shape)
    halos = plan.pool_halos or (None,) * len(plan.convs)
    fq = (lambda a: fake_quant_fmt(a, fmt)) if quantized else (lambda a: a)
    qo = fmt if quantized else None
    h = fq(x)
    if plan.spatial > 1:
        h = _to_slabs(h, plan.spatial)
    for p, (cout, k, stride, pad, pool), cp, ph in zip(
        params["convs"], spec.convs, plan.convs, halos
    ):
        h = tpl.conv2d(
            h, fq(p["w"]), stride=stride, padding=pad,
            bias=fq(p["b"]), relu=True, qout=qo, plan=cp,
        )
        if pool:
            h = _maxpool_spatial(h, pool, ph) if ph is not None else _maxpool(h, pool)
    if plan.spatial > 1:
        h = _gather_slabs(h, plan.feat_h)
    h = h.reshape(h.shape[0], -1)
    last = len(params["fcs"]) - 1
    for i, (p, gp) in enumerate(zip(params["fcs"], plan.fcs)):
        h = tpl.linear(
            h, fq(p["w"]), fq(p["b"]),
            relu=i < last, qout=qo if i < last else None, plan=gp,
        )
    return h
