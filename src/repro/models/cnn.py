"""The paper's own case-study networks (AlexNet / VGG16 / LeNet) built on the
unified compute unit.

Per the paper's HW/SW partitioning: conv + FC layers run on the "PL plane"
(the Template compute unit — im2col GEMM / Pallas kernels / Q2.14 fixed
point), while pooling, ReLU placement, flatten and softmax are "PS plane"
XLA ops.  ``quantized=True`` inference reproduces the deployed numerics:
weights and activations fake- or fully-quantized to Q2.14 around every GEMM.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.quantization import Q2_14, QFormat, fake_quant_fmt
from repro.core.template import Template

__all__ = ["CNNSpec", "ALEXNET", "VGG16", "LENET", "CNN_ZOO", "init_cnn", "cnn_forward"]


@dataclasses.dataclass(frozen=True)
class CNNSpec:
    name: str
    input_hw: int
    input_ch: int
    n_classes: int
    # conv stages: (out_ch, k, stride, pad, pool) — pool is maxpool window (0 = none)
    convs: tuple
    # fc widths (excluding the final classifier)
    fcs: tuple


ALEXNET = CNNSpec(
    "alexnet", 224, 3, 1000,
    convs=(
        (64, 11, 4, 2, 3),
        (192, 5, 1, 2, 3),
        (384, 3, 1, 1, 0),
        (256, 3, 1, 1, 0),
        (256, 3, 1, 1, 3),
    ),
    fcs=(4096, 4096),
)

VGG16 = CNNSpec(
    "vgg16", 224, 3, 1000,
    convs=(
        (64, 3, 1, 1, 0), (64, 3, 1, 1, 2),
        (128, 3, 1, 1, 0), (128, 3, 1, 1, 2),
        (256, 3, 1, 1, 0), (256, 3, 1, 1, 0), (256, 3, 1, 1, 2),
        (512, 3, 1, 1, 0), (512, 3, 1, 1, 0), (512, 3, 1, 1, 2),
        (512, 3, 1, 1, 0), (512, 3, 1, 1, 0), (512, 3, 1, 1, 2),
    ),
    fcs=(4096, 4096),
)

LENET = CNNSpec(
    "lenet", 32, 1, 10,
    convs=((6, 5, 1, 0, 2), (16, 5, 1, 0, 2)),
    fcs=(120, 84),
)

CNN_ZOO = {c.name: c for c in (ALEXNET, VGG16, LENET)}


def _maxpool(x: jax.Array, w: int) -> jax.Array:
    """NHWC max pool, window w, stride w (PS-plane op)."""
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, w, w, 1), (1, w, w, 1), "VALID"
    )


def init_cnn(key, spec: CNNSpec, dtype=jnp.float32, scale: float = 0.5):
    """He-style init, scaled into the Q2.14 representable range [-2, 2)."""
    params = {"convs": [], "fcs": []}
    ch = spec.input_ch
    hw = spec.input_hw
    keys = jax.random.split(key, len(spec.convs) + len(spec.fcs) + 1)
    ki = 0
    for (cout, k, stride, pad, pool) in spec.convs:
        fan_in = k * k * ch
        w = jax.random.normal(keys[ki], (k, k, ch, cout)) * (scale * fan_in ** -0.5)
        b = jnp.zeros((cout,))
        params["convs"].append({"w": w.astype(dtype), "b": b.astype(dtype)})
        ki += 1
        hw = (hw + 2 * pad - k) // stride + 1
        if pool:
            hw //= pool
        ch = cout
    feat = hw * hw * ch
    widths = (*spec.fcs, spec.n_classes)
    fan = feat
    for wd in widths:
        w = jax.random.normal(keys[ki], (fan, wd)) * (scale * fan ** -0.5)
        b = jnp.zeros((wd,))
        params["fcs"].append({"w": w.astype(dtype), "b": b.astype(dtype)})
        ki += 1
        fan = wd
    return params


def cnn_forward(
    tpl: Template,
    spec: CNNSpec,
    params,
    x: jax.Array,
    *,
    quantized: bool = False,
    fmt: QFormat = Q2_14,
) -> jax.Array:
    """x: (N, H, W, C) -> logits (N, n_classes).

    ``quantized``: Q2.14 both weights and activations around every GEMM
    (the deployed fixed-point numerics); the GEMM itself runs on whatever
    backend ``tpl`` selects (XLA / Pallas float / Pallas q16).
    """
    fq = (lambda a: fake_quant_fmt(a, fmt)) if quantized else (lambda a: a)
    h = fq(x)
    for p, (cout, k, stride, pad, pool) in zip(params["convs"], spec.convs):
        h = tpl.conv2d(h, fq(p["w"]), stride=stride, padding=pad)
        h = jax.nn.relu(h + fq(p["b"]))
        h = fq(h)
        if pool:
            h = _maxpool(h, pool)
    h = h.reshape(h.shape[0], -1)
    for i, p in enumerate(params["fcs"]):
        h = tpl.linear(h, fq(p["w"]), fq(p["b"]))
        if i < len(params["fcs"]) - 1:
            h = fq(jax.nn.relu(h))
    return h
