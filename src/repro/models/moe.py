"""Mixture-of-Experts FFN with grouped capacity-based dispatch (GShard style).

The expert FFNs are *batched GEMMs on the unified compute unit* — exactly the
paper's thesis that every layer type reduces to tiled matrix multiplication.
Routing (top-k softmax, position-in-expert bookkeeping) is control-plane work
and runs on the XLA "PS plane", mirroring the paper's PS/PL partitioning.

Scalability: the dispatch/combine tensors are (S_g, E, C) per token-group
with C = ceil(S_g * k / E * cf), i.e. O(S_g^2 * k * cf) — quadratic in the
group size and *independent of E*.  Tokens are therefore split into groups of
``cfg.moe_group`` (default 512) before dispatch; groups ride the batch
sharding axes while experts shard over "model" (EP).  Under GSPMD the expert
einsums keep tokens local and all-reduce only the combined output over the
expert axis — the TP-style schedule, which beats all-to-all on ICI when
top_k * d_model bytes/token exceeds the expert-sharded activation size.

Capacity semantics: each expert takes at most C tokens per group; overflow
tokens lose that expert choice (their residual path keeps them alive) — the
Switch/GShard "token dropping" formulation, chosen over ragged megablox-style
dispatch because its dense einsums are GSPMD-partitionable with no
data-dependent shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.template import Template
from repro.parallel.sharding import constrain

from .layers import init_dense

__all__ = ["init_moe", "moe_axes", "moe_ffn", "moe_ffn_dense_ref"]


def init_moe(key, cfg, dtype=jnp.float32):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    scale_in = d ** -0.5
    scale_out = ff ** -0.5
    return {
        "router": init_dense(ks[0], d, e, dtype=jnp.float32),
        "gate": (jax.random.normal(ks[1], (e, d, ff)) * scale_in).astype(dtype),
        "up": (jax.random.normal(ks[2], (e, d, ff)) * scale_in).astype(dtype),
        "down": (jax.random.normal(ks[3], (e, ff, d)) * scale_out).astype(dtype),
    }


def moe_axes(cfg) -> dict:
    return {
        "router": {"w": ("embed", None)},
        "gate": ("experts", "embed", "expert_mlp"),
        "up": ("experts", "embed", "expert_mlp"),
        "down": ("experts", "expert_mlp", "embed"),
    }


def _route(cfg, router_w, xt):
    """Top-k routing for one flat token group.  xt: (G, S, d).

    Returns (gates, idx, probs): gates (G,S,k) normalized, idx (G,S,k) int32.
    """
    logits = jnp.einsum(
        "gsd,de->gse", xt.astype(jnp.float32), router_w.astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx, probs


def moe_ffn(tpl: Template, cfg, p, x: jax.Array):
    """x: (B, S, d) -> (B, S, d), plus Switch-style aux load-balancing loss."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    sg = min(getattr(cfg, "moe_group", 512) or 512, t)
    xt = x.reshape(t, d)
    pad = (-t) % sg
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    g = xt.shape[0] // sg
    xt = xt.reshape(g, sg, d)
    xt = constrain(xt, "batch", None, "act_embed")

    cap = int(max(k, -(-sg * k // e) * cfg.capacity_factor))
    cap = min(cap, sg)

    gates, idx, probs = _route(cfg, p["router"]["w"], xt)

    # position of each (token, choice) in its expert queue, choice-major
    # (all first choices queue before any second choice — GShard order).
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)  # (G, S, k, E)
    cm = jnp.moveaxis(onehot, 2, 1)  # (G, k, S, E) choice-major
    cum = jnp.cumsum(cm.reshape(g, k * sg, e), axis=1).reshape(g, k, sg, e)
    pos = jnp.moveaxis((cum - cm), 1, 2)  # back to (G, S, k, E)
    pos = (pos * onehot).sum(-1)  # (G, S, k)
    keep = pos < cap

    # combine weights (G, S, E, C) built choice-by-choice (k is tiny) so the
    # (G, S, k, E, C) intermediate never materializes.
    dt = x.dtype
    combine = jnp.zeros((g, sg, e, cap), dt)
    for j in range(k):
        oe = jax.nn.one_hot(idx[:, :, j], e, dtype=dt)  # (G,S,E)
        oc = jax.nn.one_hot(pos[:, :, j], cap, dtype=dt)  # (G,S,C)
        w = (gates[:, :, j] * keep[:, :, j]).astype(dt)  # (G,S)
        combine = combine + w[..., None, None] * oe[..., None] * oc[:, :, None, :]
    dispatch = (combine > 0).astype(dt)

    combine = constrain(combine, "batch", None, "experts", "expert_cap")
    dispatch = constrain(dispatch, "batch", None, "experts", "expert_cap")

    # expert inputs: (G, E, C, d).  Two EP layouts, picked by the rules:
    #   experts->model            (divisible E, e.g. phi's 16)
    #   expert_cap->model         (non-divisible E, e.g. granite's 40: the
    #     capacity dim is a *batch* dim of every expert GEMM, so sharding it
    #     keeps all three GEMMs and both transposes reduction-free; only the
    #     (g, S_g, d) combine output and the weight grads cross the wire)
    ex_in = jnp.einsum("gsec,gsd->gecd", dispatch, xt)
    ex_in = constrain(ex_in, "batch", "experts", "expert_cap", None)

    # expert FFNs: batched GEMMs on the unified compute unit.  On the XLA
    # plane the einsum lowers to one batched MXU GEMM per projection; on the
    # Pallas plane each expert's GEMM routes through the hand-tiled kernel.
    if tpl.config.backend == "xla":
        bmm = lambda a, w: jnp.einsum("gecd,edf->gecf", a, w.astype(a.dtype))
    else:
        bmm = lambda a, w: jax.vmap(lambda ag: jax.vmap(tpl.matmul)(ag, w))(a)
    h = jax.nn.silu(bmm(ex_in, p["gate"])) * bmm(ex_in, p["up"])
    h = constrain(h, "batch", "experts", "expert_cap", "expert_mlp")
    ex_out = bmm(h, p["down"])
    # NOTE: no sharding constraint on ex_out — pinning it replicated forces
    # an all-reduce of the (g, E, C, d) partials (E*C/S_g ~= 10x the token
    # bytes) BEFORE the combine; left free, GSPMD reduces after the combine
    # on the (g, S_g, d) result (§Perf cell B iteration 1).

    out = jnp.einsum("gsec,gecd->gsd", combine, ex_out).reshape(g * sg, d)
    if pad:
        out = out[:t]
    out = out.reshape(b, s, d)

    # Switch-style load-balancing aux loss (mean over groups)
    density = onehot.astype(jnp.float32).sum(2).mean(1)  # (G, E) routed frac
    router_prob = probs.mean(1)  # (G, E)
    aux = e * jnp.mean(jnp.sum(density * router_prob, axis=-1))
    return out.astype(x.dtype), aux


def moe_ffn_dense_ref(cfg, p, x: jax.Array):
    """Oracle: every expert computed for every token, weighted by the same
    top-k gates with the same capacity-drop mask.  O(T·E·ff) — tests only."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    sg = min(getattr(cfg, "moe_group", 512) or 512, t)
    xt = x.reshape(t, d)
    pad = (-t) % sg
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    g = xt.shape[0] // sg
    xt = xt.reshape(g, sg, d)
    cap = int(max(k, -(-sg * k // e) * cfg.capacity_factor))
    cap = min(cap, sg)
    gates, idx, probs = _route(cfg, p["router"]["w"], xt)
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)
    cm = jnp.moveaxis(onehot, 2, 1)
    cum = jnp.cumsum(cm.reshape(g, k * sg, e), axis=1).reshape(g, k, sg, e)
    pos = jnp.moveaxis((cum - cm), 1, 2)
    pos = (pos * onehot).sum(-1)
    keep = pos < cap

    # per-expert dense outputs for all tokens
    def expert(eid):
        h = jax.nn.silu(xt @ p["gate"][eid]) * (xt @ p["up"][eid])
        return h @ p["down"][eid]

    alle = jnp.stack([expert(i) for i in range(e)], axis=2)  # (G,S,E,d)
    w = jnp.zeros((g, sg, e), x.dtype)
    for j in range(k):
        oe = jax.nn.one_hot(idx[:, :, j], e, dtype=x.dtype)
        w = w + (gates[:, :, j] * keep[:, :, j]).astype(x.dtype)[..., None] * oe
    out = jnp.einsum("gse,gsed->gsd", w, alle).reshape(g * sg, d)
    if pad:
        out = out[:t]
    return out.reshape(b, s, d).astype(x.dtype)
