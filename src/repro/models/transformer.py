"""Unified TransformerLM: one model definition covering every assigned family.

    dense   — qwen2.5-32b, internlm2-1.8b, mistral-nemo-12b, qwen2-0.5b
    moe     — granite-moe-3b-a800m, phi3.5-moe-42b-a6.6b
    hybrid  — recurrentgemma-9b (RG-LRU + local attention, pattern 2:1)
    ssm     — mamba2-1.3b (attention-free SSD)
    encdec  — whisper-medium (encoder + cross-attending decoder)
    vlm     — llama-3.2-vision-90b (gated cross-attention image layers)

Every layer is described by a :class:`LayerPlan` (mixer kind, cross-attention
flag, MoE flag); a model is a repeating *pattern* of plans.  Parameters for
pattern-position *i* are stacked over the repeat count G and executed under
``jax.lax.scan`` (one compiled layer body regardless of depth — essential for
the 100-layer dry-run cells), with ``jax.checkpoint`` per scanned group when
``cfg.remat``.  Layers left over when n_layers % period != 0 run unscanned
("tail").

All GEMMs route through the Template compute unit (the paper's single
on-chip compute unit); recurrences/scans/softmax run on the XLA "PS plane".

Three entry points per the serving/training split:
  * :func:`forward` / :func:`loss_fn`  — full-sequence teacher-forced
  * :func:`prefill`                    — full-sequence + returns decode cache
  * :func:`decode_step`                — one token against the ring cache
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name as _checkpoint_name

from repro.core.engine import validate_policy
from repro.core.quantization import NumericsPolicy, QTensor
from repro.core.template import Template
from repro.parallel.sharding import constrain

from . import moe as moe_mod
from . import rglru as rec_mod
from . import ssm as ssm_mod
from .attention import (
    attention,
    attention_axes,
    attention_islands,
    decode_attention,
    init_attention,
    init_layer_cache,
)
from .layers import (
    cross_entropy_loss,
    init_mlp,
    init_norm,
    mlp,
    mlp_axes,
    mlp_islands,
    norm,
    sinusoidal_positions,
)

__all__ = [
    "LayerPlan",
    "plan_pattern",
    "init_params",
    "param_axes",
    "precision_group_names",
    "quantize_params",
    "calibrate_policy",
    "calibrate_precision",
    "q16_island_counts",
    "forward",
    "loss_fn",
    "prefill",
    "prefill_chunk_step",
    "decode_step",
    "init_cache",
    "cache_axes",
    "insert_cache_slot",
    "insert_cache_rows",
    "clear_cache_rows",
]


class LayerPlan(NamedTuple):
    mixer: str  # "attn" | "local" | "attn_nc" | "rec" | "ssm"
    cross: bool  # followed by a cross-attention sub-layer
    moe: bool  # FFN is a mixture of experts


def plan_pattern(cfg) -> tuple:
    """One pattern period of layer plans."""
    if cfg.family == "ssm":
        return (LayerPlan("ssm", False, False),)
    if cfg.family == "hybrid":
        return tuple(
            LayerPlan("local" if m == "attn" else "rec", False, False)
            for m in cfg.pattern
        )
    if cfg.family == "vlm":
        p = cfg.cross_attn_period
        return tuple(LayerPlan("attn", i == p - 1, False) for i in range(p))
    if cfg.family == "encdec":
        return (LayerPlan("attn", True, False),)
    return (LayerPlan("attn", False, cfg.family == "moe"),)


def _split(cfg):
    pattern = plan_pattern(cfg)
    period = len(pattern)
    return pattern, cfg.n_layers // period, cfg.n_layers % period


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(key, cfg, plan: LayerPlan, dtype):
    ks = jax.random.split(key, 4)
    p = {"norm": init_norm(cfg, dtype)}
    if plan.mixer in ("attn", "local", "attn_nc"):
        p["attn"] = init_attention(ks[0], cfg, dtype=dtype)
    elif plan.mixer == "rec":
        p["rec"] = rec_mod.init_rglru(ks[0], cfg, dtype=dtype)
    elif plan.mixer == "ssm":
        p["ssm"] = ssm_mod.init_ssm(ks[0], cfg, dtype=dtype)
    else:  # pragma: no cover
        raise ValueError(plan.mixer)
    if plan.cross:
        p["cross_norm"] = init_norm(cfg, dtype)
        p["cross"] = init_attention(ks[1], cfg, bias=False, dtype=dtype)
        if cfg.family == "vlm":
            p["cross_gate"] = jnp.zeros((), dtype)
    if plan.mixer != "ssm":  # mamba2 blocks have no separate FFN
        p["ffn_norm"] = init_norm(cfg, dtype)
        p["ffn"] = (
            moe_mod.init_moe(ks[2], cfg, dtype=dtype)
            if plan.moe
            else init_mlp(ks[2], cfg, dtype=dtype)
        )
    return p


def _layer_axes(cfg, plan: LayerPlan):
    ax = {"norm": None}
    if plan.mixer in ("attn", "local", "attn_nc"):
        ax["attn"] = attention_axes(cfg)
    elif plan.mixer == "rec":
        ax["rec"] = rec_mod.rglru_axes(cfg)
    elif plan.mixer == "ssm":
        ax["ssm"] = ssm_mod.ssm_axes(cfg)
    if plan.cross:
        ax["cross_norm"] = None
        ax["cross"] = attention_axes(cfg, bias=False)
        if cfg.family == "vlm":
            ax["cross_gate"] = None
    if plan.mixer != "ssm":
        ax["ffn_norm"] = None
        ax["ffn"] = moe_mod.moe_axes(cfg) if plan.moe else mlp_axes(cfg)
    return ax


def _is_axes_leaf(x):
    return x is None or (
        isinstance(x, tuple)
        and len(x) > 0
        and all(e is None or isinstance(e, str) for e in x)
    )


def _stack_axes(ax):
    """Prepend the (unsharded) scan axis to every logical-axes leaf."""
    return jax.tree.map(
        lambda t: None if t is None else (None, *t), ax, is_leaf=_is_axes_leaf
    )


def init_params(key, cfg, dtype=None):
    dtype = jnp.dtype(dtype or cfg.dtype)
    pattern, g, r = _split(cfg)
    keys = jax.random.split(key, 8)
    d, v = cfg.d_model, cfg.vocab

    params = {
        "embed": (jax.random.normal(keys[0], (v, d)) * d ** -0.5).astype(dtype),
        "final_norm": init_norm(cfg, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": (jax.random.normal(keys[1], (d, v)) * d ** -0.5).astype(dtype)
        }

    def stacked(base_key, plan):
        ks = jax.random.split(base_key, g)
        return jax.vmap(lambda k: _init_layer(k, cfg, plan, dtype))(ks)

    bkeys = jax.random.split(keys[2], len(pattern))
    params["blocks"] = tuple(stacked(bkeys[i], p) for i, p in enumerate(pattern))
    tkeys = jax.random.split(keys[3], max(r, 1))
    params["tail"] = tuple(
        _init_layer(tkeys[j], cfg, pattern[j], dtype) for j in range(r)
    )

    if cfg.family == "encdec":
        ekeys = jax.random.split(keys[4], cfg.n_encoder_layers + 1)
        enc_plan = LayerPlan("attn_nc", False, False)
        eg = cfg.n_encoder_layers
        eks = jax.random.split(ekeys[0], eg)
        params["encoder"] = {
            "blocks": (jax.vmap(lambda k: _init_layer(k, cfg, enc_plan, dtype))(eks),),
            "final_norm": init_norm(cfg, dtype),
        }
    return params


def param_axes(cfg):
    pattern, g, r = _split(cfg)
    ax = {
        "embed": ("vocab", "embed"),
        "final_norm": None,
    }
    if not cfg.tie_embeddings:
        ax["lm_head"] = {"w": ("embed", "vocab")}
    ax["blocks"] = tuple(_stack_axes(_layer_axes(cfg, p)) for p in pattern)
    ax["tail"] = tuple(_layer_axes(cfg, pattern[j]) for j in range(r))
    if cfg.family == "encdec":
        enc_plan = LayerPlan("attn_nc", False, False)
        ax["encoder"] = {
            "blocks": (_stack_axes(_layer_axes(cfg, enc_plan)),),
            "final_norm": None,
        }
    return ax


# ---------------------------------------------------------------------------
# fixed-point residency: quantize-once parameter preparation (DESIGN.md §8)
# ---------------------------------------------------------------------------


def quantize_params(tpl: Template, cfg, params, policy: NumericsPolicy):
    """Prepare the quantized parameter tree for a q16 forward pass.

    Every GEMM weight (attention projections, FFN, LM head — including the
    tied-embedding head, which gets its own int16 copy so the float lookup
    table stays untouched) becomes a :class:`QTensor` with a per-tensor
    max-abs calibrated format; biases pin to the activation grid; norms and
    the embedding table stay float (they live on float islands).  Memoized by
    parameter-tree identity in the engine's qparam cache, so weights are
    quantized **exactly once per process** no matter how many generate() /
    scheduler sessions share the tree.

    Raises ``ValueError`` for unsupported combos: a non-q16 backend, or a
    family whose mixers cannot soundly run on the grid (recurrent/SSM state,
    cross-attention, MoE dispatch).
    """
    policy = validate_policy(tpl.config, policy)
    if not policy.quantized:
        return params
    pattern = plan_pattern(cfg)
    bad = [lp.mixer for lp in pattern if lp.mixer != "attn"]
    if bad or any(lp.cross or lp.moe for lp in pattern):
        raise ValueError(
            f"NumericsPolicy('q16') supports dense full-attention stacks "
            f"only; {cfg.name} ({cfg.family}) has "
            f"{bad or 'cross-attention / MoE layers'}"
        )
    eng = tpl.engine

    def build():
        def qdense(leaf, fmt):
            # shape (..., k, n): k is the contraction the accumulator
            # headroom rule bounds (Engine.quantize_weight); act_fmt names
            # the group's activation grid so int8 groups get int8 weights
            # and the widened headroom budget (DESIGN.md §11)
            out = {"w": eng.quantize_weight(leaf["w"], policy,
                                            contraction_axes=(-2,),
                                            fused_bias="b" in leaf,
                                            act_fmt=fmt,
                                            total_bits=fmt.total_bits)}
            if "b" in leaf:
                out["b"] = eng.quantize_weight(leaf["b"], policy, fmt=fmt)
            return out

        def qlayer(lp, name):
            fmt = policy.fmt_for(name)
            out = dict(lp)  # norms (and anything float-island) pass through
            out["attn"] = {k: qdense(v, fmt) for k, v in lp["attn"].items()}
            out["ffn"] = {k: qdense(v, fmt) for k, v in lp["ffn"].items()}
            return out

        qp = dict(params)
        qp["blocks"] = tuple(
            qlayer(b, f"g{i}") for i, b in enumerate(params["blocks"])
        )
        qp["tail"] = tuple(
            qlayer(tc, f"tail{j}") for j, tc in enumerate(params["tail"])
        )
        head_w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]["w"]
        hf = policy.fmt_for("head")
        qp["lm_head"] = {"w": eng.quantize_weight(head_w, policy,
                                                  contraction_axes=(-2,),
                                                  act_fmt=hf,
                                                  total_bits=hf.total_bits)}
        return qp

    return eng.qparams_for(params, policy, build)


def calibrate_policy(tpl: Template, cfg, params, tokens,
                     base: Optional[NumericsPolicy] = None) -> NumericsPolicy:
    """The small max-abs calibration pass: pick the activation grid.

    Runs one eager prefill over ``tokens`` (a calibration batch) with every
    island-exit quantization recording the magnitude it snaps, then returns
    ``base`` with the smallest Qm.n format whose range covers the observed
    maximum.  Random-init or wide-ranged models land on e.g. Q4.12 instead
    of saturating the paper's Q2.14 at ±2; a QAT-trained network whose
    activations fit [-2, 2) keeps Q2.14.  Quantize the final parameter tree
    *after* calibration — :func:`quantize_params` keys its cache by policy.
    """
    import dataclasses

    base = base or NumericsPolicy("q16")
    probe_qp = quantize_params(tpl, cfg, params, base)
    fmt = tpl.engine.calibrate_activation_format(
        lambda: prefill(tpl, cfg, probe_qp, tokens,
                        cache_len=tokens.shape[1], policy=base)
    )
    policy = dataclasses.replace(base, fmt=fmt)
    if policy != base:
        # the probe tree was built under the provisional base grid — drop it
        # so it doesn't pin an extra int16 weight copy next to the real one
        tpl.engine.drop_qparams(params, base)
    return policy


def precision_group_names(cfg) -> tuple:
    """Names of the per-precision scan groups of ``cfg``'s stack.

    The scanned stack stages one traced body per pattern position, so the
    finest grid a single scan can carry is per-group: "g{i}" for pattern
    position i, "tail{j}" for the j-th remainder layer, plus "head" for the
    final post-norm quantize feeding the wide logits read-out.
    """
    pattern, _, r = _split(cfg)
    return (tuple(f"g{i}" for i in range(len(pattern)))
            + tuple(f"tail{j}" for j in range(r)) + ("head",))


def calibrate_precision(tpl: Template, cfg, params, tokens, *,
                        budget: float = 0.99,
                        policy: Optional[NumericsPolicy] = None,
                        drift: Optional[dict] = None,
                        ref=None) -> NumericsPolicy:
    """The drift-aware per-group precision DSE for a transformer (§11).

    Warm path: when the PlanRegistry holds a pinned precision choice for
    *every* group of ``cfg`` (loaded from the v3 plan store), the mixed
    policy is rebuilt from the pins — zero forwards, zero searches, each
    group a registry hit (the ``REPRO_PLAN_ASSERT_WARM`` contract).

    Cold path: measure each group's *solo-flip* drift — run the network
    with only that group's activations dropped to the int8 rung of the
    calibrated grid and record the argmax agreement vs the float reference
    (``drift`` short-circuits the sweep with pre-measured rows, e.g. from
    ``benchmarks/precision_drift.py``'s JSON) — then assign int8 wherever
    the agreement meets ``budget`` (:func:`repro.core.dse.choose_precision`)
    and pin every choice with ``source: measured`` provenance.

    ``ref`` overrides the reference predictions (a (B, S) argmax array);
    the default is the pure-float teacher-forced forward.
    """
    import dataclasses

    from repro.core import dse
    from repro.core.quantization import int8_rung

    policy = policy or calibrate_policy(tpl, cfg, params, tokens)
    eng = tpl.engine
    reg = eng.plan_cache
    hw = tpl.config.hw
    names = precision_group_names(cfg)
    low = int8_rung(policy.fmt)
    if low is None:
        return policy  # the calibrated range has no int8 rung
    pins = {name: reg.precision_for(cfg.name, name, hw) for name in names}
    if all(p is not None for p in pins.values()):
        fmts = tuple(sorted(((n, p.fmt) for n, p in pins.items()),
                            key=lambda kv: kv[0]))
        return dataclasses.replace(policy, name="mixed", layer_fmts=fmts)
    if ref is None:
        ref = jnp.argmax(forward(tpl, cfg, params, tokens, mode="fwd")[0],
                         axis=-1)

    def probe_agreement(fmts):
        probe = dataclasses.replace(policy, name="mixed", layer_fmts=fmts)
        qp = quantize_params(tpl, cfg, params, probe)
        got = jnp.argmax(
            forward(tpl, cfg, qp, tokens, mode="fwd", policy=probe)[0],
            axis=-1,
        )
        eng.drop_qparams(params, probe)  # release the probe tree
        return float(jnp.mean(got == ref))

    if drift is None:
        drift = {name: probe_agreement(((name, low),)) for name in names}
    chosen = dse.choose_precision(drift, budget, policy.fmt, low)

    def full_plan():
        return tuple(sorted(((n, chosen.get(n, policy.fmt)) for n in names),
                            key=lambda kv: kv[0]))

    # solo-flip drifts compose: the joint plan can land below the *network*
    # budget even when every member met it alone.  Greedily revert the int8
    # group with the lowest measured agreement until the composed network
    # meets the budget — the accuracy constraint is on the network, not the
    # per-group probes.
    while probe_agreement(full_plan()) < budget:
        int8s = [n for n in names if chosen[n].total_bits == 8]
        if not int8s:
            break
        chosen[min(int8s, key=lambda n: (drift[n], n))] = policy.fmt
    for name in names:
        reg.pin_precision(
            cfg.name, name, chosen.get(name, policy.fmt),
            drift=drift.get(name), spec=hw, source="measured",
        )
    fmts = tuple(sorted(
        ((n, chosen.get(n, policy.fmt)) for n in names), key=lambda kv: kv[0]
    ))
    return dataclasses.replace(policy, name="mixed", layer_fmts=fmts)


def q16_island_counts(cfg, *, mode: str = "decode") -> dict:
    """The residency law: designated float islands of one traced q16 step.

    Sums the per-sublayer island counts (:func:`attention_islands`,
    :func:`mlp_islands`) over the *traced* layer bodies, plus the head (one
    quantize of the post-final-norm hidden, one exactly-descaled logits
    read-out).  Counters tick at trace time and ``lax.scan`` stages each
    pattern-position body exactly once regardless of depth, so the stack
    contributes ``len(pattern) + n_tail`` bodies — the law still catches any
    un-designated float round-trip, because an extra hop inside the layer
    body inflates the count for every scanned layer at once (DESIGN.md §8).
    """
    pattern, _, r = _split(cfg)
    att = attention_islands(cfg, mode=mode, cached=(mode == "prefill"))
    ffn = mlp_islands(cfg)
    bodies = len(pattern) + r
    return {
        "quantize": bodies * (att["quantize"] + ffn["quantize"]) + 1,
        "dequantize": bodies * (att["dequantize"] + ffn["dequantize"]) + 1,
    }


# ---------------------------------------------------------------------------
# per-layer execution
# ---------------------------------------------------------------------------


def _group_policy(policy, name: str):
    """Rebind a mixed policy to one scan group's activation grid.

    The precision granularity of the scanned stack is the pattern position
    ("g0".."gP-1"), the tail layers ("tail0"..), and "head" — one traced
    body per group, so per-group is the finest grid a single scan can
    carry.  For single-grid policies (``layer_fmts`` empty) this is the
    identity; transformer islands re-quantize at every sublayer norm, so
    inter-group boundaries need no mixed epilogue (unlike the CNN path).
    """
    if policy is None or not policy.layer_fmts:
        return policy
    import dataclasses

    return dataclasses.replace(policy, fmt=policy.fmt_for(name), layer_fmts=())


def _run_layer(tpl, cfg, plan: LayerPlan, p, h, *, positions, mode,
               cache=None, ctx=None, cache_len=0, t=None, policy=None,
               n_valid=None):
    """Returns (h, new_cache_or_None, aux)."""
    newc = {}
    aux = jnp.zeros((), jnp.float32)

    if plan.mixer in ("attn", "local", "attn_nc"):
        window = cfg.window if plan.mixer == "local" else 0
        causal = plan.mixer != "attn_nc"
        a_in = norm(cfg, p["norm"], h)
        if mode != "decode":
            a_in = constrain(a_in, "batch", "seq_act", "act_embed")
        if mode == "decode":
            out, c = decode_attention(
                tpl, p["attn"], a_in, cache["attn"], cfg=cfg, t=t, window=window,
                policy=policy, n_valid=n_valid,
            )
            newc["attn"] = c
        else:
            clen = 0
            if mode == "prefill":
                clen = min(window, cache_len) if window else cache_len
            out, c = attention(
                tpl, p["attn"], a_in, cfg=cfg, positions=positions,
                causal=causal, window=window, cache_len=clen, policy=policy,
            )
            if mode == "prefill":
                newc["attn"] = c
        if mode != "decode":
            out = constrain(out, "batch", "seq_act", "act_embed")
            out = _checkpoint_name(out, "attn_out")
        h = h + out
    elif plan.mixer == "rec":
        a_in = norm(cfg, p["norm"], h)
        if mode == "decode":
            out, c = rec_mod.rglru_decode_step(tpl, cfg, p["rec"], a_in, cache["rec"])
            newc["rec"] = c
        elif mode == "prefill":
            out, c = rec_mod.rglru_block(tpl, cfg, p["rec"], a_in, return_cache=True)
            newc["rec"] = c
        else:
            out = rec_mod.rglru_block(tpl, cfg, p["rec"], a_in)
        if mode != "decode":
            out = constrain(out, "batch", "seq_act", "act_embed")
        h = h + out
    elif plan.mixer == "ssm":
        a_in = norm(cfg, p["norm"], h)
        if mode == "decode":
            out, c = ssm_mod.ssm_decode_step(tpl, cfg, p["ssm"], a_in, cache["ssm"])
            newc["ssm"] = c
        elif mode == "prefill":
            out, c = ssm_mod.ssm_block(tpl, cfg, p["ssm"], a_in, return_cache=True)
            newc["ssm"] = c
        else:
            out = ssm_mod.ssm_block(tpl, cfg, p["ssm"], a_in)
        if mode != "decode":
            out = constrain(out, "batch", "seq_act", "act_embed")
        h = h + out

    if plan.cross:
        c_in = norm(cfg, p["cross_norm"], h)
        if mode == "decode":
            out, _ = decode_attention(
                tpl, p["cross"], c_in, cache["cross"], cfg=cfg, t=t, cross=True
            )
            newc["cross"] = cache["cross"]  # static across decode steps
        else:
            clen = ctx.shape[1] if mode == "prefill" else 0
            out, c = attention(
                tpl, p["cross"], c_in, cfg=cfg, positions=positions,
                kv_source=ctx, cache_len=clen,
            )
            if mode == "prefill":
                newc["cross"] = c
        if "cross_gate" in p:
            out = jnp.tanh(p["cross_gate"]).astype(out.dtype) * out
        if mode != "decode":
            out = constrain(out, "batch", "seq_act", "act_embed")
        h = h + out

    if plan.mixer != "ssm":
        f_in = norm(cfg, p["ffn_norm"], h)
        if mode != "decode":
            f_in = constrain(f_in, "batch", "seq_act", "act_embed")
        if plan.moe:
            out, aux = moe_mod.moe_ffn(tpl, cfg, p["ffn"], f_in)
        else:
            out = mlp(tpl, cfg, p["ffn"], f_in, policy=policy)
        if mode != "decode":
            out = constrain(out, "batch", "seq_act", "act_embed")
        h = h + out

    h = constrain(h, "batch", "seq_act", "act_embed")
    return h, (newc or None), aux


# ---------------------------------------------------------------------------
# stacks
# ---------------------------------------------------------------------------


def _run_stack(tpl, cfg, params, h, *, pattern, mode, positions,
               cache=None, ctx=None, cache_len=0, t=None, remat=False,
               policy=None, n_valid=None):
    """Scan the stacked groups + run tail layers.  Returns (h, cache', aux)."""
    n_tail = len(params["tail"]) if "tail" in params else 0

    if mode in ("train", "fwd"):
        def body(carry, xs):
            hh, aux = carry
            for i, plan in enumerate(pattern):
                hh, _, a = _run_layer(
                    tpl, cfg, plan, xs[i], hh,
                    positions=positions, mode=mode, ctx=ctx,
                    policy=_group_policy(policy, f"g{i}"),
                )
                aux = aux + a
            return (hh, aux), None

        if remat and getattr(cfg, "remat_policy", "") == "attn_out":
            body_fn = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.save_only_these_names("attn_out"),
            )
        elif remat:
            body_fn = jax.checkpoint(body)
        else:
            body_fn = body
        (h, aux), _ = jax.lax.scan(body_fn, (h, jnp.zeros((), jnp.float32)),
                                   params["blocks"])
        for j in range(n_tail):
            h, _, a = _run_layer(
                tpl, cfg, pattern[j], params["tail"][j], h,
                positions=positions, mode=mode, ctx=ctx,
                policy=_group_policy(policy, f"tail{j}"),
            )
            aux = aux + a
        return h, None, aux

    if mode == "prefill":
        def body(carry, xs):
            hh, aux = carry
            caches = []
            for i, plan in enumerate(pattern):
                hh, c, a = _run_layer(
                    tpl, cfg, plan, xs[i], hh, positions=positions,
                    mode=mode, ctx=ctx, cache_len=cache_len,
                    policy=_group_policy(policy, f"g{i}"),
                )
                caches.append(c)
                aux = aux + a
            return (hh, aux), tuple(caches)

        (h, aux), cache_blocks = jax.lax.scan(
            body, (h, jnp.zeros((), jnp.float32)), params["blocks"]
        )
        tail_caches = []
        for j in range(n_tail):
            h, c, a = _run_layer(
                tpl, cfg, pattern[j], params["tail"][j], h, positions=positions,
                mode=mode, ctx=ctx, cache_len=cache_len,
                policy=_group_policy(policy, f"tail{j}"),
            )
            tail_caches.append(c)
            aux = aux + a
        return h, {"blocks": cache_blocks, "tail": tuple(tail_caches)}, aux

    # decode
    def body(carry, xs):
        hh = carry
        p_group, c_group = xs
        newcs = []
        for i, plan in enumerate(pattern):
            hh, c, _ = _run_layer(
                tpl, cfg, plan, p_group[i], hh,
                positions=positions, mode=mode, cache=c_group[i], t=t,
                policy=_group_policy(policy, f"g{i}"), n_valid=n_valid,
            )
            newcs.append(c)
        return hh, tuple(newcs)

    h, cache_blocks = jax.lax.scan(body, h, (params["blocks"], cache["blocks"]))
    tail_caches = []
    for j in range(n_tail):
        h, c, _ = _run_layer(
            tpl, cfg, pattern[j], params["tail"][j], h,
            positions=positions, mode=mode, cache=cache["tail"][j], t=t,
            policy=_group_policy(policy, f"tail{j}"), n_valid=n_valid,
        )
        tail_caches.append(c)
    return h, {"blocks": cache_blocks, "tail": tuple(tail_caches)}, jnp.zeros((), jnp.float32)


def _encode(tpl, cfg, enc_params, frames):
    """Whisper-style encoder over precomputed frame embeddings (stub frontend)."""
    nf, d = frames.shape[1], cfg.d_model
    h = frames + sinusoidal_positions(nf, d, frames.dtype)[None]
    h = constrain(h, "batch", "ctx", "act_embed")
    plan = LayerPlan("attn_nc", False, False)

    def body(hh, xs):
        hh, _, _ = _run_layer(
            tpl, cfg, plan, xs, hh,
            positions=jnp.arange(nf), mode="fwd",
        )
        return hh, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(body_fn, h, enc_params["blocks"][0])
    return norm(cfg, enc_params["final_norm"], h)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def _embed_tokens(cfg, params, tokens):
    h = jnp.take(params["embed"], tokens, axis=0)
    return constrain(h, "batch", "seq_act", "act_embed")


def _head(tpl, cfg, params, h, *, policy=None):
    h = norm(cfg, params["final_norm"], h)
    if (
        policy is not None and policy.quantized
        and isinstance(params.get("lm_head", {}).get("w"), QTensor)
    ):
        # final logits boundary: quantize the post-norm hidden once (on the
        # head's grid under a mixed policy), read the int32 accumulator out
        # exactly — logits never saturate on the grid
        hq = tpl.quant(h, policy.fmt_for("head"))
        logits = tpl.matmul(hq, params["lm_head"]["w"], wide=True)
    else:
        w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]["w"]
        logits = tpl.matmul(h, w)
    return constrain(logits, "batch", "seq_act", "vocab")


def forward(tpl: Template, cfg, params, tokens, *, ctx=None, mode: str = "train",
            policy: Optional[NumericsPolicy] = None):
    """Teacher-forced full-sequence forward.  tokens: (B, S) -> logits (B,S,V).

    ``policy``: a quantized :class:`NumericsPolicy` runs the stack
    grid-resident — pass the matching :func:`quantize_params` tree as
    ``params`` (the QTensor weights carry the residency)."""
    s = tokens.shape[1]
    h = _embed_tokens(cfg, params, tokens)
    if getattr(cfg, "abs_pos", False):
        h = h + sinusoidal_positions(s, cfg.d_model, h.dtype)[None]
    if cfg.family == "encdec":
        ctx = _encode(tpl, cfg, params["encoder"], ctx)
    pattern, _, _ = _split(cfg)
    positions = jnp.arange(s)
    h, _, aux = _run_stack(
        tpl, cfg, params, h, pattern=pattern, mode=mode, positions=positions,
        ctx=ctx, remat=cfg.remat, policy=policy,
    )
    return _head(tpl, cfg, params, h, policy=policy), aux


def loss_fn(tpl: Template, cfg, params, batch, aux_weight: float = 0.01):
    """batch: {"tokens": (B,S) int32 [, "labels": (B,S), "ctx": (B,T,d)]}.

    Without explicit labels, next-token targets are derived by shifting
    (last position masked).  labels < 0 are masked out.
    Returns (scalar loss, metrics)."""
    tokens = batch["tokens"]
    logits, aux = forward(tpl, cfg, params, tokens, ctx=batch.get("ctx"))
    labels = batch.get("labels")
    if labels is None:
        labels = jnp.concatenate(
            [tokens[:, 1:], jnp.full_like(tokens[:, :1], -1)], axis=1
        )
    mask = (labels >= 0).astype(jnp.float32)
    ce = cross_entropy_loss(logits, jnp.maximum(labels, 0), mask)
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "aux": aux}


def prefill(tpl: Template, cfg, params, tokens, *, ctx=None,
            cache_len: Optional[int] = None, last_pos=None,
            policy: Optional[NumericsPolicy] = None):
    """Process the prompt; return (last-position logits (B,V), decode cache).

    ``last_pos`` (scalar or (B,) int32, traced) selects which position's
    logits to return — the default is the final position s-1.  The serve
    scheduler pads prompts up to a bucket length and reads the logits at the
    *real* last token, which under causal attention are unaffected by the
    right-padding."""
    s = tokens.shape[1]
    cache_len = cache_len or s
    h = _embed_tokens(cfg, params, tokens)
    if getattr(cfg, "abs_pos", False):
        h = h + sinusoidal_positions(s, cfg.d_model, h.dtype)[None]
    if cfg.family == "encdec":
        ctx = _encode(tpl, cfg, params["encoder"], ctx)
    pattern, _, _ = _split(cfg)
    h, cache, _ = _run_stack(
        tpl, cfg, params, h, pattern=pattern, mode="prefill",
        positions=jnp.arange(s), ctx=ctx, cache_len=cache_len, policy=policy,
    )
    if last_pos is None:
        h_last = h[:, -1:]
    else:
        lp = jnp.asarray(last_pos, jnp.int32)
        if lp.ndim == 0:
            h_last = jax.lax.dynamic_slice_in_dim(h, lp, 1, axis=1)
        else:  # per-row last positions
            h_last = jnp.take_along_axis(h, lp[:, None, None].astype(jnp.int32), axis=1)
    logits = _head(tpl, cfg, params, h_last, policy=policy)
    return logits[:, 0], cache


def _sinusoid_at(t, d, dtype):
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    angle = t.astype(jnp.float32) / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)]).astype(dtype)


def decode_step(tpl: Template, cfg, params, token, t, cache,
                policy: Optional[NumericsPolicy] = None):
    """One decode step.  token: (B,1) int32; t: scalar int32 position, or a
    per-row (B,) position vector when the cache is slot-indexed
    (``init_cache(..., per_slot=True)`` — continuous batching).

    Under a quantized ``policy`` (with a :func:`quantize_params` tree) the
    step is grid-resident end to end: every projection consumes/produces
    int16 QTensors, the ring cache stores int16 raws, and float appears only
    at the designated islands (:func:`q16_island_counts`).

    Returns (logits (B,V), new_cache)."""
    t = jnp.asarray(t, jnp.int32)
    t = t.reshape(()) if t.ndim == 0 else t.reshape(-1)
    h = _embed_tokens(cfg, params, token)
    if getattr(cfg, "abs_pos", False):
        if t.ndim:
            h = h + jax.vmap(lambda tt: _sinusoid_at(tt, cfg.d_model, h.dtype))(t)[:, None]
        else:
            h = h + _sinusoid_at(t, cfg.d_model, h.dtype)[None, None]
    pattern, _, _ = _split(cfg)
    h, cache, _ = _run_stack(
        tpl, cfg, params, h, pattern=pattern, mode="decode",
        positions=t, t=t, cache=cache, policy=policy,
    )
    logits = _head(tpl, cfg, params, h, policy=policy)
    return logits[:, 0], cache


def prefill_chunk_step(tpl: Template, cfg, params, tokens, t, n_valid, cache,
                       policy: Optional[NumericsPolicy] = None):
    """Advance a slot-indexed cache by one prefill *chunk* per batch row.

    tokens: (B, S) int32 — row b holds the prompt slice covering positions
    t[b]..t[b]+n_valid[b]-1 (right-padded to the fixed chunk width S);
    t: (B,) with t[b] < 0 marking an inactive lane whose cache row is left
    byte-identical; n_valid: (B,) real token counts (ragged final chunks).

    One fixed-shape launch — the scheduler interleaves it with the batched
    decode step so a long prompt streams into its slot chunk by chunk without
    stalling resident decodes.  Returns (logits (B, V) read at each row's
    last *valid* token — meaningful only for rows finishing their prompt this
    chunk — and the updated cache).  Under a quantized ``policy`` the step is
    grid-resident exactly like :func:`decode_step`.
    """
    t = jnp.asarray(t, jnp.int32).reshape(-1)
    nv = jnp.asarray(n_valid, jnp.int32).reshape(-1)
    s = tokens.shape[1]
    h = _embed_tokens(cfg, params, tokens)
    if getattr(cfg, "abs_pos", False):
        qpos = t[:, None] + jnp.arange(s)[None, :]
        h = h + jax.vmap(
            jax.vmap(lambda tt: _sinusoid_at(tt, cfg.d_model, h.dtype))
        )(qpos)
    pattern, _, _ = _split(cfg)
    h, cache, _ = _run_stack(
        tpl, cfg, params, h, pattern=pattern, mode="decode",
        positions=t, t=t, cache=cache, policy=policy, n_valid=nv,
    )
    last = jnp.clip(nv - 1, 0, s - 1)
    h_last = jnp.take_along_axis(h, last[:, None, None], axis=1)
    logits = _head(tpl, cfg, params, h_last, policy=policy)
    return logits[:, 0], cache


# ---------------------------------------------------------------------------
# decode-cache construction (for dry-run decode cells and serving)
# ---------------------------------------------------------------------------


def _ctx_len(cfg) -> int:
    if cfg.family == "encdec":
        return cfg.n_frames
    if cfg.family == "vlm":
        return cfg.n_image_tokens
    return 0


def _init_layer_cache(cfg, plan: LayerPlan, batch, cache_len, dtype,
                      filled_ctx=True, per_slot=False):
    c = {}
    if plan.mixer in ("attn", "local"):
        clen = min(cfg.window, cache_len) if (plan.mixer == "local" and cfg.window) else cache_len
        c["attn"] = init_layer_cache(batch, cfg.n_kv_heads, clen, cfg.head_dim,
                                     dtype, per_slot=per_slot)
    elif plan.mixer == "rec":
        c["rec"] = rec_mod.init_rglru_cache(cfg, batch, dtype)
    elif plan.mixer == "ssm":
        c["ssm"] = ssm_mod.init_ssm_cache(cfg, batch, dtype)
    if plan.cross:
        tctx = _ctx_len(cfg)
        cc = init_layer_cache(batch, cfg.n_kv_heads, tctx, cfg.head_dim, dtype)
        if filled_ctx:  # as-if-prefilled: cross context slots are all valid
            cc["pos"] = jnp.arange(tctx, dtype=jnp.int32)
        c["cross"] = cc
    return c


def init_cache(cfg, batch: int, cache_len: int, dtype=None, *, per_slot: bool = False,
               policy=None):
    """Zero-initialized decode cache with the exact prefill-cache structure.

    ``per_slot=True`` builds the slot-indexed layout (self-attention pos
    vectors become (B, C)) used by the continuous-batching scheduler, where
    each batch row is an independent session at its own decode position.

    A quantized ``policy`` resolves the KV storage dtype *per scan group*:
    group "g{i}"/"tail{j}" caches take ``policy.fmt_for(name).storage_dtype``
    (int8 for layers the precision DSE dropped to the 8-bit rung, int16
    otherwise), so a mixed plan's cache bytes shrink exactly where the plan
    says they may.  An explicit ``dtype`` overrides the policy uniformly."""
    pattern, g, r = _split(cfg)

    def group_dtype(name):
        if dtype is None and policy is not None and policy.quantized:
            return policy.fmt_for(name).storage_dtype
        return jnp.dtype(dtype or cfg.dtype)

    def stacked(plan, name):
        one = _init_layer_cache(cfg, plan, batch, cache_len, group_dtype(name),
                                per_slot=per_slot)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (g, *a.shape)), one)

    return {
        "blocks": tuple(stacked(p, f"g{i}") for i, p in enumerate(pattern)),
        "tail": tuple(
            _init_layer_cache(cfg, pattern[j], batch, cache_len,
                              group_dtype(f"tail{j}"), per_slot=per_slot)
            for j in range(r)
        ),
    }


def _trim_cache_positions(cache_part, valid_len):
    """Invalidate self-attention cache entries at positions >= valid_len.

    A bucket-padded prefill fills ring slots for the pad positions too; those
    entries must be masked out (pos = -1) before decode reaches position
    valid_len, or the pad keys become visible.  Cross caches (static context)
    are left untouched; rec/ssm states have no positional validity to trim —
    padding is unsound for them in the first place (the scheduler only admits
    attention-mixer families).
    """
    vl = jnp.asarray(valid_len, jnp.int32)

    def walk(node):
        if isinstance(node, dict):
            out = {}
            for key, sub in node.items():
                if key == "attn" and isinstance(sub, dict) and "pos" in sub:
                    pos = sub["pos"]
                    out[key] = {**sub, "pos": jnp.where(pos < vl, pos, -1)}
                else:
                    out[key] = walk(sub)
            return out
        if isinstance(node, tuple):
            return tuple(walk(x) for x in node)
        return node

    return walk(cache_part)


def insert_cache_slot(cache, slot: int, row_cache, *, valid_len=None):
    """Write a batch-1 prefill cache into row ``slot`` of a batched cache.

    ``cache`` is a (possibly slot-indexed) batched decode cache from
    :func:`init_cache`; ``row_cache`` is the cache returned by a batch-1
    :func:`prefill` with the same cache_len.  ``valid_len`` (the real prompt
    length) invalidates the pad positions a bucket-padded prefill filled.
    Leaves stack the batch at axis 1 under "blocks" (scan-group leading axis)
    and axis 0 under "tail"; per-slot pos rows — (C,) in the row cache,
    (B, C) batched — are detected by the ndim difference.  Returns the new
    cache (functional update; slot reuse is just a later insert).
    """
    if valid_len is not None:
        row_cache = _trim_cache_positions(row_cache, valid_len)

    def ins(batch_axis):
        def put(dst, src):
            idx = (slice(None),) * batch_axis + (slot,)
            if src.ndim == dst.ndim:  # batched leaf: drop the size-1 batch dim
                src = jnp.squeeze(src, axis=batch_axis)
            return dst.at[idx].set(src.astype(dst.dtype))

        return put

    return {
        "blocks": jax.tree.map(ins(1), cache["blocks"], row_cache["blocks"]),
        "tail": jax.tree.map(ins(0), cache["tail"], row_cache["tail"]),
    }


def insert_cache_rows(cache, rows_cache, *, src_rows, sel, valid_lens):
    """Scatter rows of a batched (B_pre, L) prefill cache into cache slots.

    The batched-bucket admission path: one prefill over B_pre stacked prompts
    produces ``rows_cache`` (same cache_len as ``cache``); for every slot j
    with ``sel[j]`` true, source row ``src_rows[j]`` is written into slot j
    and its pad positions >= ``valid_lens[j]`` invalidated (pos = -1).
    Slots with sel[j] false keep their bytes exactly (gather-select, no
    scatter aliasing), so one fixed-shape call serves any admission subset.

    ``src_rows``/``sel``/``valid_lens`` are (n_slots,) vectors; src_rows for
    unselected slots may be arbitrary in-range indices.  k/v leaves stack the
    batch at axis 1 under "blocks" and axis 0 under "tail"; the prefill's
    shared pos vector — (C,) per row cache — is detected by the ndim
    difference and expanded per slot.  Returns the new cache.
    """
    src = jnp.asarray(src_rows, jnp.int32)
    selb = jnp.asarray(sel, bool)
    vl = jnp.asarray(valid_lens, jnp.int32)
    n = selb.shape[0]

    def ins(batch_axis):
        def put(dst, src_leaf):
            if src_leaf.ndim < dst.ndim:
                # shared prefill pos (..., C) -> per-slot (..., n, C) rows,
                # pad positions trimmed per slot's real prompt length
                pos = src_leaf[..., None, :]
                pos = jnp.where(pos < vl[:, None], pos, -1)
                return jnp.where(selb[:, None], pos, dst)
            gathered = jnp.take(src_leaf, src, axis=batch_axis)
            shape = [1] * dst.ndim
            shape[batch_axis] = n
            m = selb.reshape(shape)
            return jnp.where(m, gathered.astype(dst.dtype), dst)

        return put

    return {
        "blocks": jax.tree.map(ins(1), cache["blocks"], rows_cache["blocks"]),
        "tail": jax.tree.map(ins(0), cache["tail"], rows_cache["tail"]),
    }


def clear_cache_rows(cache, sel):
    """Invalidate the self-attention pos rows of selected slots (pos := -1).

    Chunked admission streams a prompt into its slot with
    :func:`prefill_chunk_step` instead of a whole-row insert, so stale ring
    entries from the slot's previous occupant must be masked out first —
    otherwise they stay visible at positions the chunks have not reached yet.
    k/v bytes are left as-is (pos = -1 already hides them).  ``sel`` is an
    (n_slots,) bool vector; unselected rows are untouched.
    """
    selb = jnp.asarray(sel, bool)

    def walk(node):
        if isinstance(node, dict):
            out = {}
            for key, sub in node.items():
                if key == "attn" and isinstance(sub, dict) and "pos" in sub:
                    pos = sub["pos"]
                    out[key] = {**sub, "pos": jnp.where(selb[:, None], -1, pos)}
                else:
                    out[key] = walk(sub)
            return out
        if isinstance(node, tuple):
            return tuple(walk(x) for x in node)
        return node

    return walk(cache)


def cache_axes(cfg, cache_shapes):
    """Logical axes tree for a cache pytree (mirrors :func:`init_cache`).

    Leaves are named — k/v ring buffers shard (batch, kv_heads); recurrent
    and conv states shard (batch, inner); pos vectors replicate.  Stacked
    (scan-group) leading axes get a None prefix.
    """

    def by_name(subtree_name, leaf, stacked):
        pre = (None,) if stacked else ()
        if subtree_name in ("k", "v"):
            # ring caches shard their *seq* dim over the model axis
            # (flash-decoding style) because GQA kv counts (8) do not divide
            # 16-way TP; heads replicate, the softmax/LSE reduces over shards.
            return pre + ("batch", None, "seq_kv", None)
        if subtree_name == "pos":
            return None
        if subtree_name == "h":
            return pre + ("batch", "rec")
        if subtree_name == "state":
            return pre + ("batch", "act_heads", None, None)
        if subtree_name == "conv":
            return pre + ("batch", None, "ssm_inner")
        return None

    def walk(tree, stacked):
        if isinstance(tree, dict):
            out = {}
            for k, v in tree.items():
                if isinstance(v, dict):
                    out[k] = walk(v, stacked)
                elif isinstance(v, tuple):
                    out[k] = tuple(walk(x, stacked) for x in v)
                else:
                    out[k] = by_name(k, v, stacked)
            return out
        if isinstance(tree, tuple):
            return tuple(walk(x, stacked) for x in tree)
        return None

    return {
        "blocks": tuple(walk(b, True) for b in cache_shapes["blocks"]),
        "tail": tuple(walk(tc, False) for tc in cache_shapes["tail"]),
    }
