"""RG-LRU recurrent block (RecurrentGemma, arXiv:2402.19427).

The block's GEMMs (in/out projections, gate matrices) route through the
Template compute unit; the element-wise linear recurrence

    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t),
    a_t = exp(c * log_lambda * r_t),   c = 8,
    r_t = sigmoid(W_a x_t + b_a),  i_t = sigmoid(W_x x_t + b_x)

is not GEMM-shaped and runs on the XLA plane: ``jax.lax.associative_scan``
for train/prefill (log-depth, TPU-native) and an O(1) update for decode.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.template import Template
from repro.parallel.sharding import constrain

from .layers import init_dense, dense

__all__ = [
    "init_rglru",
    "rglru_axes",
    "rglru_block",
    "rglru_decode_step",
    "init_rglru_cache",
    "rglru_reference",
]

_C = 8.0  # RG-LRU temperature constant


def _d_rec(cfg) -> int:
    return getattr(cfg, "d_rec", 0) or cfg.d_model


def init_rglru(key, cfg, dtype=jnp.float32):
    d, dr = cfg.d_model, _d_rec(cfg)
    ks = jax.random.split(key, 6)
    # Lambda param s.t. a = sigmoid(lam)^(c*r) in (0,1); init so a^c ~ U(0.9, 0.999)
    u = jax.random.uniform(ks[4], (dr,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u ** (1.0 / _C)) - jnp.log1p(-(u ** (1.0 / _C)))
    ks6 = jax.random.split(ks[5], 2)
    return {
        "in_x": init_dense(ks[0], d, dr, dtype=dtype),
        "in_y": init_dense(ks[1], d, dr, dtype=dtype),
        "conv_w": (jax.random.normal(ks6[0], (cfg.ssm_conv, dr)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((dr,), dtype),
        "gate_a": init_dense(ks[2], dr, dr, bias=True, dtype=dtype),
        "gate_x": init_dense(ks[3], dr, dr, bias=True, dtype=dtype),
        "lam": lam,
        "out": init_dense(ks6[1], dr, d, dtype=dtype, scale=dr ** -0.5),
    }


def rglru_axes(cfg) -> dict:
    return {
        "in_x": {"w": ("embed", "rec")},
        "in_y": {"w": ("embed", "rec")},
        "conv_w": (None, "rec"),
        "conv_b": ("rec",),
        "gate_a": {"w": ("rec_in", "rec"), "b": ("rec",)},
        "gate_x": {"w": ("rec_in", "rec"), "b": ("rec",)},
        "lam": ("rec",),
        "out": {"w": ("rec", "embed")},
    }


def _causal_conv(x, w, b, state=None):
    width = w.shape[0]
    if state is None:
        hist = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        hist = state.astype(x.dtype)
    xp = jnp.concatenate([hist, x], axis=1)
    y = jnp.zeros_like(x)
    for i in range(width):
        y = y + xp[:, i : i + x.shape[1], :] * w[i][None, None, :]
    new_state = xp[:, -(width - 1):, :] if width > 1 else hist
    return y + b[None, None, :], new_state


def _gates(tpl, p, x):
    """r_t, i_t and the log-decay log_a for each position.  x: (B,S,dr).

    The gate matmuls are GEMMs and route through the Template compute unit.
    """
    r = jax.nn.sigmoid(dense(tpl, p["gate_a"], x))
    i = jax.nn.sigmoid(dense(tpl, p["gate_x"], x))
    log_lam = jax.nn.log_sigmoid(p["lam"].astype(jnp.float32))  # log a_base < 0
    log_a = _C * log_lam[None, None, :] * r.astype(jnp.float32)  # (B,S,dr) <= 0
    return r, i, log_a


def _lru_scan(log_a: jax.Array, gated_x: jax.Array,
              init_h: Optional[jax.Array] = None) -> jax.Array:
    """Associative scan of h_t = a_t h_{t-1} + b_t over axis 1 (seq).

    log_a: (B,S,D) f32, gated_x: (B,S,D) f32 (= sqrt(1-a^2) * i * x).
    """
    a = jnp.exp(log_a)
    b = gated_x
    if init_h is not None:
        # fold the carried state into the first step's additive term
        b = b.at[:, 0].add(a[:, 0] * init_h.astype(b.dtype))

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_reference(log_a, gated_x, init_h=None):
    """Sequential loop oracle for tests."""
    b, s, d = log_a.shape
    h = jnp.zeros((b, d), jnp.float32) if init_h is None else init_h
    out = []
    for t in range(s):
        h = jnp.exp(log_a[:, t]) * h + gated_x[:, t]
        out.append(h)
    return jnp.stack(out, axis=1)


def init_rglru_cache(cfg, batch: int, dtype) -> dict:
    dr = _d_rec(cfg)
    return {
        "h": jnp.zeros((batch, dr), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, dr), dtype),
    }


def rglru_block(
    tpl: Template,
    cfg,
    p,
    u: jax.Array,
    *,
    init_cache: Optional[dict] = None,
    return_cache: bool = False,
):
    """Full recurrent block fwd.  u: (B,S,d_model)."""
    x = dense(tpl, p["in_x"], u)
    y = jax.nn.gelu(dense(tpl, p["in_y"], u))
    conv_state = None if init_cache is None else init_cache["conv"]
    x, new_conv = _causal_conv(x, p["conv_w"], p["conv_b"], conv_state)
    x = constrain(x, "batch", None, "rec")
    r, i, log_a = _gates(tpl, p, x)
    # sqrt(1 - a^2) input normalizer keeps the state variance bounded
    sq = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, 1.0))
    gated = sq * (i.astype(jnp.float32) * x.astype(jnp.float32))
    init_h = None if init_cache is None else init_cache["h"]
    h = _lru_scan(log_a, gated, init_h).astype(x.dtype)
    o = dense(tpl, p["out"], h * y)
    if return_cache:
        return o, {"h": h[:, -1].astype(jnp.float32), "conv": new_conv}
    return o


def rglru_decode_step(tpl: Template, cfg, p, u: jax.Array, cache: dict):
    """One-token update.  u: (B,1,d_model)."""
    x = dense(tpl, p["in_x"], u)
    y = jax.nn.gelu(dense(tpl, p["in_y"], u))
    hist = cache["conv"]
    width = p["conv_w"].shape[0]
    window = jnp.concatenate([hist.astype(x.dtype), x], axis=1)  # (B,W,dr)
    xc = jnp.einsum("bwc,wc->bc", window, p["conv_w"].astype(x.dtype)) + p["conv_b"][None, :]
    new_conv = window[:, 1:, :] if width > 1 else hist
    xc = xc[:, None, :]
    r, i, log_a = _gates(tpl, p, xc)
    sq = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, 1.0))
    gated = sq * (i.astype(jnp.float32) * xc.astype(jnp.float32))
    h = jnp.exp(log_a[:, 0]) * cache["h"] + gated[:, 0]  # (B,dr)
    o = dense(tpl, p["out"], (h.astype(x.dtype))[:, None, :] * y)
    return o, {"h": h, "conv": new_conv}
