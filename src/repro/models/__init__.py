"""Model substrate: shared layers + the unified TransformerLM + CNN zoo.

- layers.py       norms, RoPE, MLP, losses
- attention.py    GQA/cross/windowed attention, ring-buffer KV cache
- moe.py          grouped capacity-based mixture-of-experts
- ssm.py          Mamba2 SSD (chunked scan + recurrent decode)
- rglru.py        RG-LRU recurrent block (RecurrentGemma)
- transformer.py  the one model definition covering all assigned families
- cnn.py          the paper's own AlexNet/VGG16/LeNet on the compute unit
"""
from . import attention, cnn, layers, moe, rglru, ssm, transformer

__all__ = ["attention", "cnn", "layers", "moe", "rglru", "ssm", "transformer"]
