"""Mamba2 SSD (state-space duality) block — the attention-free sequence mixer.

The paper's conv/FC unification covers the *projections* of this block (they
route through the Template compute unit); the SSD scan itself is not
GEMM-shaped and runs on the "PS plane" (XLA) per the paper's HW/SW
partitioning rule — documented in DESIGN.md §5.

Two execution modes:

* ``ssd_chunked`` — training/prefill: the chunked SSD algorithm (Dao & Gu,
  arXiv:2405.21060 Listing 1) under ``lax.scan`` over chunks so memory is
  bounded by one (Q x Q) intra-chunk matrix per head, and the inter-chunk
  state recurrence is the scan carry.
* ``ssd_decode_step`` — serving: the O(1)-per-token recurrent update
  ``h = exp(dt*A) h + dt * (B ⊗ x)``; ``y = C·h + D x``.

Layout conventions (B=batch, S=seq, H=ssm heads, P=head dim, G=BC groups,
N=state dim):  x: (B,S,H,P), B/C: (B,S,G,N), dt: (B,S,H).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.template import Template
from repro.parallel.sharding import constrain

from .layers import init_dense, dense, rms_norm

__all__ = [
    "init_ssm",
    "ssm_axes",
    "ssm_block",
    "ssm_decode_step",
    "init_ssm_cache",
    "ssd_reference",
]


def _conv_dim(cfg) -> int:
    return cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state


def _in_proj_dim(cfg) -> int:
    # z (d_inner) | xBC (conv_dim) | dt (nheads)
    return 2 * cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state + cfg.ssm_nheads


def init_ssm(key, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    h = cfg.ssm_nheads
    return {
        "in_proj": init_dense(ks[0], cfg.d_model, _in_proj_dim(cfg), dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, _conv_dim(cfg))) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((_conv_dim(cfg),), dtype),
        # A in (-inf, 0): A = -exp(A_log); init A in [-1, -e]
        "A_log": jnp.zeros((h,), jnp.float32)
        + jnp.log(jnp.linspace(1.0, jnp.e, h)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((h,), 0.01, jnp.float32))),
        "norm_scale": jnp.zeros((cfg.d_inner,), dtype),
        "out_proj": init_dense(ks[3], cfg.d_inner, cfg.d_model, dtype=dtype,
                               scale=cfg.d_inner ** -0.5),
    }


def ssm_axes(cfg) -> dict:
    """Logical axes: inner dim is the TP axis (heads shard over "model")."""
    return {
        "in_proj": {"w": ("embed", "ssm_inner")},
        "conv_w": (None, "ssm_inner"),
        "conv_b": ("ssm_inner",),
        "A_log": (None,),
        "D": (None,),
        "dt_bias": (None,),
        "norm_scale": ("ssm_inner",),
        "out_proj": {"w": ("ssm_inner", "embed")},
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv over time.  x: (B,S,C), w: (W,C), b: (C,).

    Returns (y, new_state) where state is the last W-1 inputs (for decode).
    """
    width = w.shape[0]
    if state is None:
        hist = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        hist = state.astype(x.dtype)
    xp = jnp.concatenate([hist, x], axis=1)  # (B, S+W-1, C)
    y = jnp.zeros_like(x)
    for i in range(width):
        y = y + xp[:, i : i + x.shape[1], :] * w[i][None, None, :]
    new_state = xp[:, -(width - 1):, :] if width > 1 else hist
    return y + b[None, None, :], new_state


def _split_in_proj(cfg, zxbcdt):
    di, g, n, h = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : di + _conv_dim_raw(di, g, n)]
    dt = zxbcdt[..., di + _conv_dim_raw(di, g, n):]
    return z, xBC, dt


def _conv_dim_raw(di, g, n):
    return di + 2 * g * n


def _split_xbc(cfg, xBC):
    di, g, n = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state
    x = xBC[..., :di]
    Bm = xBC[..., di : di + g * n]
    Cm = xBC[..., di + g * n :]
    return x, Bm, Cm


def _expand_groups(m: jax.Array, h: int) -> jax.Array:
    """(B, S, G, N) -> (B, S, H, N) by repeating each group H/G times."""
    g = m.shape[2]
    rep = h // g
    return jnp.repeat(m, rep, axis=2) if rep > 1 else m


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int,
                init_state: Optional[jax.Array] = None,
                return_state: bool = False):
    """Chunked SSD.  x: (B,S,H,P), dt: (B,S,H), A: (H,) negative,
    Bm/Cm: (B,S,H,N) (already group-expanded).  Returns y: (B,S,H,P)
    [, final_state: (B,H,P,N)].

    The inter-chunk state recurrence is the scan carry; per-chunk work is the
    quadratic intra-chunk term (Q x Q per head, Q = ``chunk``).
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    q = min(chunk, s)
    s_orig = s
    if s % q:
        # pad to a chunk multiple; dt=0 in the pad keeps the state untouched
        # (exp(0*A)=1 decay, zero input update) so the final state is exact.
        pad = q - s % q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s = s + pad
    nc = s // q

    f32 = jnp.float32
    xc = x.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h).astype(f32)
    bc = Bm.reshape(b, nc, q, h, n)
    cc = Cm.reshape(b, nc, q, h, n)

    dA = dtc * A[None, None, None, :]  # (B,nc,Q,H), negative
    cs = jnp.cumsum(dA, axis=2)  # within-chunk cumulative sum

    state0 = (
        jnp.zeros((b, h, p, n), f32)
        if init_state is None
        else init_state.astype(f32)
    )

    def body(state, inp):
        xq, dtq, bq, cq, dAq, csq = inp  # leading dim B (chunk axis scanned)
        # intra-chunk: L[q1,q2] = exp(cs[q1]-cs[q2]) for q1 >= q2
        li = csq[:, :, None, :] - csq[:, None, :, :]  # (B,Q,Q,H)
        tri = jnp.tril(jnp.ones((q, q), bool))
        L = jnp.where(tri[None, :, :, None], jnp.exp(li), 0.0)
        xdt = xq.astype(f32) * dtq[..., None]  # (B,Q,H,P) discretized input
        scores = jnp.einsum("bqhn,bkhn->bqkh", cq.astype(f32), bq.astype(f32))
        y_diag = jnp.einsum("bqkh,bqkh,bkhp->bqhp", scores, L, xdt)
        # contribution of the carried state to every position in the chunk
        y_off = jnp.einsum(
            "bqhn,bhpn,bqh->bqhp", cq.astype(f32), state, jnp.exp(csq)
        )
        # update state: decay to end-of-chunk + new inputs
        decay_states = jnp.exp(csq[:, -1:, :] - csq)  # (B,Q,H)
        new_state = state * jnp.exp(csq[:, -1, :])[:, :, None, None] + jnp.einsum(
            "bkhn,bkh,bkhp->bhpn", bq.astype(f32), decay_states, xdt
        )
        return new_state, (y_diag + y_off)

    # scan over chunks: move nc to the front
    inputs = tuple(
        jnp.moveaxis(t, 1, 0) for t in (xc, dtc, bc, cc, dA, cs)
    )
    final_state, ys = jax.lax.scan(body, state0, inputs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p).astype(x.dtype)[:, :s_orig]
    if return_state:
        return y, final_state
    return y


def ssd_reference(x, dt, A, Bm, Cm):
    """Sequential recurrence oracle (tests): O(S) loop over time."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    f32 = jnp.float32
    state = jnp.zeros((b, h, p, n), f32)
    ys = []
    for t in range(s):
        dA = jnp.exp(dt[:, t].astype(f32) * A[None, :])  # (B,H)
        upd = jnp.einsum(
            "bh,bhp,bhn->bhpn",
            dt[:, t].astype(f32),
            x[:, t].astype(f32),
            Bm[:, t].astype(f32),
        )
        state = state * dA[..., None, None] + upd
        ys.append(jnp.einsum("bhpn,bhn->bhp", state, Cm[:, t].astype(f32)))
    return jnp.stack(ys, axis=1).astype(x.dtype), state


def init_ssm_cache(cfg, batch: int, dtype) -> dict:
    return {
        "state": jnp.zeros(
            (batch, cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state), jnp.float32
        ),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, _conv_dim(cfg)), dtype),
    }


def ssm_block(
    tpl: Template,
    cfg,
    p,
    u: jax.Array,
    *,
    init_cache: Optional[dict] = None,
    return_cache: bool = False,
):
    """Full Mamba2 block fwd (train/prefill).  u: (B,S,d_model)."""
    zxbcdt = dense(tpl, p["in_proj"], u)
    z, xBC, dt = _split_in_proj(cfg, zxbcdt)
    conv_state = None if init_cache is None else init_cache["conv"]
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    xBC = jax.nn.silu(xBC)
    x, Bm, Cm = _split_xbc(cfg, xBC)
    b, s, _ = x.shape
    h, pd, g, n = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_ngroups, cfg.ssm_state
    x = x.reshape(b, s, h, pd)
    x = constrain(x, "batch", None, "act_heads", None)
    Bm = _expand_groups(Bm.reshape(b, s, g, n), h)
    Cm = _expand_groups(Cm.reshape(b, s, g, n), h)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])

    init_state = None if init_cache is None else init_cache["state"]
    out = ssd_chunked(x, dt, A, Bm, Cm, cfg.ssm_chunk,
                      init_state=init_state, return_state=return_cache)
    if return_cache:
        y, final_state = out
    else:
        y, final_state = out, None
    y = y + x * p["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(b, s, cfg.d_inner)
    # gated RMSNorm (Mamba2): normalize y, gate with silu(z)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"])
    y = constrain(y, "batch", None, "act_embed")
    o = dense(tpl, p["out_proj"], y)
    if return_cache:
        return o, {"state": final_state, "conv": new_conv}
    return o


def ssm_decode_step(tpl: Template, cfg, p, u: jax.Array, cache: dict):
    """One-token recurrent update.  u: (B,1,d_model) -> (B,1,d_model)."""
    zxbcdt = dense(tpl, p["in_proj"], u)
    z, xBC, dt = _split_in_proj(cfg, zxbcdt)
    # conv step: append to history, apply taps at the last position
    hist = cache["conv"]  # (B, W-1, C)
    width = p["conv_w"].shape[0]
    window = jnp.concatenate([hist.astype(xBC.dtype), xBC], axis=1)  # (B,W,C)
    yconv = jnp.einsum("bwc,wc->bc", window, p["conv_w"].astype(xBC.dtype))
    xBC1 = jax.nn.silu(yconv + p["conv_b"][None, :])[:, None, :]
    new_conv = window[:, 1:, :] if width > 1 else hist

    x, Bm, Cm = _split_xbc(cfg, xBC1)
    b = x.shape[0]
    h, pd, g, n = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_ngroups, cfg.ssm_state
    x = x.reshape(b, h, pd)
    Bm = _expand_groups(Bm.reshape(b, 1, g, n), h)[:, 0]
    Cm = _expand_groups(Cm.reshape(b, 1, g, n), h)[:, 0]
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"][None, :])
    A = -jnp.exp(p["A_log"])

    state = cache["state"]  # (B,H,P,N) f32
    dA = jnp.exp(dt * A[None, :])  # (B,H)
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt, x.astype(jnp.float32), Bm.astype(jnp.float32))
    state = state * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", state, Cm.astype(jnp.float32)).astype(x.dtype)
    y = y + x * p["D"][None, :, None].astype(x.dtype)
    y = y.reshape(b, 1, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"])
    o = dense(tpl, p["out_proj"], y)
    return o, {"state": state, "conv": new_conv}
