"""Shared neural-net layers (functional, pytree params, logical sharding).

All GEMMs route through the Template compute unit (the paper's unification);
norms/rotations run on the "PS plane" (plain XLA), mirroring the paper's
HW/SW partitioning.  Bias (and optionally ReLU) are fused into the compute
unit's write-back via the execution-plan engine (DESIGN.md §3), and block
selection for every dense GEMM is memoized in the engine's plan cache — the
DSE grid search runs once per distinct shape per process.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantization import NumericsPolicy, QTensor
from repro.core.template import Template
from repro.parallel.sharding import constrain

__all__ = [
    "init_dense",
    "dense",
    "mlp_islands",
    "rms_norm",
    "layer_norm",
    "apply_rope",
    "init_mlp",
    "mlp",
    "sinusoidal_positions",
    "cross_entropy_loss",
]


def init_dense(key, d_in: int, d_out: int, *, bias: bool = False, dtype=jnp.float32, scale: Optional[float] = None):
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(tpl: Template, p, x: jax.Array, *, relu: bool = False) -> jax.Array:
    """Linear layer with the bias (and optional ReLU) fused into the kernel."""
    return tpl.linear(x, p["w"], p.get("b"), relu=relu)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def norm(cfg, p, x):
    if cfg.norm == "layernorm":
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"])


def init_norm(cfg, dtype=jnp.float32):
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,), dtype), "bias": jnp.zeros((cfg.d_model,), dtype)}
    return {"scale": jnp.zeros((cfg.d_model,), dtype)}


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D), positions: (B, S) or (S,)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freq  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def init_mlp(key, cfg, d_model: Optional[int] = None, d_ff: Optional[int] = None, dtype=jnp.float32):
    d = d_model or cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        return {
            "gate": init_dense(ks[0], d, ff, dtype=dtype),
            "up": init_dense(ks[1], d, ff, dtype=dtype),
            "down": init_dense(ks[2], ff, d, dtype=dtype, scale=ff ** -0.5),
        }
    return {
        "up": init_dense(ks[0], d, ff, dtype=dtype),
        "down": init_dense(ks[1], ff, d, dtype=dtype, scale=ff ** -0.5),
    }


def mlp_axes(cfg) -> dict:
    if cfg.act == "swiglu":
        return {
            "gate": {"w": ("embed", "mlp")},
            "up": {"w": ("embed", "mlp")},
            "down": {"w": ("mlp", "embed")},
        }
    return {"up": {"w": ("embed", "mlp")}, "down": {"w": ("mlp", "embed")}}


def mlp(tpl: Template, cfg, p, x: jax.Array,
        policy: Optional[NumericsPolicy] = None) -> jax.Array:
    """FFN.  Under a quantized policy (QTensor weights, DESIGN.md §8) the
    projections run grid-resident: the post-norm input is quantized *once*
    and shared by gate/up, and only the nonlinearity — silu/gelu are float
    islands; fixed point cannot express them — crosses back to float.  The
    down projection consumes the requantized activation directly, so the
    only float hops per FFN are the designated activation island.
    """
    if policy is not None and policy.quantized and isinstance(p["up"]["w"], QTensor):
        eng = tpl.engine
        xq = eng.quant(x, policy.fmt)
        if cfg.act == "swiglu":
            h = jax.nn.silu(eng.dequant(dense(tpl, p["gate"], xq))) * eng.dequant(
                dense(tpl, p["up"], xq)
            )
        else:
            h = jax.nn.gelu(eng.dequant(dense(tpl, p["up"], xq)))
        h = constrain(h, "batch", None, "mlp")
        return eng.dequant(dense(tpl, p["down"], eng.quant(h, policy.fmt)))
    if cfg.act == "swiglu":
        h = jax.nn.silu(dense(tpl, p["gate"], x)) * dense(tpl, p["up"], x)
    else:
        h = jax.nn.gelu(dense(tpl, p["up"], x))
    h = constrain(h, "batch", None, "mlp")
    return dense(tpl, p["down"], h)


def mlp_islands(cfg) -> dict:
    """Designated float islands of one quantized FFN: (quantize, dequantize)
    call counts.  swiglu: quant {x, silu*up product}, dequant {gate, up,
    down}; gelu: quant {x, gelu out}, dequant {up, down}."""
    if cfg.act == "swiglu":
        return {"quantize": 2, "dequantize": 3}
    return {"quantize": 2, "dequantize": 2}


def sinusoidal_positions(n: int, d: int, dtype=jnp.float32) -> jax.Array:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    angle = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1).astype(dtype)


def cross_entropy_loss(logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None) -> jax.Array:
    """logits: (..., V) f32-upcast inside; labels: (...) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()
