"""Mixed int8/int16 precision acceptance suite (DESIGN.md §11).

The load-bearing assertions of the precision ladder:

* **Mixed-boundary epilogue** — the grid-resident GEMM with q8/q16 operands
  in any combination (and either output rung) is bit-identical to
  ``qtensor_matmul_ref``: an int8 layer feeds an int16 layer (and vice
  versa) through the shift-based write-back with zero float round-trips.
* **Mixed LeNet forward** — a whole forced-mixed LeNet forward (int8 and
  int16 layers interleaved) matches an independent im2col +
  ``qtensor_matmul_ref`` oracle bit-for-bit, through the exact wide
  read-out of the classifier.
* **int8 KV cache** — a group the DSE drops to the int8 rung stores int8
  raws in both ``init_cache`` and the prefill-built cache; other groups
  stay int16.
* **Half-bytes law** — the byte accounting helpers report exactly half the
  q16 activation/KV bytes for int8-assigned layers.
* **Warm pins** — a populated registry rebuilds the identical mixed policy
  with hits only: zero misses, zero forwards (REPRO_PLAN_ASSERT_WARM).
* **Composed budget** — the greedy revert loop enforces the accuracy budget
  on the *network*: when the composed plan misses it, int8 layers revert
  (lowest solo-flip agreement first) until it holds or none remain.
"""
import dataclasses
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.configs import get_config, reduced
from repro.core import dse
from repro.core.engine import (
    PLAN_STORE_ENV,
    Engine,
    PlanRegistry,
    plan_cache_for,
    reset_plan_caches,
)
from repro.core.quantization import (
    NumericsPolicy,
    Q2_6,
    Q2_14,
    QFormat,
    QTensor,
    int8_rung,
    qtensor_matmul_ref,
    quantize,
)
from repro.core.template import TemplateConfig, default_template
from repro.core.tiling import TPU_V5E
from repro.kernels.ops import conv_gemm_weights, im2col
from repro.models import transformer as T
from repro.models.cnn import (
    LENET,
    _maxpool,
    calibrate_cnn_policy,
    calibrate_cnn_precision,
    cnn_forward,
    cnn_layer_names,
    init_cnn,
    quantize_cnn_params,
)

Q3_13 = QFormat(3, 13)
Q3_5 = QFormat(3, 5, 8)


# ---------------------------------------------------------------------------
# mixed-boundary epilogue: q8<->q16 GEMM bit-exact vs the oracle
# ---------------------------------------------------------------------------


@given(st.integers(min_value=0, max_value=2 ** 31 - 1),
       st.sampled_from(["q8xq16", "q16xq8", "q8xq8", "q16xq16"]),
       st.sampled_from([Q2_14, Q2_6]))
@settings(max_examples=40, deadline=None)
def test_engine_mixed_width_matmul_bitexact_vs_oracle(seed, widths, out_fmt):
    """Engine grid-resident GEMM with any q8/q16 operand combination and
    either output rung == qtensor_matmul_ref bit-for-bit, bias + relu
    fused — the mixed-boundary epilogue is the same shift write-back."""
    eng = Engine(TemplateConfig(backend="q16", interpret=True))
    xf = Q2_6 if widths.startswith("q8") else Q2_14
    wf = Q3_5 if widths.endswith("q8") else Q3_13
    rng = np.random.default_rng(seed)
    xq = QTensor(jnp.asarray(
        rng.integers(xf.raw_min, xf.raw_max + 1, (4, 8)), xf.storage_dtype), xf)
    wq = QTensor(jnp.asarray(
        rng.integers(wf.raw_min, wf.raw_max + 1, (8, 3)), wf.storage_dtype), wf)
    bq = QTensor(jnp.asarray(
        rng.integers(xf.raw_min, xf.raw_max + 1, (3,)), xf.storage_dtype), xf)
    got = eng.matmul(xq, wq, bias=bq, relu=True, qout=out_fmt)
    want = qtensor_matmul_ref(xq, wq, out_fmt, bias=bq, relu=True)
    assert got.fmt == out_fmt and got.raw.dtype == out_fmt.storage_dtype
    np.testing.assert_array_equal(np.asarray(got.raw), np.asarray(want.raw))


# ---------------------------------------------------------------------------
# forced-mixed LeNet forward: bit-exact vs an independent oracle
# ---------------------------------------------------------------------------


def _oracle_lenet_forward(qp, policy, x):
    """Independent mixed LeNet oracle: im2col + qtensor_matmul_ref per
    layer, maxpool on raws, exact int32 read-out for the classifier."""
    names = cnn_layer_names(LENET)
    nc = len(LENET.convs)
    f0 = policy.fmt_for(names[0])
    h = QTensor(quantize(x, f0), f0)
    for i, ((cout, k, stride, pad, pool), p) in enumerate(
            zip(LENET.convs, qp["convs"])):
        xr = h.raw
        if pad:
            xr = jnp.pad(xr, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
        cols, ho, wo = im2col(xr, k, k, stride)
        out = qtensor_matmul_ref(
            QTensor(cols, h.fmt),
            QTensor(conv_gemm_weights(p["w"].raw), p["w"].fmt),
            policy.fmt_for(names[i + 1]), bias=p["b"], relu=True,
        )
        h = QTensor(out.raw.reshape(x.shape[0], ho, wo, cout), out.fmt)
        if pool:
            h = _maxpool(h, pool)
    h = h.reshape(h.shape[0], -1)
    last = len(qp["fcs"]) - 1
    for i, p in enumerate(qp["fcs"]):
        if i < last:
            h = qtensor_matmul_ref(h, p["w"], policy.fmt_for(names[nc + i + 1]),
                                   bias=p["b"], relu=True)
        else:
            # wide read-out: int32 accumulator + shifted bias, exact descale
            acc = (np.asarray(h.raw, np.int64)
                   @ np.asarray(p["w"].raw, np.int64))
            acc_frac = h.fmt.frac_bits + p["w"].fmt.frac_bits
            bshift = acc_frac - p["b"].fmt.frac_bits
            acc = acc + (np.asarray(p["b"].raw, np.int64) << bshift)
            return (acc.astype(np.int32).astype(np.float32)
                    * np.float32(2.0 ** -acc_frac))


def test_mixed_lenet_forward_bitexact_vs_oracle():
    """A forced-mixed plan (int8 and int16 layers interleaved, so both
    int8->int16 and int16->int8 boundaries occur) runs the grid path
    bit-identically to the independent oracle, logits included."""
    tpl = default_template("q16")
    params = init_cnn(jax.random.PRNGKey(0), LENET, scale=0.4)
    mixed = NumericsPolicy("mixed", fmt=Q2_14, layer_fmts=(
        ("conv0", Q2_6), ("fc0", Q2_6), ("fc2", Q2_6),
    ))
    qp = quantize_cnn_params(tpl, LENET, params, mixed)
    assert qp["convs"][0]["w"].raw.dtype == jnp.int8  # int8 weight grid
    assert qp["convs"][1]["w"].raw.dtype == jnp.int16
    img = jax.random.uniform(jax.random.PRNGKey(3), (4, 32, 32, 1)) * 2 - 1
    got = cnn_forward(tpl, LENET, qp, img, policy=mixed)
    want = _oracle_lenet_forward(qp, mixed, img)
    np.testing.assert_array_equal(np.asarray(got), want)
    tpl.engine.drop_qparams(params, mixed)


# ---------------------------------------------------------------------------
# int8 KV cache + mixed transformer forward
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def mixed_tf_setup():
    cfg = reduced(get_config("qwen2-0.5b"))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tpl = default_template("q16")
    cal = jax.random.randint(jax.random.PRNGKey(9), (2, 16), 0, cfg.vocab)
    policy = T.calibrate_policy(tpl, cfg, params, cal)
    low = int8_rung(policy.fmt)
    assert low is not None
    mixed = dataclasses.replace(policy, name="mixed",
                                layer_fmts=(("g0", low),))
    qp = T.quantize_params(tpl, cfg, params, mixed)
    return cfg, params, tpl, mixed, qp


def test_init_cache_kv_dtype_follows_group_grid(mixed_tf_setup):
    cfg, params, tpl, mixed, qp = mixed_tf_setup
    cache = T.init_cache(cfg, 2, 16, policy=mixed)
    c0 = cache["blocks"][0]["attn"]
    assert c0["k"].dtype == jnp.int8 and c0["v"].dtype == jnp.int8
    for blk in cache["blocks"][1:]:
        assert blk["attn"]["k"].dtype == jnp.int16
    for tail in cache["tail"]:
        assert tail["attn"]["k"].dtype == jnp.int16
    # an explicit dtype still overrides uniformly
    cache_f = T.init_cache(cfg, 2, 16, dtype=jnp.float32, policy=mixed)
    assert cache_f["blocks"][0]["attn"]["k"].dtype == jnp.float32


def test_prefill_cache_carries_int8_group(mixed_tf_setup):
    cfg, params, tpl, mixed, qp = mixed_tf_setup
    _, cache = T.prefill(tpl, cfg, qp, jnp.zeros((1, 8), jnp.int32),
                         cache_len=16, policy=mixed)
    c0 = cache["blocks"][0]["attn"]
    assert c0["k"].dtype == jnp.int8 and c0["v"].dtype == jnp.int8
    for blk in cache["blocks"][1:]:
        assert blk["attn"]["k"].dtype == jnp.int16
    # ...and decode runs off the int8 cache, emitting finite float logits
    logits, _ = T.decode_step(tpl, cfg, qp, jnp.zeros((1, 1), jnp.int32),
                              jnp.int32(8), cache, policy=mixed)
    assert logits.dtype == jnp.float32
    assert bool(jnp.isfinite(logits).all())


def test_mixed_transformer_tracks_float(mixed_tf_setup):
    """The forced-int8 group costs bounded drift on the fixed seed set.
    A random-init net has near-tie logits, so this is a loose sanity bound;
    the CI-gated >=99% agreement runs on the trained network in
    benchmarks/precision_drift.py, where the DSE chooses the plan."""
    cfg, params, tpl, mixed, qp = mixed_tf_setup
    tpl_f = default_template()
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0, cfg.vocab)
    lf, _ = T.forward(tpl_f, cfg, params, toks, mode="fwd")
    lq, _ = T.forward(tpl, cfg, qp, toks, mode="fwd", policy=mixed)
    assert float(jnp.abs(lf - lq).mean()) < 0.3  # int8 (2^-6) noise scale
    assert float((jnp.argmax(lf, -1) == jnp.argmax(lq, -1)).mean()) >= 0.5


# ---------------------------------------------------------------------------
# half-bytes law (the byte accounting the CI gate enforces)
# ---------------------------------------------------------------------------


def test_lenet_int8_layers_cost_exactly_half_bytes():
    from benchmarks.precision_drift import (
        lenet_activation_bytes,
        lenet_activation_bytes_mixed,
        lenet_activation_elements,
    )

    base = NumericsPolicy("q16", fmt=Q2_14)
    names = cnn_layer_names(LENET)
    all8 = dataclasses.replace(
        base, name="mixed", layer_fmts=tuple((n, Q2_6) for n in names))
    q16 = lenet_activation_bytes(LENET, act_bytes=2)
    assert lenet_activation_bytes_mixed(LENET, base) == q16
    assert lenet_activation_bytes_mixed(LENET, all8) * 2 == q16
    # per-layer: dropping one layer saves exactly its element count
    el = lenet_activation_elements(LENET)
    for n in names:
        one = dataclasses.replace(base, name="mixed", layer_fmts=((n, Q2_6),))
        assert q16 - lenet_activation_bytes_mixed(LENET, one) == el[n]


def test_transformer_int8_groups_cost_exactly_half_bytes():
    from benchmarks.precision_drift import (
        transformer_decode_bytes,
        transformer_decode_bytes_mixed,
    )

    cfg = reduced(get_config("qwen2-0.5b"))
    base = NumericsPolicy("q16", fmt=Q2_14)
    names = T.precision_group_names(cfg)
    all8 = dataclasses.replace(
        base, name="mixed", layer_fmts=tuple((n, Q2_6) for n in names))
    q16 = transformer_decode_bytes(cfg, 128, act_bytes=2, kv_bytes=2)
    q8 = transformer_decode_bytes(cfg, 128, act_bytes=1, kv_bytes=1)
    assert transformer_decode_bytes_mixed(cfg, 128, base) == q16
    assert transformer_decode_bytes_mixed(cfg, 128, all8) == q8
    assert q8 * 2 == q16
    one = dataclasses.replace(base, name="mixed", layer_fmts=(("g0", Q2_6),))
    assert q16 > transformer_decode_bytes_mixed(cfg, 128, one) > q8


# ---------------------------------------------------------------------------
# DSE: choose_precision, composed revert, warm pins
# ---------------------------------------------------------------------------


def test_choose_precision_assigns_cheapest_grid_meeting_budget():
    drift = {"a": 1.0, "b": 0.991, "c": 0.42}
    plan = dse.choose_precision(drift, 0.99, Q2_14, Q2_6)
    assert plan == {"a": Q2_6, "b": Q2_6, "c": Q2_14}
    for bad in (-0.1, 1.5):
        with pytest.raises(ValueError, match="budget"):
            dse.choose_precision(drift, bad, Q2_14, Q2_6)


@pytest.fixture
def lenet_dse_setup():
    reset_plan_caches()
    tpl = default_template("q16")
    params = init_cnn(jax.random.PRNGKey(0), LENET, scale=0.4)
    img = jax.random.uniform(jax.random.PRNGKey(2), (4, 32, 32, 1)) * 2 - 1
    policy = calibrate_cnn_policy(tpl, LENET, params, img)
    yield tpl, params, img, policy
    reset_plan_caches()


def test_composed_budget_reverts_int8_layers(lenet_dse_setup):
    """Solo-flip drifts compose: hand the DSE per-layer drift that claims
    every layer passes, against a reference the composed network can never
    match — every int8 choice must be reverted to the base grid (and the
    pins record the reverted plan)."""
    tpl, params, img, policy = lenet_dse_setup
    names = cnn_layer_names(LENET)
    fake_drift = {n: 1.0 for n in names}
    wrong_ref = (jnp.argmax(cnn_forward(tpl, LENET, params, img), -1) + 1) % 10
    mixed = calibrate_cnn_precision(
        tpl, LENET, params, img, budget=0.99, policy=policy,
        drift=fake_drift, ref=wrong_ref,
    )
    assert all(f == policy.fmt for _, f in mixed.layer_fmts), \
        "an unreachable network budget must revert every int8 layer"
    reg = tpl.engine.plan_cache
    assert reg.precision_plan(LENET.name, tpl.config.hw) == {
        n: policy.fmt for n in names
    }


def test_warm_pins_rebuild_identical_policy_zero_forwards(
        lenet_dse_setup, monkeypatch):
    """Cold sweep pins every layer (one miss each); a second calibration
    replays from the pins — identical policy, hits only, and zero forwards
    (cnn_forward is boobytrapped)."""
    tpl, params, img, policy = lenet_dse_setup
    reg = tpl.engine.plan_cache
    names = cnn_layer_names(LENET)
    cold = calibrate_cnn_precision(
        tpl, LENET, params, img, budget=0.0, policy=policy,
        drift={n: 1.0 for n in names},
    )
    low = int8_rung(policy.fmt)
    assert all(f == low for _, f in cold.layer_fmts)  # budget 0: all int8
    assert reg.misses >= len(names)

    def boom(*a, **kw):  # pragma: no cover - only fires on regression
        raise AssertionError("warm precision replay ran a forward")

    monkeypatch.setattr("repro.models.cnn.cnn_forward", boom)
    misses0, hits0 = reg.misses, reg.hits
    warm = calibrate_cnn_precision(tpl, LENET, params, img,
                                   budget=0.0, policy=policy)
    assert warm == cold
    assert reg.misses == misses0, "warm replay must not search"
    assert reg.hits == hits0 + len(names)


def test_transformer_warm_pins_zero_forwards(monkeypatch):
    reset_plan_caches()
    cfg = reduced(get_config("qwen2-0.5b"))
    tpl = default_template("q16")
    base = NumericsPolicy("q16", fmt=Q2_14)
    reg = plan_cache_for(TPU_V5E)
    names = T.precision_group_names(cfg)
    for n in names:
        reg.pin_precision(cfg.name, n, Q2_6 if n == "g0" else Q2_14,
                          drift=1.0, searched=False)

    def boom(*a, **kw):  # pragma: no cover - only fires on regression
        raise AssertionError("warm precision replay ran a forward")

    monkeypatch.setattr(T, "forward", boom)
    warm = T.calibrate_precision(tpl, cfg, params=None, tokens=None,
                                 policy=base)
    assert warm.name == "mixed"
    assert dict(warm.layer_fmts)["g0"] == Q2_6
    assert all(dict(warm.layer_fmts)[n] == Q2_14 for n in names if n != "g0")
    reset_plan_caches()


# ---------------------------------------------------------------------------
# serve --backend q8: cold DSE + warm restart with zero searches
# ---------------------------------------------------------------------------


def test_serve_q8_warm_restart_zero_searches(tmp_path, monkeypatch):
    from repro.launch import serve

    monkeypatch.delenv(PLAN_STORE_ENV, raising=False)
    reset_plan_caches()
    store = str(tmp_path / "q8_store.json")
    args = ["--backend", "q8", "--prompts", "1", "--prompt-len", "8",
            "--gen", "2", "--precision-budget", "0.5", "--plan-store", store]
    serve.main(args)  # cold: calibrates, sweeps, pins, saves
    with open(store) as f:
        doc = json.load(f)
    assert doc["version"] == 3 and doc["precision"], \
        "cold q8 serve must persist measured precision pins"
    assert all(e["source"] == "measured" for e in doc["precision"])

    reset_plan_caches()  # fresh process: warm-start from the store
    serve.main(args)
    pc = plan_cache_for(TPU_V5E)
    assert pc.misses == 0, \
        "warm q8 serve must re-serve pinned precision with zero DSE searches"
    assert pc.hits > 0
    reset_plan_caches()
