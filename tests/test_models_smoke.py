"""Per-architecture smoke tests: reduced config, one forward + one train step
on CPU, asserting output shapes and no NaNs — plus prefill/decode parity
(the serving path must agree with the training path)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs, reduced
from repro.core.template import default_template
from repro.launch.steps import make_train_step
from repro.models import transformer as T
from repro.optim import adamw_init

ARCHS = sorted(all_configs())
TPL = default_template()


def _ctx_for(cfg, b, key):
    if cfg.family == "encdec":
        return jax.random.normal(key, (b, cfg.n_frames, cfg.d_model)) * 0.1
    if cfg.family == "vlm":
        return jax.random.normal(key, (b, cfg.n_image_tokens, cfg.d_model)) * 0.1
    return None


def _setup(name, no_drop_moe=False):
    cfg = reduced(all_configs()[name])
    if no_drop_moe and cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=100.0)
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    ctx = _ctx_for(cfg, b, jax.random.PRNGKey(2))
    return cfg, params, tokens, ctx


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_and_finite(name):
    cfg, params, tokens, ctx = _setup(name)
    logits, aux = T.forward(TPL, cfg, params, tokens, ctx=ctx)
    assert logits.shape == (*tokens.shape, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{name}: non-finite logits"
    assert bool(jnp.isfinite(aux)), f"{name}: non-finite aux"


@pytest.mark.parametrize("name", ARCHS)
def test_one_train_step(name):
    cfg, params, tokens, ctx = _setup(name)
    opt_state = adamw_init(params)
    step = make_train_step(cfg)
    batch = {"tokens": tokens}
    if ctx is not None:
        batch["ctx"] = ctx
    new_params, new_opt, metrics = jax.jit(step)(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(new_opt.step) == 1
    # params must actually change
    moved = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(
            lambda p, q: float(jnp.abs(p - q).sum()), params, new_params
        ),
    )
    assert moved > 0, f"{name}: update was a no-op"


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_decode_parity(name):
    """decode_step(t=S-1) after prefill(S-1) == forward(S) at the last pos."""
    cfg, params, tokens, ctx = _setup(name, no_drop_moe=True)
    s = tokens.shape[1]
    logits_full, _ = T.forward(TPL, cfg, params, tokens, ctx=ctx)
    lg_pre, cache = T.prefill(TPL, cfg, params, tokens[:, : s - 1], ctx=ctx,
                              cache_len=s + 4)
    np.testing.assert_allclose(
        np.asarray(lg_pre), np.asarray(logits_full[:, -2]), atol=3e-4, rtol=3e-4,
        err_msg=f"{name}: prefill last-logit mismatch",
    )
    lg_dec, _ = T.decode_step(TPL, cfg, params, tokens[:, s - 1 : s], s - 1, cache)
    np.testing.assert_allclose(
        np.asarray(lg_dec), np.asarray(logits_full[:, -1]), atol=3e-4, rtol=3e-4,
        err_msg=f"{name}: decode parity mismatch",
    )


@pytest.mark.parametrize("name", ["recurrentgemma-9b", "mamba2-1.3b"])
def test_multi_step_decode_matches_forward(name):
    """Roll 4 decode steps; each must match the teacher-forced forward."""
    cfg, params, tokens, ctx = _setup(name)
    s = tokens.shape[1]
    logits_full, _ = T.forward(TPL, cfg, params, tokens, ctx=ctx)
    k = 4
    _, cache = T.prefill(TPL, cfg, params, tokens[:, : s - k], ctx=ctx, cache_len=s)
    for i in range(k):
        t = s - k + i
        lg, cache = T.decode_step(TPL, cfg, params, tokens[:, t : t + 1], t, cache)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(logits_full[:, t]), atol=5e-4, rtol=5e-4,
            err_msg=f"{name}: decode step {i} diverged",
        )


def test_sliding_window_ring_buffer_wraps():
    """Hybrid arch with tiny window: decode past the window must still match
    the windowed teacher-forced forward (ring-buffer slot reuse)."""
    cfg = reduced(all_configs()["recurrentgemma-9b"])
    cfg = dataclasses.replace(cfg, window=8)  # smaller than the sequence
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    b, s = 1, 24
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    logits_full, _ = T.forward(TPL, cfg, params, tokens)
    _, cache = T.prefill(TPL, cfg, params, tokens[:, : s - 1], cache_len=s)
    # the local-attn layer cache must be window-sized, not seq-sized
    for pos_cache in jax.tree.leaves(cache):
        pass
    lg, _ = T.decode_step(TPL, cfg, params, tokens[:, s - 1 : s], s - 1, cache)
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(logits_full[:, -1]), atol=5e-4, rtol=5e-4,
    )


def test_param_axes_structure_matches_params():
    """param_axes is a valid prefix pytree of params: every axes leaf either
    replicates a whole subtree (None) or names >= the leaf's rank axes."""
    from repro.models.transformer import _is_axes_leaf
    from repro.parallel.sharding import TRAIN_RULES, tree_shardings

    mesh = jax.make_mesh((1,), ("data",))
    for name in ARCHS:
        cfg = reduced(all_configs()[name])
        params = jax.eval_shape(lambda c=cfg: T.init_params(jax.random.PRNGKey(0), c))
        axes = T.param_axes(cfg)
        # tree_shardings must accept the pair without structural errors
        sh = tree_shardings(mesh, TRAIN_RULES, params, axes)
        # and every tuple-axes leaf must match its param's rank exactly
        def walk(ax, p):
            if _is_axes_leaf(ax):
                if isinstance(ax, tuple) and hasattr(p, "shape"):
                    assert len(ax) == len(p.shape), (name, ax, p.shape)
            elif isinstance(ax, dict):
                for k in ax:
                    walk(ax[k], p[k])
            elif isinstance(ax, (list, tuple)):
                for a, q in zip(ax, p):
                    walk(a, q)

        walk(axes, params)


def test_cache_axes_structure():
    for name in ["qwen2-0.5b", "recurrentgemma-9b", "mamba2-1.3b", "whisper-medium"]:
        cfg = reduced(all_configs()[name])
        shapes = jax.eval_shape(lambda c=cfg: T.init_cache(c, 2, 32))
        axes = T.cache_axes(cfg, shapes)
        # must be structurally zippable
        jax.tree.map(
            lambda a, s: True,
            axes, shapes,
            is_leaf=lambda x: x is None or (
                isinstance(x, tuple) and all(e is None or isinstance(e, str) for e in x) and len(x) > 0
            ),
        )
