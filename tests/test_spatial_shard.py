"""Cross-chip spatial (H-slab) conv sharding differentials (ISSUE 9).

The contract (DESIGN.md §10): a spatially-sharded forward — each shard
owning an H slab, exchanging only the halo rows with its neighbors at every
conv/pool seam — is **float-allclose and q16 bit-exact** versus the
unsharded route, because contraction dims never cross a shard boundary so
every output row is produced by the very same kernel reduction.

Three layers of evidence:
  * unit tests of the halo planner's static math (aligned / ragged /
    strided / pool seams, one-hop legality errors);
  * hypothesis differentials of the engine's spatial conv executor and of
    whole-CNN forwards, meshless (the slab-major layout is device-count
    agnostic) — including the ISSUE's named ragged case H=27 over 2 shards,
    stride ∈ {1, 2}, and a pooled layer whose windows cross a slab seam;
  * a subprocess multi-device run (8 host devices) where the slab dim is
    *actually* sharded over a mesh axis and the forward runs under jit —
    see ``test_spatial_shard_multidevice``.

The exchanged-bytes model vs the full-activation gather it replaces is
gated in ``benchmarks/kernel_table.py::spatial_shard_row``.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.quantization import NumericsPolicy, Q2_14, QTensor, quantize
from repro.core.template import default_template
from repro.models import cnn as C
from repro.parallel.sharding import (
    halo_exchange,
    mask_slab_rows,
    plan_spatial_halo,
    spatial_gather_bytes,
    spatial_halo_bytes,
    spatial_shards,
)

KEY = jax.random.PRNGKey(7)


# ---------------------------------------------------------------------------
# halo planner: static math
# ---------------------------------------------------------------------------


def test_plan_aligned_seam_is_kh_minus_stride():
    # the paper-flavored case: divisible H, stride 1 -> each seam moves
    # exactly kh - stride rows in each direction
    hs = plan_spatial_halo(28, 3, 1, 1, 2)
    assert (hs.ho, hs.lo, hs.win) == (28, 14, 16)
    assert (hs.up, hs.dn) == (1, 1)
    assert hs.up + hs.dn == 3 - 1
    assert hs.offsets == (0, 0) and not hs.ragged


def test_plan_ragged_h27_over_2():
    # the ISSUE's named ragged case: H=27 over 2 shards
    hs = plan_spatial_halo(27, 3, 1, 1, 2)
    assert hs.lx == 14 and hs.ho == 27 and hs.lo == 14
    assert hs.valid_out == (14, 13) and hs.ragged


def test_plan_stride2_and_pool_seams():
    hs = plan_spatial_halo(27, 3, 2, 1, 2)
    assert hs.ho == 14 and hs.lo == 7 and hs.win == 15
    # pool = halo op with kh = stride = w, pad = 0; misaligned layout
    # (lx=13 from a previous lo) forces per-shard window offsets
    ph = plan_spatial_halo(26, 2, 2, 0, 2, lx=13)
    assert ph.ho == 13 and ph.lo == 7 and ph.offsets == (0, 1)
    assert ph.ragged and ph.valid_out == (7, 6)


def test_plan_rejects_multi_hop_halo():
    # a 7x7 kernel over 1-row slabs would need rows from 3 shards away
    with pytest.raises(ValueError, match="single-hop"):
        plan_spatial_halo(8, 7, 1, 3, 8)


def test_plan_rejects_bad_geometry():
    with pytest.raises(ValueError):
        plan_spatial_halo(2, 5, 1, 0, 2)  # no output rows
    with pytest.raises(ValueError):
        plan_spatial_halo(8, 3, 1, 1, 0)  # zero shards
    with pytest.raises(ValueError):
        spatial_shards("data")  # axis name without an active mesh


def test_byte_model_halo_below_gather():
    hs = plan_spatial_halo(56, 3, 1, 1, 4)
    halo = spatial_halo_bytes(hs, 8, 56, 64, 2)
    gather = spatial_gather_bytes(56, 8, 56, 64, 4, 2)
    assert 0 < halo < gather
    # the ratio is (up+dn)/H — two orders of magnitude for deep-net H
    assert halo * 10 < gather


# ---------------------------------------------------------------------------
# halo exchange: numeric window differential
# ---------------------------------------------------------------------------


def _to_slabs_np(x, shards):
    n, h, w, c = x.shape
    lx = -(-h // shards)
    xp = np.pad(x, ((0, 0), (0, shards * lx - h), (0, 0), (0, 0)))
    return jnp.asarray(xp.reshape(n, shards, lx, w, c).transpose(1, 0, 2, 3, 4))


def _gather_np(v, ho):
    a = np.asarray(v)
    s, n = a.shape[0], a.shape[1]
    return a.transpose(1, 0, 2, 3, 4).reshape(n, s * a.shape[2], *a.shape[3:])[:, :ho]


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_halo_exchange_window_matches_global(seed):
    rng = np.random.default_rng(seed)
    kh = int(rng.choice([1, 2, 3, 5]))
    stride = int(rng.choice([1, 2]))
    pad = int(rng.choice([0, 1]))
    shards = int(rng.choice([2, 3, 4]))
    h = int(rng.integers(max(kh + shards, 2 * shards), 30))
    hs = plan_spatial_halo(h, kh, stride, pad, shards)
    x = rng.standard_normal((2, h, 4, 3)).astype(np.float32)
    ext = np.asarray(halo_exchange(_to_slabs_np(x, shards), hs))
    for s in range(shards):
        g0 = s * hs.lo * stride - pad
        want = np.zeros((2, hs.win, 4, 3), np.float32)
        for r in range(hs.win):
            if 0 <= g0 + r < h:
                want[:, r] = x[:, g0 + r]
        np.testing.assert_array_equal(ext[s], want)


def test_mask_slab_rows_restores_invariant():
    hs = plan_spatial_halo(27, 3, 1, 1, 2)
    v = jnp.ones((2, 1, hs.lo, 3, 2))
    m = np.asarray(mask_slab_rows(v, hs))
    assert m[0].all()  # full shard untouched
    assert m[1, :, :13].all() and not m[1, :, 13:].any()  # ragged tail zeroed


# ---------------------------------------------------------------------------
# engine: spatially-sharded conv == unsharded conv (float exact, q16 bitwise)
# ---------------------------------------------------------------------------


def _conv_case(seed):
    rng = np.random.default_rng(seed)
    kh = int(rng.choice([1, 3, 5]))
    stride = int(rng.choice([1, 2]))
    pad = int(rng.choice([0, 1, kh // 2]))
    shards = int(rng.choice([2, 3]))
    h = int(rng.integers(max(kh + stride, 3 * shards), 30))
    w = int(rng.integers(kh + stride, 14))
    cin, cout = int(rng.integers(1, 7)), int(rng.integers(1, 12))
    kx = jax.random.fold_in(KEY, seed)
    x = jnp.clip(jax.random.normal(kx, (2, h, w, cin)) * 0.25, -1, 1)
    wt = jnp.clip(
        jax.random.normal(jax.random.fold_in(kx, 1), (kh, kh, cin, cout)) * 0.25,
        -1, 1,
    )
    b = jnp.clip(jax.random.normal(jax.random.fold_in(kx, 2), (cout,)) * 0.1, -1, 1)
    return x, wt, b, kh, stride, pad, shards


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_spatial_conv_float_matches_unsharded(seed):
    x, wt, b, kh, stride, pad, shards = _conv_case(seed)
    eng = default_template("pallas").engine
    ref = eng.conv2d(x, wt, bias=b, relu=True,
                     plan=eng.plan_conv(x.shape, wt.shape, stride=stride,
                                        padding=pad))
    sp = eng.plan_conv(x.shape, wt.shape, stride=stride, padding=pad,
                       spatial=shards)
    out = eng.conv2d(_to_slabs_np(np.asarray(x), shards), wt, bias=b,
                     relu=True, plan=sp)
    got = _gather_np(out, sp.halo.ho)
    np.testing.assert_allclose(got, np.asarray(ref), rtol=0, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_spatial_conv_q16_bit_exact(seed):
    x, wt, b, kh, stride, pad, shards = _conv_case(seed)
    eng = default_template("q16").engine
    qx = QTensor(quantize(x, Q2_14), Q2_14)
    qw = QTensor(quantize(wt, Q2_14), Q2_14)
    qb = QTensor(quantize(b, Q2_14), Q2_14)
    ref = eng.conv2d(qx, qw, bias=qb, relu=True,
                     plan=eng.plan_conv(x.shape, wt.shape, stride=stride,
                                        padding=pad))
    sp = eng.plan_conv(x.shape, wt.shape, stride=stride, padding=pad,
                       spatial=shards)
    slab = QTensor(_to_slabs_np(np.asarray(qx.raw), shards), Q2_14)
    out = eng.conv2d(slab, qw, bias=qb, relu=True, plan=sp)
    assert isinstance(out, QTensor)
    got = _gather_np(out.raw, sp.halo.ho)
    # bitwise: int16 raws identical, not merely close
    np.testing.assert_array_equal(got, np.asarray(ref.raw))


def test_spatial_conv_ragged_h27_stride_1_and_2():
    # the ISSUE's named case, pinned (not just drawn): H=27 over 2 shards
    eng = default_template("pallas").engine
    kx = jax.random.fold_in(KEY, 999)
    x = jax.random.normal(kx, (2, 27, 9, 4)) * 0.3
    wt = jax.random.normal(jax.random.fold_in(kx, 1), (3, 3, 4, 8)) * 0.3
    for stride in (1, 2):
        ref = eng.conv2d(x, wt, plan=eng.plan_conv(x.shape, wt.shape,
                                                   stride=stride, padding=1))
        sp = eng.plan_conv(x.shape, wt.shape, stride=stride, padding=1,
                           spatial=2)
        assert sp.halo.ragged or stride == 2
        out = eng.conv2d(_to_slabs_np(np.asarray(x), 2), wt, plan=sp)
        np.testing.assert_allclose(_gather_np(out, sp.halo.ho),
                                   np.asarray(ref), rtol=0, atol=1e-6)


# ---------------------------------------------------------------------------
# whole network: plan_cnn(spatial=) forward == unsharded forward
# ---------------------------------------------------------------------------

# LeNet-flavored spec whose pool windows *cross* a slab seam: conv (k=3,
# pad=0) maps 28 -> 26 rows over 2 shards (lo=13, odd), so the following
# 2x2 pool's windows straddle the slab boundary (offsets differ per shard).
SEAM_SPEC = C.CNNSpec(
    "seamnet", 28, 2, 7,
    convs=((5, 3, 1, 0, 2), (8, 3, 1, 0, 2)),
    fcs=(24,),
)


@pytest.mark.parametrize("spec,shards", [
    (C.LENET, 2), (C.LENET, 3), (SEAM_SPEC, 2),
])
def test_spatial_cnn_forward_float(spec, shards):
    tpl = default_template("pallas")
    params = C.init_cnn(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(KEY, (2, spec.input_hw, spec.input_hw,
                                spec.input_ch)) * 0.5
    ref = C.cnn_forward(tpl, spec, params, x)
    plan = C.plan_cnn(tpl, spec, x.shape, spatial=shards)
    assert plan.spatial == shards and plan.feat_h > 0
    if spec is SEAM_SPEC:
        # the pool seam is genuinely misaligned: per-shard offsets differ
        assert len(set(plan.pool_halos[0].offsets)) > 1
    got = C.cnn_forward(tpl, spec, params, x, plan=plan)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=0, atol=1e-5)


@pytest.mark.parametrize("spec,shards", [
    (C.LENET, 2), (C.LENET, 3), (SEAM_SPEC, 2),
])
def test_spatial_cnn_forward_q16_bit_exact(spec, shards):
    # the grid-resident path: quantize once, every conv/pool on the int16
    # grid — the sharded logits' underlying accumulations are identical, so
    # the float read-out is bit-identical too
    tpl = default_template("q16")
    params = C.init_cnn(jax.random.PRNGKey(0), spec)
    policy = NumericsPolicy("q16")
    qp = C.quantize_cnn_params(tpl, spec, params, policy)
    x = jax.random.normal(KEY, (2, spec.input_hw, spec.input_hw,
                                spec.input_ch)) * 0.5
    ref = C.cnn_forward(tpl, spec, qp, x, policy=policy)
    plan = C.plan_cnn(tpl, spec, x.shape, spatial=shards)
    got = C.cnn_forward(tpl, spec, qp, x, policy=policy, plan=plan)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_spatial_plan_memoized_separately():
    tpl = default_template("pallas")
    shape = (2, 32, 32, 1)
    p1 = C.plan_cnn(tpl, C.LENET, shape)
    p2 = C.plan_cnn(tpl, C.LENET, shape, spatial=2)
    p3 = C.plan_cnn(tpl, C.LENET, shape, spatial=2)
    assert p1.spatial == 1 and p2.spatial == 2
    assert p2 is p3 and p1 is not p2
    # describe() surfaces the seams for benchmark diffs
    assert any("halo=S2" in line for line in p2.describe())


# ---------------------------------------------------------------------------
# multi-device: slab dim sharded over a real mesh axis, under jit
# ---------------------------------------------------------------------------

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.pop("REPRO_PLAN_STORE", None)
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.core.quantization import NumericsPolicy
    from repro.core.template import default_template
    from repro.models import cnn as C
    from repro.parallel.sharding import SERVE_RULES, use_mesh

    MODE = os.environ["SPATIAL_TEST_MODE"]
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh()  # (2, 2) over ("data", "model") on 8 host devices
    S = mesh.shape["data"]
    spec = C.LENET
    tpl = default_template(MODE)
    params = C.init_cnn(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 1)) * 0.5
    policy = NumericsPolicy("q16") if MODE == "q16" else None
    if policy is not None:
        params = C.quantize_cnn_params(tpl, spec, params, policy)

    ref = C.cnn_forward(tpl, spec, params, x, policy=policy)

    with use_mesh(mesh, SERVE_RULES):
        plan = C.plan_cnn(tpl, spec, x.shape, mesh=mesh, spatial="data")
        assert plan.spatial == S and plan.spatial_axis == "data"
        assert all(cp.halo is not None and cp.halo.axis == "data"
                   for cp in plan.convs)

        fwd = jax.jit(lambda a: C.cnn_forward(
            tpl, spec, params, a, policy=policy, plan=plan))
        out = fwd(x)
        out.block_until_ready()

    print(json.dumps({
        "mode": MODE,
        "bitwise": bool(np.array_equal(np.asarray(out), np.asarray(ref))),
        "allclose": bool(np.allclose(np.asarray(out), np.asarray(ref),
                                     atol=1e-5)),
        "devices": jax.device_count(),
    }))
    """
)


@pytest.mark.parametrize("mode", ["pallas", "q16"])
def test_spatial_shard_multidevice(mode):
    env = dict(os.environ, PYTHONPATH="src", SPATIAL_TEST_MODE=mode)
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        env=env, timeout=600,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert out.returncode == 0, f"spatial shard subprocess failed:\n{out.stderr[-4000:]}"
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["devices"] == 8, rec
    assert rec["allclose"], rec
    if mode == "q16":
        # integer accumulation: the 4-shard forward is *bitwise* the
        # unsharded one, not merely close
        assert rec["bitwise"], rec
