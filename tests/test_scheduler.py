"""Deterministic simulation suite for the continuous-batching scheduler.

Everything here runs on a :class:`VirtualClock` — scripted arrival traces
(bursty, uniform, adversarial mixed prompt lengths), zero wall-clock sleeps.
The load-bearing assertions:

* batching decisions — occupancy follows the trace (bursty fills all slots,
  uniform trickles in, completions free slots for the backlog);
* slot lifecycle — every admitted request's slot is freed, no leaks, slots
  are reused across requests;
* FIFO fairness within a bucket — admission order == arrival order;
* byte-identical generation — the coalesced, bucket-padded scheduler output
  equals sequential unbatched `generate()` token-for-token;
* bucket-ladder properties (hypothesis) — smallest-rung-≥-length, padding
  invariance of real-position logits, and PlanRegistry round-trips (a warm
  mixed trace reports misses == 0);
* the hoisted-jit regression — repeated `generate()` calls do not retrace.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config, reduced
from repro.core.engine import (
    Engine,
    PlanRegistry,
    bucket_for,
    plan_cache_for,
    reset_plan_caches,
)
from repro.core.template import TemplateConfig, Template, default_template
from repro.launch.scheduler import (
    Request,
    SamplingParams,
    SchedulerConfig,
    ServeScheduler,
    TRACE_COUNTS,
    VirtualClock,
    compiled_steps,
    replay_trace,
    synthetic_trace,
)
from repro.launch.serve import generate
from repro.models import transformer as T

LADDER = (8, 16, 24)
MAX_NEW = 6


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("qwen2-0.5b"))
    tpl = default_template()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params, tpl


def make_sched(setup, *, slots=3, ladder=LADDER, max_new=MAX_NEW, **kw):
    cfg, params, tpl = setup
    return ServeScheduler(
        cfg, params, tpl=tpl, clock=VirtualClock(),
        sched=SchedulerConfig(ladder=ladder, slots=slots,
                              max_new_limit=max_new, **kw),
    )


def prompts_of(lengths, vocab=128, seed=7):
    rng = np.random.default_rng(seed)
    return [tuple(int(t) for t in rng.integers(0, vocab, size=n)) for n in lengths]


# ---------------------------------------------------------------------------
# batching decisions
# ---------------------------------------------------------------------------


def test_bursty_trace_fills_all_slots(setup):
    sched = make_sched(setup, slots=3)
    trace = [Request(prompt=p, max_new=4, arrival=0.0)
             for p in prompts_of([5, 9, 3, 17, 8, 12])]
    replay_trace(sched, trace, tick=1.0)
    # burst: first step admits slots-many, the backlog waits for completions
    occ = [e["decoded"] for e in sched.history if e["decoded"]]
    assert occ[0] == 3, f"burst must fill every slot, got occupancy {occ[0]}"
    assert max(occ) == 3
    assert sched.counters["completed"] == 6
    assert sched.counters["admitted"] == 6
    # coalescing: strictly fewer decode steps than sequential serving would do
    sequential_steps = sum(3 for _ in trace)  # max_new - 1 each
    assert sched.counters["decode_steps"] < sequential_steps


def test_uniform_trace_trickles(setup):
    sched = make_sched(setup, slots=4)
    trace = [Request(prompt=p, max_new=3, arrival=float(4 * i))
             for i, p in enumerate(prompts_of([6, 6, 6, 6]))]
    replay_trace(sched, trace, tick=1.0)
    # spaced arrivals: each request runs alone (completes before the next)
    assert all(e["decoded"] <= 1 for e in sched.history)
    assert sched.counters["completed"] == 4


def test_adversarial_mixed_lengths(setup):
    """Every bucket sees traffic; over-long prompts are refused up front."""
    sched = make_sched(setup, slots=3)
    lengths = [1, 8, 9, 16, 17, 24, 2, 23]
    trace = [Request(prompt=p, max_new=3, arrival=float(i % 3))
             for i, p in enumerate(prompts_of(lengths))]
    too_long = Request(prompt=prompts_of([25])[0], max_new=3, arrival=0.0)
    stats = replay_trace(sched, trace + [too_long], tick=1.0)
    assert sched.counters["completed"] == len(trace)
    assert sched.counters["rejected"] == 1
    assert too_long.state == "rejected"
    by_bucket = stats["buckets"]
    assert by_bucket[8]["admitted"] == 3   # lengths 1, 8, 2
    assert by_bucket[16]["admitted"] == 2  # lengths 9, 16
    assert by_bucket[24]["admitted"] == 3  # lengths 17, 24, 23
    assert sum(b["admitted"] for b in by_bucket.values()) == len(trace)


def test_unsupported_families_rejected_at_construction(setup):
    """Padding is unsound for recurrent/SSM state and for sliding-window
    rings shorter than a bucket — those configs must be refused up front."""
    cfg, params, tpl = setup
    for name in ("mamba2-1.3b", "recurrentgemma-9b", "whisper-medium"):
        bad_cfg = reduced(get_config(name))
        with pytest.raises(ValueError):
            ServeScheduler(bad_cfg, None, tpl=tpl, clock=VirtualClock())
    import dataclasses

    # all-local hybrid: the window-sized ring (8 < bucket rungs) is refused
    windowed = dataclasses.replace(cfg, family="hybrid", pattern=("attn",),
                                   window=8)
    assert all(p.mixer == "local" for p in T.plan_pattern(windowed))
    with pytest.raises(ValueError):
        ServeScheduler(windowed, params, tpl=tpl, clock=VirtualClock())


def test_admission_control_queue_cap(setup):
    sched = make_sched(setup, slots=1, max_queue=2)
    trace = [Request(prompt=p, max_new=2, arrival=0.0)
             for p in prompts_of([4, 4, 4, 4, 4])]
    for r in trace:
        sched.submit(r)
    assert sched.counters["rejected"] == 3  # queue holds 2, rest refused
    sched.drain(tick=1.0)
    assert sched.counters["completed"] == 2


# ---------------------------------------------------------------------------
# slot lifecycle
# ---------------------------------------------------------------------------


def test_slot_lifecycle_no_leak_and_reuse(setup):
    sched = make_sched(setup, slots=2)
    trace = [Request(prompt=p, max_new=3, arrival=0.0)
             for p in prompts_of([4, 6, 8, 5, 7])]
    replay_trace(sched, trace, tick=1.0)
    # no leak: every slot freed, nothing active, every request completed
    assert sched._free == [0, 1]
    assert sched.active == {}
    assert all(r.state == "completed" and r.slot is None for r in trace)
    # every admitted request held exactly one slot per admission
    for r in trace:
        assert len(r.slot_history) == 1 + r.preemptions
    # reuse: 5 requests through 2 slots must revisit slots
    used = [s for r in trace for s in r.slot_history]
    assert len(used) == 5 and set(used) == {0, 1}


def test_eos_frees_slot_early(setup):
    cfg, params, tpl = setup
    sched = make_sched(setup, slots=1)
    prompt = prompts_of([6])[0]
    # oracle: what greedy decode will emit, so eos triggers on token 2 of 5
    ref = np.asarray(generate(cfg, params, jnp.asarray([prompt], jnp.int32),
                              gen=5, tpl=tpl))[0]
    eos = int(ref[1])
    req = Request(prompt=prompt, max_new=5, eos_id=eos)
    replay_trace(sched, [req], tick=1.0)
    assert req.finish_reason == "eos"
    stop = next(i for i, t in enumerate(ref.tolist()) if t == eos)
    assert req.generated == ref[: stop + 1].tolist()
    assert sched._free == [0]


def test_preemption_requeues_and_completes(setup):
    cfg, params, tpl = setup
    sched = make_sched(setup, slots=1, preempt_after=2.0)
    a = Request(prompt=prompts_of([4])[0], max_new=6, arrival=0.0)
    b = Request(prompt=prompts_of([5], seed=9)[0], max_new=2, arrival=1.0)
    replay_trace(sched, [a, b], tick=1.0)
    assert sched.counters["preempted"] == 1
    assert a.preemptions == 1
    assert len(a.slot_history) == 2  # admitted, preempted, re-admitted
    assert a.state == b.state == "completed"
    assert len(a.generated) == 6 and len(b.generated) == 2
    assert sched._free == [0]
    # parity must survive the re-prefill of prompt+generated: the preempted
    # request's tokens still match the unbatched path end to end
    for r in (a, b):
        ref = np.asarray(generate(cfg, params, jnp.asarray([r.prompt], jnp.int32),
                                  gen=r.max_new, tpl=tpl))[0]
        assert r.generated == ref.tolist()


# ---------------------------------------------------------------------------
# FIFO fairness within a bucket
# ---------------------------------------------------------------------------


def test_fifo_within_bucket(setup):
    sched = make_sched(setup, slots=1)  # serialize admissions
    trace = [Request(prompt=p, max_new=2, arrival=float(i) * 0.25)
             for i, p in enumerate(prompts_of([6, 5, 7, 6, 4]))]  # all bucket 8
    replay_trace(sched, trace, tick=1.0)
    admitted_order = [rid for e in sched.history for rid in e["admitted"]]
    assert admitted_order == [r.rid for r in trace]
    # completion timestamps are monotone in arrival order too
    times = [sched.results[r.rid].completed_at for r in trace]
    assert times == sorted(times)


# ---------------------------------------------------------------------------
# byte-identical generation vs the unbatched path
# ---------------------------------------------------------------------------


def test_batched_tokens_byte_identical_to_unbatched(setup):
    cfg, params, tpl = setup
    sched = make_sched(setup, slots=3)
    lengths = [5, 9, 3, 17, 8, 24, 2]
    trace = [Request(prompt=p, max_new=MAX_NEW, arrival=float(i % 2))
             for i, p in enumerate(prompts_of(lengths))]
    replay_trace(sched, trace, tick=1.0)
    for r in trace:
        ref = np.asarray(generate(cfg, params, jnp.asarray([r.prompt], jnp.int32),
                                  gen=r.max_new, tpl=tpl))[0]
        got = np.asarray(sched.results[r.rid].generated)
        assert got.tolist() == ref.tolist(), (
            f"rid {r.rid} (len {len(r.prompt)}): scheduler {got.tolist()} "
            f"!= unbatched {ref.tolist()}"
        )


def test_q16_scheduler_decode_determinism():
    """The PR 4 mixed trace replayed under NumericsPolicy('q16') yields
    byte-identical tokens to the unbatched q16 `generate()`, with an int16
    slot-indexed KV cache, and the warm registry replay reports zero new DSE
    searches (DESIGN.md §8)."""
    from repro.core.quantization import NumericsPolicy

    cfg = reduced(get_config("qwen2-0.5b"))
    tpl = default_template("q16")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    cal = jax.random.randint(jax.random.PRNGKey(9), (2, 16), 0, cfg.vocab)
    policy = T.calibrate_policy(tpl, cfg, params, cal)
    sched = ServeScheduler(
        cfg, params, tpl=tpl, clock=VirtualClock(), policy=policy,
        sched=SchedulerConfig(ladder=LADDER, slots=3, max_new_limit=MAX_NEW),
    )
    sched.warmup()
    assert jax.tree.leaves(sched.cache or {}) == []  # cache built on admit
    m0 = sched.registry.misses
    lengths = [5, 9, 3, 17, 8, 24, 2]  # the PR 4 mixed trace
    trace = [Request(prompt=p, max_new=MAX_NEW, arrival=float(i % 2))
             for i, p in enumerate(prompts_of(lengths))]
    replay_trace(sched, trace, tick=1.0)
    assert sched.counters["completed"] == len(trace)
    assert sched.registry.misses == m0, (
        "warm q16 registry replay must report zero new DSE searches")
    assert sched.cache["blocks"][0]["attn"]["k"].dtype == jnp.int16
    for r in trace:
        ref = np.asarray(generate(cfg, params, jnp.asarray([r.prompt], jnp.int32),
                                  gen=r.max_new, tpl=tpl, policy=policy))[0]
        got = sched.results[r.rid].generated
        assert got == ref.tolist(), (
            f"rid {r.rid} (len {len(r.prompt)}): q16 scheduler {got} "
            f"!= unbatched q16 {ref.tolist()}"
        )


def test_scheduler_rejects_unsupported_policy_combos(setup):
    """--backend/--policy mismatches fail at construction with clear errors
    instead of silently serving the wrong numerics."""
    from repro.core.quantization import NumericsPolicy

    cfg, params, tpl = setup  # tpl is the float (xla) template
    with pytest.raises(ValueError, match="requires the 'q16' backend"):
        ServeScheduler(cfg, params, tpl=tpl, clock=VirtualClock(),
                       policy=NumericsPolicy("q16"))


# ---------------------------------------------------------------------------
# bucket-ladder properties (hypothesis)
# ---------------------------------------------------------------------------


@given(st.integers(0, 4096))
@settings(max_examples=40, deadline=None)
def test_bucket_is_smallest_rung_geq_length(length):
    ladder = (8, 16, 64, 256, 1024)
    b = bucket_for(length, ladder)
    fitting = [r for r in ladder if r >= length]
    assert b == (min(fitting) if fitting else None)
    if b is not None:
        assert b >= length
        assert all(r < length or r >= b for r in ladder)


_PAD_ENV = {}


@given(st.integers(1, 16))
@settings(max_examples=6, deadline=None)
def test_padding_never_changes_real_position_logits(s):
    if not _PAD_ENV:
        cfg = reduced(get_config("qwen2-0.5b"))
        _PAD_ENV["cfg"] = cfg
        _PAD_ENV["tpl"] = default_template()
        _PAD_ENV["params"] = T.init_params(jax.random.PRNGKey(0), cfg)
    cfg, tpl, params = _PAD_ENV["cfg"], _PAD_ENV["tpl"], _PAD_ENV["params"]
    toks = jax.random.randint(jax.random.PRNGKey(s), (1, s), 0, cfg.vocab)
    bucket = 16
    padded = jnp.pad(toks, ((0, 0), (0, bucket - s)))
    lg_exact, _ = T.prefill(tpl, cfg, params, toks, cache_len=32)
    lg_padded, _ = T.prefill(tpl, cfg, params, padded, cache_len=32,
                             last_pos=jnp.int32(s - 1))
    np.testing.assert_allclose(np.asarray(lg_padded), np.asarray(lg_exact),
                               atol=1e-5, rtol=1e-5)


def test_bucket_ladder_round_trips_plan_registry(tmp_path):
    """Every rung's plan persists through the store and replans with 0 misses."""
    reg = PlanRegistry()
    eng = Engine(TemplateConfig(backend="pallas", interpret=True), plan_cache=reg)
    ladder = (8, 32, 128)
    plans = eng.plan_gemm_ladder(ladder, 96, 64)
    assert sorted(plans) == sorted(ladder)
    assert reg.misses == len(ladder)
    path = str(tmp_path / "ladder_store.json")
    reg.save(path)
    warm = PlanRegistry()
    warm.load(path)
    eng2 = Engine(TemplateConfig(backend="pallas", interpret=True), plan_cache=warm)
    plans2 = eng2.plan_gemm_ladder(ladder, 96, 64)
    assert warm.misses == 0 and warm.hits == len(ladder)
    assert plans2 == plans


def test_warm_mixed_trace_zero_misses():
    """After warmup, a mixed trace replays against the registry with 0 misses
    (pallas backend: every GEMM consults the PlanRegistry at trace time)."""
    reset_plan_caches()
    cfg = reduced(get_config("qwen2-0.5b"))
    tpl = default_template("pallas")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    sched = ServeScheduler(
        cfg, params, tpl=tpl, clock=VirtualClock(),
        sched=SchedulerConfig(ladder=(8, 16), slots=2, max_new_limit=3),
    )
    per_bucket = sched.warmup()
    assert all(b["misses"] > 0 for b in per_bucket.values()), (
        "cold warmup must run the DSE for every bucket")
    reg = sched.registry
    h0, m0 = reg.hits, reg.misses
    trace = synthetic_trace(5, seed=1, vocab=cfg.vocab, ladder=(8, 16), max_new=3)
    stats = replay_trace(sched, trace, tick=1.0)
    assert sched.counters["completed"] == 5
    assert reg.misses == m0, (
        f"mixed trace against a warm registry must report zero new DSE "
        f"searches, got {reg.misses - m0}")
    assert stats["registry"]["misses"] == m0
    reset_plan_caches()


# ---------------------------------------------------------------------------
# hoisted-jit regression: repeated generate()/scheduler calls don't retrace
# ---------------------------------------------------------------------------


def test_generate_does_not_retrace(setup):
    cfg, params, tpl = setup
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 6), 0, cfg.vocab)
    generate(cfg, params, toks, gen=3, tpl=tpl)  # may trace (cold)
    before = dict(TRACE_COUNTS)
    for _ in range(3):
        generate(cfg, params, toks, gen=3, tpl=tpl)
    assert dict(TRACE_COUNTS) == before, (
        f"repeated generate() retraced: {dict(TRACE_COUNTS)} vs {before}")


def test_scheduler_steps_do_not_retrace(setup):
    cfg, params, tpl = setup
    sched = make_sched(setup, slots=2)
    sched.warmup()
    trace = [Request(prompt=p, max_new=3, arrival=0.0)
             for p in prompts_of([4, 9, 17])]
    replay_trace(sched, trace, tick=1.0)
    before = dict(TRACE_COUNTS)
    replay_trace(sched, [Request(prompt=p, max_new=3, arrival=0.0)
                         for p in prompts_of([6, 12, 20], seed=11)], tick=1.0)
    assert dict(TRACE_COUNTS) == before, "steady-state scheduler retraced"


def test_compiled_steps_memoized(setup):
    cfg, params, tpl = setup
    a = compiled_steps(tpl, cfg, 48)
    b = compiled_steps(tpl, cfg, 48)
    assert a[0] is b[0] and a[1] is b[1]
    c = compiled_steps(tpl, cfg, 64)
    assert c[0] is not a[0]


# ---------------------------------------------------------------------------
# coalesced (B, L) bucket prefill
# ---------------------------------------------------------------------------


_BATCH_ENV = {}


@given(st.lists(st.integers(1, 16), min_size=2, max_size=4), st.integers(0, 9))
@settings(max_examples=8, deadline=None)
def test_batched_prefill_rows_bitwise_equal_single(lengths, seed):
    """A coalesced (B, L) prefill over mixed-length right-padded prompts is
    byte-identical per row to B separate (1, L) prefills — the property that
    makes one-launch-per-rung admission parity-free."""
    if not _BATCH_ENV:
        cfg = reduced(get_config("qwen2-0.5b"))
        _BATCH_ENV["cfg"] = cfg
        _BATCH_ENV["tpl"] = default_template()
        _BATCH_ENV["params"] = T.init_params(jax.random.PRNGKey(0), cfg)
    cfg, tpl, params = _BATCH_ENV["cfg"], _BATCH_ENV["tpl"], _BATCH_ENV["params"]
    fns = compiled_steps(tpl, cfg, 24)
    bucket = 16
    rng = np.random.default_rng(seed)
    toks = np.zeros((len(lengths), bucket), np.int32)
    for i, n in enumerate(lengths):
        toks[i, :n] = rng.integers(0, cfg.vocab, size=n)
    last = np.asarray([n - 1 for n in lengths], np.int32)
    lg_batch = np.asarray(
        fns.prefill(params, jnp.asarray(toks), None, jnp.asarray(last))[0])
    for i in range(len(lengths)):
        lg_one = np.asarray(
            fns.prefill(params, jnp.asarray(toks[i: i + 1]), None,
                        jnp.asarray(last[i: i + 1]))[0])[0]
        assert np.array_equal(lg_batch[i], lg_one), (
            f"row {i} (len {lengths[i]}) of the batched prefill diverged "
            f"bitwise from its (1, L) launch")


def test_batched_mode_matches_sequential_mode(setup):
    """The coalesced launches change only the launch count, never a token:
    batched vs sequential prefill_mode agree byte-for-byte on the PR 4
    mixed trace, with strictly fewer prefill launches."""
    lengths = [5, 9, 3, 17, 8, 24, 2]
    outs, launches = [], []
    for mode in ("batched", "sequential"):
        sched = make_sched(setup, slots=3, prefill_mode=mode)
        trace = [Request(prompt=p, max_new=4, arrival=0.0)
                 for p in prompts_of(lengths)]
        replay_trace(sched, trace, tick=1.0)
        assert sched.counters["completed"] == len(trace)
        outs.append([sched.results[r.rid].generated for r in trace])
        launches.append(sched.counters["prefill_launches"])
    assert outs[0] == outs[1], "prefill coalescing changed generated tokens"
    assert launches[0] < launches[1], (
        f"batched mode must issue fewer prefill launches "
        f"({launches[0]} vs sequential {launches[1]})")
    assert launches[1] == len(lengths)  # sequential: one launch per admission


def test_prefill_launches_bounded_by_occupied_rungs(setup):
    """Per tick, prefill launches <= #distinct buckets admitted that tick —
    the acceptance bar for the coalesced admission path."""
    sched = make_sched(setup, slots=3)
    lengths = [5, 9, 3, 17, 8, 24, 2]
    trace = [Request(prompt=p, max_new=MAX_NEW, arrival=float(i % 2))
             for i, p in enumerate(prompts_of(lengths))]
    stats = replay_trace(sched, trace, tick=1.0)
    by_rid = {r.rid: r for r in trace}
    for ev in sched.history:
        rungs = {by_rid[rid].bucket for rid in ev["admitted"]}
        assert ev["prefill_launches"] <= len(rungs), (
            f"tick at {ev['now']}: {ev['prefill_launches']} prefill launches "
            f"for {len(rungs)} occupied rungs")
    assert stats["prefill_coalescing"] >= 1.0
    assert stats["counters"]["prefill_launches"] < len(lengths)
    assert stats["ttft"]["n"] == len(lengths)
    assert stats["ttft"]["p50"] <= stats["ttft"]["p99"]


# ---------------------------------------------------------------------------
# chunked prefill / decode interleaving
# ---------------------------------------------------------------------------


def test_chunked_prefill_matches_unbatched(setup):
    """Streaming long prompts chunk-by-chunk (interleaved with decode) still
    yields byte-identical tokens to the unbatched `generate()`."""
    cfg, params, tpl = setup
    sched = make_sched(setup, slots=3, prefill_chunk=8)
    lengths = [5, 9, 3, 17, 8, 24, 2]
    trace = [Request(prompt=p, max_new=MAX_NEW, arrival=float(i % 2))
             for i, p in enumerate(prompts_of(lengths))]
    replay_trace(sched, trace, tick=1.0)
    assert sched.counters["completed"] == len(trace)
    assert sched.counters["chunk_steps"] > 0, "no chunked prefill happened"
    # the chunk path really interleaved: some tick ran both chunk and decode
    assert any(e["chunk_rows"] and e["decoded"] for e in sched.history), (
        "chunk launches never overlapped a decode step")
    for r in trace:
        ref = np.asarray(generate(cfg, params, jnp.asarray([r.prompt], jnp.int32),
                                  gen=r.max_new, tpl=tpl))[0]
        got = sched.results[r.rid].generated
        assert got == ref.tolist(), (
            f"rid {r.rid} (len {len(r.prompt)}): chunked {got} "
            f"!= unbatched {ref.tolist()}")


def test_prefill_chunk_step_equivalence(setup):
    """Driving prefill_chunk_step over a prompt reproduces the whole-prompt
    prefill: same cache validity, same next-token choice, logits to 1e-5."""
    cfg, params, tpl = setup
    cache_len = 24
    s = 13
    chunk = 5
    toks = np.asarray(prompts_of([s], seed=3)[0], np.int32)[None]
    lg_ref, _ = T.prefill(tpl, cfg, params, jnp.asarray(toks),
                          cache_len=cache_len)
    cache = T.init_cache(cfg, 2, cache_len, per_slot=True)
    logits = None
    for t0 in range(0, s, chunk):
        n = min(chunk, s - t0)
        blk = np.zeros((2, chunk), np.int32)
        blk[0, :n] = toks[0, t0: t0 + n]
        tvec = np.asarray([t0, -1], np.int32)  # row 1 stays inactive
        nv = np.asarray([n, 0], np.int32)
        logits, cache = T.prefill_chunk_step(
            tpl, cfg, params, jnp.asarray(blk), jnp.asarray(tvec),
            jnp.asarray(nv), cache)
    np.testing.assert_allclose(np.asarray(logits)[0], np.asarray(lg_ref)[0],
                               atol=1e-5, rtol=1e-5)
    assert int(jnp.argmax(logits[0])) == int(jnp.argmax(lg_ref[0]))
    # the inactive lane's cache row stayed fully invalid
    pos = np.asarray(cache["blocks"][0]["attn"]["pos"])
    assert (pos[:, 1] == -1).all(), "gated-off lane's cache row moved"
    assert (np.sort(pos[0, 0][pos[0, 0] >= 0]) == np.arange(s)).all()


# ---------------------------------------------------------------------------
# sampled decode lanes (per-slot RNG)
# ---------------------------------------------------------------------------


def _sampled_run(setup, seed, lengths=(5, 9, 3, 17, 8, 24, 2), **kw):
    cfg, params, tpl = setup
    sched = ServeScheduler(
        cfg, params, tpl=tpl, clock=VirtualClock(),
        sampling=SamplingParams(temperature=0.8, top_k=20, seed=seed),
        sched=SchedulerConfig(ladder=LADDER, slots=3, max_new_limit=MAX_NEW,
                              **kw),
    )
    trace = [Request(prompt=p, max_new=MAX_NEW, arrival=float(i % 2))
             for i, p in enumerate(prompts_of(list(lengths)))]
    replay_trace(sched, trace, tick=1.0)
    assert sched.counters["completed"] == len(trace)
    return [sched.results[r.rid].generated for r in trace]


def test_sampled_decode_deterministic_per_seed(setup):
    """Two replay_trace runs with the same SamplingParams.seed emit identical
    token streams (per-slot RNG lanes keyed by (seed, slot, position) under
    the VirtualClock); a different seed diverges."""
    a = _sampled_run(setup, seed=17)
    b = _sampled_run(setup, seed=17)
    assert a == b, "same-seed sampled replays diverged"
    c = _sampled_run(setup, seed=18)
    assert a != c, "distinct seeds produced identical sampled streams"
    # chunked prefill keeps per-seed determinism too
    d = _sampled_run(setup, seed=17, prefill_chunk=8)
    e = _sampled_run(setup, seed=17, prefill_chunk=8)
    assert d == e, "same-seed chunked sampled replays diverged"
