"""Q2.14 fixed-point numerics: roundtrip, saturation, STE, hypothesis props,
QTensor/calibration basics, and write-back bit-exactness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.quantization import (
    NumericsPolicy,
    Q1_7,
    Q2_6,
    Q2_14,
    QFormat,
    QTensor,
    calibrate_format,
    dequantize,
    fake_quant_fmt,
    int8_rung,
    qmatmul_real,
    qmatmul_ref,
    qtensor_matmul_ref,
    quantize,
    quantize_qtensor,
    requantize_i32,
    requantize_i32_to_i16,
)


def test_format_ranges():
    assert Q2_14.max_val == pytest.approx(2 - 2 ** -14)
    assert Q2_14.min_val == -2.0
    assert Q2_14.resolution == 2 ** -14
    assert Q2_14.raw_max == 2 ** 15 - 1
    assert Q2_14.raw_min == -(2 ** 15)


def test_format_validation():
    with pytest.raises(ValueError):
        QFormat(10, 10)
    with pytest.raises(ValueError):
        QFormat(0, 14)


@given(st.floats(min_value=-1.99, max_value=1.99, allow_nan=False))
@settings(max_examples=200, deadline=None)
def test_roundtrip_error_bounded(x):
    """|dequantize(quantize(x)) - x| <= resolution/2 inside the range."""
    q = quantize(jnp.float32(x))
    back = float(dequantize(q))
    assert abs(back - x) <= Q2_14.resolution / 2 + 1e-9


@given(st.floats(min_value=-100, max_value=100, allow_nan=False))
@settings(max_examples=100, deadline=None)
def test_saturation(x):
    q = quantize(jnp.float32(x))
    back = float(dequantize(q))
    assert Q2_14.min_val - 1e-6 <= back <= Q2_14.max_val + 1e-6


def test_quantize_int16_storage():
    assert quantize(jnp.zeros((4,))).dtype == jnp.int16


def test_fake_quant_ste_gradient():
    """Straight-through: grad 1 inside the range, 0 outside."""
    g = jax.grad(lambda x: fake_quant_fmt(x).sum())(jnp.array([0.5, 1.5, 3.0, -5.0]))
    np.testing.assert_allclose(np.asarray(g), [1.0, 1.0, 0.0, 0.0])


def test_qmatmul_matches_float_within_error_bound():
    """End-to-end fixed-point GEMM error vs float: bounded by k * eps terms."""
    key = jax.random.PRNGKey(0)
    m, k, n = 32, 64, 16
    x = jax.random.normal(key, (m, k)) * 0.1
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n)) * 0.1
    got = qmatmul_real(x, w)
    want = x @ w
    # error model: each product has quantization error ~res; k accumulations
    bound = k * Q2_14.resolution * 0.5 + Q2_14.resolution
    assert float(jnp.abs(got - want).max()) < bound


@given(
    st.integers(min_value=1, max_value=8),
    st.integers(min_value=1, max_value=8),
)
@settings(max_examples=20, deadline=None)
def test_qmatmul_ref_saturates_not_wraps(m, n):
    """Max-magnitude products must clip at the Q write-back (k=1 so the
    int32 accumulator itself cannot wrap — deep accumulations use the
    documented wraparound int32 semantics vs the FPGA 48-bit cascade)."""
    xq = jnp.full((m, 1), Q2_14.raw_max, jnp.int16)
    wq = jnp.full((1, n), Q2_14.raw_max, jnp.int16)
    out = qmatmul_ref(xq, wq)
    assert int(out.max()) == Q2_14.raw_max  # saturated


def test_quantize_is_round_to_nearest():
    res = Q2_14.resolution
    x = jnp.array([0.4 * res, 0.6 * res, -0.6 * res])
    q = np.asarray(quantize(x))
    np.testing.assert_array_equal(q, [0, 1, -1])


# ---------------------------------------------------------------------------
# edge cases: saturation boundary, tie rounding, write-back bit-exactness
# ---------------------------------------------------------------------------


@given(st.floats(min_value=0.0, max_value=8.0, allow_nan=False))
@settings(max_examples=150, deadline=None)
def test_saturation_pins_to_exact_boundary(x):
    """Everything at/above 2 - 2^-14 saturates to *exactly* raw_max (and the
    negative side to raw_min): the boundary is the representable value, not
    an off-by-one neighbor."""
    if x >= Q2_14.max_val:
        assert int(quantize(jnp.float32(x))) == Q2_14.raw_max
        assert float(dequantize(quantize(jnp.float32(x)))) == pytest.approx(
            Q2_14.max_val)
    if -x <= Q2_14.min_val:
        assert int(quantize(jnp.float32(-x))) == Q2_14.raw_min
        assert float(dequantize(quantize(jnp.float32(-x)))) == pytest.approx(
            Q2_14.min_val)


@given(st.integers(min_value=-(2 ** 14), max_value=2 ** 14 - 1))
@settings(max_examples=100, deadline=None)
def test_quantize_tie_rounds_half_to_even(n):
    """Exact half-grid inputs (n + 0.5)·2^-14 follow round-half-to-even —
    the IEEE default ``jnp.round`` implements, matching the kernel's
    quantize stage bit-for-bit."""
    x = (n + 0.5) * Q2_14.resolution
    got = int(quantize(jnp.float32(x)))
    want = n if n % 2 == 0 else n + 1  # nearest even neighbor of n + 0.5
    assert got == want


def test_requantize_tie_rounds_half_up():
    """The accumulator write-back adds 2^(shift-1) then arithmetic-shifts:
    ties round toward +inf (half-up), the FPGA adder-tree convention —
    *documented* difference from the quantize stage's half-to-even."""
    f = Q2_14.frac_bits
    half = 1 << (f - 1)
    acc = jnp.array([half, 3 * half, -half, -3 * half], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(requantize_i32_to_i16(acc)), [1, 2, 0, -1]
    )


@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(max_examples=200, deadline=None)
def test_requantize_matches_qmatmul_ref_writeback_bitforbit(seed):
    """requantize_i32_to_i16 on a raw int32 accumulator is bit-for-bit the
    write-back qmatmul_ref performs (k=4 keeps the accumulator exact)."""
    rng = np.random.default_rng(seed)
    xq = jnp.asarray(rng.integers(-(2 ** 15), 2 ** 15, size=(3, 4)), jnp.int16)
    wq = jnp.asarray(rng.integers(-(2 ** 15), 2 ** 15, size=(4, 5)), jnp.int16)
    acc = jnp.dot(xq.astype(jnp.int32), wq.astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(requantize_i32_to_i16(acc)), np.asarray(qmatmul_ref(xq, wq))
    )


@given(st.integers(min_value=-(2 ** 30), max_value=2 ** 30))
@settings(max_examples=100, deadline=None)
def test_requantize_shift_grid(acc):
    """requantize_i32 with shift 0 / negative shifts is the exact re-scale
    (saturating); positive shifts divide with round-half-up."""
    a = jnp.int32(acc)
    assert int(requantize_i32(a, 0)) == int(
        np.clip(acc, Q2_14.raw_min, Q2_14.raw_max))
    # negative shift: exact up-scale in int32 arithmetic (emulate the wrap)
    doubled = int((np.asarray([acc], np.int32) << 1)[0])
    assert int(requantize_i32(a, -1)) == int(
        np.clip(doubled, Q2_14.raw_min, Q2_14.raw_max))
    got = int(requantize_i32(a, 3))
    want = int(np.clip((acc + 4) >> 3, Q2_14.raw_min, Q2_14.raw_max))
    assert got == want


# ---------------------------------------------------------------------------
# QTensor / calibration / mixed-format oracle
# ---------------------------------------------------------------------------


def test_qtensor_is_a_pytree():
    q = quantize_qtensor(jnp.array([0.5, -1.0]), Q2_14)
    leaves, treedef = jax.tree.flatten(q)
    assert len(leaves) == 1 and leaves[0].dtype == jnp.int16
    q2 = jax.tree.unflatten(treedef, leaves)
    assert q2.fmt == Q2_14
    out = jax.jit(lambda t: t)(q)  # flows through jit unchanged
    assert isinstance(out, QTensor) and out.fmt == Q2_14
    np.testing.assert_array_equal(np.asarray(out.raw), np.asarray(q.raw))


@given(st.floats(min_value=0.0, max_value=200.0, allow_nan=False))
@settings(max_examples=100, deadline=None)
def test_calibrate_format_covers_and_is_minimal(maxabs):
    fmt = calibrate_format(jnp.float32(maxabs))
    assert maxabs <= fmt.max_val or fmt.int_bits == 16  # covered (or maxed out)
    if fmt.int_bits > 1 and fmt.int_bits < 16:
        tighter = QFormat(fmt.int_bits - 1, fmt.frac_bits + 1)
        assert maxabs > tighter.max_val  # one fewer int bit would clip


def test_policy_validation():
    assert NumericsPolicy("q16").quantized
    assert not NumericsPolicy("float").quantized
    with pytest.raises(ValueError):
        NumericsPolicy("int8")


# ---------------------------------------------------------------------------
# int8 rung (Q1.7 / Q2.6): the precision ladder of DESIGN.md §11
# ---------------------------------------------------------------------------


def test_int8_rung_ladder():
    assert int8_rung(Q2_14) == Q2_6
    assert int8_rung(QFormat(1, 15)) == Q1_7
    assert int8_rung(QFormat(9, 7)) is None  # range needs > 7 + sign bits


def test_int8_format_ranges_and_storage():
    assert Q2_6.raw_max == 127 and Q2_6.raw_min == -128
    assert Q1_7.raw_max == 127 and Q1_7.raw_min == -128
    assert Q2_6.max_val == pytest.approx(2 - 2 ** -6)
    assert Q1_7.max_val == pytest.approx(1 - 2 ** -7)
    assert quantize(jnp.zeros((4,)), Q2_6).dtype == jnp.int8
    assert quantize(jnp.zeros((4,)), Q1_7).dtype == jnp.int8


@given(st.floats(min_value=0.0, max_value=8.0, allow_nan=False))
@settings(max_examples=150, deadline=None)
def test_int8_saturation_pins_at_127(x):
    """Out-of-range values pin to exactly +127 / -128 on both int8 rungs —
    the same exact-boundary law the int16 grid obeys."""
    for fmt in (Q2_6, Q1_7):
        if x >= fmt.max_val:
            assert int(quantize(jnp.float32(x), fmt)) == 127
        if -x <= fmt.min_val:
            assert int(quantize(jnp.float32(-x), fmt)) == -128


@given(st.integers(min_value=-(2 ** 6), max_value=2 ** 6 - 1))
@settings(max_examples=100, deadline=None)
def test_int8_quantize_tie_rounds_half_to_even(n):
    """The 8-bit quantize stage keeps round-half-to-even, same as int16."""
    x = (n + 0.5) * Q2_6.resolution
    got = int(quantize(jnp.float32(x), Q2_6))
    want = n if n % 2 == 0 else n + 1
    assert got == want


def test_int8_requantize_tie_rounds_half_up():
    """Accumulator write-back onto the int8 rung keeps the half-up adder-tree
    convention — the documented asymmetry vs the quantize stage holds at
    every storage width."""
    shift = 2 * Q2_6.frac_bits - Q2_6.frac_bits  # same-format product shift
    half = 1 << (shift - 1)
    acc = jnp.array([half, 3 * half, -half, -3 * half], jnp.int32)
    out = requantize_i32(acc, shift, Q2_6)
    assert out.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(out), [1, 2, 0, -1])


@given(st.integers(min_value=0, max_value=2 ** 31 - 1),
       st.sampled_from(["q8xq16", "q16xq8", "q8xq8"]),
       st.sampled_from([Q2_14, Q2_6]))
@settings(max_examples=60, deadline=None)
def test_mixed_width_matmul_oracle_bitexact(seed, widths, out_fmt):
    """q8<->q16 mixed-width GEMM through qtensor_matmul_ref is bit-identical
    to an int64 numpy emulation of accumulate + half-up shift + saturate,
    for int16 and int8 output rungs alike (the mixed-boundary epilogue)."""
    xf = Q2_6 if widths.startswith("q8") else Q2_14
    wf = Q2_6 if widths.endswith("q8") else Q2_14
    rng = np.random.default_rng(seed)
    xq = QTensor(jnp.asarray(
        rng.integers(xf.raw_min, xf.raw_max + 1, size=(3, 5)),
        xf.storage_dtype), xf)
    wq = QTensor(jnp.asarray(
        rng.integers(wf.raw_min, wf.raw_max + 1, size=(5, 4)),
        wf.storage_dtype), wf)
    out = qtensor_matmul_ref(xq, wq, out_fmt)
    assert out.fmt == out_fmt and out.raw.dtype == out_fmt.storage_dtype
    acc = np.asarray(xq.raw, np.int64) @ np.asarray(wq.raw, np.int64)
    shift = xf.frac_bits + wf.frac_bits - out_fmt.frac_bits
    if shift > 0:
        shifted = (acc + (1 << (shift - 1))) >> shift  # round half-up
    else:
        shifted = acc << (-shift)  # exact up-scale (q8xq8 -> int16 grid)
    want = np.clip(shifted, out_fmt.raw_min, out_fmt.raw_max)
    np.testing.assert_array_equal(np.asarray(out.raw, np.int64), want)


@given(st.integers(min_value=10, max_value=15), st.integers(min_value=8, max_value=15))
@settings(max_examples=25, deadline=None)
def test_mixed_format_matmul_oracle_vs_same_format(fa, fw):
    """qtensor_matmul_ref with equal formats degenerates to qmatmul_ref."""
    key = jax.random.PRNGKey(fa * 16 + fw)
    x = jax.random.normal(key, (4, 8)) * 0.05
    w = jax.random.normal(jax.random.fold_in(key, 1), (8, 3)) * 0.05
    xq = quantize_qtensor(x, QFormat(16 - fa, fa))
    wq = quantize_qtensor(w, QFormat(16 - fw, fw))
    out = qtensor_matmul_ref(xq, wq, QFormat(16 - fa, fa))
    # exact emulation in float: descale, dot, requantize
    acc = np.asarray(xq.raw, np.int64) @ np.asarray(wq.raw, np.int64)
    shift = fa + fw - fa
    want = np.clip((acc + (1 << (shift - 1))) >> shift,
                   xq.fmt.raw_min, xq.fmt.raw_max) if shift > 0 else acc
    np.testing.assert_array_equal(np.asarray(out.raw, np.int64), want)
