"""Q2.14 fixed-point numerics: roundtrip, saturation, STE, hypothesis props."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.quantization import (
    Q2_14,
    QFormat,
    dequantize,
    fake_quant_fmt,
    qmatmul_real,
    qmatmul_ref,
    quantize,
)


def test_format_ranges():
    assert Q2_14.max_val == pytest.approx(2 - 2 ** -14)
    assert Q2_14.min_val == -2.0
    assert Q2_14.resolution == 2 ** -14
    assert Q2_14.raw_max == 2 ** 15 - 1
    assert Q2_14.raw_min == -(2 ** 15)


def test_format_validation():
    with pytest.raises(ValueError):
        QFormat(10, 10)
    with pytest.raises(ValueError):
        QFormat(0, 14)


@given(st.floats(min_value=-1.99, max_value=1.99, allow_nan=False))
@settings(max_examples=200, deadline=None)
def test_roundtrip_error_bounded(x):
    """|dequantize(quantize(x)) - x| <= resolution/2 inside the range."""
    q = quantize(jnp.float32(x))
    back = float(dequantize(q))
    assert abs(back - x) <= Q2_14.resolution / 2 + 1e-9


@given(st.floats(min_value=-100, max_value=100, allow_nan=False))
@settings(max_examples=100, deadline=None)
def test_saturation(x):
    q = quantize(jnp.float32(x))
    back = float(dequantize(q))
    assert Q2_14.min_val - 1e-6 <= back <= Q2_14.max_val + 1e-6


def test_quantize_int16_storage():
    assert quantize(jnp.zeros((4,))).dtype == jnp.int16


def test_fake_quant_ste_gradient():
    """Straight-through: grad 1 inside the range, 0 outside."""
    g = jax.grad(lambda x: fake_quant_fmt(x).sum())(jnp.array([0.5, 1.5, 3.0, -5.0]))
    np.testing.assert_allclose(np.asarray(g), [1.0, 1.0, 0.0, 0.0])


def test_qmatmul_matches_float_within_error_bound():
    """End-to-end fixed-point GEMM error vs float: bounded by k * eps terms."""
    key = jax.random.PRNGKey(0)
    m, k, n = 32, 64, 16
    x = jax.random.normal(key, (m, k)) * 0.1
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n)) * 0.1
    got = qmatmul_real(x, w)
    want = x @ w
    # error model: each product has quantization error ~res; k accumulations
    bound = k * Q2_14.resolution * 0.5 + Q2_14.resolution
    assert float(jnp.abs(got - want).max()) < bound


@given(
    st.integers(min_value=1, max_value=8),
    st.integers(min_value=1, max_value=8),
)
@settings(max_examples=20, deadline=None)
def test_qmatmul_ref_saturates_not_wraps(m, n):
    """Max-magnitude products must clip at the Q write-back (k=1 so the
    int32 accumulator itself cannot wrap — deep accumulations use the
    documented wraparound int32 semantics vs the FPGA 48-bit cascade)."""
    xq = jnp.full((m, 1), Q2_14.raw_max, jnp.int16)
    wq = jnp.full((1, n), Q2_14.raw_max, jnp.int16)
    out = qmatmul_ref(xq, wq)
    assert int(out.max()) == Q2_14.raw_max  # saturated


def test_quantize_is_round_to_nearest():
    res = Q2_14.resolution
    x = jnp.array([0.4 * res, 0.6 * res, -0.6 * res])
    q = np.asarray(quantize(x))
    np.testing.assert_array_equal(q, [0, 1, -1])
