"""Logical-axis sharding rules: spec translation, divisibility, mesh filters."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import (
    SERVE_RULES,
    TRAIN_RULES,
    ShardingRules,
    logical_to_spec,
    named_sharding,
    tree_shardings,
    use_mesh,
)


@pytest.fixture
def mesh1():
    return jax.make_mesh((1,), ("data",))


def test_rule_lookup_and_override():
    assert TRAIN_RULES.get("embed") == "data"
    assert TRAIN_RULES.get("missing") is None
    r = TRAIN_RULES.with_overrides(embed=None, extra="model")
    assert r.get("embed") is None
    assert r.get("extra") == "model"
    # originals untouched (frozen)
    assert TRAIN_RULES.get("embed") == "data"


def test_missing_mesh_axis_dropped(mesh1):
    # mesh has only "data": "model" rules and the "pod" half must vanish
    spec = logical_to_spec(("batch", "mlp"), mesh=mesh1, rules=TRAIN_RULES,
                           dim_sizes=(8, 8))
    assert spec == P("data")  # ("pod","data") -> "data"; mlp -> dropped


def test_small_dim_replicated():
    """dim smaller than the mesh-axis product must drop to replicated.

    With a 1-device test mesh, axis size 1 always divides, so we exercise
    the drop through the rules math on a fake 4-way axis size."""
    mesh = jax.make_mesh((1,), ("data",))
    spec = logical_to_spec(("batch",), mesh=mesh, rules=TRAIN_RULES, dim_sizes=(1,))
    assert spec in (P(), P("data"))  # size-1 axis: equivalent to replicated
    from repro.parallel.sharding import _axis_size
    assert _axis_size(mesh, ("data",)) == 1


def test_divisibility_enforced_only_for_inputs(mesh1):
    rules = ShardingRules(rules=(("experts", "data"),))
    # constraint path keeps the mapping (GSPMD pads)
    s1 = logical_to_spec(("experts",), mesh=mesh1, rules=rules, dim_sizes=(3,))
    assert s1 == P("data")
    # input path drops it (jit boundary cannot pad)... with data=1 all divides;
    # simulate with a fake 2-way mesh via dim math instead:
    mesh2 = jax.make_mesh((1,), ("data",))
    s2 = logical_to_spec(("experts",), mesh=mesh2, rules=rules, dim_sizes=(3,),
                         require_divisible=True)
    assert s2 == P("data")  # 3 % 1 == 0 -> kept


def test_tree_shardings_mixed_leaves(mesh1):
    shapes = {
        "w": jax.ShapeDtypeStruct((8, 4), jnp.float32),
        "scale": jax.ShapeDtypeStruct((4,), jnp.float32),
        "nested": {"b": jax.ShapeDtypeStruct((2,), jnp.float32)},
    }
    axes = {"w": ("embed", "mlp"), "scale": None, "nested": {"b": ("mlp",)}}
    sh = tree_shardings(mesh1, TRAIN_RULES, shapes, axes)
    assert sh["w"].spec == P("data")
    assert sh["scale"].spec == P()


def test_constrain_noop_without_mesh():
    from repro.parallel.sharding import constrain

    x = jnp.ones((4, 4))
    y = constrain(x, "batch", None)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_use_mesh_context(mesh1):
    from repro.parallel.sharding import active_mesh, constrain

    assert active_mesh() is None
    with use_mesh(mesh1, TRAIN_RULES):
        assert active_mesh() is mesh1
        x = constrain(jnp.ones((4, 4)), "batch", None)
        assert x.shape == (4, 4)
    assert active_mesh() is None


def test_serve_rules_replicate_params_over_data():
    assert SERVE_RULES.get("embed") is None
    assert TRAIN_RULES.get("embed") == "data"
    # TP stays on for both
    assert SERVE_RULES.get("mlp") == "model" == TRAIN_RULES.get("mlp")


# ---------------------------------------------------------------------------
# ragged-shard planning: ONE drop rule for planners and sharding builders
# ---------------------------------------------------------------------------


class _StubMesh:
    """Duck-typed multi-way mesh: the planners and spec builders only read
    ``.shape`` and ``.axis_names``, so shard-count math is testable on a
    single-device host."""

    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


def test_ragged_cout_plans_the_shape_that_executes():
    """ISSUE 9 satellite bugfix: ``local_dim`` used to ceil-div a
    non-divisible dim (GSPMD-padding convention) while the jit-boundary
    shardings *dropped* it — so ``plan_conv(mesh=...)`` planned a local Cout
    that never executed.  Both sides now share the drop rule: non-divisible
    stays replicated."""
    from repro.parallel.sharding import local_conv_shapes, local_dim

    mesh = _StubMesh(data=2, model=4)
    # 6 % 4 != 0 -> planner keeps the full dim (replicated) ...
    assert local_dim(6, mesh, ("model",)) == 6
    # ... and the spec builder drops the mapping identically, with or
    # without the legacy require_divisible flag
    rules = ShardingRules(rules=(("vocab", "model"),))
    for rd in (False, True):
        spec = logical_to_spec(("vocab",), mesh=mesh, rules=rules,
                               dim_sizes=(6,), require_divisible=rd)
        assert spec in (P(), P(None))  # replicated either way
    # divisible dims still shard on both sides
    assert local_dim(8, mesh, ("model",)) == 2
    assert logical_to_spec(("vocab",), mesh=mesh, rules=rules,
                           dim_sizes=(8,)) == P("model")


def test_ragged_conv_plan_shapes_match_execution():
    from repro.parallel.sharding import local_conv_shapes

    mesh = _StubMesh(data=2, model=4)
    # Cout=6 not divisible by model=4: the planned local weight keeps the
    # full Cout — exactly the shape the (dropped) sharding executes
    x_shape, w_shape = local_conv_shapes(
        (4, 8, 8, 3), (3, 3, 3, 6), mesh=mesh, partition=P("data", "model")
    )
    assert w_shape == (3, 3, 3, 6)
    assert x_shape == (2, 8, 8, 3)  # batch 4 over data=2 still splits
    # divisible Cout splits as before
    _, w2 = local_conv_shapes(
        (4, 8, 8, 3), (3, 3, 3, 8), mesh=mesh, partition=P("data", "model")
    )
    assert w2 == (3, 3, 3, 2)
