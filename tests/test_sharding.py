"""Logical-axis sharding rules: spec translation, divisibility, mesh filters."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import (
    SERVE_RULES,
    TRAIN_RULES,
    ShardingRules,
    logical_to_spec,
    named_sharding,
    tree_shardings,
    use_mesh,
)


@pytest.fixture
def mesh1():
    return jax.make_mesh((1,), ("data",))


def test_rule_lookup_and_override():
    assert TRAIN_RULES.get("embed") == "data"
    assert TRAIN_RULES.get("missing") is None
    r = TRAIN_RULES.with_overrides(embed=None, extra="model")
    assert r.get("embed") is None
    assert r.get("extra") == "model"
    # originals untouched (frozen)
    assert TRAIN_RULES.get("embed") == "data"


def test_missing_mesh_axis_dropped(mesh1):
    # mesh has only "data": "model" rules and the "pod" half must vanish
    spec = logical_to_spec(("batch", "mlp"), mesh=mesh1, rules=TRAIN_RULES,
                           dim_sizes=(8, 8))
    assert spec == P("data")  # ("pod","data") -> "data"; mlp -> dropped


def test_small_dim_replicated():
    """dim smaller than the mesh-axis product must drop to replicated.

    With a 1-device test mesh, axis size 1 always divides, so we exercise
    the drop through the rules math on a fake 4-way axis size."""
    mesh = jax.make_mesh((1,), ("data",))
    spec = logical_to_spec(("batch",), mesh=mesh, rules=TRAIN_RULES, dim_sizes=(1,))
    assert spec in (P(), P("data"))  # size-1 axis: equivalent to replicated
    from repro.parallel.sharding import _axis_size
    assert _axis_size(mesh, ("data",)) == 1


def test_divisibility_enforced_only_for_inputs(mesh1):
    rules = ShardingRules(rules=(("experts", "data"),))
    # constraint path keeps the mapping (GSPMD pads)
    s1 = logical_to_spec(("experts",), mesh=mesh1, rules=rules, dim_sizes=(3,))
    assert s1 == P("data")
    # input path drops it (jit boundary cannot pad)... with data=1 all divides;
    # simulate with a fake 2-way mesh via dim math instead:
    mesh2 = jax.make_mesh((1,), ("data",))
    s2 = logical_to_spec(("experts",), mesh=mesh2, rules=rules, dim_sizes=(3,),
                         require_divisible=True)
    assert s2 == P("data")  # 3 % 1 == 0 -> kept


def test_tree_shardings_mixed_leaves(mesh1):
    shapes = {
        "w": jax.ShapeDtypeStruct((8, 4), jnp.float32),
        "scale": jax.ShapeDtypeStruct((4,), jnp.float32),
        "nested": {"b": jax.ShapeDtypeStruct((2,), jnp.float32)},
    }
    axes = {"w": ("embed", "mlp"), "scale": None, "nested": {"b": ("mlp",)}}
    sh = tree_shardings(mesh1, TRAIN_RULES, shapes, axes)
    assert sh["w"].spec == P("data")
    assert sh["scale"].spec == P()


def test_constrain_noop_without_mesh():
    from repro.parallel.sharding import constrain

    x = jnp.ones((4, 4))
    y = constrain(x, "batch", None)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_use_mesh_context(mesh1):
    from repro.parallel.sharding import active_mesh, constrain

    assert active_mesh() is None
    with use_mesh(mesh1, TRAIN_RULES):
        assert active_mesh() is mesh1
        x = constrain(jnp.ones((4, 4)), "batch", None)
        assert x.shape == (4, 4)
    assert active_mesh() is None


def test_serve_rules_replicate_params_over_data():
    assert SERVE_RULES.get("embed") is None
    assert TRAIN_RULES.get("embed") == "data"
    # TP stays on for both
    assert SERVE_RULES.get("mlp") == "model" == TRAIN_RULES.get("mlp")
