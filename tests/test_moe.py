"""MoE dispatch invariants + grouped implementation vs dense oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import all_configs, reduced
from repro.core.template import default_template
from repro.models.moe import init_moe, moe_ffn, moe_ffn_dense_ref, _route

TPL = default_template()


def _cfg(**kw):
    base = reduced(all_configs()["granite-moe-3b-a800m"])
    return dataclasses.replace(base, **kw)


def test_grouped_matches_dense_oracle():
    cfg = _cfg(capacity_factor=100.0)  # no drops
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.5
    got, aux = moe_ffn(TPL, cfg, p, x)
    want = moe_ffn_dense_ref(cfg, p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


def test_grouping_invariance_without_drops():
    """With no capacity drops the group size must not change the math."""
    p = init_moe(jax.random.PRNGKey(0), _cfg())
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 64)) * 0.5
    outs = []
    for group in (8, 16, 64):
        cfg = _cfg(capacity_factor=100.0, moe_group=group)
        out, _ = moe_ffn(TPL, cfg, p, x)
        outs.append(np.asarray(out))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(outs[0], outs[2], atol=1e-4, rtol=1e-4)


def test_capacity_drops_reduce_output_norm():
    """Tiny capacity must drop tokens (outputs shrink toward zero), never NaN."""
    p = init_moe(jax.random.PRNGKey(0), _cfg())
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 64)) * 0.5
    hi, _ = moe_ffn(TPL, _cfg(capacity_factor=100.0), p, x)
    lo, _ = moe_ffn(TPL, _cfg(capacity_factor=0.1), p, x)
    assert bool(jnp.isfinite(lo).all())
    assert float(jnp.linalg.norm(lo)) < float(jnp.linalg.norm(hi))


@given(st.integers(min_value=0, max_value=10_000))
@settings(max_examples=20, deadline=None)
def test_router_topk_invariants(seed):
    cfg = _cfg()
    key = jax.random.PRNGKey(seed)
    xt = jax.random.normal(key, (1, 8, cfg.d_model))
    w = jax.random.normal(jax.random.fold_in(key, 1), (cfg.d_model, cfg.n_experts))
    gates, idx, probs = _route(cfg, w, xt)
    # gates normalized over k; indices unique per token; probs a distribution
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, atol=1e-5)
    i = np.asarray(idx)
    for t in range(i.shape[1]):
        assert len(set(i[0, t])) == cfg.top_k
    np.testing.assert_allclose(np.asarray(probs.sum(-1)), 1.0, atol=1e-5)


def test_aux_loss_balanced_vs_collapsed():
    """Aux loss must be ~1 for balanced routing and ~E when collapsed."""
    cfg = _cfg(top_k=1)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    # collapsed router: positive inputs + a positive expert-0 column make
    # logit_0 >> logits_{e>0} for EVERY token (probs AND assignment collapse)
    p_collapsed = dict(p)
    p_collapsed["router"] = {
        "w": jnp.zeros_like(p["router"]["w"]).at[:, 0].set(1.0)
    }
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))) + 0.1
    _, aux_rand = moe_ffn(TPL, cfg, p, x)
    _, aux_coll = moe_ffn(TPL, cfg, p_collapsed, x)
    assert float(aux_coll) > float(aux_rand)
    assert float(aux_coll) == pytest.approx(cfg.n_experts, rel=0.05)


def test_phi_expert_count_divides_mesh():
    cfg = all_configs()["phi3.5-moe-42b-a6.6b"]
    assert cfg.n_experts % 16 == 0  # exact EP fit on the 16-way model axis


def test_granite_uses_capacity_ep_override():
    """40 experts don't divide 16-way TP: granite trains with capacity-dim
    EP (reduction-free expert GEMMs) and serves with FFN-dim weight
    sharding (§Perf cell B)."""
    cfg = all_configs()["granite-moe-3b-a800m"]
    overrides = dict(cfg.rule_overrides)
    assert overrides.get("experts", "x") is None
    assert overrides.get("expert_cap") == "model"
    serve = dict(cfg.serve_rule_overrides)
    assert serve.get("expert_mlp") == "model"
