"""Execution-plan engine: direct-conv kernel (all strides), fused epilogues,
routing decisions, and plan-cache memoization (DESIGN.md §1-§4)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dse
from repro.core.engine import Engine, PlanCache, reset_plan_caches
from repro.core.quantization import Q2_14, quantize
from repro.core.template import TemplateConfig, default_template
from repro.core.tiling import TPU_V5E
from repro.models.cnn import CNN_ZOO, LENET, cnn_forward, init_cnn, plan_cnn

KEY = jax.random.PRNGKey(11)


def _rand(shape, scale=0.3, salt=0):
    return jax.random.normal(jax.random.fold_in(KEY, salt), shape) * scale


# ---------------------------------------------------------------------------
# direct conv kernel: stride x padding x backend sweeps vs oracles
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("stride", [1, 2, 4])
@pytest.mark.parametrize("padding", [0, "SAME"])
def test_direct_conv_float_vs_ref(stride, padding):
    from repro.kernels import ref

    eng = Engine(TemplateConfig(backend="pallas", interpret=True))
    x = _rand((2, 13, 13, 5), salt=1)
    w = _rand((3, 3, 5, 8), salt=2)
    b = _rand((8,), scale=0.1, salt=3)
    plan = eng.plan_conv(x.shape, w.shape, stride=stride, padding=padding)
    assert plan.route == "direct"
    out = eng.conv2d(x, w, stride=stride, padding=padding, bias=b, relu=True, plan=plan)
    pad = 1 if padding == "SAME" else 0
    want = ref.conv2d_fused_ref(x, w, b, stride=stride, padding=pad, relu=True)
    assert out.shape == want.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("stride", [1, 2, 4])
@pytest.mark.parametrize("padding", [0, "SAME"])
def test_direct_conv_q16_vs_ref(stride, padding):
    from repro.kernels import ops, ref

    x = _rand((1, 12, 12, 4), salt=4)
    w = _rand((3, 3, 4, 8), salt=5)
    b = _rand((8,), scale=0.1, salt=6)
    xq, wq, bq = quantize(x), quantize(w), quantize(b)
    pad = 1 if padding == "SAME" else 0
    out = ops.conv2d_q16(
        xq, wq, bias=bq, stride=stride, padding=pad, relu=True, interpret=True
    )
    want = ref.conv2d_q16_ref(xq, wq, bq, stride=stride, padding=pad, relu=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("route", ["direct", "im2col"])
def test_conv_odd_cout_tau_padding(route):
    """cout=10 with tau=8 forces the tau-padded output-channel path."""
    from repro.kernels import ops, ref

    x = _rand((1, 9, 9, 4), salt=7)
    w = _rand((3, 3, 4, 10), salt=8)
    out = ops.conv2d(x, w, stride=2, padding=1, tau=8, route=route, interpret=True)
    want = ref.conv2d_ref(x, w, stride=2, padding=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4, rtol=1e-4)


def test_conv_q16_odd_cout_tau_padding():
    from repro.kernels import ops, ref

    x = _rand((1, 9, 9, 4), salt=9)
    w = _rand((3, 3, 4, 10), salt=10)
    xq, wq = quantize(x), quantize(w)
    out = ops.conv2d_q16(xq, wq, stride=1, padding=1, tau=8, interpret=True)
    want = ref.conv2d_q16_ref(xq, wq, stride=1, padding=1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


# ---------------------------------------------------------------------------
# fused GEMM epilogues
# ---------------------------------------------------------------------------


def test_matmul_fp_fused_epilogue():
    from repro.kernels import ops, ref

    x = _rand((33, 47), salt=11)
    w = _rand((47, 19), salt=12)
    b = _rand((19,), scale=0.1, salt=13)
    out = ops.matmul_fp(x, w, bias=b, relu=True, qout=Q2_14, interpret=True)
    want = ref.matmul_fused_ref(x, w, b, relu=True, qout=Q2_14)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-6, rtol=1e-6)


def test_matmul_q16_fused_epilogue():
    from repro.kernels import ops, ref

    x = _rand((24, 40), salt=14)
    w = _rand((40, 16), salt=15)
    b = _rand((16,), scale=0.1, salt=16)
    xq, wq, bq = quantize(x), quantize(w), quantize(b)
    out = ops.matmul_q16(xq, wq, bias=bq, relu=True, interpret=True)
    want = ref.matmul_q16_fused_ref(xq, wq, bq, relu=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


# ---------------------------------------------------------------------------
# plan cache: one DSE search per shape
# ---------------------------------------------------------------------------


def _count_searches(monkeypatch):
    calls = []
    real = dse.default_block_for

    def counting(*a, **kw):
        calls.append(a)
        return real(*a, **kw)

    monkeypatch.setattr(dse, "default_block_for", counting)
    return calls


def test_plan_cache_memoizes_and_counts(monkeypatch):
    calls = _count_searches(monkeypatch)
    cache = PlanCache()
    b1 = cache.block_for(256, 256, 256)
    b2 = cache.block_for(256, 256, 256)
    assert b1 == b2
    assert len(calls) == 1, "second lookup must not re-run the DSE grid search"
    assert cache.hits == 1 and cache.misses == 1 and len(cache) == 1
    cache.block_for(512, 256, 256)
    assert len(calls) == 2 and cache.misses == 2


def test_plan_conv_direct_selection_is_plan_cached(monkeypatch):
    """The (tau, tile_rows) conv DSE runs once per layer geometry."""
    calls = []
    real = dse.default_conv_tile_for

    def counting(*a, **kw):
        calls.append(a)
        return real(*a, **kw)

    monkeypatch.setattr(dse, "default_conv_tile_for", counting)
    cache = PlanCache()
    eng = Engine(TemplateConfig(backend="pallas", interpret=True), plan_cache=cache)
    p1 = eng.plan_conv((1, 32, 32, 8), (3, 3, 8, 16))
    p2 = eng.plan_conv((1, 32, 32, 8), (3, 3, 8, 16))
    assert p1 == p2 and p1.route == "direct"
    assert len(calls) == 1, "second plan_conv must not re-run the conv-tile DSE"
    assert cache.hits == 1 and cache.misses == 1 and len(cache) == 1


def test_plan_cache_lifecycle_counters_and_replan():
    """reset_plan_caches() leaves counters consistent, and a re-planned
    network produces identical NetworkPlan blocks (guards persisted-autotune)."""
    reset_plan_caches()
    tpl = default_template("pallas")
    pc = tpl.engine.plan_cache
    p1 = plan_cnn(tpl, LENET, (1, 32, 32, 1))
    entries, misses = len(pc), pc.misses
    assert entries > 0
    assert misses == entries, "every cached entry costs exactly one DSE search"
    assert pc.hits == 0, "LeNet has no repeated layer shapes"
    # memoized NetworkPlan: no new searches, no new hits (plan table, not cache)
    assert plan_cnn(tpl, LENET, (1, 32, 32, 1)) is p1
    assert (pc.misses, pc.hits, len(pc)) == (misses, 0, entries)
    reset_plan_caches()
    assert len(pc) == 0 and pc.hits == 0 and pc.misses == 0
    p2 = plan_cnn(tpl, LENET, (1, 32, 32, 1))
    assert p2 is not p1, "reset must drop the NetworkPlan memo"
    assert p2 == p1, "re-planning after reset must reproduce identical blocks"
    assert pc.misses == misses and len(pc) == entries
    reset_plan_caches()


def test_register_plan_store_is_emptied_on_reset():
    from repro.core.engine import register_plan_store

    store = {("some", "plan", "key"): object()}
    register_plan_store(store)
    reset_plan_caches()
    assert store == {}


def test_template_matmul_single_dse_search(monkeypatch):
    reset_plan_caches()
    calls = _count_searches(monkeypatch)
    tpl = default_template("pallas")
    x = _rand((32, 48), salt=17)
    w = _rand((48, 16), salt=18)
    o1 = tpl.matmul(x, w)
    o2 = tpl.matmul(x, w)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2))
    assert len(calls) == 1
    assert tpl.engine.plan_cache.hits >= 1
    # a *different* template instance with the same config shares the plan
    tpl2 = default_template("pallas")
    tpl2.matmul(x, w)
    assert len(calls) == 1
    reset_plan_caches()


# ---------------------------------------------------------------------------
# routing: CNN zoo convs all take the direct kernel; VMEM overflow falls back
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["pallas", "q16"])
@pytest.mark.parametrize("net", ["lenet", "alexnet", "vgg16"])
def test_cnn_zoo_routes_direct(backend, net):
    """Stride-1 *and* strided (AlexNet conv1, stride 4) convs route direct."""
    spec = CNN_ZOO[net]
    tpl = default_template(backend)
    plan = plan_cnn(tpl, spec, (1, spec.input_hw, spec.input_hw, spec.input_ch))
    assert [cp.route for cp in plan.convs] == ["direct"] * len(spec.convs)
    assert all(cp.vmem_bytes <= tpl.config.hw.vmem_bytes for cp in plan.convs)


def test_conv_vmem_overflow_falls_back_to_im2col():
    # 16 KiB: below even the manual-DMA regime's minimal working set for
    # this layer (ISSUE 8 halved the direct route's residency, so the old
    # 64 KiB budget now legitimately fits a direct config)
    hw = dataclasses.replace(TPU_V5E, vmem_bytes=16 * 1024)
    eng = Engine(TemplateConfig(backend="pallas", interpret=True, hw=hw))
    plan = eng.plan_conv((1, 64, 64, 32), (3, 3, 32, 64))
    assert plan.route == "im2col"
    assert plan.block is not None
    with pytest.raises(ValueError):
        eng.plan_conv((1, 64, 64, 32), (3, 3, 32, 64), route="direct")


# ---------------------------------------------------------------------------
# end-to-end: direct path produces the same logits as the im2col path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["pallas", "q16"])
def test_cnn_direct_matches_im2col(backend):
    params = init_cnn(jax.random.PRNGKey(0), LENET, scale=0.3)
    x = _rand((2, 32, 32, 1), scale=0.5, salt=19)
    tpl = default_template(backend)
    p_direct = plan_cnn(tpl, LENET, x.shape)
    p_gemm = plan_cnn(tpl, LENET, x.shape, force_route="im2col")
    assert all(cp.route == "direct" for cp in p_direct.convs)
    assert all(cp.route == "im2col" for cp in p_gemm.convs)
    f1 = cnn_forward(tpl, LENET, params, x, plan=p_direct)
    f2 = cnn_forward(tpl, LENET, params, x, plan=p_gemm)
    # float: 1e-4; q16: both paths are bit-exact int32 accumulations, allow
    # one Q2.14 LSB of slack for the dequantized logits.
    tol = 1e-4 if backend == "pallas" else Q2_14.resolution * 1.001
    assert float(jnp.abs(f1 - f2).max()) <= tol
    # routing assertion on the executed forward, not just the plan
    assert tpl.engine.counters["conv_direct"] >= len(LENET.convs)


def test_cnn_pallas_matches_xla_logits():
    params = init_cnn(jax.random.PRNGKey(0), LENET, scale=0.3)
    x = _rand((2, 32, 32, 1), scale=0.5, salt=20)
    f_xla = cnn_forward(default_template("xla"), LENET, params, x)
    f_pal = cnn_forward(default_template("pallas"), LENET, params, x)
    np.testing.assert_allclose(
        np.asarray(f_pal), np.asarray(f_xla), atol=1e-4, rtol=1e-4
    )


def test_plan_cnn_is_memoized():
    tpl = default_template("pallas")
    p1 = plan_cnn(tpl, LENET, (2, 32, 32, 1))
    p2 = plan_cnn(tpl, LENET, (2, 32, 32, 1))
    assert p1 is p2
    reset_plan_caches()
    p3 = plan_cnn(tpl, LENET, (2, 32, 32, 1))
    assert p3 is not p1, "reset_plan_caches must also drop NetworkPlan memos"


def test_plan_cnn_non_square_input():
    """Plans must track H and W independently (and forward must still run)."""
    spec = dataclasses.replace(LENET, convs=((6, 5, 1, 0, 2),), fcs=(16,))
    tpl = default_template("pallas")
    plan = plan_cnn(tpl, spec, (1, 32, 40, 1))
    # conv: (32-5+1, 40-5+1) = (28, 36); pool 2 -> (14, 18)
    assert plan.convs[0].gemm[0] == 28 * 36
    assert plan.fcs[0].k == 14 * 18 * 6
    # init_cnn assumes square inputs, so build params by hand from the plan
    x = _rand((1, 32, 40, 1), scale=0.5, salt=21)
    params = {
        "convs": [{"w": _rand((5, 5, 1, 6), salt=24), "b": jnp.zeros((6,))}],
        "fcs": [
            {"w": _rand((plan.fcs[0].k, 16), salt=22), "b": jnp.zeros((16,))},
            {"w": _rand((16, spec.n_classes), salt=23), "b": jnp.zeros((spec.n_classes,))},
        ],
    }
    out = cnn_forward(tpl, spec, params, x, plan=plan)
    assert out.shape == (1, spec.n_classes)
    assert bool(jnp.isfinite(out).all())


# ---------------------------------------------------------------------------
# ad-hoc dispatch under an active mesh plans LOCAL shapes (ISSUE 9 satellite)
# ---------------------------------------------------------------------------


class _StubMesh:
    """Duck-typed 2x2 mesh: planners read ``.shape``/``.axis_names`` only,
    and ``use_mesh`` enters it as a context manager — lets a single-device
    host exercise multi-way local-shape math."""

    shape = {"data": 2, "model": 2}
    axis_names = ("data", "model")

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


def test_adhoc_matmul_plans_local_shape_under_mesh():
    """Plan-less Engine.matmul inside use_mesh must plan the per-shard
    (m/data, n/model, k) shape — the one plan_gemm(mesh=...) warms and the
    sharded program executes — not the global one."""
    from repro.parallel.sharding import TRAIN_RULES, use_mesh

    eng = Engine(TemplateConfig(backend="pallas", interpret=True),
                 plan_cache=PlanCache())
    x = jax.random.normal(KEY, (8, 16))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (16, 32))
    with use_mesh(_StubMesh(), TRAIN_RULES):
        eng.matmul(x, w)
    planned = {k[:3] for k in eng.plan_cache._blocks}
    assert (4, 16, 16) in planned, planned  # local shard shape
    assert (8, 32, 16) not in planned, planned  # global shape never planned
    # outside a mesh context the global shape is planned as before
    eng.matmul(x, w)
    assert (8, 32, 16) in {k[:3] for k in eng.plan_cache._blocks}


def test_adhoc_conv2d_plans_local_shape_under_mesh():
    from repro.parallel.sharding import TRAIN_RULES, use_mesh

    eng = Engine(TemplateConfig(backend="pallas", interpret=True),
                 plan_cache=PlanCache())
    x = jax.random.normal(KEY, (4, 8, 8, 4)) * 0.3
    w = jax.random.normal(jax.random.fold_in(KEY, 2), (3, 3, 4, 8)) * 0.3
    with use_mesh(_StubMesh(), TRAIN_RULES):
        eng.conv2d(x, w, padding=1)
    # conv DSE keys: (hp, wp, cin, kh, kw, ho, wo, cout, stride, in_bytes,
    # spec) — the planned Cout is the model-sharded local 4, never 8
    couts = {k[7] for k in eng.plan_cache._conv_tiles}
    assert couts == {4}, couts
