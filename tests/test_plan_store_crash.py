"""Plan-store crash consistency: a writer killed mid-save never corrupts it.

``PlanRegistry.save`` is stage-then-commit (write + fsync ``{path}.tmp.{pid}``,
then ``os.replace``), and every ``save_plan_store`` writer stages inside the
flock'd merge lock, which the OS releases on process death.  So for either
crash window —

* **mid-stage** (died while writing the temp file): the temp holds torn JSON
  but the committed store was never touched;
* **mid-commit** (died between fsync and rename): a complete-but-orphaned
  temp file sits next to the untouched store —

the invariant is the same: the store at ``path`` stays loadable with its
previous contents, and the next ``save_plan_store`` garbage-collects the
``.tmp`` litter while merging in its own plans.  This pins down the latent
single-writer assumption the replicated serving tier (ISSUE 7) now violates
by design: N replicas all periodically merge into one shared store.

Crashes are real ``os._exit`` process deaths in subprocesses, not exceptions.
"""
import glob
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.core.engine import PlanRegistry

_CRASH_SCRIPT = textwrap.dedent(
    """
    import os, sys
    store, point = sys.argv[1], sys.argv[2]
    os.environ.pop("REPRO_PLAN_STORE", None)
    from repro.core import engine
    from repro.core.engine import Engine, plan_cache_for, save_plan_store
    from repro.core.template import TemplateConfig

    eng = Engine(TemplateConfig(backend="pallas", interpret=True),
                 plan_cache=plan_cache_for())
    eng.plan_gemm(64, 64, 64)
    save_plan_store(store)          # complete store: 1 entry
    eng.plan_gemm(128, 64, 64)      # second entry, never committed

    if point == "commit":
        real = os.replace
        def boom(src, dst, *a, **kw):
            if dst == store:
                os._exit(7)         # die after fsync, before the rename
            return real(src, dst, *a, **kw)
        os.replace = boom
    elif point == "stage":
        def boom(doc, f, **kw):
            f.write('{"version": 99, "torn')
            f.flush()
            os._exit(7)             # die mid-write: torn temp file
        engine.json.dump = boom
    else:
        raise SystemExit(f"bad crash point {point!r}")
    save_plan_store(store)
    os._exit(1)                     # the crash above must have fired
    """
)

_RECOVER_SCRIPT = textwrap.dedent(
    """
    import glob, json, os, sys
    store = sys.argv[1]
    os.environ.pop("REPRO_PLAN_STORE", None)
    from repro.core.engine import (Engine, PlanRegistry, plan_cache_for,
                                   save_plan_store)
    from repro.core.template import TemplateConfig

    eng = Engine(TemplateConfig(backend="pallas", interpret=True),
                 plan_cache=plan_cache_for())
    eng.plan_gemm(128, 64, 64)
    save_plan_store(store)
    reg = PlanRegistry()
    print(json.dumps({"entries": reg.load(store),
                      "litter": glob.glob(store + ".tmp.*")}))
    """
)


def _run(script, *argv):
    env = dict(os.environ, PYTHONPATH="src")
    return subprocess.run(
        [sys.executable, "-c", script, *argv], capture_output=True, text=True,
        env=env, timeout=300,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )


@pytest.mark.parametrize("point", ["stage", "commit"])
def test_writer_killed_mid_save_leaves_loadable_store(tmp_path, point):
    store = str(tmp_path / "plans.json")
    out = _run(_CRASH_SCRIPT, store, point)
    assert out.returncode == 7, (
        f"crash writer exited {out.returncode}, wanted the simulated kill:\n"
        f"{out.stderr[-3000:]}")

    # previous committed store: untouched, loadable, still 1 entry
    reg = PlanRegistry()
    assert reg.load(store) == 1
    assert len(reg) == 1

    # the dead writer left tmp litter behind (and, mid-stage, it is torn —
    # proving the commit really is what publishes)
    litter = glob.glob(store + ".tmp.*")
    assert litter, "crashed writer should leave a .tmp sibling"
    if point == "stage":
        with pytest.raises(json.JSONDecodeError):
            with open(litter[0]) as f:
                json.load(f)

    # next writer merges its plans in and garbage-collects the litter
    out2 = _run(_RECOVER_SCRIPT, store)
    assert out2.returncode == 0, out2.stderr[-3000:]
    rec = json.loads(out2.stdout.strip().splitlines()[-1])
    assert rec["entries"] == 2, rec  # old 64-gemm + recovered 128-gemm
    assert rec["litter"] == [], rec
    assert glob.glob(store + ".tmp.*") == []
