"""PlanRegistry: persisted plan store, measured-time autotune, and
sharding-aware local-shape planning (DESIGN.md §6).

Covers the acceptance criteria: a save → clear → load cycle reproduces
bit-identical plans (blocks, conv tiles, no-fit sentinels) with zero DSE
searches afterwards; corrupted / version-mismatched stores are rejected
cleanly; a warm serve session performs zero grid searches; and the same
logical GEMM planned under a mesh vs a single device yields local-shape
plans whose executed outputs match the unsharded reference.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import dse
from repro.core.engine import (
    PLAN_STORE_ENV,
    Engine,
    PlanCache,
    PlanRegistry,
    PlanStoreError,
    PrecisionChoice,
    load_plan_store,
    plan_cache_for,
    plan_store_stats,
    register_plan_store,
    reset_plan_caches,
    save_plan_store,
    warm_start_plan_store,
)
from repro.core.quantization import Q2_6, Q2_14
from repro.core.template import TemplateConfig, default_template
from repro.core.tiling import TPU_V5E

# Small enough that *no* direct config fits the (1, 64, 64, 32) x (3, 3, 32,
# 64) layer below: since the DMA-halo regime (ISSUE 8) can shrink the input
# window to a few rows x cols, the floor is the double-buffered tau=8 weight
# slab (9*32*8*4*2 = 18 KiB) plus the minimal window/accumulator — ~21 KiB.
TINY_HW = dataclasses.replace(TPU_V5E, vmem_bytes=16 * 1024)


def _populated_registry():
    """A registry holding a GEMM block, a direct conv tile, and — via a
    tiny-VMEM spec — a cached no-fit sentinel plus the fallback GEMM block."""
    reg = PlanRegistry()
    eng = Engine(TemplateConfig(backend="pallas", interpret=True), plan_cache=reg)
    g = eng.plan_gemm(256, 512, 256)
    c = eng.plan_conv((1, 32, 32, 8), (3, 3, 8, 16), stride=1, padding=1)
    tiny = Engine(
        TemplateConfig(backend="pallas", interpret=True, hw=TINY_HW), plan_cache=reg
    )
    c_nofit = tiny.plan_conv((1, 64, 64, 32), (3, 3, 32, 64))
    assert c.route == "direct" and c_nofit.route == "im2col"
    return reg, (g, c, c_nofit)


def _forbid_searches(monkeypatch):
    def boom(*a, **kw):  # pragma: no cover - only fires on regression
        raise AssertionError("DSE grid search ran against a warm registry")

    monkeypatch.setattr(dse, "default_block_for", boom)
    monkeypatch.setattr(dse, "default_conv_tile_for", boom)


# ---------------------------------------------------------------------------
# serialization round-trip
# ---------------------------------------------------------------------------


def test_round_trip_bit_identical(tmp_path, monkeypatch):
    """save → clear → load reproduces every plan without a single search."""
    reg, (g, c, c_nofit) = _populated_registry()
    path = str(tmp_path / "store.json")
    reg.save(path)
    doc = reg.to_doc()

    loaded = PlanRegistry()
    n = loaded.load(path)
    assert n == len(reg) > 0
    assert loaded.to_doc() == doc, "round-trip must be bit-identical"
    assert loaded.misses == 0 and loaded.hits == 0, "loads are not lookups"

    _forbid_searches(monkeypatch)
    eng = Engine(TemplateConfig(backend="pallas", interpret=True), plan_cache=loaded)
    assert eng.plan_gemm(256, 512, 256) == g
    assert eng.plan_conv((1, 32, 32, 8), (3, 3, 8, 16), stride=1, padding=1) == c
    tiny = Engine(
        TemplateConfig(backend="pallas", interpret=True, hw=TINY_HW), plan_cache=loaded
    )
    assert tiny.plan_conv((1, 64, 64, 32), (3, 3, 32, 64)) == c_nofit
    assert loaded.misses == 0


def test_no_fit_sentinel_round_trips(tmp_path):
    reg, _ = _populated_registry()
    assert None in reg._conv_tiles.values(), "test premise: a no-fit entry exists"
    path = str(tmp_path / "store.json")
    reg.save(path)
    loaded = PlanRegistry()
    loaded.load(path)
    assert None in loaded._conv_tiles.values()
    assert set(loaded._conv_tiles) == set(reg._conv_tiles)


def test_store_is_versioned_json(tmp_path):
    reg, _ = _populated_registry()
    path = str(tmp_path / "store.json")
    reg.save(path)
    with open(path) as f:
        doc = json.load(f)
    assert doc["format"] == "repro-plan-store"
    assert doc["version"] == 3
    assert doc["specs"] and doc["gemm"] and doc["conv"]
    assert "precision" in doc
    # every entry carries provenance
    assert all(e["source"] in ("analytic", "measured") for e in doc["gemm"])
    assert all(e["source"] in ("analytic", "measured") for e in doc["conv"])


# ---------------------------------------------------------------------------
# precision pins: v3 round-trip + lenient v2/v1 migration (DESIGN.md §11)
# ---------------------------------------------------------------------------


def test_precision_pin_round_trip(tmp_path):
    """Pinned per-layer grids (fmt + drift + provenance) survive
    save -> clear -> load bit-identically, and a warm replay serves them as
    hits with zero misses."""
    reg, _ = _populated_registry()
    reg.pin_precision("lenet", "conv0", Q2_6, drift=1.0)
    reg.pin_precision("lenet", "fc2", Q2_14, drift=0.97)
    path = str(tmp_path / "store.json")
    reg.save(path)

    loaded = PlanRegistry()
    n = loaded.load(path)
    assert n == len(reg) > 0
    assert loaded.misses == 0 and loaded.hits == 0, "loads are not lookups"
    assert loaded.to_doc() == reg.to_doc(), "round-trip must be bit-identical"
    assert loaded.precision_plan("lenet") == {"conv0": Q2_6, "fc2": Q2_14}
    assert loaded.precision_for("lenet", "conv0") == PrecisionChoice(Q2_6, 1.0)
    assert loaded.hits == 1 and loaded.misses == 0, \
        "warm precision replay is hits-only (REPRO_PLAN_ASSERT_WARM contract)"


def test_precision_miss_charged_by_pin_not_lookup():
    """An absent pin is not a miss (the sweep itself charges it via
    pin_precision(searched=True)); replayed pins charge nothing."""
    reg = PlanRegistry()
    assert reg.precision_for("net", "l0") is None
    assert reg.misses == 0 and reg.hits == 0
    reg.pin_precision("net", "l0", Q2_6, drift=0.995)
    assert reg.misses == 1
    reg.pin_precision("net", "l1", Q2_14, searched=False)
    assert reg.misses == 1


def test_v2_store_migrates_gemm_and_conv_without_precision(tmp_path):
    """A v2 (pre-precision) store loads leniently: gemm + conv entries merge
    unchanged, precision pins simply don't exist — even a stray precision
    section in a v2 doc is ignored rather than trusted."""
    reg, (g, c, c_nofit) = _populated_registry()
    reg.pin_precision("lenet", "conv0", Q2_6, drift=1.0)
    doc = reg.to_doc()
    doc["version"] = 2  # keep the (stray) precision section on purpose
    path = tmp_path / "v2.json"
    path.write_text(json.dumps(doc))

    loaded = PlanRegistry()
    n = loaded.load(str(path))
    assert n == len(reg._blocks) + len(reg._conv_tiles)
    assert loaded._blocks == reg._blocks
    assert loaded._conv_tiles == reg._conv_tiles
    assert loaded.precision_plan("lenet") == {}
    # the migrated plans still serve without a search
    eng = Engine(TemplateConfig(backend="pallas", interpret=True),
                 plan_cache=loaded)
    assert eng.plan_gemm(256, 512, 256) == g
    assert eng.plan_conv((1, 32, 32, 8), (3, 3, 8, 16), stride=1, padding=1) == c
    assert loaded.misses == 0


def test_v1_store_migrates_gemm_only(tmp_path):
    """v1 keeps gemm entries; its pre-column-tiling conv docs and (stray)
    precision pins are dropped so those layers re-plan/re-sweep."""
    reg, _ = _populated_registry()
    reg.pin_precision("lenet", "conv0", Q2_6, drift=1.0)
    doc = reg.to_doc()
    doc["version"] = 1
    path = tmp_path / "v1.json"
    path.write_text(json.dumps(doc))

    loaded = PlanRegistry()
    n = loaded.load(str(path))
    assert n == len(reg._blocks)
    assert loaded._blocks == reg._blocks
    assert not loaded._conv_tiles
    assert loaded.precision_plan("lenet") == {}


def test_bad_precision_entry_rejected(tmp_path):
    """A v3 store with a malformed precision entry is rejected loudly and
    leaves nothing half-merged."""
    reg, _ = _populated_registry()
    reg.pin_precision("lenet", "conv0", Q2_6, drift=1.0)
    doc = reg.to_doc()
    doc["precision"][0]["fmt"] = [2, 6]  # missing total_bits
    path = tmp_path / "badprec.json"
    path.write_text(json.dumps(doc))
    fresh = PlanRegistry()
    with pytest.raises(PlanStoreError, match="precision"):
        fresh.load(str(path))
    assert len(fresh) == 0


# ---------------------------------------------------------------------------
# rejection of bad stores
# ---------------------------------------------------------------------------


def test_corrupted_store_rejected(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text("{this is not json")
    with pytest.raises(PlanStoreError):
        PlanRegistry().load(str(path))


def test_version_mismatch_rejected(tmp_path):
    path = tmp_path / "old.json"
    path.write_text(json.dumps({
        "format": "repro-plan-store", "version": 999,
        "specs": [], "gemm": [], "conv": [],
    }))
    with pytest.raises(PlanStoreError, match="version"):
        PlanRegistry().load(str(path))


def test_wrong_format_rejected(tmp_path):
    path = tmp_path / "other.json"
    path.write_text(json.dumps({"format": "something-else", "version": 1}))
    with pytest.raises(PlanStoreError, match="format"):
        PlanRegistry().load(str(path))


def test_rejected_store_leaves_registry_untouched(tmp_path):
    """A store whose tail is corrupt must not half-merge its valid head."""
    reg, _ = _populated_registry()
    path = tmp_path / "half.json"
    doc = reg.to_doc()
    doc["conv"].append({"spec": 99, "key": [1] * 10, "choice": None})  # bad spec
    path.write_text(json.dumps(doc))
    fresh = PlanRegistry()
    with pytest.raises(PlanStoreError):
        fresh.load(str(path))
    assert len(fresh) == 0, "valid gemm entries must not leak from a rejected store"


@pytest.mark.parametrize("entry,n_specs", [
    ({"spec": 0, "key": [1, 2, 3], "block": [8, 128, 128]}, 0),  # spec missing
    ({"spec": -1, "key": [1, 2, 3], "block": [8, 128, 128]}, 1),  # negative wrap
    ({"spec": 0, "key": [1, 2], "block": [8, 128, 128]}, 1),  # short key
    ({"spec": 0, "key": [1, 2, 3], "block": [512]}, 1),  # short block
])
def test_structurally_broken_store_rejected(tmp_path, entry, n_specs):
    path = tmp_path / "broken.json"
    path.write_text(json.dumps({
        "format": "repro-plan-store", "version": 1,
        "specs": [dataclasses.asdict(TPU_V5E)] * n_specs,
        "gemm": [entry], "conv": [],
    }))
    with pytest.raises(PlanStoreError):
        PlanRegistry().load(str(path))


def test_missing_file_rejected_unless_missing_ok(tmp_path):
    with pytest.raises(PlanStoreError):
        PlanRegistry().load(str(tmp_path / "nope.json"))
    assert load_plan_store(str(tmp_path / "nope.json"), missing_ok=True) == 0


# ---------------------------------------------------------------------------
# measured-time autotune overwrite
# ---------------------------------------------------------------------------


def test_measure_and_pin_overwrites_with_provenance(tmp_path):
    reg = PlanRegistry()
    analytic = reg.block_for(128, 256, 128)
    assert reg.source_for(128, 256, 128) == "analytic"
    pinned = reg.measure_and_pin(128, 256, 128, reps=1)
    assert reg.source_for(128, 256, 128) == "measured"
    assert reg.stats()["measured"] == 1
    # the pinned block is served on the next lookup with no new search
    misses = reg.misses
    assert reg.block_for(128, 256, 128) == pinned
    assert reg.misses == misses

    # provenance survives the store round-trip
    path = str(tmp_path / "store.json")
    reg.save(path)
    loaded = PlanRegistry()
    loaded.load(path)
    assert loaded.source_for(128, 256, 128) == "measured"
    assert loaded.block_for(128, 256, 128) == pinned
    del analytic


def test_measure_and_pin_picks_from_candidates():
    from repro.core.tiling import MatmulBlock

    reg = PlanRegistry()
    cands = [MatmulBlock(128, 128, 128), MatmulBlock(256, 128, 128)]
    best = reg.measure_and_pin(256, 128, 128, candidates=cands, reps=1)
    assert best in cands


def test_merge_never_downgrades_measured_pins(tmp_path, monkeypatch):
    """A concurrent analytic writer must not clobber a measured pin — in
    merge_from, in load, and through the shared-store save cycle."""
    reset_plan_caches()
    path = str(tmp_path / "shared.json")
    # writer A: measured pin, saved to the shared store
    a = PlanRegistry()
    pinned = a.measure_and_pin(128, 256, 128, reps=1)
    a.save(path)
    # writer B: plans the same shape analytically and saves to the same store
    monkeypatch.setenv(PLAN_STORE_ENV, path)
    plan_cache_for(TPU_V5E).block_for(128, 256, 128, TPU_V5E)
    save_plan_store()
    # the measured pin survives on disk...
    check = PlanRegistry()
    check.load(path)
    assert check.source_for(128, 256, 128) == "measured"
    assert check.block_for(128, 256, 128) == pinned
    # ...and loading an analytic store over a live measured pin keeps the pin
    b = PlanRegistry()
    b.block_for(128, 256, 128)
    analytic_doc = b.to_doc()
    a.merge_doc(analytic_doc)
    assert a.source_for(128, 256, 128) == "measured"
    reset_plan_caches()


def test_cell_gemm_plans_pallas_template_warms_registry():
    """step_and_specs threads tpl → cell_gemm_plans: a Pallas template pins
    real blocks for the local shard shapes into the registry."""
    from repro.configs import get_config, reduced
    from repro.configs.base import ShapeSpec
    from repro.launch.steps import cell_gemm_plans
    from repro.parallel.sharding import TRAIN_RULES

    reset_plan_caches()
    cfg = reduced(get_config("qwen2-0.5b"))
    shape = ShapeSpec("t", 64, 8, "train")
    tpl = default_template("pallas")
    plans = cell_gemm_plans(cfg, shape, _StubMesh(), TRAIN_RULES, tpl)
    assert all(p.block is not None for p in plans.values())
    assert plan_cache_for(TPU_V5E).stats()["gemm_blocks"] > 0
    reset_plan_caches()


def test_engine_measure_and_pin_uses_engine_spec():
    reg = PlanRegistry()
    eng = Engine(TemplateConfig(backend="pallas", interpret=True), plan_cache=reg)
    blk = eng.measure_and_pin(128, 128, 128, reps=1)
    assert reg.source_for(128, 128, 128, TPU_V5E) == "measured"
    assert eng.plan_gemm(128, 128, 128).block == blk


# ---------------------------------------------------------------------------
# global store: env warm start, stats, registration dedupe
# ---------------------------------------------------------------------------


def test_global_store_env_round_trip(tmp_path, monkeypatch):
    reset_plan_caches()
    path = str(tmp_path / "global.json")
    monkeypatch.setenv(PLAN_STORE_ENV, path)
    plan_cache_for(TPU_V5E).block_for(64, 128, 64, TPU_V5E)
    plan_cache_for(TINY_HW).block_for(32, 128, 32, TINY_HW)  # 2nd spec, same file
    save_plan_store()
    reset_plan_caches()
    assert plan_store_stats()["gemm_blocks"] == 0
    ret_path, n = warm_start_plan_store()
    assert ret_path == path and n == 2
    st = plan_store_stats()
    assert st["gemm_blocks"] == 2 and st["misses"] == 0
    # both specs were re-distributed to their own registries
    assert len(plan_cache_for(TPU_V5E)) == 1
    assert len(plan_cache_for(TINY_HW)) == 1
    reset_plan_caches()


def test_warm_start_no_env_is_noop(monkeypatch):
    monkeypatch.delenv(PLAN_STORE_ENV, raising=False)
    assert warm_start_plan_store() == (None, 0)
    with pytest.raises(ValueError):
        save_plan_store()


def test_warm_start_tolerates_unusable_store(tmp_path):
    """A corrupt/version-mismatched store must not be a startup SPOF: the
    drivers cold-start with a warning instead of crashing."""
    path = tmp_path / "bad.json"
    path.write_text("{definitely not json")
    with pytest.warns(UserWarning, match="unusable plan store"):
        ret_path, n = warm_start_plan_store(str(path))
    assert ret_path == str(path) and n == 0
    # strict loading still rejects it
    with pytest.raises(PlanStoreError):
        load_plan_store(str(path))


def test_save_plan_store_merges_existing_file(tmp_path, monkeypatch):
    """Concurrent writers sharing one store append, not overwrite: saving
    merges the on-disk entries with this process's registries."""
    reset_plan_caches()
    path = str(tmp_path / "shared.json")
    # writer A persists one shape
    other = PlanRegistry()
    other.block_for(512, 512, 512, TPU_V5E)
    other.save(path)
    # writer B (this process) knows a different shape and saves to same file
    plan_cache_for(TPU_V5E).block_for(64, 128, 64, TPU_V5E)
    save_plan_store(path)
    reset_plan_caches()
    assert load_plan_store(path) == 2, "both writers' entries must survive"
    reg = plan_cache_for(TPU_V5E)
    assert (512, 512, 512, TPU_V5E) in reg._blocks
    assert (64, 128, 64, TPU_V5E) in reg._blocks
    reset_plan_caches()


def test_stats_reports_gemm_and_conv_separately():
    reg, _ = _populated_registry()
    st = reg.stats()
    assert st["gemm_blocks"] == 2  # direct gemm + im2col fallback block
    assert st["conv_tiles"] == 2  # direct tile + no-fit sentinel
    assert len(reg) == st["gemm_blocks"] + st["conv_tiles"]
    assert st["misses"] == 4 and st["hits"] == 0


def test_register_plan_store_dedupes_by_identity():
    from repro.core import engine as E

    store: dict = {}
    before = len(E._EXTRA_PLAN_STORES)
    register_plan_store(store)
    register_plan_store(store)  # re-registration (e.g. module re-import)
    register_plan_store(store)
    assert len(E._EXTRA_PLAN_STORES) == before + 1
    # remove by identity — list.remove would drop the first *equal* (empty) dict
    E._EXTRA_PLAN_STORES[:] = [s for s in E._EXTRA_PLAN_STORES if s is not store]


# ---------------------------------------------------------------------------
# sharding-aware planning (local per-shard shapes)
# ---------------------------------------------------------------------------


class _StubMesh:
    """Duck-typed mesh for pure local-shape math (no devices needed)."""

    axis_names = ("data", "model")
    shape = {"data": 4, "model": 2}


def test_local_gemm_shape_default_partition():
    from repro.parallel.sharding import local_gemm_shape

    assert local_gemm_shape(256, 512, 128, mesh=_StubMesh()) == (64, 256, 128)


def test_local_dim_rules():
    from repro.parallel.sharding import axis_size, local_dim

    mesh = _StubMesh()
    assert axis_size(mesh, ("pod", "data")) == 4  # missing "pod" dropped
    assert local_dim(256, mesh, "data") == 64
    # non-divisible stays replicated: the jit-boundary shardings DROP a
    # mapping they can't pad, so the planner must plan the full dim — one
    # rule on both sides (was ceil-div, which planned shapes that never ran)
    assert local_dim(257, mesh, "data") == 257
    assert local_dim(3, mesh, "data") == 3  # smaller than axis: replicated
    assert local_dim(256, mesh, None) == 256


def test_local_conv_shapes_batch_and_cout():
    from repro.parallel.sharding import local_conv_shapes

    x, w = local_conv_shapes((8, 32, 32, 3), (3, 3, 3, 64), mesh=_StubMesh())
    assert x == (2, 32, 32, 3)  # batch / data(4)
    assert w == (3, 3, 3, 32)  # cout / model(2)


def test_plan_gemm_mesh_vs_single_from_one_registry():
    reg = PlanRegistry()
    eng = Engine(TemplateConfig(backend="pallas", interpret=True), plan_cache=reg)
    single = eng.plan_gemm(256, 512, 128)
    local = eng.plan_gemm(256, 512, 128, mesh=_StubMesh())
    assert single.logical == () and (single.m, single.n, single.k) == (256, 512, 128)
    assert local.logical == (256, 512, 128)
    assert (local.m, local.n, local.k) == (64, 256, 128)
    assert single != local, "mesh and single-chip plans must differ"
    assert reg.stats()["gemm_blocks"] == 2, "one registry holds both"


def test_plan_cnn_mesh_local_shapes():
    from jax.sharding import PartitionSpec as P

    from repro.models.cnn import LENET, plan_cnn

    reset_plan_caches()
    tpl = default_template("pallas")
    mesh = _StubMesh()
    p_single = plan_cnn(tpl, LENET, (8, 32, 32, 1))
    p_mesh = plan_cnn(tpl, LENET, (8, 32, 32, 1), mesh=mesh,
                      partition=P("data", "model"))
    # conv GEMM M scales with the local batch (8 -> 2)
    assert p_mesh.convs[0].gemm[0] == p_single.convs[0].gemm[0] // 4
    # FC N is model-sharded (120 -> 60), K stays the gathered full width
    assert p_mesh.fcs[0].n == p_single.fcs[0].n // 2
    assert p_mesh.fcs[0].k == p_single.fcs[0].k
    # memoized separately per topology
    assert plan_cnn(tpl, LENET, (8, 32, 32, 1), mesh=mesh,
                    partition=P("data", "model")) is p_mesh
    assert plan_cnn(tpl, LENET, (8, 32, 32, 1)) is p_single
    reset_plan_caches()


def test_cell_gemm_plans_thread_rules():
    from repro.configs import get_config, reduced
    from repro.configs.base import ShapeSpec
    from repro.launch.steps import cell_gemm_plans
    from repro.parallel.sharding import TRAIN_RULES

    cfg = reduced(get_config("qwen2-0.5b"))
    shape = ShapeSpec("t", 64, 8, "train")
    plans = cell_gemm_plans(cfg, shape, _StubMesh(), TRAIN_RULES)
    assert set(plans) == {"qkv", "attn_out", "mlp_up", "mlp_down", "lm_head"}
    m_tokens = shape.tokens
    # M sharded over ("pod","data") -> data(4); N of mlp_up over model(2)
    assert plans["mlp_up"].m == m_tokens // 4
    assert plans["mlp_up"].n == cfg.d_ff // 2
    assert plans["mlp_up"].logical == (m_tokens, cfg.d_ff, cfg.d_model)
    # the down-projection contracts over the model-sharded ff dim
    assert plans["mlp_down"].k == cfg.d_ff // 2
    assert plans["mlp_down"].n == cfg.d_model


# ---------------------------------------------------------------------------
# acceptance: mesh vs single device — local plans, executed outputs match
# ---------------------------------------------------------------------------

_MESH_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.engine import Engine
    from repro.core.template import TemplateConfig
    from repro.launch.mesh import make_test_mesh, gemm_partition

    mesh = make_test_mesh()  # (2, 2) ("data", "model")
    eng = Engine(TemplateConfig(backend="pallas", interpret=True))
    m, n, k = 256, 512, 128
    p_single = eng.plan_gemm(m, n, k)
    p_mesh = eng.plan_gemm(m, n, k, mesh=mesh)

    rng = np.random.default_rng(0)
    X = rng.standard_normal((m, k)).astype(np.float32) * 0.3
    W = rng.standard_normal((k, n)).astype(np.float32) * 0.3
    x = jax.device_put(jnp.asarray(X), NamedSharding(mesh, P("data", None)))
    w = jax.device_put(jnp.asarray(W), NamedSharding(mesh, P(None, "model")))
    out = np.asarray(jax.jit(jnp.dot)(x, w))
    ref = X @ W
    print(json.dumps({
        "single": [p_single.m, p_single.n, p_single.k],
        "local": [p_mesh.m, p_mesh.n, p_mesh.k],
        "logical": list(p_mesh.logical),
        "x_shard": list(x.addressable_shards[0].data.shape),
        "w_shard": list(w.addressable_shards[0].data.shape),
        "max_err": float(np.abs(out - ref).max()),
        "blocks_differ": p_single.block != p_mesh.block,
    }))
    """
)


def test_mesh_local_plans_match_executed_shards():
    """Under make_test_mesh() the plan's (m, n, k) must equal the shapes the
    shards actually execute, and the sharded product must match the
    unsharded reference (runs in a subprocess: needs 8 host devices)."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", _MESH_SCRIPT], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=300,
    )
    assert out.returncode == 0, f"mesh-plan subprocess failed:\n{out.stderr[-3000:]}"
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["single"] == [256, 512, 128]
    assert rec["local"] == [128, 256, 128]
    assert rec["logical"] == [256, 512, 128]
    # the planned local shape IS the executed shard shape
    assert rec["x_shard"] == [rec["local"][0], rec["local"][2]]
    assert rec["w_shard"] == [rec["local"][2], rec["local"][1]]
    assert rec["max_err"] < 1e-3


# ---------------------------------------------------------------------------
# acceptance: warm serve session performs zero DSE searches
# ---------------------------------------------------------------------------


def test_serve_warm_start_zero_searches(tmp_path, monkeypatch):
    from repro.launch import serve

    monkeypatch.delenv(PLAN_STORE_ENV, raising=False)
    reset_plan_caches()
    store = str(tmp_path / "serve_store.json")
    args = ["--backend", "pallas", "--prompts", "1", "--prompt-len", "8",
            "--gen", "2", "--plan-store", store]
    serve.main(args)  # cold: populates + saves the store
    assert os.path.exists(store)
    cold_misses = plan_cache_for(TPU_V5E).misses
    assert cold_misses > 0, "cold serve must have planned something"

    reset_plan_caches()  # simulate a fresh serving process
    serve.main(args)  # warm: loads the store
    pc = plan_cache_for(TPU_V5E)
    assert pc.misses == 0, "warm serve must perform zero DSE grid searches"
    assert pc.hits > 0
    reset_plan_caches()
