"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantization import Q2_14, QFormat, quantize
from repro.core.tiling import MatmulBlock
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(42)


def _rand(shape, dtype=jnp.float32, scale=1.0, key=KEY):
    k = jax.random.fold_in(key, hash(shape) % (2**31))
    return (jax.random.normal(k, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# matmul (float)
# ---------------------------------------------------------------------------

MM_SHAPES = [
    (8, 8, 8),
    (32, 16, 24),
    (100, 60, 36),  # non-multiples -> internal padding
    (128, 256, 64),
    (257, 129, 511),  # primes
    (1, 128, 128),
]


@pytest.mark.parametrize("m,k,n", MM_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_fp_vs_ref(m, k, n, dtype):
    x = _rand((m, k), dtype)
    w = _rand((k, n), dtype)
    out = ops.matmul_fp(x, w, interpret=True)
    want = ref.matmul_ref(x, w)
    assert out.dtype == want.dtype
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_matmul_fp_custom_block():
    x = _rand((64, 96))
    w = _rand((96, 80))
    out = ops.matmul_fp(x, w, block=MatmulBlock(32, 128, 128), interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.matmul_ref(x, w)),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# matmul (Q2.14 fixed point)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", [(16, 16, 16), (64, 100, 48), (33, 57, 65)])
@pytest.mark.parametrize("fmt", [Q2_14, QFormat(4, 12), QFormat(8, 8)])
def test_matmul_q16_vs_ref(m, k, n, fmt):
    # keep products small enough that int32 accumulation cannot overflow
    x = _rand((m, k), scale=0.2)
    w = _rand((k, n), scale=0.2)
    xq, wq = quantize(x, fmt), quantize(w, fmt)
    out = ops.matmul_q16(xq, wq, fmt=fmt, interpret=True)
    want = ref.matmul_q16_ref(xq, wq, fmt)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------

CONV_CASES = [
    # n, h, w, cin, cout, k, stride, pad
    (1, 8, 8, 3, 8, 3, 1, 0),
    (2, 12, 12, 4, 16, 3, 1, 1),
    (1, 16, 16, 8, 8, 5, 1, 2),
    (2, 32, 32, 3, 16, 11, 4, 2),  # AlexNet-conv1-like: strided -> im2col path
    (1, 9, 9, 2, 6, 2, 2, 0),
]


@pytest.mark.parametrize("n,h,w,cin,cout,k,stride,pad", CONV_CASES)
def test_conv2d_vs_ref(n, h, w, cin, cout, k, stride, pad):
    x = _rand((n, h, w, cin))
    wt = _rand((k, k, cin, cout), scale=0.3)
    out = ops.conv2d(x, wt, stride=stride, padding=pad, interpret=True)
    want = ref.conv2d_ref(x, wt, stride=stride, padding=pad)
    assert out.shape == want.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-3, rtol=2e-3)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FA_CASES = [
    # b, hq, hkv, sq, sk, d, causal
    (1, 4, 4, 64, 64, 32, True),
    (2, 8, 2, 64, 64, 32, True),   # GQA
    (1, 4, 1, 128, 128, 64, True),  # MQA
    (2, 4, 4, 64, 64, 32, False),
    (1, 2, 2, 96, 96, 32, True),   # non-multiple of block
]


@pytest.mark.parametrize("b,hq,hkv,sq,sk,d,causal", FA_CASES)
def test_flash_attention_vs_ref(b, hq, hkv, sq, sk, d, causal):
    q = _rand((b, hq, sq, d), scale=0.5)
    k = _rand((b, hkv, sk, d), scale=0.5)
    v = _rand((b, hkv, sk, d), scale=0.5)
    out = ops.flash_attention(q, k, v, causal=causal, bq=32, bk=32, interpret=True)
    g = hq // hkv
    qf = q.reshape(b, hkv, g, sq, d).reshape(b * hq, sq, d)
    kf = jnp.broadcast_to(k[:, :, None], (b, hkv, g, sk, d)).reshape(b * hq, sk, d)
    vf = jnp.broadcast_to(v[:, :, None], (b, hkv, g, sk, d)).reshape(b * hq, sk, d)
    want = ref.attention_ref(qf, kf, vf, causal=causal).reshape(b, hq, sq, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-3, rtol=2e-3)


def test_flash_attention_q_offset():
    """Decode-style: 16 query rows appended at the end of 64 keys."""
    b, h, d, sk, sq = 1, 2, 32, 64, 16
    q = _rand((b, h, sq, d), scale=0.5)
    k = _rand((b, h, sk, d), scale=0.5)
    v = _rand((b, h, sk, d), scale=0.5)
    out = ops.flash_attention(q, k, v, causal=True, q_offset=sk - sq,
                              bq=16, bk=16, interpret=True)
    want = ref.attention_ref(
        q.reshape(b * h, sq, d), k.reshape(b * h, sk, d), v.reshape(b * h, sk, d),
        causal=True, q_offset=sk - sq,
    ).reshape(b, h, sq, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-3, rtol=2e-3)
