"""Paper-faithful plane: CNN zoo on the compute unit + FPGA model vs Table 1/2."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fpga_model import (
    BOARDS,
    NETWORKS,
    TemplateInstance,
    ULTRA96,
    ZCU102,
    ZCU104,
    alexnet_layers,
    evaluate_network,
    lenet_layers,
)
from repro.core.template import default_template
from repro.core.tiling import ConvTiling, FCTiling
from repro.models.cnn import CNN_ZOO, LENET, cnn_forward, init_cnn

TPL = default_template()


def _small_lenet_input():
    return jax.random.normal(jax.random.PRNGKey(0), (2, 32, 32, 1)) * 0.5


def test_lenet_forward_shapes():
    params = init_cnn(jax.random.PRNGKey(0), LENET)
    out = cnn_forward(TPL, LENET, params, _small_lenet_input())
    assert out.shape == (2, 10)
    assert bool(jnp.isfinite(out).all())


def test_lenet_quantized_close_to_float():
    params = init_cnn(jax.random.PRNGKey(0), LENET, scale=0.3)
    x = _small_lenet_input()
    f = cnn_forward(TPL, LENET, params, x, quantized=False)
    q = cnn_forward(TPL, LENET, params, x, quantized=True)
    # Q2.14 resolution is 6e-5; logits must agree to ~1e-2 through 5 layers
    assert float(jnp.abs(f - q).max()) < 5e-2
    # and classification must agree
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(f, -1)), np.asarray(jnp.argmax(q, -1))
    )


def test_alexnet_reduced_forward():
    import dataclasses

    spec = dataclasses.replace(CNN_ZOO["alexnet"], input_hw=128)
    params = init_cnn(jax.random.PRNGKey(1), spec)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 128, 128, 3)) * 0.5
    out = cnn_forward(TPL, spec, params, x)
    assert out.shape == (1, 1000)
    assert bool(jnp.isfinite(out).all())


# ---------------------------------------------------------------------------
# FPGA analytic model vs the paper's tables
# ---------------------------------------------------------------------------

PAPER_CU = {"Ultra96": (12, 24), "ZCU104": (20, 30), "ZCU102": (20, 55)}
PAPER_GOPS = {"Ultra96": 51.0, "ZCU104": 107.0, "ZCU102": 230.0}


def _instance(board_name):
    board = BOARDS[board_name]
    mu, tau = PAPER_CU[board_name]
    conv = ConvTiling(t_r=27, t_c=27, mu=mu, tau=tau)
    fc = FCTiling(lam=1024, omega=64, mu=mu, tau=tau)
    return TemplateInstance(board=board, conv=conv, fc=fc)


@pytest.mark.parametrize("board", list(PAPER_CU))
def test_paper_compute_units_fit_their_boards(board):
    inst = _instance(board)
    assert inst.dsp <= BOARDS[board].dsp
    assert inst.bram18 <= BOARDS[board].bram18
    assert inst.fits()


@pytest.mark.parametrize("board", list(PAPER_CU))
def test_conv_throughput_within_band_of_table1(board):
    """Modeled conv-plane GOP/s within [0.4x, 1.6x] of the paper's number.

    An analytic model cannot hit synthesized numbers exactly; the band
    catches order-of-magnitude/unit errors while tolerating modeling error.
    """
    inst = _instance(board)
    rep = evaluate_network("alexnet", alexnet_layers(), inst, batch=4)
    paper = PAPER_GOPS[board]
    assert 0.4 * paper < rep.conv_gops < 1.6 * paper, rep.summary()


def test_peak_scales_with_compute_unit():
    """GOP/s ordering must follow the paper: Ultra96 < ZCU104 < ZCU102."""
    gops = [
        evaluate_network("alexnet", alexnet_layers(), _instance(b), batch=4).conv_gops
        for b in ("Ultra96", "ZCU104", "ZCU102")
    ]
    assert gops[0] < gops[1] < gops[2]


def test_lenet_low_utilization():
    """Tiny network: latency dominated by fill/transfer, GOP/s far below peak."""
    inst = _instance("Ultra96")
    rep = evaluate_network("lenet", lenet_layers(), inst)
    assert rep.gops < inst.peak_gops


def test_network_tables_complete():
    for name, fn in NETWORKS.items():
        layers = fn()
        assert all(l.ops > 0 for l in layers)
    # AlexNet conv ops ≈ 1.3 GOP (Krizhevsky): sanity vs eq. (2)
    conv_ops = sum(l.ops for l in alexnet_layers() if l.kind == "conv")
    assert 1.0e9 < conv_ops < 1.5e9
