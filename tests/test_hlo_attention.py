"""HLO analyzer unit tests + chunked-attention equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hlo_analysis import analyze_hlo
from repro.models.attention import _sdpa_chunked, _sdpa_dense

# ---------------------------------------------------------------------------
# analyzer: trip counts, dots, collectives
# ---------------------------------------------------------------------------


def test_analyzer_multiplies_scan_trip_count():
    def f(x, ws):
        def body(h, w):
            return jnp.tanh(h @ w), None
        return jax.lax.scan(body, x, ws)[0]

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    flops = {}
    for n in (2, 8):
        ws = jax.ShapeDtypeStruct((n, 256, 256), jnp.float32)
        hlo = jax.jit(f).lower(x, ws).compile().as_text()
        flops[n] = analyze_hlo(hlo).flops
    base = 2 * 256 ** 3
    assert flops[2] == pytest.approx(2 * base, rel=0.01)
    assert flops[8] == pytest.approx(8 * base, rel=0.01)
    # XLA's own cost_analysis does NOT do this — that is the analyzer's job
    assert flops[8] / flops[2] == pytest.approx(4.0, rel=0.01)


def test_analyzer_dot_flops_exact():
    m, k, n = 128, 320, 64
    hlo = (
        jax.jit(lambda a, b: a @ b)
        .lower(jax.ShapeDtypeStruct((m, k), jnp.float32),
               jax.ShapeDtypeStruct((k, n), jnp.float32))
        .compile().as_text()
    )
    st = analyze_hlo(hlo)
    assert st.flops == pytest.approx(2 * m * k * n, rel=1e-6)


def test_analyzer_batched_dot():
    hlo = (
        jax.jit(lambda a, b: jnp.einsum("bik,bkj->bij", a, b))
        .lower(jax.ShapeDtypeStruct((4, 32, 16), jnp.float32),
               jax.ShapeDtypeStruct((4, 16, 8), jnp.float32))
        .compile().as_text()
    )
    st = analyze_hlo(hlo)
    assert st.flops == pytest.approx(2 * 4 * 32 * 16 * 8, rel=1e-6)


def test_analyzer_bytes_reasonable():
    n = 512
    hlo = (
        jax.jit(lambda a, b: a @ b)
        .lower(jax.ShapeDtypeStruct((n, n), jnp.float32),
               jax.ShapeDtypeStruct((n, n), jnp.float32))
        .compile().as_text()
    )
    st = analyze_hlo(hlo)
    expect = 3 * n * n * 4  # two reads + one write
    assert expect <= st.bytes <= 4 * expect


# ---------------------------------------------------------------------------
# chunked attention == dense attention
# ---------------------------------------------------------------------------


def _mk(b, s, t, h, hkv, d, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    q = jax.random.normal(ks[0], (b, s, h, d)) * 0.5
    k = jax.random.normal(ks[1], (b, t, hkv, d)) * 0.5
    v = jax.random.normal(ks[2], (b, t, hkv, d)) * 0.5
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("s,t,bq,bk", [(64, 64, 16, 16), (96, 96, 32, 32),
                                       (40, 40, 16, 16)])
def test_chunked_matches_dense(causal, s, t, bq, bk):
    q, k, v = _mk(2, s, t, 4, 2, 16)
    got = _sdpa_chunked(q, k, v, causal=causal, window=0, q_offset=0, bq=bq, bk=bk)
    if causal:
        rows = jnp.arange(s)[:, None]
        cols = jnp.arange(t)[None, :]
        mask = jnp.broadcast_to((rows >= cols)[None, None], (2, 1, s, t))
    else:
        mask = None
    want = _sdpa_dense(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=2e-4)


def test_chunked_windowed_matches_dense():
    s = 64
    w = 16
    q, k, v = _mk(1, s, s, 2, 1, 16, key=3)
    got = _sdpa_chunked(q, k, v, causal=True, window=w, q_offset=0, bq=16, bk=16)
    rows = jnp.arange(s)[:, None]
    cols = jnp.arange(s)[None, :]
    m = (rows >= cols) & ((rows - cols) < w)
    want = _sdpa_dense(q, k, v, jnp.broadcast_to(m[None, None], (1, 1, s, s)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=2e-4)


def test_chunked_gradients_flow():
    q, k, v = _mk(1, 32, 32, 2, 2, 8)

    def loss(q, k, v):
        return _sdpa_chunked(q, k, v, causal=True, window=0, q_offset=0,
                             bq=16, bk=16).sum()

    gq, gk, gv = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    for g in (gq, gk, gv):
        assert bool(jnp.isfinite(g).all())
        assert float(jnp.abs(g).max()) > 0


def test_ring_cache_fill_wraps_correctly():
    """_fill_cache keeps the LAST C positions with slot = pos % C."""
    from repro.models.attention import _fill_cache

    b, s, hkv, d, c = 1, 10, 1, 4, 4
    k = jnp.arange(s, dtype=jnp.float32)[None, :, None, None] * jnp.ones((b, s, hkv, d))
    cache = _fill_cache(k, k, jnp.arange(s), c)
    pos = np.asarray(cache["pos"])
    # positions 6..9 must be present, each at slot p % 4
    assert sorted(pos.tolist()) == [6, 7, 8, 9]
    for slot, p in enumerate(pos):
        assert p % c == slot
        assert float(cache["k"][0, 0, slot, 0]) == float(p)
