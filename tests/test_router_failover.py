"""Deterministic fault-injection suite for the replicated serving tier.

Everything runs on one shared :class:`VirtualClock` — the router, its
scheduler replicas, and the :class:`FaultPlan` are all keyed to the same
integer ticks, so a (trace, fault plan) pair replays identically every run.
The load-bearing assertions (DESIGN.md §9):

* **byte-identical ledger** — killing a replica at any tick (hypothesis-
  drawn kill times x bursty/uniform/adversarial traces) leaves the global
  token ledger byte-identical to an unkilled single-replica run: zero lost,
  zero duplicated tokens;
* **no session served twice** — every placement interval before the last
  ended with a kill, the last with completion (`assert_exactly_once`);
* **FIFO preserved across requeue** — a dead replica's sessions re-enter
  the router queue ahead of unrouted work, in their original relative
  order (routing sequence numbers are strictly increasing in the original
  admission order);
* **admission-reject + delayed-store faults** compose with kills without
  breaking parity, and the flock'd store stays loadable throughout.
"""
import os
import tempfile

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduced
from repro.core.engine import PlanRegistry
from repro.core.template import default_template
from repro.launch.router import Assignment, ReplicaRouter, TokenLedger
from repro.launch.scheduler import (
    Request,
    SchedulerConfig,
    ServeScheduler,
    VirtualClock,
    request_from_snapshot,
    session_snapshot,
)
from repro.models import transformer as T
from repro.runtime.failover import FaultPlan

# Resume headroom: prompts <= 16 and max_new <= 6 keep every resumed
# session's re-prefill (prompt + generated <= 22) inside the 24 top rung.
LADDER = (8, 16, 24)
MAX_NEW = 6
TRACE_KINDS = ("bursty", "uniform", "adversarial")


_SETUP = None


def get_setup():
    """Lazy module-wide (cfg, params, tpl) — shared with the property test,
    which cannot take fixtures (it must run under the conftest shim too)."""
    global _SETUP
    if _SETUP is None:
        cfg = reduced(get_config("qwen2-0.5b"))
        tpl = default_template()
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        _SETUP = (cfg, params, tpl)
    return _SETUP


@pytest.fixture(scope="module")
def setup():
    return get_setup()


def make_trace(kind: str, base_rid: int):
    """A deterministic trace; fresh Request objects per call (the scheduler
    mutates them) with *stable rids* so runs are comparable by session."""
    rng = np.random.default_rng(11)
    if kind == "bursty":
        lens, arrivals = [5, 9, 3, 15, 8, 16, 2, 11], [0.0] * 8
    elif kind == "uniform":
        lens = [6, 12, 4, 16, 7, 10, 3, 14]
        arrivals = [2.0 * i for i in range(len(lens))]
    else:  # adversarial: big prompts burst first, small ones starve behind
        lens = [16, 16, 15, 2, 3, 2, 16, 2]
        arrivals = [0.0, 0.0, 0.0, 1.0, 1.0, 2.0, 2.0, 3.0]
    out = []
    for i, (n, at) in enumerate(zip(lens, arrivals)):
        prompt = tuple(int(t) for t in rng.integers(0, 96, size=n))
        out.append(Request(prompt=prompt, max_new=3 + (i % (MAX_NEW - 2)),
                           arrival=at, rid=base_rid + i))
    return out


def make_router(setup, n_replicas, **kw):
    cfg, params, tpl = setup

    def make_sched(rid, clock):
        return ServeScheduler(
            cfg, params, tpl=tpl, clock=clock,
            sched=SchedulerConfig(ladder=LADDER, slots=3,
                                  max_new_limit=MAX_NEW),
        )

    return ReplicaRouter(make_sched, n_replicas, clock=VirtualClock(), **kw)


_REFERENCE: dict = {}


def reference_ledger(setup, kind: str) -> dict:
    """The unkilled single-replica ledger, keyed by trace position."""
    if kind not in _REFERENCE:
        router = make_router(setup, 1)
        trace = make_trace(kind, base_rid=10_000)
        router.run(trace)
        assert len(router.completed) == len(trace)
        led = router.ledger.as_dict()
        _REFERENCE[kind] = {i: led[r.rid] for i, r in enumerate(trace)}
    return _REFERENCE[kind]


def by_position(router, trace) -> dict:
    led = router.ledger.as_dict()
    return {i: led.get(r.rid) for i, r in enumerate(trace)}


# ---------------------------------------------------------------------------
# the ledger itself (no model needed)
# ---------------------------------------------------------------------------


def test_ledger_exactly_once_protocol():
    led = TokenLedger()
    assert led.record(1, 0, 10) and led.record(1, 1, 11)
    # a resumed replica regenerating its prefix is suppressed, not stored
    assert not led.record(1, 0, 10)
    assert led.duplicates_suppressed == 1
    assert led.tokens(1) == [10, 11]
    with pytest.raises(RuntimeError, match="divergence"):
        led.record(1, 1, 99)  # regenerated token must match byte-for-byte
    with pytest.raises(RuntimeError, match="gap"):
        led.record(1, 5, 12)  # skipping positions means tokens were lost


def test_session_snapshot_round_trip():
    req = Request(prompt=(3, 1, 4), max_new=5, eos_id=7, arrival=2.0)
    req.generated = [9, 2]
    back = request_from_snapshot(session_snapshot(req))
    assert (back.rid, back.prompt, back.generated) == (req.rid, req.prompt, [9, 2])
    assert back.remaining == 3 and back.state == "new"


# ---------------------------------------------------------------------------
# multi-replica parity without faults
# ---------------------------------------------------------------------------


def test_two_replicas_match_single_replica(setup):
    ref = reference_ledger(setup, "bursty")
    router = make_router(setup, 2)
    trace = make_trace("bursty", base_rid=11_000)
    router.run(trace)
    assert by_position(router, trace) == ref
    router.assert_exactly_once()
    # work actually spread across replicas
    used = {a[0].replica for a in router.assignments.values()}
    assert used == {0, 1}
    assert router.counters["killed"] == 0


# ---------------------------------------------------------------------------
# kill-at-tick: byte-identical ledger, exactly-once, FIFO across requeue
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", TRACE_KINDS)
@pytest.mark.parametrize("kill_tick", [1, 4])
def test_kill_at_tick_byte_identical(setup, tmp_path, kind, kill_tick):
    ref = reference_ledger(setup, kind)
    router = make_router(
        setup, 2,
        fault_plan=FaultPlan(kills=((kill_tick, 0),)),
        checkpoint_dir=str(tmp_path), checkpoint_every=2,
    )
    trace = make_trace(kind, base_rid=12_000 + 100 * kill_tick)
    router.run(trace)

    # zero lost, zero duplicated: byte-identical to the unkilled run
    assert by_position(router, trace) == ref
    router.verify_against({r.rid: ref[i] for i, r in enumerate(trace)})
    router.assert_exactly_once()
    assert router.counters["killed"] == 1
    assert router.counters["restarted"] == 1

    # FIFO preserved across the requeue: the killed sessions' replacement
    # placements happen in the same relative order as their original ones
    killed = [(recs[0].seq, recs[1].seq)
              for recs in router.assignments.values() if len(recs) > 1]
    if killed:
        killed.sort()
        reseq = [second for _, second in killed]
        assert reseq == sorted(reseq), (
            "requeued sessions were re-routed out of their original order")
        assert router.counters["requeued_sessions"] == len(killed)


def test_kill_with_checkpoint_restores_generated(setup, tmp_path):
    """A mid-stream kill restores generated-so-far tokens from the replica's
    checkpoint; regenerated overlap is suppressed as verified duplicates."""
    ref = reference_ledger(setup, "bursty")
    router = make_router(
        setup, 2, fault_plan=FaultPlan(kills=((4, 0),)),
        checkpoint_dir=str(tmp_path), checkpoint_every=1,
    )
    trace = make_trace("bursty", base_rid=13_000)
    router.run(trace)
    assert by_position(router, trace) == ref
    c = router.counters
    assert c["restored_sessions"] > 0, "kill at tick 4 must hit live sessions"
    assert c["restored_tokens"] > 0
    # checkpoint_every=1 means the ledger never outran the checkpoint by
    # more than one tick's tokens; any overlap had to verify byte-equal
    assert router.ledger.duplicates_suppressed >= 0
    line = router.stats_line()
    assert "restored=" in line and "requeued=" in line and "r0[" in line


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_kill_parity_property(seed):
    """Hypothesis-drawn kill times x traces x checkpoint cadence: the ledger
    is always byte-identical to the unkilled run (seed -> case, following
    the test_conv_routes idiom so the conftest shim can drive it too)."""
    rng = np.random.default_rng(seed)
    kill_tick = int(rng.integers(1, 10))
    kind = TRACE_KINDS[int(rng.integers(0, len(TRACE_KINDS)))]
    checkpoint_every = int(rng.integers(1, 4))
    victim = int(rng.integers(0, 2))
    setup = get_setup()
    ref = reference_ledger(setup, kind)
    with tempfile.TemporaryDirectory() as ckpt:
        router = make_router(
            setup, 2, fault_plan=FaultPlan(kills=((kill_tick, victim),)),
            checkpoint_dir=ckpt, checkpoint_every=checkpoint_every,
        )
        trace = make_trace(kind, base_rid=20_000)
        router.run(trace)
    assert by_position(router, trace) == ref
    router.assert_exactly_once()


# ---------------------------------------------------------------------------
# the other fault species
# ---------------------------------------------------------------------------


def test_admission_reject_window_routes_elsewhere(setup):
    ref = reference_ledger(setup, "bursty")
    router = make_router(
        setup, 2, fault_plan=FaultPlan(reject_windows=((0, 0, 3),)))
    trace = make_trace("bursty", base_rid=14_000)
    router.run(trace)
    assert by_position(router, trace) == ref
    for recs in router.assignments.values():
        for rec in recs:
            assert not (rec.replica == 0 and rec.start_tick <= 3), (
                f"placement on replica 0 during its reject window: {rec}")


def test_delayed_store_save_lands_late_but_complete(setup, tmp_path):
    store = str(tmp_path / "plan_store.json")
    router = make_router(
        setup, 2,
        fault_plan=FaultPlan(delayed_saves=((0, 2, 3),)),
        store_path=store, store_save_every=2,
    )
    trace = make_trace("bursty", base_rid=15_000)
    router.run(trace)
    log = router.store_save_log
    assert log, "periodic store saves must have fired"
    delayed = [e for e in log if e["replica"] == 0 and e["due"] == 2]
    assert delayed and delayed[0]["actual"] == 5, delayed
    on_time = [e for e in log if e["replica"] == 1 and e["due"] == 2]
    assert on_time and on_time[0]["actual"] == 2, on_time
    # the store survived every (possibly interleaved) merge write
    assert os.path.exists(store)
    PlanRegistry().load(store)  # raises PlanStoreError if torn


def test_faults_compose(setup, tmp_path):
    """Kill + reject window + delayed save in one replay: parity holds."""
    ref = reference_ledger(setup, "adversarial")
    router = make_router(
        setup, 3,
        fault_plan=FaultPlan(
            kills=((3, 1),),
            reject_windows=((2, 0, 2),),
            delayed_saves=((0, 2, 2),),
        ),
        checkpoint_dir=str(tmp_path / "ckpt"), checkpoint_every=2,
        store_path=str(tmp_path / "store.json"), store_save_every=2,
    )
    trace = make_trace("adversarial", base_rid=16_000)
    router.run(trace)
    assert by_position(router, trace) == ref
    router.assert_exactly_once()
    assert router.counters["killed"] == 1


# ---------------------------------------------------------------------------
# the latent submit() double-count (resumed sessions)
# ---------------------------------------------------------------------------


def test_submit_budgets_resumed_sessions_by_remaining(setup):
    """A restored session's generated tokens are part of seq_len; admission
    must budget remaining (not max_new) or every near-budget resume would
    be spuriously rejected — the exact path a failover exercises."""
    cfg, params, tpl = setup
    sched = ServeScheduler(
        cfg, params, tpl=tpl, clock=VirtualClock(),
        sched=SchedulerConfig(ladder=LADDER, slots=3, max_new_limit=MAX_NEW),
    )
    # cache_len = 24 + 6 = 30; seq_len 20 + max_new 6 > 30 would wrongly
    # reject, but remaining = 2 fits: 20 + 2 <= 30
    req = Request(prompt=tuple(range(16)), max_new=MAX_NEW)
    req.generated = [1, 2, 3, 4]
    assert sched.cache_len == 30 and req.seq_len == 20 and req.remaining == 2
    assert sched.submit(req), "resumed session must be admitted by remaining"
    assert sched.counters["resumed_sessions"] == 1
    # a spent session has nothing left to generate
    done = Request(prompt=(1, 2), max_new=2)
    done.generated = [5, 6]
    assert not sched.submit(done)
