"""Fixed-point residency acceptance suite (DESIGN.md §8).

The load-bearing assertions of the QTensor layer:

* **The island law** — a traced q16 transformer step performs *zero* float
  round-trips between consecutive linear ops: the engine's quantize /
  dequantize counters equal exactly the designated-island counts
  (`transformer.q16_island_counts`: softmax/RoPE/activation islands + the
  head boundary), for both prefill and decode.
* **Quantize-once weights** — the qparam cache builds one tree per
  (params, policy) per engine; every later generate()/scheduler call is a
  cache hit (`qparam_builds == 1`).
* **Grid-resident CNN** — the whole LeNet forward costs one quantize (the
  input) and one dequantize (the classifier read-out); maxpool runs on the
  int16 raws.
* **int16 KV cache** — prefill/decode caches store int16 raws under the
  quantized policy, and the grid path stays bit-consistent with the
  mixed-format oracle.
* **Unsupported combos fail loudly** — q16 policy on a float backend, or on
  families whose mixers cannot run on the grid, raise ValueError.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.engine import Engine, validate_policy
from repro.core.quantization import (
    NumericsPolicy,
    Q2_14,
    QFormat,
    QTensor,
    qtensor_matmul_ref,
    quantize_qtensor,
)
from repro.core.template import TemplateConfig, default_template
from repro.models import transformer as T
from repro.models.cnn import (
    LENET,
    calibrate_cnn_policy,
    cnn_forward,
    init_cnn,
    quantize_cnn_params,
)


@pytest.fixture(scope="module")
def q16_setup():
    cfg = reduced(get_config("qwen2-0.5b"))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tpl = default_template("q16")
    cal = jax.random.randint(jax.random.PRNGKey(9), (2, 16), 0, cfg.vocab)
    policy = T.calibrate_policy(tpl, cfg, params, cal)
    qp = T.quantize_params(tpl, cfg, params, policy)
    return cfg, params, tpl, policy, qp


def _reset_island_counters(eng):
    eng.counters["quantize_calls"] = 0
    eng.counters["dequantize_calls"] = 0


# ---------------------------------------------------------------------------
# the island law (acceptance criterion)
# ---------------------------------------------------------------------------


def test_decode_step_obeys_island_law(q16_setup):
    """One q16 decode step: counter ticks == designated float islands, no
    more — any extra tick is an un-designated float round-trip between
    consecutive linear ops."""
    cfg, params, tpl, policy, qp = q16_setup
    _, cache = T.prefill(tpl, cfg, qp, jnp.zeros((2, 8), jnp.int32),
                         cache_len=16, policy=policy)
    eng = tpl.engine
    _reset_island_counters(eng)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, _ = T.decode_step(tpl, cfg, qp, tok, jnp.int32(8), cache,
                              policy=policy)
    law = T.q16_island_counts(cfg, mode="decode")
    assert eng.counters["quantize_calls"] == law["quantize"]
    assert eng.counters["dequantize_calls"] == law["dequantize"]
    assert logits.dtype == jnp.float32  # the head read-out is the exit


def test_prefill_obeys_island_law(q16_setup):
    cfg, params, tpl, policy, qp = q16_setup
    eng = tpl.engine
    _reset_island_counters(eng)
    T.prefill(tpl, cfg, qp, jnp.zeros((1, 8), jnp.int32), cache_len=16,
              policy=policy)
    law = T.q16_island_counts(cfg, mode="prefill")
    assert eng.counters["quantize_calls"] == law["quantize"]
    assert eng.counters["dequantize_calls"] == law["dequantize"]


def test_island_law_scales_with_designated_islands():
    """The law itself is sane: swiglu adds one dequant over gelu; RoPE adds
    one quantize+dequant pair to decode."""
    import dataclasses

    cfg = reduced(get_config("qwen2-0.5b"))
    sw = T.q16_island_counts(cfg, mode="decode")
    ge = T.q16_island_counts(dataclasses.replace(cfg, act="gelu"), mode="decode")
    assert sw["dequantize"] == ge["dequantize"] + 1
    nr = T.q16_island_counts(dataclasses.replace(cfg, use_rope=False),
                             mode="decode")
    assert sw["quantize"] == nr["quantize"] + 1
    assert sw["dequantize"] == nr["dequantize"] + 1


# ---------------------------------------------------------------------------
# quantize-once weights
# ---------------------------------------------------------------------------


def test_weights_quantized_exactly_once(q16_setup):
    cfg, params, tpl, policy, qp = q16_setup
    eng = tpl.engine
    builds0 = eng.counters["qparam_builds"]
    hits0 = eng.counters["qparam_cache_hits"]
    qp2 = T.quantize_params(tpl, cfg, params, policy)
    qp3 = T.quantize_params(tpl, cfg, params, policy)
    assert qp2 is qp and qp3 is qp
    assert eng.counters["qparam_builds"] == builds0  # no rebuild
    assert eng.counters["qparam_cache_hits"] == hits0 + 2


def test_generate_reuses_qparams(q16_setup):
    from repro.launch.serve import generate

    cfg, params, tpl, policy, qp = q16_setup
    eng = tpl.engine
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 6), 0, cfg.vocab)
    generate(cfg, params, toks, gen=3, tpl=tpl, policy=policy)
    builds = eng.counters["qparam_builds"]
    weights = eng.counters["weights_quantized"]
    out1 = generate(cfg, params, toks, gen=3, tpl=tpl, policy=policy)
    out2 = generate(cfg, params, toks, gen=3, tpl=tpl, policy=policy)
    assert eng.counters["qparam_builds"] == builds, "generate() re-quantized"
    assert eng.counters["weights_quantized"] == weights
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_qparam_tree_shapes(q16_setup):
    cfg, params, tpl, policy, qp = q16_setup
    blk = qp["blocks"][0]
    assert isinstance(blk["attn"]["wq"]["w"], QTensor)
    assert isinstance(blk["ffn"]["down"]["w"], QTensor)
    assert blk["attn"]["wq"]["w"].dtype == jnp.int16
    # norms and the embedding lookup table stay float
    assert blk["norm"]["scale"].dtype == jnp.float32
    assert qp["embed"].dtype == jnp.float32
    # tied embeddings still get an int16 head copy
    assert isinstance(qp["lm_head"]["w"], QTensor)
    assert qp["lm_head"]["w"].shape == (cfg.d_model, cfg.vocab)


# ---------------------------------------------------------------------------
# int16 cache + numerics
# ---------------------------------------------------------------------------


def test_prefill_cache_is_int16(q16_setup):
    cfg, params, tpl, policy, qp = q16_setup
    _, cache = T.prefill(tpl, cfg, qp, jnp.zeros((1, 8), jnp.int32),
                         cache_len=16, policy=policy)
    c = cache["blocks"][0]["attn"]
    assert c["k"].dtype == jnp.int16 and c["v"].dtype == jnp.int16
    assert c["pos"].dtype == jnp.int32


def test_q16_decode_tracks_float_path(q16_setup):
    """Drift vs the float backend stays at quantization-noise scale and the
    greedy argmax matches on the fixed seed set."""
    cfg, params, tpl, policy, qp = q16_setup
    tpl_f = default_template()
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0, cfg.vocab)
    lf, _ = T.forward(tpl_f, cfg, params, toks, mode="fwd")
    lq, _ = T.forward(tpl, cfg, qp, toks, mode="fwd", policy=policy)
    assert float(jnp.abs(lf - lq).mean()) < 5e-3
    assert float((jnp.argmax(lf, -1) == jnp.argmax(lq, -1)).mean()) >= 0.99


def test_grid_matmul_matches_mixed_format_oracle():
    """Engine grid-resident GEMM == qtensor_matmul_ref bit-for-bit, formats
    mixed (calibrated weight grid != activation grid), bias + relu fused."""
    eng = Engine(TemplateConfig(backend="q16", interpret=True))
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (6, 16)) * 0.4
    w = jax.random.normal(jax.random.fold_in(key, 1), (16, 8)) * 0.05
    b = jax.random.normal(jax.random.fold_in(key, 2), (8,)) * 0.1
    xq = quantize_qtensor(x, QFormat(4, 12))
    wq = quantize_qtensor(w)  # per-tensor calibrated (finer than Q4.12)
    bq = quantize_qtensor(b, QFormat(4, 12))
    assert wq.fmt.frac_bits > 12
    got = eng.matmul(xq, wq, bias=bq, relu=True)
    want = qtensor_matmul_ref(xq, wq, xq.fmt, bias=bq, relu=True)
    assert got.fmt == xq.fmt  # output follows the input's grid
    np.testing.assert_array_equal(np.asarray(got.raw), np.asarray(want.raw))


def test_wide_head_readout_is_exact():
    """wide=True returns the int32 accumulator exactly descaled — no
    saturation even when the true product leaves the int16 grid's range."""
    eng = Engine(TemplateConfig(backend="q16", interpret=True))
    # true value 4 * 0.81 = 3.24 > 2 (outside Q2.14's range) while the int32
    # accumulator stays inside 2^31 (the documented wraparound bound)
    xq = quantize_qtensor(jnp.full((1, 4), 0.9), Q2_14)
    wq = quantize_qtensor(jnp.full((4, 2), 0.9), Q2_14)
    out = eng.matmul(xq, wq, wide=True)
    acc = np.asarray(xq.raw, np.int64) @ np.asarray(wq.raw, np.int64)
    want = (acc.astype(np.float32) * np.float32(2.0 ** -28)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(out), want)
    assert float(out[0, 0]) == pytest.approx(3.24, rel=1e-3)


# ---------------------------------------------------------------------------
# grid-resident CNN
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def lenet_setup():
    params = init_cnn(jax.random.PRNGKey(0), LENET, scale=0.4)
    tpl = default_template("q16")
    img = jax.random.uniform(jax.random.PRNGKey(2), (4, 32, 32, 1)) * 2 - 1
    policy = calibrate_cnn_policy(tpl, LENET, params, img)
    qp = quantize_cnn_params(tpl, LENET, params, policy)
    return params, tpl, policy, qp


def test_lenet_forward_one_quant_one_dequant(lenet_setup):
    params, tpl, policy, qp = lenet_setup
    eng = tpl.engine
    img = jax.random.uniform(jax.random.PRNGKey(5), (3, 32, 32, 1)) * 2 - 1
    _reset_island_counters(eng)
    logits = cnn_forward(tpl, LENET, qp, img, policy=policy)
    assert eng.counters["quantize_calls"] == 1, "only the input quantizes"
    assert eng.counters["dequantize_calls"] == 1, "only the classifier dequantizes"
    assert logits.dtype == jnp.float32 and logits.shape == (3, 10)


def test_lenet_grid_path_tracks_float(lenet_setup):
    params, tpl, policy, qp = lenet_setup
    tpl_f = default_template()
    img = jax.random.uniform(jax.random.PRNGKey(6), (8, 32, 32, 1)) * 2 - 1
    lf = cnn_forward(tpl_f, LENET, params, img)
    lq = cnn_forward(tpl, LENET, qp, img, policy=policy)
    assert float(jnp.abs(lf - lq).max()) < 1e-2
    assert float((jnp.argmax(lf, -1) == jnp.argmax(lq, -1)).mean()) >= 0.99


def test_maxpool_on_raw_matches_pool_of_dequant():
    from repro.models.cnn import _maxpool

    q = quantize_qtensor(
        jax.random.normal(jax.random.PRNGKey(0), (1, 8, 8, 4)), Q2_14
    )
    pooled = _maxpool(q, 2)
    assert isinstance(pooled, QTensor) and pooled.dtype == jnp.int16
    np.testing.assert_array_equal(
        np.asarray(pooled.dequantize()),
        np.asarray(_maxpool(q.dequantize(), 2)),
    )


# ---------------------------------------------------------------------------
# unsupported combos fail loudly
# ---------------------------------------------------------------------------


def test_q16_policy_requires_q16_backend():
    cfg = reduced(get_config("qwen2-0.5b"))
    with pytest.raises(ValueError, match="requires the 'q16' backend"):
        validate_policy(TemplateConfig(backend="xla"), NumericsPolicy("q16"))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="requires the 'q16' backend"):
        T.quantize_params(default_template("pallas"), cfg, params,
                          NumericsPolicy("q16"))


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "recurrentgemma-9b",
                                  "whisper-medium", "granite-moe-3b-a800m"])
def test_q16_policy_rejects_non_grid_families(arch):
    cfg = reduced(get_config(arch))
    tpl = default_template("q16")
    with pytest.raises(ValueError):
        T.quantize_params(tpl, cfg, {"blocks": (), "tail": ()},
                          NumericsPolicy("q16"))


def test_float_policy_is_passthrough():
    cfg = reduced(get_config("qwen2-0.5b"))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    out = T.quantize_params(default_template(), cfg, params,
                            NumericsPolicy("float"))
    assert out is params
