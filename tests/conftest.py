"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see ONE device
(the dry-run sets its own 512-device flag in its own process)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


def assert_close(a, b, atol=1e-4, rtol=1e-4, msg=""):
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32),
        atol=atol, rtol=rtol, err_msg=msg,
    )
