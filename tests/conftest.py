"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see ONE device
(the dry-run sets its own 512-device flag in its own process).

Also provides a minimal ``hypothesis`` shim when the real package is absent
(this container has no network), so the property tests still collect and run
with deterministic boundary + pseudo-random examples.  Install the real
thing via requirements-dev.txt to get full shrinking/fuzzing behavior.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# hypothesis shim (only when hypothesis is not installed)
# ---------------------------------------------------------------------------

try:  # pragma: no cover - depends on environment
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import functools as _functools
    import random as _random
    import sys as _sys
    import types as _types

    class _Strategy:
        """Draws deterministic boundary values first, then seeded randoms."""

        def __init__(self, draw):
            self._draw = draw

    def _integers(min_value=0, max_value=2**31 - 1):
        bounds = (min_value, max_value, min_value + (max_value - min_value) // 2)

        def draw(rng, i):
            if i < len(bounds):
                return bounds[i]
            return rng.randint(min_value, max_value)

        return _Strategy(draw)

    def _floats(min_value=0.0, max_value=1.0, allow_nan=False, **_kw):
        bounds = (float(min_value), float(max_value), 0.5 * (min_value + max_value))

        def draw(rng, i):
            if i < len(bounds):
                return bounds[i]
            return rng.uniform(min_value, max_value)

        return _Strategy(draw)

    def _sampled_from(elements):
        choices = list(elements)

        def draw(rng, i):
            if i < len(choices):
                return choices[i]
            return rng.choice(choices)

        return _Strategy(draw)

    def _lists(elements, min_size=0, max_size=10):
        def draw(rng, i):
            if i == 0:
                size = min_size
            elif i == 1:
                size = max_size
            else:
                size = rng.randint(min_size, max_size)
            # offset the element draw index so list contents vary per example
            return [elements._draw(rng, i + j + 1) for j in range(size)]

        return _Strategy(draw)

    def _given(*strategies, **kw):
        assert not kw, "hypothesis shim supports positional strategies only"

        def deco(fn):
            @_functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(
                    wrapper, "_shim_max_examples",
                    getattr(fn, "_shim_max_examples", 10),
                )
                rng = _random.Random(0)
                for i in range(n):
                    ex = tuple(s._draw(rng, i) for s in strategies)
                    fn(*args, *ex, **kwargs)

            # pytest must not introspect the strategy params as fixtures
            del wrapper.__wrapped__
            wrapper._shim_given = True
            return wrapper

        return deco

    def _settings(max_examples=10, deadline=None, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn

        return deco

    _h = _types.ModuleType("hypothesis")
    _st = _types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.lists = _lists
    _st.sampled_from = _sampled_from
    _h.given = _given
    _h.settings = _settings
    _h.strategies = _st
    _sys.modules["hypothesis"] = _h
    _sys.modules["hypothesis.strategies"] = _st


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


def assert_close(a, b, atol=1e-4, rtol=1e-4, msg=""):
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32),
        atol=atol, rtol=rtol, err_msg=msg,
    )
