"""Sharded batched decode: 2-way tensor-parallel == single-device, bitwise.

The decode TP design (DESIGN.md §9) is column-parallel only — every matmul
shards its *output* dim over "model", activations are gathered back to
replicated at the existing constrain seams, and the per-slot KV cache shards
over "data" — precisely so the sharded computation performs the same
reductions in the same order as the unsharded one.  That makes bitwise
equality a testable contract (not a tolerance), in float AND in q16 (whose
integer accumulation is exact regardless of split).

Runs in a subprocess (needs ``--xla_force_host_platform_device_count=8``
before jax imports, like test_plan_registry's mesh test).  Each mode also
round-trips the plan store: the cold mesh run saves it, a warm restart
(fresh caches, store re-loaded) must re-plan with **zero** DSE misses per
shard and reproduce the same tokens.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.pop("REPRO_PLAN_STORE", None)
    import json, tempfile
    import jax
    import numpy as np
    from repro.configs import get_config, reduced
    from repro.core.engine import (load_plan_store, reset_plan_caches,
                                   save_plan_store)
    from repro.core.template import default_template
    from repro.launch.mesh import make_test_mesh
    from repro.launch.scheduler import (Request, SchedulerConfig,
                                        ServeScheduler, VirtualClock,
                                        replay_trace)
    from repro.models import transformer as T

    MODE = os.environ["SHARD_TEST_MODE"]
    cfg = reduced(get_config("qwen2-0.5b"))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    LADDER = (8, 16)
    mesh = make_test_mesh()  # (2, 2) over ("data", "model") on 8 host devices

    tpl = default_template(MODE)
    policy = None
    if MODE == "q16":
        cal = jax.random.randint(jax.random.PRNGKey(9), (2, 16), 0, cfg.vocab)
        policy = T.calibrate_policy(tpl, cfg, params, cal)

    def trace():
        rng = np.random.default_rng(7)
        lens = [5, 9, 3, 15, 8, 16, 2]
        return [Request(prompt=tuple(int(t) for t in rng.integers(0, 64, n)),
                        max_new=4, arrival=0.0, rid=3000 + i)
                for i, n in enumerate(lens)]

    def run(mesh_arg):
        s = ServeScheduler(
            cfg, params, tpl=tpl, clock=VirtualClock(), policy=policy,
            sched=SchedulerConfig(ladder=LADDER, slots=4, max_new_limit=8),
            mesh=mesh_arg)
        warm_start = s.registry.misses
        s.warmup()
        warmup_misses = s.registry.misses - warm_start
        replay_start = s.registry.misses
        replay_trace(s, trace())
        toks = {r.rid: list(r.generated) for r in s.results.values()}
        return toks, s.registry.misses - replay_start, warmup_misses, s

    single, single_replay_misses, _, _ = run(None)
    sharded, shard_replay_misses, cold_mesh_warmup_misses, s2 = run(mesh)

    # warm restart: persist the store, drop every in-process cache, reload,
    # and re-run sharded — warmup must plan from the store alone
    store = tempfile.mktemp(suffix=".json")
    save_plan_store(store)
    reset_plan_caches()
    n_loaded = load_plan_store(store)
    warm, warm_replay_misses, warm_mesh_warmup_misses, s3 = run(mesh)

    print(json.dumps({
        "mode": MODE,
        "tokens_equal": single == sharded,
        "warm_tokens_equal": single == warm,
        "sessions": len(single),
        "total_tokens": sum(len(v) for v in single.values()),
        "single_replay_misses": single_replay_misses,
        "shard_replay_misses": shard_replay_misses,
        "cold_mesh_warmup_misses": cold_mesh_warmup_misses,
        "warm_mesh_warmup_misses": warm_mesh_warmup_misses,
        "warm_warmup_shard_misses": int(s3.counters["warmup_shard_misses"]),
        "warm_replay_misses": warm_replay_misses,
        "store_entries": n_loaded,
    }))
    """
)


@pytest.mark.parametrize("mode", ["pallas", "q16"])
def test_sharded_decode_bitwise_and_warm_store(mode):
    env = dict(os.environ, PYTHONPATH="src", SHARD_TEST_MODE=mode)
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        env=env, timeout=600,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert out.returncode == 0, f"sharded decode subprocess failed:\n{out.stderr[-4000:]}"
    rec = json.loads(out.stdout.strip().splitlines()[-1])

    # the differential contract: bitwise-identical token streams
    assert rec["tokens_equal"], rec
    assert rec["sessions"] == 7 and rec["total_tokens"] > 0

    # a warmed scheduler never searches during replay, sharded or not
    assert rec["single_replay_misses"] == 0, rec
    assert rec["shard_replay_misses"] == 0, rec

    # cold mesh warmup *does* perform shard-local DSE: the meshless run
    # already fully warmed the registry at global shapes, so any miss during
    # the mesh scheduler's warmup is a per-shard local plan.  (Since the
    # ad-hoc dispatch mesh fix, locals are planned inline at trace time —
    # counted here — and the explicit localize pass is a redundancy net
    # that may legitimately find nothing left to plan.)
    assert rec["cold_mesh_warmup_misses"] > 0, rec
    # ...and a store round-trip makes every one of them a hit: zero DSE
    # misses anywhere in warmup on warm restart, with identical tokens
    assert rec["warm_mesh_warmup_misses"] == 0, rec
    assert rec["warm_warmup_shard_misses"] == 0, rec
    assert rec["warm_replay_misses"] == 0, rec
    assert rec["warm_tokens_equal"], rec
    assert rec["store_entries"] > 0
