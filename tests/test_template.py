"""The unified compute unit: backend equivalence + tiling legality/DSE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.dse import default_block_for, explore_tpu_block
from repro.core.template import TemplateConfig, Template, default_template
from repro.core.tiling import MatmulBlock, TPU_V5E, clamp_block

KEY = jax.random.PRNGKey(7)


def test_backends_agree():
    x = jax.random.normal(KEY, (48, 100)) * 0.1
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (100, 36)) * 0.1
    ref = default_template("xla").matmul(x, w)
    pal = default_template("pallas").matmul(x, w)
    q16 = default_template("q16").matmul(x, w)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref), atol=1e-4, rtol=1e-4)
    # fixed point: bounded quantization error
    assert float(jnp.abs(q16 - ref).max()) < 0.01


def test_leading_dims_flattened():
    x = jax.random.normal(KEY, (2, 3, 5, 16))
    w = jax.random.normal(jax.random.fold_in(KEY, 2), (16, 8))
    tpl = default_template("xla")
    out = tpl.matmul(x, w)
    assert out.shape == (2, 3, 5, 8)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(x.reshape(-1, 16) @ w).reshape(2, 3, 5, 8),
        atol=1e-4, rtol=1e-4,
    )


def test_conv2d_matches_lax():
    x = jax.random.normal(KEY, (2, 10, 10, 3))
    w = jax.random.normal(jax.random.fold_in(KEY, 3), (3, 3, 3, 8)) * 0.2
    tpl = default_template("xla")
    out = tpl.conv2d(x, w, stride=1, padding=1)
    want = jax.lax.conv_general_dilated(
        x, w, (1, 1), [(1, 1), (1, 1)], dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# tiling properties (hypothesis)
# ---------------------------------------------------------------------------


@given(
    st.integers(min_value=1, max_value=4096),
    st.integers(min_value=1, max_value=4096),
    st.integers(min_value=1, max_value=4096),
)
@settings(max_examples=100, deadline=None)
def test_clamp_block_always_legal_alignment(m, n, k):
    b = clamp_block(m, n, k, MatmulBlock(512, 512, 512))
    assert b.bm % TPU_V5E.sublane == 0
    assert b.bn % TPU_V5E.lane == 0
    assert b.bk % TPU_V5E.lane == 0
    assert b.vmem_bytes() <= MatmulBlock(512, 512, 512).vmem_bytes()


@given(
    st.integers(min_value=128, max_value=8192),
    st.integers(min_value=128, max_value=8192),
    st.integers(min_value=128, max_value=8192),
)
@settings(max_examples=30, deadline=None)
def test_dse_block_fits_vmem(m, n, k):
    blk = default_block_for(m, n, k)
    assert blk.vmem_bytes() <= TPU_V5E.vmem_bytes
    assert blk.aligned()


def test_dse_prefers_higher_intensity():
    ranked = explore_tpu_block(4096, 4096, 4096)
    assert len(ranked) >= 2
    scores = [s for _, s in ranked]
    assert scores == sorted(scores, reverse=True)
    best = ranked[0][0]
    # the best block for a big square GEMM should be MXU-saturating
    assert best.bm >= 256 and best.bn >= 256


def test_mxu_efficiency_penalizes_misalignment():
    good = MatmulBlock(256, 256, 256)
    assert good.mxu_efficiency() == 1.0
