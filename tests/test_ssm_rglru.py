"""Sequence-mixer correctness: SSD chunked vs recurrence; RG-LRU scan vs loop."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import all_configs, reduced
from repro.core.template import default_template
from repro.models import rglru, ssm

TPL = default_template()
CFG = reduced(all_configs()["mamba2-1.3b"])
RCFG = reduced(all_configs()["recurrentgemma-9b"])


@pytest.mark.parametrize("chunk", [4, 8, 32])
@pytest.mark.parametrize("s", [16, 24, 32])
def test_ssd_chunked_matches_recurrence(chunk, s):
    """Chunk size must not change the result (incl. s % chunk != 0)."""
    b, h, p, n = 2, 4, 8, 16
    key = jax.random.PRNGKey(chunk * 100 + s)
    x = jax.random.normal(key, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (h,)) * 0.3)
    B = jax.random.normal(jax.random.fold_in(key, 3), (b, s, h, n)) * 0.3
    C = jax.random.normal(jax.random.fold_in(key, 4), (b, s, h, n)) * 0.3
    got, st_c = ssm.ssd_chunked(x, dt, A, B, C, chunk, return_state=True)
    want, st_r = ssm.ssd_reference(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st_c), np.asarray(st_r), atol=1e-4, rtol=1e-3)


def test_ssd_carried_state_continuation():
    """ssd(x1++x2) == ssd(x2 | final_state(x1)) — prefill continuation."""
    b, s, h, p, n = 1, 24, 2, 8, 8
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (h,)) * 0.3)
    B = jax.random.normal(jax.random.fold_in(key, 3), (b, s, h, n)) * 0.3
    C = jax.random.normal(jax.random.fold_in(key, 4), (b, s, h, n)) * 0.3
    full = ssm.ssd_chunked(x, dt, A, B, C, 8)
    cut = 16
    _, state1 = ssm.ssd_chunked(
        x[:, :cut], dt[:, :cut], A, B[:, :cut], C[:, :cut], 8, return_state=True
    )
    part2 = ssm.ssd_chunked(
        x[:, cut:], dt[:, cut:], A, B[:, cut:], C[:, cut:], 8, init_state=state1
    )
    np.testing.assert_allclose(
        np.asarray(part2), np.asarray(full[:, cut:]), atol=1e-4, rtol=1e-3
    )


def test_ssm_block_decode_parity():
    key = jax.random.PRNGKey(0)
    p = ssm.init_ssm(key, CFG)
    u = jax.random.normal(jax.random.fold_in(key, 1), (2, 17, CFG.d_model))
    y_full = ssm.ssm_block(TPL, CFG, p, u)
    _, cache = ssm.ssm_block(TPL, CFG, p, u[:, :-1], return_cache=True)
    y_dec, _ = ssm.ssm_decode_step(TPL, CFG, p, u[:, -1:], cache)
    np.testing.assert_allclose(
        np.asarray(y_dec[:, 0]), np.asarray(y_full[:, -1]), atol=1e-3, rtol=1e-3
    )


@given(st.integers(min_value=1, max_value=1000))
@settings(max_examples=25, deadline=None)
def test_rglru_scan_matches_loop(seed):
    key = jax.random.PRNGKey(seed)
    b, s, d = 2, 12, 8
    log_a = -jax.nn.softplus(jax.random.normal(key, (b, s, d)))
    gx = jax.random.normal(jax.random.fold_in(key, 1), (b, s, d))
    got = rglru._lru_scan(log_a, gx)
    want = rglru.rglru_reference(log_a, gx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5, rtol=1e-4)


def test_rglru_scan_with_initial_state():
    key = jax.random.PRNGKey(3)
    b, s, d = 1, 10, 4
    log_a = -jax.nn.softplus(jax.random.normal(key, (b, s, d)))
    gx = jax.random.normal(jax.random.fold_in(key, 1), (b, s, d))
    h0 = jax.random.normal(jax.random.fold_in(key, 2), (b, d))
    got = rglru._lru_scan(log_a, gx, h0)
    want = rglru.rglru_reference(log_a, gx, h0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5, rtol=1e-4)


def test_rglru_block_decode_parity():
    key = jax.random.PRNGKey(0)
    p = rglru.init_rglru(key, RCFG)
    u = jax.random.normal(jax.random.fold_in(key, 1), (2, 13, RCFG.d_model))
    y_full = rglru.rglru_block(TPL, RCFG, p, u)
    _, cache = rglru.rglru_block(TPL, RCFG, p, u[:, :-1], return_cache=True)
    y_dec, _ = rglru.rglru_decode_step(TPL, RCFG, p, u[:, -1:], cache)
    np.testing.assert_allclose(
        np.asarray(y_dec[:, 0]), np.asarray(y_full[:, -1]), atol=1e-3, rtol=1e-3
    )


def test_rglru_state_stays_bounded():
    """sqrt(1-a^2) normalization: |h| must stay O(|x|) over long sequences."""
    key = jax.random.PRNGKey(0)
    p = rglru.init_rglru(key, RCFG)
    u = jax.random.normal(jax.random.fold_in(key, 1), (1, 256, RCFG.d_model))
    _, cache = rglru.rglru_block(TPL, RCFG, p, u, return_cache=True)
    assert float(jnp.abs(cache["h"]).max()) < 50.0
