"""Fault-tolerance: atomic checkpoints, restart loops, stragglers, elasticity."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore, save
from repro.checkpoint.manager import _MANIFEST
from repro.runtime import (
    FailureInjector,
    HeartbeatMonitor,
    SimulatedFailure,
    detect_stragglers,
    run_with_restarts,
)
from repro.runtime.failover import plan_elastic_remesh


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (4, 8)),
        "nested": {"b": jnp.arange(6, dtype=jnp.int32), "c": jnp.float32(3.5)},
        "list": (jnp.ones((2, 2)), jnp.zeros((3,))),
    }


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 7, t, extra={"note": "x"})
    assert latest_step(str(tmp_path)) == 7
    back = restore(str(tmp_path), 7, jax.tree.map(jnp.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomicity_no_partial_checkpoints(tmp_path):
    """A crashed writer must leave no visible checkpoint."""
    t = _tree()
    import repro.checkpoint.manager as M

    orig = M.json.dump
    try:
        def boom(*a, **k):
            raise RuntimeError("crash mid-write")

        M.json.dump = boom
        with pytest.raises(RuntimeError):
            save(str(tmp_path), 3, t)
    finally:
        M.json.dump = orig
    assert latest_step(str(tmp_path)) is None
    # tmp dirs cleaned on the next successful save
    save(str(tmp_path), 4, t)
    leftovers = [d for d in os.listdir(tmp_path) if ".tmp" in d]
    assert leftovers == []
    assert latest_step(str(tmp_path)) == 4


def test_restore_shape_mismatch_rejected(tmp_path):
    save(str(tmp_path), 1, {"w": jnp.ones((4,))})
    with pytest.raises(ValueError):
        restore(str(tmp_path), 1, {"w": jnp.ones((5,))})


def test_manager_rotation(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        m.save(s, {"x": jnp.full((2,), s)})
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(tmp_path) if d.startswith("step_")
    )
    assert steps == [3, 4]
    assert m.latest() == 4


def test_elastic_restore_different_rules(tmp_path):
    """Save unsharded, restore with explicit (single-device) shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    t = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    save(str(tmp_path), 2, t)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P())}
    back = restore(str(tmp_path), 2, t, sh)
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(t["w"]))


# ---------------------------------------------------------------------------
# restart loop
# ---------------------------------------------------------------------------


def test_run_with_restarts_resumes_exactly(tmp_path):
    """Injected failures must replay from the checkpoint with identical data."""
    state = {"acc": 0.0, "step": 0}
    ckpt = {}
    seen = []

    def step_fn(step):
        inj.check(step)
        seen.append(step)
        state["acc"] += float(step)

    def save_fn(step):
        ckpt[step] = dict(state, step=step)

    def restore_fn():
        if not ckpt:
            state.update(acc=0.0, step=0)
            return 0
        s = max(ckpt)
        state.update({k: v for k, v in ckpt[s].items() if k != "step"})
        return s

    inj = FailureInjector(fail_at_steps=[7, 13])
    stats = run_with_restarts(
        num_steps=20, step_fn=step_fn, save_fn=save_fn, restore_fn=restore_fn,
        checkpoint_every=5, max_failures=3,
    )
    assert stats["failures"] == 2
    assert stats["restarts"] == [5, 10]
    # restore discards replayed partial work: the final state is EXACTLY the
    # no-failure result even though some steps executed twice
    assert state["acc"] == sum(range(20))
    assert sorted(set(seen)) == list(range(20))
    replayed = [s for s in set(seen) if seen.count(s) == 2]
    assert sorted(replayed) == [5, 6, 10, 11, 12]


def test_run_with_restarts_gives_up_after_max():
    inj = FailureInjector(fail_at_steps=[1])

    def step_fn(step):
        if step == 1:
            raise SimulatedFailure("always")

    with pytest.raises(SimulatedFailure):
        run_with_restarts(
            num_steps=5, step_fn=step_fn, save_fn=lambda s: None,
            restore_fn=lambda: 0, max_failures=2,
        )


# ---------------------------------------------------------------------------
# heartbeats / stragglers / elasticity
# ---------------------------------------------------------------------------


def test_heartbeat_detects_dead_host():
    mon = HeartbeatMonitor(["h0", "h1", "h2"], timeout_steps=2)
    for step in range(5):
        mon.report("h0", step, 1.0)
        mon.report("h1", step, 1.0)
        if step < 2:
            mon.report("h2", step, 1.0)
    assert mon.dead_hosts(current_step=4) == ["h2"]


def test_straggler_detection_median_policy():
    times = {
        "h0": [1.0] * 5,
        "h1": [1.0] * 5,
        "h2": [1.0] * 5,
        "slow": [1.0, 1.0, 3.1, 3.2, 3.3],
    }
    assert detect_stragglers(times, factor=2.0, patience=3) == ["slow"]
    # a single slow step is not a straggler
    times["blip"] = [1.0, 1.0, 1.0, 3.5, 1.0]
    assert "blip" not in detect_stragglers(times, factor=2.0, patience=3)


def test_elastic_remesh_plan():
    plan = plan_elastic_remesh({"data": 16, "model": 16}, lost_hosts=4,
                               hosts_per_replica=4)
    assert plan is not None
    assert plan.new_shape == (15, 16)
    assert plan.dropped_axis == "data"
    with pytest.raises(SimulatedFailure):
        plan_elastic_remesh({"data": 1, "model": 16}, lost_hosts=8,
                            hosts_per_replica=4)


def test_end_to_end_train_restart(tmp_path):
    """The real training driver: loss decreases and failures do not corrupt."""
    from repro.launch.train import main

    stats, history = main([
        "--arch", "qwen2-0.5b", "--steps", "14", "--batch", "4", "--seq", "64",
        "--ckpt-every", "4", "--ckpt-dir", str(tmp_path), "--fail-at", "9",
        "--log-every", "100",
    ])
    assert stats["failures"] == 1
    assert stats["steps"] == 14
    assert history[-1] < history[0]  # learned something through the restart
