"""Cross-route differential harness: direct / im2col / xla / q16 must agree.

Property-based (hypothesis, or the conftest shim when it isn't installed):
the conv geometry (H, W, Cin, Cout, K, stride, padding, relu, bias) is
derived from a drawn seed so the suite sweeps every route — the untiled
direct kernel, the two-block row-tiled cases, the manual-DMA (𝒯, ℭ) tiled
cases (ISSUE 8), the im2col GEMM, and the xla lowering — and asserts they
are bitwise-close in float and within quantization tolerance in q16
(DESIGN.md §2, ISSUE 2).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import dse
from repro.core.engine import Engine, reset_plan_caches
from repro.core.quantization import Q2_14, dequantize, quantize
from repro.core.template import TemplateConfig
from repro.core.tiling import TPU_V5E, ceil_div
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(42)


def _draw_case(seed: int):
    """Seed -> a conv case; every route (incl. tiled) is reachable."""
    rng = np.random.default_rng(seed)
    k = int(rng.choice([1, 3, 5]))
    stride = int(rng.choice([1, 2, 4]))
    pad = int(rng.choice([0, 1, max(1, k // 2)]))
    h = int(rng.integers(k + stride, 18))
    w_ = int(rng.integers(k + stride, 18))
    cin = int(rng.integers(1, 9))
    cout = int(rng.integers(1, 20))
    relu = bool(rng.integers(0, 2))
    use_bias = bool(rng.integers(0, 2))
    kx = jax.random.fold_in(KEY, seed)
    # clip to [-1, 1]: keeps the q16 bound below deterministic (|a|, |b| <= 1)
    x = jnp.clip(jax.random.normal(kx, (2, h, w_, cin)) * 0.25, -1, 1)
    w = jnp.clip(jax.random.normal(jax.random.fold_in(kx, 1), (k, k, cin, cout)) * 0.25, -1, 1)
    b = jnp.clip(jax.random.normal(jax.random.fold_in(kx, 2), (cout,)) * 0.1, -1, 1) if use_bias else None
    return x, w, b, k, stride, pad, relu


def _tile_rows_for(k: int, stride: int, ho: int) -> int:
    """A legal tile height that actually tiles (>= 2 tiles) when ho allows."""
    th = max(ceil_div(k, stride), ceil_div(ho, 3))
    return th if th < ho else 0


def _dma_tiles_for(ho: int, wo: int) -> tuple[int, int]:
    """A ragged-edged (𝒯, ℭ) tile for the DMA regime (no legality bound)."""
    return max(1, ceil_div(ho, 3)), max(1, ceil_div(wo, 2))


# ---------------------------------------------------------------------------
# float: direct (untiled + tiled) == im2col == xla, bitwise-close
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_float_routes_agree(seed):
    x, w, b, k, stride, pad, relu = _draw_case(seed)
    ho = (x.shape[1] + 2 * pad - k) // stride + 1
    want = ref.conv2d_fused_ref(x, w, b, stride=stride, padding=pad, relu=relu)
    kw = dict(bias=b, stride=stride, padding=pad, relu=relu, interpret=True)
    outs = {
        "direct": ops.conv2d(x, w, route="direct", tau=8, **kw),
        "im2col": ops.conv2d(x, w, route="im2col", **kw),
    }
    th = _tile_rows_for(k, stride, ho)
    if th:
        outs["tiled"] = ops.conv2d(x, w, route="direct", tau=8, tile_rows=th, **kw)
        outs["dma_rows"] = ops.conv2d(
            x, w, route="direct", tau=8, tile_rows=th, halo_mode="dma", **kw
        )
    wo_ = (x.shape[2] + 2 * pad - k) // stride + 1
    tr, tc = _dma_tiles_for(ho, wo_)
    outs["dma_rc"] = ops.conv2d(
        x, w, route="direct", tau=8, tile_rows=tr, tile_cols=tc,
        halo_mode="dma", **kw
    )
    eng = Engine(TemplateConfig(backend="xla"))
    outs["xla"] = eng.conv2d(x, w, stride=stride, padding=pad, bias=b, relu=relu)
    for name, out in outs.items():
        assert out.shape == want.shape, name
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(want), atol=1e-4, rtol=1e-4,
            err_msg=f"route {name} (seed {seed})",
        )


# ---------------------------------------------------------------------------
# q16: direct (untiled + tiled) == im2col bit-exact; vs float within one LSB
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_q16_routes_agree(seed):
    x, w, b, k, stride, pad, relu = _draw_case(seed)
    ho = (x.shape[1] + 2 * pad - k) // stride + 1
    xq, wq = quantize(x), quantize(w)
    bq = None if b is None else quantize(b)
    kw = dict(bias=bq, stride=stride, padding=pad, relu=relu, interpret=True)
    want = ref.conv2d_q16_ref(xq, wq, bq, stride=stride, padding=pad, relu=relu)
    routes = {
        "direct": ops.conv2d_q16(xq, wq, route="direct", tau=8, **kw),
        "im2col": ops.conv2d_q16(xq, wq, route="im2col", **kw),
    }
    th = _tile_rows_for(k, stride, ho)
    if th:
        routes["tiled"] = ops.conv2d_q16(xq, wq, route="direct", tau=8, tile_rows=th, **kw)
        routes["dma_rows"] = ops.conv2d_q16(
            xq, wq, route="direct", tau=8, tile_rows=th, halo_mode="dma", **kw
        )
    wo_ = (x.shape[2] + 2 * pad - k) // stride + 1
    tr, tc = _dma_tiles_for(ho, wo_)
    routes["dma_rc"] = ops.conv2d_q16(
        xq, wq, route="direct", tau=8, tile_rows=tr, tile_cols=tc,
        halo_mode="dma", **kw
    )
    for name, out in routes.items():
        # all q16 routes accumulate exactly in int32 -> bit-identical raw
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(want), err_msg=f"route {name} (seed {seed})"
        )
    # quantization tolerance vs the float compute on the *snapped* operands:
    # exact int32 accumulation leaves only the final round-shift (<= LSB/2)
    # and the output clip, so one Q2.14 LSB bounds the difference.
    xd, wd = dequantize(xq), dequantize(wq)
    bd = None if bq is None else dequantize(bq)
    fwant = ref.conv2d_fused_ref(xd, wd, bd, stride=stride, padding=pad, relu=relu)
    fwant = jnp.clip(fwant, Q2_14.min_val, Q2_14.max_val)
    err = float(jnp.abs(dequantize(want) - fwant).max())
    assert err <= Q2_14.resolution * 1.001, f"q16 vs float {err} (seed {seed})"


# ---------------------------------------------------------------------------
# spatially-tiled planner cases: oversized layers stay direct and match
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("stride,pad", [(1, 1), (2, 0), (2, "SAME")])
def test_oversized_layer_tiles_and_matches_im2col(stride, pad):
    """A layer whose untiled slab exceeds the budget stays direct, tiled.

    Budgets are scaled per backend (q16 slabs are half the bytes) so both
    backends are genuinely oversized-yet-tileable at this 32x32x32 layer.
    """
    kx = jax.random.fold_in(KEY, 7)
    x = jnp.clip(jax.random.normal(kx, (1, 32, 32, 32)) * 0.25, -1, 1)
    w = jnp.clip(jax.random.normal(jax.random.fold_in(kx, 1), (3, 3, 32, 16)) * 0.25, -1, 1)
    b = jax.random.normal(jax.random.fold_in(kx, 2), (16,)) * 0.1
    cases = (
        ("pallas", 4, 256 * 1024, 1e-4),
        ("q16", 2, 128 * 1024, Q2_14.resolution * 1.001),
    )
    for backend, in_bytes, budget, tol in cases:
        hw = dataclasses.replace(TPU_V5E, vmem_bytes=budget)
        eng = Engine(TemplateConfig(backend=backend, interpret=True, hw=hw))
        plan = eng.plan_conv(x.shape, w.shape, stride=stride, padding=pad)
        hp, wp = 32 + 2 * plan.pad, 32 + 2 * plan.pad
        ho = (hp - 3) // stride + 1
        untiled = dse.direct_conv_vmem(
            hp, wp, 32, 3, 3, ho, ho, plan.tau, in_bytes, stride=stride
        )
        assert untiled > budget, backend  # it really was oversized
        assert plan.route == "direct", backend
        assert plan.spatial_tiles >= 2 or plan.col_tiles >= 2
        assert plan.tile_rows > 0 or plan.tile_cols > 0
        assert plan.halo_mode in ("two_block", "dma")
        assert plan.vmem_bytes <= budget
        p_gemm = eng.plan_conv(x.shape, w.shape, stride=stride, padding=pad, route="im2col")
        out_t = eng.conv2d(x, w, stride=stride, padding=pad, bias=b, relu=True, plan=plan)
        out_g = eng.conv2d(x, w, stride=stride, padding=pad, bias=b, relu=True, plan=p_gemm)
        err = float(jnp.abs(out_t - out_g).max())
        assert err <= tol, f"{backend}: tiled vs im2col {err}"


def test_acceptance_shape_plans_tiled_direct_on_default_hw():
    """ISSUE 2 acceptance: 3x3, Cin=64, 512x512 exceeds v5e VMEM untiled."""
    eng = Engine(TemplateConfig(backend="pallas", interpret=True))
    plan = eng.plan_conv((1, 512, 512, 64), (3, 3, 64, 64), stride=1, padding=1)
    untiled = dse.direct_conv_vmem(514, 514, 64, 3, 3, 512, 512, plan.tau, 4)
    assert untiled > eng.config.hw.vmem_bytes
    assert plan.route == "direct"
    assert plan.spatial_tiles >= 2
    # ISSUE 8 acceptance: the extreme-width shape tiles as (𝒯, ℭ) blocks
    # under the manual-DMA halo — no im2col fallback, a real column tile
    assert plan.halo_mode == "dma" and plan.col_tiles >= 2 and plan.tile_cols > 0
    assert plan.vmem_bytes <= eng.config.hw.vmem_bytes
    # the whole VGG16 stack at 512x512 now stays on the direct route
    from repro.core.template import default_template
    from repro.models.cnn import CNN_ZOO, plan_cnn

    reset_plan_caches()
    net = plan_cnn(default_template("pallas"), CNN_ZOO["vgg16"], (1, 512, 512, 3))
    assert [cp.route for cp in net.convs] == ["direct"] * len(net.convs)
    assert any(cp.spatial_tiles >= 2 for cp in net.convs)
    assert all(cp.vmem_bytes <= TPU_V5E.vmem_bytes for cp in net.convs)
    assert len(net.describe()) == len(net.convs) + len(net.fcs)
    reset_plan_caches()


# ---------------------------------------------------------------------------
# the forced-fallback boundary: below the minimal tiled working set -> im2col
# ---------------------------------------------------------------------------


def test_forced_fallback_boundary():
    x_shape, w_shape = (1, 24, 24, 16), (3, 3, 16, 8)
    hp = wp = 24
    ho = wo = 22
    # the smallest config the DSE may pick: tau=8, minimal legal tile
    vmin = min(
        c.vmem_bytes
        for c in dse.explore_conv_spatial(
            hp, wp, 16, 3, 3, ho, wo, 8, 1,
            dataclasses.replace(TPU_V5E, vmem_bytes=2**62), 4, top=1000,
        )
    )
    below = dataclasses.replace(TPU_V5E, vmem_bytes=vmin - 1)
    eng_below = Engine(TemplateConfig(backend="pallas", interpret=True, hw=below))
    plan = eng_below.plan_conv(x_shape, w_shape)
    assert plan.route == "im2col" and plan.block is not None
    with pytest.raises(ValueError):
        eng_below.plan_conv(x_shape, w_shape, route="direct")
    at = dataclasses.replace(TPU_V5E, vmem_bytes=vmin)
    eng_at = Engine(TemplateConfig(backend="pallas", interpret=True, hw=at))
    plan_at = eng_at.plan_conv(x_shape, w_shape)
    assert plan_at.route == "direct" and plan_at.vmem_bytes == vmin
    assert plan_at.spatial_tiles >= 2 or plan_at.col_tiles >= 2
    # both sides of the boundary compute the same numbers
    kx = jax.random.fold_in(KEY, 11)
    x = jax.random.normal(kx, x_shape) * 0.25
    w = jax.random.normal(jax.random.fold_in(kx, 1), w_shape) * 0.25
    out_below = eng_below.conv2d(x, w, plan=plan)
    out_at = eng_at.conv2d(x, w, plan=plan_at)
    np.testing.assert_allclose(
        np.asarray(out_at), np.asarray(out_below), atol=1e-4, rtol=1e-4
    )


# ---------------------------------------------------------------------------
# tiled kernel sweep: stride x padding x ragged tile boundaries vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("stride", [1, 2, 4])
@pytest.mark.parametrize("tile_rows", [0, 3, 5])
def test_tiled_direct_conv_vs_ref_sweep(stride, tile_rows):
    kx = jax.random.fold_in(KEY, 13 + stride)
    x = jax.random.normal(kx, (2, 15, 13, 4)) * 0.25
    w = jax.random.normal(jax.random.fold_in(kx, 1), (3, 3, 4, 10)) * 0.25
    b = jax.random.normal(jax.random.fold_in(kx, 2), (10,)) * 0.1
    out = ops.conv2d(
        x, w, bias=b, stride=stride, padding=1, tau=8, relu=True,
        tile_rows=tile_rows, interpret=True,
    )
    want = ref.conv2d_fused_ref(x, w, b, stride=stride, padding=1, relu=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4, rtol=1e-4)
    xq, wq, bq = quantize(x), quantize(w), quantize(b)
    outq = ops.conv2d_q16(
        xq, wq, bias=bq, stride=stride, padding=1, tau=8, relu=True,
        tile_rows=tile_rows, interpret=True,
    )
    wantq = ref.conv2d_q16_ref(xq, wq, bq, stride=stride, padding=1, relu=True)
    np.testing.assert_array_equal(np.asarray(outq), np.asarray(wantq))


def test_tile_rows_too_small_raises():
    """stride*tile_rows < kh cannot cover the tap window -> loud error."""
    x = jnp.zeros((1, 16, 16, 4))
    w = jnp.zeros((5, 5, 4, 8))
    with pytest.raises(ValueError, match="tap window"):
        ops.conv2d(x, w, tile_rows=2, interpret=True)


# ---------------------------------------------------------------------------
# manual-DMA halo regime (ISSUE 8): (𝒯, ℭ) tiles vs oracle, both dtypes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("stride", [1, 2, 4])
@pytest.mark.parametrize("tile", [(3, 0), (0, 4), (5, 3), (2, 2)])
def test_dma_halo_conv_vs_ref_sweep(stride, tile):
    """DMA-halo row/column/joint tiling with ragged edges matches the oracle.

    (2, 2) with stride 1 and k=3 is *illegal* under the two-block scheme
    (stride·tile_rows < kh) but fine under DMA — the fetched window always
    covers the tap extent, so the legality bound is gone.
    """
    tr, tc = tile
    kx = jax.random.fold_in(KEY, 17 + stride)
    x = jax.random.normal(kx, (2, 15, 13, 4)) * 0.25
    w = jax.random.normal(jax.random.fold_in(kx, 1), (3, 3, 4, 10)) * 0.25
    b = jax.random.normal(jax.random.fold_in(kx, 2), (10,)) * 0.1
    out = ops.conv2d(
        x, w, bias=b, stride=stride, padding=1, tau=8, relu=True,
        tile_rows=tr, tile_cols=tc, halo_mode="dma", interpret=True,
    )
    want = ref.conv2d_fused_ref(x, w, b, stride=stride, padding=1, relu=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4, rtol=1e-4)
    xq, wq, bq = quantize(x), quantize(w), quantize(b)
    outq = ops.conv2d_q16(
        xq, wq, bias=bq, stride=stride, padding=1, tau=8, relu=True,
        tile_rows=tr, tile_cols=tc, halo_mode="dma", interpret=True,
    )
    wantq = ref.conv2d_q16_ref(xq, wq, bq, stride=stride, padding=1, relu=True)
    np.testing.assert_array_equal(np.asarray(outq), np.asarray(wantq))


def test_column_tiling_requires_dma():
    """tile_cols under the two-block BlockSpec scheme is a loud error."""
    x = jnp.zeros((1, 16, 16, 4))
    w = jnp.zeros((3, 3, 4, 8))
    with pytest.raises(ValueError, match="dma"):
        ops.conv2d(x, w, tile_rows=4, tile_cols=4, interpret=True)


def test_dma_tile_smaller_than_tap_window_works():
    """The two-block legality bound does not apply to the DMA regime."""
    kx = jax.random.fold_in(KEY, 23)
    x = jax.random.normal(kx, (1, 16, 16, 4)) * 0.25
    w = jax.random.normal(jax.random.fold_in(kx, 1), (5, 5, 4, 8)) * 0.25
    out = ops.conv2d(
        x, w, stride=1, tau=8, tile_rows=2, tile_cols=3, halo_mode="dma",
        interpret=True,
    )
    want = ref.conv2d_fused_ref(x, w, None, stride=1, padding=0, relu=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4, rtol=1e-4)


def test_divisor_tile_ladder_offers_exact_tilings():
    """ISSUE 8 satellite: the ladder enumerates non-power-of-two divisors of
    the extent, so shapes like Ho=27 can tile exactly (9·3) instead of only
    via ragged halvings (27→14→7)."""
    assert 9 in dse._tile_ladder(27, 1) and 3 in dse._tile_ladder(27, 1)
    assert 5 in dse._tile_ladder(15, 1)
    assert dse._tile_ladder(8, 1) == [8, 4, 2, 1]
    # and the explored configs include an exact non-power-of-two tiling
    ranked = dse.explore_conv_spatial(
        29, 29, 8, 3, 3, 27, 27, 8, 1,
        dataclasses.replace(TPU_V5E, vmem_bytes=64 * 1024), 4, top=1000,
    )
    assert any(c.tile_rows == 9 and c.halo_mode == "dma" for c in ranked)
