"""CI-scale dry-run: the full lower+compile machinery on an 8-device mesh.

Runs in a subprocess because XLA_FLAGS must be set before jax initializes —
the main test process keeps its single device.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, dataclasses, jax
    import jax.numpy as jnp
    from repro.configs import all_configs, reduced, SHAPES
    from repro.configs.base import ShapeSpec
    from repro.launch.mesh import make_test_mesh
    from repro.launch.steps import step_and_specs
    from repro.parallel.sharding import TRAIN_RULES, SERVE_RULES, use_mesh
    from repro.core.hlo_analysis import analyze_hlo

    arch, kind, multi = "%ARCH%", "%KIND%", %MULTI%
    cfg = dataclasses.replace(reduced(all_configs()[arch]), remat=True)
    if kind == "train":
        shape = ShapeSpec("t", 64, 8, "train")
        rules = TRAIN_RULES
    elif kind == "prefill":
        shape = ShapeSpec("p", 64, 4, "prefill")
        rules = SERVE_RULES
    else:
        shape = ShapeSpec("d", 64, 4, "decode")
        rules = SERVE_RULES
    if cfg.rule_overrides:
        rules = rules.with_overrides(**dict(cfg.rule_overrides))
    mesh = make_test_mesh(multi_pod=multi)
    with use_mesh(mesh, rules):
        cell = step_and_specs(cfg, shape, mesh, rules)
        jitted = jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate_argnums)
        lowered = jitted.lower(*cell.args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
    st = analyze_hlo(hlo, total_devices=8)
    print(json.dumps({
        "ok": True,
        "flops": st.flops,
        "bytes": st.bytes,
        "wire": st.wire_bytes,
        "colls": st.coll_counts,
        "temp": getattr(mem, "temp_size_in_bytes", -1),
    }))
    """
)


def _run(arch: str, kind: str, multi: bool = False) -> dict:
    code = (_SCRIPT.replace("%ARCH%", arch).replace("%KIND%", kind)
            .replace("%MULTI%", str(multi)))
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=420,
    )
    assert out.returncode == 0, f"{arch}/{kind} failed:\n{out.stderr[-3000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "granite-moe-3b-a800m",
                                  "mamba2-1.3b", "recurrentgemma-9b"])
def test_mini_dryrun_train(arch):
    rec = _run(arch, "train")
    assert rec["ok"]
    assert rec["flops"] > 0
    # a sharded training step must communicate
    assert rec["wire"] > 0, f"no collectives found for {arch}"


def test_mini_dryrun_decode():
    rec = _run("qwen2-0.5b", "decode")
    assert rec["ok"] and rec["flops"] > 0


def test_mini_dryrun_multipod():
    """The pod axis must shard: multi-pod compiles and communicates."""
    rec = _run("qwen2-0.5b", "train", multi=True)
    assert rec["ok"]
    assert rec["wire"] > 0


def test_mini_dryrun_prefill_encdec():
    rec = _run("whisper-medium", "prefill")
    assert rec["ok"] and rec["flops"] > 0
