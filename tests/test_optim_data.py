"""Optimizer math, grad accumulation, compression, data determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.pipeline import synthetic_batch, synthetic_images
from repro.optim import AdamW, adamw_init, adamw_update
from repro.optim.compress import (
    apply_error_feedback,
    compress_int8,
    decompress_int8,
)
from repro.optim.schedules import cosine_warmup, linear_warmup


def test_adamw_matches_reference_math():
    """One step against a hand-computed Adam update."""
    opt = AdamW(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                clip_norm=None)
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.5, 0.5])}
    st_ = adamw_init(p)
    new_p, new_st, _ = adamw_update(opt, g, st_, p)
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    want = 1.0 - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(float(new_p["w"][0]), want, rtol=1e-6)


def test_weight_decay_only_on_matrices():
    opt = AdamW(lr=0.1, weight_decay=0.5, clip_norm=None)
    p = {"mat": jnp.ones((2, 2)), "vec": jnp.ones((2,))}
    g = jax.tree.map(jnp.zeros_like, p)
    new_p, _, _ = adamw_update(opt, g, adamw_init(p), p)
    assert float(new_p["mat"][0, 0]) < 1.0  # decayed
    np.testing.assert_allclose(np.asarray(new_p["vec"]), 1.0)  # not decayed


def test_grad_clipping_bounds_update():
    opt = AdamW(lr=1.0, clip_norm=1.0)
    p = {"w": jnp.zeros((3,))}
    g = {"w": jnp.full((3,), 100.0)}
    _, _, metrics = adamw_update(opt, g, adamw_init(p), p)
    assert float(metrics["grad_norm"]) == pytest.approx(100.0 * np.sqrt(3), rel=1e-4)


def test_accumulation_equivalence():
    """accum=2 over a batch == accum=1 over the same batch (same grads)."""
    from repro.configs import all_configs, reduced
    from repro.launch.steps import make_train_step
    from repro.models import transformer as T
    from repro.optim import adamw_init

    cfg = reduced(all_configs()["qwen2-0.5b"])
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab)
    batch = {"tokens": tokens}
    opt = AdamW(lr=1e-2, clip_norm=None)
    p1, _, m1 = jax.jit(make_train_step(cfg, opt=opt, accum=1))(
        params, adamw_init(params), batch
    )
    p2, _, m2 = jax.jit(make_train_step(cfg, opt=opt, accum=2))(
        params, adamw_init(params), batch
    )
    # same average gradient -> same update (up to accumulation dtype error)
    err = jax.tree.reduce(
        max, jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), p1, p2)
    )
    assert err < 5e-3, f"accum mismatch {err}"


def test_schedules():
    lw = linear_warmup(1.0, 10)
    assert float(lw(jnp.int32(0))) == 0.0
    assert float(lw(jnp.int32(5))) == pytest.approx(0.5)
    assert float(lw(jnp.int32(20))) == 1.0
    cw = cosine_warmup(1.0, 10, 100, final_frac=0.1)
    assert float(cw(jnp.int32(10))) == pytest.approx(1.0, abs=1e-3)
    assert float(cw(jnp.int32(100))) == pytest.approx(0.1, abs=1e-3)


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------


@given(st.integers(min_value=0, max_value=10_000))
@settings(max_examples=30, deadline=None)
def test_int8_roundtrip_error_bound(seed):
    g = jax.random.normal(jax.random.PRNGKey(seed), (64,))
    q, scale = compress_int8(g)
    back = decompress_int8(q, scale)
    assert float(jnp.abs(back - g).max()) <= float(scale) / 2 + 1e-7
    assert q.dtype == jnp.int8


def test_error_feedback_accumulates_residual():
    grads = {"w": jnp.array([0.3e-3, -0.2e-3, 1.0])}
    ef = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    comp = lambda x: compress_int8(x)
    decomp = lambda p: decompress_int8(*p)
    out, ef2 = apply_error_feedback(grads, ef, comp, decomp)
    # residual = original - compressed
    np.testing.assert_allclose(
        np.asarray(ef2["w"]), np.asarray(grads["w"] - out["w"]), atol=1e-7
    )
    # over many steps the *mean* compressed signal converges to the true grad
    total = jnp.zeros_like(grads["w"])
    ef = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    n = 400
    for _ in range(n):
        out, ef = apply_error_feedback(grads, ef, comp, decomp)
        total = total + out["w"]
    # the time-average of EF-compressed gradients converges to the true
    # gradient with O(1/n) bias (residual is bounded by one quantization step)
    step = float(jnp.max(jnp.abs(grads["w"]))) / 127.0
    np.testing.assert_allclose(
        np.asarray(total / n), np.asarray(grads["w"]), atol=step / 2 + 2e-5
    )


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_batches_deterministic_across_calls():
    a = synthetic_batch(0, 5, 4, 32, 1000)
    b = synthetic_batch(0, 5, 4, 32, 1000)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = synthetic_batch(0, 6, 4, 32, 1000)
    assert not np.array_equal(np.asarray(a), np.asarray(c))
    d = synthetic_batch(1, 5, 4, 32, 1000)
    assert not np.array_equal(np.asarray(a), np.asarray(d))


def test_tokens_in_vocab_and_learnable():
    t = synthetic_batch(0, 0, 8, 128, 257)
    assert int(t.min()) >= 0 and int(t.max()) < 257
    # Markov structure: next token correlates with current (mutual info > 0)
    x = np.asarray(t)
    # same (prev, noise-free) transitions repeat => entropy of next|prev < log V
    pairs = {}
    for row in x:
        for a, b in zip(row[:-1], row[1:]):
            pairs.setdefault(int(a), set()).add(int(b))
    branching = np.mean([len(v) for v in pairs.values()])
    assert branching < 257 / 4  # far from uniform


def test_images_deterministic_and_shaped():
    img, lab = synthetic_images(0, 3, 4, 32, 3, 10)
    img2, lab2 = synthetic_images(0, 3, 4, 32, 3, 10)
    np.testing.assert_array_equal(np.asarray(img), np.asarray(img2))
    assert img.shape == (4, 32, 32, 3)
    assert lab.shape == (4,)
    assert int(lab.max()) < 10


def test_pipeline_includes_ctx_for_multimodal():
    from repro.configs import SHAPES, all_configs, reduced
    from repro.data import make_pipeline

    cfg = reduced(all_configs()["whisper-medium"])
    pipe = make_pipeline(cfg, SHAPES["train_4k"], global_batch=2, seq_len=16)
    b = pipe.batch(0)
    assert b["ctx"].shape == (2, cfg.n_frames, cfg.d_model)
