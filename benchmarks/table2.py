"""Paper Table 2 reproduction: benchmark vs Bjerge et al. [10] on Ultra96.

Paper numbers: previous method 31 GOP/s / 4.6 ms / 3.55 W vs proposed
51 GOP/s / 0.174 ms / 4.7 W at 16-bit on the same board.

Note (flagged in DESIGN.md §7): 0.174 ms for full AlexNet at 51 GOP/s is
internally inconsistent (1.45 GOP / 51 GOP/s ≈ 28 ms).  0.174 ms is
consistent with a single mid-size *layer* (e.g. conv3: 0.299 GOP / 51 GOP/s /
... ≈ ms-scale) — "minimal layer of execution time" in the paper's
conclusion.  We therefore report both interpretations.
"""
from __future__ import annotations

from repro.core.fpga_model import alexnet_layers, evaluate_network
from .table1 import instance_for

PAPER_PREV = {"gops": 31.0, "latency_ms": 4.6, "power_w": 3.55}
PAPER_OURS = {"gops": 51.0, "latency_ms": 0.174, "power_w": 4.7}


def run() -> dict:
    inst = instance_for("Ultra96")
    layers = alexnet_layers()
    rep = evaluate_network("alexnet", layers, inst, batch=4)
    per_layer = {
        l.layer.name: {"gops": round(l.gops, 1), "latency_ms": round(l.latency_ms, 3)}
        for l in rep.layers
    }
    min_layer = min(rep.layers, key=lambda l: l.latency_ms)
    return {
        "modeled_conv_gops": round(rep.conv_gops, 1),
        "modeled_full_net_latency_ms": round(rep.latency_ms, 3),
        "modeled_min_layer_latency_ms": round(min_layer.latency_ms, 3),
        "modeled_min_layer": min_layer.layer.name,
        "paper_prev": PAPER_PREV,
        "paper_ours": PAPER_OURS,
        "speedup_vs_prev_paper_claim": round(PAPER_OURS["gops"] / PAPER_PREV["gops"], 2),
        "speedup_vs_prev_modeled": round(rep.conv_gops / PAPER_PREV["gops"], 2),
        "per_layer": per_layer,
    }


def main():
    print("== Table 2: benchmark vs Bjerge et al. [10] on Ultra96 ==")
    r = run()
    print(f"paper:   prev {PAPER_PREV['gops']} GOP/s vs proposed "
          f"{PAPER_OURS['gops']} GOP/s  (1.65x)")
    print(f"modeled: proposed {r['modeled_conv_gops']} GOP/s "
          f"({r['speedup_vs_prev_modeled']}x vs prev paper number)")
    print(f"modeled full-AlexNet latency: {r['modeled_full_net_latency_ms']} ms "
          f"(paper table: {PAPER_OURS['latency_ms']} ms — see inconsistency note)")
    print(f"modeled fastest single layer: {r['modeled_min_layer']} = "
          f"{r['modeled_min_layer_latency_ms']} ms")
    return r


if __name__ == "__main__":
    main()
