"""Router soak: N real scheduler worker processes, one shared plan store,
one injected kill — zero lost/duplicated tokens and zero cold DSE searches.

    PYTHONPATH=src python -m benchmarks.router_soak --workers 3 \
        --requests 24 --out router_soak.json

The cross-process half of the ISSUE 7 failover story (the in-process half —
VirtualClock fault injection through :class:`ReplicaRouter` — lives in
tests/test_router_failover.py and the kernel_table ``router_failover`` row).
The parent:

1. replays the whole trace through ONE in-process scheduler (the reference
   ledger) and merges the resulting plans into a shared flock'd plan store;
2. partitions the trace round-robin across N worker subprocesses
   (``--worker`` mode: a real ServeScheduler per process, warm-started from
   the shared store), each streaming ``T rid pos tok`` ledger lines and
   ``C rid`` completion markers on stdout and checkpointing its in-flight
   sessions every ``--checkpoint-every`` ticks;
3. kills one worker for real (``--die-at-tick`` -> ``os._exit(137)``,
   stdout torn mid-line and all), recovers its unfinished sessions from the
   victim's last checkpoint (or the original request when the session was
   never checkpointed) and replays them through a recovery worker;
4. merges every stream into one :class:`TokenLedger` — regenerated overlap
   must verify byte-equal to be suppressed — and gates on:

   * ledger byte-identical to the reference (zero lost, zero duplicated);
   * every surviving worker + the recovery worker reporting **zero** DSE
     misses across its entire run, warmup included (the shared store is the
     only plan source);
   * at least one session restored from a checkpoint mid-stream (the kill
     must actually exercise the restore + duplicate-suppression path).

Exits non-zero on any gate failure; ``--out`` writes the stats JSON
artifact CI uploads.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

import jax
import numpy as np

#: trace prompts sweep only up to 24 while the schedulers run a 32 top rung:
#: a resumed session re-prefills prompt + generated (<= 24 + 6 = 30), so the
#: recovery path always finds a bucket (DESIGN.md §9 resumability headroom)
TRACE_LADDER = (8, 16, 24)
SCHED_LADDER = (8, 16, 32)
MAX_NEW = 6
MAX_NEW_LIMIT = 8


def build_scheduler(args):
    from repro.configs import get_config, reduced
    from repro.core.template import default_template
    from repro.launch.scheduler import (SchedulerConfig, ServeScheduler,
                                        VirtualClock)
    from repro.models import transformer as T

    cfg = reduced(get_config(args.arch))
    tpl = default_template(args.backend)
    params = T.init_params(jax.random.PRNGKey(args.seed), cfg)
    sched = ServeScheduler(
        cfg, params, tpl=tpl, clock=VirtualClock(),
        sched=SchedulerConfig(ladder=SCHED_LADDER, slots=args.slots,
                              max_new_limit=MAX_NEW_LIMIT,
                              max_queue=max(256, args.requests)),
    )
    return cfg, sched


# ---------------------------------------------------------------------------
# worker mode: one real scheduler process on the shared store
# ---------------------------------------------------------------------------


def worker_main(args) -> None:
    from repro.core.engine import plan_store_stats, warm_start_plan_store
    from repro.checkpoint.manager import CheckpointManager
    from repro.launch.scheduler import request_from_snapshot

    _, loaded = warm_start_plan_store()
    before = plan_store_stats()
    _, sched = build_scheduler(args)
    sched.warmup()

    with open(args.reqfile) as f:
        snaps = json.load(f)
    seen = {}
    for snap in snaps:
        req = request_from_snapshot(snap)
        seen[req.rid] = len(req.generated)  # resume point: emit only new
        if not sched.submit(req):
            raise RuntimeError(f"worker rejected session {req.rid}")

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    done = set()
    tick = 0
    while sched.queue or sched.active:
        if args.die_at_tick >= 0 and tick == args.die_at_tick:
            sys.stdout.flush()
            os._exit(137)  # the injected kill: no cleanup, no final line
        sched.step()
        for req in list(sched.active.values()) + list(sched.results.values()):
            cur = seen.get(req.rid, 0)
            for pos in range(cur, len(req.generated)):
                print(f"T {req.rid} {pos} {req.generated[pos]}")
            seen[req.rid] = len(req.generated)
            if req.state == "completed" and req.rid not in done:
                done.add(req.rid)
                print(f"C {req.rid}")
        if mgr is not None and tick % args.checkpoint_every == 0:
            mgr.save(tick, {"tick": np.asarray(tick, np.int64)},
                     extra={"tick": tick, "sessions": sched.export_sessions()})
        tick += 1

    after = plan_store_stats()
    print(json.dumps({
        "worker": args.worker_id,
        "warm_entries": loaded,
        "dse_misses": after["misses"] - before["misses"],
        "completed": len(done),
        "ticks": tick,
        "mean_occupancy": sched.stats()["mean_occupancy"],
        "ttft_p50": round(sched.stats()["ttft"].get("p50", 0.0), 3),
    }))


# ---------------------------------------------------------------------------
# parent mode
# ---------------------------------------------------------------------------


def _spawn(args, wid, reqfile, ckpt_dir, store, die_at=-1):
    cmd = [
        sys.executable, "-m", "benchmarks.router_soak", "--worker",
        "--worker-id", str(wid), "--reqfile", reqfile,
        "--ckpt-dir", ckpt_dir, "--die-at-tick", str(die_at),
        "--checkpoint-every", str(args.checkpoint_every),
        "--arch", args.arch, "--backend", args.backend,
        "--slots", str(args.slots), "--seed", str(args.seed),
        "--requests", str(args.requests),
    ]
    env = dict(os.environ, REPRO_PLAN_STORE=store,
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH", ""))
    return subprocess.Popen(cmd, stdout=subprocess.PIPE, text=True, env=env)


def _consume(ledger, text, counters):
    """Feed one worker's streamed stdout into the shared ledger.  A worker
    killed mid-write may tear its last line — malformed lines are dropped
    (their tokens are exactly what recovery re-derives)."""
    completed = set()
    last_json = None
    for line in text.splitlines():
        parts = line.split()
        if len(parts) == 4 and parts[0] == "T":
            rid, pos, tok = (int(p) for p in parts[1:])
            if ledger.record(rid, pos, tok):
                counters["ledger_tokens"] += 1
        elif len(parts) == 2 and parts[0] == "C":
            completed.add(int(parts[1]))
        elif line.startswith("{"):
            last_json = json.loads(line)
        else:
            counters["torn_lines"] += 1
    return completed, last_json


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--worker-id", type=int, default=0)
    ap.add_argument("--reqfile", default="")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--die-at-tick", type=int, default=-1)
    ap.add_argument("--checkpoint-every", type=int, default=2)
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--backend", default="pallas",
                    choices=["xla", "pallas", "q16"])
    ap.add_argument("--workers", type=int, default=3)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kill-tick", type=int, default=3,
                    help="tick at which worker 0 dies (-1 = no kill); the "
                         "default lands mid-drain for the stock 24-request "
                         "trace (worker 0 needs ~6 ticks)")
    ap.add_argument("--out", default="router_soak.json",
                    help="stats JSON artifact path ('' = skip)")
    args = ap.parse_args(argv)
    if args.worker:
        return worker_main(args)

    from repro.core.engine import (plan_store_stats, save_plan_store,
                                   warm_start_plan_store)
    from repro.checkpoint.manager import CheckpointManager
    from repro.launch.router import TokenLedger
    from repro.launch.scheduler import (replay_trace, session_snapshot,
                                        synthetic_trace)

    t_start = time.time()
    _, warm_loaded = warm_start_plan_store()
    before = plan_store_stats()

    # 1. the reference ledger (one in-process scheduler, whole trace) — this
    #    also plants every plan the workers will need
    cfg, ref_sched = build_scheduler(args)
    ref_sched.warmup()
    trace = synthetic_trace(args.requests, seed=args.seed, vocab=cfg.vocab,
                            ladder=TRACE_LADDER, max_new=MAX_NEW)
    snapshots = {r.rid: session_snapshot(r) for r in trace}
    replay_trace(ref_sched, trace)
    reference = {r.rid: list(ref_sched.results[r.rid].generated)
                 for r in trace}
    parent_misses = plan_store_stats()["misses"] - before["misses"]
    print(f"[router-soak] reference: {len(reference)} sessions, "
          f"{sum(len(v) for v in reference.values())} tokens, "
          f"{parent_misses} parent DSE misses (warm_loaded={warm_loaded})")
    if os.environ.get("REPRO_PLAN_ASSERT_WARM") == "1" and parent_misses > 0:
        raise RuntimeError(
            f"ASSERT_WARM: reference run searched {parent_misses} times "
            "against a populated store")

    work = tempfile.mkdtemp(prefix="router_soak_")
    store = os.path.join(work, "plan_store.json")
    save_plan_store(store)  # merged: warm-started entries + reference plans

    # 2. partition round-robin and launch the worker fleet
    parts = {w: [] for w in range(args.workers)}
    for i, r in enumerate(trace):
        parts[i % args.workers].append(snapshots[r.rid])
    procs = {}
    for wid, part in parts.items():
        reqfile = os.path.join(work, f"reqs_{wid}.json")
        with open(reqfile, "w") as f:
            json.dump(part, f)
        ckpt = os.path.join(work, f"ckpt_{wid}")
        die_at = args.kill_tick if wid == 0 else -1
        procs[wid] = (_spawn(args, wid, reqfile, ckpt, store, die_at), ckpt)

    ledger = TokenLedger()
    counters = {"ledger_tokens": 0, "torn_lines": 0}
    worker_rows = []
    victim_completed = set()
    for wid, (proc, ckpt) in procs.items():
        out, _ = proc.communicate(timeout=1200)
        completed, row = _consume(ledger, out, counters)
        if wid == 0 and args.kill_tick >= 0:
            assert proc.returncode == 137, (
                f"victim exited {proc.returncode}, expected the injected kill")
            victim_completed = completed
            print(f"[router-soak] worker 0 killed at tick {args.kill_tick} "
                  f"({len(completed)} of {len(parts[0])} sessions done)")
        else:
            assert proc.returncode == 0, f"worker {wid} failed rc={proc.returncode}"
            assert row is not None and len(completed) == len(parts[wid])
            worker_rows.append(row)

    # 3. recover the victim's unfinished sessions: last checkpoint first,
    #    original request when admitted after it — then a recovery worker
    restored = requeued_fresh = restored_tokens = 0
    if args.kill_tick >= 0:
        _, ckpt0 = procs[0]
        _, extra = CheckpointManager(ckpt0).latest_extra()
        ckpt_snaps = {int(s["rid"]): s
                      for s in (extra or {}).get("sessions", ())}
        recovered = []
        for snap in parts[0]:
            rid = snap["rid"]
            if rid in victim_completed:
                continue
            if rid in ckpt_snaps:
                restored += 1
                restored_tokens += len(ckpt_snaps[rid]["generated"])
                recovered.append(ckpt_snaps[rid])
            else:
                requeued_fresh += 1
                recovered.append(snap)
        assert recovered, "kill tick too late: nothing left to recover"
        assert restored > 0, (
            "kill must catch checkpointed in-flight sessions (restore path)")
        reqfile = os.path.join(work, "reqs_recovery.json")
        with open(reqfile, "w") as f:
            json.dump(recovered, f)
        rproc, _ = procs["recovery"] = (
            _spawn(args, 99, reqfile, os.path.join(work, "ckpt_r"), store), None)
        out, _ = rproc.communicate(timeout=1200)
        completed, row = _consume(ledger, out, counters)
        assert rproc.returncode == 0, f"recovery worker rc={rproc.returncode}"
        assert len(completed) == len(recovered)
        worker_rows.append(row)
        print(f"[router-soak] recovery: {restored} restored "
              f"(+{restored_tokens} checkpointed tokens), "
              f"{requeued_fresh} requeued fresh, "
              f"{ledger.duplicates_suppressed} duplicate tokens suppressed")

    # 4. the gates
    led = ledger.as_dict()
    assert set(led) == set(reference), (
        f"session mismatch: missing={sorted(set(reference) - set(led))} "
        f"extra={sorted(set(led) - set(reference))}")
    for rid, want in reference.items():
        assert led[rid] == want, (
            f"session {rid} diverged across the kill: {led[rid]} != {want}")
    print(f"[router-soak] parity OK: {len(reference)} sessions "
          "byte-identical to the single-process reference — "
          "zero lost, zero duplicated")
    cold = {r["worker"]: r["dse_misses"] for r in worker_rows}
    assert all(m == 0 for m in cold.values()), (
        f"cold DSE searches in warm workers: {cold}")
    assert all(r["warm_entries"] > 0 for r in worker_rows)
    print(f"[router-soak] warm fleet OK: 0 DSE searches across "
          f"{len(worker_rows)} worker processes (shared store)")

    row = {
        "bench": "router_soak",
        "arch": cfg.name, "backend": args.backend,
        "workers": args.workers, "requests": args.requests,
        "slots": args.slots, "seed": args.seed,
        "kill_tick": args.kill_tick,
        "checkpoint_every": args.checkpoint_every,
        "sessions": len(reference),
        "tokens": sum(len(v) for v in reference.values()),
        "ledger_tokens": counters["ledger_tokens"],
        "duplicates_suppressed": ledger.duplicates_suppressed,
        "torn_lines": counters["torn_lines"],
        "restored_sessions": restored,
        "restored_tokens": restored_tokens,
        "requeued_fresh": requeued_fresh,
        "victim_completed": len(victim_completed),
        "parent_dse_misses": parent_misses,
        "worker_dse_misses": cold,
        "workers_detail": worker_rows,
        "wall_s": round(time.time() - t_start, 2),
    }
    print(json.dumps({k: v for k, v in row.items() if k != "workers_detail"}))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(row, f, indent=1)
            f.write("\n")
        print(f"[router-soak] stats written to {args.out}")
    return row


if __name__ == "__main__":
    main()
