"""Benchmark harness entry point: one module per paper table/finding.

    PYTHONPATH=src python -m benchmarks.run

  table1          — paper Table 1 (resources + GOP/s, 3 ZYNQ boards)
  table2          — paper Table 2 (vs Bjerge et al. on Ultra96)
  dse_sweep       — paper §III.E tau≈2mu finding + TPU block DSE
  kernel_table    — Pallas compute-unit structural metrics + oracle check
  roofline_report — §Roofline table from the dry-run cache (if present)
"""
from __future__ import annotations

import sys
import traceback


def main():
    failures = []
    for name in ("table1", "table2", "dse_sweep", "kernel_table"):
        print("\n" + "=" * 72)
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main()
        except Exception:
            traceback.print_exc()
            failures.append(name)
    import os

    for label, d in (("baseline", "experiments/dryrun"),
                     ("optimized", "experiments/dryrun_opt")):
        print("\n" + "=" * 72)
        print(f"== Roofline ({label}) ==")
        try:
            from benchmarks import roofline_report

            if not os.path.isdir(d):
                print(f"(no {d} — run repro.launch.dryrun first)")
                continue
            rows = roofline_report.main(["--mesh", "16x16", "--dir", d])
            if rows:
                print(f"\n({label} roofline rows: {len(rows)} single-pod cells)")
        except Exception:
            traceback.print_exc()
            failures.append(f"roofline_report:{label}")
    if failures:
        print(f"\nbenchmark FAILURES: {failures}")
        sys.exit(1)
    print("\nall benchmarks completed")


if __name__ == "__main__":
    main()
