"""Benchmark harness entry point: one module per paper table/finding.

    PYTHONPATH=src python -m benchmarks.run

  table1          — paper Table 1 (resources + GOP/s, 3 ZYNQ boards)
  table2          — paper Table 2 (vs Bjerge et al. on Ultra96)
  dse_sweep       — paper §III.E tau≈2mu finding + TPU block DSE
  kernel_table    — Pallas compute-unit structural metrics + oracle check
  precision_drift — fixed-point drift + per-layer precision DSE sweep (§8/§11)
  scheduler_soak  — continuous-batching mixed-trace soak (virtual clock)
  router_soak     — multi-process replica fleet + injected kill (§9)
  roofline_report — §Roofline table from the dry-run cache (if present)

The per-module rows are consolidated into ``BENCH_pr10.json`` at the repo
root (one object per module that returned JSON-serializable rows).
"""
from __future__ import annotations

import json
import os
import sys
import traceback


def main():
    from repro.core.engine import (
        plan_store_stats,
        save_plan_store,
        warm_start_plan_store,
    )

    store_path, n = warm_start_plan_store()
    warm = n > 0
    if warm:
        print(f"[plan-store] warm-started {n} entries from {store_path}")

    failures = []
    results = {}
    for name in ("table1", "table2", "dse_sweep", "kernel_table",
                 "precision_drift", "scheduler_soak", "router_soak"):
        print("\n" + "=" * 72)
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            out = mod.main()
        except Exception:
            traceback.print_exc()
            failures.append(name)
        else:
            try:
                json.dumps(out)
            except (TypeError, ValueError):
                continue
            if out is not None:
                results[name] = out

    for label, d in (("baseline", "experiments/dryrun"),
                     ("optimized", "experiments/dryrun_opt")):
        print("\n" + "=" * 72)
        print(f"== Roofline ({label}) ==")
        try:
            from benchmarks import roofline_report

            if not os.path.isdir(d):
                print(f"(no {d} — run repro.launch.dryrun first)")
                continue
            rows = roofline_report.main(["--mesh", "16x16", "--dir", d])
            if rows:
                print(f"\n({label} roofline rows: {len(rows)} single-pod cells)")
        except Exception:
            traceback.print_exc()
            failures.append(f"roofline_report:{label}")
    st = plan_store_stats()
    print(f"\n[plan-store] this run: {st['gemm_blocks']} GEMM blocks + "
          f"{st['conv_tiles']} conv tiles in registry, "
          f"{st['misses']} new DSE searches, {st['hits']} cache hits")
    if os.environ.get("REPRO_PLAN_ASSERT_WARM") == "1":
        # CI warm-start gate: a run against a populated store must not search.
        # Checked *before* saving — persisting the newly searched entries on
        # a failing gate would make a retry self-heal and mask the regression.
        if not warm:
            print("[plan-store] ASSERT_WARM set but no store was loaded")
            sys.exit(1)
        if st["misses"] > 0:
            print(f"[plan-store] warm-start FAILED: {st['misses']} DSE searches "
                  "ran against a populated store")
            sys.exit(1)
        print("[plan-store] warm-start OK: zero DSE searches")
    if store_path:
        save_plan_store(store_path)
        print(f"[plan-store] saved to {store_path}")
    bench_out = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_pr10.json")
    try:
        with open(bench_out, "w") as f:
            json.dump(results, f, indent=2, default=str)
        print(f"[bench] consolidated results for {sorted(results)} "
              f"-> {bench_out}")
    except Exception:
        traceback.print_exc()
        failures.append("BENCH_pr10.json")
    if failures:
        print(f"\nbenchmark FAILURES: {failures}")
        sys.exit(1)
    print("\nall benchmarks completed")


if __name__ == "__main__":
    main()
