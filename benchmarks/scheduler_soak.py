"""Scheduler soak: batched vs sequential prefill on the virtual clock.

    PYTHONPATH=src python -m benchmarks.scheduler_soak --requests 200 \
        --out scheduler_stats.json

Replays ONE deterministic mixed prompt-length closed-loop burst (every bucket
of the ladder sees traffic; all arrivals at t=0, admission driven by slot
frees) through the continuous-batching scheduler twice — once in ``prefill_mode="sequential"``
(the pre-coalescing behaviour: one (1, L) prefill launch per admission) and
once in the default batched mode with chunked long-prompt prefill.  Both runs
use a :class:`VirtualClock` with a per-launch cost, so throughput and TTFT
are measured in deterministic virtual seconds — machine-independent, valid to
compare against a stored baseline in CI.

The soak asserts the batched run beats the sequential one on prefill
launches, virtual tokens/s, and p99 TTFT, AND that both modes generate
byte-identical tokens per request (coalescing is a pure launch-count
optimisation).  With ``--baseline`` pointing at a stored sequential-run JSON
(``benchmarks/baselines/scheduler_soak_pr4.json``) and matching knobs, the
batched run must also beat the stored numbers; ``--write-baseline`` emits
that file from the sequential run.

With ``REPRO_PLAN_ASSERT_WARM=1`` the soak is a CI gate: the plan store
named by ``REPRO_PLAN_STORE`` must warm-start the registry and the *entire*
soak — warmup traces included — must incur zero DSE grid searches.  The
soak never writes the store back (a failing gate must not self-heal on
retry; the benchmark harness owns persistence).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.configs import get_config, reduced
from repro.core.engine import plan_store_stats, warm_start_plan_store
from repro.core.template import default_template
from repro.launch.scheduler import (
    SchedulerConfig,
    ServeScheduler,
    VirtualClock,
    replay_trace,
    synthetic_trace,
)
from repro.models import transformer as T

LADDER = (8, 16, 32)

#: knobs that must match for a stored baseline row to be comparable
BASELINE_KEYS = ("arch", "backend", "requests", "slots", "gen", "seed",
                 "ladder", "tick", "launch_cost")


def build_trace(args, vocab):
    """The soak trace: a closed-loop burst — every request arrives at t=0 and
    admission is driven purely by slot frees.  (Timed arrivals would couple
    the trace to each mode's virtual launch costs, making the A/B comparison
    measure arrival phasing instead of coalescing.)  Rebuilt fresh per run —
    Request objects are mutated by the scheduler."""
    return synthetic_trace(args.requests, seed=args.seed, vocab=vocab,
                           ladder=LADDER, max_new=args.gen)


def run_mode(args, cfg, tpl, params, *, mode: str, chunk: int) -> dict:
    sched = ServeScheduler(
        cfg, params, tpl=tpl, clock=VirtualClock(),
        sched=SchedulerConfig(ladder=LADDER, slots=args.slots,
                              max_new_limit=args.gen,
                              max_queue=max(256, args.requests),
                              prefill_mode=mode, prefill_chunk=chunk),
    )
    t0 = time.time()
    sched.warmup()
    warm_s = time.time() - t0
    trace = build_trace(args, cfg.vocab)
    t0 = time.time()
    stats = replay_trace(sched, trace, tick=args.tick,
                         launch_cost=args.launch_cost)
    soak_s = time.time() - t0
    if sched.counters["completed"] != args.requests:
        raise RuntimeError(
            f"soak[{mode}] incomplete: {sched.counters['completed']}"
            f"/{args.requests} requests completed")
    c = sched.counters
    vt = sched.clock.now()
    ttft = stats["ttft"]
    return {
        "mode": mode,
        "prefill_chunk": chunk,
        "warmup_s": round(warm_s, 2),
        "soak_s": round(soak_s, 2),
        "tokens": c["tokens"],
        "tokens_per_s_wall": round(c["tokens"] / max(soak_s, 1e-9), 1),
        "virtual_time": round(vt, 2),
        "tokens_per_vs": round(c["tokens"] / max(vt, 1e-9), 3),
        "prefill_launches": c["prefill_launches"],
        "prefill_coalescing": stats["prefill_coalescing"],
        "chunk_steps": c["chunk_steps"],
        "decode_steps": c["decode_steps"],
        "launches": c["prefill_launches"] + c["chunk_steps"] + c["decode_steps"],
        "ttft_p50": round(ttft.get("p50", 0.0), 3),
        "ttft_p99": round(ttft.get("p99", 0.0), 3),
        "ttft_mean": round(ttft.get("mean", 0.0), 3),
        "stats": stats,
        # keyed by trace position — rids are globally unique across runs
        "generated": {i: list(sched.results[r.rid].generated)
                      for i, r in enumerate(trace)},
        "stats_line": sched.stats_line(),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--backend", default="pallas", choices=["xla", "pallas", "q16"])
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--gen", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunk width for the batched run (0 = whole-bucket; "
                         "chunking trades extra launches for bounded per-tick "
                         "prefill work, so the launch-count soak gates run "
                         "with it off)")
    ap.add_argument("--tick", type=float, default=0.25,
                    help="virtual seconds per scheduler tick")
    ap.add_argument("--launch-cost", type=float, default=0.05,
                    help="virtual seconds charged per compute launch — makes "
                         "launch-count savings visible in virtual time")
    ap.add_argument("--baseline",
                    default=os.path.join(os.path.dirname(__file__),
                                         "baselines",
                                         "scheduler_soak_pr4.json"),
                    help="stored sequential-run JSON to beat ('' = skip)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write the sequential run to --baseline and exit 0")
    ap.add_argument("--out", default="scheduler_stats.json",
                    help="soak comparison JSON artifact path ('' = skip)")
    args = ap.parse_args(argv)

    store_path, loaded = warm_start_plan_store()
    if loaded:
        print(f"[soak] plan store: warm-started {loaded} entries from {store_path}")
    before = plan_store_stats()

    cfg = reduced(get_config(args.arch))
    tpl = default_template(args.backend)
    params = T.init_params(jax.random.PRNGKey(args.seed), cfg)

    knobs = {"arch": cfg.name, "backend": args.backend,
             "requests": args.requests, "slots": args.slots, "gen": args.gen,
             "seed": args.seed, "ladder": list(LADDER), "tick": args.tick,
             "launch_cost": args.launch_cost}

    seq = run_mode(args, cfg, tpl, params, mode="sequential", chunk=0)
    bat = run_mode(args, cfg, tpl, params, mode="batched",
                   chunk=args.prefill_chunk)
    for r in (seq, bat):
        print(f"[soak] {r['mode']:>10}: launches={r['launches']} "
              f"(prefill {r['prefill_launches']}, chunk {r['chunk_steps']}, "
              f"decode {r['decode_steps']}) vtime={r['virtual_time']} "
              f"tok/vs={r['tokens_per_vs']} ttft_p50={r['ttft_p50']} "
              f"ttft_p99={r['ttft_p99']} wall={r['soak_s']}s")
        print(f"[soak] {r['stats_line']}")

    # parity: coalescing + chunking must never change a generated token
    if seq["generated"] != bat["generated"]:
        bad = [i for i in seq["generated"]
               if seq["generated"][i] != bat["generated"].get(i)]
        raise RuntimeError(
            f"batched mode changed generated tokens for requests {bad[:5]}")
    print(f"[soak] parity OK: {len(seq['generated'])} requests byte-identical "
          "across modes")

    if args.write_baseline:
        row = {"bench": "scheduler_soak_baseline", **knobs,
               **{k: seq[k] for k in
                  ("prefill_launches", "launches", "virtual_time",
                   "tokens", "tokens_per_vs", "ttft_p50", "ttft_p99")}}
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        with open(args.baseline, "w") as f:
            json.dump(row, f, indent=1)
            f.write("\n")
        print(f"[soak] sequential baseline written to {args.baseline}")
        return row

    # the batched run must beat the sequential one on the same trace
    assert bat["prefill_launches"] < seq["prefill_launches"], (
        f"no launch saving: batched {bat['prefill_launches']} vs "
        f"sequential {seq['prefill_launches']}")
    assert bat["tokens_per_vs"] > seq["tokens_per_vs"], (
        f"no virtual-throughput win: batched {bat['tokens_per_vs']} vs "
        f"sequential {seq['tokens_per_vs']} tok/vs")
    assert bat["ttft_p99"] <= seq["ttft_p99"], (
        f"p99 TTFT regressed: batched {bat['ttft_p99']} vs "
        f"sequential {seq['ttft_p99']}")
    print("[soak] batched beats sequential: "
          f"launches {seq['launches']}->{bat['launches']}, "
          f"tok/vs {seq['tokens_per_vs']}->{bat['tokens_per_vs']}, "
          f"ttft_p99 {seq['ttft_p99']}->{bat['ttft_p99']}")

    # ... and the stored PR 4 baseline, when the knobs match
    if args.baseline and os.path.exists(args.baseline):
        with open(args.baseline) as f:
            base = json.load(f)
        if all(base.get(k) == knobs[k] for k in BASELINE_KEYS):
            assert bat["tokens_per_vs"] > base["tokens_per_vs"], (
                f"batched {bat['tokens_per_vs']} tok/vs does not beat stored "
                f"baseline {base['tokens_per_vs']}")
            assert bat["ttft_p99"] <= base["ttft_p99"], (
                f"batched ttft_p99 {bat['ttft_p99']} worse than stored "
                f"baseline {base['ttft_p99']}")
            print(f"[soak] beats stored baseline {args.baseline}: "
                  f"tok/vs {base['tokens_per_vs']}->{bat['tokens_per_vs']}, "
                  f"ttft_p99 {base['ttft_p99']}->{bat['ttft_p99']}")
        else:
            print(f"[soak] stored baseline knobs differ; comparison skipped")

    after = plan_store_stats()
    new_misses = after["misses"] - before["misses"]
    row = {
        "bench": "scheduler_soak",
        **knobs,
        "new_dse_misses": new_misses,
        "warm_started_entries": loaded,
        "sequential": {k: v for k, v in seq.items()
                       if k not in ("generated", "stats", "stats_line")},
        "batched": {k: v for k, v in bat.items()
                    if k not in ("generated", "stats", "stats_line")},
        **{k: v for k, v in bat["stats"].items() if k != "counters"},
    }
    print(json.dumps({k: v for k, v in row.items()
                      if k not in ("sequential", "batched", "buckets")}))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(row, f, indent=1)
            f.write("\n")
        print(f"[soak] comparison stats written to {args.out}")
    if os.environ.get("REPRO_PLAN_ASSERT_WARM") == "1":
        if not loaded:
            raise RuntimeError("ASSERT_WARM set but no plan store was loaded")
        if new_misses > 0:
            raise RuntimeError(
                f"warm-start failed: soak incurred {new_misses} DSE searches "
                "against a populated store"
            )
        print("[soak] warm-start OK: zero DSE searches across the whole soak")
    return row


if __name__ == "__main__":
    main()
