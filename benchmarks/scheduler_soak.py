"""Scheduler soak: a mixed prefill/decode trace on the virtual clock.

    PYTHONPATH=src python -m benchmarks.scheduler_soak --requests 200 \
        --out scheduler_stats.json

Replays a deterministic mixed prompt-length arrival trace (every bucket of
the ladder sees traffic; arrivals part-burst, part-spaced) through the
continuous-batching scheduler under a :class:`VirtualClock` — no wall-clock
sleeps, so the soak is pure scheduler + compute work.  Emits the per-bucket
stats JSON as an artifact.

With ``REPRO_PLAN_ASSERT_WARM=1`` the soak is a CI gate: the plan store
named by ``REPRO_PLAN_STORE`` must warm-start the registry and the *entire*
soak — warmup traces included — must incur zero DSE grid searches.  The
soak never writes the store back (a failing gate must not self-heal on
retry; the benchmark harness owns persistence).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.configs import get_config, reduced
from repro.core.engine import plan_store_stats, warm_start_plan_store
from repro.core.template import default_template
from repro.launch.scheduler import (
    SchedulerConfig,
    ServeScheduler,
    VirtualClock,
    replay_trace,
    synthetic_trace,
)
from repro.models import transformer as T

LADDER = (8, 16, 32)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--backend", default="pallas", choices=["xla", "pallas", "q16"])
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--gen", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="scheduler_stats.json",
                    help="per-bucket stats JSON artifact path ('' = skip)")
    args = ap.parse_args(argv)

    store_path, loaded = warm_start_plan_store()
    if loaded:
        print(f"[soak] plan store: warm-started {loaded} entries from {store_path}")
    before = plan_store_stats()

    cfg = reduced(get_config(args.arch))
    tpl = default_template(args.backend)
    params = T.init_params(jax.random.PRNGKey(args.seed), cfg)
    sched = ServeScheduler(
        cfg, params, tpl=tpl, clock=VirtualClock(),
        sched=SchedulerConfig(ladder=LADDER, slots=args.slots,
                              max_new_limit=args.gen),
    )
    t0 = time.time()
    sched.warmup()
    warm_s = time.time() - t0
    # half the trace arrives as a burst at t=0, half spaced out — both the
    # saturated and the trickle regime in one soak
    burst = synthetic_trace(args.requests // 2, seed=args.seed,
                            vocab=cfg.vocab, ladder=LADDER, max_new=args.gen)
    spaced = synthetic_trace(args.requests - len(burst), seed=args.seed + 1,
                             vocab=cfg.vocab, ladder=LADDER, max_new=args.gen,
                             arrival_every=0.5)
    t0 = time.time()
    stats = replay_trace(sched, burst + spaced, tick=0.25)
    soak_s = time.time() - t0

    after = plan_store_stats()
    new_misses = after["misses"] - before["misses"]
    row = {
        "bench": "scheduler_soak",
        "arch": cfg.name,
        "backend": args.backend,
        "requests": args.requests,
        "slots": args.slots,
        "ladder": list(LADDER),
        "warmup_s": round(warm_s, 2),
        "soak_s": round(soak_s, 2),
        "virtual_time": round(sched.clock.now(), 2),
        "new_dse_misses": new_misses,
        "warm_started_entries": loaded,
        **stats,
    }
    print(json.dumps({k: v for k, v in row.items() if k != "counters"}))
    print(f"[soak] {sched.stats_line()}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(row, f, indent=1)
            f.write("\n")
        print(f"[soak] per-bucket stats written to {args.out}")
    if sched.counters["completed"] != args.requests:
        raise RuntimeError(
            f"soak incomplete: {sched.counters['completed']}/{args.requests} "
            "requests completed"
        )
    if os.environ.get("REPRO_PLAN_ASSERT_WARM") == "1":
        if not loaded:
            raise RuntimeError("ASSERT_WARM set but no plan store was loaded")
        if new_misses > 0:
            raise RuntimeError(
                f"warm-start failed: soak incurred {new_misses} DSE searches "
                "against a populated store"
            )
        print("[soak] warm-start OK: zero DSE searches across the whole soak")
    return row


if __name__ == "__main__":
    main()
