"""Kernel-plane benchmark: the Pallas compute unit across workload GEMMs.

No TPU in this container, so wall-clock numbers would measure the Python
interpreter, not the kernel.  Instead this reports the *structural* kernel
metrics the DSE optimizes — chosen BlockSpec, VMEM working set, MXU
efficiency, arithmetic intensity vs the v5e ridge point, and the modeled
MXU-bound time per GEMM — and runs a correctness pass (interpret=True) of
every kernel against its oracle at a reduced shape.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import plan_cache_for
from repro.core.tiling import TPU_V5E
from repro.kernels import ops, ref

CASES = {
    # label: (m, n, k) — per-device GEMMs from the assigned workloads
    "qwen2.5-32b train mlp-up": (65536 // 16, 27648 // 16, 5120),
    "qwen2.5-32b train qkv": (65536 // 16, 5120 // 16 + 1280, 5120),
    "llama-90b train mlp-up": (65536 // 16, 28672 // 16, 8192),
    "qwen2-0.5b decode lm-head": (128 // 16, 151936 // 16, 896),
    "granite expert ffn": (512, 512, 1536),
    "alexnet conv2 im2col": (27 * 27 * 4, 192, 64 * 25),
}


def structural_rows() -> list[dict]:
    rows = []
    ridge = TPU_V5E.peak_bf16_flops / TPU_V5E.hbm_bw
    registry = plan_cache_for(TPU_V5E)  # warm runs serve these from the store
    for label, (m, n, k) in CASES.items():
        blk = registry.block_for(m, n, k)
        flops = 2.0 * m * n * k
        mxu_s = flops / (TPU_V5E.peak_bf16_flops * blk.mxu_efficiency())
        hbm_s = (m * k + k * n + m * n) * 2 / TPU_V5E.hbm_bw
        rows.append({
            "gemm": label,
            "mnk": (m, n, k),
            "block": (blk.bm, blk.bn, blk.bk),
            "vmem_MiB": round(blk.vmem_bytes() / 2**20, 1),
            "mxu_eff": round(blk.mxu_efficiency(), 3),
            "ai": round(blk.arithmetic_intensity(), 1),
            "ridge": round(ridge, 1),
            "bound": "compute" if blk.arithmetic_intensity() >= ridge else "memory",
            "mxu_us": round(mxu_s * 1e6, 1),
            "hbm_us": round(hbm_s * 1e6, 1),
        })
    return rows


def correctness_pass() -> dict:
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (96, 160)) * 0.3
    w = jax.random.normal(jax.random.fold_in(key, 1), (160, 64)) * 0.3
    mm = float(jnp.abs(ops.matmul_fp(x, w, interpret=True) - ref.matmul_ref(x, w)).max())
    from repro.core.quantization import quantize
    q = float(jnp.abs(
        ops.matmul_q16(quantize(x), quantize(w), interpret=True).astype(jnp.int32)
        - ref.matmul_q16_ref(quantize(x), quantize(w)).astype(jnp.int32)
    ).max())
    xi = jax.random.normal(key, (1, 10, 10, 4))
    wi = jax.random.normal(jax.random.fold_in(key, 2), (3, 3, 4, 8)) * 0.3
    cv = float(jnp.abs(ops.conv2d(xi, wi, interpret=True) - ref.conv2d_ref(xi, wi)).max())
    qq = jax.random.normal(key, (1, 4, 64, 32)) * 0.3
    kk = jax.random.normal(jax.random.fold_in(key, 3), (1, 2, 64, 32)) * 0.3
    fa_out = ops.flash_attention(qq, kk, kk, causal=True, bq=32, bk=32, interpret=True)
    qf = qq.reshape(1, 2, 2, 64, 32).reshape(4, 64, 32)
    kf = jnp.broadcast_to(kk[:, :, None], (1, 2, 2, 64, 32)).reshape(4, 64, 32)
    fa = float(jnp.abs(fa_out.reshape(4, 64, 32) - ref.attention_ref(qf, kf, kf)).max())
    return {"matmul_fp": mm, "matmul_q16_raw": q, "conv2d": cv, "flash_attention": fa}


def _time_conv(route: str, x, w, reps: int = 3) -> float:
    fn = lambda: jax.block_until_ready(
        ops.conv2d(x, w, stride=1, padding=1, route=route, interpret=True)
    )
    fn()  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def im2col_vs_direct_row(n=1, hw=16, cin=16, cout=32, k=3, pad=1) -> dict:
    """Structural + measured comparison of the two conv routes, as JSON.

    Bytes are the HBM traffic of each route's GEMM stage (f32): im2col must
    materialize the (N·Ho·Wo, Cin·K²) column matrix, the direct kernel
    streams the image slab once. Wall time is interpret=True on CPU (it
    measures the Pallas interpreter, not the MXU — useful only as a relative
    trajectory between PRs; the structural bytes are the hardware story).
    """
    ho = wo = hw + 2 * pad - k + 1
    m, nn, kk = n * ho * wo, cout, cin * k * k
    im2col_bytes = (m * kk + kk * nn + m * nn) * 4
    hp = hw + 2 * pad
    direct_bytes = (n * hp * hp * cin + k * k * cin * cout + n * ho * wo * cout) * 4
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, hw, hw, cin)) * 0.3
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, k, cin, cout)) * 0.3
    return {
        "bench": "conv_route_comparison",
        "conv": {"n": n, "hw": hw, "cin": cin, "cout": cout, "k": k, "pad": pad},
        "gemm_mnk": [m, nn, kk],
        "im2col_gemm_bytes": im2col_bytes,
        "direct_gemm_bytes": direct_bytes,
        "bytes_ratio_im2col_over_direct": round(im2col_bytes / direct_bytes, 2),
        "im2col_wall_s_interpret": round(_time_conv("im2col", x, w), 4),
        "direct_wall_s_interpret": round(_time_conv("direct", x, w), 4),
    }


def spatial_tiling_row() -> dict:
    """Oracle row for the spatially-tiled direct conv route, as JSON.

    Structural: the acceptance-criteria layer (3×3, Cin=64, 512×512) whose
    untiled slab exceeds the v5e VMEM budget must plan ``direct`` with ≥ 2
    spatial tiles, a (𝒯, ℭ) DMA-halo tiling, and a modeled working set
    inside the budget.  The regime columns compare the DMA-halo scheme
    against the best legal two-block config *at that config's tile dims*
    (weights and output write-back move identically under either halo
    scheme, so the honest gate is VMEM residency and the input-stream
    traffic term — both must come out ≤ 0.6×).  Numeric: on a shrunken
    budget the same planner decision is executed end-to-end and checked
    against the im2col route (interpret=True).
    """
    import dataclasses

    from repro.core.engine import Engine
    from repro.core.dse import (direct_conv_input_traffic, direct_conv_vmem,
                                explore_conv_spatial)
    from repro.core.template import TemplateConfig

    eng = Engine(TemplateConfig(backend="pallas", interpret=True))
    plan = eng.plan_conv((1, 512, 512, 64), (3, 3, 64, 64), stride=1, padding=1)
    untiled = direct_conv_vmem(514, 514, 64, 3, 3, 512, 512, plan.tau or 64, 4)
    # best legal two-block config on the same layer (large top: the DMA
    # configs dominate the ranking, the two-block baseline sits further down)
    two_blk = next(c for c in explore_conv_spatial(
        514, 514, 64, 3, 3, 512, 512, 64, 1, TPU_V5E, 4, top=4096)
        if c.halo_mode == "two_block")
    vm = {mode: direct_conv_vmem(
        514, 514, 64, 3, 3, 512, 512, two_blk.tau, 4,
        tile_rows=two_blk.tile_rows, halo_mode=mode)
        for mode in ("two_block", "dma")}
    tr = {mode: direct_conv_input_traffic(
        514, 514, 64, 3, 3, 512, 512, 64, 1, two_blk.tau, 4,
        tile_rows=two_blk.tile_rows, halo_mode=mode)
        for mode in ("two_block", "dma")}
    # numeric differential at a budget that forces tiling on a small layer
    hw = dataclasses.replace(TPU_V5E, vmem_bytes=256 * 1024)
    eng_s = Engine(TemplateConfig(backend="pallas", interpret=True, hw=hw))
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 32, 32, 32)) * 0.3
    w = jax.random.normal(jax.random.fold_in(key, 1), (3, 3, 32, 16)) * 0.3
    p_dir = eng_s.plan_conv(x.shape, w.shape, stride=1, padding=1)
    p_gem = eng_s.plan_conv(x.shape, w.shape, stride=1, padding=1, route="im2col")
    err = float(jnp.abs(
        eng_s.conv2d(x, w, stride=1, padding=1, plan=p_dir)
        - eng_s.conv2d(x, w, stride=1, padding=1, plan=p_gem)
    ).max())
    return {
        "bench": "spatial_tiled_direct_conv",
        "layer": {"hw": 512, "cin": 64, "cout": 64, "k": 3, "pad": 1},
        "route": plan.route,
        "tau": plan.tau,
        "tile_rows": plan.tile_rows,
        "spatial_tiles": plan.spatial_tiles,
        "tile_cols": plan.tile_cols,
        "col_tiles": plan.col_tiles,
        "halo_mode": plan.halo_mode,
        "vmem_MiB": round(plan.vmem_bytes / 2**20, 1),
        "untiled_vmem_MiB": round(untiled / 2**20, 1),
        "budget_MiB": round(TPU_V5E.vmem_bytes / 2**20, 1),
        "two_block_tile_rows": two_blk.tile_rows,
        "vmem_MiB_two_block": round(vm["two_block"] / 2**20, 1),
        "vmem_MiB_dma_same_tile": round(vm["dma"] / 2**20, 1),
        "hbm_in_MiB_two_block": round(tr["two_block"] / 2**20, 1),
        "hbm_in_MiB_dma_same_tile": round(tr["dma"] / 2**20, 1),
        "vmem_ratio_dma_over_two_block": round(vm["dma"] / vm["two_block"], 3),
        "hbm_ratio_dma_over_two_block": round(tr["dma"] / tr["two_block"], 3),
        "small_layer_tiles": p_dir.spatial_tiles,
        "small_layer_halo": p_dir.halo_mode,
        "tiled_vs_im2col_max_err": err,
    }


def spatial_shard_row(shards: int = 4) -> dict:
    """Cross-chip spatial (H-slab) sharding row, as JSON (DESIGN.md §10).

    Structural: for every VGG16 @ 224² conv/pool seam under ``shards`` H
    slabs, the modeled bytes the halo exchange moves between neighbor shards
    — ``(S−1)·(up+dn)·N·W·C`` per seam, the ``kh − stride`` rows of the
    paper's dependency analysis — versus the full-activation ring all-gather
    it replaces (``(S−1)·N·H·W·C`` per conv).  The gate is *strict*: every
    seam must exchange fewer bytes than the gather, and the network total
    must come out at least an order of magnitude smaller.  Numeric: the
    grid-resident q16 LeNet forward over 2 slabs must be **bit-identical**
    to the unsharded route (the repo's signature invariant — contraction
    dims never cross a shard boundary).
    """
    from repro.core.quantization import NumericsPolicy
    from repro.core.template import default_template
    from repro.models.cnn import (CNN_ZOO, LENET, cnn_forward, init_cnn,
                                  plan_cnn, quantize_cnn_params)
    from repro.parallel.sharding import spatial_gather_bytes, spatial_halo_bytes

    spec = CNN_ZOO["vgg16"]
    n, itemsize = 1, 2  # q16 activation plane
    tpl = default_template("pallas")
    plan = plan_cnn(tpl, spec, (n, 224, 224, spec.input_ch), spatial=shards)
    hh, ww, ch = 224, 224, spec.input_ch
    layers = []
    halo_total = gather_total = 0
    for i, ((cout, k, stride, pad, pool), cp, ph) in enumerate(
        zip(spec.convs, plan.convs, plan.pool_halos)
    ):
        hs = cp.halo
        halo = spatial_halo_bytes(hs, n, ww, ch, itemsize)
        gather = spatial_gather_bytes(hh, n, ww, ch, shards, itemsize)
        hh = (hh + 2 * pad - k) // stride + 1
        ww = (ww + 2 * pad - k) // stride + 1
        ch = cout
        if pool:
            halo += spatial_halo_bytes(ph, n, ww, ch, itemsize)
            hh //= pool
            ww //= pool
        layers.append({
            "layer": f"conv{i}", "halo_bytes": halo, "gather_bytes": gather,
            "ratio": round(halo / gather, 4),
        })
        halo_total += halo
        gather_total += gather
    # numeric differential: 2-slab grid-resident q16 LeNet, bitwise
    tq = default_template("q16")
    params = init_cnn(jax.random.PRNGKey(0), LENET)
    policy = NumericsPolicy("q16")
    qp = quantize_cnn_params(tq, LENET, params, policy)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 1)) * 0.5
    ref = cnn_forward(tq, LENET, qp, x, policy=policy)
    sp = plan_cnn(tq, LENET, x.shape, spatial=2)
    got = cnn_forward(tq, LENET, qp, x, policy=policy, plan=sp)
    return {
        "bench": "spatial_shard_halo_exchange",
        "net": "vgg16@224",
        "shards": shards,
        "halo_MiB_total": round(halo_total / 2**20, 2),
        "gather_MiB_total": round(gather_total / 2**20, 2),
        "bytes_ratio_halo_over_gather": round(halo_total / gather_total, 4),
        "per_layer_max_ratio": max(l["ratio"] for l in layers),
        "all_layers_halo_below_gather": all(
            l["halo_bytes"] < l["gather_bytes"] for l in layers
        ),
        "layers": layers[:3] + layers[-1:],  # head + tail, keep the row short
        "lenet_q16_2shard_bitwise": bool(
            np.array_equal(np.asarray(got), np.asarray(ref))
        ),
    }


def plan_store_warm_start_row() -> dict:
    """Cold-vs-warm plan time through a persisted store, as JSON.

    Plans a fixed shape set into an *isolated* registry (so the benchmark
    leaves the process-global registries untouched), saves it, loads it into
    a fresh registry, and re-plans: the warm pass must perform zero DSE grid
    searches and be faster than the cold pass by roughly the full search
    cost.
    """
    import os
    import tempfile

    from repro.core.engine import Engine, PlanRegistry
    from repro.core.template import TemplateConfig

    gemms = [(256, 512, 256), (1024, 1024, 512), (4096, 1728, 5120)]
    convs = [((1, 32, 32, 16), (3, 3, 16, 32)), ((1, 224, 224, 3), (11, 11, 3, 64))]

    def plan_all(reg):
        eng = Engine(TemplateConfig(backend="pallas", interpret=True), plan_cache=reg)
        t0 = time.perf_counter()
        for m, n, k in gemms:
            eng.plan_gemm(m, n, k)
        for x_shape, w_shape in convs:
            eng.plan_conv(x_shape, w_shape, stride=1, padding=1)
        return time.perf_counter() - t0

    cold = PlanRegistry()
    cold_s = plan_all(cold)
    fd, path = tempfile.mkstemp(suffix=".json")
    os.close(fd)
    try:
        cold.save(path)
        warm = PlanRegistry()
        warm.load(path)
        warm_s = plan_all(warm)
    finally:
        os.unlink(path)
    return {
        "bench": "plan_store_warm_start",
        "entries": len(cold),
        "cold_plan_s": round(cold_s, 4),
        "warm_plan_s": round(warm_s, 4),
        "speedup": round(cold_s / max(warm_s, 1e-9), 1),
        "cold_misses": cold.misses,
        "warm_misses": warm.misses,
    }


def q16_residency_row() -> dict:
    """Fixed-point residency oracle row (DESIGN.md §8), as JSON.

    Runs the grid-resident LeNet forward (exactly one quantize + one
    dequantize for the whole network, asserted via engine counters) and
    reports end-to-end drift vs float plus the structural per-token /
    per-sample activation bytes of the q16 vs float paths — the q16 side
    must move at most half the bytes.
    """
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from benchmarks.q16_drift import (
        lenet_row, transformer_decode_bytes,
    )
    from repro.configs import get_config, reduced

    lenet = lenet_row(batches=2)
    cfg = reduced(get_config("qwen2-0.5b"))
    row = {
        "bench": "q16_residency",
        "lenet_argmax_agreement": lenet["argmax_agreement"],
        "lenet_logit_mae": lenet["logit_mae"],
        "lenet_quantize_calls_per_fwd": lenet["quantize_calls"] // lenet["batches"],
        "lenet_dequantize_calls_per_fwd": lenet["dequantize_calls"] // lenet["batches"],
        "lenet_act_bytes": {"float": lenet["act_bytes_float"],
                            "q16": lenet["act_bytes_q16"]},
        "transformer_per_token_bytes": {
            "float": transformer_decode_bytes(cfg, 48, act_bytes=4, kv_bytes=4),
            "q16": transformer_decode_bytes(cfg, 48, act_bytes=2, kv_bytes=2),
        },
    }
    b = row["transformer_per_token_bytes"]
    row["bytes_ratio"] = round(b["q16"] / b["float"], 3)
    return row


def precision_dse_row() -> dict:
    """Mixed int8/int16 precision-DSE gate row (DESIGN.md §11), as JSON.

    Runs the drift-aware per-layer precision DSE over the QAT-trained LeNet
    (shared with ``benchmarks.precision_drift``, so the cold CI run pins one
    consistent set of measured choices) and gates the two §11 laws: every
    int8-chosen layer moves *exactly half* the q16 activation bytes, and the
    composed mixed network keeps >= 99% argmax agreement with its float
    reference.
    """
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from benchmarks.precision_drift import lenet_precision_sweep

    row = lenet_precision_sweep()
    return {
        "bench": "precision_dse",
        "net": row["net"],
        "budget": row["budget"],
        "base_fmt": row["base_fmt"],
        "plan": row["plan"],
        "int8_layers": row["int8_layers"],
        "argmax_agreement": row["argmax_agreement"],
        "act_bytes_q16": row["act_bytes_q16"],
        "act_bytes_mixed": row["act_bytes_mixed"],
        "int8_layer_bytes_q16": row["int8_layer_bytes_q16"],
        "int8_layer_bytes_mixed": row["int8_layer_bytes_mixed"],
        "int8_half_bytes_exact": all(
            row["int8_layer_bytes_mixed"][n] * 2 == row["int8_layer_bytes_q16"][n]
            for n in row["int8_layers"]
        ),
    }


def scheduler_mixed_trace_row() -> dict:
    """Continuous-batching mixed-trace throughput row, as JSON.

    A small mixed prompt-length trace through the serve scheduler on a
    virtual clock (pallas backend, so every GEMM consults the PlanRegistry):
    reports decode coalescing (decode steps vs the sequential equivalent),
    prefill coalescing (admitted rows per (B, L) prefill launch — with at
    most one launch per occupied bucket rung per tick), mean slot occupancy,
    the DSE misses incurred *after* warmup (must be 0 — the bucket ladder is
    the whole point), and a byte-identical parity check of two requests
    against the unbatched `generate()` path.
    """
    from repro.configs import get_config, reduced
    from repro.core.template import default_template
    from repro.launch.scheduler import (
        SchedulerConfig, ServeScheduler, VirtualClock, replay_trace,
        synthetic_trace,
    )
    from repro.launch.serve import generate
    from repro.models import transformer as T

    cfg = reduced(get_config("qwen2-0.5b"))
    tpl = default_template("pallas")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    ladder = (8, 16)
    sched = ServeScheduler(
        cfg, params, tpl=tpl, clock=VirtualClock(),
        sched=SchedulerConfig(ladder=ladder, slots=3, max_new_limit=3),
    )
    sched.warmup()
    m0 = sched.registry.misses
    trace = synthetic_trace(6, seed=2, vocab=cfg.vocab, ladder=ladder, max_new=3)
    for r in trace:
        r.max_new = 3  # fixed budget: the coalescing ratio is then structural
    t0 = time.perf_counter()
    stats = replay_trace(sched, trace, tick=1.0)
    wall = time.perf_counter() - t0
    # delta captured here: the unbatched parity references below legitimately
    # plan their own exact-length (non-bucketed) shapes
    post_warmup_misses = sched.registry.misses - m0
    c = stats["counters"]
    sequential_steps = sum(r.max_new - 1 for r in trace)
    parity = all(
        np.asarray(sched.results[r.rid].generated).tolist()
        == np.asarray(generate(cfg, params, jnp.asarray([r.prompt], jnp.int32),
                               gen=r.max_new, tpl=tpl))[0].tolist()
        for r in trace[:2]
    )
    # per tick, one coalesced launch per occupied rung — never one per row
    by_rid = {r.rid: r for r in trace}
    launches_bounded = all(
        ev["prefill_launches"] <= len({by_rid[rid].bucket
                                       for rid in ev["admitted"]})
        for ev in sched.history
    )
    return {
        "bench": "scheduler_mixed_trace",
        "requests": len(trace),
        "ladder": list(ladder),
        "slots": 3,
        "completed": c["completed"],
        "decode_steps": c["decode_steps"],
        "sequential_decode_steps": sequential_steps,
        "prefill_launches": c["prefill_launches"],
        "prefill_rows": c["prefill_rows"],
        "prefill_coalescing": stats["prefill_coalescing"],
        "launches_bounded_by_rungs": launches_bounded,
        "ttft_p50": round(stats["ttft"].get("p50", 0.0), 3),
        "ttft_p99": round(stats["ttft"].get("p99", 0.0), 3),
        "mean_occupancy": stats["mean_occupancy"],
        "tokens": c["tokens"],
        "wall_s_interpret": round(wall, 3),
        "post_warmup_misses": post_warmup_misses,
        "byte_identical_vs_unbatched": parity,
    }


def router_failover_row() -> dict:
    """Replicated-serving failover row, as JSON (in-process, virtual clock).

    Two ServeScheduler replicas behind a :class:`ReplicaRouter`, one kill
    injected mid-stream via :class:`FaultPlan`, sessions restored from the
    dead replica's checkpoint: the global token ledger must come out
    byte-identical to an unkilled single-replica run (zero lost, zero
    duplicated tokens), with every regenerated overlap token verified equal
    before being suppressed as a duplicate (DESIGN.md §9).
    """
    import tempfile

    from repro.configs import get_config, reduced
    from repro.core.template import default_template
    from repro.launch.router import ReplicaRouter
    from repro.launch.scheduler import (Request, SchedulerConfig,
                                        ServeScheduler, VirtualClock)
    from repro.models import transformer as T
    from repro.runtime.failover import FaultPlan

    cfg = reduced(get_config("qwen2-0.5b"))
    tpl = default_template("pallas")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    ladder = (8, 16, 24)  # top rung holds max prompt 16 + max_new 4 resume

    def make_sched(rid, clock):
        return ServeScheduler(
            cfg, params, tpl=tpl, clock=clock,
            sched=SchedulerConfig(ladder=ladder, slots=3, max_new_limit=4))

    def trace(base_rid):
        rng = np.random.default_rng(3)
        return [Request(prompt=tuple(int(t) for t in rng.integers(0, 96, n)),
                        max_new=4, arrival=0.0, rid=base_rid + i)
                for i, n in enumerate([5, 9, 3, 15, 8, 16, 2, 11])]

    reference = ReplicaRouter(make_sched, 1, clock=VirtualClock())
    ref_trace = trace(50_000)
    reference.run(ref_trace)
    ref = {i: reference.ledger.tokens(r.rid) for i, r in enumerate(ref_trace)}

    with tempfile.TemporaryDirectory() as ckpt:
        router = ReplicaRouter(
            make_sched, 2, clock=VirtualClock(),
            fault_plan=FaultPlan(kills=((2, 0),)),
            checkpoint_dir=ckpt, checkpoint_every=1)
        kill_trace = trace(51_000)
        t0 = time.perf_counter()
        stats = router.run(kill_trace)
        wall = time.perf_counter() - t0
    got = {i: router.ledger.tokens(r.rid) for i, r in enumerate(kill_trace)}
    router.assert_exactly_once()
    c = stats["counters"]
    return {
        "bench": "router_failover",
        "replicas": 2,
        "requests": len(kill_trace),
        "kill_tick": 2,
        "ticks": stats["ticks"],
        "completed": stats["completed"],
        "killed": c.get("killed", 0),
        "restarted": c.get("restarted", 0),
        "requeued_sessions": c.get("requeued_sessions", 0),
        "restored_sessions": c.get("restored_sessions", 0),
        "restored_tokens": c.get("restored_tokens", 0),
        "duplicates_suppressed": stats["duplicates_suppressed"],
        "ledger_tokens": c.get("ledger_tokens", 0),
        "byte_identical_vs_unkilled": got == ref,
        "wall_s_interpret": round(wall, 3),
        "stats_line": router.stats_line(),
    }


def main():
    print("== Kernel structural table (TPU v5e targets) ==")
    print(f"{'gemm':28s} {'block':>16s} {'vmem':>6s} {'mxu':>5s} "
          f"{'AI':>6s} {'bound':>8s} {'mxu_us':>8s} {'hbm_us':>8s}")
    for r in structural_rows():
        print(f"{r['gemm']:28s} {str(r['block']):>16s} {r['vmem_MiB']:6.1f} "
              f"{r['mxu_eff']:5.2f} {r['ai']:6.1f} {r['bound']:>8s} "
              f"{r['mxu_us']:8.1f} {r['hbm_us']:8.1f}")
    print("\n== Kernel correctness vs oracles (interpret=True) ==")
    for k, v in correctness_pass().items():
        print(f"  {k:18s} max|err| = {v:.2e}")
    print("\n== im2col vs direct conv route (JSON, append-able trajectory) ==")
    row = im2col_vs_direct_row()
    print(json.dumps(row))
    print("\n== spatial-tiled direct conv (JSON, append-able trajectory) ==")
    tiled = spatial_tiling_row()
    print(json.dumps(tiled))
    assert tiled["route"] == "direct" and tiled["spatial_tiles"] >= 2
    assert tiled["halo_mode"] == "dma" and tiled["col_tiles"] >= 2, \
        "the 512² layer must plan the (T, C) DMA-halo regime, not fall back"
    assert tiled["vmem_ratio_dma_over_two_block"] <= 0.6, \
        "DMA-halo VMEM residency must be at most 0.6x the two-block scheme"
    assert tiled["hbm_ratio_dma_over_two_block"] <= 0.6, \
        "DMA-halo input re-streaming must be at most 0.6x the two-block scheme"
    assert tiled["tiled_vs_im2col_max_err"] < 1e-4
    print("\n== plan store cold vs warm (JSON, append-able trajectory) ==")
    warm_row = plan_store_warm_start_row()
    print(json.dumps(warm_row))
    assert warm_row["warm_misses"] == 0, "warm registry must not re-search"
    assert warm_row["cold_misses"] == warm_row["entries"]
    print("\n== q16 fixed-point residency (JSON, append-able trajectory) ==")
    qrow = q16_residency_row()
    print(json.dumps(qrow))
    assert qrow["lenet_quantize_calls_per_fwd"] == 1, \
        "grid-resident LeNet must quantize only its input"
    assert qrow["lenet_dequantize_calls_per_fwd"] == 1, \
        "grid-resident LeNet must dequantize only its classifier read-out"
    assert qrow["bytes_ratio"] <= 0.5, \
        "q16 per-token activation bytes must be at most half the float path"
    assert qrow["lenet_argmax_agreement"] >= 0.99
    print("\n== precision DSE: mixed int8/int16 plan (JSON, append-able trajectory) ==")
    prow = precision_dse_row()
    print(json.dumps(prow))
    assert prow["int8_layers"], \
        "the QAT-trained LeNet must drop at least one layer to the int8 rung"
    assert prow["int8_half_bytes_exact"], \
        "an int8-chosen layer must move exactly half the q16 activation bytes"
    assert prow["argmax_agreement"] >= 0.99, \
        "the composed mixed int8/int16 network fell below 99% argmax agreement"
    print("\n== continuous-batching mixed trace (JSON, append-able trajectory) ==")
    sched_row = scheduler_mixed_trace_row()
    print(json.dumps(sched_row))
    assert sched_row["completed"] == sched_row["requests"]
    assert sched_row["post_warmup_misses"] == 0, \
        "bucketed traffic must not re-search after warmup"
    assert sched_row["byte_identical_vs_unbatched"], \
        "coalesced decode diverged from the unbatched path"
    assert sched_row["decode_steps"] < sched_row["sequential_decode_steps"]
    assert sched_row["prefill_launches"] < sched_row["requests"], \
        "bursty admissions must coalesce into fewer (B, L) prefill launches"
    assert sched_row["prefill_coalescing"] > 1.0
    assert sched_row["launches_bounded_by_rungs"], \
        "a tick issued more prefill launches than occupied bucket rungs"
    print("\n== replicated-serving failover (JSON, append-able trajectory) ==")
    frow = router_failover_row()
    print(json.dumps({k: v for k, v in frow.items() if k != "stats_line"}))
    print("  " + frow["stats_line"])
    assert frow["byte_identical_vs_unkilled"], \
        "failover changed the token ledger (lost or corrupted tokens)"
    assert frow["completed"] == frow["requests"]
    assert frow["killed"] == 1 and frow["restarted"] == 1
    assert frow["requeued_sessions"] > 0, \
        "the kill must catch in-flight sessions for the row to mean anything"
    print("\n== spatial H-slab sharding: halo vs gather bytes (JSON) ==")
    srow = spatial_shard_row()
    print(json.dumps(srow))
    assert srow["all_layers_halo_below_gather"], \
        "a layer's halo exchange moved >= the full-activation gather"
    assert srow["per_layer_max_ratio"] < 1.0
    assert srow["bytes_ratio_halo_over_gather"] < 0.1, \
        "network-total halo traffic should be an order below the gather"
    assert srow["lenet_q16_2shard_bitwise"], \
        "spatially-sharded q16 forward diverged bitwise from unsharded"
    print("\n== VGG16 @ 512x512 network plan (route/tile regressions diff here) ==")
    from repro.core.template import default_template
    from repro.models.cnn import CNN_ZOO, plan_cnn

    net = plan_cnn(default_template("pallas"), CNN_ZOO["vgg16"], (1, 512, 512, 3))
    for line in net.describe():
        print("  " + line)
    return structural_rows()


if __name__ == "__main__":
    main()
