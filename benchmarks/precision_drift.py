"""Per-layer precision drift sweep + end-to-end fixed-point drift and bytes.

Extends the original ``q16_drift`` benchmark (whose rows and gates it still
emits — ``benchmarks.q16_drift`` remains a thin alias of this module) with
the measurement side of the drift-aware precision DSE (DESIGN.md §11):

  * solo-flip drift rows — for every layer (LeNet) / scan group (reduced
    transformer), run the network with *only* that layer's activations
    dropped to the int8 rung of the calibrated grid and record the argmax
    agreement vs the float reference.  The emitted ``drift`` mapping is
    exactly the dict :func:`repro.models.cnn.calibrate_cnn_precision` /
    :func:`repro.models.transformer.calibrate_precision` consume via their
    ``drift=`` argument, so a stored JSON short-circuits the sweep.
  * the chosen mixed plan — the cheapest grid per layer meeting the network
    accuracy budget — plus its structural activation bytes: an int8-chosen
    layer moves exactly half the q16 bytes (1 vs 2 bytes per element).

Drift is measured teacher-forced (per-position logits under identical
inputs), so one early disagreement cannot cascade into a misleadingly low
token match.  Bytes are structural: activations crossing the compute unit
between layers plus KV-cache traffic, at 2 bytes (int16) / 1 byte (int8)
vs 4 (f32); float islands run f32 on both paths and the final logits are
model *output*, so neither is counted.

    PYTHONPATH=src python -m benchmarks.precision_drift
        [--out precision_drift.json] [--assert-agreement 0.99]
        [--budget 0.99]
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np


def _agreement(lf, lq) -> dict:
    lf, lq = jnp.asarray(lf), jnp.asarray(lq)
    return {
        "logit_mae": float(jnp.abs(lf - lq).mean()),
        "logit_max_err": float(jnp.abs(lf - lq).max()),
        "argmax_agreement": float(
            (jnp.argmax(lf, -1) == jnp.argmax(lq, -1)).mean()
        ),
    }


# ---------------------------------------------------------------------------
# structural bytes (per token / per sample activations crossing the unit)
# ---------------------------------------------------------------------------


def _transformer_decode_elements(cfg, cache_len: int) -> tuple:
    """(per-layer activation, per-layer KV, head) elements one decode token
    moves through the compute unit — the layer-count-free building block
    shared by the uniform and mixed byte accountings."""
    d = cfg.d_model
    qh = cfg.eff_heads * cfg.head_dim
    kv = cfg.n_kv_heads * cfg.head_dim
    ff = cfg.d_ff
    gates = 2 if cfg.act == "swiglu" else 1
    per_layer_act = (
        d              # quantized attention input (shared by q/k/v)
        + qh + 2 * kv  # q/k/v projection outputs
        + qh + d       # wo input + output
        + d            # quantized FFN input
        + gates * ff   # up (+gate) outputs
        + ff + d       # down input + output
    )
    per_layer_kv = 2 * cache_len * kv + 2 * kv  # read k+v rings, write new row
    head = d  # quantized post-final-norm hidden into the LM head
    return per_layer_act, per_layer_kv, head


def transformer_decode_bytes(cfg, cache_len: int, *, act_bytes: int,
                             kv_bytes: int) -> int:
    """Activation + KV bytes one decode token moves through the compute unit.

    Counts the tensors entering/leaving GEMMs between layers and the ring
    cache read/write; excludes weights (identical both paths), float-island
    internals (f32 on both paths), and the logits (model output).
    """
    per_layer_act, per_layer_kv, head = _transformer_decode_elements(cfg, cache_len)
    return cfg.n_layers * (per_layer_act * act_bytes + per_layer_kv * kv_bytes) \
        + head * act_bytes


def transformer_decode_bytes_mixed(cfg, cache_len: int, policy) -> int:
    """Per-token decode bytes under a mixed per-group precision plan.

    Each scan group's layers (and its slice of the KV cache) move bytes at
    that group's grid width — 1 byte where the precision DSE dropped the
    group to the int8 rung, 2 where it stayed int16.
    """
    from repro.models import transformer as T

    per_layer_act, per_layer_kv, head = _transformer_decode_elements(cfg, cache_len)
    pattern, g, r = T._split(cfg)

    def group_bytes(name, n_layers):
        width = policy.fmt_for(name).total_bits // 8
        return n_layers * (per_layer_act + per_layer_kv) * width

    total = sum(group_bytes(f"g{i}", g) for i in range(len(pattern)))
    total += sum(group_bytes(f"tail{j}", 1) for j in range(r))
    return total + head * (policy.fmt_for("head").total_bits // 8)


def lenet_activation_elements(spec) -> dict:
    """Per-grid activation elements of the CNN, keyed by layer name.

    The grid convention of DESIGN.md §11: ``fmt_for(L)`` is layer L's
    *input* activation grid, so layer L-1's output (and its grid-transparent
    pooled map) are attributed to layer L.  The classifier output is the
    model output (exact int32 read-out) and is excluded.
    """
    from repro.models.cnn import cnn_layer_names

    names = cnn_layer_names(spec)
    el = {n: 0 for n in names}
    hw, ch = spec.input_hw, spec.input_ch
    el[names[0]] += hw * hw * ch  # quantized input
    for i, (cout, k, stride, pad, pool) in enumerate(spec.convs):
        hw = (hw + 2 * pad - k) // stride + 1
        el[names[i + 1]] += hw * hw * cout  # conv output (ReLU fused)
        if pool:
            hw //= pool
            el[names[i + 1]] += hw * hw * cout  # pooled map, same grid
        ch = cout
    nc = len(spec.convs)
    for i, wd in enumerate(spec.fcs):
        el[names[nc + i + 1]] += wd
    return el


def lenet_activation_bytes(spec, *, act_bytes: int) -> int:
    """Per-sample activation bytes crossing the unit at a uniform width."""
    return sum(lenet_activation_elements(spec).values()) * act_bytes


def lenet_activation_bytes_mixed(spec, policy) -> int:
    """Per-sample activation bytes under a mixed per-layer precision plan."""
    return sum(
        el * (policy.fmt_for(name).total_bits // 8)
        for name, el in lenet_activation_elements(spec).items()
    )


# ---------------------------------------------------------------------------
# drift rows (the original q16 end-to-end rows)
# ---------------------------------------------------------------------------


def lenet_row(seed: int = 0, batches: int = 4) -> dict:
    from repro.core.template import default_template
    from repro.data.pipeline import synthetic_images
    from repro.models.cnn import (
        LENET, calibrate_cnn_policy, cnn_forward, init_cnn, quantize_cnn_params,
    )

    params = init_cnn(jax.random.PRNGKey(seed), LENET, scale=0.4)
    tpl_f = default_template("xla")
    tpl_q = default_template("q16")
    cal_img, _ = synthetic_images(7, 0, 8, LENET.input_hw, LENET.input_ch,
                                  LENET.n_classes)
    policy = calibrate_cnn_policy(tpl_q, LENET, params, cal_img)
    qp = quantize_cnn_params(tpl_q, LENET, params, policy)

    eng = tpl_q.engine
    q0, d0 = eng.counters["quantize_calls"], eng.counters["dequantize_calls"]
    lf, lq = [], []
    for b in range(batches):
        img, _ = synthetic_images(99, 1000 + b, 16, LENET.input_hw,
                                  LENET.input_ch, LENET.n_classes)
        lf.append(cnn_forward(tpl_f, LENET, params, img))
        lq.append(cnn_forward(tpl_q, LENET, qp, img, policy=policy))
    row = {
        "bench": "q16_drift_lenet",
        "activation_fmt": policy.fmt.name,
        "batches": batches,
        **_agreement(jnp.concatenate(lf), jnp.concatenate(lq)),
        "quantize_calls": eng.counters["quantize_calls"] - q0,
        "dequantize_calls": eng.counters["dequantize_calls"] - d0,
        "act_bytes_float": lenet_activation_bytes(LENET, act_bytes=4),
        "act_bytes_q16": lenet_activation_bytes(LENET, act_bytes=2),
    }
    row["bytes_ratio"] = round(row["act_bytes_q16"] / row["act_bytes_float"], 3)
    return row


def transformer_row(seed: int = 0, arch: str = "qwen2-0.5b") -> dict:
    from repro.configs import get_config, reduced
    from repro.core.template import default_template
    from repro.models import transformer as T

    cfg = reduced(get_config(arch))
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    tpl_f = default_template("xla")
    tpl_q = default_template("q16")
    cal = jax.random.randint(jax.random.PRNGKey(seed + 9), (2, 16), 0, cfg.vocab)
    policy = T.calibrate_policy(tpl_q, cfg, params, cal)
    qp = T.quantize_params(tpl_q, cfg, params, policy)

    # teacher-forced per-position drift on a fixed seed set
    toks = jax.random.randint(jax.random.PRNGKey(seed + 1), (4, 32), 0, cfg.vocab)
    lf, _ = T.forward(tpl_f, cfg, params, toks, mode="fwd")
    lq, _ = T.forward(tpl_q, cfg, qp, toks, mode="fwd", policy=policy)

    cache_len = 48
    return {
        "bench": "q16_drift_transformer",
        "arch": cfg.name,
        "activation_fmt": policy.fmt.name,
        "positions": int(np.prod(toks.shape)),
        **_agreement(lf, lq),
        "per_token_bytes_float": transformer_decode_bytes(
            cfg, cache_len, act_bytes=4, kv_bytes=4),
        "per_token_bytes_q16": transformer_decode_bytes(
            cfg, cache_len, act_bytes=2, kv_bytes=2),
    }


# ---------------------------------------------------------------------------
# per-layer solo-flip precision sweep (the DSE's measurement side, §11)
# ---------------------------------------------------------------------------


_QAT_CACHE: dict = {}


def train_lenet_qat(seed: int = 0, float_steps: int = 60,
                    qat_steps: int = 30):
    """The QAT clamp recipe of examples/train_lenet_q214 in miniature.

    Phase 1 trains float; phase 2 fine-tunes with fake-quant Q2.14 (STE),
    whose saturating clamp trains the activations into the grid's [-2, 2)
    range — the recipe that makes a *deployed* fixed-point network agree
    with its float reference (an unclamped random/float-trained net drifts
    as soon as an internal activation leaves the grid).  Memoized: the
    kernel-table gate and this module's sweep measure the same network.
    """
    key = (seed, float_steps, qat_steps)
    if key in _QAT_CACHE:
        return _QAT_CACHE[key]
    from functools import partial

    from repro.core.template import default_template
    from repro.data.pipeline import synthetic_images
    from repro.models.cnn import LENET, cnn_forward, init_cnn
    from repro.optim import AdamW, adamw_init, adamw_update

    tpl = default_template("xla")
    params = init_cnn(jax.random.PRNGKey(seed), LENET, scale=0.4)
    opt = AdamW(lr=3e-3, weight_decay=0.0)
    opt_state = adamw_init(params)

    def loss_fn(p, img, lab, quantized):
        logits = cnn_forward(tpl, LENET, p, img, quantized=quantized)
        onehot = jax.nn.one_hot(lab, LENET.n_classes)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        return -(onehot * logp).sum(-1).mean()

    @partial(jax.jit, static_argnums=(4,))
    def train_step(p, o, img, lab, quantized):
        l, g = jax.value_and_grad(loss_fn)(p, img, lab, quantized)
        p, o, _ = adamw_update(AdamW(lr=3e-3, weight_decay=0.0), g, o, p)
        return p, o, l

    for step in range(float_steps + qat_steps):
        img, lab = synthetic_images(0, step, 32, LENET.input_hw,
                                    LENET.input_ch, LENET.n_classes)
        params, opt_state, _ = train_step(params, opt_state, img, lab,
                                          step >= float_steps)
    _QAT_CACHE[key] = params
    return params


def lenet_precision_sweep(seed: int = 0, budget: float = 0.99) -> dict:
    """Solo-flip drift per LeNet layer + the chosen mixed int8/int16 plan.

    Measures the QAT-trained LeNet (:func:`train_lenet_qat`) — the clamp
    recipe holds its activations inside the grid, so the int8 rung has real
    headroom and layers actually drop.  The reference is the *fake-quant*
    float forward: the clamp is part of the trained model, so that is the
    semantics deployment must agree with.  The sweep itself runs inside
    :func:`calibrate_cnn_precision` (which pins every per-layer choice in
    the PlanRegistry with ``source: measured`` — a warm plan store replays
    the pins with zero forwards); this row reads the pins back and
    evaluates the *composed* mixed plan on the measurement batches.
    """
    from repro.core.template import default_template
    from repro.data.pipeline import synthetic_images
    from repro.models.cnn import (
        LENET, calibrate_cnn_policy, calibrate_cnn_precision, cnn_forward,
        cnn_layer_names, quantize_cnn_params,
    )

    params = train_lenet_qat(seed)
    tpl_f = default_template("xla")
    tpl_q = default_template("q16")
    cal_img, _ = synthetic_images(7, 0, 16, LENET.input_hw, LENET.input_ch,
                                  LENET.n_classes)
    policy = calibrate_cnn_policy(tpl_q, LENET, params, cal_img)
    # the DSE measurement set: large enough that the composed-network budget
    # check inside the calibrator is meaningful (the same batches the row's
    # agreement is evaluated on — the budget is a guarantee on this set)
    meas = jnp.concatenate([
        synthetic_images(99, 1000 + b, 16, LENET.input_hw, LENET.input_ch,
                         LENET.n_classes)[0]
        for b in range(4)
    ])
    ref_logits = cnn_forward(tpl_f, LENET, params, meas, quantized=True)
    mixed = calibrate_cnn_precision(
        tpl_q, LENET, params, meas, budget=budget, policy=policy,
        ref=jnp.argmax(ref_logits, axis=-1))

    reg, hw = tpl_q.engine.plan_cache, tpl_q.config.hw
    drift, plan = {}, {}
    for name in cnn_layer_names(LENET):
        pin = reg.precision_for(LENET.name, name, hw)
        drift[name] = pin.drift
        plan[name] = pin.fmt.name

    qp = quantize_cnn_params(tpl_q, LENET, params, mixed)
    mixed_logits = cnn_forward(tpl_q, LENET, qp, meas, policy=mixed)

    el = lenet_activation_elements(LENET)
    int8_layers = [n for n, f in mixed.layer_fmts if f.total_bits == 8]
    row = {
        "bench": "precision_dse_lenet",
        "net": LENET.name,
        "budget": budget,
        "base_fmt": policy.fmt.name,
        "drift": drift,          # feed back via calibrate_cnn_precision(drift=)
        "plan": plan,
        "int8_layers": sorted(int8_layers),
        **_agreement(ref_logits, mixed_logits),
        "act_bytes_q16": lenet_activation_bytes(LENET, act_bytes=2),
        "act_bytes_mixed": lenet_activation_bytes_mixed(LENET, mixed),
        "int8_layer_bytes_q16": {n: 2 * el[n] for n in int8_layers},
        "int8_layer_bytes_mixed": {n: el[n] for n in int8_layers},
    }
    row["bytes_saved"] = row["act_bytes_q16"] - row["act_bytes_mixed"]
    return row


def transformer_precision_sweep(seed: int = 0, budget: float = 0.99,
                                arch: str = "qwen2-0.5b") -> dict:
    """Solo-flip drift per transformer scan group + the chosen mixed plan."""
    from repro.configs import get_config, reduced
    from repro.core.template import default_template
    from repro.models import transformer as T

    cfg = reduced(get_config(arch))
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    tpl_f = default_template("xla")
    tpl_q = default_template("q16")
    cal = jax.random.randint(jax.random.PRNGKey(seed + 9), (2, 16), 0, cfg.vocab)
    policy = T.calibrate_policy(tpl_q, cfg, params, cal)
    # measure the DSE on the same teacher-forced position set the row's
    # agreement is evaluated on (the budget is a guarantee on this set)
    meas = jax.random.randint(jax.random.PRNGKey(seed + 1), (4, 32), 0, cfg.vocab)
    mixed = T.calibrate_precision(tpl_q, cfg, params, meas,
                                  budget=budget, policy=policy)

    reg, hw = tpl_q.engine.plan_cache, tpl_q.config.hw
    drift, plan = {}, {}
    for name in T.precision_group_names(cfg):
        pin = reg.precision_for(cfg.name, name, hw)
        drift[name] = pin.drift
        plan[name] = pin.fmt.name

    qp = T.quantize_params(tpl_q, cfg, params, mixed)
    toks = jax.random.randint(jax.random.PRNGKey(seed + 1), (4, 32), 0, cfg.vocab)
    lf, _ = T.forward(tpl_f, cfg, params, toks, mode="fwd")
    lq, _ = T.forward(tpl_q, cfg, qp, toks, mode="fwd", policy=mixed)

    cache_len = 48
    int8_groups = [n for n, f in mixed.layer_fmts if f.total_bits == 8]
    return {
        "bench": "precision_dse_transformer",
        "net": cfg.name,
        "budget": budget,
        "base_fmt": policy.fmt.name,
        "drift": drift,       # feed back via T.calibrate_precision(drift=)
        "plan": plan,
        "int8_groups": sorted(int8_groups),
        **_agreement(lf, lq),
        "per_token_bytes_q16": transformer_decode_bytes(
            cfg, cache_len, act_bytes=2, kv_bytes=2),
        "per_token_bytes_mixed": transformer_decode_bytes_mixed(
            cfg, cache_len, mixed),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None, help="write the rows as JSON here")
    ap.add_argument("--assert-agreement", type=float, default=None,
                    help="fail unless argmax agreement >= this on every row")
    ap.add_argument("--budget", type=float, default=0.99,
                    help="precision-DSE accuracy budget (min solo-flip "
                         "argmax agreement to drop a layer to int8)")
    args = ap.parse_args(argv)

    print("== q16 end-to-end drift (grid-resident QTensor path) ==")
    rows = [lenet_row(), transformer_row()]
    for row in rows:
        print(json.dumps(row))
    lenet, tfm = rows
    assert lenet["quantize_calls"] == lenet["batches"], (
        "LeNet must quantize exactly once per forward (the input)")
    assert lenet["dequantize_calls"] == lenet["batches"], (
        "LeNet must dequantize exactly once per forward (the classifier)")
    ratio = tfm["per_token_bytes_q16"] / tfm["per_token_bytes_float"]
    assert ratio <= 0.5, f"q16 per-token bytes ratio {ratio} > 0.5"
    assert lenet["bytes_ratio"] <= 0.5

    print("\n== per-layer precision DSE sweep (solo-flip drift, §11) ==")
    sweeps = [lenet_precision_sweep(budget=args.budget),
              transformer_precision_sweep(budget=args.budget)]
    for row in sweeps:
        print(json.dumps(row))
    lsw = sweeps[0]
    assert lsw["int8_layers"], (
        "the QAT-trained LeNet must drop at least one layer to the int8 rung "
        "— the clamp recipe trains its activations into the grid")
    # the structural half-bytes law: every int8-chosen layer moves exactly
    # half the q16 bytes, and the network totals agree with the per-layer sum
    for n in lsw["int8_layers"]:
        assert lsw["int8_layer_bytes_mixed"][n] * 2 == lsw["int8_layer_bytes_q16"][n]
    assert lsw["act_bytes_q16"] - lsw["bytes_saved"] == lsw["act_bytes_mixed"]
    for row in sweeps:
        assert row["plan"], "the DSE must record a choice for every layer"
        assert all(v is None or 0.0 <= v <= 1.0 for v in row["drift"].values())
    rows += sweeps

    if args.assert_agreement is not None:
        for row in rows:
            if row["argmax_agreement"] < args.assert_agreement:
                raise SystemExit(
                    f"{row['bench']}: argmax agreement "
                    f"{row['argmax_agreement']:.4f} < {args.assert_agreement}"
                )
        print(f"argmax agreement gate OK (>= {args.assert_agreement})")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"wrote {args.out}")
    return rows


if __name__ == "__main__":
    main()
