"""Roofline report: aggregate the dry-run JSON cache into the §Roofline table.

    PYTHONPATH=src python -m benchmarks.roofline_report [--dir experiments/dryrun]

Per (arch x shape x mesh): the three terms in seconds, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs, per-device memory, and a one-line
what-would-move-it-down note derived from the collective/dot profile.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

HBM_PER_CHIP = 16 * 2**30  # v5e


def _advice(rec: dict) -> str:
    r = rec["roofline"]
    dom = r["dominant"]
    h = rec.get("hlo", {})
    if dom == "collective_s":
        big = max(h.get("coll_bytes", {"": 0}), key=lambda k: h["coll_bytes"].get(k, 0))
        return (f"cut {big} bytes (bf16 wire / SP instead of TP all-reduce / "
                f"overlap with compute)")
    if dom == "memory_s":
        if rec["kind"] == "decode":
            return "KV-cache reads dominate: quantize cache / wider batch per chip"
        return ("attention p-matrix + remat traffic: flash kernel keeps p in "
                "VMEM; bf16 intermediates; fewer recomputes")
    if rec.get("useful_ratio", 1) < 0.5:
        return "compute-bound but wasteful: causal-chunk skip + remat policy"
    return "compute-bound: increase per-chip batch or shrink TP degree"


def load(dir_: str, mesh: str | None = None, tag: str = "") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if "skipped" in rec:
            continue
        if mesh and rec.get("mesh") != mesh:
            continue
        if rec.get("tag", "") != tag:
            continue
        rows.append(rec)
    return rows


def table(rows: list[dict]) -> str:
    out = []
    hdr = (f"| {'arch':24s} | {'shape':11s} | {'mesh':7s} | {'compute_s':>9s} | "
           f"{'memory_s':>9s} | {'collect_s':>9s} | {'dominant':10s} | "
           f"{'useful':>6s} | {'frac':>6s} | {'GiB/dev':>7s} | fits |")
    out.append(hdr)
    out.append("|" + "-" * (len(hdr) - 2) + "|")
    for rec in rows:
        r = rec["roofline"]
        mem = rec["memory"].get("per_device_total_bytes", 0)
        out.append(
            f"| {rec['arch']:24s} | {rec['shape']:11s} | {rec['mesh']:7s} | "
            f"{r['compute_s']:9.3e} | {r['memory_s']:9.3e} | "
            f"{r['collective_s']:9.3e} | {r['dominant'][:-2]:10s} | "
            f"{rec['useful_ratio']:6.3f} | {rec['roofline_fraction']:6.3f} | "
            f"{mem/2**30:7.2f} | {'Y' if mem <= HBM_PER_CHIP else 'N':4s} |"
        )
    return "\n".join(out)


def pick_hillclimb_cells(rows: list[dict]) -> dict:
    """worst roofline fraction / most collective-bound / most representative."""
    runnable = [r for r in rows if r["kind"] == "train" or r["kind"] == "prefill"]
    if not runnable:
        runnable = rows
    worst = min(runnable, key=lambda r: r["roofline_fraction"])

    def coll_share(r):
        t = r["roofline"]
        total = t["compute_s"] + t["memory_s"] + t["collective_s"]
        return t["collective_s"] / max(total, 1e-30)

    coll = max(rows, key=coll_share)
    # most representative of the paper's technique: the biggest dense-GEMM
    # training cell (the compute unit doing what the template was built for)
    dense_train = [r for r in rows if r["kind"] == "train"]
    rep = max(dense_train, key=lambda r: r["model_flops"]) if dense_train else worst
    return {
        "worst_fraction": (worst["arch"], worst["shape"], worst["mesh"]),
        "most_collective_bound": (coll["arch"], coll["shape"], coll["mesh"]),
        "most_representative": (rep["arch"], rep["shape"], rep["mesh"]),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--tag", default="")
    args = ap.parse_args(argv)
    rows = load(args.dir, args.mesh, args.tag)
    if not rows:
        print(f"(no dry-run records in {args.dir} for mesh {args.mesh})")
        return []
    print(table(rows))
    print("\nadvice per dominant term:")
    for rec in rows:
        print(f"  {rec['arch']:24s} {rec['shape']:11s}: {_advice(rec)}")
    picks = pick_hillclimb_cells(rows)
    print("\nhillclimb cell selection:", json.dumps(picks, indent=1))
    return rows


if __name__ == "__main__":
    main()
