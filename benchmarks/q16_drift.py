"""Back-compat alias: this benchmark moved to :mod:`benchmarks.precision_drift`.

The original q16 end-to-end drift rows (and their CI gates) are emitted by
the extended per-layer precision sweep; ``python -m benchmarks.q16_drift``
keeps working, as do the structural-bytes imports in ``kernel_table``.
"""
from __future__ import annotations

from benchmarks.precision_drift import (  # noqa: F401
    _agreement,
    lenet_activation_bytes,
    lenet_activation_elements,
    lenet_precision_sweep,
    lenet_row,
    main,
    train_lenet_qat,
    transformer_decode_bytes,
    transformer_decode_bytes_mixed,
    transformer_precision_sweep,
    transformer_row,
)

if __name__ == "__main__":
    main()
