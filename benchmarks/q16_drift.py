"""End-to-end Q2.14/q16 accuracy drift + per-token activation bytes.

The paper's claim is that an entire network can run in 16-bit fixed point
with negligible accuracy loss while moving half the activation bytes.  This
benchmark measures both halves of that claim for the grid-resident QTensor
path (DESIGN.md §8) on two workloads:

  * LeNet — the paper's own case-study CNN: the whole forward runs on the
    int16 grid (one quantize at the input, one exact accumulator read-out at
    the classifier).
  * the reduced transformer config (qwen2-0.5b-smoke) — the ROADMAP "q16
    transformer inference" item: attention + MLP projections grid-resident,
    int16 KV cache, float only at the designated islands.

Drift is measured teacher-forced (per-position logits under identical
inputs), so one early disagreement cannot cascade into a misleadingly low
token match.  Bytes are structural: activations crossing the compute unit
between layers plus KV-cache traffic, at 2 bytes (int16) vs 4 (f32); float
islands run f32 on both paths and the final logits are model *output*, so
neither is counted.  The q16/float ratio is therefore exactly 0.5 — the
acceptance bound "q16 ≤ half the float path" is checked, not assumed.

    PYTHONPATH=src python -m benchmarks.q16_drift [--out q16_drift.json]
        [--assert-agreement 0.99]
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np


def _agreement(lf, lq) -> dict:
    lf, lq = jnp.asarray(lf), jnp.asarray(lq)
    return {
        "logit_mae": float(jnp.abs(lf - lq).mean()),
        "logit_max_err": float(jnp.abs(lf - lq).max()),
        "argmax_agreement": float(
            (jnp.argmax(lf, -1) == jnp.argmax(lq, -1)).mean()
        ),
    }


# ---------------------------------------------------------------------------
# structural bytes (per token / per sample activations crossing the unit)
# ---------------------------------------------------------------------------


def transformer_decode_bytes(cfg, cache_len: int, *, act_bytes: int,
                             kv_bytes: int) -> int:
    """Activation + KV bytes one decode token moves through the compute unit.

    Counts the tensors entering/leaving GEMMs between layers and the ring
    cache read/write; excludes weights (identical both paths), float-island
    internals (f32 on both paths), and the logits (model output).
    """
    d = cfg.d_model
    qh = cfg.eff_heads * cfg.head_dim
    kv = cfg.n_kv_heads * cfg.head_dim
    ff = cfg.d_ff
    gates = 2 if cfg.act == "swiglu" else 1
    per_layer_act = (
        d              # quantized attention input (shared by q/k/v)
        + qh + 2 * kv  # q/k/v projection outputs
        + qh + d       # wo input + output
        + d            # quantized FFN input
        + gates * ff   # up (+gate) outputs
        + ff + d       # down input + output
    )
    per_layer_kv = 2 * cache_len * kv + 2 * kv  # read k+v rings, write new row
    head = d  # quantized post-final-norm hidden into the LM head
    return cfg.n_layers * (per_layer_act * act_bytes + per_layer_kv * kv_bytes) \
        + head * act_bytes


def lenet_activation_bytes(spec, *, act_bytes: int) -> int:
    """Per-sample activation elements crossing the unit for the CNN zoo."""
    hw, ch = spec.input_hw, spec.input_ch
    total = hw * hw * ch  # quantized input
    for cout, k, stride, pad, pool in spec.convs:
        hw = (hw + 2 * pad - k) // stride + 1
        total += hw * hw * cout  # conv output (ReLU fused in-kernel)
        if pool:
            hw //= pool
            total += hw * hw * cout  # pooled map feeding the next stage
        ch = cout
    fan = hw * hw * ch
    for wd in spec.fcs:  # classifier output excluded: it is the model output
        total += wd
    return total * act_bytes


# ---------------------------------------------------------------------------
# drift rows
# ---------------------------------------------------------------------------


def lenet_row(seed: int = 0, batches: int = 4) -> dict:
    from repro.core.template import default_template
    from repro.data.pipeline import synthetic_images
    from repro.models.cnn import (
        LENET, calibrate_cnn_policy, cnn_forward, init_cnn, quantize_cnn_params,
    )

    params = init_cnn(jax.random.PRNGKey(seed), LENET, scale=0.4)
    tpl_f = default_template("xla")
    tpl_q = default_template("q16")
    cal_img, _ = synthetic_images(7, 0, 8, LENET.input_hw, LENET.input_ch,
                                  LENET.n_classes)
    policy = calibrate_cnn_policy(tpl_q, LENET, params, cal_img)
    qp = quantize_cnn_params(tpl_q, LENET, params, policy)

    eng = tpl_q.engine
    q0, d0 = eng.counters["quantize_calls"], eng.counters["dequantize_calls"]
    lf, lq = [], []
    for b in range(batches):
        img, _ = synthetic_images(99, 1000 + b, 16, LENET.input_hw,
                                  LENET.input_ch, LENET.n_classes)
        lf.append(cnn_forward(tpl_f, LENET, params, img))
        lq.append(cnn_forward(tpl_q, LENET, qp, img, policy=policy))
    row = {
        "bench": "q16_drift_lenet",
        "activation_fmt": policy.fmt.name,
        "batches": batches,
        **_agreement(jnp.concatenate(lf), jnp.concatenate(lq)),
        "quantize_calls": eng.counters["quantize_calls"] - q0,
        "dequantize_calls": eng.counters["dequantize_calls"] - d0,
        "act_bytes_float": lenet_activation_bytes(LENET, act_bytes=4),
        "act_bytes_q16": lenet_activation_bytes(LENET, act_bytes=2),
    }
    row["bytes_ratio"] = round(row["act_bytes_q16"] / row["act_bytes_float"], 3)
    return row


def transformer_row(seed: int = 0, arch: str = "qwen2-0.5b") -> dict:
    from repro.configs import get_config, reduced
    from repro.core.template import default_template
    from repro.models import transformer as T

    cfg = reduced(get_config(arch))
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    tpl_f = default_template("xla")
    tpl_q = default_template("q16")
    cal = jax.random.randint(jax.random.PRNGKey(seed + 9), (2, 16), 0, cfg.vocab)
    policy = T.calibrate_policy(tpl_q, cfg, params, cal)
    qp = T.quantize_params(tpl_q, cfg, params, policy)

    # teacher-forced per-position drift on a fixed seed set
    toks = jax.random.randint(jax.random.PRNGKey(seed + 1), (4, 32), 0, cfg.vocab)
    lf, _ = T.forward(tpl_f, cfg, params, toks, mode="fwd")
    lq, _ = T.forward(tpl_q, cfg, qp, toks, mode="fwd", policy=policy)

    cache_len = 48
    return {
        "bench": "q16_drift_transformer",
        "arch": cfg.name,
        "activation_fmt": policy.fmt.name,
        "positions": int(np.prod(toks.shape)),
        **_agreement(lf, lq),
        "per_token_bytes_float": transformer_decode_bytes(
            cfg, cache_len, act_bytes=4, kv_bytes=4),
        "per_token_bytes_q16": transformer_decode_bytes(
            cfg, cache_len, act_bytes=2, kv_bytes=2),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None, help="write the rows as JSON here")
    ap.add_argument("--assert-agreement", type=float, default=None,
                    help="fail unless argmax agreement >= this on both rows")
    args = ap.parse_args(argv)

    print("== q16 end-to-end drift (grid-resident QTensor path) ==")
    rows = [lenet_row(), transformer_row()]
    for row in rows:
        print(json.dumps(row))
    lenet, tfm = rows
    assert lenet["quantize_calls"] == lenet["batches"], (
        "LeNet must quantize exactly once per forward (the input)")
    assert lenet["dequantize_calls"] == lenet["batches"], (
        "LeNet must dequantize exactly once per forward (the classifier)")
    ratio = tfm["per_token_bytes_q16"] / tfm["per_token_bytes_float"]
    assert ratio <= 0.5, f"q16 per-token bytes ratio {ratio} > 0.5"
    assert lenet["bytes_ratio"] <= 0.5
    if args.assert_agreement is not None:
        for row in rows:
            if row["argmax_agreement"] < args.assert_agreement:
                raise SystemExit(
                    f"{row['bench']}: argmax agreement "
                    f"{row['argmax_agreement']:.4f} < {args.assert_agreement}"
                )
        print(f"argmax agreement gate OK (>= {args.assert_agreement})")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"wrote {args.out}")
    return rows


if __name__ == "__main__":
    main()
