"""Design-space exploration sweep — reproduces the paper's §III.E finding
that the template performs best when τ ≈ 2μ under resource constraints,
and derives the per-board compute-unit choice the paper reports.

Also runs the TPU-plane analogue: Pallas (bm, bn, bk) block selection under
the VMEM budget for representative GEMMs of the assigned LM architectures.
"""
from __future__ import annotations

from repro.core.dse import explore_board, explore_tpu_block
from repro.core.fpga_model import BOARDS, alexnet_layers


def run_fpga() -> dict:
    out = {}
    layers = alexnet_layers()
    for name, board in BOARDS.items():
        results = explore_board(board, layers, top=5)
        rows = [
            {
                "mu": r.mu,
                "tau": r.tau,
                "ratio": round(r.tau / r.mu, 2),
                "gops": round(r.gops, 1),
                "latency_ms": round(r.latency_ms, 2),
                "dsp": r.instance.dsp,
                "bram": r.instance.bram18,
            }
            for r in results
        ]
        out[name] = rows
    return out


def run_tpu() -> dict:
    """Block choice for the biggest GEMMs in the assigned archs (bf16)."""
    cases = {
        "qwen2.5-32b mlp (65536x27648x5120)": (65536, 27648, 5120),
        "llama-90b qkv (65536x10240x8192)": (65536, 10240, 8192),
        "qwen2-0.5b mlp (65536x4864x896)": (65536, 4864, 896),
        "granite expert (512x512x1536)": (512, 512, 1536),
    }
    out = {}
    for label, (m, n, k) in cases.items():
        ranked = explore_tpu_block(m, n, k, top=3)
        out[label] = [
            {
                "block": (b.bm, b.bn, b.bk),
                "score": round(s, 4),
                "vmem_MiB": round(b.vmem_bytes() / 2**20, 1),
                "ai_flops_per_byte": round(b.arithmetic_intensity(), 1),
            }
            for b, s in ranked
        ]
    return out


def main():
    print("== DSE: FPGA plane (paper §III.E — expect tau ~ 2*mu) ==")
    fpga = run_fpga()
    for board, rows in fpga.items():
        best = rows[0]
        print(f"{board:8s} best CU {best['mu']}x{best['tau']} "
              f"(ratio {best['ratio']}) {best['gops']} GOP/s "
              f"DSP {best['dsp']} BRAM {best['bram']}")
        ratios = [r["ratio"] for r in rows]
        print(f"         top-5 tau/mu ratios: {ratios}")
    print("\n== DSE: TPU plane (Pallas block selection under VMEM) ==")
    tpu = run_tpu()
    for label, rows in tpu.items():
        b = rows[0]
        print(f"{label:45s} -> block {b['block']} vmem {b['vmem_MiB']} MiB "
              f"AI {b['ai_flops_per_byte']} score {b['score']}")
    return {"fpga": fpga, "tpu": tpu}


if __name__ == "__main__":
    main()
