"""Paper Table 1 reproduction: resource utilization + GOP/s on three boards.

The paper reports, for AlexNet on the template with per-board compute units
(Ultra96 12x24 @169 MHz, ZCU104 20x30 @198 MHz, ZCU102 20x55 @167 MHz):
FF/LUT/BRAM/DSP utilization and 51 / 107 / 230 GOP/s.

We evaluate the analytic template model (core/fpga_model.py) at the same
compute-unit configurations and report modeled resources + conv-plane GOP/s
next to the paper's numbers.
"""
from __future__ import annotations

from repro.core.fpga_model import (
    BOARDS,
    TemplateInstance,
    alexnet_layers,
    evaluate_network,
)
from repro.core.tiling import ConvTiling, FCTiling

PAPER = {
    # board: (mu, tau, FF, LUT, BRAM, DSP, GOP/s, MHz)
    "Ultra96": (12, 24, 23_500, 15_600, 332, 334, 51, 169),
    "ZCU104": (20, 30, 46_000, 24_000, 594, 586, 107, 198),
    "ZCU102": (20, 55, 139_000, 57_000, 1_700, 1_700, 230, 167),
}


def instance_for(board_name: str) -> TemplateInstance:
    mu, tau = PAPER[board_name][:2]
    conv = ConvTiling(t_r=27, t_c=27, mu=mu, tau=tau)
    fc = FCTiling(lam=1024, omega=64, mu=mu, tau=tau)
    return TemplateInstance(board=BOARDS[board_name], conv=conv, fc=fc)


def run(batch: int = 4) -> list[dict]:
    rows = []
    layers = alexnet_layers()
    for name, vals in PAPER.items():
        inst = instance_for(name)
        rep = evaluate_network("alexnet", layers, inst, batch=batch)
        rows.append({
            "board": name,
            "cu": f"{inst.conv.mu}x{inst.conv.tau}",
            "dsp_model": inst.dsp,
            "dsp_paper": vals[5],
            "bram_model": inst.bram18,
            "bram_paper": vals[4],
            "lut_model": inst.lut,
            "lut_paper": vals[3],
            "ff_model": inst.ff,
            "ff_paper": vals[2],
            "gops_model": round(rep.conv_gops, 1),
            "gops_paper": vals[6],
            "gops_all_layers": round(rep.gops, 1),
            "latency_ms": round(rep.latency_ms, 3),
            "peak_gops": round(inst.peak_gops, 1),
            "fits": inst.fits(),
        })
    return rows


def main():
    print("== Table 1: resource utilization + performance (AlexNet) ==")
    rows = run()
    hdr = (f"{'board':8s} {'CU':7s} {'DSP m/p':12s} {'BRAM m/p':12s} "
           f"{'GOP/s m/p':12s} {'peak':7s} {'lat ms':8s} fits")
    print(hdr)
    for r in rows:
        print(
            f"{r['board']:8s} {r['cu']:7s} "
            f"{r['dsp_model']:4d}/{r['dsp_paper']:<6d} "
            f"{r['bram_model']:4d}/{r['bram_paper']:<6d} "
            f"{r['gops_model']:5.1f}/{r['gops_paper']:<5.0f} "
            f"{r['peak_gops']:6.1f} {r['latency_ms']:8.3f} {r['fits']}"
        )
    return rows


if __name__ == "__main__":
    main()
